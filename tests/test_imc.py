"""IMC subsystem: bit-serial kernel goldens (bit-exact vs the packed
matmul kernels at 8-bit activations), oracle parity across formats and
precisions, the array event/energy model, and engine-level routing +
accounting (interpret mode on CPU)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import AMCConfig
from repro.core import quant, ternary
from repro.imc import BitSerialArray, ImcEventLedger, energy
from repro.kernels import ops, ref
from repro.kernels.imc_dot import mag_bits, qmax_for, quantize_activations


def _int_activations(rng, M, K, q=127):
    """Integer-valued bf16 activations with row absmax == q (the abits
    qmax), so the activation scale is exactly 1.0, quantization is exact,
    and the IMC path is bit-exact."""
    x = rng.integers(-q, q + 1, size=(M, K)).astype(np.float32)
    x[:, 0] = q
    return jnp.asarray(x, jnp.bfloat16)


def _ternary_weights(key, K, N):
    t, scale = ternary.ternarize(jax.random.normal(key, (K, N)))
    return ternary.pack_ternary_2bit(t), scale


# ---------------------------------------------------------------------------
# bit-exact goldens vs the packed matmul kernels (the acceptance bar)
# ---------------------------------------------------------------------------

def test_imc_dot_bit_exact_vs_ternary_matmul():
    M, K, N = 128, 512, 256
    wp, scale = _ternary_weights(jax.random.PRNGKey(0), K, N)
    x = _int_activations(np.random.default_rng(0), M, K)
    y = ops.imc_dot(x, wp, scale, fmt="ternary", abits=8)
    golden = ops.ternary_matmul(x, wp, scale)
    np.testing.assert_array_equal(np.asarray(y, np.float32),
                                  np.asarray(golden, np.float32))


def test_imc_dual_dot_bit_exact_vs_dual_plane_matmul():
    M, K, N = 128, 256, 256
    k = jax.random.PRNGKey(1)
    qh, sh = quant.quantize_int4(jax.random.normal(k, (K, N)), axis=0)
    ql, sl = quant.quantize_int4(
        jax.random.normal(jax.random.fold_in(k, 1), (K, N)), axis=0)
    buf = quant.pack_int4_pair(qh, ql)
    x = _int_activations(np.random.default_rng(1), M, K)
    yh, yl = ops.imc_dual_dot(x, buf, sh, sl, abits=8)
    gh, gl = ops.dual_plane_matmul(x, buf, sh, sl)
    np.testing.assert_array_equal(np.asarray(yh, np.float32),
                                  np.asarray(gh, np.float32))
    np.testing.assert_array_equal(np.asarray(yl, np.float32),
                                  np.asarray(gl, np.float32))


# ---------------------------------------------------------------------------
# oracle parity across formats, precisions and blocks
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["ternary", "int4", "int8"])
@pytest.mark.parametrize("abits", [1, 4, 8])
def test_imc_dot_matches_oracle_exact(fmt, abits):
    """Bit-exact kernel==oracle wherever activation quantization is exact
    (integer rows at the precision's qmax -> unit scale)."""
    M, K, N = 128, 256, 128
    key = jax.random.PRNGKey(2)
    if fmt == "ternary":
        wp, scale = _ternary_weights(key, K, N)
    elif fmt == "int4":
        q, scale = quant.quantize_int4(jax.random.normal(key, (K, N)),
                                       axis=0)
        wp = quant.pack_int4_pair(q[0::2], q[1::2])
    else:
        wp, scale = quant.quantize_int8(jax.random.normal(key, (K, N)),
                                        axis=0)
    x = _int_activations(np.random.default_rng(2), M, K, q=qmax_for(abits))
    y = ops.imc_dot(x, wp, scale, fmt=fmt, abits=abits)
    r = ref.imc_dot_ref(x, wp, scale, fmt=fmt, abits=abits)
    np.testing.assert_array_equal(np.asarray(y, np.float32),
                                  np.asarray(r, np.float32))


def test_imc_dot_matches_oracle_random_inputs():
    """General bf16 inputs: the jitted wrapper's quantization may differ
    from the eager oracle's by 1 ulp on rounding ties, so tolerance."""
    M, K, N = 128, 256, 128
    wp, scale = _ternary_weights(jax.random.PRNGKey(3), K, N)
    x = jax.random.normal(jax.random.PRNGKey(4), (M, K), jnp.bfloat16)
    y = ops.imc_dot(x, wp, scale, fmt="ternary", abits=8)
    r = ref.imc_dot_ref(x, wp, scale, fmt="ternary", abits=8)
    assert ref.rel_err(y, r) < 0.02


def test_imc_dot_block_sweep():
    M, K, N = 256, 1024, 256
    wp, scale = _ternary_weights(jax.random.PRNGKey(4), K, N)
    x = _int_activations(np.random.default_rng(5), M, K)
    r = ref.imc_dot_ref(x, wp, scale, fmt="ternary", abits=8)
    for bm, bk, bn in ((64, 256, 64), (128, 512, 256), (128, 1024, 128)):
        y = ops.imc_dot(x, wp, scale, fmt="ternary", abits=8, bm=bm, bk=bk,
                        bn=bn)
        np.testing.assert_array_equal(np.asarray(y, np.float32),
                                      np.asarray(r, np.float32))


def test_imc_dual_dot_matches_oracle():
    M, K, N = 128, 256, 128
    k = jax.random.PRNGKey(6)
    qh, sh = quant.quantize_int4(jax.random.normal(k, (K, N)), axis=0)
    ql, sl = quant.quantize_int4(
        jax.random.normal(jax.random.fold_in(k, 1), (K, N)), axis=0)
    buf = quant.pack_int4_pair(qh, ql)
    for abits in (4, 8):
        x = _int_activations(np.random.default_rng(6), M, K,
                             q=qmax_for(abits))
        yh, yl = ops.imc_dual_dot(x, buf, sh, sl, abits=abits)
        rh, rl = ref.imc_dual_dot_ref(x, buf, sh, sl, abits=abits)
        np.testing.assert_array_equal(np.asarray(yh, np.float32),
                                      np.asarray(rh, np.float32))
        np.testing.assert_array_equal(np.asarray(yl, np.float32),
                                      np.asarray(rl, np.float32))


def test_imc_precision_reconfigurable_monotone():
    """arXiv:2008.03378: more activation bits -> strictly better fidelity
    (on a fixed random problem)."""
    M, K, N = 128, 512, 128
    wp, scale = _ternary_weights(jax.random.PRNGKey(7), K, N)
    x = jax.random.normal(jax.random.PRNGKey(8), (M, K), jnp.bfloat16)
    dense = ref.ternary_matmul_ref(x, wp, scale)
    errs = [ref.rel_err(ops.imc_dot(x, wp, scale, fmt="ternary", abits=a),
                        dense) for a in (1, 4, 8)]
    assert errs[2] < errs[1] < errs[0], errs


def test_quantize_activations_ranges():
    x = jax.random.normal(jax.random.PRNGKey(9), (8, 64), jnp.bfloat16)
    for abits in (1, 4, 8):
        xq, xs = quantize_activations(x, abits)
        q = qmax_for(abits)
        assert int(jnp.max(jnp.abs(xq.astype(jnp.int32)))) <= q
        assert mag_bits(abits) == (1 if abits == 1 else abits - 1)
        # dequantized activations approximate the input
        err = ref.rel_err(xq.astype(jnp.float32) * xs, x)
        assert err < 1.0 / max(q - 1, 1) + 0.05, (abits, err)


# ---------------------------------------------------------------------------
# event/energy model invariants
# ---------------------------------------------------------------------------

def test_imc_event_counts_scale_with_precision():
    e4 = energy.imc_dot_events(2, 64, 32, abits=4)
    e8 = energy.imc_dot_events(2, 64, 32, abits=8)
    assert e4["wordline"] == 2 * 64 * 3 and e8["wordline"] == 2 * 64 * 7
    assert e4["adc"] == 2 * 32 * 3
    assert energy.energy_fj(e4) < energy.energy_fj(e8)


def test_dual_plane_shares_wordlines():
    """ONE wordline stream drives BOTH planes: 2x bitline/ADC, 1x WL."""
    e1 = energy.imc_dot_events(1, 64, 32, abits=8, planes=1)
    e2 = energy.imc_dot_events(1, 64, 32, abits=8, planes=2)
    assert e2["wordline"] == e1["wordline"]
    assert e2["bitline"] == 2 * e1["bitline"]
    assert e2["adc"] == 2 * e1["adc"]


def test_augmented_reads_cost_differently_from_normal():
    """Tables III/IV structure: augmented cells cost MORE per cell but
    fewer cells per value -> cheaper per value."""
    E = energy.EVENT_ENERGY_FJ
    assert E["read_8t_dynamic"] > E["read_6t"]
    assert E["read_7t"] > E["read_6t"]
    per_value_normal = 16 * E["read_6t"]
    per_value_int4 = 4 * E["read_8t_dynamic"]
    per_value_trit = 1 * E["read_7t"]
    assert per_value_int4 < per_value_normal
    assert per_value_trit < per_value_int4
    ev = energy.kv_read_events(10, 10, aug_bits=4)
    assert ev["read_6t"] == 160 and ev["read_8t_dynamic"] == 40


def test_matmul_events_by_impl():
    # packed impl fetches the array; imc impl computes in it
    fetch = energy.matmul_events(4, 256, 128, storage="ternary",
                                 impl="packed")
    imc = energy.matmul_events(4, 256, 128, storage="ternary", impl="imc",
                               abits=8)
    assert fetch == {"read_7t": 256 * 128}
    assert "wordline" in imc and "read_7t" not in imc
    # dense storage has no resident array: imc falls back to the fetch
    dense = energy.matmul_events(4, 256, 128, storage="dense", impl="imc")
    assert dense == {"read_6t": 16 * 256 * 128}


def test_bit_serial_array_logs_events():
    w = jax.random.normal(jax.random.PRNGKey(10), (256, 128))
    ledger = ImcEventLedger()
    arr = BitSerialArray.from_dense(w, fmt="ternary", abits=8,
                                    ledger=ledger)
    x = jax.random.normal(jax.random.PRNGKey(11), (8, 256), jnp.bfloat16)
    y = arr.dot(x)
    assert y.shape == (8, 128)
    d = ledger.describe()
    assert d["groups"]["imc_dot"]["events"]["wordline"] == 8 * 256 * 7
    assert d["energy_fj_total"] > 0
    # dual array: one WL stream, two outputs
    arr2 = BitSerialArray.from_dense_pair(
        w, jax.random.normal(jax.random.PRNGKey(12), (256, 128)),
        ledger=ImcEventLedger())
    yh, yl = arr2.dot(x)
    assert yh.shape == yl.shape == (8, 128)
    ev = arr2.ledger.counts
    assert ev[("imc_dot", "bitline")] == 2 * ev[("imc_dot", "wordline")] \
        * 128 // 256


def test_augmented_store_access_events():
    from repro.core.amc import AugmentedStore, Mode
    st_ = AugmentedStore((8, 8))
    st_.write_static(jnp.ones((8, 8)))
    assert st_.events == {"write_6t": 16 * 64}
    st_.set_mode(Mode.AUGMENTED_DUAL)
    st_.push_dynamic(jnp.ones((8, 8)) * 0.5)
    _ = st_.pop_dynamic()
    assert st_.events["write_8t_dynamic"] == 4 * 64
    assert st_.events["read_8t_dynamic"] == 4 * 64
    assert st_.energy_fj() > 0


# ---------------------------------------------------------------------------
# engine-level routing + accounting
# ---------------------------------------------------------------------------

def _engine(**amc_kw):
    from repro.launch.mesh import make_local_mesh
    from repro.serve import ServeEngine
    cfg = get_arch("qwen1.5-0.5b").reduced()
    return ServeEngine(cfg, make_local_mesh(), max_batch=2, max_seq=64,
                       prefill_chunk=16, **amc_kw)


def test_engine_imc_routing_decodes_and_accounts():
    from repro.serve import Request
    eng = _engine(weight_mode="ternary", matmul_impl="imc", imc_abits=8)
    out = eng.generate([Request(prompt=np.array([3, 5, 7], np.int32),
                                max_new_tokens=4, id=0)])
    assert len(out[0]) == 4
    imc = eng.stats()["imc"]
    assert imc["matmul_impl"] == "imc" and imc["imc_abits"] == 8
    w = imc["groups"]["weights"]["events"]
    assert "wordline" in w and "adc" in w      # in-array compute
    assert imc["energy_fj_total"] > 0 and imc["tokens"] > 0
    assert imc["energy_pj_per_token"] > 0


def test_engine_imc_logits_close_to_packed():
    """abits=8 activation quantization is a small perturbation of the
    packed kernel path on the same packed weights."""
    import jax as _jax
    from repro.models import model as M
    from repro.models.params import init_params
    from repro.models import augment
    cfg = get_arch("qwen1.5-0.5b").reduced()
    cfg_p = dataclasses.replace(cfg, amc=AMCConfig(weight_mode="ternary"))
    cfg_i = dataclasses.replace(cfg, amc=AMCConfig(weight_mode="ternary",
                                                   matmul_impl="imc",
                                                   imc_abits=8))
    dense = init_params(M.abstract_params(cfg), _jax.random.PRNGKey(0))
    packed = augment.augment_params(cfg_p, dense)
    tokens = _jax.random.randint(_jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab)
    y_p = M.forward(cfg_p, packed, {"tokens": tokens})
    y_i = M.forward(cfg_i, packed, {"tokens": tokens})
    assert ref.rel_err(y_i, y_p) < 0.1


def test_engine_kv_read_event_classes_follow_page_mode():
    """Normal pools bill read_6t for cache reads, augmented pools the 8T
    dynamic-read events — at different per-value cost (acceptance)."""
    from repro.serve import Request
    req = Request(prompt=np.array([3, 5, 7], np.int32), max_new_tokens=3,
                  id=0)
    eng_n = _engine(kv_mode="normal")
    eng_a = _engine(kv_mode="int4")
    eng_n.generate([req])
    eng_a.generate([Request(prompt=req.prompt.copy(), max_new_tokens=3,
                            id=0)])
    kv_n = eng_n.stats()["imc"]["groups"]["kv_read"]["events"]
    kv_a = eng_a.stats()["imc"]["groups"]["kv_read"]["events"]
    assert set(kv_n) == {"read_6t"}
    assert set(kv_a) == {"read_8t_dynamic"}
    sn, sa = eng_n.stats()["imc"], eng_a.stats()["imc"]
    assert sn["kv_read_fj_per_value_normal_mode"] \
        != sa["kv_read_fj_per_value_augmented_mode"]


def test_refresh_traffic_folds_into_energy_total():
    """Pool refresh maintenance must show up in the ledger's "refresh"
    group and hence in energy_fj_total (not as a side number)."""
    from repro.serve import Request
    eng = _engine(kv_mode="int4", retention_steps=2)
    # span several pages (page_size=16) so non-tail pages age and expire
    eng.generate([Request(prompt=np.array([3, 5, 7], np.int32),
                          max_new_tokens=40, id=0)])
    imc = eng.stats()["imc"]
    assert eng.pool.stats["refreshes"] > 0
    refresh_fj = imc["groups"]["refresh"]["energy_fj"]
    assert refresh_fj > 0 and imc["refresh_energy_fj"] == refresh_fj
    others = sum(d["energy_fj"] for g, d in imc["groups"].items()
                 if g != "refresh")
    assert imc["energy_fj_total"] == pytest.approx(others + refresh_fj)


def test_moe_ternary_expert_banks_pack_and_match_golden():
    """Ternary mode packs the 4-D expert banks; the packed forward matches
    the dequantized dense golden."""
    import jax as _jax
    from repro.models import augment, model as M
    from repro.models.params import init_params
    cfg = get_arch("qwen3-moe-30b-a3b").reduced()
    cfg_t = dataclasses.replace(cfg, amc=AMCConfig(weight_mode="ternary"))
    dense = init_params(M.abstract_params(cfg), _jax.random.PRNGKey(0))
    packed = augment.augment_params(cfg_t, dense)
    moe_p = packed["layers"]["moe"]
    assert "w_up_packed" in moe_p and "w_up" not in moe_p
    assert moe_p["w_up_packed"].dtype == jnp.uint8
    assert moe_p["w_up_packed"].shape[-2] * 4 == cfg_t.d_model
    # pspec view matches the packed tree
    ps = augment.augment_pspecs(cfg_t, M.abstract_params(cfg_t))
    assert ps["layers"]["moe"]["w_up_packed"].shape \
        == moe_p["w_up_packed"].shape
    deq = augment.dequant_params(cfg_t, packed)
    tokens = _jax.random.randint(_jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab)
    y_pack = M.forward(cfg_t, packed, {"tokens": tokens})
    cfg_n = dataclasses.replace(cfg, amc=AMCConfig(weight_mode="normal"))
    y_deq = M.forward(cfg_n, deq, {"tokens": tokens})
    assert ref.rel_err(y_pack, y_deq) < 0.03


def test_unknown_matmul_impl_raises():
    from repro.models import augment
    amc = AMCConfig(matmul_impl="nonsense")
    x = jnp.ones((4, 8), jnp.bfloat16)
    wp, scale = _ternary_weights(jax.random.PRNGKey(13), 8, 8)
    with pytest.raises(ValueError, match="matmul_impl"):
        augment.ternary_apply(x, wp, scale, amc=amc)
