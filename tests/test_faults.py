"""Retention-fault injection + self-healing serving (core/faults.py, the
stores' integrity machinery and the engine's heal policies).

The contract under test is the paper's static-survives / dynamic-decays
asymmetry made operational: faults are sampled deterministically from
the leakage physics, every corruption of a dynamic plane is DETECTED by
the integrity words before it can be served (zero silent corruption),
and recovery — scrub-from-master, recompute-via-preemption, retry with
backoff, drain-and-requeue on array loss — restores token streams that
are bit-identical to a fault-free run."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import faults as F
from repro.configs import get_arch
from repro.configs.base import AMCConfig
from repro.kernels import ref
from repro.kernels.quantize_pack_kv import quantize_pack_kv_pallas
from repro.launch.mesh import make_local_mesh
from repro.serve import Request, ServeEngine

MESH = make_local_mesh()


def _cfg(arch, **amc):
    base = dict(pool_mode="always-augmented", kv_mode="int4")
    base.update(amc)
    return dataclasses.replace(get_arch(arch).reduced(),
                               amc=AMCConfig(**base))


def _reqs(cfg, n, plen, max_new, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab, size=(plen,))
                    .astype(np.int32), max_new_tokens=max_new, id=i)
            for i in range(n)]


def _clone(reqs):
    return [Request(prompt=r.prompt, max_new_tokens=r.max_new_tokens,
                    id=r.id) for r in reqs]


# ---------------------------------------------------------------------------
# FaultModel: deterministic, physics-scaled sampling
# ---------------------------------------------------------------------------

def test_fault_model_deterministic_and_seed_sensitive():
    fm = F.FaultModel(rate=0.3, seed=7)
    draws = [fm.fault(f"pg{u}", s, age=4, retention_steps=8)
             for u in range(16) for s in range(16)]
    again = [F.FaultModel(rate=0.3, seed=7).fault(
        f"pg{u}", s, age=4, retention_steps=8)
        for u in range(16) for s in range(16)]
    assert draws == again                      # replayable chaos
    other = [F.FaultModel(rate=0.3, seed=8).fault(
        f"pg{u}", s, age=4, retention_steps=8)
        for u in range(16) for s in range(16)]
    assert draws != other                      # seed actually matters
    m = F.FaultModel(rate=0.3, seed=7).corruption_mask("pg0", 3)
    assert 1 <= m <= 255
    assert m == fm.corruption_mask("pg0", 3)


def test_fault_model_age_semantics():
    fm = F.FaultModel(rate=0.2)
    # just-written cells sit at full level: never fault
    assert fm.p_fault(0, 8) == 0.0
    assert not fm.fault("u", 0, age=0, retention_steps=8)
    # probability grows linearly with age inside the window
    ps = [fm.p_fault(a, 8) for a in range(1, 9)]
    assert all(a < b for a, b in zip(ps, ps[1:]))
    # past the window (only reachable after a missed refresh): certain
    assert fm.p_fault(9, 8) == 1.0
    assert fm.fault("u", 0, age=9, retention_steps=8)


def test_fault_model_temperature_monotone():
    """Hotter silicon -> shorter retention -> fatter fault tail (the
    85C/25C asymmetry of the paper's Tables I-II)."""
    ps = [F.FaultModel(rate=0.01, temp_c=t).p_fault(4, 8)
          for t in (25, 45, 65, 85, 105)]
    assert all(a < b for a, b in zip(ps, ps[1:])), ps
    # calibration point: 85C is the 1x reference
    assert F.FaultModel(rate=0.01, temp_c=85.0).temp_scale() == (
        pytest.approx(1.0))


# ---------------------------------------------------------------------------
# integrity words: host oracle == jnp oracle == fused Pallas kernel
# ---------------------------------------------------------------------------

def test_integrity_word_kernel_parity():
    kv = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (64, 32)))
    packed, scale, words = quantize_pack_kv_pallas(
        jax.numpy.asarray(kv), with_integrity=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(words),
                                  np.asarray(ref.integrity_words_ref(packed)))
    pn = np.asarray(packed)
    for i in (0, 17, 63):
        assert int(words[i, 0]) == F.integrity_word(pn[i])
    del scale


def test_integrity_word_detects_any_single_byte_flip():
    rng = np.random.default_rng(1)
    a = rng.integers(0, 256, size=(4, 8), dtype=np.uint8)
    b = rng.standard_normal((4, 2)).astype(np.float32)
    w = F.integrity_word(a, b)
    for flat in (0, 13, 31):
        bad = a.copy()
        bad.flat[flat] ^= 0x5A
        assert F.integrity_word(bad, b) != w
    # order-sensitive: swapping two (distinct) bytes changes the word
    swapped = a.copy()
    swapped.flat[0], swapped.flat[1] = a.flat[1], a.flat[0]
    if a.flat[0] != a.flat[1]:
        assert F.integrity_word(swapped, b) != w


# ---------------------------------------------------------------------------
# chaos: token identity to the fault-free run, across store kinds
# ---------------------------------------------------------------------------

_CHAOS = {
    # arch -> (plen, max_new, retention, rate, prompt_seed): paged rows
    # need prompts spanning > 1 page so non-tail pages genuinely age; the
    # slab store restamps every step, so it needs a tight window + a
    # certain rate.  The MoE prompt seed picks a prompt set whose logits
    # don't sit on an argmax near-tie: the expert-gather numerics of
    # chunked recompute are not bit-stable for every prompt (the same
    # flip reproduces under a plain, fault-free preemption), so other
    # seeds would test prefill numerics rather than the fault machinery.
    "qwen1.5-0.5b": (20, 8, 8, 0.5, 0),
    "qwen3-moe-30b-a3b": (20, 8, 8, 0.5, 3),
    "mamba2-130m": (5, 8, 4, 1.0, 0),
}


@pytest.mark.parametrize("arch", sorted(_CHAOS))
def test_chaos_token_identity(arch):
    plen, max_new, retention, rate, pseed = _CHAOS[arch]
    cfg = _cfg(arch)
    reqs = _reqs(cfg, 3, plen, max_new, seed=pseed)
    golden = ServeEngine(cfg, MESH, max_batch=2, max_seq=64,
                         prefill_chunk=16, retention_steps=retention
                         ).generate(_clone(reqs))
    eng = ServeEngine(cfg, MESH, max_batch=2, max_seq=64, prefill_chunk=16,
                      retention_steps=retention, fault_rate=rate,
                      fault_seed=1)
    outs = eng.generate(_clone(reqs))
    fl = eng.stats()["faults"]
    assert fl["faults_injected"] > 0, "chaos run injected nothing"
    assert fl["zero_silent_corruption"]
    assert not eng.failed
    assert all(np.array_equal(golden[i], outs[i]) for i in golden), (
        f"{arch}: recovery broke token identity: {fl}")


def test_zero_silent_corruption_property_across_seeds():
    """Accounting invariant over several chaos seeds: every injected
    fault is either detected by an integrity scan or masked (its storage
    released before any read) — nothing pending, nothing silent."""
    cfg = _cfg("qwen1.5-0.5b")
    reqs = _reqs(cfg, 3, 20, 8)
    injected_total = 0
    for seed in range(5):
        eng = ServeEngine(cfg, MESH, max_batch=2, max_seq=64,
                          prefill_chunk=16, retention_steps=8,
                          fault_rate=0.5, fault_seed=seed)
        eng.generate(_clone(reqs))
        fl = eng.stats()["faults"]
        assert fl["faults_injected"] == (
            fl["faults_detected"] + fl["faults_masked"]), fl
        assert fl["faults_pending"] == 0
        assert fl["zero_silent_corruption"]
        injected_total += fl["faults_injected"]
    assert injected_total > 0


def test_scrub_from_master_heals_prefix_band():
    """The encdec cross-KV prefix band keeps a host master copy at
    quantize-on-write, so a corrupted prefix page is healed IN PLACE
    (scrub) without preempting the row."""
    cfg = _cfg("whisper-tiny")
    reqs = _reqs(cfg, 2, 4, 6)
    golden = ServeEngine(cfg, MESH, max_batch=2, max_seq=32,
                         retention_steps=4).generate(_clone(reqs))
    eng = ServeEngine(cfg, MESH, max_batch=2, max_seq=32,
                      retention_steps=4, fault_rate=1.0, fault_seed=3)
    outs = eng.generate(_clone(reqs))
    fl = eng.stats()["faults"]
    assert fl["recovered_scrub"] > 0, fl
    assert fl["zero_silent_corruption"]
    assert all(np.array_equal(golden[i], outs[i]) for i in golden)


# ---------------------------------------------------------------------------
# recovery policies: retry budget, repeat offenders, array loss, ablation
# ---------------------------------------------------------------------------

def test_retry_exhaustion_fails_request_never_serves_corruption():
    """With a zero retry budget a fault immediately exhausts the
    request's budget: it lands in `engine.failed` (uncorrectable) rather
    than being served from corrupt storage."""
    cfg = _cfg("qwen1.5-0.5b")
    reqs = _reqs(cfg, 3, 20, 8)
    eng = ServeEngine(cfg, MESH, max_batch=2, max_seq=64, prefill_chunk=16,
                      retention_steps=8, fault_rate=0.5, fault_seed=1,
                      max_retries=0)
    outs = eng.generate(_clone(reqs))
    fl = eng.stats()["faults"]
    assert fl["faults_injected"] > 0
    assert fl["uncorrectable"] > 0 and eng.failed
    assert fl["zero_silent_corruption"]
    # every request is accounted for exactly once: served or failed
    assert set(outs) | set(eng.failed) == {r.id for r in reqs}
    assert not (set(outs) & set(eng.failed))


def test_repeat_offender_page_decommissioned():
    """A physical unit that keeps faulting is taken out of service: the
    paged pool retires the page (threshold 1 -> first detection)."""
    cfg = _cfg("qwen1.5-0.5b")
    reqs = _reqs(cfg, 3, 20, 8)
    eng = ServeEngine(cfg, MESH, max_batch=2, max_seq=64, prefill_chunk=16,
                      retention_steps=8, fault_rate=0.5, fault_seed=1,
                      fault_pin_threshold=1)
    outs = eng.generate(_clone(reqs))
    fl = eng.stats()["faults"]
    assert fl["faults_detected"] > 0
    assert fl["pages_decommissioned"] + fl["pinned_normal"] > 0, fl
    assert fl["zero_silent_corruption"]
    assert all(len(v) == 8 for v in outs.values())


def test_slab_offender_pinned_to_normal_mode():
    """Slab stores can't retire a row (it IS the request's slot), so a
    repeat offender is pinned back to the static Normal plane — the
    paper's static-survives escape hatch."""
    cfg = _cfg("mamba2-130m")
    reqs = _reqs(cfg, 3, 5, 8)
    eng = ServeEngine(cfg, MESH, max_batch=2, max_seq=32,
                      retention_steps=4, fault_rate=1.0, fault_seed=2,
                      fault_pin_threshold=1)
    eng.generate(_clone(reqs))
    fl = eng.stats()["faults"]
    assert fl["faults_detected"] > 0
    assert fl["pinned_normal"] > 0, fl
    assert fl["zero_silent_corruption"]


def test_forced_array_loss_drain_requeue_identity():
    cfg = _cfg("qwen1.5-0.5b")
    reqs = _reqs(cfg, 3, 20, 6)
    golden = ServeEngine(cfg, MESH, max_batch=2, max_seq=64,
                         prefill_chunk=16).generate(_clone(reqs))
    eng = ServeEngine(cfg, MESH, max_batch=2, max_seq=64, prefill_chunk=16)
    for r in _clone(reqs):
        eng.add_request(r)
    eng.step_all()
    eng.step_all()
    eng.inject_array_loss()
    while eng.active.any() or eng._queue:
        eng.step_all()
    fl = eng.stats()["faults"]
    assert fl["array_losses"] == 1
    assert fl["supervisor_restarts"] == 1
    assert fl["array_loss_requeues"] > 0
    assert all(np.array_equal(golden[i], eng.outputs[i]) for i in golden)


def test_integrity_off_ablation_forfeits_detection():
    """With integrity checking disabled the injector still corrupts, but
    nothing is detected — the zero-silent-corruption property is
    honestly reported as LOST (the ablation the paper's reliability
    argument rests on)."""
    cfg = _cfg("qwen1.5-0.5b")
    reqs = _reqs(cfg, 3, 20, 8)
    eng = ServeEngine(cfg, MESH, max_batch=2, max_seq=64, prefill_chunk=16,
                      retention_steps=8, fault_rate=0.5, fault_seed=1,
                      integrity_check=False)
    eng.generate(_clone(reqs))
    fl = eng.stats()["faults"]
    assert fl["faults_injected"] > 0
    assert fl["faults_detected"] == 0
    assert not fl["zero_silent_corruption"]


def test_rate_zero_engine_is_inert():
    """fault_rate == 0 with no array-loss rate attaches no model: no
    injection, no integrity overhead, stats report disabled."""
    cfg = _cfg("qwen1.5-0.5b")
    eng = ServeEngine(cfg, MESH, max_batch=2, max_seq=64, prefill_chunk=16)
    eng.generate(_reqs(cfg, 2, 20, 6))
    fl = eng.stats()["faults"]
    assert not fl["enabled"]
    assert fl["faults_injected"] == 0 and fl["faults_detected"] == 0
    assert fl["zero_silent_corruption"]
