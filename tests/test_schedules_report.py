"""LR schedules (incl. MiniCPM's WSD) and the dry-run report generator."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.schedule import cosine, wsd

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS = os.path.join(REPO, "results", "dryrun_final")


def test_cosine_shape():
    lrs = [float(cosine(s, peak_lr=1.0, warmup=10, total=100))
           for s in range(101)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1.0) < 1e-6            # peak at end of warmup
    assert all(a >= b - 1e-9 for a, b in zip(lrs[10:], lrs[11:]))  # decays
    assert lrs[100] >= 0.099                     # final_frac floor


def test_wsd_shape():
    """Warmup -> flat plateau -> sharp decay (MiniCPM)."""
    lrs = [float(wsd(s, peak_lr=1.0, warmup=10, total=100, decay_frac=0.1))
           for s in range(101)]
    assert abs(lrs[10] - 1.0) < 1e-6
    plateau = lrs[11:89]
    assert max(plateau) - min(plateau) < 1e-6    # stable region is FLAT
    assert lrs[100] < 0.02                        # decayed hard
    assert lrs[95] < lrs[90] <= 1.0


def test_wsd_differs_from_cosine_mid_run():
    # cosine has already decayed at 50% progress; WSD has not
    c = float(cosine(50, peak_lr=1.0, warmup=10, total=100))
    w = float(wsd(50, peak_lr=1.0, warmup=10, total=100))
    assert w > c + 0.2


@pytest.mark.skipif(not os.path.isdir(RESULTS),
                    reason="dry-run results not generated")
def test_report_generates_from_final_results():
    from repro.launch.report import load_records, summarize
    recs = load_records(RESULTS)
    assert len(recs) == 80, "40 cells x 2 meshes"
    skips = [r for r in recs if r.get("skipped")]
    assert len(skips) == 16, "8 long_500k skips per mesh"
    for r in recs:
        if r.get("skipped"):
            assert "quadratic" in r["reason"]
            continue
        # every compiled cell has positive flops and a dominant term
        assert r["flops_per_device"] > 0
        assert r["dominant"] in ("compute_s", "memory_s", "collective_s")
        assert r["memory"]["total_gib_per_device"] > 0
    md = summarize(RESULTS)
    assert "Roofline terms" in md and "Multi-pod" in md


@pytest.mark.skipif(not os.path.isdir(RESULTS),
                    reason="dry-run results not generated")
def test_final_results_memory_budget():
    """All compiled cells fit 16 GiB except grok-1's documented boundary
    cases (EXPERIMENTS.md SSHBM-fit audit)."""
    from repro.launch.report import load_records
    over = [(r["arch"], r["shape"]) for r in load_records(RESULTS)
            if not r.get("skipped") and not r["memory"]["fits_16gib"]]
    assert all(a == "grok-1-314b" for a, _ in over), over
    assert len(over) <= 3
