"""Dry-run machinery tests: HLO analyzer calibration + one real cell lowered
on the production mesh in a subprocess (512 forced host devices)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import mesh_context

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_analyzer_matches_xla_loop_free():
    def f(x, w):
        return jnp.tanh(x @ w)
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    r = analyze_hlo(c.as_text())
    ca = c.cost_analysis()
    if isinstance(ca, list):  # pre-0.5 jax returns one dict per computation
        ca = ca[0]
    assert r["flops"] == ca["flops"]
    if jax.__version_info__ >= (0, 5):
        # pre-0.5 XLA charges fused-parameter bytes differently; the
        # analyzer tracks the current cost model
        assert r["bytes_accessed"] == ca["bytes accessed"]
    else:
        assert r["bytes_accessed"] >= ca["bytes accessed"] > 0


def test_analyzer_multiplies_trip_counts():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    r = analyze_hlo(c.as_text())
    assert r["flops"] == 2 * 128 * 256 * 256 * 10
    ca = c.cost_analysis()
    if isinstance(ca, list):  # pre-0.5 jax returns one dict per computation
        ca = ca[0]
    # XLA counts the body once — exactly 10x less
    assert ca["flops"] * 10 == r["flops"]


def test_analyzer_nested_loops():
    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return jnp.tanh(c), None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    assert analyze_hlo(c.as_text())["flops"] == 2 * 64 * 128 * 128 * 15


def test_analyzer_counts_collectives():
    mesh = jax.make_mesh((1,), ("d",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    def f(x):
        return jax.lax.with_sharding_constraint(
            x.sum(0, keepdims=True), NamedSharding(mesh, P(None, None)))
    # single device: no collectives expected — analyzer must return zeros
    with mesh_context(mesh):
        c = jax.jit(f).lower(
            jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    r = analyze_hlo(c.as_text())
    assert r["collective_total_bytes"] == 0


@pytest.mark.slow
def test_one_production_cell_compiles(tmp_path):
    """whisper-tiny x train_4k on the 256-chip mesh, in a subprocess (the
    512-device override must not leak into this test session)."""
    out = tmp_path / "dry"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper-tiny", "--shape", "train_4k", "--out", str(out)],
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        capture_output=True, text=True, timeout=540, cwd=REPO)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    rec = json.loads((out / "whisper-tiny_train_4k_pod.json").read_text())
    assert not rec["skipped"]
    assert rec["flops_per_device"] > 0
    assert rec["collectives"]["total_bytes"] > 0
    assert jax.device_count() == 1  # no leak
