"""System behaviour tests: attention semantics, MoE dispatch invariants,
RoPE/window correctness, loss masking of the padded vocab."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # degrade: property tests fall back to fixed params
    HAS_HYPOTHESIS = False

from repro.configs import get_arch
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import model as M
from repro.models.params import init_params


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _naive_attention(q, k, v, causal=True, window=None):
    B, S, H, D = q.shape
    KV = k.shape[2]
    qg = q.reshape(B, S, KV, H // KV, D)
    s = jnp.einsum("bqkhd,bskd->bkhqs", qg, k).astype(jnp.float32)
    s = s / (D ** 0.5)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    m = jnp.ones((S, S), bool)
    if causal:
        m &= j <= i
    if window is not None:
        m &= j > i - window
    s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkhqs,bskd->bqkhd", p.astype(v.dtype), v)
    return o.reshape(B, S, H, D)


@pytest.mark.parametrize("S,chunk,window", [(64, 16, None), (64, 64, None),
                                            (64, 16, 24), (128, 32, 32)])
def test_chunked_attention_matches_naive(S, chunk, window):
    key = jax.random.PRNGKey(0)
    B, H, KV, D = 2, 4, 2, 16
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, D))
    out = L.attention(q, k, v, causal=True, window=window, q_chunk=chunk)
    ref = _naive_attention(q, k, v, causal=True, window=window)
    assert np.allclose(np.asarray(out), np.asarray(ref), atol=2e-3)


def test_decode_attention_ring_permutation_invariance():
    """Ring caches shuffle token order; softmax must not care."""
    key = jax.random.PRNGKey(3)
    B, H, KV, D, S = 1, 2, 2, 16, 8
    q = jax.random.normal(key, (B, 1, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, D))
    pos = jnp.array([S - 1])
    o1 = L.decode_attention(q, k, v, pos)
    perm = jax.random.permutation(jax.random.fold_in(key, 3), S)
    o2 = L.decode_attention(q, k[:, perm], v[:, perm], pos)
    assert np.allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def _check_decode_attention_mask(valid_len):
    """Cache beyond `positions` must not influence the output."""
    key = jax.random.PRNGKey(4)
    B, H, KV, D, S = 1, 2, 1, 8, 64
    q = jax.random.normal(key, (B, 1, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, D))
    pos = jnp.array([valid_len - 1])
    o1 = L.decode_attention(q, k, v, pos)
    k2 = k.at[:, valid_len:].set(99.0)
    v2 = v.at[:, valid_len:].set(-99.0)
    o2 = L.decode_attention(q, k2, v2, pos)
    assert np.allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


if HAS_HYPOTHESIS:
    @given(st.integers(1, 63))
    @settings(max_examples=10, deadline=None)
    def test_decode_attention_mask_property(valid_len):
        _check_decode_attention_mask(valid_len)
else:
    @pytest.mark.parametrize("valid_len", [1, 7, 32, 63])
    def test_decode_attention_mask_property(valid_len):
        _check_decode_attention_mask(valid_len)


def test_rope_relative_property():
    """RoPE: <q_i, k_j> depends only on (i - j)."""
    D = 32
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, D))

    def dot_at(qi, kj):
        qr = L.apply_rope(q, jnp.array([qi]))
        kr = L.apply_rope(k, jnp.array([kj]))
        return float(jnp.sum(qr * kr))

    assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-3
    assert abs(dot_at(5, 3) - dot_at(5, 4)) > 1e-4  # position-dependent


# ---------------------------------------------------------------------------
# MoE dispatch invariants
# ---------------------------------------------------------------------------

def _moe_setup(E=8, k=2, d=16, f=32):
    base = get_arch("qwen3-moe-30b-a3b").reduced()
    cfg = dataclasses.replace(
        base, d_model=d, d_ff=f,
        moe=dataclasses.replace(base.moe, n_experts=E, top_k=k))
    p = init_params(moe_mod.moe_pspecs(cfg, 1), jax.random.PRNGKey(0))
    p = jax.tree.map(lambda t: t[0], p)  # drop layer dim
    return cfg, p


def test_moe_identity_when_experts_equal():
    """If all experts share weights, output == single-expert FFN (combine
    weights sum to 1 after top-k renorm and no token is dropped)."""
    cfg, p = _moe_setup(E=4, k=2)
    for name in ("w_gate", "w_up", "w_down"):
        p[name] = jnp.broadcast_to(p[name][:1], p[name].shape)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32) * 0.1
    cfg_big_cap = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    out = moe_mod.moe_ffn(cfg_big_cap, p, x)
    w_gate, w_up, w_down = p["w_gate"][0], p["w_up"][0], p["w_down"][0]
    expect = (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down
    assert np.allclose(np.asarray(out, np.float32),
                       np.asarray(expect, np.float32), atol=3e-2)


def test_moe_capacity_drops_tokens():
    cfg, p = _moe_setup(E=4, k=1)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.26, top_k=1))
    # steer every token to expert 0 by biasing the router
    p["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(10.0)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 16, cfg.d_model))
    out = moe_mod.moe_ffn(cfg, p, x)
    # capacity ~ ceil(16*1*0.26/4) = 2 of 16 tokens survive
    nonzero = np.abs(np.asarray(out, np.float32)).sum(-1) > 1e-6
    assert 1 <= nonzero.sum() <= 4, nonzero.sum()


# ---------------------------------------------------------------------------
# loss / vocab padding
# ---------------------------------------------------------------------------

def test_padded_vocab_never_predicted():
    cfg = get_arch("granite-3-2b").reduced()
    assert cfg.vocab_padded % 256 == 0
    big = get_arch("granite-3-2b")
    assert big.vocab_padded == 49408 and big.vocab == 49155
    params = init_params(M.abstract_params(cfg), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    logits = M.forward(cfg, params, {"tokens": toks}, q_chunk=8)
    pad_logits = np.asarray(logits[..., cfg.vocab:], np.float32)
    if pad_logits.size:
        assert (pad_logits <= -1e29).all()


def test_loss_is_finite_and_positive():
    cfg = get_arch("qwen1.5-0.5b").reduced()
    params = init_params(M.abstract_params(cfg), jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab),
             "targets": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                           cfg.vocab)}
    loss = M.loss_fn(cfg, params, batch, q_chunk=8)
    assert np.isfinite(float(loss)) and float(loss) > 0
    assert float(loss) < np.log(cfg.vocab_padded) + 1.0
