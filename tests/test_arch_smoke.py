"""Per-arch smoke tests: reduced same-family config, one forward + one
train step on CPU, asserting output shapes and no NaNs — plus a decode
step wherever the family has one."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.configs.base import ShapeConfig
from repro.distributed.sharding import Rules
from repro.models import model as M
from repro.models.params import init_params
from repro.optim import adamw
from repro.train import step as step_lib

ALL_ARCHS = sorted(ARCHS)


def _batch_for(cfg, B, S, key):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.encdec:
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(key, 1),
            (B, cfg.encdec.n_frames, cfg.encdec.frame_dim), jnp.bfloat16)
    if cfg.vision:
        batch["patches"] = jax.random.normal(
            jax.random.fold_in(key, 2),
            (B, cfg.vision.n_patches, cfg.vision.vision_dim), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_smoke(arch):
    cfg = get_arch(arch).reduced()
    params = init_params(M.abstract_params(cfg), jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = _batch_for(cfg, B, S, jax.random.PRNGKey(1))
    logits = M.forward(cfg, params, batch, q_chunk=16)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert not np.isnan(np.asarray(logits, np.float32)).any()


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch):
    cfg = get_arch(arch).reduced()
    params = init_params(M.abstract_params(cfg),
                         jax.random.PRNGKey(0), dtype_override=jnp.float32)
    B, S = 2, 32
    batch = _batch_for(cfg, B, S, jax.random.PRNGKey(1))
    batch["targets"] = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                          cfg.vocab)
    settings = step_lib.TrainSettings(lr=1e-3, q_chunk=16)
    state = step_lib.TrainState(params, adamw.adamw_init(params),
                                jnp.zeros((), jnp.int32))
    train_step = step_lib.make_train_step(cfg, settings, rules=None)
    new_state, loss = jax.jit(train_step)(state, batch)
    assert np.isfinite(float(loss)), loss
    # params actually changed
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        new_state.params, state.params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_step_smoke(arch):
    cfg = get_arch(arch).reduced()
    params = init_params(M.abstract_params(cfg), jax.random.PRNGKey(0))
    B, S = 2, 32
    shape = ShapeConfig("d", S, B, "decode")
    cache = jax.tree.map(
        lambda l: jnp.zeros(l.shape, l.jdtype), M.abstract_cache(cfg, shape),
        is_leaf=lambda x: hasattr(x, "jdtype"))
    batch = {"tokens": jnp.ones((B, 1), jnp.int32),
             "positions": jnp.zeros((B,), jnp.int32)}
    logits, new_cache = M.decode_step(cfg, params, cache, batch)
    assert logits.shape == (B, 1, cfg.vocab_padded)
    assert not np.isnan(np.asarray(logits, np.float32)).any()
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


def test_all_archs_have_exact_assigned_dims():
    """The configs must carry the exact assigned numbers."""
    spec = {
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    }
    for name, (L, d, H, KV, ff, V) in spec.items():
        c = get_arch(name)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab) == (L, d, H, KV, ff, V), name
    assert ARCHS["mamba2-130m"].ssm.state_dim == 128
    assert ARCHS["qwen3-moe-30b-a3b"].moe.n_experts == 128
    assert ARCHS["qwen3-moe-30b-a3b"].moe.top_k == 8
    assert ARCHS["grok-1-314b"].moe.n_experts == 8
    assert ARCHS["grok-1-314b"].moe.top_k == 2
    assert ARCHS["qwen1.5-0.5b"].qkv_bias


def test_decode_matches_forward_dense():
    """Teacher-forced decode reproduces the full forward's final logits."""
    from repro.configs.base import AMCConfig
    cfg = dataclasses.replace(
        get_arch("granite-3-2b").reduced(),
        amc=AMCConfig(weight_mode="normal", kv_mode="normal"))
    params = init_params(M.abstract_params(cfg), jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full = M.forward(cfg, params, {"tokens": toks}, q_chunk=16)
    shape = ShapeConfig("d", S, B, "decode")
    cache = jax.tree.map(
        lambda l: jnp.zeros(l.shape, l.jdtype), M.abstract_cache(cfg, shape),
        is_leaf=lambda x: hasattr(x, "jdtype"))
    lg = None
    for t in range(S):
        lg, cache = M.decode_step(
            cfg, params, cache,
            {"tokens": toks[:, t:t + 1],
             "positions": jnp.full((B,), t, jnp.int32)})
    err = np.abs(np.asarray(lg[:, 0]) - np.asarray(full[:, -1])).max()
    assert err < 0.15, err
