"""Speculative decoding out of the augmented plane.

Three layers of guarantees:
  * kernel: the verify window kernel is BIT-identical, per window slot,
    to the single-token paged kernel at the slot's horizon — including
    windows that straddle a page boundary, windows exactly one page
    wide, and horizons one token short of a page, on mixed
    Normal/Augmented pools;
  * engine: `spec_k >= 2` emits token-identical streams to `spec_k == 1`
    for dense, moe and ssm families (the accept/rollback contract), and
    keeps doing so when every draft is forced to be WRONG — which drives
    the paged store's page retraction and the slab store's snapshot
    rollback;
  * admission: a request whose prompt + budget can never fit the store
    fails fast with a clean ValueError instead of looping admission.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from test_cache_pool import _contiguous_packed, _page_out

from repro.configs import get_arch
from repro.configs.base import AMCConfig
from repro.kernels import ops as K
from repro.kernels.ref import rel_err
from repro.launch.mesh import make_local_mesh
from repro.models import layers as L
from repro.serve import Request, ServeEngine


# ---------------------------------------------------------------------------
# window kernel: per-slot bit-identity on page-boundary geometries
# ---------------------------------------------------------------------------

def _mixed_pool(rng, B, KV, D, page, maxP, kv_bits):
    """A paged pool with alternating Normal/Augmented pages (the Normal
    plane holds the dequantized rows) plus its hold-previous tables —
    the same construction as the mixed-mode oracle test."""
    S = maxP * page
    kp_c, vp_c, ks_c, vs_c = _contiguous_packed(rng, B, KV, S, D, kv_bits)
    kp, table = _page_out(kp_c, page, maxP, B)
    vp, _ = _page_out(vp_c, page, maxP, B)
    ks, _ = _page_out(ks_c, page, maxP, B)
    vs, _ = _page_out(vs_c, page, maxP, B)
    unpack = L.unpack_kv_int4 if kv_bits == 4 else L.unpack_kv_int8
    kn = jnp.zeros((B * maxP + 1, KV, page, D), jnp.bfloat16)
    vn = jnp.zeros((B * maxP + 1, KV, page, D), jnp.bfloat16)
    modes = np.ones((B, maxP), np.int32)
    for b in range(B):
        for p in range(0, maxP, 2):
            phys = table[b, p]
            kn = kn.at[phys].set(unpack(kp[phys], ks[phys][..., None]))
            vn = vn.at[phys].set(unpack(vp[phys], vs[phys][..., None]))
            modes[b, p] = 0
    nidx = np.zeros((B, maxP), np.int32)
    pidx = np.zeros((B, maxP), np.int32)
    lastn = np.zeros(B, np.int32)
    lastp = np.zeros(B, np.int32)
    for s in range(maxP):
        lastn = np.where(modes[:, s] == 0, table[:, s], lastn)
        lastp = np.where(modes[:, s] == 1, table[:, s], lastp)
        nidx[:, s], pidx[:, s] = lastn, lastp
    return ((kn, vn, kp, vp, ks, vs),
            (jnp.asarray(modes), jnp.asarray(nidx), jnp.asarray(pidx)))


# page = 8: window geometries the speculative engine actually produces
_WINDOW_CASES = {
    "straddles_two_pages": (6, 4),      # positions 6..9 cross page 0 -> 1
    "window_eq_page_size": (8, 8),      # slots exactly cover page 1
    "one_short_of_page": (5, 4),        # horizons 6,7,8,9: one hits p-1
    "ends_one_short_of_page": (12, 3),  # horizons 13,14,15: stops at p-1
}


@pytest.mark.parametrize("kv_bits", [4, 8])
@pytest.mark.parametrize("case", sorted(_WINDOW_CASES))
def test_window_kernel_slotwise_bit_identical(kv_bits, case):
    """Window slot w must reproduce the single-token kernel at lengths ==
    start + w + 1 BIT-for-bit: pages past a slot's horizon contribute
    exp(-inf) == 0.0 exactly in the f32 online softmax, so the fused
    window walk and the per-token walk are the same op sequence. This is
    the property that makes speculative accept/rollback token-identical
    to step-by-step decode."""
    start0, W = _WINDOW_CASES[case]
    rng = np.random.default_rng(5)
    B, KV, Hg, D, page, maxP = 2, 2, 2, 32, 8, 4
    planes, tables = _mixed_pool(rng, B, KV, D, page, maxP, kv_bits)
    qw = jnp.asarray(rng.standard_normal((B, KV, W, Hg, D)), jnp.bfloat16)
    starts = jnp.asarray([start0, max(start0 - 3, 0)], jnp.int32)
    ow = K.paged_kv_attention_window(qw, *planes, starts, *tables,
                                     page=page, kv_bits=kv_bits)
    for w in range(W):
        o1 = K.paged_kv_attention(qw[:, :, w], *planes, starts + w + 1,
                                  *tables, page=page, kv_bits=kv_bits)
        a = np.asarray(ow[:, :, w]).view(np.uint16)
        b = np.asarray(o1).view(np.uint16)
        assert (a == b).all(), f"slot {w} diverged from single-token kernel"


@pytest.mark.parametrize("kv_bits", [4, 8])
def test_window_kernel_matches_ref_oracle(kv_bits):
    rng = np.random.default_rng(6)
    B, KV, Hg, D, page, maxP, W = 2, 2, 2, 32, 8, 4, 5
    planes, tables = _mixed_pool(rng, B, KV, D, page, maxP, kv_bits)
    qw = jnp.asarray(rng.standard_normal((B, KV, W, Hg, D)), jnp.bfloat16)
    starts = jnp.asarray([7, 20], jnp.int32)     # one straddle, one interior
    o = K.paged_kv_attention_window(qw, *planes, starts, *tables,
                                    page=page, kv_bits=kv_bits)
    o_ref = K.paged_kv_attention_window(qw, *planes, starts, *tables,
                                        page=page, kv_bits=kv_bits,
                                        use_ref=True)
    assert rel_err(o, o_ref) < 0.02


def test_masked_quantize_pack_scrubs_rejected_rows():
    """The speculative store-back: rejected rows commit as zero bytes +
    unit scale; accepted rows are bit-identical to the unmasked pack."""
    rng = np.random.default_rng(7)
    kv = jnp.asarray(rng.standard_normal((2, 6, 2, 32)), jnp.bfloat16)
    valid = jnp.asarray(np.array([[1, 1, 1, 0, 0, 0],
                                  [1, 0, 1, 0, 1, 0]], bool))[:, :, None]
    p, s = K.quantize_pack_kv(kv, valid)
    p0, s0 = K.quantize_pack_kv(kv)
    keep = np.broadcast_to(np.asarray(valid), kv.shape[:-1])
    assert np.array_equal(np.asarray(p)[keep], np.asarray(p0)[keep])
    assert np.array_equal(np.asarray(s, np.float32)[keep],
                          np.asarray(s0, np.float32)[keep])
    assert (np.asarray(p)[~keep] == 0).all()
    assert (np.asarray(s, np.float32)[~keep] == 1.0).all()


# ---------------------------------------------------------------------------
# engine: token identity, forced rejection, capacity admission
# ---------------------------------------------------------------------------

_FAMILIES = {
    "dense_int4": ("qwen1.5-0.5b", dict(kv_mode="int4")),
    "moe": ("qwen3-moe-30b-a3b", dict(kv_mode="int4")),
    "ssm": ("mamba2-130m", {}),
}


def _gen(arch, knobs, spec_k, *, wrap_draft=None, max_seq=40):
    cfg = get_arch(arch).reduced()
    eng = ServeEngine(cfg, make_local_mesh(), max_batch=2, max_seq=max_seq,
                      prefill_chunk=8, spec_k=spec_k, **knobs)
    if wrap_draft is not None:
        eng._draft_decode = wrap_draft(eng._draft_decode)
    rng = np.random.default_rng(11)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=(n,))
                    .astype(np.int32), max_new_tokens=m, id=i)
            for i, (n, m) in enumerate([(5, 9), (9, 6), (3, 7)])]
    return eng.generate(reqs), eng


@pytest.mark.parametrize("family", sorted(_FAMILIES))
def test_spec_decode_token_identical_to_stepwise(family):
    """The acceptance golden: spec_k >= 2 must emit the exact token
    streams of spec_k == 1 (greedy stepwise decode), for paged KV and
    recurrent-slab families alike, through admission/queueing and row
    retirement."""
    arch, knobs = _FAMILIES[family]
    base, _ = _gen(arch, knobs, 1)
    spec, eng = _gen(arch, knobs, 3)
    assert spec == base
    st = eng.stats()["spec"]
    assert st["enabled"] and st["verify_dispatches"] > 0
    assert st["accepted_tokens"] >= st["spec_rounds"]   # >= 1 token/round


def _negate(fn):
    """Draft wrapper that argmax-inverts the logits: every drafted token
    is (near-certainly) WRONG, so each round accepts exactly the one
    verify-produced token and every optimistic draft write is rejected —
    the worst-case rollback path."""
    def wrapped(params, state, batch):
        lg, new_state = fn(params, state, batch)
        return -lg, new_state
    return wrapped


def test_spec_forced_rejection_retracts_paged_pages():
    arch, knobs = _FAMILIES["dense_int4"]
    base, _ = _gen(arch, knobs, 1)
    spec, eng = _gen(arch, knobs, 4, wrap_draft=_negate)
    assert spec == base
    st = eng.stats()
    # each round accepted ~1 of 4 slots: draft pages past the accepted
    # horizon were speculatively allocated and must have been released
    assert st["pool"]["retracted_pages"] > 0
    assert st["spec"]["accepted_tokens"] < \
        st["spec"]["spec_rounds"] * eng.spec_k


def test_spec_forced_rejection_rolls_back_slab_state():
    arch, knobs = _FAMILIES["ssm"]
    base, _ = _gen(arch, knobs, 1)
    spec, eng = _gen(arch, knobs, 3, wrap_draft=_negate)
    assert spec == base
    pool = eng.stats()["pool"]
    assert pool["spec_snapshots"] > 0
    assert pool["spec_rollbacks"] == pool["spec_snapshots"]


def test_add_request_rejects_request_exceeding_store_capacity():
    """A request whose prompt + generation budget can NEVER fit one row
    of the store (pages, not max_seq) must fail fast at add_request."""
    cfg = get_arch("qwen1.5-0.5b").reduced()
    cfg = dataclasses.replace(cfg, amc=AMCConfig(kv_mode="normal"))
    eng = ServeEngine(cfg, make_local_mesh(), max_batch=2, max_seq=64,
                      pool_pages_normal=2)       # 2 x 16-token pages/row
    ok = Request(prompt=np.arange(8, dtype=np.int32), max_new_tokens=8,
                 id=0)
    assert eng.add_request(ok) is not None       # 15 peak tokens fit
    with pytest.raises(ValueError, match="holds at most"):
        eng.add_request(Request(prompt=np.arange(8, dtype=np.int32),
                                max_new_tokens=40, id=1))
