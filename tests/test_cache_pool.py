"""Paged augmented KV pool: kernel golden (bit-identical to the
contiguous packed path on single-mode pools), mixed-mode oracle parity,
and the pool's byte-budget / mode-switch accounting."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import AMCConfig
from repro.kernels import ops as K
from repro.kernels.ref import rel_err
from repro.models import layers as L
from repro.serve.cache_pool import PagedKVPool


# ---------------------------------------------------------------------------
# kernel goldens
# ---------------------------------------------------------------------------

def _contiguous_packed(rng, B, KV, S, D, kv_bits):
    if kv_bits == 4:
        kp = jnp.asarray(rng.integers(0, 256, (B, KV, S, D // 2)), jnp.uint8)
        vp = jnp.asarray(rng.integers(0, 256, (B, KV, S, D // 2)), jnp.uint8)
    else:
        kp = jnp.asarray(rng.integers(-127, 128, (B, KV, S, D)), jnp.int8)
        vp = jnp.asarray(rng.integers(-127, 128, (B, KV, S, D)), jnp.int8)
    ks = jnp.asarray(rng.uniform(0.01, 0.1, (B, KV, S)), jnp.bfloat16)
    vs = jnp.asarray(rng.uniform(0.01, 0.1, (B, KV, S)), jnp.bfloat16)
    return kp, vp, ks, vs


def _page_out(contig, page, maxP, B):
    """Split a contiguous (B, KV, S, ·) operand into arena pages with an
    in-order page table (physical pages 1..B*maxP; 0 is the dump)."""
    KV = contig.shape[1]
    tail = contig.shape[3:]
    arena = jnp.zeros((B * maxP + 1, KV, page) + tail, contig.dtype)
    table = np.zeros((B, maxP), np.int32)
    phys = 1
    for b in range(B):
        for p in range(maxP):
            arena = arena.at[phys].set(contig[b, :, p * page:(p + 1) * page])
            table[b, p] = phys
            phys += 1
    return arena, table


@pytest.mark.parametrize("kv_bits", [4, 8])
def test_paged_kernel_bit_identical_to_contiguous_on_single_mode(kv_bits):
    """Acceptance golden: an all-Augmented paged pool walked in logical
    page order must be BIT-identical to `packed_kv_attention` with
    bs == page_size — same block walk, same op order."""
    rng = np.random.default_rng(0)
    B, KV, Hg, D, page, maxP = 2, 2, 4, 32, 8, 4
    S = maxP * page
    q = jnp.asarray(rng.standard_normal((B, KV, Hg, D)), jnp.bfloat16)
    kp_c, vp_c, ks_c, vs_c = _contiguous_packed(rng, B, KV, S, D, kv_bits)
    lengths = jnp.asarray([S, 13], jnp.int32)
    o_contig = K.packed_kv_attention(q, kp_c, vp_c, ks_c, vs_c, lengths,
                                     bs=page, kv_bits=kv_bits)

    kp, table = _page_out(kp_c, page, maxP, B)
    vp, _ = _page_out(vp_c, page, maxP, B)
    ks, _ = _page_out(ks_c, page, maxP, B)
    vs, _ = _page_out(vs_c, page, maxP, B)
    d_n = D
    kn = jnp.zeros((1, KV, page, d_n), jnp.bfloat16)
    vn = jnp.zeros((1, KV, page, d_n), jnp.bfloat16)
    modes = jnp.ones((B, maxP), jnp.int32)
    o_paged = K.paged_kv_attention(
        q, kn, vn, kp, vp, ks, vs, lengths, modes,
        jnp.zeros((B, maxP), jnp.int32), jnp.asarray(table),
        page=page, kv_bits=kv_bits)
    a = np.asarray(o_paged).view(np.uint16)
    b = np.asarray(o_contig).view(np.uint16)
    assert (a == b).all(), "paged walk diverged from contiguous kernel"


@pytest.mark.parametrize("kv_bits", [4, 8])
def test_paged_kernel_mixed_mode_matches_oracle(kv_bits):
    """Pages alternating Normal/Augmented (with the Normal plane holding
    the dequantized rows) must agree with the gather+dense oracle."""
    rng = np.random.default_rng(1)
    B, KV, Hg, D, page, maxP = 2, 2, 2, 32, 8, 4
    S = maxP * page
    q = jnp.asarray(rng.standard_normal((B, KV, Hg, D)), jnp.bfloat16)
    kp_c, vp_c, ks_c, vs_c = _contiguous_packed(rng, B, KV, S, D, kv_bits)
    kp, table = _page_out(kp_c, page, maxP, B)
    vp, _ = _page_out(vp_c, page, maxP, B)
    ks, _ = _page_out(ks_c, page, maxP, B)
    vs, _ = _page_out(vs_c, page, maxP, B)
    unpack = L.unpack_kv_int4 if kv_bits == 4 else L.unpack_kv_int8
    # even logical pages go Normal: dequantize them into the bf16 arena
    kn = jnp.zeros((B * maxP + 1, KV, page, D), jnp.bfloat16)
    vn = jnp.zeros((B * maxP + 1, KV, page, D), jnp.bfloat16)
    modes = np.ones((B, maxP), np.int32)
    for b in range(B):
        for p in range(0, maxP, 2):
            phys = table[b, p]
            kn = kn.at[phys].set(unpack(kp[phys], ks[phys][..., None]))
            vn = vn.at[phys].set(unpack(vp[phys], vs[phys][..., None]))
            modes[b, p] = 0
    nidx = np.zeros((B, maxP), np.int32)
    pidx = np.zeros((B, maxP), np.int32)
    lastn = np.zeros(B, np.int32)
    lastp = np.zeros(B, np.int32)
    for s in range(maxP):
        lastn = np.where(modes[:, s] == 0, table[:, s], lastn)
        lastp = np.where(modes[:, s] == 1, table[:, s], lastp)
        nidx[:, s], pidx[:, s] = lastn, lastp
    lengths = jnp.asarray([S, 21], jnp.int32)
    args = (q, kn, vn, kp, vp, ks, vs, lengths, jnp.asarray(modes),
            jnp.asarray(nidx), jnp.asarray(pidx))
    o = K.paged_kv_attention(*args, page=page, kv_bits=kv_bits)
    o_ref = K.paged_kv_attention(*args, page=page, kv_bits=kv_bits,
                                 use_ref=True)
    assert rel_err(o, o_ref) < 0.02


# ---------------------------------------------------------------------------
# pool accounting and mode switches
# ---------------------------------------------------------------------------

def _pool(kv_mode="normal", pool_mode="augment-on-pressure", **kw):
    cfg = get_arch("qwen1.5-0.5b").reduced()
    cfg = dataclasses.replace(cfg, amc=AMCConfig(kv_mode=kv_mode,
                                                 pool_mode=pool_mode))
    return PagedKVPool(cfg, max_batch=2, max_seq=32, **kw)


def test_pool_budget_accounting_alloc_free():
    pool = _pool()
    pbn = pool.geom.page_bytes_normal
    assert pool.live_bytes == 0
    assert pool.alloc_page(0, 0, step=0)
    assert pool.live_bytes == pbn
    assert pool.page_mode[0, 0] == 0
    pool.free_row(0)
    assert pool.live_bytes == 0
    assert not pool.allocated.any()


def test_pool_augment_frees_budget_and_preserves_values():
    pool = _pool()
    g = pool.geom
    assert pool.alloc_page(0, 0, step=0)
    # write a recognizable page into the Normal plane
    rng = np.random.default_rng(2)
    phys = int(pool.page_table[0, 0])
    x = jnp.asarray(rng.standard_normal(
        pool.arenas["kn"].shape[:1] + pool.arenas["kn"].shape[2:]),
        jnp.bfloat16)
    pool.arenas["kn"] = pool.arenas["kn"].at[:, phys].set(x)
    before = pool.live_bytes
    pool.augment_page(0, 0, step=1)
    assert pool.page_mode[0, 0] == 1
    assert pool.live_bytes == before - (g.page_bytes_normal
                                        - g.page_bytes_aug)
    assert pool.stats["augment_events"] == 1
    assert (0, 0) in pool.policies          # retention clock started
    # round-trip: promote back and compare against the original rows
    assert pool.promote_page(0, 0, step=2)
    phys2 = int(pool.page_table[0, 0])
    y = pool.arenas["kn"][:, phys2]
    err = rel_err(y, x)
    tol = 0.2 if g.aug_bits == 4 else 0.02   # one quant step
    assert err < tol, err
    assert pool.live_bytes == before
    assert (0, 0) not in pool.policies


def test_pool_budget_rejects_when_exhausted_normal_only():
    g = _pool().geom
    pool = _pool(pool_mode="normal-only",      # exactly maxP pages: 1 seq
                 budget_bytes=2 * g.page_bytes_normal)
    assert pool.alloc_page(0, 0, 0) and pool.alloc_page(0, 1, 0)
    assert not pool.alloc_page(1, 0, 0)        # budget spent, no augmenting
    assert pool.stats["alloc_failures"] == 1
    assert not pool.can_admit_tokens(1)


def test_pool_pressure_augments_coldest_first():
    g = _pool().geom
    # budget fits 2 Normal pages, and a third page only after exactly one
    # augmentation (normal + 2*aug <= budget < 2*normal + aug)
    pool = _pool(budget_bytes=g.page_bytes_normal + 2 * g.page_bytes_aug)
    assert pool.alloc_page(0, 0, step=0)       # coldest (earliest write)
    assert pool.alloc_page(0, 1, step=5)
    # budget full: the next alloc must demote the step-0 page, not step-5
    assert pool.alloc_page(1, 0, step=6)
    assert pool.page_mode[0, 0] == 1           # cold page went Augmented
    assert pool.page_mode[0, 1] == 0           # hot page stayed Normal
    assert pool.page_mode[1, 0] == 1           # newcomer placed packed
    assert pool.stats["augment_events"] == 1


def test_pool_refresh_restamps_and_accounts_traffic():
    pool = _pool(kv_mode="int8", pool_mode="always-augmented",
                 retention_steps=2)
    assert pool.alloc_page(0, 0, step=0)
    assert pool.refresh_due(1) == []
    assert pool.refresh_due(2) == [(0, 0)]     # age == retention_steps
    pool.refresh_page(0, 0, step=2)
    assert pool.refresh_due(2) == []           # restamped
    assert pool.stats["refreshes"] == 1
    assert pool.stats["refresh_bytes"] == 2 * pool.geom.page_bytes_aug
    assert pool.max_augmented_age(3) == 1


def test_pool_single_sequence_must_fit_budget():
    g = _pool().geom
    with pytest.raises(ValueError, match="cannot hold one full sequence"):
        _pool(pool_mode="normal-only",          # 1 of the 2 pages needed
              budget_bytes=g.page_bytes_normal)


def test_device_tables_hold_previous_semantics():
    pool = _pool(kv_mode="int8")
    pool.alloc_page(0, 0, 0)
    pool.alloc_page(0, 1, 0)
    pool.augment_page(0, 1, step=1)            # page 1 -> packed plane
    t = pool.device_tables()
    md = np.asarray(t["page_modes"])
    ni, pi = np.asarray(t["normal_idx"]), np.asarray(t["packed_idx"])
    assert md[0, 0] == 0 and md[0, 1] == 1
    assert ni[0, 0] == pool.page_table[0, 0]
    assert ni[0, 1] == ni[0, 0]                # held: no DMA for normal
    assert pi[0, 0] == 0                       # dump until first aug page
    assert pi[0, 1] == pool.page_table[0, 1]
