"""Dequant-free decode + augmented weight storage, end-to-end through the
model stack: golden equivalence of the kernel-backed paths vs the dense
references, and an HLO-text proof that the jitted decode step never
materializes the bf16 KV cache."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import AMCConfig, ShapeConfig
from repro.models import augment
from repro.models import model as M
from repro.models.params import init_params


def _cfg(kv_mode="normal", weight_mode="normal", kv_impl="kernel",
         arch="granite-3-2b"):
    cfg = get_arch(arch).reduced()
    return dataclasses.replace(
        cfg, amc=AMCConfig(weight_mode=weight_mode, kv_mode=kv_mode,
                           kv_impl=kv_impl))


def _zero_cache(cfg, B, S):
    shape = ShapeConfig("d", S, B, "decode")
    return jax.tree.map(
        lambda l: jnp.zeros(l.shape, l.jdtype), M.abstract_cache(cfg, shape),
        is_leaf=lambda x: hasattr(x, "jdtype"))


from repro.kernels.ref import rel_err as _rel_err  # shared oracle metric


# ---------------------------------------------------------------------------
# kernel-backed decode vs the old unpack-then-dense path (golden)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_mode", ["int4", "int8"])
def test_decode_kernel_matches_dequant_reference(kv_mode):
    """The Pallas flash-decode path and the dequantize-everything path
    must produce the same logits (same packed cache in, same math)."""
    B, S, T = 2, 32, 6
    cfg_k = _cfg(kv_mode, kv_impl="kernel")
    cfg_d = _cfg(kv_mode, kv_impl="dequant")
    params = init_params(M.abstract_params(cfg_k), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg_k.vocab)
    cache_k, cache_d = _zero_cache(cfg_k, B, S), _zero_cache(cfg_d, B, S)
    for t in range(T):
        batch = {"tokens": toks[:, t:t + 1],
                 "positions": jnp.full((B,), t, jnp.int32)}
        lg_k, cache_k = M.decode_step(cfg_k, params, cache_k, batch)
        lg_d, cache_d = M.decode_step(cfg_d, params, cache_d, batch)
        assert _rel_err(lg_k, lg_d) < 0.05, t
    # the caches REPRESENT the same values (the two impls are distinct XLA
    # programs, so fusion-order rounding may flip a quantization boundary
    # on isolated entries — a flipped entry is off by one full quant step,
    # so compare dequantized MEAN deviation, not bytes or max)
    from repro.models import layers as L
    unpack = L.unpack_kv_int4 if kv_mode == "int4" else L.unpack_kv_int8
    for kv in ("k", "v"):
        a = np.asarray(unpack(cache_k[kv], cache_k[f"{kv}_scale"]),
                       np.float32)
        b = np.asarray(unpack(cache_d[kv], cache_d[f"{kv}_scale"]),
                       np.float32)
        assert np.abs(a - b).mean() / max(np.abs(b).max(), 1e-6) < 1e-3, kv


@pytest.mark.parametrize("kv_mode", ["int4", "int8"])
def test_prefill_then_decode_kernel_vs_dequant(kv_mode):
    """prefill_step fills the packed head-major cache; decode continues on
    it — kernel and dequant impls must agree through the whole chain."""
    B, S, P = 2, 32, 7
    cfg_k = _cfg(kv_mode, kv_impl="kernel", arch="qwen1.5-0.5b")
    cfg_d = _cfg(kv_mode, kv_impl="dequant", arch="qwen1.5-0.5b")
    params = init_params(M.abstract_params(cfg_k), jax.random.PRNGKey(2))
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, P), 0, cfg_k.vocab)
    batch = {"tokens": toks, "positions": jnp.zeros((B,), jnp.int32),
             "write_mask": jnp.ones((B,), bool)}
    outs = {}
    for name, cfg in (("kernel", cfg_k), ("dequant", cfg_d)):
        cache = _zero_cache(cfg, B, S)
        lg, cache = M.prefill_step(cfg, params, cache, batch)
        dl, cache = M.decode_step(
            cfg, params, cache,
            {"tokens": toks[:, -1:],
             "positions": jnp.full((B,), P, jnp.int32)})
        outs[name] = (lg, dl)
    assert _rel_err(outs["kernel"][0], outs["dequant"][0]) < 0.05
    assert _rel_err(outs["kernel"][1], outs["dequant"][1]) < 0.05


def test_decode_int4_agrees_with_normal_cache():
    """Sanity: the packed-kernel decode tracks the full-precision cache.
    With random-init weights the logit gaps are tiny, so int4 KV noise
    flips some argmaxes — require majority agreement (the seed's serving
    version of this check required 1-of-2)."""
    B, S, T = 2, 32, 8
    cfg_q = _cfg("int4")
    cfg_n = _cfg("normal")
    params = init_params(M.abstract_params(cfg_q), jax.random.PRNGKey(4))
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, T), 0, cfg_q.vocab)
    cache_q, cache_n = _zero_cache(cfg_q, B, S), _zero_cache(cfg_n, B, S)
    agree = 0
    for t in range(T):
        batch = {"tokens": toks[:, t:t + 1],
                 "positions": jnp.full((B,), t, jnp.int32)}
        lg_q, cache_q = M.decode_step(cfg_q, params, cache_q, batch)
        lg_n, cache_n = M.decode_step(cfg_n, params, cache_n, batch)
        agree += int((jnp.argmax(lg_q[:, -1], -1)
                      == jnp.argmax(lg_n[:, -1], -1)).sum())
    assert agree > B * T // 2, (agree, B * T)


# ---------------------------------------------------------------------------
# acceptance: the jitted int4 decode step materializes NO bf16 cache
# ---------------------------------------------------------------------------

def _decode_hlo(cfg, B, S):
    params = init_params(M.abstract_params(cfg), jax.random.PRNGKey(0))
    cache = _zero_cache(cfg, B, S)
    batch = {"tokens": jnp.ones((B, 1), jnp.int32),
             "positions": jnp.zeros((B,), jnp.int32)}
    fn = jax.jit(lambda p, c, b: M.decode_step(cfg, p, c, b))
    return fn.lower(params, cache, batch).as_text()


def _bf16_cache_shapes(cfg, B, S):
    """Textual type patterns of a full dequantized cache, any dim order."""
    KV, hd = cfg.n_kv_heads, cfg.hd
    return [f"tensor<{B}x{S}x{KV}x{hd}xbf16>",
            f"tensor<{B}x{KV}x{S}x{hd}xbf16>"]


@pytest.mark.parametrize("kv_mode", ["int4", "int8"])
def test_decode_hlo_materializes_no_bf16_cache(kv_mode):
    """The acceptance criterion of the dequant-free decode: the lowered
    decode step contains no (B, S, KV, hd)-shaped bf16 tensor in any
    layout. The dequant reference path DOES (positive control, proving
    the pattern actually detects the dequantized cache)."""
    B, S = 2, 64
    cfg = _cfg(kv_mode, kv_impl="kernel")
    txt = _decode_hlo(cfg, B, S)
    pats = _bf16_cache_shapes(cfg, B, S)
    for pat in pats:
        assert pat not in txt, f"dequantized cache {pat} in kernel-path HLO"
    ref_txt = _decode_hlo(_cfg(kv_mode, kv_impl="dequant"), B, S)
    assert any(p in ref_txt for p in pats), \
        "positive control failed: dequant path shows no bf16 cache"


def test_decode_hlo_no_full_cache_unpack_int8():
    """int8 float-cache absence too: no (B,*,*,hd) f32 cache either."""
    B, S = 2, 64
    cfg = _cfg("int8", kv_impl="kernel")
    txt = _decode_hlo(cfg, B, S)
    KV, hd = cfg.n_kv_heads, cfg.hd
    for pat in (f"tensor<{B}x{S}x{KV}x{hd}xf32>",
                f"tensor<{B}x{KV}x{S}x{hd}xf32>"):
        assert pat not in txt, pat


# ---------------------------------------------------------------------------
# augmented weight storage: packed forward == dense(dequantized) forward
# ---------------------------------------------------------------------------

def _golden_weight_pair(weight_mode, arch="granite-3-2b"):
    """(augmented cfg+params, dense cfg+reference params).

    The dense reference carries the DEQUANTIZED packed weights, so any
    disagreement is kernel math, not quantization error."""
    cfg_a = _cfg(weight_mode=weight_mode, arch=arch)
    cfg_n = _cfg(weight_mode="normal", arch=arch)
    dense = init_params(M.abstract_params(cfg_n), jax.random.PRNGKey(6))
    aug = augment.augment_params(cfg_a, dense)
    ref = augment.dequant_params(cfg_a, aug)
    return cfg_a, aug, cfg_n, ref


@pytest.mark.parametrize("weight_mode", ["ternary", "dual"])
def test_forward_augmented_matches_dense_dequant(weight_mode):
    cfg_a, aug, cfg_n, ref = _golden_weight_pair(weight_mode)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0, cfg_a.vocab)
    lg_a = M.forward(cfg_a, aug, {"tokens": toks}, q_chunk=16)
    lg_r = M.forward(cfg_n, ref, {"tokens": toks}, q_chunk=16)
    assert _rel_err(lg_a, lg_r) < 0.03


@pytest.mark.parametrize("weight_mode", ["ternary", "dual"])
def test_decode_augmented_matches_dense_dequant(weight_mode):
    cfg_a, aug, cfg_n, ref = _golden_weight_pair(weight_mode,
                                                 arch="qwen1.5-0.5b")
    B, S, T = 2, 32, 4
    toks = jax.random.randint(jax.random.PRNGKey(8), (B, T), 0, cfg_a.vocab)
    cache_a, cache_r = _zero_cache(cfg_a, B, S), _zero_cache(cfg_n, B, S)
    for t in range(T):
        batch = {"tokens": toks[:, t:t + 1],
                 "positions": jnp.full((B,), t, jnp.int32)}
        lg_a, cache_a = M.decode_step(cfg_a, aug, cache_a, batch)
        lg_r, cache_r = M.decode_step(cfg_n, ref, cache_r, batch)
        assert _rel_err(lg_a, lg_r) < 0.03, t


def test_augment_params_idempotent_and_invertible():
    cfg = _cfg(weight_mode="ternary")
    dense = init_params(
        M.abstract_params(_cfg(weight_mode="normal")), jax.random.PRNGKey(9))
    aug = augment.augment_params(cfg, dense)
    assert augment.is_augmented(aug)
    assert augment.augment_params(cfg, aug) is aug         # idempotent
    attn = aug["layers"]["attn"]
    assert attn["wq_packed"].dtype == jnp.uint8
    # packed dim is K//4: 8x fewer bytes than the bf16 master
    assert attn["wq_packed"].nbytes * 8 == dense["layers"]["attn"]["wq"].nbytes
    ref = augment.dequant_params(cfg, aug)
    assert set(ref["layers"]["attn"]) == set(dense["layers"]["attn"])


def test_augment_pspecs_match_packed_arrays():
    """The declarative PSpec view and the real packed arrays must agree on
    shapes and dtypes (one tree, two views)."""
    cfg = _cfg(weight_mode="dual", arch="qwen1.5-0.5b")
    dense_specs = M.abstract_params(_cfg(weight_mode="normal",
                                         arch="qwen1.5-0.5b"))
    aug_specs = augment.augment_pspecs(cfg, dense_specs)
    dense = init_params(dense_specs, jax.random.PRNGKey(10))
    aug = augment.augment_params(cfg, dense)
    specs = jax.tree_util.tree_leaves_with_path(
        aug_specs, is_leaf=lambda x: hasattr(x, "jdtype"))
    arrays = dict(jax.tree_util.tree_leaves_with_path(aug))
    assert len(specs) == len(arrays)
    for path, spec in specs:
        arr = arrays[path]
        assert tuple(spec.shape) == arr.shape, path
        assert spec.jdtype == arr.dtype, path


# ---------------------------------------------------------------------------
# serving engine with augmented weights
# ---------------------------------------------------------------------------

def test_engine_weight_mode_knob_and_stats():
    import numpy as np
    from repro.launch.mesh import make_local_mesh
    from repro.serve import Request, ServeEngine

    cfg = _cfg(arch="qwen1.5-0.5b")        # amc: all-normal
    eng = ServeEngine(cfg, make_local_mesh(), max_batch=2, max_seq=32,
                      weight_mode="ternary", kv_mode="int4", seed=3)
    assert eng.cfg.amc.weight_mode == "ternary"
    assert augment.is_augmented(eng.params)
    rng = np.random.default_rng(0)
    outs = eng.generate([Request(
        prompt=rng.integers(0, cfg.vocab, size=(5,)).astype(np.int32),
        max_new_tokens=4, id=0)])
    assert len(outs[0]) == 4
    assert all(0 <= t < cfg.vocab_padded for t in outs[0])
    st = eng.stats()
    assert st["weight_bits_per_value"] == 2.0
    assert st["kv_bits_per_value"] == 4.0
    # packed weights strictly smaller than the dense logical footprint;
    # int4 cache rows are hd/2 bytes + scales vs 2*hd bf16 (~3.6x)
    assert st["weight_bytes_physical"] < st["weight_bytes_logical"]
    assert st["cache_capacity_factor"] > 3.0
    assert st["capacity_factor"] > 1.5


def test_engine_augmented_matches_dense_dequant_serving():
    """Full serving golden: an engine with packed ternary weights must
    generate the same greedy tokens as one fed the dequantized dense
    weights (the packing is the only difference)."""
    import numpy as np
    from repro.launch.mesh import make_local_mesh
    from repro.serve import Request, ServeEngine

    cfg_a = _cfg(kv_mode="int4", weight_mode="ternary", arch="qwen1.5-0.5b")
    cfg_n = _cfg(kv_mode="int4", weight_mode="normal", arch="qwen1.5-0.5b")
    dense = init_params(M.abstract_params(cfg_n), jax.random.PRNGKey(11))
    aug = augment.augment_params(cfg_a, dense)
    ref = augment.dequant_params(cfg_a, aug)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg_a.vocab, size=(6,)).astype(np.int32)
               for _ in range(2)]
    outs = {}
    for name, (cfg, params) in (("aug", (cfg_a, aug)),
                                ("ref", (cfg_n, ref))):
        eng = ServeEngine(cfg, make_local_mesh(), max_batch=2, max_seq=32,
                          prefill_chunk=4, params=params)
        outs[name] = eng.generate(
            [Request(prompt=p, max_new_tokens=4, id=i)
             for i, p in enumerate(prompts)])
    agree = sum(outs["aug"][i] == outs["ref"][i] for i in range(2))
    assert agree == 2, outs
