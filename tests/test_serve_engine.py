"""Serving hot path: single-dispatch chunked prefill (dispatch-count
regression), golden equivalence vs the per-token seed path, continuous
batching (slot release/reclaim, ragged lengths)."""
import dataclasses
import math

import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import AMCConfig
from repro.launch.mesh import make_local_mesh
from repro.serve import Request, ServeEngine


def _cfg(mode=None):
    cfg = get_arch("qwen1.5-0.5b").reduced()
    if mode is not None:
        cfg = dataclasses.replace(cfg, amc=AMCConfig(kv_mode=mode))
    return cfg


def _prompt(rng, n, vocab):
    return rng.integers(0, vocab, size=(n,)).astype(np.int32)


# ---------------------------------------------------------------------------
# dispatch-count regression: prefill must be O(P / chunk), not O(P)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("plen,chunk", [(17, 8), (9, 8), (25, 4), (2, 8)])
def test_prefill_dispatch_count(plen, chunk):
    cfg = _cfg()
    eng = ServeEngine(cfg, make_local_mesh(), max_batch=2, max_seq=64,
                      prefill_chunk=chunk)
    rng = np.random.default_rng(0)
    before = eng.dispatch_count
    slot = eng.add_request(Request(prompt=_prompt(rng, plen, cfg.vocab),
                                   max_new_tokens=2, id=0))
    # prompt[:-1] is prefilled; the last token is fed by the first decode
    want = math.ceil((plen - 1) / chunk)
    assert eng.dispatch_count - before == want, \
        f"{plen}-token prompt took {eng.dispatch_count - before} dispatches"
    assert int(eng.positions[slot]) == plen - 1


def test_add_request_rejects_prompt_longer_than_cache():
    """Past max_seq every cache write would clamp to the last slot and
    silently corrupt the row — the engine must reject instead."""
    cfg = _cfg()
    eng = ServeEngine(cfg, make_local_mesh(), max_batch=1, max_seq=16)
    rng = np.random.default_rng(7)
    with pytest.raises(ValueError, match="exceeds max_seq"):
        eng.add_request(Request(prompt=_prompt(rng, 17, cfg.vocab),
                                max_new_tokens=1, id=0))


def test_prefill_single_token_prompt_no_dispatch():
    cfg = _cfg()
    eng = ServeEngine(cfg, make_local_mesh(), max_batch=2, max_seq=32)
    before = eng.dispatch_count
    eng.add_request(Request(prompt=np.array([3], np.int32),
                            max_new_tokens=2, id=0))
    assert eng.dispatch_count == before


# ---------------------------------------------------------------------------
# golden equivalence: chunked prefill == per-token seed path, greedy tokens
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["int4", "int8", "normal"])
def test_prefill_golden_vs_stepwise(mode):
    cfg = _cfg(mode)
    rng = np.random.default_rng(3)
    prompts = [_prompt(rng, n, cfg.vocab) for n in (7, 4, 10, 2)]

    def run(chunked: bool):
        eng = ServeEngine(cfg, make_local_mesh(), max_batch=2, max_seq=32,
                          prefill_chunk=4, seed=5)
        if not chunked:
            eng._prefill = None      # force the per-token warmup loop
        reqs = [Request(prompt=p, max_new_tokens=4, id=i)
                for i, p in enumerate(prompts)]
        return eng.generate(reqs), eng.dispatch_count

    fast, fast_n = run(chunked=True)
    slow, slow_n = run(chunked=False)
    assert fast == slow, (fast, slow)
    assert fast_n < slow_n


def test_prefill_near_cache_end_stays_chunked_and_golden():
    """A short final chunk near the cache end used to fall back to
    per-token stepwise prefill (the spill check compared against the
    padded chunk size C, not the actual n): now the scatter window is
    left-shifted and replays already-prefilled tokens instead — outputs
    must stay identical to the per-token path."""
    cfg = _cfg()
    rng = np.random.default_rng(4)
    prompt = _prompt(rng, 19, cfg.vocab)   # 18 prefill tokens

    def run(chunked: bool):
        eng = ServeEngine(cfg, make_local_mesh(), max_batch=1, max_seq=20,
                          prefill_chunk=8, seed=1)
        if not chunked:
            eng._prefill = None
        out = eng.generate([Request(prompt=prompt, max_new_tokens=1, id=0)])
        return out, eng.dispatch_count

    fast, fast_n = run(True)
    slow, slow_n = run(False)
    assert fast == slow
    assert fast_n < slow_n


@pytest.mark.parametrize("plen,chunk,max_seq", [(19, 8, 20), (18, 8, 20),
                                                (31, 8, 32), (21, 4, 22)])
def test_prefill_short_final_chunk_dispatch_count(plen, chunk, max_seq):
    """Regression for the spill check: prefill near the cache end must
    still cost ceil(P / chunk) dispatches (no stepwise fallback while the
    real tokens fit)."""
    cfg = _cfg()
    eng = ServeEngine(cfg, make_local_mesh(), max_batch=1, max_seq=max_seq,
                      prefill_chunk=chunk)
    rng = np.random.default_rng(5)
    before = eng.dispatch_count
    slot = eng.add_request(Request(prompt=_prompt(rng, plen, cfg.vocab),
                                   max_new_tokens=1, id=0))
    want = math.ceil((plen - 1) / chunk)
    assert eng.dispatch_count - before == want, \
        (plen, chunk, max_seq, eng.dispatch_count - before, want)
    assert int(eng.positions[slot]) == plen - 1


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------

def test_slot_release_and_reclaim():
    cfg = _cfg()
    eng = ServeEngine(cfg, make_local_mesh(), max_batch=2, max_seq=32,
                      prefill_chunk=4)
    rng = np.random.default_rng(1)
    reqs = [Request(prompt=_prompt(rng, 4, cfg.vocab),
                    max_new_tokens=3 + i, id=i) for i in range(5)]
    outs = eng.generate(reqs)
    assert sorted(outs) == [0, 1, 2, 3, 4]       # all 5 ran on 2 slots
    for i, toks in outs.items():
        assert len(toks) == 3 + i
        assert all(0 <= t < cfg.vocab_padded for t in toks)
    assert not eng.active.any()                  # every slot released
    assert eng.slot_req == [None, None]


def test_ragged_lengths_across_batch():
    """Rows with different prompt lengths and budgets coexist in one
    batch; each request sees exactly its own budget."""
    cfg = _cfg()
    eng = ServeEngine(cfg, make_local_mesh(), max_batch=3, max_seq=32,
                      prefill_chunk=4)
    rng = np.random.default_rng(2)
    lens = [2, 9, 5]
    budgets = [6, 2, 4]
    reqs = [Request(prompt=_prompt(rng, n, cfg.vocab), max_new_tokens=b,
                    id=i) for i, (n, b) in enumerate(zip(lens, budgets))]
    outs = eng.generate(reqs)
    for i, b in enumerate(budgets):
        assert len(outs[i]) == b, outs
    # per-row positions advanced independently (ragged, no cross-talk)
    assert not eng.active.any()


def test_prefill_does_not_disturb_other_slots():
    """Prefilling a new request mid-flight must not change what an
    already-running slot generates (write-masked cache scatter)."""
    cfg = _cfg()
    rng = np.random.default_rng(6)
    p_a = _prompt(rng, 6, cfg.vocab)
    p_b = _prompt(rng, 11, cfg.vocab)

    # alone: request A with a huge budget, no interference
    eng1 = ServeEngine(cfg, make_local_mesh(), max_batch=2, max_seq=64,
                       prefill_chunk=4, seed=2)
    alone = eng1.generate([Request(prompt=p_a, max_new_tokens=8, id=0)])[0]

    # interleaved: A starts, B arrives after A has generated a few tokens
    eng2 = ServeEngine(cfg, make_local_mesh(), max_batch=2, max_seq=64,
                       prefill_chunk=4, seed=2)
    eng2.add_request(Request(prompt=p_a, max_new_tokens=8, id=0))
    for _ in range(3):
        eng2.step_all()
    eng2.add_request(Request(prompt=p_b, max_new_tokens=4, id=1))
    while eng2.active.any():
        eng2.step_all()
    assert eng2.outputs[0] == alone
