"""core/retention.py: the leakage model must reproduce the paper's
calibration tables (I-II) exactly, and the step-based RefreshPolicy
derived from it must be monotone in temperature (colder -> longer
retention -> more decode steps between refreshes)."""
import jax.numpy as jnp
import pytest

from repro.core.retention import (LeakageModel, RefreshPolicy,
                                  V_SENSE_FRACTION, quant_error_halflife)


# ---------------------------------------------------------------------------
# paper calibration points (Tables I-II)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cell,temp_c,want_us", [
    ("8T", 85, 25.0), ("8T", 25, 250.0),
    ("7T", 85, 4.0),
])
def test_leakage_reproduces_paper_points(cell, temp_c, want_us):
    assert LeakageModel(cell=cell).retention_us(temp_c) == pytest.approx(
        want_us, rel=1e-9)


def test_leakage_7t_25c_at_least_50us():
    """Table II quotes the 7T cell's 25C retention as '> 50us'."""
    assert LeakageModel(cell="7T").retention_us(25) >= 50.0


@pytest.mark.parametrize("cell", ["8T", "7T"])
def test_retention_monotone_decreasing_in_temperature(cell):
    m = LeakageModel(cell=cell)
    temps = [0, 25, 45, 65, 85, 105]
    rets = [m.retention_us(t) for t in temps]
    assert all(a > b for a, b in zip(rets, rets[1:])), rets


def test_readable_flips_exactly_at_retention():
    """The sense margin crosses V_SENSE_FRACTION at the retention time."""
    m = LeakageModel(cell="8T")
    lvl = jnp.ones(())
    ret = m.retention_us(85)
    assert bool(m.readable(lvl, 0.5 * ret, 85))
    assert not bool(m.readable(lvl, 1.5 * ret, 85))
    # decay at exactly retention equals the sense threshold
    assert float(m.decay(lvl, ret, 85)) == pytest.approx(V_SENSE_FRACTION,
                                                         rel=1e-6)


# ---------------------------------------------------------------------------
# RefreshPolicy wiring (the serving scheduler's clock)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cell", ["8T", "7T"])
def test_refresh_policy_steps_monotone_in_temperature(cell):
    """Colder silicon buys strictly more decode steps per refresh window
    (the cryo-friendly scaling the paper calls out), never below 1."""
    step_us = 1.0
    temps = [0, 25, 45, 65, 85, 105]
    steps = [RefreshPolicy.from_leakage(cell, t, step_us).retention_steps
             for t in temps]
    assert all(a >= b for a, b in zip(steps, steps[1:])), steps
    assert steps[0] > steps[-1], steps
    assert all(s >= 1 for s in steps)
    # calibration: 8T @ 85C with 1us steps = floor(25us / 1us)
    if cell == "8T":
        assert RefreshPolicy.from_leakage("8T", 85, 1.0).retention_steps == 25


def test_refresh_policy_validity_window():
    pol = RefreshPolicy(retention_steps=3)
    assert not pol.valid(0)            # never written
    pol.stamp(10)
    assert pol.valid(12) and not pol.needs_refresh(12)
    assert not pol.valid(13) and pol.needs_refresh(13)
    assert pol.expires_at() == 13
    pol.stamp(13)                      # refresh restamps
    assert pol.valid(15)


def test_quant_error_halflife_tracks_bits():
    assert quant_error_halflife(4) > quant_error_halflife(8)


# ---------------------------------------------------------------------------
# boundary semantics pinned (the fault model and scheduler both key off
# `age == retention_steps` being the FIRST invalid step — off-by-one here
# silently shifts every injection/refresh decision)
# ---------------------------------------------------------------------------

def test_refresh_policy_boundary_exactly_at_retention():
    pol = RefreshPolicy(retention_steps=8)
    pol.stamp(100)
    assert pol.valid(107) and not pol.needs_refresh(107)    # age == ret - 1
    assert not pol.valid(108) and pol.needs_refresh(108)    # age == ret
    assert pol.age(108) == 8 and pol.expires_at() == 108


def test_refresh_policy_never_written_plane():
    """A plane that was never stamped is invalid but does NOT demand a
    refresh (there is nothing to re-quantize) and reports age 0."""
    pol = RefreshPolicy(retention_steps=8)
    assert not pol.valid(0) and not pol.valid(10 ** 6)
    assert not pol.needs_refresh(5)
    assert pol.age(123) == 0


def test_from_leakage_extreme_temps_clamp_to_one():
    """Steps so long (or silicon so hot) that retention < one step must
    clamp to 1, never 0 — else an augmented page could never be read."""
    assert RefreshPolicy.from_leakage("7T", 125, 1e6).retention_steps == 1
    assert RefreshPolicy.from_leakage("8T", 105, 1e9).retention_steps == 1
    assert RefreshPolicy.from_leakage("8T", -40, 1.0).retention_steps >= 1
