"""Continuous-batching scheduler over the paged pool: zero-drop
admission under overload, augment-on-pressure capacity vs normal-only at
equal bytes, the refresh invariant (no augmented page outlives
retention_steps), preemption-by-augmentation, BOS handling, and the
queue-backed add_request regression."""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import AMCConfig
from repro.launch.mesh import make_local_mesh
from repro.serve import Request, ServeEngine


def _cfg(**amc):
    cfg = get_arch("qwen1.5-0.5b").reduced()
    return dataclasses.replace(cfg, amc=AMCConfig(**amc))


def _reqs(rng, cfg, n, plen=6, max_new=4):
    return [Request(prompt=rng.integers(0, cfg.vocab, size=(plen,))
                    .astype(np.int32), max_new_tokens=max_new, id=i)
            for i in range(n)]


# ---------------------------------------------------------------------------
# acceptance: 4x offered load, zero drops
# ---------------------------------------------------------------------------

def test_zero_drops_at_4x_offered_load():
    cfg = _cfg(kv_mode="int8")
    eng = ServeEngine(cfg, make_local_mesh(), max_batch=2, max_seq=32,
                      prefill_chunk=16)
    rng = np.random.default_rng(0)
    reqs = _reqs(rng, cfg, 4 * eng.max_batch)   # all offered at once
    outs = eng.generate(reqs)
    assert sorted(outs) == list(range(8))       # nothing dropped
    for i, toks in outs.items():
        assert len(toks) == 4, (i, toks)
    assert len(eng.scheduler.queue) == 0
    assert eng.scheduler.stats["peak_queue_depth"] >= 6  # 8 offered, 2 rows


def test_augment_on_pressure_admits_more_at_equal_bytes():
    """The paper's on-demand capacity: at the SAME byte budget, the
    augment-on-pressure pool must reach strictly higher peak concurrency
    than normal-only (cold pages demoted to the packed plane make room)."""
    base = get_arch("qwen1.5-0.5b").reduced()
    rng = np.random.default_rng(1)
    peaks, pools = {}, {}
    for mode in ("normal-only", "augment-on-pressure"):
        cfg = dataclasses.replace(
            base, amc=AMCConfig(kv_mode="normal", pool_mode=mode))
        eng = ServeEngine(cfg, make_local_mesh(), max_batch=4, max_seq=32,
                          prefill_chunk=16, pool_budget_bytes=2 * 16384)
        budget = eng.pool.budget_bytes
        outs = eng.generate(_reqs(rng, cfg, 8, plen=8, max_new=4))
        assert all(len(outs[i]) == 4 for i in range(8)), mode
        peaks[mode] = eng.scheduler.stats["peak_concurrency"]
        pools[mode] = (budget, eng.stats()["pool"])
    assert pools["normal-only"][0] == pools["augment-on-pressure"][0]
    assert peaks["augment-on-pressure"] > peaks["normal-only"], peaks
    assert pools["augment-on-pressure"][1]["augment_events"] > 0


# ---------------------------------------------------------------------------
# refresh invariant
# ---------------------------------------------------------------------------

def test_augmented_pages_refreshed_within_retention_steps():
    """Scheduler invariant: at every decode-step boundary, no augmented
    page has gone more than retention_steps steps without a (re)write or
    refresh."""
    cfg = _cfg(kv_mode="int8", pool_mode="always-augmented",
               retention_steps=2)
    eng = ServeEngine(cfg, make_local_mesh(), max_batch=2, max_seq=32,
                      prefill_chunk=16)
    rng = np.random.default_rng(2)
    for r in _reqs(rng, cfg, 2, plen=20, max_new=8):
        eng.add_request(r)
    while eng.active.any():
        eng.step_all()
        age = eng.pool.max_augmented_age(eng.step_idx)
        assert age <= cfg.amc.retention_steps, (age, eng.step_idx)
    st = eng.stats()
    assert st["refreshes"] > 0              # cold prompt pages expired
    assert st["refresh_bytes"] > 0


# ---------------------------------------------------------------------------
# preemption-by-augmentation
# ---------------------------------------------------------------------------

def test_preemption_requeues_and_completes_identically():
    """When growth outruns even augmentation, the youngest row is
    preempted and resumed by greedy recompute — same tokens as an
    unpressured run, zero drops."""
    cfg = _cfg(kv_mode="int8", pool_mode="always-augmented")
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=(14,)).astype(np.int32)
               for _ in range(2)]

    def run(budget_pages):
        eng = ServeEngine(cfg, make_local_mesh(), max_batch=2, max_seq=32,
                          prefill_chunk=16, seed=4,
                          pool_budget_bytes=budget_pages
                          * 8704)  # page_bytes_aug of the reduced config
        assert eng.pool.geom.page_bytes_aug == 8704, \
            "reduced-config geometry changed; update the test budget"
        outs = eng.generate([Request(prompt=p, max_new_tokens=6, id=i)
                             for i, p in enumerate(prompts)])
        return outs, eng.scheduler.stats["preemptions"]

    full, p0 = run(budget_pages=4)      # both rows fit: no preemption
    tight, p1 = run(budget_pages=3)     # 2 growing rows, 3 pages: preempt
    assert p0 == 0 and p1 >= 1
    assert full == tight                # recompute reproduced the tokens


def test_double_preemption_does_not_duplicate_tokens():
    """A resumed entry's prompt already contains its first stint's
    generated tokens; a second preemption must rebuild from the ORIGINAL
    prompt + the full output list, not concatenate the two (which would
    duplicate the first stint)."""
    cfg = _cfg(kv_mode="int8", pool_mode="always-augmented")
    eng = ServeEngine(cfg, make_local_mesh(), max_batch=2, max_seq=32,
                      prefill_chunk=16, seed=9)
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab, size=(6,)).astype(np.int32)
    eng.add_request(Request(prompt=prompt, max_new_tokens=8, id=0))
    eng.step_all()
    eng.step_all()                              # 2 tokens generated
    for round_ in range(2):                     # preempt, resume, repeat
        eng._preempt(0)
        entry = eng._queue[0]
        want = np.concatenate([prompt,
                               np.asarray(eng.outputs[0], np.int32)])
        assert np.array_equal(entry.prompt, want), round_
        eng.step_all()                          # re-admit + 1 more token
    while eng.active.any() or eng._queue:
        eng.step_all()
    assert len(eng.outputs[0]) == 8


# ---------------------------------------------------------------------------
# queue-backed add_request (regression: full batch used to drop to None)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "mamba2-130m"])
def test_add_request_enqueues_when_full_never_drops(arch):
    cfg = get_arch(arch).reduced()
    eng = ServeEngine(cfg, make_local_mesh(), max_batch=1, max_seq=32,
                      prefill_chunk=8)
    rng = np.random.default_rng(5)
    r0, r1, r2 = _reqs(rng, cfg, 3, plen=4, max_new=3)
    assert eng.add_request(r0) == 0          # admitted immediately
    assert eng.add_request(r1) is None       # batch full -> queued
    assert eng.add_request(r2) is None
    assert len(eng._queue) == 2              # queued, NOT dropped
    for _ in range(64):
        if not (eng.active.any() or eng._queue):
            break
        eng.step_all()
    assert sorted(eng.outputs) == [0, 1, 2]
    assert all(len(eng.outputs[i]) == 3 for i in range(3))


# ---------------------------------------------------------------------------
# add_request validation (regression: bad requests used to grow the queue
# silently — a max_new_tokens=0 row would occupy its slot forever, and a
# duplicate id would merge two requests' outputs)
# ---------------------------------------------------------------------------

def test_add_request_rejects_nonpositive_budget():
    cfg = _cfg(kv_mode="normal")
    eng = ServeEngine(cfg, make_local_mesh(), max_batch=1, max_seq=16)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.add_request(Request(prompt=np.array([1, 2], np.int32),
                                max_new_tokens=0, id=0))
    assert len(eng._queue) == 0              # rejected, not queued


def test_add_request_rejects_duplicate_inflight_id():
    cfg = _cfg(kv_mode="normal")
    eng = ServeEngine(cfg, make_local_mesh(), max_batch=1, max_seq=16)
    rng = np.random.default_rng(11)
    r0, r1, r2 = _reqs(rng, cfg, 3, plen=3, max_new=2)
    r1.id = r0.id                            # same id, running
    assert eng.add_request(r0) == 0
    with pytest.raises(ValueError, match="already queued"):
        eng.add_request(r1)
    r2.id = 7
    eng.add_request(r2)                      # queued (batch full)
    dup = Request(prompt=r2.prompt, max_new_tokens=2, id=7)
    with pytest.raises(ValueError, match="already queued"):
        eng.add_request(dup)
    while eng.active.any() or eng._queue:
        eng.step_all()
    assert sorted(eng.outputs) == [0, 7]
    # COMPLETED ids are reserved too: outputs keys the token lists, so a
    # recycled id would append the new request's tokens onto the old ones
    with pytest.raises(ValueError, match="completed"):
        eng.add_request(Request(prompt=r2.prompt, max_new_tokens=2, id=0))


# ---------------------------------------------------------------------------
# explicit BOS handling (regression: empty prompt used to feed token 0)
# ---------------------------------------------------------------------------

def test_empty_prompt_without_bos_raises():
    cfg = _cfg(kv_mode="normal")
    eng = ServeEngine(cfg, make_local_mesh(), max_batch=1, max_seq=16)
    with pytest.raises(ValueError, match="bos_id"):
        eng.add_request(Request(prompt=np.array([], np.int32),
                                max_new_tokens=2, id=0))


def test_empty_prompt_with_bos_matches_explicit_prompt():
    cfg = _cfg(kv_mode="normal")
    bos = 7
    eng_a = ServeEngine(cfg, make_local_mesh(), max_batch=1, max_seq=16,
                        seed=6, bos_id=bos)
    out_a = eng_a.generate([Request(prompt=np.array([], np.int32),
                                    max_new_tokens=4, id=0)])
    eng_b = ServeEngine(cfg, make_local_mesh(), max_batch=1, max_seq=16,
                        seed=6)
    out_b = eng_b.generate([Request(prompt=np.array([bos], np.int32),
                                    max_new_tokens=4, id=0)])
    assert out_a == out_b


# ---------------------------------------------------------------------------
# unified engine vs the pre-refactor contiguous engine (pinned goldens)
# ---------------------------------------------------------------------------

# Greedy tokens captured from the PRE-REFACTOR engine (legacy contiguous
# slot cache for ssm/hybrid/audio/vlm, paged pool for dense/moe), one
# ISOLATED single-request run per prompt: prompts = default_rng(42) of
# lengths (5, 9), seed=0, max_batch=2, max_seq=32, prefill_chunk=8,
# max_new_tokens=6. The unified engine must reproduce these tokens in a
# BATCHED run: the legacy engine leaked one request's pad-token
# dispatches into co-scheduled rows' recurrent state (no write masking,
# no admission reset — its batched ssm/hybrid outputs depended on
# traffic), while the unified slab store write-masks store-back and
# resets slabs at admission, so every request decodes exactly as if it
# were alone. For the paged families the legacy batched run already
# equalled these isolated tokens (write-masked scatter predates this
# refactor).
_PRE_REFACTOR_GOLDENS = {
    "qwen1.5-0.5b": {0: [34, 34, 34, 139, 139, 139],               # dense
                     1: [84, 226, 226, 226, 226, 226]},
    "qwen3-moe-30b-a3b": {0: [263, 390, 55, 55, 55, 55],           # moe
                          1: [300, 316, 217, 300, 300, 9]},
    "mamba2-130m": {0: [59, 376, 223, 235, 253, 266],              # ssm
                    1: [361, 384, 297, 505, 179, 44]},
    "recurrentgemma-9b": {0: [430, 373, 307, 305, 84, 392],        # hybrid
                          1: [392, 336, 316, 170, 10, 316]},
    "whisper-tiny": {0: [126, 126, 126, 296, 296, 126],            # audio
                     1: [296, 126, 126, 126, 315, 126]},
    "llama-3.2-vision-11b": {0: [46] * 6,                          # vlm
                             1: [409, 234, 461, 461, 461, 461]},
}


@pytest.mark.parametrize("arch", sorted(_PRE_REFACTOR_GOLDENS))
def test_unified_engine_matches_pre_refactor_golden(arch):
    """Every family decodes through Scheduler + state store now; greedy
    outputs must stay token-identical to the pinned pre-refactor run."""
    cfg = get_arch(arch).reduced()
    rng = np.random.default_rng(42)
    prompts = [rng.integers(0, cfg.vocab, size=(n,)).astype(np.int32)
               for n in (5, 9)]
    eng = ServeEngine(cfg, make_local_mesh(), max_batch=2, max_seq=32,
                      prefill_chunk=8, seed=0)
    outs = eng.generate([Request(prompt=p, max_new_tokens=6, id=i)
                         for i, p in enumerate(prompts)])
    assert outs == _PRE_REFACTOR_GOLDENS[arch]


# ---------------------------------------------------------------------------
# unified-store admission / refresh / preemption for the non-KV families
# ---------------------------------------------------------------------------

def _slab_cfg(arch, pool_mode, **amc):
    cfg = get_arch(arch).reduced()
    return dataclasses.replace(
        cfg, amc=dataclasses.replace(cfg.amc, pool_mode=pool_mode, **amc))


@pytest.mark.parametrize("arch", ["mamba2-130m", "recurrentgemma-9b",
                                  "whisper-tiny"])
def test_zero_drops_at_4x_offered_load_all_families(arch):
    """The acceptance sweep holds for recurrent-state and encdec rows
    too: 4x max_batch offered at once, everything completes."""
    cfg = get_arch(arch).reduced()
    eng = ServeEngine(cfg, make_local_mesh(), max_batch=2, max_seq=32,
                      prefill_chunk=16)
    rng = np.random.default_rng(0)
    reqs = _reqs(rng, cfg, 4 * eng.max_batch)
    outs = eng.generate(reqs)
    assert sorted(outs) == list(range(8))
    assert all(len(outs[i]) == 4 for i in range(8))
    assert len(eng.scheduler.queue) == 0
    assert eng.scheduler.stats["peak_queue_depth"] >= 6


def test_slab_augment_on_pressure_admits_more_at_equal_bytes():
    """The paper's on-demand capacity, for RECURRENT state: at the same
    byte budget the augment-on-pressure slab pool reaches strictly higher
    peak concurrency than normal-only (cold slabs quantized in place)."""
    rng = np.random.default_rng(1)
    probe = ServeEngine(get_arch("mamba2-130m").reduced(),
                        make_local_mesh(), max_batch=4, max_seq=32)
    budget = 2 * probe.store.slab_bytes_normal
    del probe
    peaks, stores = {}, {}
    for mode in ("normal-only", "augment-on-pressure"):
        cfg = _slab_cfg("mamba2-130m", mode)
        eng = ServeEngine(cfg, make_local_mesh(), max_batch=4, max_seq=32,
                          prefill_chunk=16, pool_budget_bytes=budget)
        outs = eng.generate(_reqs(rng, cfg, 8, plen=8, max_new=4))
        assert all(len(outs[i]) == 4 for i in range(8)), mode
        peaks[mode] = eng.scheduler.stats["peak_concurrency"]
        stores[mode] = eng.stats()["pool"]
    assert peaks["augment-on-pressure"] > peaks["normal-only"], peaks
    assert stores["augment-on-pressure"]["augment_events"] > 0
    assert stores["normal-only"]["augment_events"] == 0


@pytest.mark.parametrize("arch", ["mamba2-130m", "recurrentgemma-9b"])
def test_slab_refresh_invariant_always_augmented(arch):
    """Augmented slabs are dynamic storage: decode re-writes (restamps)
    them every step, so no slab may outlive retention_steps unrefreshed
    — and the requests still complete (the quantize/dequantize round
    trip is lossy but serving-stable)."""
    cfg = _slab_cfg(arch, "always-augmented", retention_steps=2)
    eng = ServeEngine(cfg, make_local_mesh(), max_batch=2, max_seq=32,
                      prefill_chunk=16)
    rng = np.random.default_rng(2)
    for r in _reqs(rng, cfg, 2, plen=6, max_new=6):
        eng.add_request(r)
    assert int(eng.store.slot_mode[eng.active].sum()) == 2  # all augmented
    while eng.active.any():
        eng.step_all()
        age = eng.store.max_augmented_age(eng.step_idx)
        assert age <= cfg.amc.retention_steps, (age, eng.step_idx)
    assert all(len(v) == 6 for v in eng.outputs.values())


def test_static_prefix_pages_refresh_and_account():
    """The encdec cross-KV prefix band is COLD storage: under an
    always-augmented pool its pages expire every retention_steps and the
    refresh pass restamps them — genuine refresh traffic in stats()."""
    cfg = get_arch("whisper-tiny").reduced()
    cfg = dataclasses.replace(
        cfg, amc=dataclasses.replace(cfg.amc, kv_mode="int8",
                                     retention_steps=2))
    eng = ServeEngine(cfg, make_local_mesh(), max_batch=2, max_seq=32,
                      prefill_chunk=16)
    assert eng.store.prefix_pages > 0
    rng = np.random.default_rng(3)
    outs = eng.generate(_reqs(rng, cfg, 2, plen=6, max_new=8))
    assert all(len(v) == 8 for v in outs.values())
    st = eng.stats()
    assert st["refreshes"] > 0
    assert st["refresh_bytes"] > 0


@pytest.mark.parametrize("arch", ["mamba2-130m", "recurrentgemma-9b"])
def test_preemption_recompute_token_identity_slab_families(arch):
    """Mirror of the dense preemption golden for recurrent-state rows:
    preempt a running request mid-generation, let greedy recompute
    resume it, and require the exact tokens of an unpreempted run."""
    cfg = get_arch(arch).reduced()
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab, size=(6,)).astype(np.int32)

    def run(preempt: bool):
        eng = ServeEngine(cfg, make_local_mesh(), max_batch=2, max_seq=32,
                          prefill_chunk=8, seed=5)
        eng.add_request(Request(prompt=prompt, max_new_tokens=6, id=0))
        eng.step_all()
        eng.step_all()                       # 2 tokens generated
        if preempt:
            eng._preempt(0)                  # slab freed, entry requeued
            assert not eng.active.any()
        while eng.active.any() or eng._queue:
            eng.step_all()
        return eng.outputs[0], eng.scheduler.stats["preemptions"]

    plain, p0 = run(False)
    resumed, p1 = run(True)
    assert p0 == 0 and p1 == 1
    assert len(plain) == 6
    assert plain == resumed                  # recompute reproduced tokens
