"""Array-fleet serving: placement policies (pure), device partitioning,
pinned fleet-vs-single token identity across families, migration under
pressure, array-loss drain that never charges retry budgets (the
cross-array PR-7 guarantee), byte-budget/no-loss placement invariants
(hypothesis), and per-array trace lanes merging into one valid trace."""
import dataclasses
import itertools

import numpy as np
import pytest

from repro.configs import get_arch
from repro.launch.mesh import make_local_mesh
from repro.obs.export import validate_chrome_trace
from repro.serve import (ArrayFleet, ArrayView, Request, ServeEngine,
                         make_policy, make_serving, partition_devices)
from repro.serve.placement import make_array_meshes
from repro.serve.state_store import make_store

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _amc(cfg, **kw):
    return dataclasses.replace(cfg, amc=dataclasses.replace(cfg.amc, **kw))


def _prompt(rng, n, vocab):
    return rng.integers(0, vocab, size=(n,)).astype(np.int32)


def _reqs(cfg, n, plen, max_new, seed=0, id0=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=_prompt(rng, plen, cfg.vocab),
                    max_new_tokens=max_new, id=id0 + i) for i in range(n)]


# ---------------------------------------------------------------------------
# placement policies: pure ArrayView logic, no devices
# ---------------------------------------------------------------------------

def _view(aid, *, alive=True, running=0, queued=0, free_rows=4,
          live_bytes=0, budget_bytes=1000, admit=True):
    return ArrayView(aid=aid, alive=alive, running=running, queued=queued,
                     free_rows=free_rows, live_bytes=live_bytes,
                     budget_bytes=budget_bytes,
                     admit_probe=(lambda n: admit))


def test_least_loaded_order_and_tiebreaks():
    p = make_policy("least-loaded")
    prompt = np.arange(4, dtype=np.int32)
    # fewest running+queued wins
    views = [_view(0, running=2), _view(1, running=1), _view(2, queued=3)]
    assert p.place(prompt, views) == 1
    # tie on load -> more headroom wins
    views = [_view(0, live_bytes=800), _view(1, live_bytes=100)]
    assert p.place(prompt, views) == 1
    # full tie -> lowest aid (deterministic replays)
    assert p.place(prompt, [_view(0), _view(1)]) == 0
    # dead arrays are never placement targets
    views = [_view(0, alive=False), _view(1, running=3)]
    assert p.place(prompt, views) == 1


def test_budget_headroom_prefers_free_bytes():
    p = make_policy("budget-headroom")
    prompt = np.arange(4, dtype=np.int32)
    views = [_view(0, live_bytes=100, running=0),
             _view(1, live_bytes=0, running=5)]
    # headroom dominates load for this policy
    assert p.place(prompt, views) == 1


def test_affinity_stable_and_falls_back():
    p = make_policy("affinity")
    views = [_view(0), _view(1), _view(2)]
    shared = [7, 3, 7, 3, 7, 3, 7, 3]            # same 8-token prefix...
    a = np.array(shared + [1, 2], np.int32)
    b = np.array(shared + [9, 9, 9], np.int32)   # ...different tails
    got = p.place(a, views)
    # prefix-stable: same prefix -> same array, every time
    assert got == p.place(a, views) == p.place(b, views)
    # preferred array saturated -> least-loaded fallback, not queue-behind
    views[got] = _view(got, free_rows=0, admit=False)
    fallback = p.place(a, views)
    assert fallback != got


def test_make_policy_unknown_raises():
    with pytest.raises(ValueError, match="unknown placement policy"):
        make_policy("round-robin")


def test_place_raises_when_no_survivors():
    p = make_policy("least-loaded")
    with pytest.raises(RuntimeError, match="no surviving arrays"):
        p.place(np.arange(2, dtype=np.int32),
                [_view(0, alive=False), _view(1, alive=False)])


def test_partition_devices_groups_and_round_robin():
    devs = ["d0", "d1", "d2", "d3"]
    # contiguous equal groups when devices >= arrays
    assert partition_devices(devs, 2) == [["d0", "d1"], ["d2", "d3"]]
    assert partition_devices(devs, 4) == [["d0"], ["d1"], ["d2"], ["d3"]]
    # remainder devices stay idle (equal per-array compute)
    assert partition_devices(devs, 3) == [["d0"], ["d1"], ["d2"]]
    # fewer devices than arrays: round-robin sharing (over-host case)
    assert partition_devices(["d0"], 3) == [["d0"], ["d0"], ["d0"]]
    assert partition_devices(["d0", "d1"], 4) == \
        [["d0"], ["d1"], ["d0"], ["d1"]]
    with pytest.raises(ValueError):
        partition_devices(devs, 0)


def test_make_array_meshes_share_one_cpu_device():
    meshes = make_array_meshes(3)          # 1 CPU device in the test env
    assert len(meshes) == 3
    for m in meshes:
        assert dict(m.shape) == {"data": 1, "model": 1}


# ---------------------------------------------------------------------------
# factory
# ---------------------------------------------------------------------------

def test_make_serving_switches_on_num_arrays():
    cfg = get_arch("qwen1.5-0.5b").reduced()
    eng = make_serving(cfg, make_local_mesh(), num_arrays=1, max_batch=2,
                       max_seq=32)
    assert isinstance(eng, ServeEngine)
    fleet = make_serving(cfg, make_local_mesh(), num_arrays=2, max_batch=2,
                         max_seq=32)
    assert isinstance(fleet, ArrayFleet) and fleet.num_arrays == 2
    # cfg knob alone is enough — no explicit argument needed
    fleet2 = make_serving(_amc(cfg, num_arrays=2), max_batch=2, max_seq=32)
    assert isinstance(fleet2, ArrayFleet)


# ---------------------------------------------------------------------------
# pinned token identity: fleet(2) == single array, per family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen1.5-0.5b",       # dense paged KV
                                  "qwen3-moe-30b-a3b",  # moe
                                  "mamba2-130m"])       # ssm slab store
def test_fleet_token_identity_vs_single_array(arch):
    """Golden: the fleet decodes the SAME weights through the same
    kernels and per-request decode is batch-composition invariant, so
    sharding requests across arrays must not change one token."""
    cfg = get_arch(arch).reduced()
    reqs = _reqs(cfg, 4, 6, 5, seed=3)
    single = ServeEngine(cfg, make_local_mesh(), max_batch=4, max_seq=32,
                         seed=1)
    want = single.generate([dataclasses.replace(r) for r in reqs])
    fleet = ArrayFleet(cfg, num_arrays=2, max_batch=2, max_seq=32, seed=1)
    got = fleet.generate(reqs)
    assert got == want
    assert not fleet.failed
    st_ = fleet.stats()["fleet"]
    # both arrays actually served (least-loaded spreads 4 reqs 2/2)
    assert st_["placements_per_array"] == [2, 2]
    assert st_["peak_concurrency"] >= 3


# ---------------------------------------------------------------------------
# migration: queued work moves off a pressured array and completes
# ---------------------------------------------------------------------------

def test_rebalance_migrates_queued_work_to_idle_array():
    cfg = get_arch("qwen1.5-0.5b").reduced()
    fleet = ArrayFleet(cfg, num_arrays=2, max_batch=2, max_seq=32, seed=1)
    reqs = _reqs(cfg, 6, 6, 4, seed=5)
    # bypass the policy: pile everything onto array 0 so its queue backs
    # up behind 2 rows while array 1 sits idle
    for r in reqs:
        fleet.engines[0].add_request(r)
    for _ in range(200):
        if not fleet.has_work:
            break
        fleet.step_all()
    assert not fleet.has_work
    st_ = fleet.stats()["fleet"]
    assert st_["migrations"] > 0
    assert fleet.outputs.keys() == {r.id for r in reqs}
    assert all(len(v) == 4 for v in fleet.outputs.values())
    assert not fleet.failed


# ---------------------------------------------------------------------------
# array loss: drain onto survivors, retry budgets never charged
# ---------------------------------------------------------------------------

def test_array_loss_drains_onto_survivors_without_charging_retries():
    """Satellite guarantee: losing an array is not the request's fault.
    With max_retries=0 ANY charge against the retry budget fails the
    request instantly — so every request completing proves the drain
    path leaves `fault_retries` untouched across arrays."""
    cfg = get_arch("qwen1.5-0.5b").reduced()
    fleet = ArrayFleet(cfg, num_arrays=2, max_batch=2, max_seq=32, seed=1,
                       max_retries=0)
    reqs = _reqs(cfg, 6, 8, 8, seed=2)
    for r in reqs:
        fleet.add_request(r)
    for _ in range(3):
        fleet.step_all()
    lost = fleet.inject_array_loss()       # busiest array
    for _ in range(400):
        if not fleet.has_work:
            break
        fleet.step_all()
    assert not fleet.has_work
    st_ = fleet.stats()["fleet"]
    assert st_["array_losses"] == 1 and st_["dead"] == [lost]
    assert st_["drain_requeues"] > 0
    # zero-retry budget intact -> nothing failed, everything finished
    assert not fleet.failed
    assert fleet.outputs.keys() == {r.id for r in reqs}
    assert all(len(v) == 8 for v in fleet.outputs.values())
    # survivors carried every later placement
    survivor = ({0, 1} - {lost}).pop()
    assert fleet.engines[lost].store.live_bytes == 0
    assert not fleet.engines[lost].active.any()
    assert fleet.engines[survivor].step_idx > 0


def test_losing_every_array_raises():
    cfg = get_arch("qwen1.5-0.5b").reduced()
    fleet = ArrayFleet(cfg, num_arrays=2, max_batch=2, max_seq=32, seed=1)
    for r in _reqs(cfg, 2, 4, 4, seed=9):
        fleet.add_request(r)
    fleet.inject_array_loss(0)
    fleet.step_all()                       # drained onto array 1
    fleet.inject_array_loss(1)
    with pytest.raises(RuntimeError, match="no\\s+survivors"):
        fleet.step_all()                   # nothing left to drain onto


# ---------------------------------------------------------------------------
# engine hand-off primitives
# ---------------------------------------------------------------------------

def test_drain_requests_rebuilds_prompts_and_keeps_retry_budget():
    cfg = get_arch("qwen1.5-0.5b").reduced()
    eng = ServeEngine(cfg, make_local_mesh(), max_batch=2, max_seq=32,
                      seed=1)
    reqs = _reqs(cfg, 3, 5, 6, seed=4)
    for r in reqs:
        eng.add_request(r)
    for _ in range(2):
        eng.step_all()
    drained = eng.drain_requests()
    assert len(drained) == 3
    assert not eng.active.any() and not eng.scheduler.queue
    assert eng.store.live_bytes == 0
    # 2 rows were running (resumed on drain); the 3rd never left the queue
    assert sum(e.resumed for e, _ in drained) == 2
    by_id = {e.req.id: (e, gen) for e, gen in drained}
    for r in reqs:
        entry, gen = by_id[r.id]
        assert entry.fault_retries == 0          # budget never charged
        np.testing.assert_array_equal(entry.base_prompt, r.prompt)
        np.testing.assert_array_equal(
            entry.prompt, np.concatenate([r.prompt,
                                          np.asarray(gen, np.int32)]))
        assert entry.remaining == r.max_new_tokens - len(gen)


def test_adopt_request_rejects_duplicate_ids():
    cfg = get_arch("qwen1.5-0.5b").reduced()
    eng = ServeEngine(cfg, make_local_mesh(), max_batch=2, max_seq=32,
                      seed=1)
    eng.add_request(_reqs(cfg, 1, 5, 4, seed=6)[0])
    drained = eng.drain_requests()
    entry, gen = drained[0]
    eng.adopt_request(entry, gen)
    with pytest.raises(ValueError, match="already lives on this array"):
        eng.adopt_request(entry, gen)


# ---------------------------------------------------------------------------
# placement invariants: budgets never exceeded, requests never lost
# ---------------------------------------------------------------------------

_IDS = itertools.count(10_000)


def _pressured_fleet():
    cfg = get_arch("qwen1.5-0.5b").reduced()
    cfg = _amc(cfg, pool_mode="augment-on-pressure", retention_steps=4)
    # two Normal pages per array: tight enough that admissions contend
    probe = make_store(cfg, max_batch=2, max_seq=32)
    budget = 2 * probe.geom.page_bytes_normal
    del probe
    return ArrayFleet(cfg, num_arrays=2, max_batch=2, max_seq=32, seed=1,
                      pool_budget_bytes=budget)


@pytest.fixture(scope="module")
def pressured_fleet():
    return _pressured_fleet()


def _check_invariants(fleet):
    for i, eng in enumerate(fleet.engines):
        assert eng.store.live_bytes <= eng.store.budget_bytes, \
            f"array {i} over budget: {eng.store.live_bytes} > " \
            f"{eng.store.budget_bytes}"


def _drive_ops(fleet, ops):
    """Random admit/step/migrate schedule against a LIVE fleet (reused
    across examples — ids from a global counter). After the tail drain
    every admitted request must exist with its exact token count."""
    cfg = fleet.cfg
    added = {}
    rng = np.random.default_rng(ops[0][1] if ops else 0)
    for kind, a, b in ops:
        if kind == "add":
            rid = next(_IDS)
            req = Request(prompt=_prompt(rng, a, cfg.vocab),
                          max_new_tokens=b, id=rid)
            fleet.add_request(req)
            added[rid] = b
        else:
            fleet.step_all()               # steps, then rebalances
        _check_invariants(fleet)
    for _ in range(500):
        if not fleet.has_work:
            break
        fleet.step_all()
        _check_invariants(fleet)
    assert not fleet.has_work
    outs = fleet.outputs
    for rid, want in added.items():        # no request ever lost
        assert rid in outs and len(outs[rid]) == want, \
            f"request {rid} lost or truncated: {outs.get(rid)}"
    assert not fleet.failed


_OP = st.one_of(
    st.tuples(st.just("add"), st.integers(1, 10), st.integers(1, 5)),
    st.tuples(st.just("step"), st.just(0), st.just(0)),
) if HAVE_HYPOTHESIS else None

if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(ops=st.lists(_OP, min_size=1, max_size=12))
    def test_placement_invariants_random_schedules(pressured_fleet, ops):
        _drive_ops(pressured_fleet, ops)
else:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_placement_invariants_random_schedules(pressured_fleet, seed):
        rng = np.random.default_rng(seed)
        ops = []
        for _ in range(12):
            if rng.random() < 0.6:
                ops.append(("add", int(rng.integers(1, 11)),
                            int(rng.integers(1, 6))))
            else:
                ops.append(("step", 0, 0))
        _drive_ops(pressured_fleet, ops)


# ---------------------------------------------------------------------------
# observability: per-array lanes merge into one schema-valid trace
# ---------------------------------------------------------------------------

def test_fleet_trace_has_per_array_lanes_and_validates(tmp_path):
    cfg = get_arch("qwen1.5-0.5b").reduced()
    fleet = ArrayFleet(cfg, num_arrays=2, max_batch=2, max_seq=32, seed=1,
                       trace=True, metrics=True)
    outs = fleet.generate(_reqs(cfg, 4, 6, 4, seed=8))
    assert len(outs) == 4
    obj = fleet.export_trace(str(tmp_path / "fleet_trace.json"))
    assert validate_chrome_trace(obj) == []
    pids = {e["pid"] for e in obj["traceEvents"]}
    assert pids == {0, 1}                  # one lane per array
    names = {e["args"]["name"] for e in obj["traceEvents"]
             if e.get("name") == "process_name"}
    assert names == {"array 0", "array 1"}
    placements = [e for e in obj["traceEvents"]
                  if e.get("name") == "placement"]
    assert len(placements) == 4
    assert all(p["args"]["kind"] == "admit" for p in placements)
    # fleet-wide metrics: one shared registry counted every admission
    text = fleet.export_metrics(str(tmp_path / "fleet.prom"))
    assert "amc_placement_admit 4" in text


def test_fleet_stats_report_per_array_state():
    cfg = get_arch("qwen1.5-0.5b").reduced()
    fleet = ArrayFleet(cfg, num_arrays=2, max_batch=2, max_seq=32, seed=1)
    fleet.generate(_reqs(cfg, 4, 6, 3, seed=11))
    st_ = fleet.stats()
    fl = st_["fleet"]
    assert fl["num_arrays"] == 2 and fl["alive"] == [0, 1]
    assert len(fl["per_array"]) == 2 and len(st_["arrays"]) == 2
    for a in fl["per_array"]:
        assert {"occupancy", "mode_normal", "mode_augmented",
                "refresh_debt", "energy_fj", "heads_axes",
                "tensor_parallel"} <= a.keys()
        # 1 CPU device -> model axis 1 -> no TP claimed
        assert a["model_axis"] == 1 and a["tensor_parallel"] is False
    assert sum(fl["placements_per_array"]) == 4
