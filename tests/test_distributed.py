"""Distributed substrate: sharding rules, gradient compression, pipeline
parallelism, fault/straggler handling. Runs on a 4-device CPU sub-mesh via
XLA host-device override in a subprocess-free way (this file re-execs jax
with 4 devices only if the flag isn't already set — so it composes with
the 1-device default used elsewhere: tests here use mesh shapes of 1)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, get_shape
from repro.launch.mesh import mesh_context
from repro.distributed.fault import (SimulatedFailure, StragglerMonitor,
                                     Supervisor)
from repro.distributed.sharding import Rules


# ---------------------------------------------------------------------------
# sharding rules (pure logic — use fake meshes via jax.make_mesh on 1 dev)
# ---------------------------------------------------------------------------

class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def _rules(cfg, shape_name, mesh_shape=None):
    mesh = FakeMesh(mesh_shape or {"data": 16, "model": 16})
    return Rules.make(mesh, cfg, get_shape(shape_name))


def test_rules_head_tp_disabled_for_indivisible_heads():
    r = _rules(get_arch("minicpm-2b"), "train_4k")       # 36 heads
    assert r.resolve("heads") is None
    assert r.resolve("mlp") == ("model",)                 # 5760 % 16 == 0
    r2 = _rules(get_arch("whisper-tiny"), "train_4k")     # 6 heads
    assert r2.resolve("heads") is None
    r3 = _rules(get_arch("granite-3-2b"), "train_4k")     # 32 heads
    assert r3.resolve("heads") == ("model",)


def test_rules_heads_degrade_to_replication_on_fleet_submeshes():
    """Per-array fleet meshes put the array's devices on "model"; heads
    shard TP only where the count divides that axis and replicate
    otherwise — never a crash, never a silent mis-shard."""
    cfg = get_arch("granite-3-2b")                        # 32 heads
    for model_axis, want in [(1, ("model",)), (2, ("model",)),
                             (4, ("model",)), (3, None), (5, None),
                             (7, None)]:
        r = _rules(cfg, "train_4k", {"data": 1, "model": model_axis})
        assert r.resolve("heads") == want, \
            f"32 heads over model={model_axis}: got {r.resolve('heads')}"
    # indivisible head count degrades even on a power-of-two axis
    r = _rules(get_arch("minicpm-2b"), "train_4k",        # 36 heads
               {"data": 1, "model": 8})
    assert r.resolve("heads") is None
    assert r.resolve("mlp") == ("model",)                  # 5760 % 8 == 0


def test_rules_single_device_array_replicates_trivially():
    """The over-host fleet case: every logical array shares one CPU
    device, model axis 1 — everything "shards" onto the single device
    (resolve returns the axis; the mesh makes it a no-op)."""
    r = _rules(get_arch("granite-3-2b"), "train_4k",
               {"data": 1, "model": 1})
    assert r.resolve("heads") == ("model",)
    assert r.resolve("mlp") == ("model",)


def test_rules_kv_vs_cache_seq_exclusive():
    # kv=16 divides 16 -> kv TP, no cache seq sharding
    r = _rules(get_arch("qwen1.5-0.5b"), "decode_32k")
    assert r.resolve("kv_heads") == ("model",)
    assert r.resolve("cache_seq") is None
    # kv=8 doesn't divide 16 -> SP on the cache
    r2 = _rules(get_arch("granite-3-2b"), "decode_32k")
    assert r2.resolve("kv_heads") is None
    assert r2.resolve("cache_seq") == ("model",)


def test_rules_batch_not_sharded_when_too_small():
    r = _rules(get_arch("mamba2-130m"), "long_500k")      # batch 1
    assert r.resolve("batch") is None


def test_rules_moe_modes():
    r = _rules(get_arch("qwen3-moe-30b-a3b"), "train_4k")
    assert r.resolve("experts") == ("model",)              # EP: 128/16
    r2 = _rules(get_arch("grok-1-314b"), "train_4k")
    assert r2.resolve("experts") is None                   # TP mode: 8 experts
    assert r2.resolve("mlp") == ("model",)


def test_param_and_opt_spec_trees_align():
    from repro.models import model as M
    from repro.train import step as step_lib
    cfg = get_arch("qwen3-moe-30b-a3b")
    ap = M.abstract_params(cfg)
    oa = step_lib.opt_abstract(ap, "amc_adamw")
    # same tree structure for m_q as params
    assert (jax.tree.structure(oa.m_q, is_leaf=lambda x: hasattr(x, "axes"))
            == jax.tree.structure(ap, is_leaf=lambda x: hasattr(x, "axes")))


# ---------------------------------------------------------------------------
# gradient compression (single-device axis: semantics = identity + residual)
# ---------------------------------------------------------------------------

def test_compressed_allreduce_error_feedback():
    from repro.distributed.collectives import make_compressed_grad_allreduce
    mesh = jax.make_mesh((1, 1), ("pod", "data"))
    # pod axis size 1 -> compression disabled (returns None)
    assert make_compressed_grad_allreduce(mesh, "pod") is None


def test_compressed_quantization_bounded_and_unbiased():
    from repro.distributed.collectives import _q8
    g = jax.random.normal(jax.random.PRNGKey(0), (64, 256)) * 1e-3
    q, scale = _q8(g)
    deq = np.asarray(q, np.float32) * np.asarray(scale)
    err = np.abs(deq - np.asarray(g))
    assert (err <= np.asarray(scale) * 0.5 + 1e-9).all()
    # residual carries exactly the lost mass (error feedback invariant)
    res = np.asarray(g) - deq
    assert np.allclose(res + deq, np.asarray(g), atol=1e-7)


# ---------------------------------------------------------------------------
# pipeline parallelism (1-stage degenerate case on CPU = identity schedule)
# ---------------------------------------------------------------------------

def test_pipeline_single_stage_equals_direct():
    from repro.distributed.pipeline import pipeline_forward
    mesh = jax.make_mesh((1,), ("pod",))
    w = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 8))

    def stage(p, x):
        return jnp.tanh(x @ p)

    fn = pipeline_forward(mesh, stage, n_micro=3)
    xs = jax.random.normal(jax.random.PRNGKey(1), (3, 4, 8))
    with mesh_context(mesh):
        out = fn(w, xs)
    expect = jnp.tanh(xs @ w[0])
    assert np.allclose(np.asarray(out), np.asarray(expect), atol=1e-5)


# ---------------------------------------------------------------------------
# fault handling
# ---------------------------------------------------------------------------

def test_supervisor_restores_and_resumes():
    calls = {"restores": 0, "runs": 0}

    def restore():
        calls["restores"] += 1
        return 0

    sup = Supervisor(restore, max_restarts=2)
    state = {"fail": True}

    def step():
        calls["runs"] += 1
        if state["fail"]:
            state["fail"] = False
            raise SimulatedFailure("node died")

    assert not sup.run_step(step)     # failed + recovered
    assert sup.run_step(step)         # clean
    assert calls["restores"] == 1 and calls["runs"] == 2


def test_supervisor_gives_up_after_max_restarts():
    sup = Supervisor(lambda: 0, max_restarts=1)
    with pytest.raises(SimulatedFailure):
        for _ in range(3):
            sup.run_step(lambda: (_ for _ in ()).throw(SimulatedFailure())
                         .__next__())


def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(threshold=2.0, patience=2)
    for i in range(5):
        mon.record(i, 1.0)
    assert not mon.events
    flagged = [mon.record(10 + i, 5.0) for i in range(3)]
    assert any(flagged) and mon.events


def test_supervisor_exhaustion_reraises_past_budget():
    """The restart budget is spent silently; the failure PAST it re-raises
    to the caller (the serving engine surfaces it instead of looping)."""
    restores = {"n": 0}

    def restore():
        restores["n"] += 1

    sup = Supervisor(restore, max_restarts=3)

    def boom():
        raise SimulatedFailure("array lost")

    for _ in range(3):
        assert not sup.run_step(boom)      # recovered, budget spent
    with pytest.raises(SimulatedFailure):
        sup.run_step(boom)                 # budget exhausted: re-raise
    assert restores["n"] == 3 and sup.restarts == 4


def test_straggler_ewma_resists_poisoning():
    """Pathologically slow steps barely move the EWMA baseline (weight
    0.98), so a burst can't drag the threshold up and hide itself."""
    mon = StragglerMonitor(threshold=2.0, patience=3)
    for i in range(10):
        mon.record(i, 1.0)
    assert mon._ewma == pytest.approx(1.0)
    for i in range(10, 13):
        mon.record(i, 100.0)               # 100x burst, patience-long
    assert mon.events, "burst should have requested mitigation"
    # plain decay=0.9 weighting would leave the baseline near 28; the
    # poisoning-resistant weight keeps it single-digit...
    assert mon._ewma < 10.0
    # ...so the very next 100x step is still detected as slow
    assert 100.0 > mon.threshold * mon._ewma
