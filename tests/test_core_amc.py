"""Core AMC library: packing roundtrips, FILO discipline, retention model.
Includes hypothesis property tests on the storage invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    # degrade gracefully (requirements-dev.txt not installed): run the
    # property tests over a small deterministic sample grid instead of
    # skipping the whole module

    class _Strat:
        def __init__(self, sample):
            self.sample = sample

    class _St:
        @staticmethod
        def integers(lo, hi):          # hypothesis bounds are inclusive
            return _Strat(lambda rng: int(rng.integers(lo, hi + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strat(lambda rng: float(rng.uniform(min_value,
                                                        max_value)))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strat(lambda rng: seq[int(rng.integers(len(seq)))])

    st = _St()

    def settings(**_kw):
        return lambda fn: fn

    def given(*strats):
        def deco(fn):
            def run():
                rng = np.random.default_rng(0)
                for _ in range(5):
                    fn(*(s.sample(rng) for s in strats))
            run.__name__ = fn.__name__        # keep pytest's test id;
            run.__doc__ = fn.__doc__          # no __wrapped__, or pytest
            return run                        # treats params as fixtures
        return deco

from repro.core import amc
from repro.core import dual_plane as dp
from repro.core import quant, ternary
from repro.core.amc import AugmentedStore, FILOViolation, Mode, RetentionExpired
from repro.core.retention import LeakageModel, RefreshPolicy


# ---------------------------------------------------------------------------
# int4 nibble packing
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_int4_pack_roundtrip_property(seed):
    k = jax.random.PRNGKey(seed)
    hi = jax.random.randint(k, (5, 7), -8, 8).astype(jnp.int8)
    lo = jax.random.randint(jax.random.fold_in(k, 1), (5, 7), -8, 8).astype(jnp.int8)
    p = quant.pack_int4_pair(hi, lo)
    uh, ul = quant.unpack_int4_pair(p)
    assert (np.asarray(uh) == np.asarray(hi)).all()
    assert (np.asarray(ul) == np.asarray(lo)).all()
    assert p.dtype == jnp.uint8 and p.shape == hi.shape


@given(st.integers(0, 2**31 - 1), st.sampled_from(["base3", "2bit"]))
@settings(max_examples=25, deadline=None)
def test_ternary_pack_roundtrip_property(seed, fmt):
    k = jax.random.PRNGKey(seed)
    t = jax.random.randint(k, (20, 6), -1, 2).astype(jnp.int8)
    if fmt == "base3":
        r = ternary.unpack_ternary_base3(ternary.pack_ternary_base3(t), 20)
    else:
        r = ternary.unpack_ternary_2bit(ternary.pack_ternary_2bit(t), 20)
    assert (np.asarray(r) == np.asarray(t)).all()


def test_ternary_capacity_factors():
    assert ternary.bits_per_value("base3") == 1.6   # 10x vs bf16
    assert ternary.bits_per_value("2bit") == 2.0    # 8x vs bf16


def test_ternarize_values_and_scale():
    w = jax.random.normal(jax.random.PRNGKey(0), (256, 64))
    t, scale = ternary.ternarize(w)
    assert set(np.unique(np.asarray(t))) <= {-1, 0, 1}
    assert (np.asarray(scale) > 0).all()
    # dequantized ternary correlates with the original weights
    wq = np.asarray(ternary.ternary_dequant(t, scale), np.float32)
    corr = np.corrcoef(wq.ravel(), np.asarray(w).ravel())[0, 1]
    assert corr > 0.7, corr


def test_ste_gradient_is_identity():
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    g = jax.grad(lambda w: (ternarize_out := ternary.ternarize_ste(w)).sum())(w)
    assert np.allclose(np.asarray(g), 1.0)


# ---------------------------------------------------------------------------
# dual plane (8T cell semantics)
# ---------------------------------------------------------------------------

def test_dual_plane_planes_independent():
    k = jax.random.PRNGKey(0)
    d = dp.alloc((32, 32))
    w = jax.random.normal(k, (32, 32))
    d = dp.write_static(d, w)
    static0 = np.asarray(dp.read_static(d), np.float32)
    d = dp.write_dynamic(d, jax.random.normal(jax.random.fold_in(k, 1), (32, 32)))
    # dynamic write must NOT disturb the static plane
    assert np.allclose(np.asarray(dp.read_static(d), np.float32), static0)


def test_dual_plane_static_write_destroys_dynamic():
    k = jax.random.PRNGKey(0)
    d = dp.alloc((16, 16))
    d = dp.write_static(d, jax.random.normal(k, (16, 16)))
    d = dp.write_dynamic(d, jax.random.normal(jax.random.fold_in(k, 1), (16, 16)))
    d = dp.write_static(d, jax.random.normal(jax.random.fold_in(k, 2), (16, 16)))
    # the paper's hazard: dynamic plane zeroed by the static write
    assert (np.asarray(dp.read_dynamic_q(d)) == 0).all()


def test_dual_plane_quantization_error_bounded():
    w = jax.random.normal(jax.random.PRNGKey(2), (64, 64))
    d = dp.write_static(dp.alloc((64, 64)), w, axis=0)
    err = np.abs(np.asarray(dp.read_static(d), np.float32) - np.asarray(w))
    lsb = np.asarray(d.static_scale)
    assert (err <= lsb * 0.75 + 1e-3).all()


# ---------------------------------------------------------------------------
# AugmentedStore: FILO ledger + retention
# ---------------------------------------------------------------------------

def test_store_filo_violation_raises_and_force_destroys():
    st_ = AugmentedStore((16, 16))
    st_.write_static(jax.random.normal(jax.random.PRNGKey(0), (16, 16)))
    st_.set_mode(Mode.AUGMENTED_DUAL)
    st_.push_dynamic(jax.random.normal(jax.random.PRNGKey(1), (16, 16)))
    with pytest.raises(FILOViolation):
        st_.read_static()
    # force=True mirrors the physics: the access destroys the dynamic bit
    _ = st_.read_static(force=True)
    assert not st_.dynamic_live
    assert st_.stats["filo_faults"] == 1


def test_store_filo_drain_then_static_ok():
    st_ = AugmentedStore((8, 8))
    st_.write_static(jnp.ones((8, 8)))
    st_.set_mode(Mode.AUGMENTED_DUAL)
    st_.push_dynamic(jnp.ones((8, 8)) * 0.5)
    _ = st_.pop_dynamic()
    _ = st_.read_static()  # no violation after drain


def test_store_retention_expiry_and_refresh():
    st_ = AugmentedStore((8, 8), retention_steps=2)
    st_.write_static(jnp.ones((8, 8)))
    st_.set_mode(Mode.AUGMENTED_DUAL)
    st_.push_dynamic(jnp.ones((8, 8)) * 0.25)
    st_.tick(3)  # past retention
    with pytest.raises(RetentionExpired):
        st_.pop_dynamic()
    st_.refresh(jnp.ones((8, 8)) * 0.25)  # DRAM-style refresh
    out = st_.pop_dynamic()
    assert np.allclose(np.asarray(out, np.float32), 0.25, atol=0.05)
    assert st_.stats["refreshes"] == 1


def test_store_capacity_factors():
    st_ = AugmentedStore((10, 16))
    assert st_.capacity_factor() == 1.0
    st_.set_mode(Mode.AUGMENTED_DUAL)
    assert st_.capacity_factor() == 4.0
    assert st_.physical_bytes() == 160      # 1 byte per logical index
    st_.set_mode(Mode.AUGMENTED_TERNARY)
    assert st_.capacity_factor() == 10.0    # base3: 1.6 bits/value


# ---------------------------------------------------------------------------
# capacity math: mode_physical_bytes and capacity_factor must agree for
# every mode x ternary format (property-based)
# ---------------------------------------------------------------------------

def _pack_granule(mode: Mode, fmt: str) -> int:
    if mode is Mode.AUGMENTED_TERNARY:
        return 5 if fmt == "base3" else 4
    return 1


@given(st.integers(1, 1 << 20), st.sampled_from(["base3", "2bit"]))
@settings(max_examples=50, deadline=None)
def test_capacity_factor_and_physical_bytes_agree(n, fmt):
    """For every mode: capacity_factor * bits_per_value == 16 (the bf16
    Normal word), and at packing-granule multiples the byte count equals
    logical_values * bits_per_value / 8 exactly. One AUGMENTED_DUAL byte
    holds TWO logical int4 values (static + dynamic plane)."""
    for mode in Mode:
        bpv = amc.mode_bits_per_value(mode, fmt)
        assert amc.capacity_factor(mode, fmt) * bpv == pytest.approx(16.0)
        g = _pack_granule(mode, fmt)
        nn = -(-n // g) * g
        phys = amc.mode_physical_bytes(nn, mode, fmt)
        values = 2 * nn if mode is Mode.AUGMENTED_DUAL else nn
        assert phys * 8 == pytest.approx(values * bpv), (mode, fmt, nn)
        # non-multiples may pay at most one extra packed byte (ceil)
        exact = amc.mode_physical_bytes(n, mode, fmt)
        lower = (2 * n if mode is Mode.AUGMENTED_DUAL else n) * bpv / 8
        assert lower <= exact < lower + 1 + 1e-9, (mode, fmt, n)


@given(st.integers(0, 2**31 - 1), st.integers(1, 12), st.integers(1, 8))
@settings(max_examples=25, deadline=None)
def test_base3_pack_roundtrip_shapes_property(seed, kmul, cols):
    """base-3 trit packing round-trips over arbitrary (5k, cols) shapes."""
    K = 5 * kmul
    k = jax.random.PRNGKey(seed)
    t = jax.random.randint(k, (K, cols), -1, 2).astype(jnp.int8)
    r = ternary.unpack_ternary_base3(ternary.pack_ternary_base3(t), K)
    assert (np.asarray(r) == np.asarray(t)).all()
    # the packed byte really holds 5 trits: physical bytes match the
    # capacity ledger
    packed = ternary.pack_ternary_base3(t)
    assert packed.size == amc.mode_physical_bytes(
        t.size, Mode.AUGMENTED_TERNARY, "base3")


@given(st.integers(0, 2**31 - 1), st.integers(1, 12), st.integers(1, 8))
@settings(max_examples=25, deadline=None)
def test_2bit_pack_roundtrip_shapes_property(seed, kmul, cols):
    K = 4 * kmul
    k = jax.random.PRNGKey(seed)
    t = jax.random.randint(k, (K, cols), -1, 2).astype(jnp.int8)
    r = ternary.unpack_ternary_2bit(ternary.pack_ternary_2bit(t), K)
    assert (np.asarray(r) == np.asarray(t)).all()
    assert ternary.pack_ternary_2bit(t).size == amc.mode_physical_bytes(
        t.size, Mode.AUGMENTED_TERNARY, "2bit")


# ---------------------------------------------------------------------------
# retention model reproduces the paper's tables
# ---------------------------------------------------------------------------

def test_leakage_model_matches_paper_tables():
    m8 = LeakageModel("8T")
    assert m8.retention_us(85) == pytest.approx(25.0)
    assert m8.retention_us(25) == pytest.approx(250.0)
    m7 = LeakageModel("7T")
    assert m7.retention_us(85) == pytest.approx(4.0)
    assert m7.retention_us(25) == pytest.approx(50.0)


@given(st.floats(min_value=0.0, max_value=85.0),
       st.floats(min_value=0.1, max_value=60.0))
@settings(max_examples=50, deadline=None)
def test_retention_monotone_in_temperature(temp, colder_by):
    """Paper: retention improves as temperature drops (cryo-friendly)."""
    m = LeakageModel("8T")
    assert m.retention_us(temp - colder_by) > m.retention_us(temp)


def test_sense_readable_until_retention():
    m = LeakageModel("7T")
    r85 = m.retention_us(85)
    assert bool(m.readable(jnp.float32(1.0), r85 * 0.99, 85))
    assert not bool(m.readable(jnp.float32(1.0), r85 * 1.01, 85))


def test_refresh_policy_window():
    p = RefreshPolicy(retention_steps=3)
    p.stamp(10)
    assert p.valid(12) and not p.valid(13)
    assert p.needs_refresh(13)
