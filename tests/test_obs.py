"""Observability plane: tracing, metrics, exporters and engine wiring.

Contracts under test:

  * well-formed traces — every span closed at end of run, globally
    monotonic timestamps, schema-valid Chrome trace JSON, and request-id
    continuity: a preempted request's whole life stays on ONE track
  * trace/metrics agreement — TTFT percentiles recomputed from the trace
    instants land within one log-bucket of the histogram estimates
  * stats() idempotence — repeated calls return deep-equal payloads
  * zero-overhead disabled mode — the Null facade leaves no state behind
  * per-step wall times surfaced for every run (straggler monitor feed)
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import AMCConfig
from repro.launch.mesh import make_local_mesh
from repro.obs import (ENGINE_TRACK, REQ_TRACK_BASE, LogHistogram,
                       MetricsRegistry, NullEngineObs, TimeSeries, Tracer,
                       make_engine_obs, validate_chrome_trace,
                       validate_chrome_trace_file)
from repro.serve import Request, ServeEngine

MESH = make_local_mesh()


def _cfg(arch="qwen1.5-0.5b", **amc):
    return dataclasses.replace(get_arch(arch).reduced(),
                               amc=AMCConfig(**amc))


def _reqs(cfg, n, plen, max_new, seed=0, id0=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab, size=(plen,))
                    .astype(np.int32), max_new_tokens=max_new, id=id0 + i)
            for i in range(n)]


# ---------------------------------------------------------------------------
# metrics primitives
# ---------------------------------------------------------------------------

def test_log_histogram_buckets_and_percentiles():
    h = LogHistogram()
    for v in (1e-5, 1e-4, 1e-3, 1e-3, 1e-3, 1e-2):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 6
    assert s["min"] == 1e-5 and s["max"] == 1e-2
    # p50 of six values is the 3rd: a 1e-3 — reported as its bucket edge
    assert h.bucket_index(s["p50"]) - h.bucket_index(1e-3) <= 1
    assert h.within_one_bucket(s["p50"], 1e-3)
    # a value and its own report always agree within one bucket
    assert h.within_one_bucket(s["p99"], 1e-2)
    assert not h.within_one_bucket(1e-5, 1e-2)


def test_log_histogram_overflow_and_observe_n():
    h = LogHistogram(lo=1e-6, n_buckets=4)
    h.observe(1e9)                               # overflow bucket
    assert h.percentile(99) == 1e9               # reports max, not inf
    h.observe_n(2e-6, 3)
    assert h.count == 4 and h.counts[h.bucket_index(2e-6)] == 3


def test_timeseries_bounded_with_uniform_coverage():
    ts = TimeSeries(max_samples=8)
    for t in range(1000):
        ts.sample(t, t * 10)
    assert len(ts.samples) <= 8
    steps = [t for t, _ in ts.samples]
    assert steps == sorted(steps)
    assert steps[0] < 300 and steps[-1] > 700    # covers the whole run
    assert ts.last() == ts.samples[-1][1]


def test_prometheus_text_exposition():
    m = MetricsRegistry()
    m.inc("requests", 3)
    m.gauge("depth", 2)
    m.observe("lat_s", 0.01)
    m.observe("lat_s", 0.5)
    text = m.prometheus_text()
    assert "# TYPE amc_requests counter" in text
    assert "amc_requests 3" in text
    assert "amc_depth 2" in text
    assert "# TYPE amc_lat_s histogram" in text
    assert 'amc_lat_s_bucket{le="+Inf"} 2' in text
    assert "amc_lat_s_count 2" in text
    # cumulative bucket counts: every le value's count <= total
    lines = [ln for ln in text.splitlines() if ln.startswith("amc_lat_s_bucket")]
    counts = [int(ln.rsplit(" ", 1)[1]) for ln in lines]
    assert counts == sorted(counts)


# ---------------------------------------------------------------------------
# tracer well-formedness
# ---------------------------------------------------------------------------

def test_tracer_spans_instants_counters_schema():
    clk = iter(x * 1e-3 for x in range(100))
    tr = Tracer(clock=lambda: next(clk))
    sid = tr.begin(ENGINE_TRACK, "step", step=0)
    tr.instant(tr.request_track(5), "enqueue", step=0)
    tr.counter("mode_mix", normal=3, augmented=1)
    tr.end(sid, kind="decode")
    with tr.span(ENGINE_TRACK, "step", step=1):
        pass
    assert tr.open_spans() == 0
    obj = tr.chrome_trace()
    assert validate_chrome_trace(obj) == []
    phases = {e["ph"] for e in obj["traceEvents"]}
    assert {"X", "i", "C", "M"} <= phases
    names = {e["args"]["name"] for e in obj["traceEvents"]
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert "req 5" in names and "engine/steps" in names


def test_tracer_open_span_flagged_at_export():
    tr = Tracer()
    tr.begin(ENGINE_TRACK, "step", step=0)
    obj = tr.chrome_trace()
    assert tr.open_spans() == 1                  # export does not close it
    bad = [p for p in validate_chrome_trace(obj) if "left open" in p]
    assert bad, "open span must be flagged by the validator"


def test_validator_catches_malformed_traces():
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({"traceEvents": [{"ph": "Z"}]}) != []
    # non-monotonic timestamps
    ev = [{"name": "a", "ph": "i", "s": "t", "ts": 5.0, "pid": 0, "tid": 0},
          {"name": "b", "ph": "i", "s": "t", "ts": 1.0, "pid": 0, "tid": 0}]
    probs = validate_chrome_trace({"traceEvents": ev})
    assert any("monotonic" in p for p in probs)


# ---------------------------------------------------------------------------
# engine wiring: full lifecycle trace
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def traced_run():
    """Overloaded engine (queueing + preemption pressure), obs fully on."""
    cfg = _cfg(kv_mode="int4", pool_mode="always-augmented",
               trace=True, metrics=True)
    eng = ServeEngine(cfg, MESH, max_batch=2, max_seq=48, prefill_chunk=8,
                      pool_budget_bytes=40_000)
    reqs = _reqs(cfg, 5, 6, 10)
    outs = eng.generate(reqs)
    return eng, reqs, outs


def test_trace_all_spans_closed_and_schema_valid(traced_run, tmp_path):
    eng, _, _ = traced_run
    assert eng.obs.tracer.open_spans() == 0
    path = str(tmp_path / "trace.json")
    eng.export_trace(path)
    assert validate_chrome_trace_file(path) == []
    obj = json.load(open(path))
    ts = [e["ts"] for e in obj["traceEvents"] if e["ph"] != "M"]
    assert ts == sorted(ts)


def test_trace_covers_request_lifecycle(traced_run):
    eng, reqs, outs = traced_run
    obj = eng.obs.tracer.chrome_trace()
    for r in reqs:
        tid = REQ_TRACK_BASE + r.id
        lane = [e for e in obj["traceEvents"]
                if e["tid"] == tid and e["ph"] != "M"]
        names = [e["name"] for e in lane]
        assert "enqueue" in names and "first_token" in names
        assert "queue" in names and "active" in names
        assert "completed" in names
        # prefill chunk spans ride on the request's own lane
        assert any(n == "prefill_chunk" for n in names)
        done = [e for e in lane if e["name"] == "completed"]
        assert done[0]["args"]["tokens"] == len(outs[r.id])


def test_trace_request_id_continuity_across_preemption():
    """A preempted+resumed request's whole life lives on ONE track:
    preempt instant, a SECOND queue span, a second active span — all on
    the same tid."""
    cfg = _cfg(kv_mode="int8", pool_mode="always-augmented",
               trace=True, metrics=True)
    # 2 growing rows, 3 pages of storage: growth outruns augmentation and
    # the youngest row is preempted (test_scheduler.py's known-tight cell)
    eng = ServeEngine(cfg, MESH, max_batch=2, max_seq=32, prefill_chunk=16,
                      seed=4, pool_budget_bytes=3 * 8704)
    outs = eng.generate(_reqs(cfg, 2, 14, 6, seed=3))
    assert all(len(v) == 6 for v in outs.values())
    st = eng.stats()
    assert st["preemptions"] >= 1, "config must force preemption"
    obj = eng.obs.tracer.chrome_trace()
    by_tid = {}
    for e in obj["traceEvents"]:
        if e["ph"] != "M":
            by_tid.setdefault(e["tid"], []).append(e["name"])
    preempted = [tid for tid, names in by_tid.items()
                 if tid >= REQ_TRACK_BASE and "preempt" in names]
    assert preempted
    for tid in preempted:
        names = by_tid[tid]
        assert names.count("queue") >= 2        # re-queued on the same lane
        assert names.count("active") >= 2       # re-admitted on the same lane
        assert "completed" in names
    assert eng.obs.tracer.open_spans() == 0
    counters = st["obs"]["counters"]
    assert counters["preempt_capacity"] == st["preemptions"]


def test_ttft_metrics_agree_with_trace_within_one_bucket(traced_run):
    eng, _, _ = traced_run
    obj = eng.obs.tracer.chrome_trace()
    enq, first = {}, {}
    for e in obj["traceEvents"]:
        if e["ph"] != "i":
            continue
        if e["name"] == "enqueue":
            enq[e["tid"]] = e["ts"]
        elif e["name"] == "first_token":
            first.setdefault(e["tid"], e["ts"])
    ttfts = [(first[t] - enq[t]) * 1e-6 for t in enq]
    ref = LogHistogram()
    for t in ttfts:
        ref.observe(t)
    h = eng.stats()["obs"]["histograms"]["ttft_s"]
    assert h["count"] == len(ttfts)
    for p in (50, 90, 99):
        assert ref.within_one_bucket(ref.percentile(p), h[f"p{p}"])


def test_mode_mix_and_occupancy_timelines(traced_run):
    eng, _, _ = traced_run
    st = eng.stats()
    # the O(1) incremental mode-mix counters agree with the reduction
    # describe() computes from the allocation tables
    assert eng.store.mode_mix() == (st["pool"]["pages_live_normal"],
                                    st["pool"]["pages_live_augmented"])
    ts = st["obs"]["timeseries"]
    for key in ("mode_normal", "mode_augmented", "pool_occupancy",
                "queue_depth", "refresh_debt"):
        assert key in ts and ts[key]["n_samples"] >= 1, key
    # always-augmented store: every live unit is in the dynamic plane
    full = eng.obs.metrics.dump_timeseries()
    assert all(v == 0 for _, v in full["mode_normal"])
    assert any(v > 0 for _, v in full["mode_augmented"])
    assert any(v > 0 for _, v in full["energy_kv_read_fj"])
    # perfetto counter events mirror the sampled series
    obj = eng.obs.tracer.chrome_trace()
    assert any(e["ph"] == "C" and e["name"] == "mode_mix"
               for e in obj["traceEvents"])


# ---------------------------------------------------------------------------
# stats() idempotence + step-time surfacing (satellites)
# ---------------------------------------------------------------------------

def test_mode_mix_counters_match_reduction_on_slab_store():
    cfg = _cfg("mamba2-130m", pool_mode="always-augmented",
               trace=True, metrics=True)
    eng = ServeEngine(cfg, MESH, max_batch=2, max_seq=32, prefill_chunk=8)
    eng.generate(_reqs(cfg, 3, 6, 6))
    pool = eng.stats()["pool"]
    assert eng.store.mode_mix() == (pool["slabs_live_normal"],
                                    pool["slabs_live_augmented"])
    ts = eng.stats()["obs"]["timeseries"]
    assert ts["mode_augmented"]["n_samples"] >= 1


def test_stats_idempotent_with_and_without_obs():
    for amc in (dict(kv_mode="int4", pool_mode="always-augmented"),
                dict(kv_mode="int4", pool_mode="always-augmented",
                     trace=True, metrics=True)):
        cfg = _cfg(**amc)
        eng = ServeEngine(cfg, MESH, max_batch=2, max_seq=32,
                          prefill_chunk=8)
        eng.generate(_reqs(cfg, 3, 4, 6))
        first = eng.stats()
        for _ in range(3):
            assert eng.stats() == first


def test_step_times_surfaced_for_every_run():
    cfg = _cfg(kv_mode="int4", pool_mode="always-augmented")  # no faults
    eng = ServeEngine(cfg, MESH, max_batch=2, max_seq=32, prefill_chunk=8)
    eng.generate(_reqs(cfg, 2, 4, 6))
    st = eng.stats()["step_times"]
    assert st["n_steps"] >= 6
    assert 0 < st["min_s"] <= st["mean_s"] <= st["max_s"]
    assert st["mitigations"] == 0


# ---------------------------------------------------------------------------
# fault + speculative lanes
# ---------------------------------------------------------------------------

def test_fault_injected_spec_run_traces_heal_events(tmp_path):
    """The acceptance scenario: speculative decoding under fault
    injection with tracing on — the exported trace is schema-valid and
    carries admit/prefill/decode/fault lanes; fault-lane instants agree
    with the engine's own fault counters."""
    cfg = _cfg(kv_mode="int4", pool_mode="always-augmented", spec_k=3,
               retention_steps=8, fault_rate=0.5, fault_seed=1,
               trace=True, metrics=True)
    eng = ServeEngine(cfg, MESH, max_batch=2, max_seq=64, prefill_chunk=16)
    outs = eng.generate(_reqs(cfg, 3, 20, 8))
    st = eng.stats()
    assert st["faults"]["faults_injected"] > 0
    assert st["faults"]["zero_silent_corruption"]
    path = str(tmp_path / "fault_trace.json")
    eng.export_trace(path)
    assert validate_chrome_trace_file(path) == []
    obj = json.load(open(path))
    names = [e["name"] for e in obj["traceEvents"] if e["ph"] != "M"]
    assert "fault_pass" in names                 # fault lane spans
    assert "spec_draft" in names and "spec_verify" in names
    assert names.count("inject") == st["faults"]["faults_injected"]
    assert names.count("detect") == st["faults"]["faults_detected"]
    heals = names.count("heal_scrub") + names.count("heal_recompute")
    assert heals == st["faults"]["recovered"]
    c = st["obs"]["counters"]
    assert c["fault_inject"] == st["faults"]["faults_injected"]
    assert c.get("store_augment", 0) == st["augment_events"]
    # spec metrics plane
    assert st["obs"]["histograms"]["accepted_per_round"]["count"] \
        == st["spec"]["spec_rounds"]
    assert c["tokens_emitted"] == sum(len(v) for v in outs.values())


def test_obs_off_by_default_and_null_exports_raise():
    cfg = _cfg(kv_mode="int4", pool_mode="always-augmented")
    eng = ServeEngine(cfg, MESH, max_batch=2, max_seq=32, prefill_chunk=8)
    assert isinstance(eng.obs, NullEngineObs)
    eng.generate(_reqs(cfg, 2, 4, 4))
    assert eng.stats()["obs"] == {"enabled": False, "trace": False,
                                  "metrics": False}
    with pytest.raises(ValueError, match="disabled"):
        eng.export_trace("/tmp/nope.json")
    with pytest.raises(ValueError, match="disabled"):
        eng.export_metrics("/tmp/nope.prom")
    assert make_engine_obs(cfg.amc) is eng.obs   # shared Null singleton


def test_single_plane_modes_serve_and_export(tmp_path):
    """metrics-only and trace-only engines run, stats() describes them,
    and only the enabled plane exports (regression: describe() used to
    assume a recording tracer and crash metrics-only serving)."""
    for trace, metrics in ((False, True), (True, False)):
        cfg = _cfg(kv_mode="int8", trace=trace, metrics=metrics)
        eng = ServeEngine(cfg, MESH, max_batch=2, max_seq=32,
                          prefill_chunk=8)
        eng.generate(_reqs(cfg, 2, 4, 4))
        obs = eng.stats()["obs"]
        assert obs["enabled"] and obs["trace"] == trace \
            and obs["metrics"] == metrics
        if trace:
            eng.export_trace(str(tmp_path / "t.json"))
        else:
            assert obs["trace_events"] == 0 and obs["open_spans"] == 0
        if metrics:
            eng.export_metrics(str(tmp_path / "m.prom"))


def test_engine_prometheus_export(traced_run, tmp_path):
    eng, reqs, outs = traced_run
    path = str(tmp_path / "metrics.prom")
    text = eng.export_metrics(path)
    assert open(path).read() == text
    assert f"amc_requests_completed {len(reqs)}" in text
    total = sum(len(v) for v in outs.values())
    assert f"amc_tokens_emitted {total}" in text
    assert 'amc_ttft_s_bucket{le="+Inf"}' in text
