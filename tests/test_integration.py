"""End-to-end behaviour: training converges, checkpoint/restart is
bit-exact, failure injection recovers, serving engine generates, AMC-Adam
tracks AdamW, data pipeline is deterministic + checkpointable."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt_lib
from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.data import PrefetchIterator, SyntheticLM
from repro.distributed.fault import SimulatedFailure
from repro.launch.mesh import make_local_mesh
from repro.serve import Request, ServeEngine
from repro.train import TrainSettings
from repro.train.trainer import Trainer, TrainerConfig


def _mk_trainer(tmp, arch="qwen1.5-0.5b", steps=12, injector=None, seed=0):
    cfg = get_arch(arch).reduced()
    shape = ShapeConfig("t", 64, 4, "train")
    settings = TrainSettings(lr=5e-3, q_chunk=16)
    tcfg = TrainerConfig(total_steps=steps, ckpt_every=4,
                         ckpt_dir=str(tmp), warmup=2, seed=seed)
    return Trainer(cfg, shape, make_local_mesh(), settings, tcfg,
                   failure_injector=injector)


def test_training_loss_decreases(tmp_path):
    tr = _mk_trainer(tmp_path / "a", steps=25)
    losses = tr.train()
    tr.close()
    assert len(losses) == 25
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05, losses


def test_checkpoint_restart_bit_exact(tmp_path):
    # run A: 12 steps straight through
    tr_a = _mk_trainer(tmp_path / "a", steps=12)
    losses_a = tr_a.train()
    tr_a.close()
    # run B: 8 steps (ckpt at 4, 8), new trainer resumes at 8 -> 12
    # (same total_steps so the LR schedule is identical)
    tr_b = _mk_trainer(tmp_path / "b", steps=12)
    tr_b.train(n_steps=8)
    tr_b.close()
    tr_b2 = _mk_trainer(tmp_path / "b", steps=12)
    assert tr_b2.current_step() == 8, "auto-resume from latest ckpt"
    losses_b = tr_b2.train()
    tr_b2.close()
    np.testing.assert_allclose(losses_a[8:], losses_b[8:], rtol=1e-5,
                               err_msg="restart must be bit-exact")


def test_failure_injection_recovers(tmp_path):
    fired = {"done": False}

    def injector(step):
        if step == 6 and not fired["done"]:
            fired["done"] = True
            raise SimulatedFailure("chip lost")

    tr = _mk_trainer(tmp_path / "f", steps=10, injector=injector)
    losses = tr.train()
    tr.close()
    assert fired["done"]
    assert tr.supervisor.restarts == 1
    assert len(losses) == 10          # no lost or repeated steps
    # compare against a clean run: identical stream
    tr_clean = _mk_trainer(tmp_path / "g", steps=10)
    losses_clean = tr_clean.train()
    tr_clean.close()
    np.testing.assert_allclose(losses, losses_clean, rtol=1e-5)


def test_async_checkpointer_atomicity(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"w": jnp.arange(10.0), "b": jnp.ones((3, 3))}
    ck = ckpt_lib.AsyncCheckpointer(d, keep=2)
    for s in (1, 2, 3):
        ck.save(s, jax.tree.map(lambda t: t * s, tree))
    ck.wait()
    assert ckpt_lib.all_steps(d) == [2, 3]     # GC keeps last 2
    restored, _ = ckpt_lib.restore(d, 3, tree)
    assert np.allclose(np.asarray(restored["w"]), np.arange(10.0) * 3)
    # partial checkpoint (no manifest) is invisible
    os.makedirs(os.path.join(d, "step_00000009.tmp"))
    assert ckpt_lib.latest_step(d) == 3


def test_data_pipeline_deterministic_and_checkpointable():
    src = SyntheticLM(vocab=97, seq_len=16, global_batch=4, seed=3)
    it = PrefetchIterator(src)
    a = [next(it) for _ in range(3)]
    state = it.state_dict()
    b = [next(it) for _ in range(2)]
    it.load_state_dict(state)                   # rewind
    c = [next(it) for _ in range(2)]
    it.close()
    for x, y in zip(b, c):
        assert (np.asarray(x["tokens"]) == np.asarray(y["tokens"])).all()
    # pure function of step
    assert (src.batch_at(5)["tokens"] == src.batch_at(5)["tokens"]).all()


def test_amc_adam_tracks_adamw():
    """Quantized-state Adam must follow fp32 Adam closely (error-feedback
    via every-step refresh keeps moments well-conditioned)."""
    from repro.optim import (adamw_init, adamw_update, amc_adamw_init,
                             amc_adamw_update)
    key = jax.random.PRNGKey(0)
    p = {"w": jax.random.normal(key, (64, 64))}
    s_a, s_b = adamw_init(p), amc_adamw_init(p)
    pa = pb = p
    for i in range(10):
        g = {"w": jax.random.normal(jax.random.fold_in(key, i), (64, 64))}
        pa, s_a = adamw_update(g, s_a, pa, lr=1e-2)
        pb, s_b = amc_adamw_update(g, s_b, pb, lr=1e-2)
    diff = np.abs(np.asarray(pa["w"]) - np.asarray(pb["w"])).max()
    scale = np.abs(np.asarray(pa["w"]) - np.asarray(p["w"])).max()
    assert diff < 0.2 * scale, (diff, scale)


def test_serve_engine_continuous_batching():
    cfg = get_arch("qwen1.5-0.5b").reduced()
    eng = ServeEngine(cfg, make_local_mesh(), max_batch=2, max_seq=32)
    rng = np.random.default_rng(1)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32),
                    max_new_tokens=5, id=i) for i in range(4)]
    outs = eng.generate(reqs)
    assert sorted(outs) == [0, 1, 2, 3]
    for rid, toks in outs.items():
        assert len(toks) == 5
        assert all(0 <= t < cfg.vocab_padded for t in toks)


def test_serve_packed_vs_normal_kv_agree():
    """int4 KV serving must produce (near-)identical greedy tokens."""
    from repro.configs.base import AMCConfig
    base = get_arch("qwen1.5-0.5b").reduced()
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, base.vocab, size=(4,)).astype(np.int32)
               for _ in range(2)]
    outs = {}
    for mode in ("normal", "int8"):
        cfg = dataclasses.replace(base, amc=AMCConfig(kv_mode=mode))
        eng = ServeEngine(cfg, make_local_mesh(), max_batch=2, max_seq=32,
                          seed=7)
        reqs = [Request(prompt=p, max_new_tokens=4, id=i)
                for i, p in enumerate(prompts)]
        outs[mode] = eng.generate(reqs)
    agree = sum(outs["normal"][i] == outs["int8"][i] for i in range(2))
    assert agree >= 1, outs
