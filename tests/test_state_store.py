"""Unified decode-state stores: slab quantization roundtrips, mode
switching, the shared byte-budget invariant (hypothesis), composite
admission atomicity, and the store registry."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.models import model as M
from repro.serve.cache_pool import PagedKVPool
from repro.serve.state_store import (AugmentedStatePool, CompositeStore,
                                     make_store, slab_reconstitute,
                                     slab_store_back)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _amc(cfg, **kw):
    return dataclasses.replace(cfg, amc=dataclasses.replace(cfg.amc, **kw))


def _slab_pool(pool_mode="augment-on-pressure", *, max_batch=4,
               budget_slabs=None, state_bits=8, arch="mamba2-130m",
               retention_steps=4):
    cfg = _amc(get_arch(arch).reduced(), pool_mode=pool_mode,
               state_bits=state_bits)
    shape = ShapeConfig("t", 32, max_batch, "decode")
    specs = M.abstract_cache(cfg, shape)
    pool = AugmentedStatePool(cfg, specs, max_batch=max_batch,
                              retention_steps=retention_steps)
    if budget_slabs is not None:
        pool.budget_bytes = budget_slabs * pool.slab_bytes_normal
    return pool


# ---------------------------------------------------------------------------
# slab plane roundtrips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [8, 4])
def test_slab_quant_roundtrip_accuracy(bits):
    """reconstitute(store_back(x)) on an augmented slot approximates x
    within the symmetric-quant error bound; a Normal slot is exact."""
    pool = _slab_pool(state_bits=bits, max_batch=2)
    key = jax.random.PRNGKey(0)
    cache = jax.tree.map(
        lambda l: (jax.random.normal(key, l.shape, jnp.float32)
                   .astype(l.dtype) if jnp.issubdtype(l.dtype, jnp.floating)
                   else l),
        pool.state["normal"])
    modes = jnp.array([0, 1], jnp.int32)
    state = slab_store_back(pool.state, cache, modes, bits)
    back = slab_reconstitute(state, modes, bits)
    qmax = 127 if bits == 8 else 7
    for path, (a, b) in zip(
            jax.tree_util.tree_flatten_with_path(cache)[0],
            zip(jax.tree.leaves(cache), jax.tree.leaves(back))):
        a32, b32 = a.astype(jnp.float32), b.astype(jnp.float32)
        # Normal slot: bit-identical
        np.testing.assert_array_equal(np.asarray(a32[:, 0]),
                                      np.asarray(b32[:, 0]))
        if not jnp.issubdtype(a.dtype, jnp.floating):
            continue
        # Augmented slot: within ~1 LSB of the per-vector scale (bf16
        # scale storage adds a relative half-percent on top)
        amax = jnp.max(jnp.abs(a32[:, 1]), axis=-1, keepdims=True)
        tol = np.asarray(amax / qmax + 0.01 * amax + 1e-6)
        err = np.asarray(jnp.abs(a32[:, 1] - b32[:, 1]))
        assert (err <= tol).all(), (path, float(err.max()))


def test_slab_scale_leaves_pass_through_state_bits4():
    """Regression: a packed ring-KV's companion scale tensors (trailing
    dim 1) must NOT be swept into the quantizable set — with
    state_bits=4 that used to crash at construction (odd trailing dim),
    and at int8 it silently re-quantized the scales."""
    cfg = _amc(get_arch("recurrentgemma-9b").reduced(), kv_mode="int4",
               pool_mode="always-augmented", state_bits=4)
    shape = ShapeConfig("t", 32, 2, "decode")
    pool = AugmentedStatePool(cfg, M.abstract_cache(cfg, shape),
                              max_batch=2)           # must not raise
    assert all(not k.endswith("_scale']") for k in pool.state["packed"])
    cache = jax.tree.map(
        lambda l: jnp.full_like(l, 2) if l.dtype == jnp.bfloat16
        and l.shape[-1] == 1 else l, pool.state["normal"])
    modes = jnp.array([1, 1], jnp.int32)
    back = slab_reconstitute(slab_store_back(pool.state, cache, modes, 4),
                             modes, 4)
    for (path, a), b in zip(jax.tree_util.tree_flatten_with_path(cache)[0],
                            jax.tree.leaves(back)):
        if a.shape[-1] == 1 and jnp.issubdtype(a.dtype, jnp.floating):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_slab_int_leaves_pass_through_unchanged():
    """Already-packed integer leaves (hybrid int8 ring KV) are packed
    storage: augmentation must not touch them."""
    cfg = _amc(get_arch("recurrentgemma-9b").reduced(), kv_mode="int8",
               pool_mode="always-augmented")
    shape = ShapeConfig("t", 32, 2, "decode")
    pool = AugmentedStatePool(cfg, M.abstract_cache(cfg, shape),
                              max_batch=2)
    int_leaves = [l for l in jax.tree.leaves(pool.state["normal"])
                  if not jnp.issubdtype(l.dtype, jnp.floating)]
    assert int_leaves, "expected packed ring-KV leaves"
    cache = jax.tree.map(
        lambda l: jnp.ones_like(l) if not jnp.issubdtype(
            l.dtype, jnp.floating) else l, pool.state["normal"])
    modes = jnp.array([1, 1], jnp.int32)
    state = slab_store_back(pool.state, cache, modes, 8)
    back = slab_reconstitute(state, modes, 8)
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(back)):
        if not jnp.issubdtype(a.dtype, jnp.floating):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# slab lifecycle: admit / augment / promote / refresh / release
# ---------------------------------------------------------------------------

def test_slab_admission_prefers_normal_then_augments_under_pressure():
    pool = _slab_pool(budget_slabs=2, max_batch=4)
    step = 0
    assert pool.admit_row(0, 5, step) and pool.slot_mode[0] == 0
    assert pool.admit_row(1, 5, step) and pool.slot_mode[1] == 0
    assert pool.live_bytes == 2 * pool.slab_bytes_normal
    # third admission: no normal room -> coldest slabs augmented in place
    assert pool.can_admit_tokens(5)
    assert pool.admit_row(2, 5, step)
    assert pool.slot_mode[2] == 1
    assert pool.stats["augment_events"] >= 1
    assert pool.live_bytes <= pool.budget_bytes
    pool.release_row(2)
    assert pool.live_bytes <= 2 * pool.slab_bytes_normal


def test_slab_budget_rejects_when_even_augmentation_cannot_fit():
    pool = _slab_pool(budget_slabs=1, max_batch=4)
    assert pool.admit_row(0, 5, 0)
    admitted = []
    for row in (1, 2, 3):
        if pool.can_admit_tokens(5) and pool.admit_row(row, 5, 0):
            admitted.append(row)
    # an aug slab costs > slab_normal/3 here, so at most 2 more fit —
    # and the pool must have said no rather than blow the budget
    assert pool.live_bytes <= pool.budget_bytes


def test_slab_refresh_restamps_and_promotes():
    pool = _slab_pool(budget_slabs=4, max_batch=2, retention_steps=2)
    assert pool.admit_row(0, 5, 0)
    pool.augment_slot(0, 0)
    assert pool.slot_mode[0] == 1
    assert pool.refresh_due(1) == []
    due = pool.refresh_due(2)                # age == retention_steps
    assert due == [0]
    pool.refresh(0, 2)                       # budget has room -> promote
    assert pool.slot_mode[0] == 0
    assert pool.stats["promote_events"] == 1
    assert pool.stats["refreshes"] == 1
    assert pool.stats["refresh_bytes"] > 0


def test_static_slab_is_never_restamped_by_writes():
    pool = _slab_pool(budget_slabs=4, max_batch=2, retention_steps=2)
    pool.static = True
    assert pool.admit_row(0, 5, 0)
    pool.augment_slot(0, 0)
    pool.note_token_writes(np.array([0]), np.array([3]), 1)
    assert pool.refresh_due(2) == [0]        # write did NOT restamp


# ---------------------------------------------------------------------------
# budget invariant under random admit / preempt / refresh (hypothesis)
# ---------------------------------------------------------------------------

def _drive_ops(pool, ops):
    """Replay an op sequence against a store; the invariant under test is
    live_bytes <= budget_bytes at EVERY boundary (plus non-negativity)."""
    step = 0
    for row, op in ops:
        step += 1
        if op == 0:                                        # admit
            if not pool.slot_alloc[row] and pool.can_admit_tokens(5):
                assert pool.admit_row(row, 5, step)
        elif op == 1:                                      # release/preempt
            pool.release_row(row)
        elif op == 2:                                      # decode write
            rows = np.flatnonzero(pool.slot_alloc)
            pool.note_token_writes(rows, np.zeros_like(rows), step)
        else:                                              # refresh pass
            for key in pool.refresh_due(step):
                pool.refresh(key, step)
        assert 0 <= pool.live_bytes <= pool.budget_bytes, (row, op, step)
    recount = sum(pool._cost(int(pool.slot_mode[r]))
                  for r in np.flatnonzero(pool.slot_alloc))
    assert recount == pool.live_bytes


def _random_ops(rng, n=40, rows=4):
    return [(int(rng.integers(0, rows)), int(rng.integers(0, 4)))
            for _ in range(n)]


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           budget_slabs=st.integers(1, 4),
           mode=st.sampled_from(["normal-only", "augment-on-pressure",
                                 "always-augmented"]))
    def test_slab_budget_invariant_random_ops(seed, budget_slabs, mode):
        pool = _slab_pool(mode, budget_slabs=budget_slabs, max_batch=4,
                          retention_steps=2)
        _drive_ops(pool, _random_ops(np.random.default_rng(seed)))
else:
    @pytest.mark.parametrize("seed,budget_slabs,mode", [
        (s, b, m) for s in (0, 1, 2)
        for b in (1, 3)
        for m in ("normal-only", "augment-on-pressure",
                  "always-augmented")])
    def test_slab_budget_invariant_random_ops(seed, budget_slabs, mode):
        pool = _slab_pool(mode, budget_slabs=budget_slabs, max_batch=4,
                          retention_steps=2)
        _drive_ops(pool, _random_ops(np.random.default_rng(seed)))


def test_paged_pool_budget_invariant_random_ops():
    """Same invariant through the unified interface on the PAGED store
    (the other StateStore implementation)."""
    cfg = _amc(get_arch("qwen1.5-0.5b").reduced(),
               pool_mode="augment-on-pressure")
    rng = np.random.default_rng(7)
    pool = PagedKVPool(cfg, max_batch=4, max_seq=32,
                       budget_bytes=3 * 16384)
    step = 0
    for row, op in _random_ops(rng, n=60):
        step += 1
        if op == 0:
            if not pool.allocated[row].any() and pool.can_admit_tokens(20):
                assert pool.admit_row(row, 20, step)
        elif op == 1:
            pool.release_row(row)
        elif op == 2:
            rows = np.flatnonzero(pool.allocated[:4].any(axis=1))
            pool.note_token_writes(rows, np.zeros_like(rows), step)
        else:
            for key in pool.refresh_due(step):
                pool.refresh(key, step)
        assert 0 <= pool.live_bytes <= pool.budget_bytes, (row, op, step)


# ---------------------------------------------------------------------------
# composite store + registry
# ---------------------------------------------------------------------------

def test_composite_admission_is_atomic():
    """If one part cannot admit, the other part's reservation rolls
    back — no orphaned capacity."""
    cfg = get_arch("llama-3.2-vision-11b").reduced()
    store = make_store(cfg, max_batch=2, max_seq=32)
    assert isinstance(store, CompositeStore)
    # choke the prefix part: one slab budget only
    prefix = store.parts["prefix"]
    prefix.budget_bytes = prefix.slab_bytes_normal
    assert store.admit_row(0, 5, 0)
    kv_live = store.parts["kv"].live_bytes
    assert not store.can_admit_tokens(5)
    assert not store.admit_row(1, 5, 0)
    assert store.parts["kv"].live_bytes == kv_live     # rolled back
    assert not store.parts["kv"].allocated[1].any()
    store.release_row(0)
    assert store.live_bytes == 0


@pytest.mark.parametrize("arch,kind", [
    ("qwen1.5-0.5b", "paged"), ("qwen3-moe-30b-a3b", "paged"),
    ("whisper-tiny", "paged"), ("llama-3.2-vision-11b", "composite"),
    ("mamba2-130m", "slab"), ("recurrentgemma-9b", "slab")])
def test_store_registry_covers_every_family(arch, kind):
    cfg = get_arch(arch).reduced()
    store = make_store(cfg, max_batch=2, max_seq=32)
    assert store.kind == kind
    if arch == "whisper-tiny":
        assert store.prefix_pages > 0       # cross-KV static band
    # the whole interface surface exists
    for name in ("can_admit_tokens", "admit_row", "ensure_position",
                 "release_row", "note_token_writes", "refresh_due",
                 "refresh", "max_augmented_age", "device_tables",
                 "read_value_counts", "write_value_counts",
                 "physical_bytes", "describe"):
        assert callable(getattr(store, name)), (arch, name)
    assert store.budget_bytes > 0 and store.live_bytes == 0
