import os

# Smoke tests and benches must see the real device count (1 CPU device) —
# the 512-device override lives ONLY inside launch/dryrun.py.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_threefry_partitionable", True)
