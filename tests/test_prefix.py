"""Shared-prefix page reuse: COW divergence at every page geometry,
refcount/demotion/eviction invariants, the masked page-copy kernel's
parity with the pack/unpack primitives it composes, placement fallback,
and decode token identity to the sharing-disabled engine."""
import dataclasses
import zlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import AMCConfig
from repro.kernels import ops as K
from repro.launch.mesh import make_local_mesh
from repro.models import layers as L
from repro.serve import Request, ServeEngine, make_serving
from repro.serve.cache_pool import PagedKVPool, _cow_page_op
from repro.serve.placement import ArrayView, make_policy
from repro.serve.prefix import PrefixIndex, chain_hashes

PAGE, CHUNK = 8, 8


# ---------------------------------------------------------------------------
# engine-level: prefill skipping + COW at every divergence geometry
# ---------------------------------------------------------------------------

def _engine(prefix_cache, arch="qwen1.5-0.5b", max_seq=96):
    cfg = get_arch(arch).reduced()
    cfg = dataclasses.replace(
        cfg, amc=dataclasses.replace(cfg.amc, page_size=PAGE))
    return ServeEngine(cfg, make_local_mesh(), max_batch=4,
                       max_seq=max_seq, prefill_chunk=CHUNK, seed=1,
                       prefix_cache=prefix_cache)


def _drain(eng):
    while eng.active.any() or eng._queue:
        eng.step_all()
    return {rid: list(map(int, eng.outputs[rid])) for rid in eng.outputs}


def _prompts(seed=0):
    rng = np.random.default_rng(seed)
    sys_p = rng.integers(0, 100, size=(4 * PAGE,)).astype(np.int32)
    a = rng.integers(0, 100, size=(9,)).astype(np.int32)
    b = (a + 1) % 100          # diverges from `a` at its very first token
    return sys_p, a, b


def _run_pair(prompts, max_new=4):
    """The same request stream through sharing-on and sharing-off
    engines; returns (on_engine, per-request prefill dispatch deltas,
    outputs_on, outputs_off)."""
    outs, deltas = {}, []
    for pc in (4, 0):
        eng = _engine(pc)
        for i, p in enumerate(prompts):
            before = eng.prefill_dispatch_count
            eng.add_request(Request(prompt=p, max_new_tokens=max_new, id=i))
            if pc:
                deltas.append(eng.prefill_dispatch_count - before)
        outs[pc] = _drain(eng)
        if pc:
            on = eng
    return on, deltas, outs[4], outs[0]


def test_full_hit_zero_prefill_dispatches_for_shared_run():
    """A 100%-shared page-aligned system prompt costs ZERO prefill
    dispatches on 2nd+ requests — fed == the cached run exactly — and
    the first token after the run lands in a fresh page (no COW)."""
    sys_p, a, _ = _prompts()
    p0 = np.concatenate([sys_p, a[:1]])     # fed = sys_p: registers 4 pages
    p1 = np.concatenate([sys_p, a[1:2]])    # fed = sys_p: full hit
    eng, deltas, on, off = _run_pair([p0, p1])
    assert deltas[0] == -(-sys_p.size // CHUNK)     # miss pays full prefill
    assert deltas[1] == 0                           # hit pays nothing
    st = eng.stats()["prefix"]
    assert st["hits"] == 1 and st["dispatches_saved"] >= deltas[0]
    assert st["cow_events"] == 0                    # divergence past the run
    assert on == off


def test_cow_divergence_at_page_boundary_shares_without_copy():
    """Divergence exactly ON a page boundary: every matched page is
    fully shared, the tail allocates fresh pages, so no COW fires."""
    sys_p, a, b = _prompts()
    p0 = np.concatenate([sys_p, a[:5]])     # fed 36 -> registers 4 pages
    p1 = np.concatenate([sys_p, b[:5]])     # diverges at token 32
    eng, deltas, on, off = _run_pair([p0, p1])
    st = eng.stats()["prefix"]
    assert st["hits"] == 1
    assert st["cow_events"] == 0
    assert deltas[1] == -(-(p1.size - 1 - 4 * PAGE) // CHUNK)
    assert on == off


def test_cow_divergence_mid_page_copies_boundary_page():
    """Divergence mid-page INSIDE the entry's coverage: the boundary
    page is mapped shared (refcount 2) and the prefill tail's first
    write copies it — exactly one COW, `keep` = tokens before the
    divergence point."""
    sys_p, a, _ = _prompts()
    c = a.copy()
    c[4:] = (c[4:] + 7) % 100               # same first 4 tail tokens
    p0 = np.concatenate([sys_p, a])         # fed 40 -> registers 5 pages
    p1 = np.concatenate([sys_p, c])         # match m = 36, mid page 4
    eng, deltas, on, off = _run_pair([p0, p1])
    st = eng.stats()["prefix"]
    assert st["hits"] == 1
    assert st["cow_events"] == 1
    assert st["cow_bytes"] > 0
    assert deltas[1] == -(-(p1.size - 1 - 36) // CHUNK)
    assert on == off


def test_cow_on_first_decode_write_into_shared_page():
    """A prompt that ends mid-shared-page pays zero prefill dispatches
    (fed == matched run) and COWs on its FIRST DECODE token's write —
    the decode-side divergence geometry."""
    sys_p, a, _ = _prompts()
    p0 = np.concatenate([sys_p, a])         # registers 5 pages (40 tokens)
    p1 = np.concatenate([sys_p, a[:4]])     # fed 35 tokens, all matched
    eng, deltas, on, off = _run_pair([p0, p1])
    st = eng.stats()["prefix"]
    assert st["hits"] == 1
    assert deltas[1] == 0                   # nothing left to prefill
    assert st["cow_events"] == 1            # first decode write, keep=3
    assert on == off


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "qwen3-moe-30b-a3b"])
def test_decode_token_identity_with_sharing(arch):
    """Sharing changes which physical pages prefill writes, never what
    decode computes — pinned for the dense and moe families."""
    rng = np.random.default_rng(3)
    sys_p = rng.integers(0, 100, size=(2 * PAGE,)).astype(np.int32)
    prompts = [np.concatenate(
        [sys_p, rng.integers(0, 100, size=(5,)).astype(np.int32)])
        for _ in range(3)]
    outs = {}
    for pc in (4, 0):
        eng = _engine(pc, arch=arch, max_seq=64)
        for i, p in enumerate(prompts):
            eng.add_request(Request(prompt=p, max_new_tokens=3, id=i))
        outs[pc] = _drain(eng)
        if pc:
            assert eng.stats()["prefix"]["hits"] == 2
    assert outs[4] == outs[0]


def test_add_request_rejects_out_of_vocab_tokens():
    eng = _engine(0)
    bad = np.array([0, 1, eng.cfg.vocab], np.int32)
    with pytest.raises(ValueError, match="outside the vocab"):
        eng.add_request(Request(prompt=bad, max_new_tokens=1, id=0))
    with pytest.raises(ValueError, match="outside the vocab"):
        eng.add_request(Request(prompt=np.array([-1, 2], np.int32),
                                max_new_tokens=1, id=1))


# ---------------------------------------------------------------------------
# PrefixIndex: chain hashes, deepest-first match, boundary extension
# ---------------------------------------------------------------------------

def test_chain_hashes_page_granular_and_prefix_consistent():
    t = np.arange(25, dtype=np.int32)
    h = chain_hashes(t, PAGE)
    assert len(h) == 3                      # only FULL pages are hashed
    assert h[:2] == chain_hashes(t[:16], PAGE)     # chaining is a prefix
    u = t.copy()
    u[0] += 1                               # first-page change reseeds all
    assert chain_hashes(u, PAGE)[2] != h[2]


def test_match_prefers_deepest_entry_and_extends_into_boundary_page():
    t = np.arange(100, 124, dtype=np.int32)
    idx = PrefixIndex(2, PAGE)
    idx.add_entry(idx.acquire_slot(None, 0), 90, t[:16], step=0)
    idx.add_entry(idx.acquire_slot(None, 1), 91, t[:24], step=1)
    e, m = idx.match(t[:24])
    assert e.row == 91 and m == 24          # deepest wins over the 2-pager
    q = t.copy()
    q[19:] += 50                            # diverge mid page 2
    e, m = idx.match(q)
    assert e.row == 91 and m == 19          # full pages + 3-token extension
    assert idx.probe(q) == 19


# ---------------------------------------------------------------------------
# pool: restamp-once refresh, demotion ladder, eviction only at refcount 0
# ---------------------------------------------------------------------------

def _ppool(entries, kv_mode="normal", pool_mode="augment-on-pressure", **kw):
    cfg = get_arch("qwen1.5-0.5b").reduced()
    cfg = dataclasses.replace(cfg, amc=AMCConfig(
        kv_mode=kv_mode, pool_mode=pool_mode, prefix_cache=entries))
    return PagedKVPool(cfg, max_batch=2, max_seq=32, **kw)


def test_shared_page_refresh_restamps_once_not_per_sharer():
    """A shared physical page nearing retention expiry appears ONCE in
    refresh_due (on its canonical share-band key) however many rows map
    it, and one refresh_page restamps it for every sharer."""
    pool = _ppool(2, kv_mode="int8", pool_mode="always-augmented",
                  retention_steps=2)
    assert pool.alloc_page(0, 0, 0) and pool.alloc_page(0, 1, 0)
    erow = pool.entry_row(0)
    pool.register_entry_pages(erow, 0, 2, step=0)
    pool.share_page(erow, 0, 1, 0, step=0)  # third sharer of page (0,0)
    due = pool.refresh_due(2)
    assert sorted(due) == [(erow, 0), (erow, 1)]   # 2 physical, not 5 keys
    for lp in (0, 1):
        pool.refresh_page(erow, lp, step=2)
    assert pool.refresh_due(2) == []
    assert pool.stats["refreshes"] == 2
    assert pool.stats["refresh_bytes"] == 2 * 2 * pool.geom.page_bytes_aug
    # releasing the canonical holder re-homes the clock, doesn't drop it
    pool.free_row(1)
    pool.free_row(0)
    assert sorted(pool.policies) == [(erow, 0), (erow, 1)]


def test_prefix_pages_demote_under_pressure_and_evict_only_at_refcount_0():
    pool0 = _ppool(1)
    pbn = pool0.geom.page_bytes_normal
    pool = _ppool(1, budget_bytes=2 * pbn)
    idx = PrefixIndex(1, pool.geom.page_size)
    pool.attach_prefix_index(idx)
    assert pool.alloc_page(0, 0, 0) and pool.alloc_page(0, 1, 0)
    erow = pool.entry_row(0)
    pool.register_entry_pages(erow, 0, 2, step=0)
    idx.add_entry(0, erow, np.arange(2 * pool.geom.page_size,
                                     dtype=np.int32), step=0)
    # refcount 2: the shared pages are untouchable — no demotion source,
    # no eviction candidate, so the pressured alloc must FAIL
    assert not idx.evict_one(pool, step=1)
    assert not pool.alloc_page(1, 0, step=1)
    assert (np.asarray(pool.page_mode[0, :2]) == 0).all()
    assert pool.stats["prefix_demotions"] == 0
    assert pool.stats["prefix_evictions"] == 0
    # sharer gone (refcount 1, entry only): admission headroom reappears
    # and the allocator DEMOTES the idle prefix pages instead of evicting
    pool.free_row(0)
    assert pool.can_admit_tokens(pool.geom.page_size)
    assert pool.alloc_page(1, 0, step=2)
    assert pool.stats["prefix_demotions"] > 0
    assert pool.stats["prefix_evictions"] == 0
    assert 0 in idx.entries                 # still cached, just denser
    assert (np.asarray(pool.page_mode[erow, :2]) == 1).all()
    # eviction is the LAST rung, at refcount 0 only
    assert pool._reclaim_prefix(step=3)
    assert pool.stats["prefix_evictions"] == 1
    assert not idx.entries
    # only row 1's page remains, charged at whichever mode it landed in
    assert pool.live_bytes == pool._cost(int(pool.page_mode[1, 0]))


def test_coldest_normal_never_selects_refcounted_pages():
    pool = _ppool(1)
    assert pool.alloc_page(0, 0, 0) and pool.alloc_page(0, 1, 0)
    pool.register_entry_pages(pool.entry_row(0), 0, 2, step=0)  # rc 2 both
    assert pool.alloc_page(1, 0, 5)         # hotter, but unshared
    victim = pool._coldest_normal()
    assert victim is not None
    assert pool.page_refcount(*victim) == 1
    assert victim == (1, 0)                 # NOT the cold shared pages


# ---------------------------------------------------------------------------
# masked page-copy kernel: parity with the primitives it composes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("src_mode,dst_mode,aug_bits", [
    (0, 0, 4), (1, 1, 4), (1, 1, 8),
    (0, 1, 4), (0, 1, 8), (1, 0, 4), (1, 0, 8)])
def test_cow_page_op_matches_pack_unpack_primitives(src_mode, dst_mode,
                                                    aug_bits):
    rng = np.random.default_rng(7)
    Lg, N, KV, P, hd = 2, 3, 2, PAGE, 32
    src, dst, keep = 1, 2, 5
    da = hd // 2 if aug_bits == 4 else hd
    pdt = jnp.uint8 if aug_bits == 4 else jnp.int8
    kn = rng.standard_normal((Lg, N, KV, P, hd)).astype(np.float32)
    kp = rng.integers(0, 256 if aug_bits == 4 else 127,
                      (Lg, N, KV, P, da))
    ks = rng.uniform(0.01, 0.1, (Lg, N, KV, P)).astype(np.float32)

    def arenas():
        return {"kn": jnp.asarray(kn, jnp.bfloat16),
                "vn": jnp.asarray(-kn, jnp.bfloat16),
                "kp": jnp.asarray(kp, pdt), "vp": jnp.asarray(kp, pdt),
                "ks": jnp.asarray(ks, jnp.bfloat16),
                "vs": jnp.asarray(ks, jnp.bfloat16)}

    a = arenas()                  # donated to the op
    ref = arenas()                # survives for the oracle
    out = _cow_page_op(a, src, dst, keep, src_mode=src_mode,
                       dst_mode=dst_mode, aug_bits=aug_bits)
    mask = (jnp.arange(P) < keep)[None, None, :]
    if (src_mode, dst_mode) == (0, 0):
        want = jnp.where(mask[..., None], ref["kn"][:, src], 0)
        assert (out["kn"][:, dst] == want).all()
    elif (src_mode, dst_mode) == (1, 1):
        assert (out["kp"][:, dst] == jnp.where(
            mask[..., None], ref["kp"][:, src], 0)).all()
        assert (out["ks"][:, dst] == jnp.where(
            mask, ref["ks"][:, src], 1)).all()
    elif (src_mode, dst_mode) == (0, 1):
        if aug_bits == 4:
            p, s = K.quantize_pack_kv(ref["kn"][:, src], mask)
        else:
            p, s = L.pack_kv_int8(ref["kn"][:, src])
            p = jnp.where(mask[..., None], p, 0)
            s = jnp.where(mask[..., None], s, 1)
        assert (out["kp"][:, dst] == p).all()
        assert (out["ks"][:, dst] == s[..., 0].astype(jnp.bfloat16)).all()
    else:
        unpack = L.unpack_kv_int4 if aug_bits == 4 else L.unpack_kv_int8
        d = unpack(ref["kp"][:, src], ref["ks"][:, src][..., None])
        want = jnp.where(mask[..., None], d, 0).astype(jnp.bfloat16)
        assert (out["kn"][:, dst] == want).all()


# ---------------------------------------------------------------------------
# placement: affinity's deterministic fallback rung + fleet accounting
# ---------------------------------------------------------------------------

def _view(aid, free_rows=1, admit=True):
    return ArrayView(aid=aid, alive=True, running=0, queued=0,
                     free_rows=free_rows, live_bytes=0,
                     budget_bytes=1 << 20,
                     admit_probe=(lambda n: admit))


def test_affinity_fallback_excludes_preferred_and_is_recorded():
    pol = make_policy("affinity")
    prompt = np.arange(40, dtype=np.int32)
    pref = zlib.crc32(prompt[:pol.prefix_tokens].tobytes()) % 2
    assert pol.place(prompt, [_view(0), _view(1)]) == pref
    assert pol.last_reason == "hash"
    views = [_view(0), _view(1)]
    views[pref] = _view(pref, free_rows=0)      # preferred over budget
    assert pol.place(prompt, views) == 1 - pref  # deterministic: the other
    assert pol.last_reason == "fallback"


def test_fleet_placement_stats_record_decision_rungs():
    cfg = get_arch("qwen1.5-0.5b").reduced()
    cfg = dataclasses.replace(
        cfg, amc=dataclasses.replace(cfg.amc, page_size=PAGE))
    fleet = make_serving(cfg, make_local_mesh(), num_arrays=2,
                         placement="affinity", prefix_cache=2,
                         max_batch=1, max_seq=64, prefill_chunk=CHUNK)
    rng = np.random.default_rng(5)
    sys_p = rng.integers(0, 100, size=(4 * PAGE,)).astype(np.int32)
    for i in range(3):
        tail = rng.integers(0, 100, size=(3,)).astype(np.int32)
        fleet.add_request(Request(prompt=np.concatenate([sys_p, tail]),
                                  max_new_tokens=2, id=i))
    pl = fleet.stats()["placement"]
    assert pl["policy"] == "affinity"
    assert sum(pl["decisions"].values()) == 3
    # array 0 rung names only — the fallback rung must be attributable
    assert set(pl["decisions"]) <= {"prefix", "hash", "fallback"}
    assert pl["decisions"].get("fallback", 0) >= 1
    while fleet.has_work:
        fleet.step_all()
    assert len(fleet.outputs) == 3
