"""Per-kernel allclose vs the ref.py oracles, swept over shapes, dtypes and
block sizes (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant, ternary
from repro.kernels import ops, ref


_rel_err = ref.rel_err


# ---------------------------------------------------------------------------
# ternary matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N", [(128, 512, 256), (256, 1024, 128),
                                   (128, 2048, 512), (384, 512, 384)])
def test_ternary_matmul_shapes(M, K, N):
    w = jax.random.normal(jax.random.PRNGKey(0), (K, N))
    t, scale = ternary.ternarize(w)
    wp = ternary.pack_ternary_2bit(t)
    x = jax.random.normal(jax.random.PRNGKey(1), (M, K), jnp.bfloat16)
    y = ops.ternary_matmul(x, wp, scale, bm=128, bk=512, bn=128)
    r = ref.ternary_matmul_ref(x, wp, scale)
    assert _rel_err(y, r) < 0.02


@pytest.mark.parametrize("bm,bk,bn", [(64, 256, 64), (128, 512, 256),
                                      (128, 1024, 128)])
def test_ternary_matmul_blocks(bm, bk, bn):
    M, K, N = 256, 1024, 256
    w = jax.random.normal(jax.random.PRNGKey(2), (K, N))
    t, scale = ternary.ternarize(w)
    wp = ternary.pack_ternary_2bit(t)
    x = jax.random.normal(jax.random.PRNGKey(3), (M, K), jnp.bfloat16)
    y = ops.ternary_matmul(x, wp, scale, bm=bm, bk=bk, bn=bn)
    r = ref.ternary_matmul_ref(x, wp, scale)
    assert _rel_err(y, r) < 0.02


def test_ternary_matmul_fp32_activations():
    M, K, N = 128, 512, 128
    w = jax.random.normal(jax.random.PRNGKey(4), (K, N))
    t, scale = ternary.ternarize(w)
    wp = ternary.pack_ternary_2bit(t)
    x = jax.random.normal(jax.random.PRNGKey(5), (M, K), jnp.float32)
    y = ops.ternary_matmul(x.astype(jnp.bfloat16), wp, scale)
    r = ref.ternary_matmul_ref(x.astype(jnp.bfloat16), wp, scale)
    assert _rel_err(y, r) < 0.02


# ---------------------------------------------------------------------------
# dual-plane matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N", [(128, 256, 256), (256, 512, 128),
                                   (128, 1024, 256)])
def test_dual_plane_matmul_shapes(M, K, N):
    k = jax.random.PRNGKey(0)
    w_hi = jax.random.normal(k, (K, N))
    w_lo = jax.random.normal(jax.random.fold_in(k, 1), (K, N))
    qh, sh = quant.quantize_int4(w_hi, axis=0)
    ql, sl = quant.quantize_int4(w_lo, axis=0)
    buf = quant.pack_int4_pair(qh, ql)
    x = jax.random.normal(jax.random.fold_in(k, 2), (M, K), jnp.bfloat16)
    yh, yl = ops.dual_plane_matmul(x, buf, sh, sl, bm=128, bk=256, bn=128)
    rh, rl = ref.dual_plane_matmul_ref(x, buf, sh, sl)
    assert _rel_err(yh, rh) < 0.02
    assert _rel_err(yl, rl) < 0.02


def test_dual_plane_one_buffer_two_results_differ():
    """The two planes must really be independent data."""
    K, N = 256, 128
    k = jax.random.PRNGKey(7)
    qh, sh = quant.quantize_int4(jax.random.normal(k, (K, N)), axis=0)
    ql, sl = quant.quantize_int4(
        jax.random.normal(jax.random.fold_in(k, 1), (K, N)), axis=0)
    buf = quant.pack_int4_pair(qh, ql)
    x = jax.random.normal(jax.random.fold_in(k, 2), (128, K), jnp.bfloat16)
    yh, yl = ops.dual_plane_matmul(x, buf, sh, sl)
    assert not np.allclose(np.asarray(yh, np.float32),
                           np.asarray(yl, np.float32), atol=0.1)


# ---------------------------------------------------------------------------
# packed-KV decode attention
# ---------------------------------------------------------------------------

def _make_kv(key, B, KV, S, D):
    kf = jax.random.normal(key, (B, KV, S, D))
    vf = jax.random.normal(jax.random.fold_in(key, 1), (B, KV, S, D))
    kq, ks = quant.quantize_int4(kf, axis=-1)
    vq, vs = quant.quantize_int4(vf, axis=-1)
    kp = quant.pack_int4_pair(kq[..., 0::2], kq[..., 1::2])
    vp = quant.pack_int4_pair(vq[..., 0::2], vq[..., 1::2])
    return kp, vp, ks[..., 0].astype(jnp.bfloat16), vs[..., 0].astype(jnp.bfloat16)


@pytest.mark.parametrize("B,KV,Hg,D,S", [(2, 4, 4, 64, 512),
                                         (1, 8, 2, 128, 1024),
                                         (4, 2, 8, 64, 256)])
def test_packed_kv_attention_shapes(B, KV, Hg, D, S):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, KV, Hg, D), jnp.bfloat16)
    kp, vp, ks, vs = _make_kv(jax.random.fold_in(key, 9), B, KV, S, D)
    lengths = jnp.asarray(
        np.random.default_rng(0).integers(1, S + 1, size=(B,)), jnp.int32)
    o = ops.packed_kv_attention(q, kp, vp, ks, vs, lengths, bs=128)
    r = ref.packed_kv_attention_ref(q, kp, vp, ks, vs, lengths)
    assert _rel_err(o, r) < 0.03


def test_packed_kv_attention_block_sweep():
    B, KV, Hg, D, S = 2, 2, 4, 64, 512
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (B, KV, Hg, D), jnp.bfloat16)
    kp, vp, ks, vs = _make_kv(jax.random.fold_in(key, 4), B, KV, S, D)
    lengths = jnp.array([300, 512], jnp.int32)
    r = ref.packed_kv_attention_ref(q, kp, vp, ks, vs, lengths)
    for bs in (64, 128, 256, 512):
        o = ops.packed_kv_attention(q, kp, vp, ks, vs, lengths, bs=bs)
        assert _rel_err(o, r) < 0.03, bs


def test_packed_kv_attention_skips_invalid_blocks():
    """Scalar-prefetched lengths: grid work must be ∝ actual length. The
    kernel's block-visit counter reports how many sequence blocks each
    (row, head) actually processed."""
    B, KV, Hg, D, S, bs = 3, 2, 4, 64, 1024, 128
    key = jax.random.PRNGKey(11)
    q = jax.random.normal(key, (B, KV, Hg, D), jnp.bfloat16)
    kp, vp, ks, vs = _make_kv(jax.random.fold_in(key, 12), B, KV, S, D)
    lengths = jnp.array([12, 300, 1024], jnp.int32)
    o, visits = ops.packed_kv_attention(q, kp, vp, ks, vs, lengths, bs=bs,
                                        debug_visits=True)
    expect = np.maximum(np.ceil(np.asarray(lengths) / bs), 1).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(visits), np.tile(expect[:, None],
                                                              (1, KV)))
    # 12 valid tokens in a 1024-slot cache: 1 block visited, not 8
    assert int(np.asarray(visits)[0, 0]) == 1
    r = ref.packed_kv_attention_ref(q, kp, vp, ks, vs, lengths)
    assert _rel_err(o, r) < 0.03


def test_packed_kv_attention_short_lengths_numerics():
    """lengths ≪ max_seq with the skipping path still matches the oracle
    to seed tolerance."""
    B, KV, Hg, D, S = 2, 4, 2, 64, 2048
    key = jax.random.PRNGKey(13)
    q = jax.random.normal(key, (B, KV, Hg, D), jnp.bfloat16)
    kp, vp, ks, vs = _make_kv(jax.random.fold_in(key, 14), B, KV, S, D)
    lengths = jnp.array([1, 37], jnp.int32)
    for bs in (128, 512):
        o = ops.packed_kv_attention(q, kp, vp, ks, vs, lengths, bs=bs)
        r = ref.packed_kv_attention_ref(q, kp, vp, ks, vs, lengths)
        assert _rel_err(o, r) < 0.03, bs


def test_packed_kv_attention_respects_length_mask():
    """Tokens beyond `length` must not affect the output."""
    B, KV, Hg, D, S = 1, 2, 2, 64, 256
    key = jax.random.PRNGKey(5)
    q = jax.random.normal(key, (B, KV, Hg, D), jnp.bfloat16)
    kp, vp, ks, vs = _make_kv(jax.random.fold_in(key, 6), B, KV, S, D)
    lengths = jnp.array([100], jnp.int32)
    o1 = ops.packed_kv_attention(q, kp, vp, ks, vs, lengths, bs=64)
    # scramble the masked region
    kp2 = kp.at[:, :, 100:].set(255)
    vp2 = vp.at[:, :, 100:].set(255)
    o2 = ops.packed_kv_attention(q, kp2, vp2, ks, vs, lengths, bs=64)
    assert np.allclose(np.asarray(o1, np.float32), np.asarray(o2, np.float32))


# ---------------------------------------------------------------------------
# fused quantize-pack (the cache write driver)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(2, 16, 4, 64), (1, 7, 2, 128),
                                   (3, 5, 70)])
def test_quantize_pack_kv_matches_ref(shape):
    kv = jax.random.normal(jax.random.PRNGKey(21), shape, jnp.bfloat16)
    p, s = ops.quantize_pack_kv(kv)
    pr, sr = ref.quantize_pack_kv_ref(kv)
    assert p.dtype == jnp.uint8 and p.shape == shape[:-1] + (shape[-1] // 2,)
    np.testing.assert_array_equal(np.asarray(p), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(s, np.float32),
                                  np.asarray(sr.astype(jnp.bfloat16),
                                             np.float32))


def test_quantize_pack_kv_bit_exact_with_pack_kv_int4():
    """The engine's golden equivalence rests on kernel == pack_kv_int4."""
    from repro.models import layers as L
    kv = jax.random.normal(jax.random.PRNGKey(22), (4, 9, 2, 64),
                           jnp.bfloat16)
    p, s = ops.quantize_pack_kv(kv)
    pl_, sl = L.pack_kv_int4(kv)
    np.testing.assert_array_equal(np.asarray(p), np.asarray(pl_))
    np.testing.assert_array_equal(np.asarray(s, np.float32),
                                  np.asarray(sl, np.float32))


def test_quantize_pack_kv_padding_path():
    """Row counts that don't divide the block size go through the padded
    path and must be unchanged by it."""
    kv = jax.random.normal(jax.random.PRNGKey(23), (13, 32), jnp.bfloat16)
    p_pad, s_pad = ops.quantize_pack_kv(kv, bn=8)     # 13 rows, bn=8 -> pad 3
    pr, sr = ref.quantize_pack_kv_ref(kv)
    np.testing.assert_array_equal(np.asarray(p_pad), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(s_pad, np.float32),
                                  np.asarray(sr.astype(jnp.bfloat16),
                                             np.float32))


def test_quantize_pack_kv_roundtrip_attention():
    """Cache built by the fused kernel feeds the attention kernel and
    matches the all-reference pipeline."""
    from repro.models import layers as L
    B, KV, Hg, D, S = 2, 2, 2, 64, 256
    key = jax.random.PRNGKey(24)
    q = jax.random.normal(key, (B, KV, Hg, D), jnp.bfloat16)
    kf = jax.random.normal(jax.random.fold_in(key, 1), (B, KV, S, D),
                           jnp.bfloat16)
    vf = jax.random.normal(jax.random.fold_in(key, 2), (B, KV, S, D),
                           jnp.bfloat16)
    kp, ks = ops.quantize_pack_kv(kf)
    vp, vs = ops.quantize_pack_kv(vf)
    lengths = jnp.array([200, 64], jnp.int32)
    o = ops.packed_kv_attention(q, kp, vp, ks[..., 0], vs[..., 0], lengths,
                                bs=64)
    kp2, ks2 = L.pack_kv_int4(kf)
    vp2, vs2 = L.pack_kv_int4(vf)
    r = ref.packed_kv_attention_ref(q, kp2, vp2, ks2[..., 0], vs2[..., 0],
                                    lengths)
    assert _rel_err(o, r) < 0.03


@pytest.mark.parametrize("B,KV,Hg,D,S", [(2, 4, 4, 64, 512),
                                         (1, 2, 2, 128, 256)])
def test_packed_kv_attention_int8(B, KV, Hg, D, S):
    """kv_bits=8: the cache stays int8 in HBM (no nibble unpack, the cast
    is the sense amp); same online-softmax path, same length skipping."""
    key = jax.random.PRNGKey(17)
    q = jax.random.normal(key, (B, KV, Hg, D), jnp.bfloat16)
    kf = jax.random.normal(jax.random.fold_in(key, 1), (B, KV, S, D))
    vf = jax.random.normal(jax.random.fold_in(key, 2), (B, KV, S, D))
    kq, ks = quant.quantize_int8(kf, axis=-1)
    vq, vs = quant.quantize_int8(vf, axis=-1)
    ks2 = ks[..., 0].astype(jnp.bfloat16)
    vs2 = vs[..., 0].astype(jnp.bfloat16)
    lengths = jnp.asarray(
        np.random.default_rng(1).integers(1, S + 1, size=(B,)), jnp.int32)
    o = ops.packed_kv_attention(q, kq, vq, ks2, vs2, lengths, bs=128,
                                kv_bits=8)
    r = ref.packed_kv_attention_ref(q, kq, vq, ks2, vs2, lengths, kv_bits=8)
    assert _rel_err(o, r) < 0.03


def test_packed_kv_attention_int8_respects_length_mask():
    B, KV, Hg, D, S = 1, 2, 2, 64, 256
    key = jax.random.PRNGKey(19)
    q = jax.random.normal(key, (B, KV, Hg, D), jnp.bfloat16)
    kf = jax.random.normal(jax.random.fold_in(key, 1), (B, KV, S, D))
    vf = jax.random.normal(jax.random.fold_in(key, 2), (B, KV, S, D))
    kq, _ks = quant.quantize_int8(kf, axis=-1)
    vq, _vs = quant.quantize_int8(vf, axis=-1)
    ks = _ks[..., 0].astype(jnp.bfloat16)
    vs = _vs[..., 0].astype(jnp.bfloat16)
    lengths = jnp.array([100], jnp.int32)
    o1 = ops.packed_kv_attention(q, kq, vq, ks, vs, lengths, bs=64, kv_bits=8)
    kq2 = kq.at[:, :, 100:].set(127)
    vq2 = vq.at[:, :, 100:].set(127)
    o2 = ops.packed_kv_attention(q, kq2, vq2, ks, vs, lengths, bs=64,
                                 kv_bits=8)
    assert np.allclose(np.asarray(o1, np.float32), np.asarray(o2, np.float32))


def test_packed_kv_attention_length_beyond_capacity():
    """lengths > S means 'all slots valid' (ring-cache callers pass
    position+1 past capacity); the output row must still be written."""
    B, KV, Hg, D, S = 1, 2, 2, 64, 256
    key = jax.random.PRNGKey(31)
    q = jax.random.normal(key, (B, KV, Hg, D), jnp.bfloat16)
    kp, vp, ks, vs = _make_kv(jax.random.fold_in(key, 32), B, KV, S, D)
    o = ops.packed_kv_attention(q, kp, vp, ks, vs,
                                jnp.array([S + 100], jnp.int32), bs=64)
    r = ref.packed_kv_attention_ref(q, kp, vp, ks, vs,
                                    jnp.array([S], jnp.int32))
    assert np.isfinite(np.asarray(o, np.float32)).all()
    assert _rel_err(o, r) < 0.03
