"""Per-kernel allclose vs the ref.py oracles, swept over shapes, dtypes and
block sizes (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant, ternary
from repro.kernels import ops, ref


def _rel_err(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return np.abs(a - b).max() / max(np.abs(b).max(), 1e-6)


# ---------------------------------------------------------------------------
# ternary matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N", [(128, 512, 256), (256, 1024, 128),
                                   (128, 2048, 512), (384, 512, 384)])
def test_ternary_matmul_shapes(M, K, N):
    w = jax.random.normal(jax.random.PRNGKey(0), (K, N))
    t, scale = ternary.ternarize(w)
    wp = ternary.pack_ternary_2bit(t)
    x = jax.random.normal(jax.random.PRNGKey(1), (M, K), jnp.bfloat16)
    y = ops.ternary_matmul(x, wp, scale, bm=128, bk=512, bn=128)
    r = ref.ternary_matmul_ref(x, wp, scale)
    assert _rel_err(y, r) < 0.02


@pytest.mark.parametrize("bm,bk,bn", [(64, 256, 64), (128, 512, 256),
                                      (128, 1024, 128)])
def test_ternary_matmul_blocks(bm, bk, bn):
    M, K, N = 256, 1024, 256
    w = jax.random.normal(jax.random.PRNGKey(2), (K, N))
    t, scale = ternary.ternarize(w)
    wp = ternary.pack_ternary_2bit(t)
    x = jax.random.normal(jax.random.PRNGKey(3), (M, K), jnp.bfloat16)
    y = ops.ternary_matmul(x, wp, scale, bm=bm, bk=bk, bn=bn)
    r = ref.ternary_matmul_ref(x, wp, scale)
    assert _rel_err(y, r) < 0.02


def test_ternary_matmul_fp32_activations():
    M, K, N = 128, 512, 128
    w = jax.random.normal(jax.random.PRNGKey(4), (K, N))
    t, scale = ternary.ternarize(w)
    wp = ternary.pack_ternary_2bit(t)
    x = jax.random.normal(jax.random.PRNGKey(5), (M, K), jnp.float32)
    y = ops.ternary_matmul(x.astype(jnp.bfloat16), wp, scale)
    r = ref.ternary_matmul_ref(x.astype(jnp.bfloat16), wp, scale)
    assert _rel_err(y, r) < 0.02


# ---------------------------------------------------------------------------
# dual-plane matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N", [(128, 256, 256), (256, 512, 128),
                                   (128, 1024, 256)])
def test_dual_plane_matmul_shapes(M, K, N):
    k = jax.random.PRNGKey(0)
    w_hi = jax.random.normal(k, (K, N))
    w_lo = jax.random.normal(jax.random.fold_in(k, 1), (K, N))
    qh, sh = quant.quantize_int4(w_hi, axis=0)
    ql, sl = quant.quantize_int4(w_lo, axis=0)
    buf = quant.pack_int4_pair(qh, ql)
    x = jax.random.normal(jax.random.fold_in(k, 2), (M, K), jnp.bfloat16)
    yh, yl = ops.dual_plane_matmul(x, buf, sh, sl, bm=128, bk=256, bn=128)
    rh, rl = ref.dual_plane_matmul_ref(x, buf, sh, sl)
    assert _rel_err(yh, rh) < 0.02
    assert _rel_err(yl, rl) < 0.02


def test_dual_plane_one_buffer_two_results_differ():
    """The two planes must really be independent data."""
    K, N = 256, 128
    k = jax.random.PRNGKey(7)
    qh, sh = quant.quantize_int4(jax.random.normal(k, (K, N)), axis=0)
    ql, sl = quant.quantize_int4(
        jax.random.normal(jax.random.fold_in(k, 1), (K, N)), axis=0)
    buf = quant.pack_int4_pair(qh, ql)
    x = jax.random.normal(jax.random.fold_in(k, 2), (128, K), jnp.bfloat16)
    yh, yl = ops.dual_plane_matmul(x, buf, sh, sl)
    assert not np.allclose(np.asarray(yh, np.float32),
                           np.asarray(yl, np.float32), atol=0.1)


# ---------------------------------------------------------------------------
# packed-KV decode attention
# ---------------------------------------------------------------------------

def _make_kv(key, B, KV, S, D):
    kf = jax.random.normal(key, (B, KV, S, D))
    vf = jax.random.normal(jax.random.fold_in(key, 1), (B, KV, S, D))
    kq, ks = quant.quantize_int4(kf, axis=-1)
    vq, vs = quant.quantize_int4(vf, axis=-1)
    kp = quant.pack_int4_pair(kq[..., 0::2], kq[..., 1::2])
    vp = quant.pack_int4_pair(vq[..., 0::2], vq[..., 1::2])
    return kp, vp, ks[..., 0].astype(jnp.bfloat16), vs[..., 0].astype(jnp.bfloat16)


@pytest.mark.parametrize("B,KV,Hg,D,S", [(2, 4, 4, 64, 512),
                                         (1, 8, 2, 128, 1024),
                                         (4, 2, 8, 64, 256)])
def test_packed_kv_attention_shapes(B, KV, Hg, D, S):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, KV, Hg, D), jnp.bfloat16)
    kp, vp, ks, vs = _make_kv(jax.random.fold_in(key, 9), B, KV, S, D)
    lengths = jnp.asarray(
        np.random.default_rng(0).integers(1, S + 1, size=(B,)), jnp.int32)
    o = ops.packed_kv_attention(q, kp, vp, ks, vs, lengths, bs=128)
    r = ref.packed_kv_attention_ref(q, kp, vp, ks, vs, lengths)
    assert _rel_err(o, r) < 0.03


def test_packed_kv_attention_block_sweep():
    B, KV, Hg, D, S = 2, 2, 4, 64, 512
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (B, KV, Hg, D), jnp.bfloat16)
    kp, vp, ks, vs = _make_kv(jax.random.fold_in(key, 4), B, KV, S, D)
    lengths = jnp.array([300, 512], jnp.int32)
    r = ref.packed_kv_attention_ref(q, kp, vp, ks, vs, lengths)
    for bs in (64, 128, 256, 512):
        o = ops.packed_kv_attention(q, kp, vp, ks, vs, lengths, bs=bs)
        assert _rel_err(o, r) < 0.03, bs


def test_packed_kv_attention_respects_length_mask():
    """Tokens beyond `length` must not affect the output."""
    B, KV, Hg, D, S = 1, 2, 2, 64, 256
    key = jax.random.PRNGKey(5)
    q = jax.random.normal(key, (B, KV, Hg, D), jnp.bfloat16)
    kp, vp, ks, vs = _make_kv(jax.random.fold_in(key, 6), B, KV, S, D)
    lengths = jnp.array([100], jnp.int32)
    o1 = ops.packed_kv_attention(q, kp, vp, ks, vs, lengths, bs=64)
    # scramble the masked region
    kp2 = kp.at[:, :, 100:].set(255)
    vp2 = vp.at[:, :, 100:].set(255)
    o2 = ops.packed_kv_attention(q, kp2, vp2, ks, vs, lengths, bs=64)
    assert np.allclose(np.asarray(o1, np.float32), np.asarray(o2, np.float32))
