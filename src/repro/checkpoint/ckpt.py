"""Fault-tolerant checkpointing: atomic, sharded-aware, async, elastic.

Layout per step:
    <dir>/step_000042.tmp/...   (written first)
    <dir>/step_000042/          (atomic rename when complete)
        manifest.json           (tree structure, shapes, dtypes, step,
                                 data-iterator state, content digests)
        arr_<i>.npy             (one file per leaf, full logical array)

Restore is ELASTIC: arrays are saved as full logical values and re-laid-out
onto the *current* mesh via device_put with the requested shardings, so a
job restarted on a different pod count (e.g. 512 -> 256 chips) resumes
without conversion. Partial/corrupt checkpoints are detected via the
manifest (written last inside the tmp dir) and skipped by `latest_step`.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Optional

import jax
import ml_dtypes
import numpy as np

# numpy can't serialize bfloat16 etc. — store as a same-width integer view
# and record the logical dtype in the manifest
_VIEW_DTYPES = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
                "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
                "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8)}


def _encode(arr: np.ndarray):
    name = arr.dtype.name
    if name in _VIEW_DTYPES:
        return arr.view(_VIEW_DTYPES[name][1]), name
    return arr, name


def _decode(arr: np.ndarray, name: str) -> np.ndarray:
    if name in _VIEW_DTYPES:
        return arr.view(_VIEW_DTYPES[name][0])
    return arr


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, extra: Optional[dict] = None) -> str:
    """Atomic synchronous save. Returns the final directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    entries = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        stored, dtype_name = _encode(arr)
        fn = f"arr_{i}.npy"
        np.save(os.path.join(tmp, fn), stored, allow_pickle=False)
        entries.append({"file": fn, "shape": list(arr.shape),
                        "dtype": dtype_name,
                        "digest": hashlib.sha256(
                            stored.tobytes()[:4096]).hexdigest()[:16]})
    manifest = {"step": step, "n_leaves": len(leaves),
                "treedef": str(treedef), "entries": entries,
                "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Overlaps checkpoint I/O with training (one in flight at a time)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree, extra=None) -> None:
        # materialize on host synchronously (cheap vs I/O), write async
        leaves, treedef = _flatten(tree)
        host = [np.asarray(jax.device_get(l)) for l in leaves]
        tree_host = jax.tree.unflatten(treedef, host)
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, tree_host, extra), daemon=True)
        self._thread.start()

    def _write(self, step, tree_host, extra):
        save(self.ckpt_dir, step, tree_host, extra)
        self._gc()

    def _gc(self):
        steps = sorted(all_steps(self.ckpt_dir))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
                out.append(int(d[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like_tree, shardings=None):
    """Load into the structure of `like_tree`; reshard onto `shardings`
    (a matching tree of NamedShardings) if given — elastic restore."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(like_tree)
    assert manifest["n_leaves"] == len(leaves), "tree structure changed"
    shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                    else [None] * len(leaves))
    out = []
    for i, (leaf, shard) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(os.path.join(final, f"arr_{i}.npy"))
        arr = _decode(arr, manifest["entries"][i]["dtype"])
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.device_put(arr))
    return jax.tree.unflatten(treedef, out), manifest["extra"]
