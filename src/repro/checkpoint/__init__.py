from repro.checkpoint.ckpt import (AsyncCheckpointer, all_steps, latest_step,
                                   restore, save)

__all__ = ["AsyncCheckpointer", "all_steps", "latest_step", "restore", "save"]
