"""Continuous-batching scheduler over the unified augmented state stores.

Requests enter a FIFO queue and are admitted into the running batch
between decode steps (slot-free lifecycle: a sequence joins whenever a
row AND enough store capacity exist, and leaves the moment it finishes —
`ServeEngine.step_all` drives one scheduler pass per decode dispatch).

The scheduler is STORE-AGNOSTIC: it talks to any `state_store.StateStore`
(the paged KV pool of dense/MoE/encdec/vlm rows, the fixed-size augmented
slab pool of ssm/hybrid rows, or a composite of both) through the same
interface — can_admit_tokens / admit_row / ensure_position / release_row /
refresh_due / refresh.

Admission control asks the store whether the request's decode state could
be held *right now*, counting the headroom that augmenting cold storage
would release. Under pressure the store augments cold pages or slabs in
place — the paper's on-demand capacity — so load beyond the Normal-mode
capacity queues briefly instead of being rejected; nothing is ever
dropped.

Preemption-by-augmentation: when a RUNNING sequence grows into new
storage and even augmentation cannot free room, the engine preempts the
youngest-admitted victim — its storage returns to the store and its
request re-enters the queue *front* with prompt := prompt +
generated-so-far (deterministic greedy recompute on resume), so
preemption costs work, never tokens.

The refresh scheduler runs first in every pass: augmented storage whose
`RefreshPolicy` expired (age >= retention_steps decode steps) is
re-materialized in place or promoted back to Normal, with the traffic
accounted in `stats()` — interleaved with decode exactly like DRAM
refresh cycles steal array bandwidth.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from repro.obs import hooks as obs_hooks


@dataclasses.dataclass
class QueueEntry:
    """A queued (or re-queued) generation request."""
    req: object                  # serve.Request (id, max_new_tokens)
    prompt: np.ndarray           # effective prompt; on resume this is the
                                 # original prompt + tokens generated so far
    remaining: int               # generation budget left
    base_prompt: np.ndarray = None   # ORIGINAL prompt — the resume prompt
                                     # is always rebuilt from this + the
                                     # full output list, so repeated
                                     # preemptions never duplicate tokens
    resumed: bool = False
    enqueue_step: int = 0
    fault_retries: int = 0       # times this request was requeued by the
                                 # fault-recovery path (bounded by
                                 # cfg.amc.max_retries)
    not_before: int = 0          # earliest step this entry may be admitted
                                 # (exponential backoff after a fault retry)

    def __post_init__(self):
        if self.base_prompt is None:
            self.base_prompt = self.prompt


class Scheduler:
    def __init__(self, store, *, max_batch: int, obs=None):
        self.store = store
        self.max_batch = max_batch
        # observability facade (obs/hooks.py) — a Null no-op by default
        self.obs = obs if obs is not None else obs_hooks.NULL_OBS
        self.queue: deque[QueueEntry] = deque()
        self._admit_ticket = 0
        # per-row admission ticket: the LIFO victim order for preemption
        self.row_ticket = np.full(max_batch, -1, np.int64)
        self.stats = {
            "enqueued": 0, "requeues": 0, "admitted": 0, "preemptions": 0,
            "refresh_passes": 0, "peak_queue_depth": 0,
            "peak_concurrency": 0, "queue_wait_steps": 0,
            "fault_passes": 0,
        }

    # -- queue ---------------------------------------------------------------

    def enqueue(self, entry: QueueEntry, *, front: bool = False) -> None:
        """`front` requeues (preemption resume / admission race) — counted
        separately so `enqueued` stays the offered-request count."""
        (self.queue.appendleft if front else self.queue.append)(entry)
        self.stats["requeues" if front else "enqueued"] += 1
        self.stats["peak_queue_depth"] = max(self.stats["peak_queue_depth"],
                                             len(self.queue))
        self.obs.on_queue_depth(len(self.queue))

    def pop_admittable(self, step: int) -> Optional[QueueEntry]:
        """First eligible entry if the store could hold its decode state
        right now (counting augmentation headroom). Entries in fault-retry
        backoff (`not_before > step`) are skipped without losing their
        queue position; among ELIGIBLE entries head-of-line order is
        preserved — a big request is never starved by smaller ones
        jumping the queue."""
        for i, entry in enumerate(self.queue):
            if entry.not_before > step:
                continue            # backing off after a fault retry
            if not self.store.can_admit_tokens(max(len(entry.prompt), 1)):
                return None         # eligible head blocks (no queue-jumping)
            del self.queue[i]
            self.stats["queue_wait_steps"] += step - entry.enqueue_step
            self.obs.on_queue_depth(len(self.queue))
            return entry
        return None

    def backlog_ready(self, step: int) -> bool:
        """Whether any queued entry is out of backoff (the engine's idle
        loop must tick the clock, not raise, while everything backs off)."""
        return any(e.not_before <= step for e in self.queue)

    # -- state lifecycle ------------------------------------------------------

    def admit(self, row: int, n_tokens: int, step: int, *,
              shared=None) -> bool:
        """Reserve the row's decode state in the store; all-or-nothing.
        ``shared=(entry_row, matched_tokens)`` maps a cached prefix's
        pages into the row instead of allocating them (paged stores)."""
        if shared is not None:
            ok = self.store.admit_row(row, n_tokens, step, shared=shared)
        else:
            ok = self.store.admit_row(row, n_tokens, step)
        if not ok:
            return False
        self._admit_ticket += 1
        self.row_ticket[row] = self._admit_ticket
        self.stats["admitted"] += 1
        running = int((self.row_ticket >= 0).sum())
        self.stats["peak_concurrency"] = max(self.stats["peak_concurrency"],
                                             running)
        return True

    def ensure_position(self, row: int, pos: int, step: int) -> bool:
        """Guarantee storage for the token at `pos` exists before a decode
        writes it (paged stores grow a page at a time; slab stores are
        fixed-size and always succeed for admitted rows)."""
        return self.store.ensure_position(row, pos, step)

    def ensure_window(self, row: int, start: int, count: int,
                      step: int) -> bool:
        """`ensure_position` over a speculative window: storage for every
        position in [start, start + count) must exist before the draft
        pass writes it. Idempotent — the engine's preemption loop retries
        the whole window after evicting a victim."""
        for pos in range(start, start + count):
            if not self.store.ensure_position(row, pos, step):
                return False
        return True

    def release_row(self, row: int) -> None:
        self.store.release_row(row)
        self.row_ticket[row] = -1

    def preemption_victim(self, protect: int,
                          active: np.ndarray) -> Optional[int]:
        """Youngest-admitted active row other than `protect` (LIFO: the
        sequence with the least sunk prefill work pays for the preemption)."""
        tickets = np.where(active, self.row_ticket, -1)
        tickets[protect] = -1
        victim = int(tickets.argmax())
        return victim if tickets[victim] >= 0 else None

    # -- refresh --------------------------------------------------------------

    def refresh_pass(self, step: int) -> int:
        """Drain every expired augmented page/slab (DRAM-style refresh
        cycle, interleaved with decode). Returns units refreshed."""
        due = self.store.refresh_due(step)
        for key in due:
            self.store.refresh(key, step)
        if due:
            self.stats["refresh_passes"] += 1
        return len(due)

    # -- retention faults -----------------------------------------------------

    def fault_pass(self, step: int) -> list:
        """One inject-then-scan cycle over the store's augmented storage
        (the engine heals what this returns). Runs BEFORE refresh and
        dispatch so corrupted data is never read, refreshed or promoted."""
        injected = self.store.inject_faults(step)
        bad = self.store.scan_integrity(step)
        if injected or bad:
            self.stats["fault_passes"] += 1
        return bad

    def describe(self) -> dict:
        return {"queue_depth": len(self.queue), **self.stats}
