"""Continuous-batching scheduler over the paged augmented KV pool.

Requests enter a FIFO queue and are admitted into the running batch
between decode steps (slot-free lifecycle: a sequence joins whenever a
row AND enough pool capacity exist, and leaves the moment it finishes —
`ServeEngine.step_all` drives one scheduler pass per decode dispatch).

Admission control asks the pool whether the request's prompt could be
stored *right now*, counting the headroom that augmenting cold pages
would release (`PagedKVPool.can_admit_tokens`). Under pressure the pool
augments cold Normal pages in place — the paper's on-demand capacity —
so load beyond the Normal-mode capacity queues briefly instead of being
rejected; nothing is ever dropped.

Preemption-by-augmentation: when a RUNNING sequence grows into a new
page and even augmentation cannot free room, the engine preempts the
youngest-admitted victim — its pages return to the pool and its request
re-enters the queue *front* with prompt := prompt + generated-so-far
(deterministic greedy recompute on resume), so preemption costs work,
never tokens.

The refresh scheduler runs first in every pass: augmented pages whose
`RefreshPolicy` expired (age >= retention_steps decode steps) are
re-materialized in place or promoted back to Normal, with the traffic
accounted in `stats()` — interleaved with decode exactly like DRAM
refresh cycles steal array bandwidth.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from repro.serve.cache_pool import PagedKVPool


@dataclasses.dataclass
class QueueEntry:
    """A queued (or re-queued) generation request."""
    req: object                  # serve.Request (id, max_new_tokens)
    prompt: np.ndarray           # effective prompt; on resume this is the
                                 # original prompt + tokens generated so far
    remaining: int               # generation budget left
    base_prompt: np.ndarray = None   # ORIGINAL prompt — the resume prompt
                                     # is always rebuilt from this + the
                                     # full output list, so repeated
                                     # preemptions never duplicate tokens
    resumed: bool = False
    enqueue_step: int = 0

    def __post_init__(self):
        if self.base_prompt is None:
            self.base_prompt = self.prompt


class Scheduler:
    def __init__(self, pool: PagedKVPool, *, max_batch: int):
        self.pool = pool
        self.max_batch = max_batch
        self.queue: deque[QueueEntry] = deque()
        self._admit_ticket = 0
        # per-row admission ticket: the LIFO victim order for preemption
        self.row_ticket = np.full(max_batch, -1, np.int64)
        self.stats = {
            "enqueued": 0, "requeues": 0, "admitted": 0, "preemptions": 0,
            "refresh_passes": 0, "peak_queue_depth": 0,
            "peak_concurrency": 0, "queue_wait_steps": 0,
        }

    # -- queue ---------------------------------------------------------------

    def enqueue(self, entry: QueueEntry, *, front: bool = False) -> None:
        """`front` requeues (preemption resume / admission race) — counted
        separately so `enqueued` stays the offered-request count."""
        (self.queue.appendleft if front else self.queue.append)(entry)
        self.stats["requeues" if front else "enqueued"] += 1
        self.stats["peak_queue_depth"] = max(self.stats["peak_queue_depth"],
                                             len(self.queue))

    def pop_admittable(self, step: int) -> Optional[QueueEntry]:
        """FIFO head if the pool could hold its prompt right now (counting
        augmentation headroom); head-of-line order is preserved — a big
        request is never starved by smaller ones jumping the queue."""
        if not self.queue:
            return None
        entry = self.queue[0]
        if not self.pool.can_admit_tokens(max(len(entry.prompt), 1)):
            return None
        self.queue.popleft()
        self.stats["queue_wait_steps"] += step - entry.enqueue_step
        return entry

    # -- page lifecycle -------------------------------------------------------

    def admit(self, row: int, n_tokens: int, step: int) -> bool:
        """Allocate the prompt's pages for a fresh row; all-or-nothing."""
        pages = -(-max(n_tokens, 1) // self.pool.geom.page_size)
        done = []
        for lp in range(pages):
            if not self.pool.alloc_page(row, lp, step):
                for d in done:
                    self.pool._release(row, d)
                return False
            done.append(lp)
        self._admit_ticket += 1
        self.row_ticket[row] = self._admit_ticket
        self.stats["admitted"] += 1
        running = int((self.row_ticket >= 0).sum())
        self.stats["peak_concurrency"] = max(self.stats["peak_concurrency"],
                                             running)
        return True

    def ensure_position(self, row: int, pos: int, step: int) -> bool:
        """Guarantee the page holding `pos` exists before a decode writes
        it (sequences grow one token per step; augmentation pressure is
        applied inside the pool's allocator)."""
        lp = pos // self.pool.geom.page_size
        assert lp < self.pool.max_pages, (
            f"position {pos} past the page table ({self.pool.max_pages} "
            f"pages): the engine's max_seq done-condition should retire "
            f"rows before this")
        if self.pool.allocated[row, lp]:
            return True
        return self.pool.alloc_page(row, lp, step)

    def release_row(self, row: int) -> None:
        self.pool.free_row(row)
        self.row_ticket[row] = -1

    def preemption_victim(self, protect: int,
                          active: np.ndarray) -> Optional[int]:
        """Youngest-admitted active row other than `protect` (LIFO: the
        sequence with the least sunk prefill work pays for the preemption)."""
        tickets = np.where(active, self.row_ticket, -1)
        tickets[protect] = -1
        victim = int(tickets.argmax())
        return victim if tickets[victim] >= 0 else None

    # -- refresh --------------------------------------------------------------

    def refresh_pass(self, step: int) -> int:
        """Drain every expired augmented page (DRAM-style refresh cycle,
        interleaved with decode). Returns pages refreshed."""
        due = self.pool.refresh_due(step)
        for row, lp in due:
            self.pool.refresh_page(row, lp, step)
        if due:
            self.stats["refresh_passes"] += 1
        return len(due)

    def describe(self) -> dict:
        return {"queue_depth": len(self.queue), **self.stats}
