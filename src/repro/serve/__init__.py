from repro.serve.cache_pool import PagedKVPool
from repro.serve.engine import Request, ServeEngine
from repro.serve.fleet import ArrayFleet, make_serving
from repro.serve.placement import (ArrayView, PlacementPolicy, make_policy,
                                   partition_devices)
from repro.serve.scheduler import QueueEntry, Scheduler
from repro.serve.state_store import (AugmentedStatePool, CompositeStore,
                                     make_store)

__all__ = ["Request", "ServeEngine", "PagedKVPool", "Scheduler",
           "QueueEntry", "AugmentedStatePool", "CompositeStore",
           "make_store", "ArrayFleet", "make_serving", "ArrayView",
           "PlacementPolicy", "make_policy", "partition_devices"]
