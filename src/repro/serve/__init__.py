from repro.serve.cache_pool import PagedKVPool
from repro.serve.engine import Request, ServeEngine
from repro.serve.scheduler import QueueEntry, Scheduler

__all__ = ["Request", "ServeEngine", "PagedKVPool", "Scheduler",
           "QueueEntry"]
