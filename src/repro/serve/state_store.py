"""Unified per-family decode-state stores — every architecture's decode
state behind ONE augmented-storage interface.

The paper's array re-provisions its own capacity on demand: the same SRAM
cells hold Normal (6T, one bit per cell) or Augmented (8T/7T, multi-bit
dynamic) data. PR 3/4 modeled that for transformer KV caches only
(`cache_pool.PagedKVPool`); this module generalizes "KV cache" to ANY
per-request decode state, so ssm / hybrid / encdec / vlm rows get the same
admission control, augment-on-pressure, preemption-with-recompute, refresh
clocking and array-event accounting as dense/MoE rows.

StateStore interface (duck-typed; implemented by `PagedKVPool`,
`AugmentedStatePool` and `CompositeStore`):

  kind                      "paged" | "slab" | "composite"
  can_admit_tokens(n)       admission probe, counting augmentation headroom
  admit_row(row, n, step, *, shared=None)  all-or-nothing capacity grab
                            for a fresh row; paged pools accept
                            shared=(entry_row, m) to map a cached
                            prefix's pages by refcount instead of
                            allocating the first ceil(m/page) pages
  ensure_position(row, pos, step)  capacity for the next token write
  release_row(row)          free a finished / preempted row
  note_token_writes(rows, positions, step)  restamp written storage
  refresh_due(step) / refresh(key, step)    retention-driven maintenance
  max_augmented_age(step)   refresh-invariant probe
  state (property)          device tree, donated through the jitted step
  device_tables()           extra per-dispatch batch operands
  read/write_value_counts() array-event counts for the energy ledger
  live_bytes / budget_bytes / aug_bits / describe()

`AugmentedStatePool` is the new member: FIXED-SIZE per-row slabs (the
SSM/conv recurrent state of ssm rows, the LRU/conv/ring-window state of
hybrid rows, the static patch-KV prefix of vlm rows). A slab lives in one
of two modes:

  Normal     native dtype (bf16 / f32) rows in the ``normal`` plane
  Augmented  int8 or nibble-packed int4 rows + per-vector scales
             (``packed`` + ``scale`` planes, via `core/quant`)

against one byte budget. Under pressure the pool augments cold slabs in
place so more rows can be admitted (the same on-demand capacity the paged
pool gives KV pages). Augmented slabs are DYNAMIC storage in the paper's
sense: every decode step reads them through the "sense amp" (dequantize),
updates, and re-writes them through the "write driver" (quantize) — the
write restamps the slab's `RefreshPolicy`; a slab that goes unwritten
(a static vlm prefix) expires after `retention_steps` and the refresh
pass re-materializes or promotes it, exactly like the paged pool's pages.

Integer leaves (a hybrid row's already-packed int8 ring KV) pass through
the packed plane unchanged — they are packed storage already.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import faults as F
from repro.core import quant
from repro.core.retention import RefreshPolicy
from repro.serve.cache_pool import PagedKVPool, resolve_pool_mode


def _keystr(path) -> str:
    return jax.tree_util.keystr(path)


def _is_float(x) -> bool:
    return jnp.issubdtype(x.dtype, jnp.floating)


# ---------------------------------------------------------------------------
# pure slab-plane ops (traced inside the jitted decode step)
# ---------------------------------------------------------------------------

def _quant_leaf(x: jax.Array, bits: int):
    """Float leaf -> (packed, scale) with per-vector (last-axis) scales.
    int8 stores one value per byte; int4 nibble-packs adjacent pairs."""
    if bits == 8:
        q, s = quant.quantize_int8(x.astype(jnp.float32), axis=-1)
        return q, s.astype(jnp.bfloat16)
    q, s = quant.quantize_int4(x.astype(jnp.float32), axis=-1)
    packed = quant.pack_int4_pair(q[..., ::2], q[..., 1::2])
    return packed, s.astype(jnp.bfloat16)


def _dequant_leaf(p: jax.Array, s: jax.Array, bits: int, dtype) -> jax.Array:
    if bits == 8:
        return quant.dequantize(p, s, dtype)
    hi = quant.unpack_int4_hi(p)
    lo = quant.unpack_int4_lo(p)
    q = jnp.stack([hi, lo], axis=-1).reshape(p.shape[:-1] + (-1,))
    return quant.dequantize(q, s, dtype)


def _packed_zeros(leaf: jax.Array, bits: int):
    """(packed, scale) zero planes matching `leaf` (q=0 dequantizes to an
    exact 0.0 whatever the scale, so zeroed planes read back as zeros)."""
    if bits == 8:
        p = jnp.zeros(leaf.shape, jnp.int8)
    else:
        if leaf.shape[-1] % 2:
            raise ValueError(
                f"state_bits=4 needs an even trailing dim, got {leaf.shape}")
        p = jnp.zeros(leaf.shape[:-1] + (leaf.shape[-1] // 2,), jnp.uint8)
    s = jnp.ones(leaf.shape[:-1] + (1,), jnp.bfloat16)
    return p, s


def _mode_mask(modes: jax.Array, leaf: jax.Array) -> jax.Array:
    """(B,) slot modes -> boolean mask broadcastable over a slab leaf
    (batch axis 1): True where the slot is Augmented."""
    shape = (1, modes.shape[0]) + (1,) * (leaf.ndim - 2)
    return (modes == 1).reshape(shape)


def _row_mask(write: jax.Array, leaf: jax.Array) -> jax.Array:
    """(B,) bool write mask -> broadcastable over a slab leaf."""
    shape = (1, write.shape[0]) + (1,) * (leaf.ndim - 2)
    return write.reshape(shape)


def _quantizable(leaf: jax.Array) -> bool:
    """Whether a slab leaf takes the packed dynamic plane: float data
    with a real vector axis. Integer leaves are packed storage already,
    and trailing-dim-1 float leaves are the SCALES of such packed
    storage (quantizing a scale against itself is meaningless) — both
    pass through the normal plane untouched."""
    return _is_float(leaf) and leaf.shape[-1] > 1


def slab_reconstitute(state: dict, modes: Optional[jax.Array],
                      bits: int) -> dict:
    """Merge the two planes into the logical native-dtype cache tree the
    family decode step consumes: Normal slots read the ``normal`` plane,
    Augmented slots dequantize the ``packed`` plane (the sense-amp path).
    A single-plane state (normal-only pool) passes through untouched."""
    if "packed" not in state:
        return state["normal"]
    flat, treedef = jax.tree_util.tree_flatten_with_path(state["normal"])
    out = []
    for (path, leaf) in flat:
        key = _keystr(path)
        if key in state["packed"]:
            d = _dequant_leaf(state["packed"][key],
                              state["scale"][key], bits, leaf.dtype)
            leaf = jnp.where(_mode_mask(modes, leaf), d, leaf)
        out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def slab_store_back(state: dict, new_cache: dict,
                    modes: Optional[jax.Array], bits: int,
                    write: Optional[jax.Array] = None) -> dict:
    """Write the updated cache back into its slot's plane: Normal slots
    into the ``normal`` plane, Augmented slots quantized into ``packed``
    (the write-driver path — lossy, and the physical restamp the host
    RefreshPolicy records). Each written slot's OTHER plane is zeroed so
    no stale native master shadows an augmented slab.

    `write` is the (B,) dispatch write mask: rows NOT being written keep
    BOTH planes bit-identical — the slab form of the paged pool's
    write-masked scatter. (The legacy contiguous engine skipped this and
    let one request's prefill advance every other row's recurrent state
    with pad-token updates; the unified store isolates rows.)"""
    if "packed" not in state:
        if write is None:
            return {"normal": new_cache}
        old_flat, treedef = jax.tree_util.tree_flatten_with_path(
            state["normal"])
        new_leaves = jax.tree.leaves(new_cache)
        merged = [jnp.where(_row_mask(write, new), new, old)
                  for (_, old), new in zip(old_flat, new_leaves)]
        return {"normal": jax.tree_util.tree_unflatten(treedef, merged)}
    flat, treedef = jax.tree_util.tree_flatten_with_path(new_cache)
    old_normal = jax.tree.leaves(state["normal"])
    normal_out = []
    packed_out, scale_out = dict(state["packed"]), dict(state["scale"])
    for (path, leaf), old in zip(flat, old_normal):
        key = _keystr(path)
        w = (jnp.ones((), bool) if write is None
             else _row_mask(write, leaf))
        if key in state["packed"]:
            mask = _mode_mask(modes, leaf)        # (1, B, 1...): broadcasts
            q, s = _quant_leaf(leaf, bits)
            packed_out[key] = jnp.where(
                w & mask, q, jnp.where(w, jnp.zeros_like(q),
                                       state["packed"][key]))
            scale_out[key] = jnp.where(
                w & mask, s, jnp.where(w, jnp.ones_like(s),
                                       state["scale"][key]))
            leaf = jnp.where(mask, jnp.zeros_like(leaf), leaf)
        normal_out.append(jnp.where(w, leaf, old))
    return {"normal": jax.tree_util.tree_unflatten(treedef, normal_out),
            "packed": packed_out, "scale": scale_out}


@functools.partial(jax.jit, donate_argnums=(0,))
def _reset_row_op(state: dict, row: jax.Array) -> dict:
    """Zero one slot across every plane (admission starts from fresh
    state; recycled rows must not leak the previous request's state)."""
    def z(leaf):
        if leaf.ndim >= 2 and not leaf.shape[0] == 0:
            return leaf.at[:, row].set(jnp.zeros_like(leaf[:, row]))
        return leaf
    out = {"normal": jax.tree.map(z, state["normal"])}
    if "packed" in state:
        out["packed"] = {k: z(v) for k, v in state["packed"].items()}
        out["scale"] = {k: v.at[:, row].set(jnp.ones_like(v[:, row]))
                        for k, v in state["scale"].items()}
    return out


@functools.partial(jax.jit, static_argnames=("bits",), donate_argnums=(0,))
def _augment_row_op(state: dict, row: jax.Array, *, bits: int) -> dict:
    """Normal -> Augmented for one slot: quantize its float rows into the
    packed plane and drop the native master (the in-place WL/SL mode
    switch of the paper, at slab granularity)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(state["normal"])
    _, treedef = jax.tree.flatten(state["normal"])
    normal_out, packed, scale = [], dict(state["packed"]), \
        dict(state["scale"])
    for (path, leaf) in flat:
        key = _keystr(path)
        if key in packed:
            q, s = _quant_leaf(leaf[:, row], bits)
            packed[key] = packed[key].at[:, row].set(q)
            scale[key] = scale[key].at[:, row].set(s)
            leaf = leaf.at[:, row].set(jnp.zeros_like(leaf[:, row]))
        normal_out.append(leaf)
    return {"normal": jax.tree.unflatten(treedef, normal_out),
            "packed": packed, "scale": scale}


@functools.partial(jax.jit, static_argnames=("bits",), donate_argnums=(0,))
def _promote_row_op(state: dict, row: jax.Array, *, bits: int) -> dict:
    """Augmented -> Normal for one slot (refresh-promote)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(state["normal"])
    _, treedef = jax.tree.flatten(state["normal"])
    normal_out, packed, scale = [], dict(state["packed"]), \
        dict(state["scale"])
    for (path, leaf) in flat:
        key = _keystr(path)
        if key in packed:
            d = _dequant_leaf(packed[key][:, row], scale[key][:, row],
                              bits, leaf.dtype)
            leaf = leaf.at[:, row].set(d)
            packed[key] = packed[key].at[:, row].set(
                jnp.zeros_like(packed[key][:, row]))
        normal_out.append(leaf)
    return {"normal": jax.tree.unflatten(treedef, normal_out),
            "packed": packed, "scale": scale}


@functools.partial(jax.jit, donate_argnums=(0,))
def _corrupt_row_op(state: dict, row, mask) -> dict:
    """Retention-fault injection: XOR slot `row` of every packed plane
    with a nonzero byte `mask` (bitcast keeps it dtype-safe for uint8 and
    int8 planes). Traced scalars: repeated injections reuse one compile."""
    out = dict(state)
    m = jnp.asarray(mask, jnp.uint8)
    packed = {}
    for k, v in state["packed"].items():
        slab = v[:, row]
        b = jax.lax.bitcast_convert_type(slab, jnp.uint8)
        b = jnp.bitwise_xor(b, m)
        packed[k] = v.at[:, row].set(
            jax.lax.bitcast_convert_type(b, slab.dtype))
    out["packed"] = packed
    return out


@functools.partial(jax.jit, donate_argnums=(0,))
def _restore_row_op(state: dict, row, packed: dict, scale: dict) -> dict:
    """Scrub-on-detect: re-write slot `row`'s packed planes from masters."""
    out = dict(state)
    out["packed"] = {k: v.at[:, row].set(packed[k].astype(v.dtype))
                     for k, v in state["packed"].items()}
    out["scale"] = {k: v.at[:, row].set(scale[k].astype(v.dtype))
                    for k, v in state["scale"].items()}
    return out


# ---------------------------------------------------------------------------
# AugmentedStatePool — fixed-size per-row decode-state slabs
# ---------------------------------------------------------------------------

class AugmentedStatePool:
    """See module docstring. `specs` is the family's abstract decode-state
    tree (PSpec leaves, batch at axis 1). `static=True` marks a
    write-once prefix store (vlm patch KV): decode never rewrites it, so
    augmented slabs genuinely age and the refresh pass restamps them."""

    kind = "slab"

    def __init__(self, cfg: ModelConfig, specs, *, max_batch: int,
                 budget_bytes: Optional[int] = None,
                 retention_steps: Optional[int] = None,
                 static: bool = False, table_key: str = "slot_modes"):
        self.cfg = cfg
        self.max_batch = max_batch
        self.static = static
        self.table_key = table_key
        # "auto" pins slabs to Normal: kv_mode governs the KV CACHE (the
        # family code packs its own ring/cross KV leaves accordingly, and
        # those already-packed leaves pass through this store untouched)
        # — quantizing the accumulated recurrent state is a different,
        # lossy decision the pool_mode knob must opt into explicitly.
        if cfg.amc.pool_mode == "auto":
            self.pool_mode = "normal-only"
        else:
            self.pool_mode = resolve_pool_mode(cfg)
        self.state_bits = cfg.amc.state_bits
        if self.state_bits not in (4, 8):
            raise ValueError(f"state_bits must be 4 or 8, "
                             f"got {self.state_bits}")
        self.retention_steps = (cfg.amc.retention_steps
                                if retention_steps is None
                                else retention_steps)
        from repro.models.params import is_pspec
        normal = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.jdtype), specs, is_leaf=is_pspec)
        for leaf in jax.tree.leaves(normal):
            assert leaf.ndim >= 2 and leaf.shape[1] == max_batch, (
                "slab leaves must carry the batch at axis 1", leaf.shape)
        self._state = {"normal": normal}
        self.mixed = self.pool_mode != "normal-only"
        n_norm = n_aug = n_values = 0
        for leaf in jax.tree.leaves(normal):
            per_slot = int(np.prod(leaf.shape)) // max_batch
            per_slot_bytes = leaf.nbytes // max_batch
            n_norm += per_slot_bytes
            n_values += per_slot
            if _quantizable(leaf):
                scale_vals = per_slot // leaf.shape[-1]
                n_aug += per_slot * self.state_bits // 8 + 2 * scale_vals
            else:
                # already-packed integer leaves and their scale tensors
                n_aug += per_slot_bytes
        self.slab_bytes_normal, self.slab_bytes_aug = n_norm, n_aug
        self.values_per_slot = n_values
        if self.mixed:
            packed, scale = {}, {}
            for path, leaf in jax.tree_util.tree_flatten_with_path(
                    normal)[0]:
                if _quantizable(leaf):
                    p, s = _packed_zeros(leaf, self.state_bits)
                    packed[_keystr(path)] = p
                    scale[_keystr(path)] = s
            self._state["packed"], self._state["scale"] = packed, scale
        cheapest = n_aug if self.mixed else n_norm
        self.budget_bytes = (max_batch * n_norm if budget_bytes is None
                             else budget_bytes)
        if self.budget_bytes < cheapest:
            raise ValueError(
                f"budget_bytes={self.budget_bytes} cannot hold one slab "
                f"({cheapest} B in the pool's cheapest mode)")
        self.live_bytes = 0
        self.slot_mode = np.zeros(max_batch, np.int32)   # 0 normal, 1 aug
        self.slot_alloc = np.zeros(max_batch, bool)
        self.last_write = np.full(max_batch, -1, np.int64)
        self.policies: dict[int, RefreshPolicy] = {}
        self._tables_cache: Optional[dict] = None
        self._spec_snapshot: Optional[dict] = None
        self.stats = {
            "augment_events": 0, "promote_events": 0, "refreshes": 0,
            "refresh_bytes": 0, "augment_bytes": 0,
            "maintenance_dispatches": 0, "alloc_failures": 0,
            "peak_live_bytes": 0, "spec_snapshots": 0, "spec_rollbacks": 0,
            "faults_injected": 0, "faults_detected": 0, "faults_masked": 0,
            "refresh_misses": 0, "integrity_checks": 0, "pinned_normal": 0,
        }
        # retention-fault machinery (core/faults.py) — inert until a
        # FaultModel is attached
        self._fm: Optional[F.FaultModel] = None
        self._integrity = False
        self._fault_tag = ""
        self._words: dict[int, int] = {}       # per-slab integrity words
        self._dirty: set[int] = set()          # rewritten since last flush
        self._pending: set[int] = set()        # injected, unscanned
        self._masters: dict[int, tuple] = {}   # static-store host copies
        self._offenders: dict[str, int] = {}   # by physical unit id
        self._pin_normal = np.zeros(max_batch, bool)  # repeat offenders
        self._obs = None        # EngineObs facade (attach_obs) — optional
        self._live_by_mode = [0, 0]   # live slabs per mode, kept
        # incrementally so the per-step mode-mix sample is O(1)

    # -- byte accounting ----------------------------------------------------

    @property
    def aug_bits(self) -> int:
        return self.state_bits

    def _cost(self, mode: int) -> int:
        return self.slab_bytes_normal if mode == 0 else self.slab_bytes_aug

    def can_admit_tokens(self, n_tokens: int) -> bool:
        """Fixed-size slabs: the token count is irrelevant, the question
        is whether one more slab fits — augmenting cold Normal slabs if
        the policy allows (the on-demand capacity probe)."""
        free_b = self.budget_bytes - self.live_bytes
        if self.pool_mode == "normal-only":
            return self._cost(0) <= free_b
        if (self.pool_mode == "augment-on-pressure"
                and self._cost(0) <= free_b):
            return True
        need = self._cost(1) - free_b
        if need <= 0:
            return True
        if self.pool_mode != "augment-on-pressure":
            return False
        per = self._cost(0) - self._cost(1)
        n_norm = int((self.slot_alloc & (self.slot_mode == 0)).sum())
        return -(-need // per) <= n_norm

    # -- allocation ---------------------------------------------------------

    def admit_row(self, row: int, n_tokens: int, step: int, *,
                  shared=None) -> bool:
        # `shared` (prefix page reuse) is a paged-pool concept; slab
        # state has no pages to alias — accepted and ignored
        assert not self.slot_alloc[row], row
        order = {"normal-only": (0,), "always-augmented": (1,),
                 "augment-on-pressure": (0, 1)}[self.pool_mode]
        if self._pin_normal[row] and self.pool_mode != "normal-only":
            # repeat-offender slot: its dynamic cells misbehave, so prefer
            # the static plane whenever the budget allows
            order = (0,) + tuple(m for m in order if m != 0)
        mode = None
        for m in order:
            if self.live_bytes + self._cost(m) <= self.budget_bytes:
                mode = m
                break
        if mode is None and self.pool_mode == "augment-on-pressure":
            while self.live_bytes + self._cost(1) > self.budget_bytes:
                if not self._augment_coldest(step):
                    self.stats["alloc_failures"] += 1
                    return False
            mode = 1
        if mode is None:
            self.stats["alloc_failures"] += 1
            return False
        self.slot_alloc[row] = True
        self.slot_mode[row] = mode
        self.last_write[row] = step
        self.live_bytes += self._cost(mode)
        self._live_by_mode[mode] += 1
        self.stats["peak_live_bytes"] = max(self.stats["peak_live_bytes"],
                                            self.live_bytes)
        if mode == 1:
            pol = RefreshPolicy(retention_steps=self.retention_steps)
            pol.stamp(step)
            self.policies[row] = pol
            if self._fm is not None:
                self._dirty.add(row)
        self._state = _reset_row_op(self._state, row)
        self.stats["maintenance_dispatches"] += 1
        self._tables_cache = None
        return True

    def ensure_position(self, row: int, pos: int, step: int) -> bool:
        """Slabs are fixed-size: an admitted row always has room."""
        return bool(self.slot_alloc[row])

    def max_row_tokens(self) -> Optional[int]:
        """Fixed-size slabs hold a row's whole recurrent state whatever
        its length: no per-row token capacity bound."""
        return None

    def release_row(self, row: int) -> None:
        if not self.slot_alloc[row]:
            return
        if row in self._pending:
            # the corruption evaporated with the row's state before any
            # scan reached it
            self._pending.discard(row)
            self.stats["faults_masked"] += 1
        self._words.pop(row, None)
        self._masters.pop(row, None)
        self._dirty.discard(row)
        self.live_bytes -= self._cost(int(self.slot_mode[row]))
        self._live_by_mode[int(self.slot_mode[row])] -= 1
        self.slot_alloc[row] = False
        self.slot_mode[row] = 0
        self.last_write[row] = -1
        self.policies.pop(row, None)
        self._tables_cache = None

    # -- mode switching -------------------------------------------------------

    def _coldest_normal(self) -> Optional[int]:
        cand = self.slot_alloc & (self.slot_mode == 0) & ~self._pin_normal
        if not cand.any():
            return None
        age = np.where(cand, self.last_write, np.iinfo(np.int64).max)
        return int(age.argmin())

    def _augment_coldest(self, step: int) -> bool:
        row = self._coldest_normal()
        if row is None or not self.mixed:
            return False
        self.augment_slot(row, step)
        return True

    def augment_slot(self, row: int, step: int) -> None:
        """Normal -> Augmented in place: quantize the slab into the packed
        plane, release the byte difference back to the budget. The native
        master is gone — the slab is dynamic data on the retention clock."""
        assert self.mixed and self.slot_alloc[row] \
            and self.slot_mode[row] == 0
        self._state = _augment_row_op(self._state, row,
                                      bits=self.state_bits)
        self.stats["maintenance_dispatches"] += 1
        self.slot_mode[row] = 1
        self.live_bytes -= self._cost(0) - self._cost(1)
        self._live_by_mode[0] -= 1
        self._live_by_mode[1] += 1
        pol = RefreshPolicy(retention_steps=self.retention_steps)
        pol.stamp(step)
        self.policies[row] = pol
        if self._fm is not None:
            self._dirty.add(row)
        self.stats["augment_events"] += 1
        self.stats["augment_bytes"] += self._cost(0) + self._cost(1)
        self._tables_cache = None
        if self._obs is not None:
            self._obs.store_event("augment", f"slab{row}", step)

    def promote_slot(self, row: int, step: int) -> bool:
        """Augmented -> Normal (refresh-promote) when the budget has room."""
        assert self.slot_alloc[row] and self.slot_mode[row] == 1
        if row in self._pending:
            # never materialize a corrupted packed slab into the static
            # plane — the fault pass must detect and heal it first
            return False
        cost_up = self._cost(0) - self._cost(1)
        if self.live_bytes + cost_up > self.budget_bytes:
            return False
        self._state = _promote_row_op(self._state, row,
                                      bits=self.state_bits)
        self.stats["maintenance_dispatches"] += 1
        self.slot_mode[row] = 0
        self.live_bytes += cost_up
        self._live_by_mode[1] -= 1
        self._live_by_mode[0] += 1
        self.last_write[row] = step
        self.policies.pop(row, None)
        self._words.pop(row, None)
        self._masters.pop(row, None)
        self._dirty.discard(row)
        self.stats["promote_events"] += 1
        self._tables_cache = None
        if self._obs is not None:
            self._obs.store_event("promote", f"slab{row}", step)
        return True

    # -- retention / refresh --------------------------------------------------

    def note_token_writes(self, rows: np.ndarray, positions: np.ndarray,
                          step: int) -> None:
        """Decode rewrote these rows' slabs through the write driver:
        restamp coldness and (augmented rows) the retention clock."""
        if self.static:
            return                      # decode never writes a prefix slab
        for row in np.asarray(rows).ravel():
            row = int(row)
            if not self.slot_alloc[row]:
                continue
            self.last_write[row] = step
            pol = self.policies.get(row)
            if pol is not None:
                pol.stamp(step)
                if self._fm is not None:
                    self._dirty.add(row)

    def refresh_due(self, step: int) -> list[int]:
        return [row for row, pol in self.policies.items()
                if pol.needs_refresh(step)]

    def refresh(self, row: int, step: int) -> None:
        """Refresh one expired augmented slab: promote back to Normal when
        allowed and affordable, else restamp in place (re-write the packed
        rows) and account the traffic."""
        pol = self.policies.get(row)
        if pol is None:
            return
        if (self._fm is not None
                and self._fm.refresh_miss(self._unit_id(row), step)):
            # the refresh pulse itself failed: the slab keeps aging toward
            # certain fault — inject/scan will catch what decays
            self.stats["refresh_misses"] += 1
            return
        if self.pool_mode == "augment-on-pressure" \
                and self.cfg.amc.refresh_promote \
                and self.promote_slot(row, step):
            self.stats["refreshes"] += 1
            self.stats["refresh_bytes"] += self._cost(1) + self._cost(0)
            return
        pol.stamp(step)
        self.stats["refreshes"] += 1
        self.stats["refresh_bytes"] += 2 * self._cost(1)   # read + re-write
        self.last_write[row] = step
        if self._obs is not None:
            self._obs.store_event("restamp", f"slab{row}", step)

    def max_augmented_age(self, step: int) -> int:
        return max((pol.age(step) for pol in self.policies.values()),
                   default=0)

    # -- observability ----------------------------------------------------------

    def attach_obs(self, obs) -> None:
        """Wire the engine's observability facade: mode transitions and
        fault injections emit refresh/fault-lane events from here."""
        self._obs = obs

    def mode_mix(self) -> tuple[int, int]:
        """(live Normal slabs, live Augmented slabs) — one sample of the
        paper's 6T/8T+ mode-mix timeline. O(1): incremental counters,
        sampled every engine step (describe() recomputes the same pair
        by reduction as the ground-truth cross-check)."""
        return self._live_by_mode[0], self._live_by_mode[1]

    # -- retention-fault injection / detection / healing ------------------------
    # (core/faults.py FaultModel; mirrors PagedKVPool's page-level
    # machinery at slab granularity. A slab's physical unit IS its slot —
    # rows never migrate between arrays — so offender tracking keys on
    # the row index.)

    def attach_fault_model(self, fm: F.FaultModel, *, integrity: bool = True,
                           tag: str = "") -> None:
        self._fm = fm
        self._integrity = integrity
        self._fault_tag = tag
        self._dirty.update(self.policies.keys())

    def _unit_id(self, row: int) -> str:
        return f"{self._fault_tag}slab{row}"

    def _packed_keys(self) -> list[str]:
        return sorted(self._state.get("packed", {}))

    def _unit_payload_np(self, row: int) -> tuple:
        ps = []
        for key in self._packed_keys():
            ps.append(np.asarray(self._state["packed"][key][:, row]))
            ps.append(np.asarray(self._state["scale"][key][:, row]))
        return tuple(ps)

    def _unit_word(self, row: int) -> int:
        return F.integrity_word(*self._unit_payload_np(row))

    def _flush_integrity(self) -> None:
        """Bring integrity words up to date for every augmented slab that
        was (re)written since the last flush. Static stores (write-once
        vlm prefix) also stash a host master copy — the scrub source."""
        for row in self.policies:
            if row in self._words and row not in self._dirty:
                continue
            payload = self._unit_payload_np(row)
            self._words[row] = F.integrity_word(*payload)
            if self.static:
                self._masters[row] = payload
        self._dirty.clear()

    def inject_faults(self, step: int) -> int:
        """Sample retention faults for every live augmented slab and
        corrupt the packed planes on device (deterministic under seed)."""
        if self._fm is None or not self.mixed:
            return 0
        self._flush_integrity()
        n = 0
        for row, pol in list(self.policies.items()):
            if row in self._pending:
                continue
            uid = self._unit_id(row)
            if self._fm.fault(uid, step, pol.age(step), self.retention_steps):
                mask = self._fm.corruption_mask(uid, step)
                self._state = _corrupt_row_op(self._state, row, mask)
                self._pending.add(row)
                self.stats["faults_injected"] += 1
                if self._obs is not None:
                    self._obs.on_fault("inject", uid, step)
                n += 1
        return n

    def scan_integrity(self, step: int) -> list[int]:
        """Verify every augmented slab against its stored integrity word;
        return the corrupted rows (detected, never silently served)."""
        if self._fm is None or not self._integrity:
            return []
        self._flush_integrity()
        bad: list[int] = []
        for row, word in list(self._words.items()):
            self.stats["integrity_checks"] += 1
            if self._unit_word(row) == word:
                continue
            bad.append(row)
            self._pending.discard(row)
            self.stats["faults_detected"] += 1
            uid = self._unit_id(row)
            self._offenders[uid] = self._offenders.get(uid, 0) + 1
            if (self._offenders[uid] >= self._fm.pin_threshold
                    and not self._pin_normal[row]):
                self._pin_normal[row] = True
                self.stats["pinned_normal"] += 1
        return bad

    def scrub_from_master(self, row: int) -> bool:
        """Heal a detected-corrupt slab from the host master copy (static
        stores only — dynamic slabs must be recomputed). Repeat-offender
        rows are pinned back to the Normal plane when the budget allows."""
        master = self._masters.get(row)
        if master is None:
            return False
        keys = self._packed_keys()
        packed = {k: jnp.asarray(master[2 * i])
                  for i, k in enumerate(keys)}
        scale = {k: jnp.asarray(master[2 * i + 1])
                 for i, k in enumerate(keys)}
        self._state = _restore_row_op(self._state, row, packed, scale)
        self.stats["maintenance_dispatches"] += 1
        self._words[row] = F.integrity_word(*master)
        self._dirty.discard(row)
        if self._pin_normal[row]:
            self.promote_slot(row, step=0)
        return True

    def fault_row(self, row: int) -> Optional[int]:
        return row

    def fault_unit_bytes(self, row: int) -> int:
        return self.slab_bytes_aug

    def fault_counters(self) -> dict:
        return {k: self.stats[k] for k in
                ("faults_injected", "faults_detected", "faults_masked",
                 "refresh_misses", "integrity_checks", "pinned_normal")}

    def faults_pending(self) -> int:
        return len(self._pending)

    # -- speculative decode: slab snapshot / rollback --------------------------

    def speculative_snapshot(self) -> None:
        """Pin the pre-draft slab planes. The draft pass advances the real
        recurrent state, so the engine dispatches drafts through a
        NON-donating step (these buffers stay valid) and the verify scan
        replays the window from this exact tree — rejected draft steps
        never touch committed storage."""
        self._spec_snapshot = self._state
        self.stats["spec_snapshots"] += 1

    def speculative_restore(self) -> None:
        """Roll the slab planes back to the pre-draft snapshot (always
        called before verify: verify itself re-runs the accepted steps)."""
        assert self._spec_snapshot is not None, "no speculative snapshot"
        self._state = self._spec_snapshot
        self._spec_snapshot = None
        self.stats["spec_rollbacks"] += 1

    def retract_token_writes(self, rows: np.ndarray,
                             new_lengths: np.ndarray) -> int:
        """Slab rollback is wholesale (snapshot/restore above): there is
        no per-token storage to retract."""
        return 0

    # -- device views ---------------------------------------------------------

    @property
    def state(self):
        return self._state

    @state.setter
    def state(self, new) -> None:
        self._state = new

    def device_tables(self) -> dict:
        if not self.mixed:
            return {}
        if self._tables_cache is None:
            self._tables_cache = {
                self.table_key: jnp.asarray(self.slot_mode)}
        return self._tables_cache

    # -- array event accounting ------------------------------------------------

    def _value_counts(self, rows: np.ndarray) -> tuple[int, int]:
        if rows.size == 0:
            return 0, 0
        modes = self.slot_mode[rows]
        alive = self.slot_alloc[rows]
        v = self.values_per_slot
        return (int((alive & (modes == 0)).sum()) * v,
                int((alive & (modes == 1)).sum()) * v)

    def read_value_counts(self, rows: np.ndarray,
                          lengths: np.ndarray) -> tuple[int, int]:
        """Every dispatch senses each active row's whole slab once."""
        return self._value_counts(rows)

    def write_value_counts(self, rows: np.ndarray, n_new: int,
                           write_starts: np.ndarray) -> tuple[int, int]:
        """...and (non-static stores) re-writes it once."""
        if self.static:
            return 0, 0
        return self._value_counts(rows)

    def physical_bytes(self) -> int:
        """Staged plane capacity (both planes when mode-mixing is on —
        the slab analogue of the pool's two arenas)."""
        phys = self.max_batch * self.slab_bytes_normal
        if self.mixed:
            phys += self.max_batch * self.slab_bytes_aug
        return phys

    def describe(self) -> dict:
        live_n = int((self.slot_alloc & (self.slot_mode == 0)).sum())
        live_a = int((self.slot_alloc & (self.slot_mode == 1)).sum())
        return {
            "kind": self.kind,
            "pool_mode": self.pool_mode,
            "static": self.static,
            "state_bits": self.state_bits,
            "slab_bytes_normal": self.slab_bytes_normal,
            "slab_bytes_aug": self.slab_bytes_aug,
            "slab_capacity_factor": (self.slab_bytes_normal
                                     / self.slab_bytes_aug),
            "slabs_live_normal": live_n,
            "slabs_live_augmented": live_a,
            "budget_bytes": self.budget_bytes,
            "live_bytes": self.live_bytes,
            "retention_steps": self.retention_steps,
            **self.stats,
        }


# ---------------------------------------------------------------------------
# CompositeStore — one row spans several stores (vlm: paged KV + prefix)
# ---------------------------------------------------------------------------

class CompositeStore:
    """Fans the StateStore interface out over named parts; a row is
    admitted into ALL parts or none. `state` is {part_name: part_state};
    refresh keys are (part_name, part_key)."""

    kind = "composite"

    def __init__(self, parts: dict):
        self.parts = parts

    def can_admit_tokens(self, n: int) -> bool:
        return all(p.can_admit_tokens(n) for p in self.parts.values())

    def admit_row(self, row: int, n_tokens: int, step: int, *,
                  shared=None) -> bool:
        done = []
        for name, p in self.parts.items():
            if not p.admit_row(row, n_tokens, step):
                for d in done:
                    d.release_row(row)
                return False
            done.append(p)
        return True

    def ensure_position(self, row: int, pos: int, step: int) -> bool:
        return all(p.ensure_position(row, pos, step)
                   for p in self.parts.values())

    def max_row_tokens(self) -> Optional[int]:
        caps = [c for c in (p.max_row_tokens()
                            for p in self.parts.values()) if c is not None]
        return min(caps) if caps else None

    def release_row(self, row: int) -> None:
        for p in self.parts.values():
            p.release_row(row)

    def note_token_writes(self, rows, positions, step) -> None:
        for p in self.parts.values():
            p.note_token_writes(rows, positions, step)

    def refresh_due(self, step: int) -> list:
        return [(name, key) for name, p in self.parts.items()
                for key in p.refresh_due(step)]

    def refresh(self, key, step: int) -> None:
        name, part_key = key
        self.parts[name].refresh(part_key, step)

    def max_augmented_age(self, step: int) -> int:
        return max(p.max_augmented_age(step) for p in self.parts.values())

    def attach_obs(self, obs) -> None:
        for p in self.parts.values():
            p.attach_obs(obs)

    def mode_mix(self) -> tuple[int, int]:
        return self._sum_counts(lambda p: p.mode_mix())

    # -- retention faults: fan out, part-qualified keys -------------------------

    def attach_fault_model(self, fm, *, integrity: bool = True,
                           tag: str = "") -> None:
        for name, p in self.parts.items():
            p.attach_fault_model(fm, integrity=integrity,
                                 tag=f"{tag}{name}:")

    def inject_faults(self, step: int) -> int:
        return sum(p.inject_faults(step) for p in self.parts.values())

    def scan_integrity(self, step: int) -> list:
        return [(name, key) for name, p in self.parts.items()
                for key in p.scan_integrity(step)]

    def scrub_from_master(self, key) -> bool:
        name, part_key = key
        return self.parts[name].scrub_from_master(part_key)

    def fault_row(self, key) -> Optional[int]:
        name, part_key = key
        return self.parts[name].fault_row(part_key)

    def fault_unit_bytes(self, key) -> int:
        name, part_key = key
        return self.parts[name].fault_unit_bytes(part_key)

    def fault_counters(self) -> dict:
        out: dict = {}
        for p in self.parts.values():
            for k, v in p.fault_counters().items():
                out[k] = out.get(k, 0) + v
        return out

    def faults_pending(self) -> int:
        return sum(p.faults_pending() for p in self.parts.values())

    @property
    def state(self):
        return {name: p.state for name, p in self.parts.items()}

    @state.setter
    def state(self, new) -> None:
        for name, p in self.parts.items():
            p.state = new[name]

    def device_tables(self) -> dict:
        out = {}
        for p in self.parts.values():
            out.update(p.device_tables())
        return out

    @property
    def live_bytes(self) -> int:
        return sum(p.live_bytes for p in self.parts.values())

    @property
    def budget_bytes(self) -> int:
        return sum(p.budget_bytes for p in self.parts.values())

    @property
    def aug_bits(self) -> int:
        return next(iter(self.parts.values())).aug_bits

    def _sum_counts(self, fn) -> tuple[int, int]:
        n = a = 0
        for p in self.parts.values():
            pn, pa = fn(p)
            n, a = n + pn, a + pa
        return n, a

    def read_value_counts(self, rows, lengths):
        return self._sum_counts(
            lambda p: p.read_value_counts(rows, lengths))

    def write_value_counts(self, rows, n_new, starts):
        return self._sum_counts(
            lambda p: p.write_value_counts(rows, n_new, starts))

    def physical_bytes(self) -> int:
        return sum(p.physical_bytes() for p in self.parts.values())

    def describe(self) -> dict:
        parts = {name: p.describe() for name, p in self.parts.items()}
        agg = {"kind": self.kind, "parts": parts,
               "budget_bytes": self.budget_bytes,
               "live_bytes": self.live_bytes}
        for k in ("refreshes", "refresh_bytes", "augment_events",
                  "promote_events", "maintenance_dispatches",
                  "alloc_failures", "peak_live_bytes", "augment_bytes",
                  "faults_injected", "faults_detected", "faults_masked",
                  "refresh_misses", "integrity_checks", "pinned_normal",
                  "pages_decommissioned"):
            agg[k] = sum(d.get(k, 0) for d in parts.values())
        return agg


# ---------------------------------------------------------------------------
# store registry + per-family step builders
# ---------------------------------------------------------------------------

def make_store(cfg: ModelConfig, *, max_batch: int, max_seq: int,
               budget_bytes: Optional[int] = None,
               pages_normal: Optional[int] = None,
               pages_packed: Optional[int] = None,
               retention_steps: Optional[int] = None):
    """The per-family store registry: every architecture's decode state
    maps onto paged KV pages, fixed-size augmented slabs, or both."""
    fam = cfg.family
    if fam in ("dense", "moe"):
        return PagedKVPool(cfg, max_batch=max_batch, max_seq=max_seq,
                           pages_normal=pages_normal,
                           pages_packed=pages_packed,
                           budget_bytes=budget_bytes,
                           retention_steps=retention_steps)
    if fam == "audio":
        # decoder self-KV pages + the cross-attention KV as a STATIC
        # prefix band of the same pool (the paper's static plane)
        return PagedKVPool(cfg, max_batch=max_batch, max_seq=max_seq,
                           pages_normal=pages_normal,
                           pages_packed=pages_packed,
                           budget_bytes=budget_bytes,
                           retention_steps=retention_steps,
                           prefix_tokens=cfg.encdec.n_frames)
    if fam == "vlm":
        from repro.models import vision
        nb = vision._n_blocks(cfg)
        pool_kw = dict(max_batch=max_batch, max_seq=max_seq,
                       pages_normal=pages_normal,
                       pages_packed=pages_packed,
                       retention_steps=retention_steps,
                       n_layers=nb * vision.N_SELF_PER_BLOCK)
        pool = PagedKVPool(cfg, budget_bytes=None, **pool_kw)
        prefix = AugmentedStatePool(
            cfg, vision.prefix_state_specs(cfg, max_batch),
            max_batch=max_batch, retention_steps=retention_steps,
            static=True, table_key="prefix_modes")
        if budget_bytes is not None:
            # ONE operator budget spans both parts: split proportionally
            # to their default (full-capacity) shares so stats() reports
            # exactly the requested total and prefix admission is bound
            # by it too
            total_default = pool.budget_bytes + prefix.budget_bytes
            kv_share = budget_bytes * pool.budget_bytes // total_default
            pool.budget_bytes = kv_share
            prefix.budget_bytes = budget_bytes - kv_share
        return CompositeStore({"kv": pool, "prefix": prefix})
    if fam in ("ssm", "hybrid"):
        from repro.models import model as M
        from repro.configs.base import ShapeConfig
        shape = ShapeConfig("serve", max_seq, max_batch, "decode")
        return AugmentedStatePool(cfg, M.abstract_cache(cfg, shape),
                                  max_batch=max_batch,
                                  budget_bytes=budget_bytes,
                                  retention_steps=retention_steps)
    raise ValueError(f"no decode-state store for family {fam!r}")


def make_step_fns(cfg: ModelConfig, store, *,
                  rules=None) -> dict[str, Optional[Callable]]:
    """(decode, prefill, verify) callables for `jax.jit` over (params,
    state, batch) — the ONE place the store kind meets the family
    dispatch. ``verify`` is the speculative-decode verify step (None for
    families without one: the engine falls back to stepwise decode)."""
    from repro.models import model as M
    fam = cfg.family

    if fam in ("dense", "moe", "audio"):
        return {
            "decode": lambda p, s, b: M.paged_decode_step(cfg, p, s, b,
                                                          rules=rules),
            "prefill": (lambda p, s, b: M.paged_prefill_step(cfg, p, s, b,
                                                             rules=rules))
            if fam != "audio" else None,
            "verify": (lambda p, s, b: M.paged_verify_step(cfg, p, s, b,
                                                           rules=rules))
            if fam != "audio" else None,
        }
    if fam == "vlm":
        prefix_bits = store.parts["prefix"].state_bits

        def vlm_decode(params, state, batch):
            prefix = slab_reconstitute(state["prefix"],
                                       batch.get("prefix_modes"),
                                       prefix_bits)
            logits, new_kv = M.paged_decode_step(
                cfg, params, state["kv"], {**batch, **prefix}, rules=rules)
            return logits, {"kv": new_kv, "prefix": state["prefix"]}
        return {"decode": vlm_decode, "prefill": None, "verify": None}

    # slab families (ssm / hybrid): reconstitute -> family step -> store
    bits = store.state_bits

    def slab_decode(params, state, batch):
        cache = slab_reconstitute(state, batch.get("slot_modes"), bits)
        logits, new_cache = M.decode_step(cfg, params, cache, batch,
                                          rules=rules)
        return logits, slab_store_back(state, new_cache,
                                       batch.get("slot_modes"), bits,
                                       write=batch.get("write_mask"))

    def slab_verify(params, state, batch):
        """Speculative verify for recurrent-state families: replay the
        W-token window as a `lax.scan` of the SAME single-token decode
        step from the pre-draft slab state (the engine restored it),
        then commit exactly the state after the accepted prefix.

        Each scan step is bit-identical to one stepwise dispatch (same
        function, same single-token shapes), which is what makes slab
        speculation token-identical; the wholesale restore + re-scan IS
        the rollback — rejected draft steps live only in intermediate
        scan carries that are never stored back."""
        tokens = batch["tokens"]                        # (B, W)
        starts = batch["positions"]                     # (B,)
        wmask = batch["write_mask"]                     # (B, W) bool
        modes = batch.get("slot_modes")
        B, W = tokens.shape
        cache0 = slab_reconstitute(state, modes, bits)

        def body(cache, w):
            step_batch = {
                "tokens": jax.lax.dynamic_slice_in_dim(tokens, w, 1, 1),
                "positions": starts + w}
            lg, new_cache = M.decode_step(cfg, params, cache, step_batch,
                                          rules=rules)
            return new_cache, (lg[:, -1], new_cache)

        _, (lgs, caches) = jax.lax.scan(body, cache0, jnp.arange(W))
        logits = jnp.moveaxis(lgs, 0, 1)                # (B, W, V)

        # greedy acceptance (same formula as the paged verify step);
        # capped by the host's per-row window mask near retirement
        v = jnp.argmax(logits, axis=-1).astype(tokens.dtype)
        mism = jnp.concatenate([tokens[:, 1:] != v[:, :-1],
                                jnp.ones((B, 1), bool)], axis=1)
        n_acc = jnp.argmax(mism, axis=1) + 1            # (B,) in [1, W]
        cap = jnp.maximum(wmask.sum(axis=1), 1)
        sel = jnp.minimum(n_acc, cap) - 1               # committed step idx

        def pick(leaf):
            # stacked scan ys: (W, L?, B, ...) with batch at axis 2
            idx = sel.reshape((1, 1, -1) + (1,) * (leaf.ndim - 3))
            return jnp.take_along_axis(leaf, idx.astype(jnp.int32),
                                       axis=0)[0]

        committed = jax.tree.map(pick, caches)
        return logits, slab_store_back(state, committed, modes, bits,
                                       write=wmask[:, 0])

    return {"decode": slab_decode, "prefill": None, "verify": slab_verify}
