"""ArrayFleet — multi-array sharded serving over a jax device mesh.

The paper's capacity unit is one SRAM array; everything below `ArrayFleet`
serves exactly one. The fleet instantiates `num_arrays` logical arrays —
one full `ServeEngine` each, so every array owns its OWN byte budget,
state store (paged KV pool and/or slab pool), refresh clock (`step_idx`),
fault domain (FaultModel + per-array Supervisor) and energy/IMC ledger —
over a partition of the jax mesh (serve/placement.py): contiguous device
groups when devices >= arrays, with each array's projections sharded
tensor-parallel over its own "model" axis by the distributed/sharding
Rules (replicated where head counts don't divide); round-robin device
sharing otherwise (the `jax.sharding`-over-host case — on one CPU device
every logical array shares it).

Placement: a fleet-level `PlacementPolicy` (least-loaded /
budget-headroom / affinity) admits each request onto one array. Between
decode rounds the fleet *migrates* queued work off pressured arrays onto
arrays that can admit it right now (`ServeEngine.adopt_request` seeds
the target's output map so later preemption-recompute stays
token-identical), and a fleet-level Supervisor drains a LOST array onto
the survivors — preserving `fault_retries` budgets, because losing an
array is never the request's fault.

Token identity: all arrays decode the same weights (one dense tree,
packed identically per array) through the same kernels, and per-request
decode is batch-composition invariant, so fleet-mode outputs are
token-identical to single-array serving — `tests/test_fleet.py` pins
this for dense, moe and ssm.

Observability: per-array `EngineObs` facades share ONE trace epoch and
ONE metrics registry; each array records on its own trace pid ("array
N" process lanes in perfetto), placement/migration/drain decisions land
as instants on the target array's scheduler lane, and `export_trace`
merges everything into a single schema-valid Chrome trace.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.fault import SimulatedFailure, Supervisor
from repro.launch.mesh import make_local_mesh, mesh_context
from repro.models import model as M
from repro.models.params import init_params
from repro.obs import hooks as obs_hooks
from repro.obs.metrics import MetricsRegistry
from repro.serve.engine import Request, ServeEngine
from repro.serve.placement import (ArrayView, make_array_meshes,
                                   make_policy)


class ArrayFleet:
    def __init__(self, cfg: ModelConfig, mesh=None, *,
                 num_arrays: Optional[int] = None,
                 placement: Optional[str] = None,
                 params=None, seed: int = 0,
                 trace: Optional[bool] = None,
                 metrics: Optional[bool] = None,
                 obs_sample_every: Optional[int] = None,
                 fault_seed: Optional[int] = None,
                 **engine_kwargs):
        n = num_arrays if num_arrays is not None else cfg.amc.num_arrays
        if n < 1:
            raise ValueError(f"num_arrays must be >= 1, got {n}")
        self.cfg = cfg
        self.num_arrays = n
        self.policy = make_policy(placement if placement is not None
                                  else cfg.amc.placement)
        self.meshes = make_array_meshes(n, mesh)
        # one dense weight tree, initialized once: every array packs the
        # SAME weights (augment_params is deterministic), which is what
        # makes fleet decode token-identical to single-array decode
        dense_cfg = dataclasses.replace(
            cfg, amc=dataclasses.replace(cfg.amc, weight_mode="normal"))
        if params is None:
            with mesh_context(self.meshes[0]):
                params = init_params(M.abstract_params(dense_cfg),
                                     jax.random.PRNGKey(seed))
        # obs: per-array facades on one shared clock epoch + one shared
        # metrics registry, each tracing on its own pid ("array N" lane)
        trace_on = cfg.amc.trace if trace is None else trace
        metrics_on = cfg.amc.metrics if metrics is None else metrics
        sample_every = (cfg.amc.obs_sample_every if obs_sample_every is None
                        else obs_sample_every)
        self._obs_on = bool(trace_on or metrics_on)
        epoch = time.perf_counter()
        registry = MetricsRegistry() if self._obs_on else None
        base_fault_seed = (cfg.amc.fault_seed if fault_seed is None
                           else fault_seed)
        self.engines: list[ServeEngine] = []
        for aid in range(n):
            obs = None
            if self._obs_on:
                obs = obs_hooks.EngineObs(
                    trace=trace_on, metrics=metrics_on,
                    sample_every=sample_every, pid=aid,
                    process=f"array {aid}", epoch=epoch, registry=registry)
            self.engines.append(ServeEngine(
                cfg, self.meshes[aid], params=params, seed=seed,
                trace=trace, metrics=metrics,
                obs_sample_every=obs_sample_every,
                # de-correlate the per-array fault schedules: each array
                # is its own fault domain, not a mirror of array 0
                fault_seed=base_fault_seed + aid,
                obs=obs, **engine_kwargs))
        self.placements: dict[int, int] = {}     # request id -> array id
        self._dead: set[int] = set()
        self._pending_loss: set[int] = set()
        # lost arrays drain through the SAME Supervisor machinery the
        # single-array engine uses for intra-array loss
        self.supervisor = Supervisor(self._drain_lost_arrays,
                                     max_restarts=max(64, 4 * n))
        self.step_count = 0
        self._fleet_stats = {
            "placements": 0, "migrations": 0, "array_losses": 0,
            "drain_requeues": 0, "peak_concurrency": 0,
        }
        # decision-reason histogram (affinity distinguishes prefix / hash
        # / fallback via `last_reason`; other policies count their name)
        self._placement_decisions: dict[str, int] = {}

    # -- request intake ---------------------------------------------------------

    def _alive_ids(self) -> list[int]:
        return [i for i in range(self.num_arrays) if i not in self._dead]

    def _views(self) -> list[ArrayView]:
        return [ArrayView(aid=i, alive=i not in self._dead,
                          running=int(e.active.sum()),
                          queued=len(e.scheduler.queue),
                          free_rows=int((~e.active).sum()),
                          live_bytes=int(e.store.live_bytes),
                          budget_bytes=int(e.store.budget_bytes),
                          admit_probe=e.store.can_admit_tokens,
                          prefix_probe=e.prefix_probe)
                for i, e in enumerate(self.engines)]

    def add_request(self, req: Request) -> int:
        """Place `req` on one array (policy decision) and enqueue it
        there. Returns the array id; like the single-array engine, the
        request is admitted immediately when a row + capacity exist and
        queues otherwise — never dropped."""
        if req.id in self.placements:
            raise ValueError(
                f"request id {req.id} already placed on array "
                f"{self.placements[req.id]} — ids are fleet-unique")
        aid = self.policy.place(np.asarray(req.prompt), self._views())
        eng = self.engines[aid]
        eng.add_request(req)          # validates; may admit immediately
        self.placements[req.id] = aid
        self._fleet_stats["placements"] += 1
        reason = getattr(self.policy, "last_reason", self.policy.name)
        self._placement_decisions[reason] = \
            self._placement_decisions.get(reason, 0) + 1
        eng.obs.on_placement(req.id, aid, self.policy.name, "admit",
                             eng.step_idx)
        self._note_concurrency()
        return aid

    # -- fleet stepping ---------------------------------------------------------

    def _note_concurrency(self) -> None:
        running = sum(int(self.engines[i].active.sum())
                      for i in self._alive_ids())
        if running > self._fleet_stats["peak_concurrency"]:
            self._fleet_stats["peak_concurrency"] = running

    def step_all(self) -> dict:
        """One fleet round: drain any lost array onto survivors, admit +
        decode one step on every array with work, then rebalance queued
        work across arrays. Returns {(array_id, row): next_token}."""
        if self._pending_loss:
            self.supervisor.run_step(self._fleet_health_check)
        out: dict = {}
        running = 0
        for aid in self._alive_ids():
            eng = self.engines[aid]
            if eng.scheduler.queue and not eng.active.all():
                eng._admit()
            n_act = int(eng.active.sum())
            running += n_act
            if n_act:
                for row, tok in eng.step_all().items():
                    out[(aid, row)] = tok
            elif eng.scheduler.queue:
                # nothing admittable (capacity or retry backoff): the
                # array's step clock still ticks so backoff expires
                eng.step_idx += 1
        if running > self._fleet_stats["peak_concurrency"]:
            self._fleet_stats["peak_concurrency"] = running
        self._rebalance()
        self.step_count += 1
        return out

    def _rebalance(self) -> int:
        """Migrate queued entries an array cannot admit right now onto an
        array that can (free row AND store capacity, counting
        augmentation headroom). Eligibility respects fault-retry backoff;
        the backoff horizon is translated between the two arrays' step
        clocks. A migration lands the request at the target's queue tail
        and is admitted by the target's next pass — strictly-better-now
        targets mean work never ping-pongs."""
        moved = 0
        for src_id in self._alive_ids():
            src = self.engines[src_id]
            q = src.scheduler.queue
            i = 0
            while i < len(q):
                entry = q[i]
                if entry.not_before > src.step_idx:
                    i += 1              # backing off: not migratable yet
                    continue
                need = max(len(entry.prompt), 1)
                if (not src.active.all()
                        and src.store.can_admit_tokens(need)):
                    i += 1              # source admits it next pass itself
                    continue
                dst_id = self._migration_target(need, src_id)
                if dst_id is None:
                    i += 1
                    continue
                del q[i]
                gen = src.outputs.pop(entry.req.id, [])
                src.obs.on_handoff(entry.req.id, src.step_idx, "migrated")
                dst = self.engines[dst_id]
                entry.not_before = dst.step_idx + max(
                    0, entry.not_before - src.step_idx)
                entry.enqueue_step = dst.step_idx
                dst.adopt_request(entry, gen)
                self.placements[entry.req.id] = dst_id
                self._fleet_stats["migrations"] += 1
                dst.obs.on_placement(entry.req.id, dst_id, self.policy.name,
                                     "migrate", dst.step_idx)
                moved += 1
        return moved

    def _migration_target(self, need_tokens: int,
                          exclude: int) -> Optional[int]:
        best, best_key = None, None
        for v in self._views():
            if not v.alive or v.aid == exclude:
                continue
            if not v.can_admit_now(need_tokens):
                continue
            key = (v.load, -v.headroom_bytes, v.aid)
            if best_key is None or key < best_key:
                best, best_key = v.aid, key
        return best

    # -- array loss -------------------------------------------------------------

    def inject_array_loss(self, array_id: Optional[int] = None) -> int:
        """Force a whole-array loss at the next fleet step (chaos hook).
        Default target: the busiest alive array. The fleet Supervisor
        drains its running rows AND queue onto the survivors."""
        alive = self._alive_ids()
        if not alive:
            raise RuntimeError("no alive arrays left to lose")
        if array_id is None:
            array_id = max(alive,
                           key=lambda i: int(self.engines[i].active.sum()))
        if array_id in self._dead:
            raise ValueError(f"array {array_id} is already lost")
        self._pending_loss.add(array_id)
        return array_id

    def _fleet_health_check(self) -> None:
        if self._pending_loss:
            raise SimulatedFailure(
                f"array loss: {sorted(self._pending_loss)} at fleet step "
                f"{self.step_count}")

    def _drain_lost_arrays(self) -> int:
        """Supervisor restore hook: every pending lost array is drained —
        running rows preempted, queue emptied — and each request is
        re-placed on a survivor at the FRONT of its queue, preserving
        relative order and (critically) its `fault_retries` budget: an
        array loss is not the request's fault, so the retry bound is
        never charged (the cross-array PR-7 guarantee)."""
        moved = 0
        for aid in sorted(self._pending_loss):
            if aid in self._dead:
                continue
            eng = self.engines[aid]
            drained = eng.drain_requests()
            self._dead.add(aid)
            self._fleet_stats["array_losses"] += 1
            eng.obs.on_fault("array_loss", f"array{aid}", eng.step_idx)
            if drained and not self._alive_ids():
                raise RuntimeError(
                    "array loss drained the last alive array — no "
                    "survivors to re-place its requests on")
            # reversed + front=True keeps the drained order at the head
            # of each destination queue
            for entry, gen in reversed(drained):
                dst_id = self.policy.place(entry.prompt, self._views())
                dst = self.engines[dst_id]
                entry.not_before = dst.step_idx + max(
                    0, entry.not_before - eng.step_idx)
                entry.enqueue_step = dst.step_idx
                dst.adopt_request(entry, gen, front=True)
                self.placements[entry.req.id] = dst_id
                dst.obs.on_placement(entry.req.id, dst_id, self.policy.name,
                                     "drain", dst.step_idx)
                moved += 1
            self._fleet_stats["drain_requeues"] += len(drained)
        self._pending_loss.clear()
        return moved

    # -- drive / results --------------------------------------------------------

    @property
    def has_work(self) -> bool:
        return any(self.engines[i].active.any()
                   or self.engines[i].scheduler.queue
                   for i in self._alive_ids())

    @property
    def outputs(self) -> dict[int, list[int]]:
        """Fleet-wide output map. Each request id lives on exactly one
        array at a time (migration/drain pop it from the source first),
        so this merge is collision-free."""
        out: dict[int, list[int]] = {}
        for eng in self.engines:
            out.update(eng.outputs)
        return out

    @property
    def failed(self) -> dict[int, list[int]]:
        out: dict[int, list[int]] = {}
        for eng in self.engines:
            out.update(eng.failed)
        return out

    def generate(self, requests: list[Request]) -> dict[int, list[int]]:
        """Run all requests to completion across the fleet: place
        everything, then step rounds until every array drains. Zero
        drops — queued work migrates to whichever array can admit it."""
        for req in requests:
            self.add_request(req)
        while self.has_work:
            if not any(self.engines[i].active.any()
                       for i in self._alive_ids()):
                # nothing running anywhere: rebalance + admit once more;
                # if a ready backlog still cannot land, the fleet is
                # misconfigured (budget below one sequence on every array)
                self._rebalance()
                for aid in self._alive_ids():
                    self.engines[aid]._admit()
                if not any(self.engines[i].active.any()
                           for i in self._alive_ids()):
                    if any(self.engines[i].scheduler.backlog_ready(
                            self.engines[i].step_idx)
                           for i in self._alive_ids()):
                        raise RuntimeError(
                            "queued requests but nothing admittable on "
                            "any array — per-array budget below one "
                            "sequence?")
                    for aid in self._alive_ids():
                        self.engines[aid].step_idx += 1
                    continue
            self.step_all()
        return self.outputs

    # -- stats / observability --------------------------------------------------

    def stats(self) -> dict:
        """Fleet summary + full per-array engine stats. The "fleet" block
        carries the aggregate headlines (peak admitted concurrency,
        placement/migration/drain counters, byte totals) and a compact
        per-array table: occupancy, mode mix, refresh debt, sharding."""
        per_array = []
        for i, eng in enumerate(self.engines):
            mode_n, mode_a = eng.store.mode_mix()
            mesh_model = int(self.meshes[i].shape.get("model", 1))
            per_array.append({
                "array": i,
                "alive": i not in self._dead,
                "running": int(eng.active.sum()),
                "queued": len(eng.scheduler.queue),
                "live_bytes": int(eng.store.live_bytes),
                "budget_bytes": int(eng.store.budget_bytes),
                "occupancy": eng.store.live_bytes
                             / max(eng.store.budget_bytes, 1),
                "mode_normal": mode_n,
                "mode_augmented": mode_a,
                "refresh_debt": eng.store.max_augmented_age(eng.step_idx),
                "peak_concurrency":
                    eng.scheduler.stats["peak_concurrency"],
                "preemptions": eng.scheduler.stats["preemptions"],
                "step_idx": eng.step_idx,
                "dispatches": eng.dispatch_count,
                "energy_fj": eng.energy_ledger.describe()
                             ["energy_fj_total"],
                "mesh_devices": int(np.asarray(
                    self.meshes[i].devices).size),
                "model_axis": mesh_model,
                # TP where head counts divide the array's model axis,
                # replicated otherwise (Rules.resolve degradation)
                "heads_axes": (list(eng.rules.resolve("heads") or ())
                               or None),
                "tensor_parallel": (mesh_model > 1
                                    and eng.rules.resolve("heads")
                                    is not None),
            })
        placements_per_array = [0] * self.num_arrays
        for aid in self.placements.values():
            placements_per_array[aid] += 1
        fleet = {
            "num_arrays": self.num_arrays,
            "placement": self.policy.name,
            "alive": self._alive_ids(),
            "dead": sorted(self._dead),
            **self._fleet_stats,
            "steps": self.step_count,
            "running": sum(a["running"] for a in per_array),
            "queued": sum(a["queued"] for a in per_array),
            "aggregate_budget_bytes": sum(a["budget_bytes"]
                                          for a in per_array),
            "aggregate_live_bytes": sum(a["live_bytes"]
                                        for a in per_array),
            "placements_per_array": placements_per_array,
            "per_array": per_array,
        }
        return {"fleet": fleet,
                "placement": {
                    "policy": self.policy.name,
                    "decisions": dict(self._placement_decisions),
                },
                "arrays": [eng.stats() for eng in self.engines]}

    def export_trace(self, path: str) -> dict:
        """Merge every array's trace (distinct pids, one shared epoch)
        into a single perfetto-loadable Chrome trace and write it."""
        import json

        from repro.obs.export import merge_chrome_traces
        if not self._obs_on:
            return self.engines[0].export_trace(path)  # raises with help
        obj = merge_chrome_traces(
            [eng.obs.tracer.chrome_trace() for eng in self.engines])
        with open(path, "w") as f:
            json.dump(obj, f)
        return obj

    def export_metrics(self, path: str) -> str:
        """One fleet-wide Prometheus dump — the arrays share a registry."""
        return self.engines[0].export_metrics(path)


def make_serving(cfg: ModelConfig, mesh=None, *,
                 num_arrays: Optional[int] = None,
                 placement: Optional[str] = None, **kwargs):
    """Engine factory: a plain single-array `ServeEngine` when
    `num_arrays` (argument or cfg.amc.num_arrays) is 1, an `ArrayFleet`
    above that. The CLI and benches go through here so `--num-arrays`
    is the only switch between the two."""
    n = num_arrays if num_arrays is not None else cfg.amc.num_arrays
    if n <= 1:
        return ServeEngine(cfg, mesh if mesh is not None
                           else make_local_mesh(), **kwargs)
    return ArrayFleet(cfg, mesh, num_arrays=n, placement=placement,
                      **kwargs)
