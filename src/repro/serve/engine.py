"""Serving engine: continuous batching over the paged augmented KV pool.

Transformer families (dense/MoE) serve from `cache_pool.PagedKVPool` — a
two-plane paged cache whose pages mode-switch between Normal (bf16) and
Augmented (packed int4/int8, capacity_factor > 1) — driven by
`scheduler.Scheduler`: a FIFO request queue with admission control,
slot-free sequence lifecycle (join/leave the running batch between decode
steps), preemption-by-augmentation, and a retention-driven refresh pass
interleaved with decode (`core/retention.py`'s RefreshPolicy clocks every
augmented page). Families whose decode state is not a transformer KV
cache (ssm/hybrid/audio/vlm) keep the legacy contiguous slot cache.

Requests are never dropped: `add_request` enqueues when the pool or the
running batch is full and returns the row index on immediate admission or
None when queued; `generate` drains the queue to completion. Empty
prompts require an explicit `bos_id` — there is no silent token-0 feed.

Hot-path shape is unchanged from the contiguous engine: a P-token prompt
costs ceil(P / prefill_chunk) jitted dispatches, one batched decode
dispatch serves every running row, and host bookkeeping is vectorized
numpy. Pool maintenance (augment / promote / refresh) dispatches are
accounted separately (`stats()["pool"]["maintenance_dispatches"]`).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import amc
from repro.distributed.sharding import Rules
from repro.imc import energy as imc_energy
from repro.launch.mesh import mesh_context
from repro.models import augment
from repro.models import model as M
from repro.models.params import init_params, is_pspec
from repro.serve.cache_pool import PagedKVPool
from repro.serve.scheduler import QueueEntry, Scheduler


@dataclasses.dataclass(eq=False)
class Request:
    prompt: np.ndarray            # (plen,) int32
    max_new_tokens: int = 16
    id: int = 0


def _abstract_bytes(tree) -> int:
    """Total bytes of a PSpec tree (dense logical footprint)."""
    leaves = jax.tree.leaves(tree, is_leaf=is_pspec)
    return sum(int(np.prod(l.shape)) * jnp.dtype(l.jdtype).itemsize
               for l in leaves)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, mesh, *, max_batch: int = 8,
                 max_seq: int = 256, prefill_chunk: int = 32, params=None,
                 weight_mode: Optional[str] = None,
                 kv_mode: Optional[str] = None, seed: int = 0,
                 bos_id: Optional[int] = None,
                 pool_mode: Optional[str] = None,
                 pool_budget_bytes: Optional[int] = None,
                 pool_pages_normal: Optional[int] = None,
                 pool_pages_packed: Optional[int] = None,
                 retention_steps: Optional[int] = None,
                 paged: Optional[bool] = None,
                 matmul_impl: Optional[str] = None,
                 imc_abits: Optional[int] = None):
        # engine-level AMC knobs override the config (e.g. serve a dense
        # checkpoint with ternary weights without touching the arch file)
        if weight_mode is not None or kv_mode is not None \
                or pool_mode is not None or matmul_impl is not None \
                or imc_abits is not None:
            cfg = dataclasses.replace(cfg, amc=dataclasses.replace(
                cfg.amc,
                weight_mode=weight_mode or cfg.amc.weight_mode,
                kv_mode=kv_mode or cfg.amc.kv_mode,
                pool_mode=pool_mode or cfg.amc.pool_mode,
                matmul_impl=matmul_impl or cfg.amc.matmul_impl,
                imc_abits=imc_abits or cfg.amc.imc_abits))
        self.cfg, self.mesh = cfg, mesh
        self.max_batch, self.max_seq = max_batch, max_seq
        self.prefill_chunk = min(prefill_chunk, max_seq)
        self.bos_id = bos_id
        self.paged = M.supports_paging(cfg) if paged is None else paged
        shape = ShapeConfig("serve", max_seq, max_batch, "decode")
        self.rules = Rules.make(mesh, cfg, shape)
        dense_cfg = dataclasses.replace(
            cfg, amc=dataclasses.replace(cfg.amc, weight_mode="normal"))
        with mesh_context(mesh):
            if params is None:
                params = init_params(M.abstract_params(dense_cfg),
                                     jax.random.PRNGKey(seed))
            # pack the matmul weights into augmented storage (no-op for
            # weight_mode="normal", already-packed trees, other families)
            self.params = augment.augment_params(cfg, params)
            if self.paged:
                self.pool = PagedKVPool(
                    cfg, max_batch=max_batch, max_seq=max_seq,
                    pages_normal=pool_pages_normal,
                    pages_packed=pool_pages_packed,
                    budget_bytes=pool_budget_bytes,
                    retention_steps=retention_steps)
                self.scheduler = Scheduler(self.pool, max_batch=max_batch)
            else:
                self.pool, self.scheduler = None, None
                self._legacy_queue: deque[QueueEntry] = deque()
                ca = M.abstract_cache(cfg, shape)
                self._cache = jax.tree.map(
                    lambda l: jnp.zeros(l.shape, l.jdtype), ca,
                    is_leaf=lambda x: hasattr(x, "jdtype"))
        self._logical_weight_bytes = _abstract_bytes(
            M.abstract_params(dense_cfg))
        self._logical_cache_bytes = _abstract_bytes(M.abstract_cache(
            dataclasses.replace(
                cfg, amc=dataclasses.replace(cfg.amc, kv_mode="normal")),
            shape))
        if self.paged:
            self._decode = jax.jit(
                lambda p, c, b: M.paged_decode_step(cfg, p, c, b,
                                                    rules=self.rules),
                donate_argnums=(1,))
            self._prefill = jax.jit(
                lambda p, c, b: M.paged_prefill_step(cfg, p, c, b,
                                                     rules=self.rules),
                donate_argnums=(1,))
        else:
            self._decode = jax.jit(
                lambda p, c, b: M.decode_step(cfg, p, c, b,
                                              rules=self.rules),
                donate_argnums=(1,))
            self._prefill = None
            if M.supports_prefill(cfg):
                self._prefill = jax.jit(
                    lambda p, c, b: M.prefill_step(cfg, p, c, b,
                                                   rules=self.rules),
                    donate_argnums=(1,))
        # slot bookkeeping (host side, int32 once — dispatched as-is)
        self.positions = np.zeros(max_batch, np.int32)
        self.remaining = np.zeros(max_batch, np.int32)
        self.active = np.zeros(max_batch, bool)
        self.last_token = np.zeros(max_batch, np.int32)
        self.slot_req: list[Optional[Request]] = [None] * max_batch
        self._slot_entry: list[Optional[QueueEntry]] = [None] * max_batch
        self.outputs: dict[int, list[int]] = {}
        self.dispatch_count = 0   # jitted device dispatches (prefill+decode)
        self.step_idx = 0         # decode-step clock (retention time base)
        # array-level event/energy ledger (imc/energy.py): weight-side
        # events follow cfg.amc.matmul_impl, cache-side events follow the
        # per-page mode (Normal pages cost 6T reads, Augmented pages the
        # 8T dynamic reads). Analytic, host-side — per real dispatch.
        self.energy_ledger = imc_energy.ImcEventLedger()
        self._account = cfg.family in ("dense", "moe")
        self._refresh_bytes_seen = 0

    def _sync_refresh_events(self) -> None:
        """Fold pool refresh traffic accrued since the last sync into the
        ledger's "refresh" group, so energy totals include maintenance."""
        if not (self.paged and self._account):
            return
        rb = self.pool.stats["refresh_bytes"]
        if rb > self._refresh_bytes_seen:
            self.energy_ledger.add(
                imc_energy.refresh_events(rb - self._refresh_bytes_seen),
                "refresh")
            self._refresh_bytes_seen = rb

    # -- array event accounting ------------------------------------------------

    def _kv_value_counts(self, rows: np.ndarray,
                         lengths: np.ndarray) -> tuple[int, int]:
        """(normal, augmented) cache VALUES held by `rows` up to
        `lengths` tokens — split by page mode for the paged pool, by
        kv_mode for the contiguous cache."""
        cfg = self.cfg
        per_tok = 2 * cfg.n_layers * cfg.n_kv_heads * cfg.hd
        if rows.size == 0:
            return 0, 0
        if not self.paged:
            tok = int(lengths.sum())
            if cfg.amc.kv_mode == "normal":
                return tok * per_tok, 0
            return 0, tok * per_tok
        page = cfg.amc.page_size
        tok_per_page = np.clip(
            lengths[:, None] - np.arange(self.pool.max_pages)[None, :] * page,
            0, page)
        alloc = self.pool.allocated[rows]
        modes = self.pool.page_mode[rows]
        n_norm = int((tok_per_page * (alloc & (modes == 0))).sum())
        n_aug = int((tok_per_page * (alloc & (modes == 1))).sum())
        return n_norm * per_tok, n_aug * per_tok

    def _account_dispatch(self, rows: np.ndarray, n_new: int,
                          read_lengths: Optional[np.ndarray],
                          write_starts: np.ndarray) -> None:
        """Fold one dispatch into the event ledger: weight-side matmul
        events for `n_new` useful tokens per row, cache reads over
        `read_lengths` (None for write-only accounting), and the write of
        the `n_new` tokens from `write_starts`, costed by the mode of the
        page each token lands in."""
        if not self._account or rows.size == 0:
            return
        cfg, a = self.cfg, self.cfg.amc
        n_tok = int(rows.size) * n_new
        self.energy_ledger.add(
            imc_energy.decode_matmul_events(cfg, n_tok), "weights")
        if read_lengths is not None:
            nn, na = self._kv_value_counts(rows, read_lengths)
            self.energy_ledger.add(
                imc_energy.kv_read_events(nn, na, aug_bits=a.aug_bits),
                "kv_read")
        per_tok = 2 * cfg.n_layers * cfg.n_kv_heads * cfg.hd
        if self.paged:
            pos = write_starts[:, None] + np.arange(n_new)[None, :]
            lp = np.minimum(pos // a.page_size, self.pool.max_pages - 1)
            mode = self.pool.page_mode[rows[:, None], lp]
            alive = self.pool.allocated[rows[:, None], lp]
            wn = int((alive & (mode == 0)).sum()) * per_tok
            wa = int((alive & (mode == 1)).sum()) * per_tok
        else:
            wn, wa = ((n_tok * per_tok, 0) if a.kv_mode == "normal"
                      else (0, n_tok * per_tok))
        self.energy_ledger.add(
            imc_energy.kv_write_events(wn, wa, aug_bits=a.aug_bits),
            "kv_write")

    # -- cache view -----------------------------------------------------------

    @property
    def cache(self):
        """The decode-state tree: paged arenas or the contiguous cache."""
        return self.pool.arenas if self.paged else self._cache

    @property
    def _queue(self) -> deque:
        return self.scheduler.queue if self.paged else self._legacy_queue

    # -- continuous batching ---------------------------------------------------

    def add_request(self, req: Request) -> Optional[int]:
        """Enqueue a request and admit as many queued requests as fit.

        Returns the running-batch row if THIS request was admitted
        immediately, else None — meaning queued, never dropped: the
        scheduler admits it between later decode steps (`generate` and
        `step_all` both drain the queue)."""
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            if self.bos_id is None:
                raise ValueError(
                    "empty prompt with no bos_id: pass bos_id=<token> to "
                    "ServeEngine to define what an empty prompt decodes "
                    "from (there is no implicit token 0)")
            prompt = np.array([self.bos_id], np.int32)
        if prompt.size > self.max_seq:
            # past max_seq every cache write would clamp to the last slot,
            # silently corrupting the row — reject instead
            raise ValueError(
                f"prompt of {prompt.size} tokens exceeds "
                f"max_seq={self.max_seq} cache slots")
        entry = QueueEntry(req=req, prompt=prompt,
                           remaining=req.max_new_tokens,
                           enqueue_step=self.step_idx)
        if self.paged:
            self.scheduler.enqueue(entry)
        else:
            self._legacy_queue.append(entry)
        admitted = self._admit()
        return admitted.get(req.id)

    def _admit(self) -> dict[int, int]:
        """Admission pass: move queued requests into free rows while both
        a row and (paged) pool capacity exist. FIFO, head-of-line."""
        admitted: dict[int, int] = {}
        while True:
            free = np.flatnonzero(~self.active)
            if free.size == 0:
                break
            row = int(free[0])
            if self.paged:
                entry = self.scheduler.pop_admittable(self.step_idx)
                if entry is None:
                    break
                if not self.scheduler.admit(row, len(entry.prompt),
                                            self.step_idx):
                    # can_admit_tokens raced a concurrent change; requeue
                    self.scheduler.enqueue(entry, front=True)
                    break
            else:
                if not self._legacy_queue:
                    break
                entry = self._legacy_queue.popleft()
            self._start_row(row, entry)
            admitted[entry.req.id] = row
        return admitted

    def _start_row(self, row: int, entry: QueueEntry) -> None:
        self.active[row] = True
        self.slot_req[row] = entry.req
        self._slot_entry[row] = entry
        self.positions[row] = 0
        self.remaining[row] = entry.remaining
        self.outputs.setdefault(entry.req.id, [])
        prompt = entry.prompt
        # feed prompt[:-1] into the cache (the last prompt token is fed by
        # the first batched decode step, whose argmax is the first
        # generated token)
        if prompt.size > 1:
            self.prefill(row, prompt[:-1])
        self.last_token[row] = int(prompt[-1])

    def _preempt(self, victim: int) -> None:
        """Preemption: release the victim's pages and requeue it with
        prompt := prompt + generated-so-far (greedy recompute on resume —
        work is lost, tokens are not)."""
        entry = self._slot_entry[victim]
        gen = np.asarray(self.outputs[entry.req.id], np.int32)
        # rebuild from the ORIGINAL prompt + every generated token so far:
        # entry.prompt of an already-resumed entry contains earlier stints'
        # tokens, and outputs holds them too — concatenating those would
        # duplicate them on a second preemption
        resumed = QueueEntry(
            req=entry.req,
            prompt=np.concatenate([entry.base_prompt, gen]),
            base_prompt=entry.base_prompt,
            remaining=int(self.remaining[victim]),
            resumed=True, enqueue_step=self.step_idx)
        self.scheduler.release_row(victim)
        self.active[victim] = False
        self.slot_req[victim] = None
        self._slot_entry[victim] = None
        self.scheduler.enqueue(resumed, front=True)
        self.scheduler.stats["preemptions"] += 1

    # -- prefill ---------------------------------------------------------------

    def _paged_batch(self, extra: dict) -> dict:
        return {**self.pool.device_tables(), **extra}

    def _dispatch(self, fn, batch: dict):
        """One jitted dispatch against the backend's state tree (the paged
        arenas or the contiguous cache), with the paged device tables
        merged in. The ONE place the two backends' dispatch plumbing
        lives."""
        if self.paged:
            batch = self._paged_batch(batch)
        with mesh_context(self.mesh):
            if self.paged:
                logits, self.pool.arenas = fn(self.params, self.pool.arenas,
                                              batch)
            else:
                logits, self._cache = fn(self.params, self._cache, batch)
        self.dispatch_count += 1
        return logits

    def _ensure_prefill_pages(self, slot: int, first: int, last: int) -> None:
        """Chunked prefill writes positions [first, last] — every page in
        that span must exist (admission allocates them; direct `prefill`
        callers would otherwise silently scatter into the dump page)."""
        page = self.cfg.amc.page_size
        for lp in range(first // page, last // page + 1):
            if not self.scheduler.ensure_position(slot, lp * page,
                                                  self.step_idx):
                raise RuntimeError(
                    f"pool exhausted allocating prefill page {lp} of row "
                    f"{slot}")

    def prefill(self, slot: int, tokens: np.ndarray,
                return_next: bool = False) -> Optional[int]:
        """Feed `tokens` into the slot's cache rows/pages.

        One jitted dispatch per `prefill_chunk` tokens — ceil(P / chunk)
        total, vs P decode steps for the per-token warmup loop. With
        `return_next` also returns the greedy continuation of the last
        prefilled token — that argmax blocks on the async dispatches, so
        the admission hot path (`add_request`) leaves it off.
        """
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        if tokens.size == 0:
            return None
        if self._prefill is None:           # family without chunked prefill
            return self._prefill_stepwise(slot, tokens)
        C = self.prefill_chunk
        write_mask = np.zeros(self.max_batch, bool)
        write_mask[slot] = True
        last_logits, last_n = None, 0
        for start in range(0, tokens.size, C):
            chunk = tokens[start:start + C]
            n = chunk.size
            p = int(self.positions[slot])
            if p + n > self.max_seq:
                # genuinely no room for the real tokens
                return self._prefill_stepwise(slot, tokens[start:])
            # A padded dispatch writes C slots; near the cache end the
            # scatter start is shifted left so the write window is
            # [max_seq - C, max_seq) and the left-pad REPLAYS the last
            # `shift` already-prefilled tokens (deterministic recompute ->
            # bit-identical KV rewrite, exact attention). A short final
            # chunk therefore still costs ONE dispatch instead of falling
            # back to per-token steps.
            shift = max(0, p + C - self.max_seq)
            if shift > start:
                # the replay tokens precede this call's buffer
                return self._prefill_stepwise(slot, tokens[start:])
            tok = np.zeros((self.max_batch, C), np.int32)
            tok[slot, :shift + n] = tokens[start - shift:start + n]
            positions = self.positions.copy()
            positions[slot] = p - shift
            if self.paged:
                self._ensure_prefill_pages(slot, p - shift, p + n - 1)
            logits = self._dispatch(self._prefill,
                                    {"tokens": jnp.asarray(tok),
                                     "positions": jnp.asarray(positions),
                                     "write_mask": jnp.asarray(write_mask)})
            self._account_dispatch(np.array([slot]), n,
                                   np.array([p + n]), np.array([p]))
            self.energy_ledger.note_tokens(n)
            self.positions[slot] += n
            if self.paged:
                page = self.cfg.amc.page_size
                lps = np.unique(np.arange(p - shift, p + n) // page)
                self.pool.note_writes(np.full(lps.size, slot), lps,
                                      self.step_idx)
            last_logits, last_n = logits, shift + n
        if not return_next:
            return None
        return int(jnp.argmax(last_logits[slot, last_n - 1]))

    def _prefill_stepwise(self, slot: int, tokens: np.ndarray):
        last = None
        for t in tokens:
            last = self._step_slot(slot, int(t))
        return last

    def _step_slot(self, slot: int, token: int) -> int:
        if self.paged:
            # defensive: direct prefill() callers may outrun the pages
            # allocated at admission
            if not self.scheduler.ensure_position(
                    slot, int(self.positions[slot]), self.step_idx):
                raise RuntimeError("pool exhausted during stepwise prefill")
        tokens = np.zeros((self.max_batch, 1), np.int32)
        tokens[slot, 0] = token
        batch = {"tokens": jnp.asarray(tokens),
                 "positions": jnp.asarray(self.positions)}
        if self.paged:
            mask = np.zeros(self.max_batch, bool)
            mask[slot] = True
            batch["write_mask"] = jnp.asarray(mask)
        logits = self._dispatch(self._decode, batch)
        if self.paged:
            page = self.cfg.amc.page_size
            self.pool.note_writes(np.array([slot]),
                                  np.array([self.positions[slot] // page]),
                                  self.step_idx)
        self._account_dispatch(np.array([slot]), 1,
                               np.array([self.positions[slot] + 1]),
                               np.array([self.positions[slot]]))
        self.energy_ledger.note_tokens(1)
        self.positions[slot] += 1
        return int(jnp.argmax(logits[slot, -1]))

    # -- decode ----------------------------------------------------------------

    def _ensure_decode_capacity(self) -> None:
        """Every active row must own the page its next token lands in;
        under pressure the pool augments cold pages, and when even that
        fails the youngest-admitted row is preempted (requeued, not
        dropped)."""
        for row in np.flatnonzero(self.active):
            if not self.active[row]:
                continue    # preempted by an earlier row's allocation
            pos = int(self.positions[row])
            while not self.scheduler.ensure_position(row, pos,
                                                     self.step_idx):
                victim = self.scheduler.preemption_victim(row, self.active)
                if victim is None:
                    raise RuntimeError(
                        "paged pool cannot hold one growing sequence — "
                        "budget_bytes too small for max_seq")
                self._preempt(victim)

    def step_all(self, last_tokens: Optional[dict[int, int]] = None) -> dict:
        """One scheduler pass + one batched decode step for every active
        row: refresh expired augmented pages, admit queued requests into
        free rows, grow/augment/preempt for capacity, then dispatch.

        `last_tokens` optionally overrides the tracked per-slot feed
        token (kept for API compatibility; `generate` no longer needs
        it). Returns {row: next_token} for rows still running.
        """
        if last_tokens:
            for s, t in last_tokens.items():
                self.last_token[s] = t
        self._admit()
        if self.paged:
            self.scheduler.refresh_pass(self.step_idx)
            self._sync_refresh_events()
            self._ensure_decode_capacity()
        tokens = np.where(self.active, self.last_token, 0
                          ).astype(np.int32)[:, None]
        batch = {"tokens": jnp.asarray(tokens),
                 "positions": jnp.asarray(self.positions)}
        if self.paged:
            batch["write_mask"] = jnp.asarray(self.active)
        logits = self._dispatch(self._decode, batch)
        rows = np.flatnonzero(self.active)
        self._account_dispatch(rows, 1, self.positions[rows] + 1,
                               self.positions[rows])
        self.energy_ledger.note_tokens(rows.size)
        arg = np.asarray(jnp.argmax(logits[:, -1], axis=-1)).astype(np.int32)
        # vectorized slot bookkeeping: no per-slot Python for the numeric
        # state, only the per-request output append below
        act = self.active.copy()
        if self.paged and act.any():
            rows = np.flatnonzero(act)
            self.pool.note_writes(
                rows, self.positions[rows] // self.cfg.amc.page_size,
                self.step_idx)
        self.positions[act] += 1
        self.remaining[act] -= 1
        self.last_token = np.where(act, arg, self.last_token)
        done = act & ((self.remaining <= 0)
                      | (self.positions >= self.max_seq - 1))
        self.active &= ~done
        for s in np.flatnonzero(act):
            self.outputs[self.slot_req[s].id].append(int(arg[s]))
        for s in np.flatnonzero(done):
            self.slot_req[s] = None          # release row (cont. batching)
            self._slot_entry[s] = None
            if self.paged:
                self.scheduler.release_row(int(s))
        self.step_idx += 1
        return {int(s): int(arg[s]) for s in np.flatnonzero(act & ~done)}

    # -- stats -----------------------------------------------------------------

    def stats(self) -> dict:
        """Augmented-storage accounting (the paper's capacity headline).

        Logical bytes = what the dense bf16 representation would occupy;
        physical bytes = what the augmented planes actually occupy. For
        the paged pool, cache bytes are the USABLE page capacity (the two
        one-page write-dump lines are excluded; `pool.arena_bytes`
        reports the raw allocation). Pool/scheduler/refresh counters ride
        along under "pool" and "scheduler".
        """
        a = self.cfg.amc
        weight_phys = sum(x.nbytes for x in jax.tree.leaves(self.params))
        if self.paged:
            g = self.pool.geom
            cache_phys = (self.pool.pages_normal * g.page_bytes_normal
                          + self.pool.pages_packed * g.page_bytes_aug)
        else:
            cache_phys = sum(x.nbytes for x in jax.tree.leaves(self._cache))
        # families augment_params doesn't cover keep dense weights: report
        # the physical reality, not the requested mode
        weight_mode = (a.weight_mode if augment.is_augmented(self.params)
                       else "normal")
        wmode = amc.WEIGHT_MODES[weight_mode]
        out = {
            "kv_mode": a.kv_mode,
            "weight_mode": weight_mode,
            "weight_bits_per_value": amc.mode_bits_per_value(
                wmode, a.ternary_fmt),
            "kv_bits_per_value": amc.KV_BITS_PER_VALUE[a.kv_mode],
            "weight_bytes_logical": self._logical_weight_bytes,
            "weight_bytes_physical": weight_phys,
            "weight_capacity_factor": self._logical_weight_bytes
                                      / weight_phys,
            "cache_bytes_logical": self._logical_cache_bytes,
            "cache_bytes_physical": cache_phys,
            "cache_capacity_factor": self._logical_cache_bytes / cache_phys,
            "total_bytes_logical": (self._logical_weight_bytes
                                    + self._logical_cache_bytes),
            "total_bytes_physical": weight_phys + cache_phys,
            "capacity_factor": (self._logical_weight_bytes
                                + self._logical_cache_bytes)
                               / (weight_phys + cache_phys),
            "dispatches": self.dispatch_count,
        }
        # array-level event/energy accounting (imc/energy.py): weight-side
        # events follow matmul_impl (IMC wordline/bitline/ADC vs fetch),
        # cache reads are split by page mode — Normal pages cost 6T read
        # events, Augmented pages the 8T dynamic-read events (the paper's
        # Tables III/IV structure)
        E = imc_energy.EVENT_ENERGY_FJ
        self._sync_refresh_events()
        imc = self.energy_ledger.describe()
        imc["matmul_impl"] = a.matmul_impl
        imc["imc_abits"] = a.imc_abits
        imc["kv_read_fj_per_value_normal_mode"] = 16 * E["read_6t"]
        imc["kv_read_fj_per_value_augmented_mode"] = (
            a.aug_bits * E["read_8t_dynamic"])
        imc["refresh_energy_fj"] = imc["groups"].get(
            "refresh", {}).get("energy_fj", 0.0)
        out["imc"] = imc
        if self.paged:
            pool = self.pool.describe()
            out["pool"] = pool
            out["scheduler"] = self.scheduler.describe()
            for k in ("refreshes", "refresh_bytes", "augment_events",
                      "promote_events", "maintenance_dispatches"):
                out[k] = pool[k]
            out["preemptions"] = self.scheduler.stats["preemptions"]
        return out

    def generate(self, requests: list[Request]) -> dict[int, list[int]]:
        """Run all requests to completion: enqueue everything, then step
        until the queue AND the running batch drain. Zero drops — the
        scheduler admits from the queue between decode steps."""
        for req in requests:
            self.add_request(req)
        while self.active.any() or self._queue:
            if not self.active.any():
                self._admit()
                if not self.active.any():
                    raise RuntimeError(
                        "queued requests but nothing admittable — pool "
                        "misconfigured (budget below one sequence?)")
            self.step_all()
        return self.outputs
