"""Serving engine: continuous batching over unified augmented state stores.

EVERY model family serves through the same path: a `Scheduler` (FIFO
admission, slot-free join/leave, preemption-with-recompute, refresh pass)
driving a per-family `state_store.StateStore`:

  dense / moe   PagedKVPool — two-plane paged KV whose pages mode-switch
                between Normal (bf16) and Augmented (packed int4/int8)
  audio         PagedKVPool with a STATIC prefix band: decoder self-KV
                pages plus the cross-attention KV as fixed-length pages
  vlm           CompositeStore: PagedKVPool (self-KV) + AugmentedStatePool
                (static patch-KV prefix slabs)
  ssm / hybrid  AugmentedStatePool — fixed-size recurrent-state slabs
                (SSD/LRU/conv state, window ring KV) stored per-slot as
                Normal native dtype or Augmented packed int8/int4

All of them budget bytes against the same modeled SRAM array, augment
cold storage under pressure to admit more concurrent sequences, clock
augmented (dynamic) storage with `core/retention.RefreshPolicy`, and feed
the array-level event/energy ledger (`stats()["imc"]`).

Requests are never dropped: `add_request` enqueues when the store or the
running batch is full and returns the row index on immediate admission or
None when queued; `generate` drains the queue to completion. Empty
prompts require an explicit `bos_id` — there is no silent token-0 feed.

Hot-path shape is unchanged: a P-token prompt costs ceil(P /
prefill_chunk) jitted dispatches on families with chunked prefill (P
decode steps otherwise, as before), one batched decode dispatch serves
every running row, and host bookkeeping is vectorized numpy. Store
maintenance (augment / promote / refresh / slab reset) dispatches are
accounted separately (`stats()["pool"]["maintenance_dispatches"]`).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import amc
from repro.core import faults as faults_mod
from repro.distributed.fault import (SimulatedFailure, StragglerMonitor,
                                     Supervisor)
from repro.distributed.sharding import Rules
from repro.imc import energy as imc_energy
from repro.launch.mesh import mesh_context
from repro.models import augment
from repro.models import model as M
from repro.models.params import init_params, is_pspec
from repro.obs import hooks as obs_hooks
from repro.serve import state_store
from repro.serve.prefix import PrefixIndex
from repro.serve.scheduler import QueueEntry, Scheduler


@dataclasses.dataclass(eq=False)
class Request:
    prompt: np.ndarray            # (plen,) int32
    max_new_tokens: int = 16
    id: int = 0


def _resolve_draft_cfg(cfg: ModelConfig) -> ModelConfig:
    """Config the speculative draft pass decodes with — the paper's cheap
    dynamic-plane read of the same stored bits. "dequant" / "dense" swap
    the Pallas kernels for the plain-XLA reference paths (much cheaper in
    interpret mode, and still reading the augmented storage); "packed"
    forces the packed matmuls; "imcN" drafts through the bit-serial IMC
    dot at N-bit activations; "same" drafts at full quality (every draft
    accepted — a latency-hiding baseline, not a cost saving)."""
    impl = cfg.amc.spec_draft_impl
    a = cfg.amc
    if impl == "same":
        return cfg
    if impl == "dequant":
        amc_cfg = dataclasses.replace(a, kv_impl="dequant")
    elif impl == "dense":
        amc_cfg = dataclasses.replace(a, matmul_impl="dense",
                                      kv_impl="dequant")
    elif impl == "packed":
        amc_cfg = dataclasses.replace(a, matmul_impl="packed")
    elif impl.startswith("imc") and impl[3:] in ("1", "4", "8"):
        amc_cfg = dataclasses.replace(a, matmul_impl="imc",
                                      imc_abits=int(impl[3:]))
    else:
        raise ValueError(
            f"unknown spec_draft_impl {impl!r} (expected dequant | dense "
            f"| packed | imc1/imc4/imc8 | same)")
    return dataclasses.replace(cfg, amc=amc_cfg)


def _abstract_bytes(tree) -> int:
    """Total bytes of a PSpec tree (dense logical footprint)."""
    leaves = jax.tree.leaves(tree, is_leaf=is_pspec)
    return sum(int(np.prod(l.shape)) * jnp.dtype(l.jdtype).itemsize
               for l in leaves)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, mesh, *, max_batch: int = 8,
                 max_seq: int = 256, prefill_chunk: int = 32, params=None,
                 weight_mode: Optional[str] = None,
                 kv_mode: Optional[str] = None, seed: int = 0,
                 bos_id: Optional[int] = None,
                 pool_mode: Optional[str] = None,
                 pool_budget_bytes: Optional[int] = None,
                 pool_pages_normal: Optional[int] = None,
                 pool_pages_packed: Optional[int] = None,
                 retention_steps: Optional[int] = None,
                 matmul_impl: Optional[str] = None,
                 imc_abits: Optional[int] = None,
                 state_bits: Optional[int] = None,
                 spec_k: Optional[int] = None,
                 spec_draft_impl: Optional[str] = None,
                 prefix_cache: Optional[int] = None,
                 fault_rate: Optional[float] = None,
                 fault_seed: Optional[int] = None,
                 array_loss_rate: Optional[float] = None,
                 fault_temp_c: Optional[float] = None,
                 integrity_check: Optional[bool] = None,
                 max_retries: Optional[int] = None,
                 fault_pin_threshold: Optional[int] = None,
                 trace: Optional[bool] = None,
                 metrics: Optional[bool] = None,
                 obs_sample_every: Optional[int] = None,
                 obs=None):
        # engine-level AMC knobs override the config (e.g. serve a dense
        # checkpoint with ternary weights without touching the arch file)
        fault_overrides = (fault_rate, fault_seed, array_loss_rate,
                           fault_temp_c, integrity_check, max_retries,
                           fault_pin_threshold)
        obs_overrides = (trace, metrics, obs_sample_every)
        if weight_mode is not None or kv_mode is not None \
                or pool_mode is not None or matmul_impl is not None \
                or imc_abits is not None or state_bits is not None \
                or spec_k is not None or spec_draft_impl is not None \
                or prefix_cache is not None \
                or any(v is not None for v in fault_overrides) \
                or any(v is not None for v in obs_overrides):
            # numeric/bool fault knobs need explicit None checks — 0.0 and
            # False are legitimate override values an `or` would drop
            cfg = dataclasses.replace(cfg, amc=dataclasses.replace(
                cfg.amc,
                weight_mode=weight_mode or cfg.amc.weight_mode,
                kv_mode=kv_mode or cfg.amc.kv_mode,
                pool_mode=pool_mode or cfg.amc.pool_mode,
                matmul_impl=matmul_impl or cfg.amc.matmul_impl,
                imc_abits=imc_abits or cfg.amc.imc_abits,
                state_bits=state_bits or cfg.amc.state_bits,
                spec_k=cfg.amc.spec_k if spec_k is None else spec_k,
                spec_draft_impl=spec_draft_impl or cfg.amc.spec_draft_impl,
                prefix_cache=(cfg.amc.prefix_cache if prefix_cache is None
                              else prefix_cache),
                fault_rate=(cfg.amc.fault_rate if fault_rate is None
                            else fault_rate),
                fault_seed=(cfg.amc.fault_seed if fault_seed is None
                            else fault_seed),
                array_loss_rate=(cfg.amc.array_loss_rate
                                 if array_loss_rate is None
                                 else array_loss_rate),
                fault_temp_c=(cfg.amc.fault_temp_c if fault_temp_c is None
                              else fault_temp_c),
                integrity_check=(cfg.amc.integrity_check
                                 if integrity_check is None
                                 else integrity_check),
                max_retries=(cfg.amc.max_retries if max_retries is None
                             else max_retries),
                fault_pin_threshold=(cfg.amc.fault_pin_threshold
                                     if fault_pin_threshold is None
                                     else fault_pin_threshold),
                trace=cfg.amc.trace if trace is None else trace,
                metrics=cfg.amc.metrics if metrics is None else metrics,
                obs_sample_every=(cfg.amc.obs_sample_every
                                  if obs_sample_every is None
                                  else obs_sample_every)))
        self.cfg, self.mesh = cfg, mesh
        self.max_batch, self.max_seq = max_batch, max_seq
        self.prefill_chunk = min(prefill_chunk, max_seq)
        self.bos_id = bos_id
        shape = ShapeConfig("serve", max_seq, max_batch, "decode")
        self.rules = Rules.make(mesh, cfg, shape)
        dense_cfg = dataclasses.replace(
            cfg, amc=dataclasses.replace(cfg.amc, weight_mode="normal"))
        with mesh_context(mesh):
            if params is None:
                params = init_params(M.abstract_params(dense_cfg),
                                     jax.random.PRNGKey(seed))
            # pack the matmul weights into augmented storage (no-op for
            # weight_mode="normal", already-packed trees, other families)
            self.params = augment.augment_params(cfg, params)
            self.store = state_store.make_store(
                cfg, max_batch=max_batch, max_seq=max_seq,
                budget_bytes=pool_budget_bytes,
                pages_normal=pool_pages_normal,
                pages_packed=pool_pages_packed,
                retention_steps=retention_steps)
        # observability facade (obs/): Null unless a plane is switched on,
        # so every hook below is a constant no-op on the default path.
        # A pre-built facade may be injected (`obs=`): the ArrayFleet
        # passes per-array facades that share one trace epoch and one
        # metrics registry but record on distinct trace pids.
        self.obs = obs if obs is not None else obs_hooks.make_engine_obs(
            cfg.amc)
        if self.obs.enabled:
            self.store.attach_obs(self.obs)
        self.scheduler = Scheduler(self.store, max_batch=max_batch,
                                   obs=self.obs)
        # shared-prefix page reuse (serve/prefix.py): paged stores with a
        # share band get a token-hash index over cached prefix page runs;
        # hits map the SAME physical pages into the new row (refcounted)
        # and prefill only the tail. None on every other path — zero cost.
        self._prefix_index: Optional[PrefixIndex] = None
        if self.store.kind == "paged" \
                and getattr(self.store, "share_entries", 0) > 0:
            self._prefix_index = PrefixIndex(self.store.share_entries,
                                             self.cfg.amc.page_size)
            self.store.attach_prefix_index(self._prefix_index)
        self.prefill_dispatch_count = 0   # prefill-only subset of dispatches
        self._prefix_saved = 0            # prefill dispatches skipped by hits
        # retention-fault injection + self-healing (core/faults.py): the
        # model samples per-page/per-slab early expiries and refresh
        # misses deterministically under the seed; the store detects them
        # via integrity words; recovery runs scrub / recompute / retry
        # through the scheduler. Inert at fault_rate == array_loss_rate
        # == 0 (no model attached, zero hot-path cost).
        a2 = self.cfg.amc
        self._fault_model: Optional[faults_mod.FaultModel] = None
        if a2.fault_rate > 0.0 or a2.array_loss_rate > 0.0:
            self._fault_model = faults_mod.FaultModel(
                rate=a2.fault_rate, seed=a2.fault_seed,
                temp_c=a2.fault_temp_c,
                array_loss_rate=a2.array_loss_rate,
                pin_threshold=a2.fault_pin_threshold)
            self.store.attach_fault_model(self._fault_model,
                                          integrity=a2.integrity_check)
        # whole-array failure events drain-and-requeue through the
        # distributed fault supervisor; slow fault-recovery steps feed the
        # straggler monitor (mitigations are counted, not acted on)
        self.supervisor = Supervisor(self._recover_array_loss,
                                     max_restarts=64)
        self.straggler = StragglerMonitor()
        self._forced_array_loss = False
        self.failed: dict[int, list[int]] = {}
        self._fault_stats = {
            "recovered_scrub": 0, "recovered_recompute": 0, "retried": 0,
            "uncorrectable": 0, "array_losses": 0, "array_loss_requeues": 0,
            "straggler_mitigations": 0,
        }
        self._logical_weight_bytes = _abstract_bytes(
            M.abstract_params(dense_cfg))
        self._logical_cache_bytes = _abstract_bytes(M.abstract_cache(
            dataclasses.replace(
                cfg, amc=dataclasses.replace(cfg.amc, kv_mode="normal")),
            shape))
        fns = state_store.make_step_fns(cfg, self.store, rules=self.rules)
        self._decode = jax.jit(fns["decode"], donate_argnums=(1,))
        self._prefill = (jax.jit(fns["prefill"], donate_argnums=(1,))
                         if fns["prefill"] is not None else None)
        # self-speculative decoding: draft spec_k - 1 tokens per round out
        # of the cheap (dynamic-plane) representation, verify the whole
        # window through the full packed path in ONE dispatch, accept the
        # longest matching prefix (token-identical to stepwise decode)
        self.spec_k = cfg.amc.spec_k
        self._verify = (jax.jit(fns["verify"], donate_argnums=(1,))
                        if fns.get("verify") is not None else None)
        self._spec = self.spec_k > 1 and self._verify is not None
        self._spec_stats = {"spec_rounds": 0, "draft_dispatches": 0,
                            "verify_dispatches": 0, "accepted_tokens": 0}
        if self._spec:
            self._draft_cfg = _resolve_draft_cfg(cfg)
            draft_fns = state_store.make_step_fns(self._draft_cfg,
                                                  self.store,
                                                  rules=self.rules)
            # slab drafts advance the recurrent state the snapshot holds a
            # REFERENCE to — the draft step must not donate those buffers;
            # paged drafts only write fresh page slots, so donation is safe
            donate = (1,) if self.store.kind == "paged" else ()
            self._draft_decode = jax.jit(draft_fns["decode"],
                                         donate_argnums=donate)
        # slot bookkeeping (host side, int32 once — dispatched as-is)
        self.positions = np.zeros(max_batch, np.int32)
        self.remaining = np.zeros(max_batch, np.int32)
        self.active = np.zeros(max_batch, bool)
        self.last_token = np.zeros(max_batch, np.int32)
        self.slot_req: list[Optional[Request]] = [None] * max_batch
        self._slot_entry: list[Optional[QueueEntry]] = [None] * max_batch
        self.outputs: dict[int, list[int]] = {}
        self.dispatch_count = 0   # jitted device dispatches (prefill+decode)
        self.step_idx = 0         # decode-step clock (retention time base)
        # array-level event/energy ledger (imc/energy.py): weight-side
        # events follow cfg.amc.matmul_impl, state-side events follow the
        # per-page / per-slab mode (Normal storage costs 6T accesses,
        # Augmented the 8T dynamic ones). Analytic, host-side — per real
        # dispatch, for every family.
        self.energy_ledger = imc_energy.ImcEventLedger()
        self._refresh_bytes_seen = 0

    # -- store views -----------------------------------------------------------

    @property
    def pool(self):
        """The row's decode-state store (historic name kept: benches and
        tests address the byte budget / page geometry through it)."""
        return self.store

    @property
    def cache(self):
        """The decode-state device tree (arenas and/or slab planes)."""
        return self.store.state

    @property
    def _queue(self) -> deque:
        return self.scheduler.queue

    def _store_parts(self) -> list:
        """The store's billable units (composite stores bill each part at
        its own augmented width)."""
        if self.store.kind == "composite":
            return list(self.store.parts.values())
        return [self.store]

    def _sync_refresh_events(self) -> None:
        """Fold store refresh traffic accrued since the last sync into the
        ledger's "refresh" group, so energy totals include maintenance."""
        rb = sum(p.stats["refresh_bytes"] for p in self._store_parts())
        if rb > self._refresh_bytes_seen:
            self.energy_ledger.add(
                imc_energy.refresh_events(rb - self._refresh_bytes_seen),
                "refresh")
            self._refresh_bytes_seen = rb

    # -- array event accounting ------------------------------------------------

    def _account_dispatch(self, rows: np.ndarray, n_new: int,
                          read_lengths: Optional[np.ndarray],
                          write_starts: np.ndarray) -> None:
        """Fold one dispatch into the event ledger: weight-side matmul
        events for `n_new` useful tokens per row, state reads over
        `read_lengths` (None for write-only accounting), and the write of
        the `n_new` tokens, costed by the mode of the storage each lands
        in (the store splits the counts by page/slab mode)."""
        if rows.size == 0:
            return
        n_tok = int(rows.size) * n_new
        self.energy_ledger.add(
            imc_energy.decode_matmul_events(self.cfg, n_tok), "weights")
        # each part bills at ITS augmented width (a vlm engine's int4 KV
        # pages and int8 prefix slabs cost different 8T cells/value)
        for part in self._store_parts():
            if read_lengths is not None:
                nn, na = part.read_value_counts(rows, read_lengths)
                self.energy_ledger.add(
                    imc_energy.kv_read_events(nn, na,
                                              aug_bits=part.aug_bits),
                    "kv_read")
            wn, wa = part.write_value_counts(rows, n_new, write_starts)
            self.energy_ledger.add(
                imc_energy.kv_write_events(wn, wa, aug_bits=part.aug_bits),
                "kv_write")

    # -- continuous batching ---------------------------------------------------

    def add_request(self, req: Request) -> Optional[int]:
        """Enqueue a request and admit as many queued requests as fit.

        Returns the running-batch row if THIS request was admitted
        immediately, else None — meaning queued, never dropped: the
        scheduler admits it between later decode steps (`generate` and
        `step_all` both drain the queue)."""
        if req.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {req.max_new_tokens} "
                f"(a request that generates nothing would occupy a row "
                f"forever)")
        if req.id in self.outputs or any(
                e.req.id == req.id for e in self.scheduler.queue):
            # outputs covers running AND completed ids: reusing either
            # would silently append the new request's tokens onto the
            # old one's list
            raise ValueError(
                f"request id {req.id} is already queued, running or "
                f"completed on this engine — ids key the output map, so "
                f"reusing one would silently merge two requests' tokens")
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            if self.bos_id is None:
                raise ValueError(
                    "empty prompt with no bos_id: pass bos_id=<token> to "
                    "ServeEngine to define what an empty prompt decodes "
                    "from (there is no implicit token 0)")
            prompt = np.array([self.bos_id], np.int32)
        if int(prompt.min()) < 0 or int(prompt.max()) >= self.cfg.vocab:
            # out-of-range ids would gather garbage rows deep inside
            # prefill (and poison the prefix index) — reject at the door
            bad = prompt[(prompt < 0) | (prompt >= self.cfg.vocab)]
            raise ValueError(
                f"prompt contains token id(s) outside the vocab "
                f"[0, {self.cfg.vocab}): {bad[:8].tolist()}"
                f"{'...' if bad.size > 8 else ''}")
        if prompt.size > self.max_seq:
            # past max_seq every cache write would clamp to the last slot,
            # silently corrupting the row — reject instead
            raise ValueError(
                f"prompt of {prompt.size} tokens exceeds "
                f"max_seq={self.max_seq} cache slots")
        cap_tokens = self.store.max_row_tokens()
        if cap_tokens is not None:
            # the row stores min(P + N - 1, max_seq - 1) tokens at peak
            # (prompt + generated, last generation never written); a
            # request the store can NEVER hold would otherwise loop
            # admission/preemption forever inside generate()
            need = min(prompt.size + req.max_new_tokens - 1,
                       self.max_seq - 1)
            if need > cap_tokens:
                raise ValueError(
                    f"request needs {need} cache tokens at peak (prompt "
                    f"{prompt.size} + max_new_tokens {req.max_new_tokens}"
                    f", capped by max_seq={self.max_seq}) but the store "
                    f"holds at most {cap_tokens} tokens per row — raise "
                    f"the pool budget/pages or shrink the request")
        entry = QueueEntry(req=req, prompt=prompt,
                           remaining=req.max_new_tokens,
                           enqueue_step=self.step_idx)
        self.obs.on_enqueue(req.id, int(prompt.size), req.max_new_tokens,
                            self.step_idx)
        self.scheduler.enqueue(entry)
        admitted = self._admit()
        return admitted.get(req.id)

    def _admit(self) -> dict[int, int]:
        """Admission pass: move queued requests into free rows while both
        a row and store capacity exist. FIFO, head-of-line."""
        admitted: dict[int, int] = {}
        while True:
            free = np.flatnonzero(~self.active)
            if free.size == 0:
                break
            row = int(free[0])
            entry = self.scheduler.pop_admittable(self.step_idx)
            if entry is None:
                break
            shared = self._prefix_match(entry)
            if not self.scheduler.admit(row, len(entry.prompt),
                                        self.step_idx,
                                        shared=(None if shared is None else
                                                (shared[0].row, shared[1]))):
                # can_admit_tokens raced a concurrent change; requeue
                self.scheduler.enqueue(entry, front=True)
                break
            self._start_row(row, entry, shared=shared)
            admitted[entry.req.id] = row
        return admitted

    def _prefix_match(self, entry: QueueEntry):
        """Deepest cached prefix of the tokens this admission will FEED
        (prompt[:-1] — the last prompt token goes through decode), as
        (PrefixEntry, matched_tokens), or None."""
        if self._prefix_index is None:
            return None
        fed = entry.prompt[:-1]
        if fed.size < self.cfg.amc.page_size:
            return None
        e, m = self._prefix_index.match(fed)
        if e is None:
            self._prefix_index.note_miss()
            self.obs.on_prefix("miss", entry.req.id, 0, self.step_idx)
            return None
        return e, m

    def _start_row(self, row: int, entry: QueueEntry, shared=None) -> None:
        self.active[row] = True
        self.slot_req[row] = entry.req
        self._slot_entry[row] = entry
        self.positions[row] = 0
        self.remaining[row] = entry.remaining
        self.outputs.setdefault(entry.req.id, [])
        self.obs.on_admit(entry.req.id, row, self.step_idx)
        prompt = entry.prompt
        # feed prompt[:-1] into the cache (the last prompt token is fed by
        # the first batched decode step, whose argmax is the first
        # generated token)
        fed = prompt[:-1]
        m = 0
        if shared is not None:
            # prefix hit: admit_row already mapped the cached run's pages
            # into this row — skip their prefill dispatches entirely and
            # start the position clock past the shared tokens
            e, m = shared
            self.positions[row] = m
            self._prefix_index.note_hit(e, m, self.step_idx)
            self.store.note_entry_use(e.row, m, self.step_idx)
            C = self.prefill_chunk
            self._prefix_saved += -(-m // C)
            self.obs.on_prefix("hit", entry.req.id, m, self.step_idx)
        if fed.size > m:
            with self.obs.prefill_span(entry.req.id, int(fed.size) - m):
                self.prefill(row, fed[m:])
        if shared is None:
            self._register_prefix(row, fed)
        self.last_token[row] = int(prompt[-1])

    def _register_prefix(self, row: int, fed: np.ndarray) -> None:
        """Cache the freshly prefilled prompt's full pages as a prefix
        entry: alias them into a share-band row and index the token run.
        Skipped when the run is shorter than one page or no slot can be
        freed (every cached entry still has live sharers)."""
        idx = self._prefix_index
        if idx is None:
            return
        page = self.cfg.amc.page_size
        full = fed.size // page
        if full == 0:
            return
        slot = idx.acquire_slot(self.store, self.step_idx)
        if slot is None:
            return
        erow = self.store.entry_row(slot)
        self.store.register_entry_pages(erow, row, full, self.step_idx)
        idx.add_entry(slot, erow, fed[:full * page], self.step_idx)

    def _preempt(self, victim: int) -> None:
        """Preemption: release the victim's storage and requeue it with
        prompt := prompt + generated-so-far (greedy recompute on resume —
        work is lost, tokens are not)."""
        entry = self._slot_entry[victim]
        gen = np.asarray(self.outputs[entry.req.id], np.int32)
        # rebuild from the ORIGINAL prompt + every generated token so far:
        # entry.prompt of an already-resumed entry contains earlier stints'
        # tokens, and outputs holds them too — concatenating those would
        # duplicate them on a second preemption
        resumed = QueueEntry(
            req=entry.req,
            prompt=np.concatenate([entry.base_prompt, gen]),
            base_prompt=entry.base_prompt,
            remaining=int(self.remaining[victim]),
            resumed=True, enqueue_step=self.step_idx)
        self.scheduler.release_row(victim)
        self.active[victim] = False
        self.slot_req[victim] = None
        self._slot_entry[victim] = None
        self.obs.on_preempt(entry.req.id, self.step_idx, "capacity")
        self.scheduler.enqueue(resumed, front=True)
        self.scheduler.stats["preemptions"] += 1

    # -- fleet hand-off (serve/fleet.py drives these) ---------------------------

    def adopt_request(self, entry: QueueEntry, generated: list[int], *,
                      front: bool = False) -> None:
        """Take over a request mid-flight from another array: seed the
        output list with the tokens it already generated (the resume
        prompt in `entry.prompt` contains them, so `_start_row`'s
        setdefault keeps the seed and a later preemption rebuilds from
        base_prompt + outputs without duplication), then enqueue. The
        caller (ArrayFleet) moves each request id between at most one
        engine's books at a time."""
        rid = entry.req.id
        if rid in self.outputs or any(
                e.req.id == rid for e in self.scheduler.queue):
            raise ValueError(
                f"request id {rid} already lives on this array — the "
                f"fleet must pop it from the source array first")
        self.outputs[rid] = list(generated)
        self.obs.on_enqueue(rid, int(len(entry.prompt)), entry.remaining,
                            self.step_idx)
        self.scheduler.enqueue(entry, front=front)

    def drain_requests(self) -> list[tuple[QueueEntry, list[int]]]:
        """Array-loss drain for fleet mode: release every running row and
        empty the queue, handing back [(entry, generated-so-far)] ready
        for `adopt_request` on a surviving array. `fault_retries` budgets
        are PRESERVED, never charged — losing the array is not the
        request's fault (the cross-array extension of the single-array
        `_recover_array_loss` guarantee)."""
        drained: list[tuple[QueueEntry, list[int]]] = []
        for row in np.flatnonzero(self.active):
            entry = self._slot_entry[int(row)]
            gen = self.outputs.pop(entry.req.id, [])
            resumed = QueueEntry(
                req=entry.req,
                prompt=np.concatenate([entry.base_prompt,
                                       np.asarray(gen, np.int32)]),
                base_prompt=entry.base_prompt,
                remaining=int(self.remaining[row]),
                resumed=True, enqueue_step=self.step_idx,
                fault_retries=entry.fault_retries,
                not_before=entry.not_before)
            self.scheduler.release_row(int(row))
            self.active[row] = False
            self.slot_req[row] = None
            self._slot_entry[row] = None
            self.obs.on_handoff(entry.req.id, self.step_idx, "drained")
            drained.append((resumed, gen))
        while self.scheduler.queue:
            e = self.scheduler.queue.popleft()
            self.obs.on_handoff(e.req.id, self.step_idx, "drained")
            drained.append((e, self.outputs.pop(e.req.id, [])))
        self.obs.on_queue_depth(0)
        return drained

    # -- prefill ---------------------------------------------------------------

    def _dispatch(self, fn, batch: dict):
        """One jitted dispatch against the store's state tree, with the
        store's device tables merged in. The ONE place dispatch plumbing
        lives — every family, every store kind."""
        batch = {**self.store.device_tables(), **batch}
        with mesh_context(self.mesh):
            logits, self.store.state = fn(self.params, self.store.state,
                                          batch)
        self.dispatch_count += 1
        return logits

    def _ensure_prefill_pages(self, slot: int, first: int, last: int) -> None:
        """Chunked prefill writes positions [first, last] — every page in
        that span must exist (admission allocates them; direct `prefill`
        callers would otherwise silently scatter into the dump page)."""
        page = self.cfg.amc.page_size
        for lp in range(first // page, last // page + 1):
            # pass the page's first WRITTEN position, not its first slot:
            # a shared boundary page must copy-on-write with exactly the
            # tokens below `first` preserved
            if not self.scheduler.ensure_position(slot, max(first, lp * page),
                                                  self.step_idx):
                raise RuntimeError(
                    f"store exhausted allocating prefill page {lp} of row "
                    f"{slot}")

    def prefill(self, slot: int, tokens: np.ndarray,
                return_next: bool = False) -> Optional[int]:
        """Feed `tokens` into the slot's decode state.

        One jitted dispatch per `prefill_chunk` tokens — ceil(P / chunk)
        total, vs P decode steps for the per-token warmup loop — on
        families with a chunked prefill step; per-token elsewhere. With
        `return_next` also returns the greedy continuation of the last
        prefilled token — that argmax blocks on the async dispatches, so
        the admission hot path (`add_request`) leaves it off.
        """
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        if tokens.size == 0:
            return None
        if self._prefill is None:           # family without chunked prefill
            return self._prefill_stepwise(slot, tokens)
        C = self.prefill_chunk
        write_mask = np.zeros(self.max_batch, bool)
        write_mask[slot] = True
        req = self.slot_req[slot]
        rid = req.id if req is not None else None
        last_logits, last_n = None, 0
        for start in range(0, tokens.size, C):
            chunk = tokens[start:start + C]
            n = chunk.size
            p = int(self.positions[slot])
            if p + n > self.max_seq:
                # genuinely no room for the real tokens
                return self._prefill_stepwise(slot, tokens[start:])
            # A padded dispatch writes C slots; near the cache end the
            # scatter start is shifted left so the write window is
            # [max_seq - C, max_seq) and the left-pad REPLAYS the last
            # `shift` already-prefilled tokens (deterministic recompute ->
            # bit-identical KV rewrite, exact attention). A short final
            # chunk therefore still costs ONE dispatch instead of falling
            # back to per-token steps.
            shift = max(0, p + C - self.max_seq)
            if shift > start:
                # the replay tokens precede this call's buffer
                return self._prefill_stepwise(slot, tokens[start:])
            tok = np.zeros((self.max_batch, C), np.int32)
            tok[slot, :shift + n] = tokens[start - shift:start + n]
            positions = self.positions.copy()
            positions[slot] = p - shift
            self._ensure_prefill_pages(slot, p - shift, p + n - 1)
            with self.obs.chunk_span(rid, n):
                logits = self._dispatch(
                    self._prefill,
                    {"tokens": jnp.asarray(tok),
                     "positions": jnp.asarray(positions),
                     "write_mask": jnp.asarray(write_mask)})
            self.prefill_dispatch_count += 1
            self._account_dispatch(np.array([slot]), n,
                                   np.array([p + n]), np.array([p]))
            self.energy_ledger.note_tokens(n)
            self.positions[slot] += n
            self.store.note_token_writes(
                np.full(n + shift, slot), np.arange(p - shift, p + n),
                self.step_idx)
            last_logits, last_n = logits, shift + n
        if not return_next:
            return None
        return int(jnp.argmax(last_logits[slot, last_n - 1]))

    def _prefill_stepwise(self, slot: int, tokens: np.ndarray):
        last = None
        for t in tokens:
            last = self._step_slot(slot, int(t))
            self.prefill_dispatch_count += 1
        return last

    def _step_slot(self, slot: int, token: int) -> int:
        # defensive: direct prefill() callers may outrun the storage
        # reserved at admission
        if not self.scheduler.ensure_position(
                slot, int(self.positions[slot]), self.step_idx):
            raise RuntimeError("store exhausted during stepwise prefill")
        tokens = np.zeros((self.max_batch, 1), np.int32)
        tokens[slot, 0] = token
        mask = np.zeros(self.max_batch, bool)
        mask[slot] = True
        batch = {"tokens": jnp.asarray(tokens),
                 "positions": jnp.asarray(self.positions),
                 "write_mask": jnp.asarray(mask)}
        logits = self._dispatch(self._decode, batch)
        self.store.note_token_writes(np.array([slot]),
                                     np.array([self.positions[slot]]),
                                     self.step_idx)
        self._account_dispatch(np.array([slot]), 1,
                               np.array([self.positions[slot] + 1]),
                               np.array([self.positions[slot]]))
        self.energy_ledger.note_tokens(1)
        self.positions[slot] += 1
        return int(jnp.argmax(logits[slot, -1]))

    # -- decode ----------------------------------------------------------------

    def _ensure_decode_capacity(self) -> None:
        """Every active row must own the storage its next token lands in;
        under pressure the store augments cold storage, and when even that
        fails the youngest-admitted row is preempted (requeued, not
        dropped)."""
        for row in np.flatnonzero(self.active):
            if not self.active[row]:
                continue    # preempted by an earlier row's allocation
            pos = int(self.positions[row])
            while not self.scheduler.ensure_position(row, pos,
                                                     self.step_idx):
                victim = self.scheduler.preemption_victim(row, self.active)
                if victim is None:
                    raise RuntimeError(
                        "state store cannot hold one growing sequence — "
                        "budget_bytes too small for max_seq")
                self._preempt(victim)

    def step_all(self, last_tokens: Optional[dict[int, int]] = None) -> dict:
        """One scheduler pass + one batched decode step for every active
        row: refresh expired augmented storage, admit queued requests into
        free rows, grow/augment/preempt for capacity, then dispatch.

        `last_tokens` optionally overrides the tracked per-slot feed
        token (kept for API compatibility; `generate` no longer needs
        it). Returns {row: next_token} for rows still running.
        """
        if last_tokens:
            for s, t in last_tokens.items():
                self.last_token[s] = t
        if self._fault_model is not None or self._forced_array_loss:
            if not self.supervisor.run_step(self._array_health_check):
                # whole-array loss: every running row was drained and
                # requeued by _recover_array_loss; the step clock still
                # ticks (retry backoff is measured in steps)
                self.step_idx += 1
                return {}
        t0 = time.perf_counter()
        with self.obs.step_span(self.step_idx,
                                "spec" if self._spec else "decode"):
            with self.obs.phase_span("admit"):
                self._admit()
            if self._fault_model is not None:
                # inject -> detect -> heal BEFORE refresh and dispatch, so
                # corrupted storage is never read, refreshed or promoted
                with self.obs.fault_span(self.step_idx):
                    self._fault_pass()
            n_refreshed = self.scheduler.refresh_pass(self.step_idx)
            self.obs.on_refresh_pass(n_refreshed, self.step_idx)
            self._sync_refresh_events()
            if self._spec and self.active.any():
                out = self._step_all_spec()
            else:
                out = self._step_all_decode()
        dt = time.perf_counter() - t0
        self._note_step_time(dt)
        self.obs.on_step_done(self.step_idx, dt)
        if self.obs.wants_sample(self.step_idx):
            self.obs.sample(self.step_idx, self._obs_sample_payload())
        return out

    def _step_all_decode(self) -> dict:
        """The non-speculative decode round: one batched dispatch serves
        every active row (the body `step_all` wraps in scheduling,
        refresh, fault and observability passes)."""
        self._ensure_decode_capacity()
        tokens = np.where(self.active, self.last_token, 0
                          ).astype(np.int32)[:, None]
        batch = {"tokens": jnp.asarray(tokens),
                 "positions": jnp.asarray(self.positions),
                 "write_mask": jnp.asarray(self.active)}
        logits = self._dispatch(self._decode, batch)
        rows = np.flatnonzero(self.active)
        self._account_dispatch(rows, 1, self.positions[rows] + 1,
                               self.positions[rows])
        self.energy_ledger.note_tokens(rows.size)
        arg = np.asarray(jnp.argmax(logits[:, -1], axis=-1)).astype(np.int32)
        # vectorized slot bookkeeping: no per-slot Python for the numeric
        # state, only the per-request output append below
        act = self.active.copy()
        if act.any():
            rows = np.flatnonzero(act)
            self.store.note_token_writes(rows, self.positions[rows],
                                         self.step_idx)
        self.positions[act] += 1
        self.remaining[act] -= 1
        self.last_token = np.where(act, arg, self.last_token)
        done = act & ((self.remaining <= 0)
                      | (self.positions >= self.max_seq - 1))
        self.active &= ~done
        for s in np.flatnonzero(act):
            rid = self.slot_req[s].id
            self.outputs[rid].append(int(arg[s]))
            self.obs.on_tokens(rid, 1, self.step_idx)
        for s in np.flatnonzero(done):
            rid = self.slot_req[s].id
            self.slot_req[s] = None          # release row (cont. batching)
            self._slot_entry[s] = None
            self.scheduler.release_row(int(s))
            self.obs.on_complete(rid, self.step_idx)
        self.step_idx += 1
        return {int(s): int(arg[s]) for s in np.flatnonzero(act & ~done)}

    def _step_all_spec(self) -> dict:
        """One self-speculative round for every active row: spec_k - 1
        cheap draft dispatches propose a spec_k-token window out of the
        dynamic-plane read, ONE full-path verify dispatch scores and
        commits it, and the longest greedily-matching prefix is emitted.
        Greedy accept keeps the emitted stream token-identical to
        step-by-step decode; rejected draft storage is rolled back (page
        retraction on paged stores, snapshot restore on slab stores)."""
        W = self.spec_k
        B = self.max_batch
        # per-row window cap >= 1: stepwise decode retires a row once its
        # position reaches max_seq - 1, so no window slot may write past
        # max_seq - 2
        cap = np.ones(B, np.int32)
        rows = np.flatnonzero(self.active)
        cap[rows] = np.clip(self.max_seq - 1 - self.positions[rows], 1, W)
        # every window slot needs storage BEFORE the draft writes it; the
        # same augment-then-preempt ladder as _ensure_decode_capacity
        for row in rows:
            if not self.active[row]:
                continue    # preempted by an earlier row's allocation
            while not self.scheduler.ensure_window(
                    int(row), int(self.positions[row]), int(cap[row]),
                    self.step_idx):
                victim = self.scheduler.preemption_victim(int(row),
                                                          self.active)
                if victim is None:
                    raise RuntimeError(
                        "state store cannot hold one growing sequence — "
                        "budget_bytes too small for max_seq")
                self._preempt(victim)
        rows = np.flatnonzero(self.active)
        wmask2d = self.active[:, None] & (np.arange(W)[None, :]
                                          < cap[:, None])
        # -- draft: W - 1 cheap single-token steps propose the window tail
        toks = np.zeros((B, W), np.int32)
        toks[:, 0] = np.where(self.active, self.last_token, 0)
        if self.store.kind == "slab":
            self.store.speculative_snapshot()
        with self.obs.phase_span("spec_draft", k=W - 1):
            for i in range(W - 1):
                # clamp keeps INACTIVE rows' stale positions inside the
                # table; active rows never exceed max_seq - 2 by the cap
                # above
                pos_i = np.minimum(self.positions + i, self.max_seq - 1)
                lg = self._dispatch(
                    self._draft_decode,
                    {"tokens": jnp.asarray(toks[:, i:i + 1]),
                     "positions": jnp.asarray(pos_i),
                     "write_mask": jnp.asarray(wmask2d[:, i])})
                self.energy_ledger.add(
                    imc_energy.decode_matmul_events(self._draft_cfg,
                                                    int(rows.size)),
                    "draft")
                self._spec_stats["draft_dispatches"] += 1
                toks[:, i + 1] = np.asarray(
                    jnp.argmax(lg[:, -1], axis=-1)).astype(np.int32)
        if self.store.kind == "slab":
            # the verify scan replays the window from the pre-draft state
            self.store.speculative_restore()
        # -- verify: ONE full-quality dispatch over the whole window
        with self.obs.phase_span("spec_verify", k=W):
            logits = self._dispatch(
                self._verify,
                {"tokens": jnp.asarray(toks),
                 "positions": jnp.asarray(self.positions),
                 "write_mask": jnp.asarray(wmask2d)})
        self._spec_stats["verify_dispatches"] += 1
        self._spec_stats["spec_rounds"] += 1
        self._account_dispatch(rows, W, self.positions[rows] + cap[rows],
                               self.positions[rows])
        # -- host accept: longest prefix where the verifier agrees with
        # the draft (same formula the verify step committed KV with)
        v = np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)
        mism = np.concatenate([toks[:, 1:] != v[:, :-1],
                               np.ones((B, 1), bool)], axis=1)
        n_acc = np.minimum(mism.argmax(axis=1).astype(np.int32) + 1, cap)
        act = self.active.copy()
        n_emit = np.where(act, np.minimum(n_acc, self.remaining),
                          0).astype(np.int32)
        rw, ps = [], []
        total = 0
        for s in rows:
            na = int(n_emit[s])
            rid = self.slot_req[s].id
            self.outputs[rid].extend(int(t) for t in v[s, :na])
            self.obs.on_tokens(rid, na, self.step_idx)
            total += na
            nc = int(n_acc[s])     # committed (may exceed the emit budget)
            rw.extend([int(s)] * nc)
            ps.extend(range(int(self.positions[s]),
                            int(self.positions[s]) + nc))
        if rw:
            self.store.note_token_writes(np.array(rw), np.array(ps),
                                         self.step_idx)
        self.energy_ledger.note_tokens(total)
        self._spec_stats["accepted_tokens"] += total
        self.obs.on_spec_round(total, int(rows.size), self.step_idx)
        # roll back pages that held only rejected draft tokens (slab
        # stores already rolled back wholesale via the snapshot)
        if rows.size:
            self.store.retract_token_writes(
                rows, self.positions[rows] + n_acc[rows])
        self.positions[act] += n_emit[act]
        self.remaining[act] -= n_emit[act]
        last = v[np.arange(B), np.maximum(n_emit - 1, 0)]
        self.last_token = np.where(act, last, self.last_token)
        done = act & ((self.remaining <= 0)
                      | (self.positions >= self.max_seq - 1))
        self.active &= ~done
        for s in np.flatnonzero(done):
            rid = self.slot_req[s].id
            self.slot_req[s] = None
            self._slot_entry[s] = None
            self.scheduler.release_row(int(s))
            self.obs.on_complete(rid, self.step_idx)
        self.step_idx += 1
        return {int(s): int(v[s, n_emit[s] - 1])
                for s in np.flatnonzero(act & ~done)}

    # -- retention faults: inject / detect / heal ------------------------------

    def _note_step_time(self, dt: float) -> None:
        """Feed the per-step wall time to the straggler monitor. Always
        recorded (stats()["step_times"] surfaces min/mean/max for every
        run), mitigations only counted — never acted on."""
        if self.straggler.record(self.step_idx, dt):
            self._fault_stats["straggler_mitigations"] += 1

    def prefix_probe(self, prompt: np.ndarray) -> int:
        """Tokens of `prompt` this engine's prefix cache already holds
        (0 without one) — pure; the affinity placement policy's
        prefix-locality signal."""
        if self._prefix_index is None:
            return 0
        fed = np.asarray(prompt, np.int32).reshape(-1)[:-1]
        if fed.size < self.cfg.amc.page_size:
            return 0
        return self._prefix_index.probe(fed)

    def inject_array_loss(self) -> None:
        """Force a whole-array failure event at the next `step_all` (the
        chaos hook `examples/elastic_restart.py` and the tests drive):
        the supervisor drains every running row back to the queue and the
        engine resumes from recompute — work lost, tokens never."""
        self._forced_array_loss = True

    def _array_health_check(self) -> None:
        if self._forced_array_loss:
            self._forced_array_loss = False
            raise SimulatedFailure(
                f"injected array loss at step {self.step_idx}")
        if self._fault_model is not None \
                and self._fault_model.array_loss(self.step_idx):
            raise SimulatedFailure(
                f"sampled array loss at step {self.step_idx}")

    def _recover_array_loss(self) -> int:
        """Supervisor restore hook: the array's dynamic contents are gone,
        so every running row is preempted (released + requeued with
        prompt := prompt + generated-so-far) — the drain-and-requeue path.
        Fault-retry budgets are NOT charged: an array loss is not the
        request's fault, and charging it would fail innocent requests."""
        rows = np.flatnonzero(self.active)
        self.obs.on_fault("array_loss", f"rows={rows.size}", self.step_idx)
        for row in rows:
            self._preempt(int(row))
            self._fault_stats["array_loss_requeues"] += 1
        if self._prefix_index is not None:
            # the arenas behind every cached prefix are gone with the
            # array — the index must not serve stale physical pages
            self._prefix_index.invalidate(self.store)
        self._fault_stats["array_losses"] += 1
        return int(rows.size)

    def _fault_pass(self) -> None:
        """One inject -> detect -> heal cycle. Detected-corrupt units heal
        by scrub-from-master where a master exists (static prefix bands),
        else by recompute-via-preemption with bounded exponential-backoff
        retry; recovery traffic is billed to the energy ledger's
        "recovery" group like any other maintenance."""
        bad = self.scheduler.fault_pass(self.step_idx)
        for key in bad:
            self.obs.on_fault("detect", str(key), self.step_idx)
            self.energy_ledger.add(
                imc_energy.refresh_events(self.store.fault_unit_bytes(key)),
                "recovery")
            if self.store.scrub_from_master(key):
                self._fault_stats["recovered_scrub"] += 1
                self.obs.on_fault("heal_scrub", str(key), self.step_idx)
                continue
            row = self.store.fault_row(key)
            if row is None or not self.active[row]:
                continue    # second corrupt unit of an already-healed row
            self._heal_row_recompute(int(row))

    def _heal_row_recompute(self, row: int) -> None:
        """Recompute-via-preemption: no master exists for decode-band
        storage, so the row's state is rebuilt from its token history
        (deterministic greedy recompute — token-identical on resume).
        Each retry backs off exponentially; a request that exceeds
        cfg.amc.max_retries is failed, never silently served."""
        entry = self._slot_entry[row]
        retries = entry.fault_retries + 1
        if retries > self.cfg.amc.max_retries:
            self._fail_row(row)
            return
        gen = np.asarray(self.outputs[entry.req.id], np.int32)
        resumed = QueueEntry(
            req=entry.req,
            prompt=np.concatenate([entry.base_prompt, gen]),
            base_prompt=entry.base_prompt,
            remaining=int(self.remaining[row]),
            resumed=True, enqueue_step=self.step_idx,
            fault_retries=retries,
            not_before=self.step_idx + 2 ** (retries - 1))
        self.scheduler.release_row(row)
        self.active[row] = False
        self.slot_req[row] = None
        self._slot_entry[row] = None
        self.obs.on_fault("heal_recompute", f"row{row}", self.step_idx)
        self.obs.on_preempt(entry.req.id, self.step_idx, "fault_recompute")
        self.scheduler.enqueue(resumed, front=True)
        self._fault_stats["recovered_recompute"] += 1
        self._fault_stats["retried"] += 1

    def _fail_row(self, row: int) -> None:
        """Retry budget exhausted: surface the request in `failed` with
        whatever it generated — an explicit uncorrectable outcome, never
        a silently corrupt completion."""
        entry = self._slot_entry[row]
        self.failed[entry.req.id] = self.outputs.pop(entry.req.id, [])
        self.scheduler.release_row(row)
        self.active[row] = False
        self.slot_req[row] = None
        self._slot_entry[row] = None
        self.obs.on_fault("uncorrectable", f"req{entry.req.id}",
                          self.step_idx)
        self.obs.on_failed(entry.req.id, self.step_idx)
        self._fault_stats["uncorrectable"] += 1

    # -- stats -----------------------------------------------------------------

    def stats(self) -> dict:
        """Augmented-storage accounting (the paper's capacity headline).

        Logical bytes = what the dense bf16 representation would occupy;
        physical bytes = what the store's staged planes actually occupy
        (paged pools exclude the write-dump lines; `describe()` has the
        raw numbers). Store/scheduler/refresh counters ride along under
        "pool" and "scheduler".
        """
        a = self.cfg.amc
        weight_phys = sum(x.nbytes for x in jax.tree.leaves(self.params))
        cache_phys = self.store.physical_bytes()
        # families augment_params doesn't cover keep dense weights: report
        # the physical reality, not the requested mode
        weight_mode = (a.weight_mode if augment.is_augmented(self.params)
                       else "normal")
        wmode = amc.WEIGHT_MODES[weight_mode]
        out = {
            "kv_mode": a.kv_mode,
            "weight_mode": weight_mode,
            "weight_bits_per_value": amc.mode_bits_per_value(
                wmode, a.ternary_fmt),
            "kv_bits_per_value": amc.KV_BITS_PER_VALUE[a.kv_mode],
            "weight_bytes_logical": self._logical_weight_bytes,
            "weight_bytes_physical": weight_phys,
            "weight_capacity_factor": self._logical_weight_bytes
                                      / weight_phys,
            "cache_bytes_logical": self._logical_cache_bytes,
            "cache_bytes_physical": cache_phys,
            "cache_capacity_factor": self._logical_cache_bytes / cache_phys,
            "total_bytes_logical": (self._logical_weight_bytes
                                    + self._logical_cache_bytes),
            "total_bytes_physical": weight_phys + cache_phys,
            "capacity_factor": (self._logical_weight_bytes
                                + self._logical_cache_bytes)
                               / (weight_phys + cache_phys),
            "dispatches": self.dispatch_count,
        }
        # array-level event/energy accounting (imc/energy.py): weight-side
        # events follow matmul_impl (IMC wordline/bitline/ADC vs fetch),
        # state reads are split by page/slab mode — Normal storage costs
        # 6T read events, Augmented the 8T dynamic-read events (the
        # paper's Tables III/IV structure)
        E = imc_energy.EVENT_ENERGY_FJ
        self._sync_refresh_events()
        imc = self.energy_ledger.describe()
        imc["matmul_impl"] = a.matmul_impl
        imc["imc_abits"] = a.imc_abits
        imc["kv_read_fj_per_value_normal_mode"] = 16 * E["read_6t"]
        imc["kv_read_fj_per_value_augmented_mode"] = (
            self.store.aug_bits * E["read_8t_dynamic"])
        imc["refresh_energy_fj"] = imc["groups"].get(
            "refresh", {}).get("energy_fj", 0.0)
        out["imc"] = imc
        sp = dict(self._spec_stats)
        nd = sp["draft_dispatches"] + sp["verify_dispatches"]
        sp.update({
            "enabled": self._spec,
            "spec_k": self.spec_k,
            "spec_draft_impl": a.spec_draft_impl,
            # the speedup headline: useful tokens per device dispatch
            # across the whole draft + verify round (stepwise decode is
            # 1.0 by construction)
            "accepted_tokens_per_dispatch":
                sp["accepted_tokens"] / nd if nd else 0.0,
            "accepted_tokens_per_round":
                sp["accepted_tokens"] / sp["spec_rounds"]
                if sp["spec_rounds"] else 0.0,
        })
        out["spec"] = sp
        # retention-fault accounting: injection/detection counters from
        # the store(s), recovery outcomes from the engine, and the
        # zero-silent-corruption property — with integrity on, every
        # injected fault is either detected or masked (its storage was
        # released before any read); nothing corrupt is ever served
        fc = self.store.fault_counters()
        pending = self.store.faults_pending()
        injected = fc["faults_injected"]
        served_clean = injected == (fc["faults_detected"]
                                    + fc["faults_masked"])
        out["faults"] = {
            "enabled": self._fault_model is not None,
            "fault_rate": a.fault_rate,
            "fault_seed": a.fault_seed,
            "array_loss_rate": a.array_loss_rate,
            "fault_temp_c": a.fault_temp_c,
            "integrity_check": a.integrity_check,
            "max_retries": a.max_retries,
            "fault_pin_threshold": a.fault_pin_threshold,
            **fc,
            **self._fault_stats,
            "faults_pending": pending,
            "recovered": (self._fault_stats["recovered_scrub"]
                          + self._fault_stats["recovered_recompute"]),
            "failed_requests": len(self.failed),
            "supervisor_restarts": self.supervisor.restarts,
            "recovery_energy_fj": imc["groups"].get(
                "recovery", {}).get("energy_fj", 0.0),
            "zero_silent_corruption": bool(
                injected == 0 or (a.integrity_check and served_clean
                                  and pending == 0)),
        }
        pool = self.store.describe()
        out["pool"] = pool
        out["prefix"] = {
            "enabled": self._prefix_index is not None,
            "prefill_dispatches": self.prefill_dispatch_count,
            "dispatches_saved": self._prefix_saved,
            "cow_events": pool.get("cow_events", 0),
            "cow_bytes": pool.get("cow_bytes", 0),
            "demotions": pool.get("prefix_demotions", 0),
            "evictions": pool.get("prefix_evictions", 0),
            "pages_shared": pool.get("pages_shared", 0),
            "bytes_shared": pool.get("bytes_shared", 0),
        }
        if self._prefix_index is not None:
            out["prefix"].update(self._prefix_index.describe())
        out["scheduler"] = self.scheduler.describe()
        for k in ("refreshes", "refresh_bytes", "augment_events",
                  "promote_events", "maintenance_dispatches"):
            out[k] = pool[k]
        out["preemptions"] = self.scheduler.stats["preemptions"]
        # per-step wall times (straggler monitor feed — recorded on every
        # run, fault model or not) and the observability planes; both
        # describes are pure snapshots, so stats() stays idempotent
        out["step_times"] = self.straggler.describe()
        out["obs"] = self.obs.describe()
        return out

    # -- observability ----------------------------------------------------------

    def _obs_sample_payload(self) -> dict:
        """One time-series tick of the store/scheduler/energy state (the
        mode-mix, occupancy, refresh-debt and energy-group timelines)."""
        mode_n, mode_a = self.store.mode_mix()
        payload = {
            "pool_occupancy": self.store.live_bytes
                              / max(self.store.budget_bytes, 1),
            "mode_normal": mode_n,
            "mode_augmented": mode_a,
            "queue_depth": len(self.scheduler.queue),
            "running": int(self.active.sum()),
            "refresh_debt": self.store.max_augmented_age(self.step_idx),
        }
        E = imc_energy.EVENT_ENERGY_FJ
        for (group, cls), n in self.energy_ledger.counts.items():
            k = "energy_" + group + "_fj"
            payload[k] = payload.get(k, 0.0) + E[cls] * n
        return payload

    def export_trace(self, path: str) -> dict:
        """Write the Chrome trace-event JSON (perfetto-loadable)."""
        return self.obs.export_trace(path)

    def export_metrics(self, path: str) -> str:
        """Write the Prometheus text exposition of the metrics plane."""
        return self.obs.export_metrics(path)

    def generate(self, requests: list[Request]) -> dict[int, list[int]]:
        """Run all requests to completion: enqueue everything, then step
        until the queue AND the running batch drain. Zero drops — the
        scheduler admits from the queue between decode steps."""
        for req in requests:
            self.add_request(req)
        while self.active.any() or self._queue:
            if not self.active.any():
                self._admit()
                if not self.active.any():
                    if self.scheduler.backlog_ready(self.step_idx):
                        raise RuntimeError(
                            "queued requests but nothing admittable — "
                            "store misconfigured (budget below one "
                            "sequence?)")
                    # every queued entry is in fault-retry backoff: tick
                    # the step clock until one becomes eligible
                    self.step_idx += 1
                    continue
            self.step_all()
        return self.outputs
