"""Batched serving engine with AMC-augmented KV storage.

Prefill fills the cache (packed int4/int8 when cfg.amc.kv_mode says so —
the dynamic plane), decode steps run against it. Implements continuous
batching at the slot level: finished sequences release their cache rows to
new requests (positions are per-row, the validity mask handles ragged
lengths). The FILO discipline of the paper maps cleanly: per slot, static
context (weights / cross-KV) is written once, the per-step KV stream is
dynamic and drained (attended) before the slot is re-written.

Hot-path shape: a P-token prompt costs ceil(P / prefill_chunk) jitted
dispatches (`prefill_chunk_step` scatters each chunk's packed KV straight
into the slot's cache rows), not P full-batch decode steps; decode-side
host bookkeeping (positions / remaining / active) is vectorized numpy, so
`step_all` does no per-slot Python in the steady state beyond appending
each generated token to its request's output list.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import Rules
from repro.launch.mesh import mesh_context
from repro.models import model as M
from repro.models.params import init_params


@dataclasses.dataclass(eq=False)
class Request:
    prompt: np.ndarray            # (plen,) int32
    max_new_tokens: int = 16
    id: int = 0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, mesh, *, max_batch: int = 8,
                 max_seq: int = 256, prefill_chunk: int = 32, params=None,
                 seed: int = 0):
        self.cfg, self.mesh = cfg, mesh
        self.max_batch, self.max_seq = max_batch, max_seq
        self.prefill_chunk = min(prefill_chunk, max_seq)
        shape = ShapeConfig("serve", max_seq, max_batch, "decode")
        self.rules = Rules.make(mesh, cfg, shape)
        ap = M.abstract_params(cfg)
        with mesh_context(mesh):
            if params is None:
                params = init_params(ap, jax.random.PRNGKey(seed))
            self.params = params
            ca = M.abstract_cache(cfg, shape)
            self.cache = jax.tree.map(
                lambda l: jnp.zeros(l.shape, l.jdtype), ca,
                is_leaf=lambda x: hasattr(x, "jdtype"))
        self._decode = jax.jit(
            lambda p, c, b: M.decode_step(cfg, p, c, b, rules=self.rules),
            donate_argnums=(1,))
        self._prefill = None
        if M.supports_prefill(cfg):
            self._prefill = jax.jit(
                lambda p, c, b: M.prefill_step(cfg, p, c, b,
                                               rules=self.rules),
                donate_argnums=(1,))
        # slot bookkeeping (host side, int32 once — dispatched as-is)
        self.positions = np.zeros(max_batch, np.int32)
        self.remaining = np.zeros(max_batch, np.int32)
        self.active = np.zeros(max_batch, bool)
        self.last_token = np.zeros(max_batch, np.int32)
        self.slot_req: list[Optional[Request]] = [None] * max_batch
        self.outputs: dict[int, list[int]] = {}
        self.dispatch_count = 0   # jitted device dispatches (prefill+decode)

    # -- continuous batching --------------------------------------------------

    def add_request(self, req: Request):
        """Claim a free slot; prefill it. Returns the slot or None."""
        free = np.flatnonzero(~self.active)
        if free.size == 0:
            return None
        slot = int(free[0])
        self.active[slot] = True
        self.slot_req[slot] = req
        self.positions[slot] = 0
        self.remaining[slot] = req.max_new_tokens
        self.outputs[req.id] = []
        prompt = np.asarray(req.prompt, np.int32)
        # feed prompt[:-1] into the cache (the last prompt token is fed by
        # the first batched decode step, whose argmax is the first
        # generated token)
        if prompt.size > 1:
            self.prefill(slot, prompt[:-1])
        self.last_token[slot] = int(prompt[-1]) if prompt.size else 0
        return slot

    def prefill(self, slot: int, tokens: np.ndarray,
                return_next: bool = False) -> Optional[int]:
        """Feed `tokens` into the slot's cache rows.

        One jitted dispatch per `prefill_chunk` tokens — ceil(P / chunk)
        total, vs P decode steps for the per-token warmup loop. With
        `return_next` also returns the greedy continuation of the last
        prefilled token — that argmax blocks on the async dispatches, so
        the admission hot path (`add_request`) leaves it off.
        """
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        if tokens.size == 0:
            return None
        if self._prefill is None:           # family without chunked prefill
            return self._prefill_stepwise(slot, tokens)
        C = self.prefill_chunk
        write_mask = np.zeros(self.max_batch, bool)
        write_mask[slot] = True
        last_logits, last_n = None, 0
        for start in range(0, tokens.size, C):
            chunk = tokens[start:start + C]
            if self.positions[slot] + C > self.max_seq:
                # a padded chunk would spill past the cache end (the
                # scatter would clamp and corrupt this row's own prefix)
                return self._prefill_stepwise(slot, tokens[start:])
            n = chunk.size
            tok = np.zeros((self.max_batch, C), np.int32)
            tok[slot, :n] = chunk
            batch = {"tokens": jnp.asarray(tok),
                     "positions": jnp.asarray(self.positions),
                     "write_mask": jnp.asarray(write_mask)}
            with mesh_context(self.mesh):
                logits, self.cache = self._prefill(self.params, self.cache,
                                                   batch)
            self.dispatch_count += 1
            self.positions[slot] += n
            last_logits, last_n = logits, n
        if not return_next:
            return None
        return int(jnp.argmax(last_logits[slot, last_n - 1]))

    def _prefill_stepwise(self, slot: int, tokens: np.ndarray):
        last = None
        for t in tokens:
            last = self._step_slot(slot, int(t))
        return last

    def _step_slot(self, slot: int, token: int) -> int:
        tokens = np.zeros((self.max_batch, 1), np.int32)
        tokens[slot, 0] = token
        batch = {"tokens": jnp.asarray(tokens),
                 "positions": jnp.asarray(self.positions)}
        with mesh_context(self.mesh):
            logits, self.cache = self._decode(self.params, self.cache, batch)
        self.dispatch_count += 1
        self.positions[slot] += 1
        return int(jnp.argmax(logits[slot, -1]))

    def step_all(self, last_tokens: Optional[dict[int, int]] = None) -> dict:
        """One batched decode step for every active slot.

        `last_tokens` optionally overrides the tracked per-slot feed
        token (kept for API compatibility; `generate` no longer needs
        it). Returns {slot: next_token} for slots still running.
        """
        if last_tokens:
            for s, t in last_tokens.items():
                self.last_token[s] = t
        tokens = np.where(self.active, self.last_token, 0
                          ).astype(np.int32)[:, None]
        batch = {"tokens": jnp.asarray(tokens),
                 "positions": jnp.asarray(self.positions)}
        with mesh_context(self.mesh):
            logits, self.cache = self._decode(self.params, self.cache, batch)
        self.dispatch_count += 1
        arg = np.asarray(jnp.argmax(logits[:, -1], axis=-1)).astype(np.int32)
        # vectorized slot bookkeeping: no per-slot Python for the numeric
        # state, only the per-request output append below
        act = self.active.copy()
        self.positions[act] += 1
        self.remaining[act] -= 1
        self.last_token = np.where(act, arg, self.last_token)
        done = act & ((self.remaining <= 0)
                      | (self.positions >= self.max_seq - 1))
        self.active &= ~done
        for s in np.flatnonzero(act):
            self.outputs[self.slot_req[s].id].append(int(arg[s]))
        for s in np.flatnonzero(done):
            self.slot_req[s] = None          # release slot (cont. batching)
        return {int(s): int(arg[s]) for s in np.flatnonzero(act & ~done)}

    def generate(self, requests: list[Request]) -> dict[int, list[int]]:
        """Run all requests to completion with slot-level batching."""
        pending = list(requests)
        while pending or self.active.any():
            while pending and self.add_request(pending[0]) is not None:
                pending.pop(0)
            self.step_all()
        return self.outputs
