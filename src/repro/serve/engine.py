"""Batched serving engine with AMC-augmented KV storage.

Prefill fills the cache (packed int4/int8 when cfg.amc.kv_mode says so —
the dynamic plane), decode steps run against it. Implements continuous
batching at the slot level: finished sequences release their cache rows to
new requests (positions are per-row, the validity mask handles ragged
lengths). The FILO discipline of the paper maps cleanly: per slot, static
context (weights / cross-KV) is written once, the per-step KV stream is
dynamic and drained (attended) before the slot is re-written.

Hot-path shape: a P-token prompt costs ceil(P / prefill_chunk) jitted
dispatches (`prefill_chunk_step` scatters each chunk's packed KV straight
into the slot's cache rows), not P full-batch decode steps; decode-side
host bookkeeping (positions / remaining / active) is vectorized numpy, so
`step_all` does no per-slot Python in the steady state beyond appending
each generated token to its request's output list.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import amc
from repro.distributed.sharding import Rules
from repro.launch.mesh import mesh_context
from repro.models import augment
from repro.models import model as M
from repro.models.params import init_params, is_pspec


@dataclasses.dataclass(eq=False)
class Request:
    prompt: np.ndarray            # (plen,) int32
    max_new_tokens: int = 16
    id: int = 0


def _abstract_bytes(tree) -> int:
    """Total bytes of a PSpec tree (dense logical footprint)."""
    leaves = jax.tree.leaves(tree, is_leaf=is_pspec)
    return sum(int(np.prod(l.shape)) * jnp.dtype(l.jdtype).itemsize
               for l in leaves)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, mesh, *, max_batch: int = 8,
                 max_seq: int = 256, prefill_chunk: int = 32, params=None,
                 weight_mode: Optional[str] = None,
                 kv_mode: Optional[str] = None, seed: int = 0):
        # engine-level AMC knobs override the config (e.g. serve a dense
        # checkpoint with ternary weights without touching the arch file)
        if weight_mode is not None or kv_mode is not None:
            cfg = dataclasses.replace(cfg, amc=dataclasses.replace(
                cfg.amc,
                weight_mode=weight_mode or cfg.amc.weight_mode,
                kv_mode=kv_mode or cfg.amc.kv_mode))
        self.cfg, self.mesh = cfg, mesh
        self.max_batch, self.max_seq = max_batch, max_seq
        self.prefill_chunk = min(prefill_chunk, max_seq)
        shape = ShapeConfig("serve", max_seq, max_batch, "decode")
        self.rules = Rules.make(mesh, cfg, shape)
        dense_cfg = dataclasses.replace(
            cfg, amc=dataclasses.replace(cfg.amc, weight_mode="normal"))
        with mesh_context(mesh):
            if params is None:
                params = init_params(M.abstract_params(dense_cfg),
                                     jax.random.PRNGKey(seed))
            # pack the matmul weights into augmented storage (no-op for
            # weight_mode="normal", already-packed trees, other families)
            self.params = augment.augment_params(cfg, params)
            ca = M.abstract_cache(cfg, shape)
            self.cache = jax.tree.map(
                lambda l: jnp.zeros(l.shape, l.jdtype), ca,
                is_leaf=lambda x: hasattr(x, "jdtype"))
        self._logical_weight_bytes = _abstract_bytes(
            M.abstract_params(dense_cfg))
        self._logical_cache_bytes = _abstract_bytes(M.abstract_cache(
            dataclasses.replace(
                cfg, amc=dataclasses.replace(cfg.amc, kv_mode="normal")),
            shape))
        self._decode = jax.jit(
            lambda p, c, b: M.decode_step(cfg, p, c, b, rules=self.rules),
            donate_argnums=(1,))
        self._prefill = None
        if M.supports_prefill(cfg):
            self._prefill = jax.jit(
                lambda p, c, b: M.prefill_step(cfg, p, c, b,
                                               rules=self.rules),
                donate_argnums=(1,))
        # slot bookkeeping (host side, int32 once — dispatched as-is)
        self.positions = np.zeros(max_batch, np.int32)
        self.remaining = np.zeros(max_batch, np.int32)
        self.active = np.zeros(max_batch, bool)
        self.last_token = np.zeros(max_batch, np.int32)
        self.slot_req: list[Optional[Request]] = [None] * max_batch
        self.outputs: dict[int, list[int]] = {}
        self.dispatch_count = 0   # jitted device dispatches (prefill+decode)

    # -- continuous batching --------------------------------------------------

    def add_request(self, req: Request):
        """Claim a free slot; prefill it. Returns the slot or None."""
        if np.asarray(req.prompt).size > self.max_seq:
            # past max_seq every cache write would clamp to the last slot,
            # silently corrupting the row — reject instead
            raise ValueError(
                f"prompt of {np.asarray(req.prompt).size} tokens exceeds "
                f"max_seq={self.max_seq} cache slots")
        free = np.flatnonzero(~self.active)
        if free.size == 0:
            return None
        slot = int(free[0])
        self.active[slot] = True
        self.slot_req[slot] = req
        self.positions[slot] = 0
        self.remaining[slot] = req.max_new_tokens
        self.outputs[req.id] = []
        prompt = np.asarray(req.prompt, np.int32)
        # feed prompt[:-1] into the cache (the last prompt token is fed by
        # the first batched decode step, whose argmax is the first
        # generated token)
        if prompt.size > 1:
            self.prefill(slot, prompt[:-1])
        self.last_token[slot] = int(prompt[-1]) if prompt.size else 0
        return slot

    def prefill(self, slot: int, tokens: np.ndarray,
                return_next: bool = False) -> Optional[int]:
        """Feed `tokens` into the slot's cache rows.

        One jitted dispatch per `prefill_chunk` tokens — ceil(P / chunk)
        total, vs P decode steps for the per-token warmup loop. With
        `return_next` also returns the greedy continuation of the last
        prefilled token — that argmax blocks on the async dispatches, so
        the admission hot path (`add_request`) leaves it off.
        """
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        if tokens.size == 0:
            return None
        if self._prefill is None:           # family without chunked prefill
            return self._prefill_stepwise(slot, tokens)
        C = self.prefill_chunk
        write_mask = np.zeros(self.max_batch, bool)
        write_mask[slot] = True
        last_logits, last_n = None, 0
        for start in range(0, tokens.size, C):
            chunk = tokens[start:start + C]
            n = chunk.size
            p = int(self.positions[slot])
            if p + n > self.max_seq:
                # genuinely no room for the real tokens
                return self._prefill_stepwise(slot, tokens[start:])
            # A padded dispatch writes C slots; near the cache end the
            # scatter start is shifted left so the write window is
            # [max_seq - C, max_seq) and the left-pad REPLAYS the last
            # `shift` already-prefilled tokens (deterministic recompute ->
            # bit-identical KV rewrite, exact attention). A short final
            # chunk therefore still costs ONE dispatch instead of falling
            # back to per-token steps.
            shift = max(0, p + C - self.max_seq)
            if shift > start:
                # the replay tokens precede this call's buffer
                return self._prefill_stepwise(slot, tokens[start:])
            tok = np.zeros((self.max_batch, C), np.int32)
            tok[slot, :shift + n] = tokens[start - shift:start + n]
            positions = self.positions.copy()
            positions[slot] = p - shift
            batch = {"tokens": jnp.asarray(tok),
                     "positions": jnp.asarray(positions),
                     "write_mask": jnp.asarray(write_mask)}
            with mesh_context(self.mesh):
                logits, self.cache = self._prefill(self.params, self.cache,
                                                   batch)
            self.dispatch_count += 1
            self.positions[slot] += n
            last_logits, last_n = logits, shift + n
        if not return_next:
            return None
        return int(jnp.argmax(last_logits[slot, last_n - 1]))

    def _prefill_stepwise(self, slot: int, tokens: np.ndarray):
        last = None
        for t in tokens:
            last = self._step_slot(slot, int(t))
        return last

    def _step_slot(self, slot: int, token: int) -> int:
        tokens = np.zeros((self.max_batch, 1), np.int32)
        tokens[slot, 0] = token
        batch = {"tokens": jnp.asarray(tokens),
                 "positions": jnp.asarray(self.positions)}
        with mesh_context(self.mesh):
            logits, self.cache = self._decode(self.params, self.cache, batch)
        self.dispatch_count += 1
        self.positions[slot] += 1
        return int(jnp.argmax(logits[slot, -1]))

    def step_all(self, last_tokens: Optional[dict[int, int]] = None) -> dict:
        """One batched decode step for every active slot.

        `last_tokens` optionally overrides the tracked per-slot feed
        token (kept for API compatibility; `generate` no longer needs
        it). Returns {slot: next_token} for slots still running.
        """
        if last_tokens:
            for s, t in last_tokens.items():
                self.last_token[s] = t
        tokens = np.where(self.active, self.last_token, 0
                          ).astype(np.int32)[:, None]
        batch = {"tokens": jnp.asarray(tokens),
                 "positions": jnp.asarray(self.positions)}
        with mesh_context(self.mesh):
            logits, self.cache = self._decode(self.params, self.cache, batch)
        self.dispatch_count += 1
        arg = np.asarray(jnp.argmax(logits[:, -1], axis=-1)).astype(np.int32)
        # vectorized slot bookkeeping: no per-slot Python for the numeric
        # state, only the per-request output append below
        act = self.active.copy()
        self.positions[act] += 1
        self.remaining[act] -= 1
        self.last_token = np.where(act, arg, self.last_token)
        done = act & ((self.remaining <= 0)
                      | (self.positions >= self.max_seq - 1))
        self.active &= ~done
        for s in np.flatnonzero(act):
            self.outputs[self.slot_req[s].id].append(int(arg[s]))
        for s in np.flatnonzero(done):
            self.slot_req[s] = None          # release slot (cont. batching)
        return {int(s): int(arg[s]) for s in np.flatnonzero(act & ~done)}

    def stats(self) -> dict:
        """Augmented-storage accounting (the paper's capacity headline).

        Logical bytes = what the dense bf16 representation would occupy;
        physical bytes = what the augmented planes actually occupy in HBM.
        `capacity_factor` is logical/physical — the augmentation ratio —
        alongside the per-plane bits/value of `AugmentedStore`'s ledger.
        """
        a = self.cfg.amc
        weight_phys = sum(x.nbytes for x in jax.tree.leaves(self.params))
        cache_phys = sum(x.nbytes for x in jax.tree.leaves(self.cache))
        # families augment_params doesn't cover keep dense weights: report
        # the physical reality, not the requested mode
        weight_mode = (a.weight_mode if augment.is_augmented(self.params)
                       else "normal")
        wmode = amc.WEIGHT_MODES[weight_mode]
        return {
            "kv_mode": a.kv_mode,
            "weight_mode": weight_mode,
            "weight_bits_per_value": amc.mode_bits_per_value(
                wmode, a.ternary_fmt),
            "kv_bits_per_value": amc.KV_BITS_PER_VALUE[a.kv_mode],
            "weight_bytes_logical": self._logical_weight_bytes,
            "weight_bytes_physical": weight_phys,
            "weight_capacity_factor": self._logical_weight_bytes
                                      / weight_phys,
            "cache_bytes_logical": self._logical_cache_bytes,
            "cache_bytes_physical": cache_phys,
            "cache_capacity_factor": self._logical_cache_bytes / cache_phys,
            "total_bytes_logical": (self._logical_weight_bytes
                                    + self._logical_cache_bytes),
            "total_bytes_physical": weight_phys + cache_phys,
            "capacity_factor": (self._logical_weight_bytes
                                + self._logical_cache_bytes)
                               / (weight_phys + cache_phys),
            "dispatches": self.dispatch_count,
        }

    def generate(self, requests: list[Request]) -> dict[int, list[int]]:
        """Run all requests to completion with slot-level batching."""
        pending = list(requests)
        while pending or self.active.any():
            while pending and self.add_request(pending[0]) is not None:
                pending.pop(0)
            self.step_all()
        return self.outputs
