"""Batched serving engine with AMC-augmented KV storage.

Prefill fills the cache (packed int4/int8 when cfg.amc.kv_mode says so —
the dynamic plane), decode steps run against it. Implements continuous
batching at the slot level: finished sequences release their cache rows to
new requests (positions are per-row, the validity mask handles ragged
lengths). The FILO discipline of the paper maps cleanly: per slot, static
context (weights / cross-KV) is written once, the per-step KV stream is
dynamic and drained (attended) before the slot is re-written.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import Rules
from repro.models import model as M
from repro.models.params import init_params, to_shape_dtype
from repro.train import step as step_lib


@dataclasses.dataclass(eq=False)
class Request:
    prompt: np.ndarray            # (plen,) int32
    max_new_tokens: int = 16
    id: int = 0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, mesh, *, max_batch: int = 8,
                 max_seq: int = 256, params=None, seed: int = 0):
        self.cfg, self.mesh = cfg, mesh
        self.max_batch, self.max_seq = max_batch, max_seq
        shape = ShapeConfig("serve", max_seq, max_batch, "decode")
        self.rules = Rules.make(mesh, cfg, shape)
        ap = M.abstract_params(cfg)
        with jax.set_mesh(mesh):
            if params is None:
                params = init_params(ap, jax.random.PRNGKey(seed))
            self.params = params
            ca = M.abstract_cache(cfg, shape)
            self.cache = jax.tree.map(
                lambda l: jnp.zeros(l.shape, l.jdtype), ca,
                is_leaf=lambda x: hasattr(x, "jdtype"))
        self._decode = jax.jit(
            lambda p, c, b: M.decode_step(cfg, p, c, b, rules=self.rules),
            donate_argnums=(1,))
        # slot bookkeeping (host side)
        self.positions = np.zeros(max_batch, np.int64)
        self.remaining = np.zeros(max_batch, np.int64)
        self.active = np.zeros(max_batch, bool)
        self.slot_req: list[Optional[Request]] = [None] * max_batch
        self.outputs: dict[int, list[int]] = {}

    # -- continuous batching --------------------------------------------------

    def add_request(self, req: Request):
        """Claim a free slot; prefill it. Returns the slot or None."""
        free = np.where(~self.active)[0]
        if len(free) == 0:
            return None
        slot = int(free[0])
        self.active[slot] = True
        self.slot_req[slot] = req
        self.positions[slot] = 0
        self.remaining[slot] = req.max_new_tokens
        self.outputs[req.id] = []
        # feed prompt[:-1] through decode steps for this slot (simple
        # warmup prefill; the last prompt token is fed by the first
        # batched decode step, whose argmax is the first generated token)
        for t in req.prompt[:-1]:
            self._step_slot(slot, int(t))
        return slot

    def _step_slot(self, slot: int, token: int) -> int:
        tokens = np.zeros((self.max_batch, 1), np.int32)
        tokens[slot, 0] = token
        pos = np.asarray(self.positions, np.int32)
        batch = {"tokens": jnp.asarray(tokens),
                 "positions": jnp.asarray(pos)}
        with jax.set_mesh(self.mesh):
            logits, self.cache = self._decode(self.params, self.cache, batch)
        self.positions[slot] += 1
        return int(jnp.argmax(logits[slot, -1]))

    def step_all(self, last_tokens: dict[int, int]) -> dict[int, int]:
        """One batched decode step for every active slot."""
        tokens = np.zeros((self.max_batch, 1), np.int32)
        for s in range(self.max_batch):
            if self.active[s]:
                tokens[s, 0] = last_tokens.get(s, 0)
        batch = {"tokens": jnp.asarray(tokens),
                 "positions": jnp.asarray(self.positions, np.int32)}
        with jax.set_mesh(self.mesh):
            logits, self.cache = self._decode(self.params, self.cache, batch)
        out = {}
        arg = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for s in range(self.max_batch):
            if not self.active[s]:
                continue
            self.positions[s] += 1
            nxt = int(arg[s])
            req = self.slot_req[s]
            self.outputs[req.id].append(nxt)
            self.remaining[s] -= 1
            if self.remaining[s] <= 0 or self.positions[s] >= self.max_seq - 1:
                self.active[s] = False   # release slot (continuous batching)
                self.slot_req[s] = None
            else:
                out[s] = nxt
        return out

    def generate(self, requests: list[Request]) -> dict[int, list[int]]:
        """Run all requests to completion with slot-level batching."""
        pending = list(requests)
        last: dict[int, int] = {}
        while pending or self.active.any():
            while pending:
                slot = self.add_request(pending[0])
                if slot is None:
                    break
                req = pending.pop(0)
                last[slot] = int(req.prompt[-1]) if len(req.prompt) else 0
            last = self.step_all(last)
        return self.outputs
