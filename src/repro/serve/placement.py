"""Fleet placement: which logical SRAM array a request lands on.

The paper's unit of capacity is an *array* — its own Normal/Augmented
planes, byte budget and retention clock. `ArrayFleet` (serve/fleet.py)
runs one `ServeEngine` per array; this module owns the two pure-policy
pieces the fleet composes:

  * `PlacementPolicy` subclasses score `ArrayView` snapshots and pick an
    array for each incoming request:
      - least-loaded      fewest running + queued requests (the default:
                          spreads admissions, maximizes aggregate
                          concurrency at fixed per-array bytes)
      - budget-headroom   most free bytes (budget - live), favoring the
                          array whose allocator is least pressured
      - affinity          stable prompt-prefix hash -> preferred array
                          (shared-prefix requests co-locate, so their
                          pages stay warm on one array's planes), falling
                          back to least-loaded when the preferred array
                          cannot admit right now
  * device partitioning: N arrays over the jax mesh — contiguous device
    groups when devices >= arrays (each array's projections then shard
    tensor-parallel over its own "model" axis via distributed/sharding
    Rules, replicating where head counts don't divide), round-robin
    device *sharing* otherwise (the `jax.sharding`-over-host case: on one
    CPU device every array is a logical array on the same device).

Policies never mutate engines: they read `ArrayView` snapshots the fleet
builds per decision, so placement invariants are unit-testable without
devices.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Callable, Optional

import numpy as np


@dataclasses.dataclass
class ArrayView:
    """One array's admission-relevant state at a placement decision."""
    aid: int                     # array index in the fleet
    alive: bool                  # False once drained by an array loss
    running: int                 # active rows
    queued: int                  # scheduler queue depth
    free_rows: int               # max_batch - running
    live_bytes: int
    budget_bytes: int
    # store.can_admit_tokens probe (counts augmentation headroom)
    admit_probe: Optional[Callable[[int], bool]] = None
    # engine.prefix_probe: tokens of a prompt the array's prefix cache
    # already holds (None on fleets without prefix caching)
    prefix_probe: Optional[Callable[[np.ndarray], int]] = None

    @property
    def load(self) -> int:
        return self.running + self.queued

    @property
    def headroom_bytes(self) -> int:
        return self.budget_bytes - self.live_bytes

    def can_admit_now(self, n_tokens: int) -> bool:
        if self.free_rows <= 0:
            return False
        if self.admit_probe is None:
            return True
        return self.admit_probe(max(int(n_tokens), 1))


class PlacementPolicy:
    """Base: pick an alive array for a prompt. Deterministic — equal
    scores break toward the lower array id, so fleet runs reproduce."""

    name = "base"

    def place(self, prompt: np.ndarray, views: list[ArrayView]) -> int:
        alive = [v for v in views if v.alive]
        if not alive:
            raise RuntimeError(
                "no surviving arrays in the fleet — every array was "
                "drained by an array-loss event")
        return self._pick(prompt, alive)

    def _pick(self, prompt: np.ndarray, alive: list[ArrayView]) -> int:
        raise NotImplementedError


class LeastLoaded(PlacementPolicy):
    name = "least-loaded"

    def _pick(self, prompt, alive):
        return min(alive,
                   key=lambda v: (v.load, -v.headroom_bytes, v.aid)).aid


class BudgetHeadroom(PlacementPolicy):
    name = "budget-headroom"

    def _pick(self, prompt, alive):
        return min(alive,
                   key=lambda v: (-v.headroom_bytes, v.load, v.aid)).aid


class Affinity(PlacementPolicy):
    """Shared-prefix locality, strongest signal first:

    1. PREFIX: the array whose `PrefixIndex` already holds the deepest
       cached prefix of this prompt (ties break to the lower array id) —
       the request maps those pages by refcount and skips their prefill.
    2. HASH: no array holds the prefix yet — crc32 of the first
       `prefix_tokens` tokens picks a stable preferred array, so a
       common system prompt CONCENTRATES on one array's planes (crc32,
       NOT Python's salted hash: placement reproduces across processes).
    3. FALLBACK: the choice above cannot admit right now — deterministic
       least-loaded among the OTHER alive arrays (the over-budget array
       is excluded, so the fallback is never a disguised retry).

    `last_reason` records which rung decided — the fleet surfaces it in
    `stats()["placement"]["decisions"]`, so a fallback is distinguishable
    from a plain least-loaded decision."""

    name = "affinity"
    prefix_tokens = 8

    def __init__(self):
        self.last_reason = "hash"

    def _pick(self, prompt, alive):
        flat = np.asarray(prompt, np.int32).reshape(-1)
        best, best_m = None, 0
        for v in alive:
            if v.prefix_probe is None:
                continue
            m = v.prefix_probe(flat)
            if m > best_m:
                best, best_m = v, m
        if best is not None and best.can_admit_now(flat.size):
            self.last_reason = "prefix"
            return best.aid
        h = zlib.crc32(flat[:self.prefix_tokens].tobytes())
        preferred = alive[h % len(alive)]
        if preferred.can_admit_now(flat.size):
            self.last_reason = "hash"
            return preferred.aid
        self.last_reason = "fallback"
        others = [v for v in alive if v.aid != preferred.aid]
        return LeastLoaded()._pick(prompt, others or alive)


POLICIES = {p.name: p for p in (LeastLoaded, BudgetHeadroom, Affinity)}


def make_policy(name: str) -> PlacementPolicy:
    if name not in POLICIES:
        raise ValueError(
            f"unknown placement policy {name!r} "
            f"(expected one of {sorted(POLICIES)})")
    return POLICIES[name]()


# -- device partitioning -------------------------------------------------------

def partition_devices(devices: list, num_arrays: int) -> list[list]:
    """N arrays over the available devices: contiguous equal groups when
    devices >= arrays (remainder devices stay idle — equal per-array
    compute keeps the fleet sweep's fixed-per-array-bytes comparison
    honest), round-robin sharing otherwise (several logical arrays per
    physical device — the over-host case; on one CPU device every array
    shares it)."""
    if num_arrays < 1:
        raise ValueError(f"num_arrays must be >= 1, got {num_arrays}")
    n = len(devices)
    if n >= num_arrays:
        per = n // num_arrays
        return [list(devices[i * per:(i + 1) * per])
                for i in range(num_arrays)]
    return [[devices[i % n]] for i in range(num_arrays)]


def make_array_meshes(num_arrays: int, mesh=None) -> list:
    """One jax mesh per array over a partition of `mesh`'s devices (the
    process-global devices when no mesh is given). Each array's devices
    land on the "model" axis: within an array the sharding Rules resolve
    head-sharded tensor-parallel projections where counts divide and
    replicate otherwise; across arrays the fleet is trivially parallel
    (each array serves its own requests)."""
    import jax
    from jax.sharding import Mesh
    devs = (list(np.asarray(mesh.devices).flat) if mesh is not None
            else list(jax.devices()))
    groups = partition_devices(devs, num_arrays)
    return [Mesh(np.asarray(g).reshape(1, len(g)), ("data", "model"))
            for g in groups]
