"""Paged, mode-switchable augmented KV pool — the serving-layer analogue of
the paper's on-demand capacity.

The pool manages fixed-size pages (``cfg.amc.page_size`` tokens × all
layers × K+V) that each live in one of two modes:

  Normal     1 logical bit per physical bit: bf16 rows in the ``kn``/``vn``
             arena (the 6T static mode).
  Augmented  capacity_factor > 1: int4/int8-packed rows + per-token scales
             in the ``kp``/``vp``/``ks``/``vs`` arena (the 8T/7T dynamic
             mode) — written through the existing `quantize_pack_kv` path.

One BYTE BUDGET models the physical array (the paper's SRAM macro): a
Normal page charges `page_bytes_normal` against it, an Augmented page only
`page_bytes_aug` (~3.6x less for int4+scales). Under memory pressure the
pool *augments* cold pages — move them to the packed plane, release the
byte difference — so more sequences can be admitted instead of rejected.
The two arenas are the staging areas for the two electrical configurations
of the same budgeted cells; `live_bytes <= budget_bytes` is the invariant
the allocator enforces.

Augmented pages are DYNAMIC: each carries a `core.retention.RefreshPolicy`
stamped on every write; after `retention_steps` decode steps the page
expires and the refresh scheduler must re-materialize it (restamp + traffic
accounting) or promote it back to Normal. `refresh_due()` lists expired
pages; the serving scheduler drains that list interleaved with decode.

Host-side metadata (numpy page tables, free lists, stamps) drives
device-side arenas (jax arrays, donated through the jitted decode step).
`device_tables()` emits the scalar-prefetch operands of the paged
attention kernel, including the HOLD-PREVIOUS gather indices that let the
mode-mismatched arena skip its DMA.

`PagedKVPool` implements the `serve.state_store.StateStore` interface
(alloc / free / gather-tables / augment / promote / refresh / bytes) — it
is the attention-KV member of the per-family store registry. With
``prefix_tokens > 0`` the page table grows a second band of rows
(``max_batch`` .. ``2*max_batch``) holding each slot's STATIC-LENGTH
prefix pages — the encoder-decoder cross-attention KV, written once at
admission and read with a fixed length every decode step (the paper's
static plane; cold by construction, so these are the first pages the
pressure policy augments).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import faults as F
from repro.core.retention import RefreshPolicy
from repro.kernels import ops as K
from repro.models import layers as L

POOL_MODES = ("normal-only", "augment-on-pressure", "always-augmented")


def resolve_pool_mode(cfg: ModelConfig) -> str:
    """Validated `cfg.amc.resolved_pool_mode` (auto follows kv_mode)."""
    mode = cfg.amc.resolved_pool_mode
    if mode not in POOL_MODES:
        raise ValueError(f"unknown pool_mode {mode!r}")
    return mode


def aug_bits_for(cfg: ModelConfig) -> int:
    """Augmented-plane width of this model's pool (cfg.amc.aug_bits)."""
    return cfg.amc.aug_bits


@dataclasses.dataclass(frozen=True)
class PageGeometry:
    """Static shape/byte facts of one pool instance."""
    n_layers: int
    kv_heads: int
    head_dim: int
    page_size: int
    aug_bits: int

    @property
    def d_store(self) -> int:
        return self.head_dim // 2 if self.aug_bits == 4 else self.head_dim

    @property
    def page_bytes_normal(self) -> int:
        # K + V, all layers, bf16
        return 2 * self.n_layers * self.kv_heads * self.page_size \
            * self.head_dim * 2

    @property
    def page_bytes_aug(self) -> int:
        # K + V packed rows + bf16 per-(token, head) scales
        return 2 * self.n_layers * self.kv_heads * self.page_size \
            * (self.d_store + 2)

    @property
    def capacity_factor(self) -> float:
        return self.page_bytes_normal / self.page_bytes_aug


class PagedKVPool:
    """See module docstring. `max_batch` bounds the running-batch width
    (rows of the page table); capacity in tokens is budget-bound, not
    row-bound."""

    def __init__(self, cfg: ModelConfig, *, max_batch: int, max_seq: int,
                 pages_normal: Optional[int] = None,
                 pages_packed: Optional[int] = None,
                 budget_bytes: Optional[int] = None,
                 retention_steps: Optional[int] = None,
                 prefix_tokens: int = 0, n_layers: Optional[int] = None):
        a = cfg.amc
        self.cfg = cfg
        self.pool_mode = resolve_pool_mode(cfg)
        self.geom = PageGeometry(cfg.n_layers if n_layers is None
                                 else n_layers, cfg.n_kv_heads, cfg.hd,
                                 a.page_size, aug_bits_for(cfg))
        self.max_batch = max_batch
        self.max_pages = -(-max_seq // a.page_size)          # ceil
        self.prefix_tokens = prefix_tokens
        self.prefix_pages = -(-prefix_tokens // a.page_size) \
            if prefix_tokens else 0
        self.retention_steps = (a.retention_steps if retention_steps is None
                                else retention_steps)
        B, maxP = max_batch, self.max_pages
        pbn, pba = self.geom.page_bytes_normal, self.geom.page_bytes_aug
        # per-row page cost: decode band + (optional) static prefix band
        row_pages = maxP + self.prefix_pages
        # default arena sizing: legacy-equivalent capacity (every row can
        # reach max_seq tokens in any mode the policy may choose)
        if pages_normal is None:
            pages_normal = 0 if self.pool_mode == "always-augmented" \
                else B * row_pages
        if pages_packed is None:
            pages_packed = 0 if self.pool_mode == "normal-only" \
                else B * row_pages
        self.pages_normal, self.pages_packed = pages_normal, pages_packed
        self.budget_bytes = (B * row_pages * pbn if budget_bytes is None
                             else budget_bytes)
        seq_cost = row_pages * (pbn if self.pool_mode == "normal-only"
                                else pba)
        if self.budget_bytes < seq_cost:
            raise ValueError(
                f"budget_bytes={self.budget_bytes} cannot hold one full "
                f"sequence ({seq_cost} B in the pool's cheapest mode)")
        self.live_bytes = 0

        # device arenas — physical page 0 of each is the write-dump page
        # (masked-off scatter rows land there), so usable pages start at 1
        g = self.geom
        Nn, Np = pages_normal + 1, pages_packed + 1
        Lg, KV, P = g.n_layers, g.kv_heads, g.page_size
        self.arenas = {
            "kn": jnp.zeros((Lg, Nn, KV, P, g.head_dim), jnp.bfloat16),
            "vn": jnp.zeros((Lg, Nn, KV, P, g.head_dim), jnp.bfloat16),
            "kp": jnp.zeros((Lg, Np, KV, P, g.d_store),
                            jnp.uint8 if g.aug_bits == 4 else jnp.int8),
            "vp": jnp.zeros((Lg, Np, KV, P, g.d_store),
                            jnp.uint8 if g.aug_bits == 4 else jnp.int8),
            "ks": jnp.zeros((Lg, Np, KV, P), jnp.bfloat16),
            "vs": jnp.zeros((Lg, Np, KV, P), jnp.bfloat16),
        }

        # host page tables (numpy; mirrored to device per dispatch).
        # Rows [0, B) are the decode band; with prefix_tokens > 0 rows
        # [B, 2B) are each slot's static prefix band (table width covers
        # the wider of the two bands). With prefix_cache > 0 a SHARE band
        # of `share_entries` rows follows: each row anchors one cached
        # prompt prefix whose pages are refcount-shared into decode rows
        # (serve/prefix.py owns the hash index; the pool owns the pages).
        n_rows = 2 * B if self.prefix_pages else B
        self.share_entries = int(getattr(a, "prefix_cache", 0))
        self._share_base = n_rows
        n_rows += self.share_entries
        tw = max(maxP, self.prefix_pages)
        self.table_width = tw
        self.page_table = np.zeros((n_rows, tw), np.int32)
        self.page_mode = np.zeros((n_rows, tw), np.int32)  # 0 normal, 1 aug
        self.allocated = np.zeros((n_rows, tw), bool)
        self.last_write = np.full((n_rows, tw), -1, np.int64)
        self.free_normal = list(range(Nn - 1, 0, -1))    # pop() -> low first
        self.free_packed = list(range(Np - 1, 0, -1))
        self.policies: dict[tuple[int, int], RefreshPolicy] = {}
        self._tables_cache: Optional[dict] = None   # invalidated on any
                                                    # page-table mutation
        self.stats = {
            "augment_events": 0, "promote_events": 0, "refreshes": 0,
            "refresh_bytes": 0, "augment_bytes": 0,
            "maintenance_dispatches": 0, "alloc_failures": 0,
            "peak_live_bytes": 0, "retracted_pages": 0,
            "faults_injected": 0, "faults_detected": 0, "faults_masked": 0,
            "refresh_misses": 0, "integrity_checks": 0, "pinned_normal": 0,
            "pages_decommissioned": 0,
            "cow_events": 0, "cow_bytes": 0, "prefix_demotions": 0,
            "prefix_evictions": 0,
        }
        # physical-page reference counts, keyed (mode, phys): every
        # allocated page carries one; shared-prefix aliases raise it.
        # live_bytes charges each PHYSICAL page once — aliases are free.
        self._refcount: dict[tuple[int, int], int] = {}
        self._prefix_index = None   # serve/prefix.py PrefixIndex (optional)
        # retention-fault machinery (core/faults.py) — inert until a
        # FaultModel is attached; all dicts stay empty at fault_rate=0
        self._fm: Optional[F.FaultModel] = None
        self._integrity = False
        self._fault_tag = ""
        self._words: dict[tuple[int, int], int] = {}   # integrity words
        self._dirty: set[tuple[int, int]] = set()      # rewritten since flush
        self._pending: set[tuple[int, int]] = set()    # injected, unscanned
        self._masters: dict[tuple[int, int], tuple] = {}  # static-band copies
        self._offenders: dict[str, int] = {}           # by physical unit id
        self._decommission: set[int] = set()           # weak packed pages
        self._obs = None        # EngineObs facade (attach_obs) — optional
        self._live_by_mode = [0, 0]   # live pages per mode, kept
        # incrementally so the per-step mode-mix sample is O(1)

    # -- byte accounting ------------------------------------------------------

    def _cost(self, mode: int) -> int:
        return self.geom.page_bytes_normal if mode == 0 \
            else self.geom.page_bytes_aug

    def free_page_count(self, mode: int) -> int:
        return len(self.free_normal if mode == 0 else self.free_packed)

    def can_admit_tokens(self, n_tokens: int) -> bool:
        """Admission check: could `n_tokens` more tokens be stored right
        now, augmenting cold pages if the policy allows? Counts the
        static prefix band's pages on top of the prompt's own, and the
        IDLE shared-prefix pages (cached entries no live request maps)
        as reclaimable headroom — the allocator evicts those entries at
        refcount 0 before failing."""
        pages = -(-n_tokens // self.geom.page_size) + self.prefix_pages
        idle_n, idle_a = self._prefix_idle_counts()
        free_b = (self.budget_bytes - self.live_bytes
                  + idle_n * self._cost(0) + idle_a * self._cost(1))
        free0 = self.free_page_count(0) + idle_n
        free1 = self.free_page_count(1) + idle_a
        if self.pool_mode == "normal-only":
            return pages <= free0 and pages * self._cost(0) <= free_b
        if (self.pool_mode == "augment-on-pressure"
                and pages <= free0
                and pages * self._cost(0) <= free_b):
            return True     # fits in the static plane, no pressure at all
        if pages > free1:
            return False
        need = pages * self._cost(1) - free_b
        if need <= 0:
            return True
        per = self._cost(0) - self._cost(1)   # bytes one augmentation frees
        n_aug = -(-need // per)
        # each augmentation consumes one free packed slot ON TOP of the
        # request's own pages — don't promise an admission alloc_page
        # cannot deliver
        return (self.pool_mode == "augment-on-pressure"
                and n_aug <= self._augmentable_count()
                and pages + n_aug <= free1)

    # -- allocation -----------------------------------------------------------

    def alloc_page(self, row: int, lp: int, step: int) -> bool:
        """Allocate the logical page (row, lp). Mode policy: normal-only /
        always-augmented pin the plane; augment-on-pressure prefers Normal
        and falls back to Augmented, augmenting cold pages when even the
        packed plane doesn't fit the budget. False = pool exhausted."""
        assert not self.allocated[row, lp], (row, lp)
        order = {"normal-only": (0,), "always-augmented": (1,),
                 "augment-on-pressure": (0, 1)}[self.pool_mode]
        while True:
            for mode in order:
                if self._try_place(row, lp, mode, step):
                    return True
            if self.pool_mode == "augment-on-pressure":
                # pressure: demote cold Normal pages to the packed plane
                # until the budget fits one more Augmented page; cold
                # shared-prefix pages demote along this ladder too, and
                # idle cached prefixes are evicted (refcount 0) last
                while (self.live_bytes + self._cost(1) > self.budget_bytes
                       or self.free_page_count(1) == 0):
                    if self._augment_coldest(step):
                        continue
                    if self._reclaim_prefix(step):
                        continue
                    self.stats["alloc_failures"] += 1
                    return False
                if self._try_place(row, lp, 1, step):
                    return True
            # pinned-mode pools reach here with both planes exhausted:
            # evicting one idle cached prefix frees its pages + bytes,
            # then the order loop retries
            if not self._reclaim_prefix(step):
                self.stats["alloc_failures"] += 1
                return False

    def _try_place(self, row: int, lp: int, mode: int, step: int) -> bool:
        cost = self._cost(mode)
        free = self.free_normal if mode == 0 else self.free_packed
        if not free or self.live_bytes + cost > self.budget_bytes:
            return False
        phys = free.pop()
        self._tables_cache = None
        self.page_table[row, lp] = phys
        self.page_mode[row, lp] = mode
        self.allocated[row, lp] = True
        self.last_write[row, lp] = step
        self._refcount[(mode, phys)] = 1
        self.live_bytes += cost
        self._live_by_mode[mode] += 1
        self.stats["peak_live_bytes"] = max(self.stats["peak_live_bytes"],
                                            self.live_bytes)
        if mode == 1:
            pol = RefreshPolicy(retention_steps=self.retention_steps)
            pol.stamp(step)
            self.policies[(row, lp)] = pol
            if self._fm is not None:
                self._dirty.add((row, lp))
        return True

    def free_row(self, row: int) -> None:
        for lp in np.flatnonzero(self.allocated[row]):
            self._release(row, int(lp))

    # -- StateStore interface --------------------------------------------------
    # (serve/state_store.py documents the contract; the scheduler and the
    # engine talk to every decode-state store through these.)

    kind = "paged"

    def _prefix_row(self, row: int) -> int:
        return self.max_batch + row

    def admit_row(self, row: int, n_tokens: int, step: int, *,
                  shared=None) -> bool:
        """All-or-nothing admission: the prompt's decode-band pages plus
        (when this pool carries a static prefix) the row's prefix pages,
        zero-initialized so recycled physical pages never leak a previous
        row's KV through the static-length read. With ``shared=(erow, m)``
        the first ``m`` prompt tokens are covered by the cached prefix
        anchored at share-band row ``erow``: its full pages are mapped
        into this row's table by refcount (no new storage, no prefill),
        only the tail allocates fresh pages."""
        pages = -(-max(n_tokens, 1) // self.geom.page_size)
        done: list[tuple[int, int]] = []
        share_pages = 0
        if shared is not None and self.share_entries:
            erow, m = shared
            # ceil: a mid-page match maps the entry's boundary page too —
            # the first write past the match COWs it (ensure_position)
            share_pages = min(-(-m // self.geom.page_size), pages)
            for lp in range(share_pages):
                self.share_page(erow, lp, row, lp, step)
                done.append((row, lp))
        for lp in range(share_pages, pages):
            if not self.alloc_page(row, lp, step):
                for r, d in done:
                    self._release(r, d)
                return False
            done.append((row, lp))
        prow = self._prefix_row(row)
        for lp in range(self.prefix_pages):
            if not self.alloc_page(prow, lp, step):
                for r, d in done:
                    self._release(r, d)
                return False
            done.append((prow, lp))
            self._zero_physical(prow, lp)
        return True

    def _zero_physical(self, row: int, lp: int) -> None:
        """Zero the physical page behind (row, lp) in its current plane —
        prefix pages are read to their full static length, so stale data
        from a recycled page must be scrubbed at allocation."""
        phys = int(self.page_table[row, lp])
        mode = int(self.page_mode[row, lp])
        self.arenas = _zero_page_op(self.arenas, phys, mode=mode)
        self.stats["maintenance_dispatches"] += 1

    def ensure_position(self, row: int, pos: int, step: int) -> bool:
        """Guarantee the decode-band page holding `pos` exists before a
        dispatch writes it (growth is one token per decode step)."""
        lp = pos // self.geom.page_size
        assert lp < self.max_pages, (
            f"position {pos} past the page table ({self.max_pages} pages): "
            f"the engine's max_seq done-condition should retire rows "
            f"before this")
        if self.allocated[row, lp]:
            key = (int(self.page_mode[row, lp]),
                   int(self.page_table[row, lp]))
            if self._refcount.get(key, 1) > 1:
                # about to write into a shared-prefix page: copy-on-write
                # the tokens below `pos` into a private page first
                return self._cow_page(row, lp,
                                      pos - lp * self.geom.page_size, step)
            return True
        return self.alloc_page(row, lp, step)

    def release_row(self, row: int) -> None:
        self.free_row(row)
        if self.prefix_pages:
            self.free_row(self._prefix_row(row))

    def note_token_writes(self, rows: np.ndarray, positions: np.ndarray,
                          step: int) -> None:
        """Stamp the decode-band pages the given absolute `positions`
        land in (one entry per row)."""
        rows = np.asarray(rows).ravel()
        lps = np.asarray(positions).ravel() // self.geom.page_size
        self.note_writes(rows, lps, step)

    def refresh(self, key: tuple, step: int) -> None:
        self.refresh_page(key[0], key[1], step)

    def retract_token_writes(self, rows: np.ndarray,
                             new_lengths: np.ndarray) -> int:
        """Speculative rollback: release decode-band pages that hold ONLY
        draft tokens the verify pass rejected (pages whose first slot is
        at or past the row's post-accept length). The rejected slots of
        the surviving boundary page were already scrubbed by the verify
        step's masked commit re-scatter; retracted pages may hold stale
        bytes but are never read — the kernel's walk is bounded by
        `lengths`, and any re-allocation rewrites before the first read.
        Returns the number of pages released."""
        page = self.geom.page_size
        n = 0
        for row, length in zip(np.asarray(rows).ravel(),
                               np.asarray(new_lengths).ravel()):
            row, length = int(row), int(length)
            first_dead = -(-max(length, 0) // page)      # ceil
            for lp in np.flatnonzero(self.allocated[row]):
                if int(lp) >= first_dead:
                    self._release(row, int(lp))
                    n += 1
        if n:
            self.stats["retracted_pages"] += n
        return n

    def max_row_tokens(self) -> Optional[int]:
        """Upper bound on tokens ONE row can ever hold in this pool (the
        admission-time capacity check), assuming the rest of the pool is
        empty: the page table's depth, the cheapest plane's arena, and
        the byte budget in the cheapest mode the policy can reach — each
        less the row's static prefix pages."""
        if self.pool_mode == "normal-only":
            arena, cheapest = self.pages_normal, self._cost(0)
        elif self.pool_mode == "always-augmented":
            arena, cheapest = self.pages_packed, self._cost(1)
        else:
            arena = self.pages_normal + self.pages_packed
            cheapest = self._cost(1)
        pages = min(self.max_pages,
                    arena - self.prefix_pages,
                    self.budget_bytes // cheapest - self.prefix_pages)
        return max(pages, 0) * self.geom.page_size

    @property
    def state(self):
        """Device-side decode-state tree (donated through the jitted step)."""
        return self.arenas

    @state.setter
    def state(self, new) -> None:
        self.arenas = new

    @property
    def aug_bits(self) -> int:
        return self.geom.aug_bits

    def physical_bytes(self) -> int:
        """Usable staged capacity of both planes (write-dump lines
        excluded; `arena_bytes()` reports the raw allocation)."""
        return (self.pages_normal * self.geom.page_bytes_normal
                + self.pages_packed * self.geom.page_bytes_aug)

    # -- array event accounting (engine folds these into the IMC ledger) -----

    @property
    def _values_per_token(self) -> int:
        g = self.geom
        return 2 * g.n_layers * g.kv_heads * g.head_dim

    def read_value_counts(self, rows: np.ndarray,
                          lengths: np.ndarray) -> tuple[int, int]:
        """(normal, augmented) cache VALUES a decode dispatch reads for
        `rows` at valid `lengths`, split by page mode — prefix-band pages
        are read to their full static length every step."""
        if rows.size == 0:
            return 0, 0
        page = self.geom.page_size
        tw = self.table_width
        tok_per_page = np.clip(
            lengths[:, None] - np.arange(tw)[None, :] * page, 0, page)
        bands = [(rows, tok_per_page)]
        if self.prefix_pages:
            prows = self.max_batch + rows
            ptok = np.clip(
                self.prefix_tokens - np.arange(tw)[None, :] * page, 0, page)
            bands.append((prows, np.broadcast_to(ptok, (rows.size, tw))))
        n_norm = n_aug = 0
        for band_rows, tok in bands:
            alloc = self.allocated[band_rows]
            modes = self.page_mode[band_rows]
            n_norm += int((tok * (alloc & (modes == 0))).sum())
            n_aug += int((tok * (alloc & (modes == 1))).sum())
        v = self._values_per_token
        return n_norm * v, n_aug * v

    def write_value_counts(self, rows: np.ndarray, n_new: int,
                           write_starts: np.ndarray) -> tuple[int, int]:
        """(normal, augmented) cache VALUES one dispatch writes: `n_new`
        tokens per row from `write_starts`, costed by the mode of the
        decode-band page each token lands in."""
        if rows.size == 0:
            return 0, 0
        page = self.geom.page_size
        pos = write_starts[:, None] + np.arange(n_new)[None, :]
        lp = np.minimum(pos // page, self.max_pages - 1)
        mode = self.page_mode[rows[:, None], lp]
        alive = self.allocated[rows[:, None], lp]
        v = self._values_per_token
        wn = int((alive & (mode == 0)).sum()) * v
        wa = int((alive & (mode == 1)).sum()) * v
        return wn, wa

    def _release(self, row: int, lp: int) -> None:
        mode = int(self.page_mode[row, lp])
        phys = int(self.page_table[row, lp])
        rck = (mode, phys)
        rc = self._refcount.get(rck, 1)
        if rc > 1:
            # shared physical page: drop this alias only. The byte charge
            # and the canonical refresh/integrity metadata stay with the
            # surviving refs (rehomed if this alias was carrying them).
            self._refcount[rck] = rc - 1
            self._rehome_meta((row, lp), mode, phys)
            self._tables_cache = None
            self.allocated[row, lp] = False
            self.page_table[row, lp] = 0
            self.page_mode[row, lp] = 0
            self.last_write[row, lp] = -1
            return
        self._refcount.pop(rck, None)
        if mode == 1 and phys in self._decommission:
            # repeat-offender packed page: map the weak array out instead
            # of recycling it — capacity genuinely shrinks
            self._decommission.discard(phys)
            self.pages_packed -= 1
            self.stats["pages_decommissioned"] += 1
            if self._obs is not None:
                self._obs.store_event("decommission", f"pg{phys}", -1)
        else:
            (self.free_normal if mode == 0 else self.free_packed).append(phys)
        key = (row, lp)
        if key in self._pending:
            # the corruption evaporated with the storage before any read
            # reached it (row finished / preempted / array drained)
            self._pending.discard(key)
            self.stats["faults_masked"] += 1
        self._words.pop(key, None)
        self._masters.pop(key, None)
        self._dirty.discard(key)
        self._tables_cache = None
        self.live_bytes -= self._cost(mode)
        self._live_by_mode[mode] -= 1
        self.allocated[row, lp] = False
        self.page_table[row, lp] = 0
        self.page_mode[row, lp] = 0
        self.last_write[row, lp] = -1
        self.policies.pop((row, lp), None)

    # -- shared-prefix page reuse (refcounted aliases + copy-on-write) ---------
    # serve/prefix.py owns the token-hash index; the pool owns the pages.
    # Every cached prefix is anchored by one SHARE-band row (its "entry
    # row") whose table maps the run's physical pages; decode rows alias
    # the same physical pages by refcount. Invariants:
    #   * live_bytes charges each PHYSICAL page exactly once — the alias
    #     that carries the charge is whichever ref releases LAST.
    #   * refresh/integrity metadata (policies/_words/_masters/_pending/
    #     _dirty) for a shared page lives on exactly ONE key — the entry
    #     row while the entry is alive — so an expiring refcounted page
    #     restamps once, not once per sharer.

    def entry_row(self, slot: int) -> int:
        return self._share_base + slot

    def attach_prefix_index(self, idx) -> None:
        """Wire the engine's PrefixIndex so allocation pressure can evict
        idle cached prefixes (refcount 0) as the last reclaim rung."""
        self._prefix_index = idx

    def _reclaim_prefix(self, step: int) -> bool:
        if self._prefix_index is None:
            return False
        return self._prefix_index.evict_one(self, step)

    def _refs(self, mode: int, phys: int) -> list[tuple[int, int]]:
        """All logical keys currently mapping physical page (mode, phys)."""
        hits = np.argwhere(self.allocated & (self.page_mode == mode)
                           & (self.page_table == phys))
        return [(int(r), int(l)) for r, l in hits]

    def page_refcount(self, row: int, lp: int) -> int:
        if not self.allocated[row, lp]:
            return 0
        return self._refcount.get((int(self.page_mode[row, lp]),
                                   int(self.page_table[row, lp])), 1)

    def bytes_shared(self) -> int:
        """Bytes the sharing layer is currently saving: each extra ref of
        a physical page is storage a private copy would have cost."""
        return sum((rc - 1) * self._cost(m)
                   for (m, _p), rc in self._refcount.items() if rc > 1)

    def share_page(self, src_row: int, src_lp: int, dst_row: int,
                   dst_lp: int, step: int) -> None:
        """Alias the physical page behind (src_row, src_lp) into
        (dst_row, dst_lp): pure table writes + a refcount bump — no
        storage, no bytes, no dispatch."""
        assert self.allocated[src_row, src_lp], (src_row, src_lp)
        assert not self.allocated[dst_row, dst_lp], (dst_row, dst_lp)
        mode = int(self.page_mode[src_row, src_lp])
        phys = int(self.page_table[src_row, src_lp])
        self.page_table[dst_row, dst_lp] = phys
        self.page_mode[dst_row, dst_lp] = mode
        self.allocated[dst_row, dst_lp] = True
        self.last_write[dst_row, dst_lp] = step
        k = (mode, phys)
        self._refcount[k] = self._refcount.get(k, 1) + 1
        self._tables_cache = None

    def _move_canonical(self, src: tuple[int, int],
                        dst: tuple[int, int]) -> None:
        """Move whatever refresh/integrity metadata `src` holds to `dst`
        (no-op for entries `src` doesn't hold)."""
        if src == dst:
            return
        pol = self.policies.pop(src, None)
        if pol is not None:
            self.policies[dst] = pol
        for d in (self._words, self._masters):
            if src in d:
                d[dst] = d.pop(src)
        for s in (self._dirty, self._pending):
            if src in s:
                s.discard(src)
                s.add(dst)

    def _rehome_meta(self, key: tuple[int, int], mode: int,
                     phys: int) -> None:
        """An alias of shared page (mode, phys) is releasing: if it was
        the canonical metadata holder, hand the metadata to a surviving
        ref (highest row wins — the share band outranks decode rows, so
        an entry keeps custody of its own pages)."""
        if (key not in self.policies and key not in self._words
                and key not in self._masters and key not in self._pending
                and key not in self._dirty):
            return
        refs = [r for r in self._refs(mode, phys) if r != key]
        if not refs:
            return
        self._move_canonical(key, max(refs))

    def register_entry_pages(self, erow: int, src_row: int, n_pages: int,
                             step: int) -> None:
        """Anchor a freshly prefilled prefix: alias `src_row`'s first
        `n_pages` pages into share-band row `erow` and move each page's
        canonical metadata there (restamp-once invariant)."""
        for lp in range(n_pages):
            self.share_page(src_row, lp, erow, lp, step)
            self._move_canonical((src_row, lp), (erow, lp))

    def note_entry_use(self, erow: int, n_tokens: int, step: int) -> None:
        """A hit re-warmed this entry's first ceil(n/page) pages: reset
        coldness (NOT the retention clock — no bits were rewritten)."""
        for lp in range(-(-n_tokens // self.geom.page_size)):
            if self.allocated[erow, lp]:
                self.last_write[erow, lp] = step

    def _prefix_idle_counts(self) -> tuple[int, int]:
        """(normal, augmented) physical pages held ONLY by share-band
        entries — reclaimable headroom for the admission check, since
        `_reclaim_prefix` frees them at refcount 0 before alloc fails."""
        if not self.share_entries:
            return 0, 0
        counts: dict[tuple[int, int], int] = {}
        base = self._share_base
        for erow in range(base, base + self.share_entries):
            for lp in np.flatnonzero(self.allocated[erow]):
                k = (int(self.page_mode[erow, lp]),
                     int(self.page_table[erow, lp]))
                counts[k] = counts.get(k, 0) + 1
        idle = [k for k, n in counts.items()
                if self._refcount.get(k, 0) == n]
        return (sum(1 for m, _ in idle if m == 0),
                sum(1 for m, _ in idle if m == 1))

    def _cow_page(self, row: int, lp: int, keep: int, step: int) -> bool:
        """Copy-on-write: (row, lp) aliases a shared physical page and is
        about to diverge at token `keep` of the page. Copy tokens
        [0, keep) into a private page (masked page-copy dispatch), zero
        the rest, and repoint only this row. False = pool exhausted."""
        src_mode = int(self.page_mode[row, lp])
        src_phys = int(self.page_table[row, lp])
        order = {"normal-only": (0,), "always-augmented": (1,),
                 "augment-on-pressure": (0, 1)}[self.pool_mode]
        dst_mode = None
        while dst_mode is None:
            for mode in order:
                free = self.free_normal if mode == 0 else self.free_packed
                if free and self.live_bytes + self._cost(mode) \
                        <= self.budget_bytes:
                    dst_mode = mode
                    break
            else:
                if self.pool_mode == "augment-on-pressure" \
                        and self._augment_coldest(step):
                    continue
                if self._reclaim_prefix(step):
                    # reclaim may have freed OUR source's last other ref —
                    # then the page is private now and no copy is needed
                    if self._refcount.get((src_mode, src_phys), 1) == 1:
                        return True
                    continue
                self.stats["alloc_failures"] += 1
                return False
        free = self.free_normal if dst_mode == 0 else self.free_packed
        dst_phys = free.pop()
        self.arenas = _cow_page_op(self.arenas, src_phys, dst_phys, keep,
                                   src_mode=src_mode, dst_mode=dst_mode,
                                   aug_bits=self.geom.aug_bits)
        self.stats["maintenance_dispatches"] += 1
        self.stats["cow_events"] += 1
        self.stats["cow_bytes"] += self._cost(src_mode) + self._cost(dst_mode)
        sk = (src_mode, src_phys)
        self._refcount[sk] = self._refcount.get(sk, 2) - 1
        self._refcount[(dst_mode, dst_phys)] = 1
        self._rehome_meta((row, lp), src_mode, src_phys)
        self.page_table[row, lp] = dst_phys
        self.page_mode[row, lp] = dst_mode
        self.last_write[row, lp] = step
        self.live_bytes += self._cost(dst_mode)
        self._live_by_mode[dst_mode] += 1
        self.stats["peak_live_bytes"] = max(self.stats["peak_live_bytes"],
                                            self.live_bytes)
        if dst_mode == 1:
            pol = RefreshPolicy(retention_steps=self.retention_steps)
            pol.stamp(step)
            self.policies[(row, lp)] = pol
            if self._fm is not None:
                self._dirty.add((row, lp))
        self._tables_cache = None
        if self._obs is not None:
            self._obs.store_event("cow", f"pg{src_phys}>{dst_phys}", step)
        return True

    # -- mode switching (the paper's WL/SL reconfiguration) --------------------

    def _augmentable_count(self) -> int:
        # PHYSICAL Normal pages the pressure ladder may demote; actively
        # shared pages (refcount > 1) are pinned in place — mutating the
        # bits under a concurrent reader is never allowed
        return sum(1 for (m, _p), rc in self._refcount.items()
                   if m == 0 and rc == 1)

    def _coldest_normal(self) -> Optional[tuple[int, int]]:
        cand = self.allocated & (self.page_mode == 0)
        if self.share_entries:
            for (m, phys), rc in self._refcount.items():
                if m == 0 and rc > 1:
                    cand &= ~((self.page_table == phys)
                              & (self.page_mode == 0))
        if not cand.any():
            return None
        age = np.where(cand, self.last_write, np.iinfo(np.int64).max)
        row, lp = np.unravel_index(int(age.argmin()), age.shape)
        return int(row), int(lp)

    def _augment_coldest(self, step: int) -> bool:
        target = self._coldest_normal()
        if target is None or not self.free_packed:
            return False
        self.augment_page(*target, step=step)
        return True

    def augment_page(self, row: int, lp: int, step: int) -> None:
        """Normal -> Augmented in place: quantize-pack the page into the
        dynamic plane, release the byte difference back to the budget.
        The bf16 master is gone afterwards — the page is now dynamic data
        under the retention clock. Shared pages move ALL their aliases
        (the pressure ladder only sends refcount-1 pages here, but a
        direct call on a shared page stays consistent); a share-band
        page taking this path is a prefix DEMOTION — the dual-context
        alternative to eviction."""
        assert self.page_mode[row, lp] == 0 and self.allocated[row, lp]
        src = int(self.page_table[row, lp])
        refs = self._refs(0, src)
        dst = self.free_packed.pop()
        self.arenas = _augment_page_op(self.arenas, src, dst,
                                       aug_bits=self.geom.aug_bits)
        self.stats["maintenance_dispatches"] += 1
        self.free_normal.append(src)
        self._tables_cache = None
        for r, l in refs:
            self.page_table[r, l] = dst
            self.page_mode[r, l] = 1
        rc = self._refcount.pop((0, src), 1)
        self._refcount[(1, dst)] = rc
        self.live_bytes -= self._cost(0) - self._cost(1)
        self._live_by_mode[0] -= 1
        self._live_by_mode[1] += 1
        ckey = max(refs) if refs else (row, lp)
        pol = RefreshPolicy(retention_steps=self.retention_steps)
        pol.stamp(step)
        self.policies[ckey] = pol
        if self._fm is not None:
            self._dirty.add(ckey)
        self.stats["augment_events"] += 1
        self.stats["augment_bytes"] += self._cost(0) + self._cost(1)
        demoted = self.share_entries and ckey[0] >= self._share_base
        if demoted:
            self.stats["prefix_demotions"] += 1
        if self._obs is not None:
            self._obs.store_event("demote" if demoted else "augment",
                                  f"pg{dst}", step)

    def promote_page(self, row: int, lp: int, step: int) -> bool:
        """Augmented -> Normal (refresh-promote): dequantize back into the
        static plane when the budget has room again. Shared pages move
        ALL their aliases and clear the single canonical metadata key."""
        assert self.page_mode[row, lp] == 1 and self.allocated[row, lp]
        src = int(self.page_table[row, lp])
        refs = self._refs(1, src)
        ckey = max(refs) if refs else (row, lp)
        if ckey in self._pending:
            # never materialize a corrupted packed page into the static
            # plane — the fault pass must detect and heal it first
            return False
        cost_up = self._cost(0) - self._cost(1)
        if not self.free_normal or self.live_bytes + cost_up > self.budget_bytes:
            return False
        dst = self.free_normal.pop()
        self.arenas = _promote_page_op(self.arenas, src, dst,
                                       aug_bits=self.geom.aug_bits)
        self.stats["maintenance_dispatches"] += 1
        self.free_packed.append(src)
        self._tables_cache = None
        for r, l in refs:
            self.page_table[r, l] = dst
            self.page_mode[r, l] = 0
            self.last_write[r, l] = step
        rc = self._refcount.pop((1, src), 1)
        self._refcount[(0, dst)] = rc
        self.live_bytes += cost_up
        self._live_by_mode[1] -= 1
        self._live_by_mode[0] += 1
        self.policies.pop(ckey, None)
        self._words.pop(ckey, None)
        self._masters.pop(ckey, None)
        self._dirty.discard(ckey)
        self.stats["promote_events"] += 1
        if self._obs is not None:
            self._obs.store_event("promote", f"pg{dst}", step)
        return True

    # -- retention / refresh ----------------------------------------------------

    def note_writes(self, rows: np.ndarray, lps: np.ndarray,
                    step: int) -> None:
        """Stamp pages written by this dispatch (decode tail slots or
        prefill chunks): resets both coldness and the retention clock."""
        for row, lp in zip(np.asarray(rows).ravel(), np.asarray(lps).ravel()):
            row, lp = int(row), int(lp)
            if not self.allocated[row, lp]:
                continue
            self.last_write[row, lp] = step
            pol = self.policies.get((row, lp))
            if pol is not None:
                pol.stamp(step)
                if self._fm is not None:
                    self._dirty.add((row, lp))

    def refresh_due(self, step: int) -> list[tuple[int, int]]:
        return [key for key, pol in self.policies.items()
                if pol.needs_refresh(step)]

    def refresh_page(self, row: int, lp: int, step: int, *,
                     promote_ok: bool = True) -> None:
        """DRAM-style refresh of one expired Augmented page: promote back
        to Normal when allowed and the budget has room, else re-write the
        packed rows in place (restamp) and account the traffic."""
        if (self._fm is not None and (row, lp) in self.policies
                and self._fm.refresh_miss(self._unit_id((row, lp)), step)):
            # the refresh pulse itself failed (paper Table II tail): the
            # page stays on the old stamp and keeps aging toward certain
            # fault — inject/scan will catch what decays
            self.stats["refresh_misses"] += 1
            return
        if promote_ok and self.pool_mode == "augment-on-pressure" \
                and self.cfg.amc.refresh_promote \
                and self.promote_page(row, lp, step):
            self.stats["refreshes"] += 1
            self.stats["refresh_bytes"] += self._cost(1) + self._cost(0)
            return
        pol = self.policies.get((row, lp))
        if pol is None:                      # freed/promoted concurrently
            return
        pol.stamp(step)
        self.stats["refreshes"] += 1
        self.stats["refresh_bytes"] += 2 * self._cost(1)   # read + re-write
        if self._obs is not None:
            self._obs.store_event("restamp", f"r{row}.p{lp}", step)

    def max_augmented_age(self, step: int) -> int:
        """Oldest unrefreshed augmented page, in steps (invariant probe:
        the scheduler must keep this <= retention_steps)."""
        return max((pol.age(step) for pol in self.policies.values()),
                   default=0)

    # -- observability ----------------------------------------------------------

    def attach_obs(self, obs) -> None:
        """Wire the engine's observability facade: mode transitions and
        fault injections emit refresh/fault-lane events from here."""
        self._obs = obs

    def mode_mix(self) -> tuple[int, int]:
        """(live Normal pages, live Augmented pages) — one sample of the
        paper's 6T/8T+ mode-mix timeline. O(1): incremental counters,
        sampled every engine step (describe() recomputes the same pair
        by reduction as the ground-truth cross-check)."""
        return self._live_by_mode[0], self._live_by_mode[1]

    # -- retention-fault injection / detection / healing ------------------------
    # (core/faults.py FaultModel; the engine's fault pass drives these.
    # Only Augmented pages are at risk — the Normal plane is the paper's
    # static 6T configuration and never decays.)

    def attach_fault_model(self, fm: F.FaultModel, *, integrity: bool = True,
                           tag: str = "") -> None:
        self._fm = fm
        self._integrity = integrity
        self._fault_tag = tag
        # pages placed before attach have no integrity words yet
        self._dirty.update(self.policies.keys())

    def _unit_id(self, key: tuple[int, int]) -> str:
        """Stable PHYSICAL identity of the cells behind a logical page —
        repeat-offender tracking must follow the weak array, not the
        logical page that happens to occupy it."""
        return f"{self._fault_tag}pg{int(self.page_table[key])}"

    def _unit_payload_np(self, key: tuple[int, int]) -> tuple:
        phys = int(self.page_table[key])
        return tuple(np.asarray(self.arenas[k][:, phys])
                     for k in ("kp", "vp", "ks", "vs"))

    def _unit_word(self, key: tuple[int, int]) -> int:
        return F.integrity_word(*self._unit_payload_np(key))

    def _flush_integrity(self) -> None:
        """Bring integrity words up to date for every augmented page that
        was (re)written since the last flush — the host-side mirror of the
        fused `quantize_pack_kv(with_integrity=True)` store-back. Static
        prefix-band pages (write-once) also stash a host master copy, the
        scrub source of `scrub_from_master`."""
        for key in self.policies:
            if key in self._words and key not in self._dirty:
                continue
            payload = self._unit_payload_np(key)
            self._words[key] = F.integrity_word(*payload)
            if key[0] >= self.max_batch:
                self._masters[key] = payload
        self._dirty.clear()

    def inject_faults(self, step: int) -> int:
        """Sample retention faults for every live augmented page and
        corrupt the packed payload on device (deterministic under the
        model's seed). Returns the number of pages corrupted."""
        if self._fm is None:
            return 0
        self._flush_integrity()
        n = 0
        for key, pol in list(self.policies.items()):
            if key in self._pending:
                continue
            uid = self._unit_id(key)
            if self._fm.fault(uid, step, pol.age(step), self.retention_steps):
                phys = int(self.page_table[key])
                mask = self._fm.corruption_mask(uid, step)
                self.arenas = _corrupt_page_op(self.arenas, phys, mask)
                self._pending.add(key)
                self.stats["faults_injected"] += 1
                if self._obs is not None:
                    self._obs.on_fault("inject", uid, step)
                n += 1
        return n

    def scan_integrity(self, step: int) -> list[tuple[int, int]]:
        """Verify every augmented page's payload against its stored
        integrity word; return the corrupted keys (detected, never
        silently served). With integrity off this is a no-op — the
        zero-silent-corruption property is then forfeited by config."""
        if self._fm is None or not self._integrity:
            return []
        self._flush_integrity()
        bad: list[tuple[int, int]] = []
        for key, word in list(self._words.items()):
            self.stats["integrity_checks"] += 1
            if self._unit_word(key) == word:
                continue
            bad.append(key)
            self._pending.discard(key)
            self.stats["faults_detected"] += 1
            uid = self._unit_id(key)
            self._offenders[uid] = self._offenders.get(uid, 0) + 1
            if (self._offenders[uid] >= self._fm.pin_threshold
                    and key[0] < self.max_batch):
                # decode-band repeat offender: retire the weak physical
                # page when its current tenant releases it
                self._decommission.add(int(self.page_table[key]))
        return bad

    def scrub_from_master(self, key: tuple[int, int]) -> bool:
        """Heal a detected-corrupt page by re-writing it from the host
        master copy (static prefix band only — decode-band pages have no
        master and must be recomputed). Repeat-offender pages are pinned
        back to the Normal plane when the budget allows."""
        master = self._masters.get(key)
        if master is None:
            return False
        phys = int(self.page_table[key])
        kp, vp, ks, vs = master
        self.arenas = _restore_page_op(self.arenas, phys,
                                       jnp.asarray(kp), jnp.asarray(vp),
                                       jnp.asarray(ks), jnp.asarray(vs))
        self.stats["maintenance_dispatches"] += 1
        self._words[key] = F.integrity_word(*master)
        self._dirty.discard(key)
        if self._offenders.get(self._unit_id(key), 0) >= self._fm.pin_threshold:
            if self.promote_page(key[0], key[1], step=0):
                self.stats["pinned_normal"] += 1
        return True

    def fault_row(self, key: tuple[int, int]) -> Optional[int]:
        """Engine row whose request owns the faulted page (prefix-band
        rows map back to their decode slot; SHARE-band rows have no
        single owner — unhealed faults there are handled by entry
        eviction, not by retrying one request)."""
        row = key[0]
        if self.share_entries and row >= self._share_base:
            return None
        return row if row < self.max_batch else row - self.max_batch

    def fault_unit_bytes(self, key: tuple[int, int]) -> int:
        return self.geom.page_bytes_aug

    def fault_counters(self) -> dict:
        return {k: self.stats[k] for k in
                ("faults_injected", "faults_detected", "faults_masked",
                 "refresh_misses", "integrity_checks", "pinned_normal",
                 "pages_decommissioned")}

    def faults_pending(self) -> int:
        """Injected-but-unscanned corruptions still live in the arenas."""
        return len(self._pending)

    # -- device views -----------------------------------------------------------

    def device_tables(self) -> dict:
        """Scalar-prefetch operands for the paged kernel + write tables.
        normal_idx / packed_idx carry HOLD-PREVIOUS semantics per row so
        the mode-mismatched arena never issues a DMA. With a prefix band,
        the same tables are also emitted for rows [B, 2B) under the
        ``cross_*`` keys together with the static ``cross_lengths``."""
        if self._tables_cache is not None:
            return self._tables_cache
        pt, md = self.page_table, self.page_mode
        n_rows, tw = pt.shape
        nidx = np.zeros((n_rows, tw), np.int32)
        pidx = np.zeros((n_rows, tw), np.int32)
        lastn = np.zeros(n_rows, np.int32)
        lastp = np.zeros(n_rows, np.int32)
        for s in range(tw):
            live = self.allocated[:, s]
            lastn = np.where(live & (md[:, s] == 0), pt[:, s], lastn)
            lastp = np.where(live & (md[:, s] == 1), pt[:, s], lastp)
            nidx[:, s], pidx[:, s] = lastn, lastp
        B, maxP = self.max_batch, self.max_pages
        tables = {"page_table": jnp.asarray(pt[:B, :maxP]),
                  "page_modes": jnp.asarray(md[:B, :maxP]),
                  "normal_idx": jnp.asarray(nidx[:B, :maxP]),
                  "packed_idx": jnp.asarray(pidx[:B, :maxP])}
        if self.prefix_pages:
            Pc = self.prefix_pages
            clen = np.where(self.allocated[B:, :Pc].any(axis=1),
                            self.prefix_tokens, 0).astype(np.int32)
            tables.update({
                "cross_table": jnp.asarray(pt[B:, :Pc]),
                "cross_modes": jnp.asarray(md[B:, :Pc]),
                "cross_normal_idx": jnp.asarray(nidx[B:, :Pc]),
                "cross_packed_idx": jnp.asarray(pidx[B:, :Pc]),
                "cross_lengths": jnp.asarray(clen),
            })
        self._tables_cache = tables
        return self._tables_cache

    def arena_bytes(self) -> int:
        return sum(x.nbytes for x in jax.tree.leaves(self.arenas))

    def describe(self) -> dict:
        g = self.geom
        # PHYSICAL live pages (aliases of a shared page count once) — the
        # ground-truth cross-check of the incremental _live_by_mode pair
        live_n = sum(1 for (m, _p) in self._refcount if m == 0)
        live_a = sum(1 for (m, _p) in self._refcount if m == 1)
        return {
            "kind": self.kind,
            "pool_mode": self.pool_mode,
            "page_size": g.page_size,
            "aug_bits": g.aug_bits,
            "prefix_tokens": self.prefix_tokens,
            "pages_live_normal": live_n,
            "pages_live_augmented": live_a,
            "pages_shared": sum(1 for rc in self._refcount.values()
                                if rc > 1),
            "bytes_shared": self.bytes_shared(),
            "page_bytes_normal": g.page_bytes_normal,
            "page_bytes_aug": g.page_bytes_aug,
            "page_capacity_factor": g.capacity_factor,
            "budget_bytes": self.budget_bytes,
            "live_bytes": self.live_bytes,
            "arena_bytes": self.arena_bytes(),
            "retention_steps": self.retention_steps,
            **self.stats,
        }


# ---------------------------------------------------------------------------
# jitted maintenance ops (mode switches move one page between planes)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("aug_bits",), donate_argnums=(0,))
def _augment_page_op(arenas: dict, src: int, dst: int, *, aug_bits: int):
    """Quantize-pack Normal page `src` into packed page `dst` (all layers,
    K and V) — the existing quantize_pack_kv path is the write driver."""
    out = dict(arenas)
    for plane, packed, scale in (("kn", "kp", "ks"), ("vn", "vp", "vs")):
        x = arenas[plane][:, src]                       # (L, KV, page, hd)
        if aug_bits == 4:
            p, s = K.quantize_pack_kv(x)
        else:
            p, s = L.pack_kv_int8(x)
        out[packed] = out[packed].at[:, dst].set(p)
        out[scale] = out[scale].at[:, dst].set(s[..., 0].astype(jnp.bfloat16))
    return out


@functools.partial(jax.jit, static_argnames=("mode",), donate_argnums=(0,))
def _zero_page_op(arenas: dict, phys: int, *, mode: int):
    """Scrub one physical page in its plane (prefix-page allocation)."""
    out = dict(arenas)
    keys = ("kn", "vn") if mode == 0 else ("kp", "vp", "ks", "vs")
    for k in keys:
        out[k] = out[k].at[:, phys].set(jnp.zeros_like(out[k][:, phys]))
    return out


@functools.partial(jax.jit,
                   static_argnames=("src_mode", "dst_mode", "aug_bits"),
                   donate_argnums=(0,))
def _cow_page_op(arenas: dict, src: int, dst: int, keep, *,
                 src_mode: int, dst_mode: int, aug_bits: int):
    """Masked page copy for copy-on-write divergence: tokens [0, keep) of
    physical page `src` land in `dst` (crossing planes when the modes
    differ), the rest of `dst` is scrubbed to the plane's neutral value.
    The Normal->Augmented leg reuses `quantize_pack_kv(valid=)` — the
    same masked write driver the verify-commit path uses."""
    out = dict(arenas)
    P = arenas["kn"].shape[3]
    tokmask = jnp.arange(P) < keep
    if src_mode == 0 and dst_mode == 0:
        for k in ("kn", "vn"):
            page = jnp.where(tokmask[None, None, :, None],
                             arenas[k][:, src], 0)
            out[k] = out[k].at[:, dst].set(page)
    elif src_mode == 1 and dst_mode == 1:
        for p, s in (("kp", "ks"), ("vp", "vs")):
            pg = jnp.where(tokmask[None, None, :, None], arenas[p][:, src],
                           jnp.zeros_like(arenas[p][:, src]))
            sc = jnp.where(tokmask[None, None, :], arenas[s][:, src],
                           jnp.ones_like(arenas[s][:, src]))
            out[p] = out[p].at[:, dst].set(pg)
            out[s] = out[s].at[:, dst].set(sc)
    elif src_mode == 0 and dst_mode == 1:
        for plane, packed, scale in (("kn", "kp", "ks"), ("vn", "vp", "vs")):
            x = arenas[plane][:, src]                   # (L, KV, page, hd)
            if aug_bits == 4:
                p, s = K.quantize_pack_kv(x, tokmask[None, None, :])
            else:
                p, s = L.pack_kv_int8(x)
                p = jnp.where(tokmask[None, None, :, None], p,
                              jnp.zeros_like(p))
                s = jnp.where(tokmask[None, None, :, None], s,
                              jnp.ones_like(s))
            out[packed] = out[packed].at[:, dst].set(p)
            out[scale] = out[scale].at[:, dst].set(
                s[..., 0].astype(jnp.bfloat16))
    else:                                               # Augmented -> Normal
        unpack = L.unpack_kv_int4 if aug_bits == 4 else L.unpack_kv_int8
        for plane, packed, scale in (("kn", "kp", "ks"), ("vn", "vp", "vs")):
            d = unpack(arenas[packed][:, src], arenas[scale][:, src][..., None])
            d = jnp.where(tokmask[None, None, :, None], d, 0)
            out[plane] = out[plane].at[:, dst].set(d.astype(jnp.bfloat16))
    return out


@functools.partial(jax.jit, static_argnames=("aug_bits",), donate_argnums=(0,))
def _promote_page_op(arenas: dict, src: int, dst: int, *, aug_bits: int):
    """Dequantize packed page `src` back into Normal page `dst`."""
    unpack = L.unpack_kv_int4 if aug_bits == 4 else L.unpack_kv_int8
    out = dict(arenas)
    for plane, packed, scale in (("kn", "kp", "ks"), ("vn", "vp", "vs")):
        d = unpack(arenas[packed][:, src], arenas[scale][:, src][..., None])
        out[plane] = out[plane].at[:, dst].set(d.astype(jnp.bfloat16))
    return out


@functools.partial(jax.jit, donate_argnums=(0,))
def _corrupt_page_op(arenas: dict, phys, mask):
    """Retention-fault injection: XOR the packed payload of physical page
    `phys` with a nonzero byte `mask` (bitcast keeps the op dtype-safe for
    the uint8/int4 and int8 planes alike). `phys`/`mask` are traced
    scalars so repeated injections reuse one compilation."""
    out = dict(arenas)
    m = jnp.asarray(mask, jnp.uint8)
    for k in ("kp", "vp"):
        page = arenas[k][:, phys]
        b = jax.lax.bitcast_convert_type(page, jnp.uint8)
        b = jnp.bitwise_xor(b, m)
        out[k] = out[k].at[:, phys].set(
            jax.lax.bitcast_convert_type(b, page.dtype))
    return out


@functools.partial(jax.jit, donate_argnums=(0,))
def _restore_page_op(arenas: dict, phys, kp, vp, ks, vs):
    """Scrub-on-detect: re-write physical page `phys` from a master copy."""
    out = dict(arenas)
    for k, v in (("kp", kp), ("vp", vp), ("ks", ks), ("vs", vs)):
        out[k] = out[k].at[:, phys].set(v.astype(out[k].dtype))
    return out
