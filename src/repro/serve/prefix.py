"""Fleet-visible prefix index: token-hash lookup of cached prompt-prefix
page runs in a `PagedKVPool`'s share band.

Production prompts are dominated by shared system prompts and multi-turn
sessions; re-prefilling the common prefix per request is the biggest
redundant cost in the serving hot path. This index closes it:

  * `register` anchors a freshly prefilled prefix: the engine aliases
    the request's first full pages into a SHARE-band row of the pool
    (`register_entry_pages`) and this index records a CHAIN HASH per
    page-aligned depth (`H_i = crc32(tokens[i*P:(i+1)*P], H_{i-1})`).
  * `match` walks a new prompt's chain hashes deepest-first; a hit is
    verified token-exact, then extended token-granularly into the
    entry's boundary page — so mid-page sharing works, with the pool's
    copy-on-write path covering the divergence write.
  * Entries are evicted LRU at refcount 0 only; under byte pressure the
    pool DEMOTES cold entry pages Normal -> Augmented instead (the
    dual-context ROM-augmented 8T RAM of arXiv:2304.02908 — the second
    context keeps the data alive in denser, refresh-backed storage).

The index is host-only metadata (no device state); the pool owns the
pages, refcounts, and byte accounting.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Optional

import numpy as np


def chain_hashes(tokens: np.ndarray, page_size: int) -> list[int]:
    """Chained crc32 per full page of `tokens`: hash i covers pages
    [0, i] — prefix containment is a chain-walk, not a rehash."""
    tokens = np.ascontiguousarray(np.asarray(tokens, np.int32))
    out: list[int] = []
    h = 0
    for i in range(tokens.size // page_size):
        h = zlib.crc32(tokens[i * page_size:(i + 1) * page_size].tobytes(), h)
        out.append(h)
    return out


@dataclasses.dataclass
class PrefixEntry:
    slot: int                   # share-band slot (pool row = entry_row(slot))
    row: int                    # pool share-band row anchoring the pages
    tokens: np.ndarray          # the cached run (page-aligned length)
    n_pages: int
    hashes: list[int]           # chain hash per page depth
    hits: int = 0
    last_use_step: int = -1
    created_step: int = -1


class PrefixIndex:
    """Hash index over cached prefix entries. Pure host metadata —
    `match` never mutates pool state, so placement can probe it."""

    def __init__(self, entries: int, page_size: int):
        self.capacity = entries
        self.page_size = page_size
        self.entries: dict[int, PrefixEntry] = {}        # by slot
        self._free_slots = list(range(entries - 1, -1, -1))
        self._by_hash: dict[int, list[int]] = {}         # hash -> slots
        self.stats = {"hits": 0, "misses": 0, "tokens_shared": 0,
                      "registered": 0, "evicted": 0, "invalidated": 0}

    # -- lookup ----------------------------------------------------------------

    def match(self, tokens: np.ndarray) -> tuple[Optional[PrefixEntry], int]:
        """Deepest cached prefix of `tokens`: (entry, n_matched_tokens),
        or (None, 0). Page-granular via the chain hashes, then extended
        token-granularly into the entry's boundary page (only possible
        when the entry has a page past the matched depth). PURE."""
        tokens = np.asarray(tokens, np.int32)
        P = self.page_size
        hs = chain_hashes(tokens, P)
        for d in range(len(hs), 0, -1):
            slots = self._by_hash.get(hs[d - 1])
            if not slots:
                continue
            # among same-depth candidates, the one whose boundary page
            # extends furthest wins (ties -> first registered)
            best, best_m = None, 0
            for slot in slots:
                e = self.entries.get(slot)
                if e is None or e.n_pages < d:
                    continue
                if not np.array_equal(e.tokens[:d * P], tokens[:d * P]):
                    continue        # crc collision — verify token-exact
                m = d * P
                if d < e.n_pages:   # extend into the boundary page
                    lim = min(tokens.size, (d + 1) * P)
                    while m < lim and int(e.tokens[m]) == int(tokens[m]):
                        m += 1
                if best is None or m > best_m:
                    best, best_m = e, m
            if best is not None:
                return best, best_m
        return None, 0

    def note_hit(self, e: PrefixEntry, m: int, step: int) -> None:
        e.hits += 1
        e.last_use_step = step
        self.stats["hits"] += 1
        self.stats["tokens_shared"] += m

    def note_miss(self) -> None:
        self.stats["misses"] += 1

    # -- registration / eviction -----------------------------------------------

    def acquire_slot(self, pool, step: int) -> Optional[int]:
        """A free share-band slot, LRU-evicting an idle entry if full.
        None when every entry's pages are still mapped by live rows."""
        if self._free_slots:
            return self._free_slots.pop()
        if self.evict_one(pool, step):
            return self._free_slots.pop()
        return None

    def add_entry(self, slot: int, row: int, tokens: np.ndarray,
                  step: int) -> PrefixEntry:
        tokens = np.asarray(tokens, np.int32).copy()
        hashes = chain_hashes(tokens, self.page_size)
        n_pages = len(hashes)
        assert n_pages and tokens.size == n_pages * self.page_size
        e = PrefixEntry(slot=slot, row=row, tokens=tokens, n_pages=n_pages,
                        hashes=hashes, last_use_step=step, created_step=step)
        self.entries[slot] = e
        for h in hashes:
            self._by_hash.setdefault(h, []).append(slot)
        self.stats["registered"] += 1
        return e

    def _unlink(self, e: PrefixEntry) -> None:
        for h in e.hashes:
            slots = self._by_hash.get(h)
            if slots and e.slot in slots:
                slots.remove(e.slot)
                if not slots:
                    del self._by_hash[h]
        self.entries.pop(e.slot, None)
        self._free_slots.append(e.slot)

    def _idle(self, pool, e: PrefixEntry) -> bool:
        """Every page of `e` is held only by share-band refs (refcount
        == share-band aliases) — freeing the entry row drops them to 0."""
        for lp in range(e.n_pages):
            if not pool.allocated[e.row, lp]:
                continue
            if pool.page_refcount(e.row, lp) > 1:
                return False
        return True

    def evict_one(self, pool, step: int) -> bool:
        """Evict the least-recently-used IDLE entry, freeing its pages
        (refcount 0 by construction). False = every entry is live."""
        cand = [e for e in self.entries.values() if self._idle(pool, e)]
        if not cand:
            return False
        victim = min(cand, key=lambda e: (e.last_use_step, e.created_step))
        pool.free_row(victim.row)
        pool.stats["prefix_evictions"] += 1
        self._unlink(victim)
        self.stats["evicted"] += 1
        return True

    def invalidate(self, pool) -> None:
        """Drop every entry (array loss: the arenas behind the pages are
        gone; the hash index must not serve stale physical pages)."""
        for e in list(self.entries.values()):
            pool.free_row(e.row)
            self._unlink(e)
            self.stats["invalidated"] += 1

    # -- placement probe / introspection ---------------------------------------

    def probe(self, tokens: np.ndarray) -> int:
        """Matched-token count only (pure, cheap) — the affinity
        placement policy's prefix-locality signal."""
        _e, m = self.match(tokens)
        return m

    def describe(self) -> dict:
        total = self.stats["hits"] + self.stats["misses"]
        return {
            "capacity": self.capacity,
            "entries": len(self.entries),
            "hit_rate": self.stats["hits"] / total if total else 0.0,
            **self.stats,
        }
