"""Pallas TPU kernel: fused KV quantize-and-pack (the write driver of the
serving engine's augmented dynamic plane).

Prefill produces bf16 K/V chunks; the packed cache stores int4 nibbles +
per-token scales. Doing quantize -> nibble-pack as one kernel means the
bf16 chunk streams HBM->VMEM once and only packed bytes + scales go back —
no dequantized or int8 intermediate ever lands in HBM (the paper's "write
boosting": one array access per stored word, however many logical values
it encodes).

Per row (one token x head): scale = max|x| / 7, q = clip(round(x/scale)),
even lanes -> high nibble, odd lanes -> low nibble (same convention as
`quant.pack_int4_pair`, so the attention kernel's unpack is the inverse).

Grid: (N // bn,) over flattened token-head rows — embarrassingly parallel
(`dimension_semantics=("parallel",)`). Block (bn, D): with bn = 256,
D = 128 the VMEM term is bn*D*2 (in) + bn*D/2 (packed) + bn*4 (scale)
~ 81 KiB, far under budget; Mosaic double-buffers the row stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import INT4_MAX

DEFAULT_BN = 256


def _qpack_kernel(x_ref, p_ref, s_ref, *, bn: int, d: int):
    # arithmetic stays in the input dtype (bf16 for KV) so the quantized
    # values are bit-identical to quant.quantize_int4 / pack_kv_int4 —
    # the engine's golden test depends on this parity
    x = x_ref[...]                                        # (bn, D)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / INT4_MAX            # (bn, 1)
    q = jnp.clip(jnp.round(x / scale), -INT4_MAX, INT4_MAX).astype(jnp.int8)
    qr = q.reshape(bn, d // 2, 2)
    hi = jnp.bitwise_and(qr[:, :, 0].astype(jnp.uint8), jnp.uint8(0x0F))
    lo = jnp.bitwise_and(qr[:, :, 1].astype(jnp.uint8), jnp.uint8(0x0F))
    p_ref[...] = jnp.bitwise_or(jnp.left_shift(hi, 4), lo)
    s_ref[...] = scale.astype(s_ref.dtype)


def _qpack_integrity_kernel(x_ref, p_ref, s_ref, w_ref, *, bn: int, d: int):
    # quantize-on-write with fused integrity words: the same pack as
    # _qpack_kernel plus a per-row byte-weighted checksum (word =
    # sum_j (j+1) * packed_byte_j mod 2**32, the formula of
    # core.faults.integrity_word) — computed while the packed bytes are
    # still in VMEM, so detection metadata costs no extra array read
    x = x_ref[...]                                        # (bn, D)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / INT4_MAX            # (bn, 1)
    q = jnp.clip(jnp.round(x / scale), -INT4_MAX, INT4_MAX).astype(jnp.int8)
    qr = q.reshape(bn, d // 2, 2)
    hi = jnp.bitwise_and(qr[:, :, 0].astype(jnp.uint8), jnp.uint8(0x0F))
    lo = jnp.bitwise_and(qr[:, :, 1].astype(jnp.uint8), jnp.uint8(0x0F))
    packed = jnp.bitwise_or(jnp.left_shift(hi, 4), lo)
    p_ref[...] = packed
    s_ref[...] = scale.astype(s_ref.dtype)
    lanes = jax.lax.broadcasted_iota(jnp.uint32, (bn, d // 2), 1) + 1
    w_ref[...] = jnp.sum(packed.astype(jnp.uint32) * lanes, axis=1,
                         keepdims=True)


def _qpack_masked_kernel(x_ref, valid_ref, p_ref, s_ref, *, bn: int, d: int):
    # the speculative store-back: rows whose token was REJECTED by the
    # verify pass commit zero bytes + unit scale instead of their values
    # (nothing of the draft window lands in the augmented plane)
    x = x_ref[...]                                        # (bn, D)
    keep = valid_ref[...] != 0                            # (bn, 1)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / INT4_MAX
    q = jnp.clip(jnp.round(x / scale), -INT4_MAX, INT4_MAX).astype(jnp.int8)
    qr = q.reshape(bn, d // 2, 2)
    hi = jnp.bitwise_and(qr[:, :, 0].astype(jnp.uint8), jnp.uint8(0x0F))
    lo = jnp.bitwise_and(qr[:, :, 1].astype(jnp.uint8), jnp.uint8(0x0F))
    packed = jnp.bitwise_or(jnp.left_shift(hi, 4), lo)
    p_ref[...] = jnp.where(keep, packed, jnp.uint8(0))
    s_ref[...] = jnp.where(keep, scale, 1.0).astype(s_ref.dtype)


def quantize_pack_kv_pallas(kv: jax.Array, valid=None, *,
                            bn: int = DEFAULT_BN, interpret: bool = False,
                            with_integrity: bool = False):
    """kv: (N, D) bf16/f32, D even. Returns (packed (N, D//2) uint8,
    scale (N, 1) f32). N % bn == 0 (pad in the wrapper). `valid` (N, 1)
    int32, optional: rows with valid == 0 commit as zeros + unit scale
    (speculative decode commits only accepted tokens). With
    `with_integrity` (unmasked path only) a third (N, 1) uint32 output
    carries the per-row integrity word of `core.faults.integrity_word`
    over the packed bytes, fused with the pack — the detection metadata
    the fault-aware serving stores verify on gather/refresh."""
    N, D = kv.shape
    assert D % 2 == 0, D
    bn = min(bn, N)
    assert N % bn == 0, (N, bn)
    out_specs = [pl.BlockSpec((bn, D // 2), lambda i: (i, 0)),
                 pl.BlockSpec((bn, 1), lambda i: (i, 0))]
    out_shape = [jax.ShapeDtypeStruct((N, D // 2), jnp.uint8),
                 jax.ShapeDtypeStruct((N, 1), jnp.float32)]
    params = pltpu.TPUCompilerParams(dimension_semantics=("parallel",))
    if with_integrity:
        assert valid is None, "with_integrity is for the unmasked write path"
        return pl.pallas_call(
            functools.partial(_qpack_integrity_kernel, bn=bn, d=D),
            grid=(N // bn,),
            in_specs=[pl.BlockSpec((bn, D), lambda i: (i, 0))],
            out_specs=out_specs + [pl.BlockSpec((bn, 1), lambda i: (i, 0))],
            out_shape=out_shape + [jax.ShapeDtypeStruct((N, 1), jnp.uint32)],
            compiler_params=params,
            interpret=interpret,
        )(kv)
    if valid is None:
        return pl.pallas_call(
            functools.partial(_qpack_kernel, bn=bn, d=D),
            grid=(N // bn,),
            in_specs=[pl.BlockSpec((bn, D), lambda i: (i, 0))],
            out_specs=out_specs,
            out_shape=out_shape,
            compiler_params=params,
            interpret=interpret,
        )(kv)
    assert valid.shape == (N, 1), (valid.shape, N)
    return pl.pallas_call(
        functools.partial(_qpack_masked_kernel, bn=bn, d=D),
        grid=(N // bn,),
        in_specs=[pl.BlockSpec((bn, D), lambda i: (i, 0)),
                  pl.BlockSpec((bn, 1), lambda i: (i, 0))],
        out_specs=out_specs,
        out_shape=out_shape,
        compiler_params=params,
        interpret=interpret,
    )(kv, valid.astype(jnp.int32))
