"""Pallas TPU kernel: bit-serial in-memory-compute (IMC) dot product over
packed augmented storage.

The paper closes by noting the augmented bit-cells "can be seamlessly
combined with existing in-memory computing approaches"; this kernel is that
combination, reproducing the array semantics of "8T SRAM Cell as a
Multi-bit Dot Product Engine" (arXiv:1802.08601) and the reconfigurable
activation precision of "Bit Parallel 6T SRAM In-memory Computing"
(arXiv:2008.03378) on top of this repo's packed weight formats:

  * the weights stay IN THE ARRAY — consumed exactly as stored (2-bit
    ternary trits, dual-plane uint8, int4/int8), never dequantized in HBM;
  * activations are driven WORDLINE-SERIAL: quantized to `abits` bits
    (1/4/8 reconfigurable), then streamed one magnitude bit-plane per
    cycle — each cycle is one {-1,0,+1}-valued plane times the resident
    weights (the MXU dot plays the bitline-parallel analog accumulation);
  * partial sums are shift-added (x2^b) and the per-output-channel weight
    scale is applied in the epilogue (the ADC / sense stage).

Exactness: every bit-plane product and shift-add is integer-valued, and
for the ternary/dual/int4 formats the accumulated magnitudes stay well
under 2^24 at practical K, so the fp32 accumulation is EXACT — at full
activation precision the kernel is bit-identical to `ternary_matmul` /
`dual_plane_matmul` on the same packed bytes (golden-tested). int8
weights can exceed 2^24 beyond K ~ 1k (127*127*K), where parity vs the
oracle is near-exact rather than bit-exact (the oracle sums full-K
plane dots, the kernel per-bk blocks). The array-level event/energy model
for this access pattern (wordline pulses, bitline discharges, ADC
conversions) lives in `repro.imc.energy`.

Block sizes default to the ternary kernel's (128, 512, 256); VMEM adds one
(bm, bk) int8 activation tile + (bm, 1) scale over the packed-matmul
footprint, still far under budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 128
DEFAULT_BK = 512
DEFAULT_BN = 256

# Weight storage formats consumed as stored (no dequantized HBM copy).
IMC_FORMATS = ("ternary", "dual", "int8", "int4")


def mag_bits(abits: int) -> int:
    """Bit-serial cycles per activation: magnitude bits of a signed
    `abits`-bit value (sign rides each plane, it is not a cycle)."""
    return 1 if abits == 1 else abits - 1


def qmax_for(abits: int) -> int:
    """Symmetric activation range: [-qmax, qmax]; abits=1 is binary
    {-1, 0, +1} (the BNN-style limit of arXiv:2008.03378)."""
    return 1 if abits == 1 else 2 ** (abits - 1) - 1


def quantize_activations(x: jax.Array, abits: int):
    """Per-row symmetric quantization of the activation operand (the DAC
    in front of the wordline drivers). x (M, K) -> (xq int8, xs (M,1) f32)
    with x ~= xq * xs. When a row's absmax equals qmax the scale is
    exactly 1.0 and the bit-serial path is exact (the parity goldens)."""
    xf = x.astype(jnp.float32)
    q = qmax_for(abits)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    xs = jnp.maximum(amax, 1e-8) / q
    xq = jnp.clip(jnp.round(xf / xs), -q, q).astype(jnp.int8)
    return xq, xs


# ---------------------------------------------------------------------------
# In-VMEM weight unpack (the resident array contents, by format)
# ---------------------------------------------------------------------------

def _unpack_ternary(wp: jax.Array, bk: int, bn: int) -> jax.Array:
    """(bk//4, bn) uint8 -> (bk, bn) bf16 trits (same as ternary_matmul)."""
    shifts = (jnp.arange(4, dtype=jnp.uint8) * 2)[None, :, None]
    d = jnp.bitwise_and(jnp.right_shift(wp[:, None, :], shifts),
                        jnp.uint8(0x3))
    return (d.astype(jnp.int8) - 1).reshape(bk, bn).astype(jnp.bfloat16)


def _unpack_int4_rows(wp: jax.Array, bk: int, bn: int) -> jax.Array:
    """(bk//2, bn) uint8 -> (bk, bn) bf16: two K-adjacent int4 rows per
    byte (hi nibble = even row, lo = odd row)."""
    hi = jnp.right_shift(wp.astype(jnp.int8), 4)
    lo = jnp.right_shift(
        jnp.left_shift(wp.astype(jnp.uint8), 4).astype(jnp.int8), 4)
    w = jnp.stack([hi, lo], axis=1)                  # (bk//2, 2, bn)
    return w.reshape(bk, bn).astype(jnp.bfloat16)


def _unpack_dual(buf: jax.Array):
    """(bk, bn) uint8 -> (hi, lo) bf16 planes (same as dual_plane_matmul)."""
    hi = jnp.right_shift(buf.astype(jnp.int8), 4)
    lo = jnp.right_shift(
        jnp.left_shift(buf.astype(jnp.uint8), 4).astype(jnp.int8), 4)
    return hi.astype(jnp.bfloat16), lo.astype(jnp.bfloat16)


def _weights_for(fmt: str, wp: jax.Array, bk: int, bn: int):
    if fmt == "ternary":
        return _unpack_ternary(wp, bk, bn)
    if fmt == "int4":
        return _unpack_int4_rows(wp, bk, bn)
    return wp.astype(jnp.bfloat16)                   # int8


def _bit_serial_acc(xq: jax.Array, w, acc_refs, abits: int) -> None:
    """The wordline-serial loop: one magnitude bit-plane per cycle, MXU dot
    per plane per resident weight plane, shift-added into fp32 scratch.
    `w`/`acc_refs` are matching tuples (1 for single-plane formats, 2 for
    dual — ONE wordline drive feeds BOTH planes' bitlines)."""
    xi = xq.astype(jnp.int32)
    sign = jnp.sign(xi)
    mag = jnp.abs(xi)
    for b in range(mag_bits(abits)):
        bit = jnp.bitwise_and(jnp.right_shift(mag, b), 1)
        plane = (sign * bit).astype(jnp.bfloat16)
        for wk, acc in zip(w, acc_refs):
            acc[...] += (2.0 ** b) * jnp.dot(
                plane, wk, preferred_element_type=jnp.float32)


def _imc_dot_kernel(xq_ref, xs_ref, wp_ref, ws_ref, o_ref, acc_ref, *,
                    fmt: str, bk: int, bn: int, abits: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _weights_for(fmt, wp_ref[...], bk, bn)
    _bit_serial_acc(xq_ref[...], (w,), (acc_ref,), abits)

    @pl.when(k_step == pl.num_programs(2) - 1)
    def _done():
        # ADC + sense epilogue: activation LSB size, then weight scale —
        # with xs == 1.0 this is bit-identical to the packed kernels'
        # (acc * scale) epilogue
        o_ref[...] = (acc_ref[...] * xs_ref[...]
                      * ws_ref[...]).astype(o_ref.dtype)


def _imc_dual_kernel(xq_ref, xs_ref, buf_ref, hs_ref, ls_ref, ohi_ref,
                     olo_ref, acc_hi, acc_lo, *, abits: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_hi[...] = jnp.zeros_like(acc_hi)
        acc_lo[...] = jnp.zeros_like(acc_lo)

    hi, lo = _unpack_dual(buf_ref[...])
    _bit_serial_acc(xq_ref[...], (hi, lo), (acc_hi, acc_lo), abits)

    @pl.when(k_step == pl.num_programs(2) - 1)
    def _done():
        ohi_ref[...] = (acc_hi[...] * xs_ref[...]
                        * hs_ref[...]).astype(ohi_ref.dtype)
        olo_ref[...] = (acc_lo[...] * xs_ref[...]
                        * ls_ref[...]).astype(olo_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call drivers
# ---------------------------------------------------------------------------

def _k_pack(fmt: str) -> int:
    """Packed K rows per storage byte-row for each format."""
    return {"ternary": 4, "int4": 2, "int8": 1, "dual": 1}[fmt]


def imc_dot_pallas(xq: jax.Array, xs: jax.Array, wp: jax.Array,
                   scale: jax.Array, *, fmt: str, abits: int,
                   bm: int = DEFAULT_BM, bk: int = DEFAULT_BK,
                   bn: int = DEFAULT_BN, out_dtype=jnp.bfloat16,
                   interpret: bool = False) -> jax.Array:
    """xq (M, K) int8 + xs (M, 1) f32 activations; wp packed weights:
    (K//4, N) u8 trits / (K//2, N) u8 int4 rows / (K, N) i8; scale (1, N)
    f32. Returns (M, N) out_dtype. M % bm == K % bk == N % bn == 0."""
    assert fmt in ("ternary", "int4", "int8"), fmt
    M, K = xq.shape
    kp = _k_pack(fmt)
    Kp, N = wp.shape
    assert Kp * kp == K, (Kp, kp, K)
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (M, K, N, bm, bk, bn)
    assert bk % kp == 0, (bk, kp)
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        functools.partial(_imc_dot_kernel, fmt=fmt, bk=bk, bn=bn,
                          abits=abits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bk // kp, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xq, xs, wp, scale)


def imc_dual_dot_pallas(xq: jax.Array, xs: jax.Array, buf: jax.Array,
                        hi_scale: jax.Array, lo_scale: jax.Array, *,
                        abits: int, bm: int = DEFAULT_BM, bk: int = 256,
                        bn: int = DEFAULT_BN, out_dtype=jnp.bfloat16,
                        interpret: bool = False):
    """Dual-plane IMC dot: ONE wordline-serial activation stream drives
    BOTH int4 planes of the resident uint8 array. Returns (y_hi, y_lo)."""
    M, K = xq.shape
    K2, N = buf.shape
    assert K2 == K, (K2, K)
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (M, K, N, bm, bk, bn)
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        functools.partial(_imc_dual_kernel, abits=abits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=[pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
                   pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((M, N), out_dtype),
                   jax.ShapeDtypeStruct((M, N), out_dtype)],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xq, xs, buf, hi_scale, lo_scale)
