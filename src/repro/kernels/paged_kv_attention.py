"""Pallas TPU kernel: flash-decode attention over a PAGED, mode-switchable
augmented KV pool (the serving layer's page-table-indexed variant of
`packed_kv_attention`).

The pool stores fixed-size pages (page_size tokens) in one of two planes:

  Normal     bf16 arena  kn/vn: (Nn, KV, page, D)       — the 6T mode
  Augmented  packed arena kp/vp: (Np, KV, page, D//2|D)  — int4/int8 +
             per-(token, head) scales ks/vs: (Np, KV, page)

A sequence's logical cache is the concatenation of its page table entries
in logical order; each page carries a mode bit. The kernel walks logical
pages (innermost grid dim) and computes the online softmax exactly as the
contiguous `packed_kv_attention` does with bs == page_size — on a pool
whose pages are all Augmented this is BIT-IDENTICAL to the contiguous
kernel (same block walk, same op order), which is the golden anchor.

Scalar-prefetched page tables: `lengths` (B,), `modes` (B, maxP) and the
two HOLD-PREVIOUS gather index arrays `normal_idx` / `packed_idx`
(B, maxP) sit in SMEM before the grid runs. The host precomputes
hold-previous semantics: normal_idx[b, s] is the physical Normal page to
have resident at logical step s — the page itself when modes[b, s] == 0,
else the index already resident from the previous step, so the mode-
mismatched arena issues NO new DMA (the same pipeline-reuse trick the
contiguous kernel plays for skipped length blocks). Entries past a row's
valid page count are clamped to the last valid entry for the same reason.

Grid: (B, KV, maxP); B and KV are `parallel`, the page walk is
`arbitrary` (carries the softmax state). `pl.when` guards pages past
cdiv(length, page) — no MXU/VPU work for short rows, so grid work is
proportional to actual cache length exactly as in the contiguous kernel.

TPU note: page_size is the sequence-block size; pick >= the dtype's
sublane tile (16 for bf16, 32 for int8) on real hardware. CPU tests run
in interpret mode where any page size goes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.packed_kv_attention import NEG_INF, _load_kv_block


def _paged_kernel(lens_ref, modes_ref, ni_ref, pi_ref, q_ref, kn_ref,
                  vn_ref, kp_ref, vp_ref, ks_ref, vs_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, page: int, scale: float,
                  kv_bits: int):
    b = pl.program_id(0)
    s_step = pl.program_id(2)
    length = lens_ref[b]
    nvp = jnp.maximum(pl.cdiv(length, page), 1)   # >=1 so init/output fire
    visited = s_step < nvp

    @pl.when(s_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(visited)
    def _compute():
        q = q_ref[0, 0]                               # (Hg, D) bf16
        is_aug = modes_ref[b, s_step] == 1
        # both candidate blocks are resident (the mismatched arena's index
        # map held its previous block -> no DMA was issued for it)
        k_aug = _load_kv_block(kp_ref[0, 0], kv_bits)  # (page, D) bf16
        v_aug = _load_kv_block(vp_ref[0, 0], kv_bits)
        k = jnp.where(is_aug, k_aug, kn_ref[0, 0])
        v_int = jnp.where(is_aug, v_aug, vn_ref[0, 0])
        # Normal pages are pre-scaled bf16: the "sense amplifier" scale
        # collapses to 1. Augmented pages dequantize on score COLUMNS.
        one = jnp.ones((page,), jnp.float32)
        k_scale = jnp.where(is_aug, ks_ref[0, 0].astype(jnp.float32), one)
        v_scale = jnp.where(is_aug, vs_ref[0, 0].astype(jnp.float32), one)

        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        s = s * (k_scale * scale)[None, :]            # (Hg, page)
        valid = (s_step * page
                 + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)) < length
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[...]                           # (Hg, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                        # (Hg, page)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        pv = (p * v_scale[None, :]).astype(jnp.bfloat16)
        acc_ref[...] = (acc_ref[...] * alpha
                        + jnp.dot(pv, v_int,
                                  preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(s_step == nvp - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def paged_kv_attention_pallas(q: jax.Array, kn: jax.Array, vn: jax.Array,
                              kp: jax.Array, vp: jax.Array,
                              k_scale: jax.Array, v_scale: jax.Array,
                              lengths: jax.Array, modes: jax.Array,
                              normal_idx: jax.Array, packed_idx: jax.Array,
                              *, page: int, kv_bits: int = 4,
                              interpret: bool = False):
    """q: (B, KV, Hg, D) bf16; kn/vn: (Nn, KV, page, D) bf16;
    kp/vp: (Np, KV, page, D//2) uint8 (kv_bits=4) or (Np, KV, page, D)
    int8 (kv_bits=8); k/v_scale: (Np, KV, page) bf16; lengths: (B,) int32;
    modes / normal_idx / packed_idx: (B, maxP) int32 with HOLD-PREVIOUS
    gather semantics precomputed on the host (see module docstring).
    Returns (B, KV, Hg, D) bf16."""
    B, KV, Hg, D = q.shape
    maxP = modes.shape[1]
    assert kv_bits in (4, 8), kv_bits
    d_store = D // 2 if kv_bits == 4 else D
    assert kn.shape[2:] == (page, D), (kn.shape, page, D)
    assert kp.shape[2:] == (page, d_store), (kp.shape, page, d_store)
    scale = 1.0 / (D ** 0.5)
    lengths = jnp.minimum(lengths.astype(jnp.int32), maxP * page)

    def _nidx(b, h, s, lens, modes, ni, pi):
        return (ni[b, s], h, 0, 0)

    def _pidx(b, h, s, lens, modes, ni, pi):
        return (pi[b, s], h, 0, 0)

    def _pscale(b, h, s, lens, modes, ni, pi):
        return (pi[b, s], h, 0)

    in_specs = [
        pl.BlockSpec((1, 1, Hg, D), lambda b, h, s, *_: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, page, D), _nidx),
        pl.BlockSpec((1, 1, page, D), _nidx),
        pl.BlockSpec((1, 1, page, d_store), _pidx),
        pl.BlockSpec((1, 1, page, d_store), _pidx),
        pl.BlockSpec((1, 1, page), _pscale),
        pl.BlockSpec((1, 1, page), _pscale),
    ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B, KV, maxP),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, Hg, D), lambda b, h, s, *_: (b, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((Hg, D), jnp.float32),
                        pltpu.VMEM((Hg, 1), jnp.float32),
                        pltpu.VMEM((Hg, 1), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_paged_kernel, page=page, scale=scale,
                          kv_bits=kv_bits),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, Hg, D), jnp.bfloat16),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, modes.astype(jnp.int32), normal_idx.astype(jnp.int32),
      packed_idx.astype(jnp.int32), q, kn, vn, kp, vp, k_scale, v_scale)


def _paged_window_kernel(starts_ref, modes_ref, ni_ref, pi_ref, q_ref,
                         kn_ref, vn_ref, kp_ref, vp_ref, ks_ref, vs_ref,
                         o_ref, acc_ref, m_ref, l_ref, *, page: int,
                         scale: float, kv_bits: int, win: int, hg: int):
    """W-query-token variant of `_paged_kernel` for speculative verify.

    The W window queries of a row share one page walk: queries are
    flattened onto the score rows ((win*Hg, page) per page) and every
    softmax-state op is row-independent, so each window slot w computes
    EXACTLY the single-token kernel's op sequence for length
    starts + w + 1 — pages wholly past a slot's horizon contribute
    exp(NEG_INF - m) == 0.0 in f32, a bit-exact no-op. That is the
    token-identity anchor the speculative engine's golden test pins."""
    b = pl.program_id(0)
    s_step = pl.program_id(2)
    max_p = pl.num_programs(2)
    start = starts_ref[b]
    # horizon of the LAST window slot, clamped to the table's reach
    length = jnp.minimum(start + win, max_p * page)
    nvp = jnp.clip(pl.cdiv(length, page), 1, max_p)
    visited = s_step < nvp
    rows = win * hg

    @pl.when(s_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(visited)
    def _compute():
        q = q_ref[0, 0].reshape(rows, q_ref.shape[-1])     # (W*Hg, D) bf16
        is_aug = modes_ref[b, s_step] == 1
        k_aug = _load_kv_block(kp_ref[0, 0], kv_bits)      # (page, D) bf16
        v_aug = _load_kv_block(vp_ref[0, 0], kv_bits)
        k = jnp.where(is_aug, k_aug, kn_ref[0, 0])
        v_int = jnp.where(is_aug, v_aug, vn_ref[0, 0])
        one = jnp.ones((page,), jnp.float32)
        k_scale = jnp.where(is_aug, ks_ref[0, 0].astype(jnp.float32), one)
        v_scale = jnp.where(is_aug, vs_ref[0, 0].astype(jnp.float32), one)

        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        s = s * (k_scale * scale)[None, :]                 # (W*Hg, page)
        # causal-inside-the-window mask: score row r belongs to window
        # slot r // Hg, whose horizon is start + slot + 1 tokens
        col = (s_step * page
               + jax.lax.broadcasted_iota(jnp.int32, (rows, page), 1))
        slot = jax.lax.broadcasted_iota(jnp.int32, (rows, page), 0) // hg
        s = jnp.where(col < start + slot + 1, s, NEG_INF)

        m_prev = m_ref[...]                                # (W*Hg, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                             # (W*Hg, page)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        pv = (p * v_scale[None, :]).astype(jnp.bfloat16)
        acc_ref[...] = (acc_ref[...] * alpha
                        + jnp.dot(pv, v_int,
                                  preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(s_step == nvp - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...]).reshape(
            win, hg, o_ref.shape[-1]).astype(o_ref.dtype)


def paged_kv_attention_window_pallas(q: jax.Array, kn: jax.Array,
                                     vn: jax.Array, kp: jax.Array,
                                     vp: jax.Array, k_scale: jax.Array,
                                     v_scale: jax.Array, starts: jax.Array,
                                     modes: jax.Array, normal_idx: jax.Array,
                                     packed_idx: jax.Array, *, page: int,
                                     kv_bits: int = 4,
                                     interpret: bool = False):
    """q: (B, KV, W, Hg, D) bf16 — W speculative window queries per row at
    absolute positions starts + [0..W); arenas/scales/tables laid out as
    `paged_kv_attention_pallas`. Window slot w attends tokens
    < starts[b] + w + 1. Returns (B, KV, W, Hg, D) bf16."""
    B, KV, W, Hg, D = q.shape
    maxP = modes.shape[1]
    assert kv_bits in (4, 8), kv_bits
    d_store = D // 2 if kv_bits == 4 else D
    assert kn.shape[2:] == (page, D), (kn.shape, page, D)
    assert kp.shape[2:] == (page, d_store), (kp.shape, page, d_store)
    scale = 1.0 / (D ** 0.5)

    def _nidx(b, h, s, lens, modes, ni, pi):
        return (ni[b, s], h, 0, 0)

    def _pidx(b, h, s, lens, modes, ni, pi):
        return (pi[b, s], h, 0, 0)

    def _pscale(b, h, s, lens, modes, ni, pi):
        return (pi[b, s], h, 0)

    in_specs = [
        pl.BlockSpec((1, 1, W, Hg, D), lambda b, h, s, *_: (b, h, 0, 0, 0)),
        pl.BlockSpec((1, 1, page, D), _nidx),
        pl.BlockSpec((1, 1, page, D), _nidx),
        pl.BlockSpec((1, 1, page, d_store), _pidx),
        pl.BlockSpec((1, 1, page, d_store), _pidx),
        pl.BlockSpec((1, 1, page), _pscale),
        pl.BlockSpec((1, 1, page), _pscale),
    ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B, KV, maxP),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, W, Hg, D),
                               lambda b, h, s, *_: (b, h, 0, 0, 0)),
        scratch_shapes=[pltpu.VMEM((W * Hg, D), jnp.float32),
                        pltpu.VMEM((W * Hg, 1), jnp.float32),
                        pltpu.VMEM((W * Hg, 1), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_paged_window_kernel, page=page, scale=scale,
                          kv_bits=kv_bits, win=W, hg=Hg),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, W, Hg, D), jnp.bfloat16),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(starts.astype(jnp.int32), modes.astype(jnp.int32),
      normal_idx.astype(jnp.int32), packed_idx.astype(jnp.int32),
      q, kn, vn, kp, vp, k_scale, v_scale)
