"""Pallas TPU kernel: flash-decode attention over an int4-packed KV cache
(the serving engine's augmented dynamic plane).

Single-token GQA decode: q (B, KV, Hg, D) attends to a packed cache
k/v (B, S, KV, D//2) uint8 + per-(token, head) scales (B, S, KV).

The kernel never materializes the dequantized cache in HBM:
  * packed K blocks stream HBM->VMEM; scores = (q . k_int) * k_scale —
    the dequant scale is applied to score COLUMNS, not to K elements
    (D-fold cheaper than dequantizing K);
  * online softmax (running max m, denominator l, accumulator acc in VMEM
    scratch across sequence blocks — the innermost grid dim);
  * V blocks likewise stay int4: acc += (p * v_scale) @ v_int.

Memory term: S*D bytes/2 per head instead of S*D*2 (bf16) — 4x less HBM
traffic for the decode bottleneck, which is exactly the paper's augmented
capacity claim applied to the KV working set.

Grid: (B, KV, S//bs); block (bs, D//2) packed KV in VMEM — with bs = 512,
D = 128: 32 KiB packed KV + scratch (Hg x D acc, Hg stats) « VMEM.

The causal/validity mask is handled via the `length` operand (number of
valid cache slots per batch row); invalid columns get -inf scores.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BS = 512
NEG_INF = -1e30


def _unpack_int4_pairs(packed: jax.Array) -> jax.Array:
    """(bs, D//2) uint8 -> (bs, D) bf16 int4 values (interleaved pairs)."""
    hi = jnp.right_shift(packed.astype(jnp.int8), 4)
    lo = jnp.right_shift(
        jnp.left_shift(packed.astype(jnp.uint8), 4).astype(jnp.int8), 4)
    w = jnp.stack([hi, lo], axis=-1)        # (bs, D//2, 2)
    return w.reshape(packed.shape[0], -1).astype(jnp.bfloat16)


def _kv_attn_kernel(q_ref, k_ref, v_ref, ks_ref, vs_ref, len_ref, o_ref,
                    acc_ref, m_ref, l_ref, *, bs: int, scale: float):
    s_step = pl.program_id(2)

    @pl.when(s_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0]                          # (Hg, D) bf16
    k_int = _unpack_int4_pairs(k_ref[0, 0])  # (bs, D)
    v_int = _unpack_int4_pairs(v_ref[0, 0])
    k_scale = ks_ref[0, 0].astype(jnp.float32)  # (bs,)
    v_scale = vs_ref[0, 0].astype(jnp.float32)

    # scores with column-wise dequant
    s = jnp.dot(q, k_int.T, preferred_element_type=jnp.float32)  # (Hg, bs)
    s = s * (k_scale * scale)[None, :]
    # validity mask (ring caches rely on softmax permutation invariance)
    valid = (s_step * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
             ) < len_ref[0]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                      # (Hg, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                   # (Hg, bs)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    pv = (p * v_scale[None, :]).astype(jnp.bfloat16)
    acc_ref[...] = (acc_ref[...] * alpha
                    + jnp.dot(pv, v_int, preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(s_step == pl.num_programs(2) - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def packed_kv_attention_pallas(q: jax.Array, k_packed: jax.Array,
                               v_packed: jax.Array, k_scale: jax.Array,
                               v_scale: jax.Array, lengths: jax.Array, *,
                               bs: int = DEFAULT_BS,
                               interpret: bool = False) -> jax.Array:
    """q: (B, KV, Hg, D) bf16; k/v_packed: (B, KV, S, D//2) uint8;
    scales: (B, KV, S) bf16; lengths: (B,) int32 (valid slots per row).
    Returns (B, KV, Hg, D) bf16."""
    B, KV, Hg, D = q.shape
    S = k_packed.shape[2]
    bs = min(bs, S)
    assert S % bs == 0, (S, bs)
    scale = 1.0 / (D ** 0.5)
    grid = (B, KV, S // bs)
    return pl.pallas_call(
        functools.partial(_kv_attn_kernel, bs=bs, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Hg, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D // 2), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bs, D // 2), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bs), lambda b, h, s: (b, h, s)),
            pl.BlockSpec((1, 1, bs), lambda b, h, s: (b, h, s)),
            pl.BlockSpec((1,), lambda b, h, s: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, Hg, D), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, Hg, D), jnp.bfloat16),
        scratch_shapes=[pltpu.VMEM((Hg, D), jnp.float32),
                        pltpu.VMEM((Hg, 1), jnp.float32),
                        pltpu.VMEM((Hg, 1), jnp.float32)],
        interpret=interpret,
    )(q, k_packed, v_packed, k_scale, v_scale, lengths)
