"""Pallas TPU kernel: flash-decode attention over an int4-packed KV cache
(the serving engine's augmented dynamic plane).

Single-token GQA decode: q (B, KV, Hg, D) attends to a packed cache
k/v (B, S, KV, D//2) uint8 + per-(token, head) scales (B, S, KV).

The kernel never materializes the dequantized cache in HBM:
  * packed K blocks stream HBM->VMEM; scores = (q . k_int) * k_scale —
    the dequant scale is applied to score COLUMNS, not to K elements
    (D-fold cheaper than dequantizing K);
  * online softmax (running max m, denominator l, accumulator acc in VMEM
    scratch across sequence blocks — the innermost grid dim);
  * V blocks likewise stay int4: acc += (p * v_scale) @ v_int.

Length-aware pipelining: `lengths` is SCALAR-PREFETCHED
(`PrefetchScalarGridSpec`), so it is resident in SMEM before the grid
runs. Sequence blocks past a row's valid length are skipped entirely:
the block index maps clamp to the last valid block (the pipeline re-uses
the already-fetched block — no DMA is issued) and the kernel body is
`pl.when`-guarded off (no MXU/VPU work). Grid *work* is therefore
proportional to the actual cache length, not `max_seq` — a 12-token row
in a 64K-slot cache costs one block, not 128.

Memory term: S*D bytes/2 per head instead of S*D*2 (bf16) — 4x less HBM
traffic for the decode bottleneck, which is exactly the paper's augmented
capacity claim applied to the KV working set.

Grid: (B, KV, S//bs); block (bs, D//2) packed KV in VMEM — with bs = 512,
D = 128: 32 KiB packed KV + scratch (Hg x D acc, Hg stats) « VMEM.
B and KV are `parallel` dimension semantics (Mosaic may reorder /
parallelize them); the sequence dim is `arbitrary` (carries the online
softmax state).

The causal/validity mask inside the last valid block is handled via the
same `lengths` operand; fully invalid columns get -inf scores.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BS = 512
NEG_INF = -1e30


def _unpack_int4_pairs(packed: jax.Array) -> jax.Array:
    """(bs, D//2) uint8 -> (bs, D) bf16 int4 values (interleaved pairs)."""
    hi = jnp.right_shift(packed.astype(jnp.int8), 4)
    lo = jnp.right_shift(
        jnp.left_shift(packed.astype(jnp.uint8), 4).astype(jnp.int8), 4)
    w = jnp.stack([hi, lo], axis=-1)        # (bs, D//2, 2)
    return w.reshape(packed.shape[0], -1).astype(jnp.bfloat16)


def _load_kv_block(ref_block: jax.Array, kv_bits: int) -> jax.Array:
    """Packed KV block -> (bs, D) bf16 integer levels.

    4-bit: two nibbles per byte, interleaved pairs. 8-bit: the int8 value
    itself — no unpack, the cast is the whole "sense amplifier"."""
    if kv_bits == 4:
        return _unpack_int4_pairs(ref_block)
    return ref_block.astype(jnp.bfloat16)


def _num_valid_blocks(length, bs: int):
    """Blocks holding >= 1 valid slot; at least 1 so init/output fire."""
    return jnp.maximum(pl.cdiv(length, bs), 1)


def _kv_attn_kernel(lens_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
                    *rest, bs: int, scale: float, kv_bits: int,
                    debug_visits: bool):
    if debug_visits:
        visits_ref, acc_ref, m_ref, l_ref = rest
    else:
        acc_ref, m_ref, l_ref = rest
    s_step = pl.program_id(2)
    length = lens_ref[pl.program_id(0)]
    nvb = _num_valid_blocks(length, bs)
    visited = s_step < nvb

    @pl.when(s_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        if debug_visits:
            visits_ref[0, 0] = 0

    @pl.when(visited)
    def _compute():
        q = q_ref[0, 0]                          # (Hg, D) bf16
        k_int = _load_kv_block(k_ref[0, 0], kv_bits)  # (bs, D)
        v_int = _load_kv_block(v_ref[0, 0], kv_bits)
        k_scale = ks_ref[0, 0].astype(jnp.float32)  # (bs,)
        v_scale = vs_ref[0, 0].astype(jnp.float32)

        # scores with column-wise dequant
        s = jnp.dot(q, k_int.T, preferred_element_type=jnp.float32)
        s = s * (k_scale * scale)[None, :]       # (Hg, bs)
        # validity mask (ring caches rely on softmax permutation invariance)
        valid = (s_step * bs
                 + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)) < length
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[...]                      # (Hg, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                   # (Hg, bs)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        pv = (p * v_scale[None, :]).astype(jnp.bfloat16)
        acc_ref[...] = (acc_ref[...] * alpha
                        + jnp.dot(pv, v_int,
                                  preferred_element_type=jnp.float32))
        m_ref[...] = m_new
        if debug_visits:
            visits_ref[0, 0] += 1

    @pl.when(s_step == nvb - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def packed_kv_attention_pallas(q: jax.Array, k_packed: jax.Array,
                               v_packed: jax.Array, k_scale: jax.Array,
                               v_scale: jax.Array, lengths: jax.Array, *,
                               bs: int = DEFAULT_BS,
                               kv_bits: int = 4,
                               debug_visits: bool = False,
                               interpret: bool = False):
    """q: (B, KV, Hg, D) bf16; k/v_packed: (B, KV, S, D//2) uint8 for
    kv_bits=4 or (B, KV, S, D) int8 for kv_bits=8;
    scales: (B, KV, S) bf16; lengths: (B,) int32 (valid slots per row).
    Returns (B, KV, Hg, D) bf16 [, visits (B, KV) int32 when
    `debug_visits` — the number of sequence blocks actually processed
    per (row, head), for asserting grid work ∝ length]."""
    B, KV, Hg, D = q.shape
    S = k_packed.shape[2]
    assert kv_bits in (4, 8), kv_bits
    d_store = D // 2 if kv_bits == 4 else D
    assert k_packed.shape[-1] == d_store, (k_packed.shape, D, kv_bits)
    bs = min(bs, S)
    assert S % bs == 0, (S, bs)
    scale = 1.0 / (D ** 0.5)
    # clamp: a ring-cache caller may pass position+1 past capacity, which
    # means "all S slots valid" — without this the last-valid-block index
    # lands past the grid and the output row is never written
    lengths = jnp.minimum(lengths.astype(jnp.int32), S)

    def _last_valid(lens, b):
        return jnp.maximum(_num_valid_blocks(lens[b], bs) - 1, 0)

    def _kv_map(b, h, s, lens):
        # clamp: past-length steps re-"fetch" the last valid block, which
        # the pipeline already holds -> no DMA issued for skipped blocks
        return (b, h, jnp.minimum(s, _last_valid(lens, b)), 0)

    def _scale_map(b, h, s, lens):
        return (b, h, jnp.minimum(s, _last_valid(lens, b)))

    in_specs = [
        pl.BlockSpec((1, 1, Hg, D), lambda b, h, s, lens: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, bs, d_store), _kv_map),
        pl.BlockSpec((1, 1, bs, d_store), _kv_map),
        pl.BlockSpec((1, 1, bs), _scale_map),
        pl.BlockSpec((1, 1, bs), _scale_map),
    ]
    out_specs = pl.BlockSpec((1, 1, Hg, D), lambda b, h, s, lens: (b, h, 0, 0))
    out_shape = jax.ShapeDtypeStruct((B, KV, Hg, D), jnp.bfloat16)
    if debug_visits:
        out_specs = [out_specs,
                     pl.BlockSpec((1, 1), lambda b, h, s, lens: (b, h))]
        out_shape = [out_shape,
                     jax.ShapeDtypeStruct((B, KV), jnp.int32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, S // bs),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[pltpu.VMEM((Hg, D), jnp.float32),
                        pltpu.VMEM((Hg, 1), jnp.float32),
                        pltpu.VMEM((Hg, 1), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_kv_attn_kernel, bs=bs, scale=scale,
                          kv_bits=kv_bits, debug_visits=debug_visits),
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, q, k_packed, v_packed, k_scale, v_scale)
