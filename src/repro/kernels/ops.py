"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs in Python for correctness validation; on TPU they compile to
Mosaic. `interpret=None` auto-detects.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.dual_plane_matmul import dual_plane_matmul_pallas
from repro.kernels.imc_dot import (imc_dot_pallas, imc_dual_dot_pallas,
                                   quantize_activations)
from repro.kernels.packed_kv_attention import packed_kv_attention_pallas
from repro.kernels.paged_kv_attention import (
    paged_kv_attention_pallas, paged_kv_attention_window_pallas)
from repro.kernels.quantize_pack_kv import quantize_pack_kv_pallas
from repro.kernels.ternary_matmul import ternary_matmul_pallas


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret",
                                             "use_ref"))
def ternary_matmul(x, w_packed, scale, *, bm=128, bk=512, bn=256,
                   interpret=None, use_ref=False):
    """y = x @ unpack(w_packed) * scale — weights stay 2 bits/value in HBM."""
    if use_ref:
        return ref.ternary_matmul_ref(x, w_packed, scale)
    return ternary_matmul_pallas(x, w_packed, scale, bm=bm, bk=bk, bn=bn,
                                 interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret",
                                             "use_ref"))
def dual_plane_matmul(x, buf, hi_scale, lo_scale, *, bm=128, bk=256, bn=256,
                      interpret=None, use_ref=False):
    """(y_hi, y_lo) = x @ both int4 planes of ONE uint8 buffer."""
    if use_ref:
        return ref.dual_plane_matmul_ref(x, buf, hi_scale, lo_scale)
    return dual_plane_matmul_pallas(x, buf, hi_scale, lo_scale, bm=bm,
                                    bk=bk, bn=bn,
                                    interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("fmt", "abits", "bm", "bk",
                                             "bn", "interpret", "use_ref"))
def imc_dot(x, wp, scale, *, fmt="ternary", abits=8, bm=128, bk=512, bn=256,
            interpret=None, use_ref=False):
    """Bit-serial IMC dot product over packed weights consumed as stored.

    `fmt` selects the resident storage: "ternary" (K//4, N) u8 trits,
    "int4" (K//2, N) u8 row pairs, "int8" (K, N) i8. Activations are
    quantized per-row to `abits` bits (1/4/8 — arXiv:2008.03378's
    reconfigurable precision) and streamed one magnitude bit-plane per
    cycle. At abits=8 with unit activation scale this is bit-exact with
    `ternary_matmul` on the same packed bytes."""
    if use_ref:
        return ref.imc_dot_ref(x, wp, scale, fmt=fmt, abits=abits)
    xq, xs = quantize_activations(x, abits)
    return imc_dot_pallas(xq, xs, wp, scale, fmt=fmt, abits=abits, bm=bm,
                          bk=bk, bn=bn, interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("abits", "bm", "bk", "bn",
                                             "interpret", "use_ref"))
def imc_dual_dot(x, buf, hi_scale, lo_scale, *, abits=8, bm=128, bk=256,
                 bn=256, interpret=None, use_ref=False):
    """Bit-serial IMC dot over BOTH int4 planes of one uint8 array: a
    single wordline-serial activation stream, two bitline-parallel
    accumulations (the 8T dual-bit cell as a dot-product engine)."""
    if use_ref:
        return ref.imc_dual_dot_ref(x, buf, hi_scale, lo_scale, abits=abits)
    xq, xs = quantize_activations(x, abits)
    return imc_dual_dot_pallas(xq, xs, buf, hi_scale, lo_scale, abits=abits,
                               bm=bm, bk=bk, bn=bn,
                               interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("bs", "kv_bits", "debug_visits",
                                             "interpret", "use_ref"))
def packed_kv_attention(q, k_packed, v_packed, k_scale, v_scale, lengths, *,
                        bs=512, kv_bits=4, debug_visits=False, interpret=None,
                        use_ref=False):
    """Flash-decode over a packed KV cache (never dequantized in HBM).

    `kv_bits` selects the storage format: 4 = two int4 nibbles per byte,
    8 = int8. `lengths` is scalar-prefetched: sequence blocks past a row's
    valid length are skipped (no DMA, no compute). With `debug_visits` also
    returns the per-(row, head) count of blocks actually processed."""
    if use_ref:
        assert not debug_visits, "visit counting is a kernel-path feature"
        return ref.packed_kv_attention_ref(q, k_packed, v_packed, k_scale,
                                           v_scale, lengths, kv_bits=kv_bits)
    return packed_kv_attention_pallas(q, k_packed, v_packed, k_scale,
                                      v_scale, lengths, bs=bs,
                                      kv_bits=kv_bits,
                                      debug_visits=debug_visits,
                                      interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("page", "kv_bits", "interpret",
                                             "use_ref"))
def paged_kv_attention(q, kn, vn, kp, vp, k_scale, v_scale, lengths, modes,
                       normal_idx, packed_idx, *, page, kv_bits=4,
                       interpret=None, use_ref=False):
    """Flash-decode over the paged mode-switchable KV pool.

    Walks each row's page table (scalar-prefetched, hold-previous gather
    indices so the mode-mismatched arena issues no DMA); per-page mode
    selects the Normal bf16 plane or the Augmented packed plane. On an
    all-Augmented pool this is bit-identical to `packed_kv_attention`
    with bs == page (same block walk, same op order)."""
    if use_ref:
        # reconstruct the true page table: at mode==1 steps packed_idx
        # holds the real physical page, at mode==0 steps normal_idx does
        table = jnp.where(modes == 1, packed_idx, normal_idx)
        return ref.paged_kv_attention_ref(q, kn, vn, kp, vp, k_scale,
                                          v_scale, lengths, table, modes,
                                          kv_bits=kv_bits)
    return paged_kv_attention_pallas(q, kn, vn, kp, vp, k_scale, v_scale,
                                     lengths, modes, normal_idx, packed_idx,
                                     page=page, kv_bits=kv_bits,
                                     interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("page", "kv_bits", "interpret",
                                             "use_ref"))
def paged_kv_attention_window(q, kn, vn, kp, vp, k_scale, v_scale, starts,
                              modes, normal_idx, packed_idx, *, page,
                              kv_bits=4, interpret=None, use_ref=False):
    """Speculative-verify window variant of `paged_kv_attention`.

    q: (B, KV, W, Hg, D) — the W-token draft window per row at absolute
    positions starts + [0..W); window slot w attends tokens
    < starts + w + 1 (causal inside the window). Per window slot this is
    BIT-IDENTICAL to `paged_kv_attention` at lengths == starts + w + 1:
    the extra pages a slot's shorter horizon masks off contribute
    exp(-inf) == 0.0 exactly in the f32 online softmax, which is what
    makes accept/rollback token-identical to step-by-step decode."""
    if use_ref:
        table = jnp.where(modes == 1, packed_idx, normal_idx)
        return ref.paged_kv_attention_window_ref(
            q, kn, vn, kp, vp, k_scale, v_scale, starts, table, modes,
            kv_bits=kv_bits)
    return paged_kv_attention_window_pallas(
        q, kn, vn, kp, vp, k_scale, v_scale, starts, modes, normal_idx,
        packed_idx, page=page, kv_bits=kv_bits,
        interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("page", "kv_bits", "interpret",
                                             "use_ref"))
def paged_prefix_attention(q, kn, vn, kp, vp, k_scale, v_scale, lengths,
                           modes, normal_idx, packed_idx, *, page,
                           kv_bits=4, interpret=None, use_ref=False):
    """Cross-attention / STATIC-LENGTH variant of `paged_kv_attention`.

    Serves the encoder-decoder cross KV (and any other fixed-length
    prefix band): one un-roped query token per row attends non-causally
    over a page-table band whose valid length is pinned per row
    (`lengths` = prefix tokens, NOT positions + 1). For a single query
    a non-causal read over `lengths` tokens is exactly the causal
    kernel's masked walk, so the same grid and online softmax are reused
    — the page tables just come from the store's prefix band. Rows whose
    prefix is unallocated (length 0) read the write-dump page; callers
    ignore their logits."""
    return paged_kv_attention(q, kn, vn, kp, vp, k_scale, v_scale,
                              lengths, modes, normal_idx, packed_idx,
                              page=page, kv_bits=kv_bits,
                              interpret=interpret, use_ref=use_ref)


@functools.partial(jax.jit, static_argnames=("bn", "interpret", "use_ref"))
def quantize_pack_kv(kv, valid=None, *, bn=256, interpret=None,
                     use_ref=False):
    """Fused bf16 -> int4-packed cache rows + per-token scales, one pass.

    kv: (..., D) with D even. Returns (packed (..., D//2) uint8,
    scale (..., 1) bf16) — the same layout `models.layers.pack_kv_int4`
    produces, with no dequantized/int8 intermediate in HBM. `valid`
    (optional, bool, broadcastable to kv.shape[:-1]) is the speculative
    store-back mask: rows whose token the verify pass REJECTED commit as
    zero bytes + unit scale, so only accepted tokens land in the
    augmented plane."""
    if use_ref:
        p, s = ref.quantize_pack_kv_ref(kv)
        if valid is not None:
            keep = jnp.broadcast_to(valid, kv.shape[:-1])[..., None]
            p = jnp.where(keep, p, jnp.uint8(0))
            s = jnp.where(keep, s, 1.0)
        return p, s.astype(jnp.bfloat16)
    lead = kv.shape[:-1]
    D = kv.shape[-1]
    flat = kv.reshape(-1, D)
    N = flat.shape[0]
    bn_eff = min(bn, N)
    pad = (-N) % bn_eff
    if pad:
        flat = jnp.concatenate(
            [flat, jnp.zeros((pad, D), flat.dtype)], axis=0)
    vflat = None
    if valid is not None:
        vflat = jnp.broadcast_to(valid, lead).reshape(-1, 1).astype(jnp.int32)
        if pad:
            vflat = jnp.concatenate(
                [vflat, jnp.zeros((pad, 1), jnp.int32)], axis=0)
    p, s = quantize_pack_kv_pallas(flat, vflat, bn=bn_eff,
                                   interpret=_auto_interpret(interpret))
    p = p[:N].reshape(*lead, D // 2)
    s = s[:N].reshape(*lead, 1).astype(jnp.bfloat16)
    return p, s
