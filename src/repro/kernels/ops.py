"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs in Python for correctness validation; on TPU they compile to
Mosaic. `interpret=None` auto-detects.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.dual_plane_matmul import dual_plane_matmul_pallas
from repro.kernels.packed_kv_attention import packed_kv_attention_pallas
from repro.kernels.ternary_matmul import ternary_matmul_pallas


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret",
                                             "use_ref"))
def ternary_matmul(x, w_packed, scale, *, bm=128, bk=512, bn=256,
                   interpret=None, use_ref=False):
    """y = x @ unpack(w_packed) * scale — weights stay 2 bits/value in HBM."""
    if use_ref:
        return ref.ternary_matmul_ref(x, w_packed, scale)
    return ternary_matmul_pallas(x, w_packed, scale, bm=bm, bk=bk, bn=bn,
                                 interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret",
                                             "use_ref"))
def dual_plane_matmul(x, buf, hi_scale, lo_scale, *, bm=128, bk=256, bn=256,
                      interpret=None, use_ref=False):
    """(y_hi, y_lo) = x @ both int4 planes of ONE uint8 buffer."""
    if use_ref:
        return ref.dual_plane_matmul_ref(x, buf, hi_scale, lo_scale)
    return dual_plane_matmul_pallas(x, buf, hi_scale, lo_scale, bm=bm,
                                    bk=bk, bn=bn,
                                    interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("bs", "interpret", "use_ref"))
def packed_kv_attention(q, k_packed, v_packed, k_scale, v_scale, lengths, *,
                        bs=512, interpret=None, use_ref=False):
    """Flash-decode over an int4-packed KV cache (never dequantized in HBM)."""
    if use_ref:
        return ref.packed_kv_attention_ref(q, k_packed, v_packed, k_scale,
                                           v_scale, lengths)
    return packed_kv_attention_pallas(q, k_packed, v_packed, k_scale,
                                      v_scale, lengths, bs=bs,
                                      interpret=_auto_interpret(interpret))
