"""Pure-jnp oracles for every kernel (the ground truth the Pallas kernels
are swept against in tests/test_kernels_*.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant, ternary


def rel_err(a, b) -> float:
    """Max relative error vs oracle `b` — the ONE tolerance metric shared
    by the kernel tests and the bench parity columns."""
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return float(np.abs(a - b).max() / max(np.abs(b).max(), 1e-6))


def ternary_matmul_ref(x: jax.Array, w_packed: jax.Array,
                       scale: jax.Array, out_dtype=jnp.bfloat16) -> jax.Array:
    """x (M,K) @ unpack(w_packed (K//4,N)) * scale (1,N)."""
    K = x.shape[1]
    t = ternary.unpack_ternary_2bit(w_packed, K)          # (K, N) int8
    acc = jnp.dot(x.astype(jnp.float32), t.astype(jnp.float32))
    return (acc * scale).astype(out_dtype)


def dual_plane_matmul_ref(x: jax.Array, buf: jax.Array, hi_scale: jax.Array,
                          lo_scale: jax.Array, out_dtype=jnp.bfloat16):
    hi = quant.unpack_int4_hi(buf).astype(jnp.float32)
    lo = quant.unpack_int4_lo(buf).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    return ((xf @ hi * hi_scale).astype(out_dtype),
            (xf @ lo * lo_scale).astype(out_dtype))


def _imc_bit_serial(xq: jax.Array, w: jax.Array, abits: int) -> jax.Array:
    """The wordline-serial shift-add spec: sum_b 2^b (plane_b @ w), every
    plane in {-1,0,+1}. Integer-exact in fp32 (the kernel mirrors this op
    order, so ternary/dual parity is bit-exact, not approximate)."""
    from repro.kernels.imc_dot import mag_bits
    xi = xq.astype(jnp.int32)
    sign, mag = jnp.sign(xi), jnp.abs(xi)
    acc = jnp.zeros((xq.shape[0], w.shape[1]), jnp.float32)
    for b in range(mag_bits(abits)):
        bit = jnp.bitwise_and(jnp.right_shift(mag, b), 1)
        plane = (sign * bit).astype(jnp.bfloat16)
        acc = acc + (2.0 ** b) * jnp.dot(
            plane, w.astype(jnp.bfloat16), preferred_element_type=jnp.float32)
    return acc


def _imc_unpack_weights(fmt: str, wp: jax.Array) -> jax.Array:
    """Materialize the resident array contents (K, N) for the oracle."""
    if fmt == "ternary":
        return ternary.unpack_ternary_2bit(wp, wp.shape[0] * 4)
    if fmt == "int4":
        hi, lo = quant.unpack_int4_hi(wp), quant.unpack_int4_lo(wp)
        return jnp.stack([hi, lo], axis=1).reshape(wp.shape[0] * 2,
                                                   wp.shape[1])
    return wp                                         # int8


def imc_dot_ref(x: jax.Array, wp: jax.Array, scale: jax.Array, *,
                fmt: str = "ternary", abits: int = 8,
                out_dtype=jnp.bfloat16) -> jax.Array:
    """Oracle for `imc_dot`: per-row activation quantization, bit-serial
    accumulation over the format's resident weights, ADC epilogue.

    Bit-exact with the kernel whenever the activation quantization is
    exact (integer-valued rows with absmax == qmax -> unit scale, no
    rounding). For general bf16 inputs the eager quantization here and
    the jitted wrapper's may disagree by 1 ulp on round-to-nearest ties
    (XLA rewrites x/s to x*rcp(s)), so compare with a tolerance."""
    from repro.kernels.imc_dot import quantize_activations
    xq, xs = quantize_activations(x, abits)
    acc = _imc_bit_serial(xq, _imc_unpack_weights(fmt, wp), abits)
    return (acc * xs * scale).astype(out_dtype)


def imc_dual_dot_ref(x: jax.Array, buf: jax.Array, hi_scale: jax.Array,
                     lo_scale: jax.Array, *, abits: int = 8,
                     out_dtype=jnp.bfloat16):
    """Oracle for `imc_dual_dot`: one activation stream, both planes."""
    from repro.kernels.imc_dot import quantize_activations
    xq, xs = quantize_activations(x, abits)
    acc_hi = _imc_bit_serial(xq, quant.unpack_int4_hi(buf), abits)
    acc_lo = _imc_bit_serial(xq, quant.unpack_int4_lo(buf), abits)
    return ((acc_hi * xs * hi_scale).astype(out_dtype),
            (acc_lo * xs * lo_scale).astype(out_dtype))


def quantize_pack_kv_ref(kv: jax.Array):
    """kv (..., D) bf16 -> (packed (..., D//2) uint8, scale (..., 1) f32).
    Same per-row int4 quantization + nibble interleave as
    `models.layers.pack_kv_int4` (even lanes high, odd lanes low)."""
    q, scale = quant.quantize_int4(kv, axis=-1)
    packed = quant.pack_int4_pair(q[..., 0::2], q[..., 1::2])
    return packed, scale.astype(jnp.float32)


def integrity_words_ref(packed: jax.Array) -> jax.Array:
    """Per-row byte-weighted checksum over packed rows (..., Dp) uint8:
    word = sum_j (j + 1) * byte_j mod 2**32 — the fused-integrity output
    of `quantize_pack_kv_pallas(with_integrity=True)` and the per-row
    form of `core.faults.integrity_word`."""
    lanes = jnp.arange(1, packed.shape[-1] + 1, dtype=jnp.uint32)
    return (packed.astype(jnp.uint32) * lanes).sum(axis=-1, keepdims=True)


def _unpack_pairs_ref(packed: jax.Array) -> jax.Array:
    hi = quant.unpack_int4_hi(packed)
    lo = quant.unpack_int4_lo(packed)
    w = jnp.stack([hi, lo], axis=-1)
    return w.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


def paged_gather_kv_ref(kn, vn, kp, vp, k_scale, v_scale, page_table,
                        page_modes, kv_bits: int = 4):
    """Gather a paged two-arena pool into dense head-major caches.

    kn/vn: (Nn, KV, page, D) bf16; kp/vp: (Np, KV, page, D//2|D) packed;
    k/v_scale: (Np, KV, page); page_table/page_modes: (B, maxP) int32
    (physical page index valid in the arena selected by the mode bit).
    Returns (k, v): (B, KV, maxP*page, D) f32 — the logical contiguous
    cache the page table describes (invalid tail pages yield garbage that
    callers mask via lengths)."""
    B, maxP = page_table.shape
    KV, page, D = kn.shape[1], kn.shape[2], kn.shape[3]
    n_sel = jnp.where(page_modes == 0, page_table, 0)
    p_sel = jnp.where(page_modes == 1, page_table, 0)

    def dense(nrm, pkd, scl):
        g_n = nrm[n_sel].astype(jnp.float32)            # (B,maxP,KV,page,D)
        ints = pkd[p_sel]
        ints = (_unpack_pairs_ref(ints) if kv_bits == 4 else ints)
        g_p = (ints.astype(jnp.float32)
               * scl[p_sel].astype(jnp.float32)[..., None])
        out = jnp.where((page_modes == 1)[:, :, None, None, None], g_p, g_n)
        # (B, maxP, KV, page, D) -> (B, KV, maxP*page, D)
        return jnp.moveaxis(out, 2, 1).reshape(B, KV, maxP * page, D)

    return dense(kn, kp, k_scale), dense(vn, vp, v_scale)


def paged_kv_attention_ref(q, kn, vn, kp, vp, k_scale, v_scale, lengths,
                           page_table, page_modes,
                           kv_bits: int = 4) -> jax.Array:
    """Oracle for the paged mixed-mode kernel: gather + dense softmax.
    Layouts as `paged_kv_attention_pallas`, except the page table is the
    TRUE (page_table, page_modes) pair rather than hold-previous gather
    indices."""
    B, KV, Hg, D = q.shape
    k, v = paged_gather_kv_ref(kn, vn, kp, vp, k_scale, v_scale,
                               page_table, page_modes, kv_bits=kv_bits)
    S = k.shape[2]
    lengths = jnp.minimum(lengths.astype(jnp.int32), S)
    s = jnp.einsum("bkhd,bksd->bkhs", q.astype(jnp.float32), k) / (D ** 0.5)
    valid = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkhs,bksd->bkhd", p, v)
    return o.astype(jnp.bfloat16)


def paged_kv_attention_window_ref(q, kn, vn, kp, vp, k_scale, v_scale,
                                  starts, page_table, page_modes,
                                  kv_bits: int = 4) -> jax.Array:
    """Oracle for the speculative-verify window kernel: gather + dense
    softmax with a PER-WINDOW-SLOT causal horizon.

    q: (B, KV, W, Hg, D) — W query tokens per row at absolute positions
    starts + [0..W). Window slot w attends tokens < starts + w + 1 (its
    own position included), so slot 0 reproduces the single-token decode
    read exactly and later slots see the window's own KV causally."""
    B, KV, W, Hg, D = q.shape
    k, v = paged_gather_kv_ref(kn, vn, kp, vp, k_scale, v_scale,
                               page_table, page_modes, kv_bits=kv_bits)
    S = k.shape[2]
    lengths = jnp.minimum(starts.astype(jnp.int32)[:, None]
                          + jnp.arange(W)[None, :] + 1, S)       # (B, W)
    s = jnp.einsum("bkwhd,bksd->bkwhs", q.astype(jnp.float32), k) / (D ** 0.5)
    valid = jnp.arange(S)[None, None, :] < lengths[:, :, None]   # (B, W, S)
    s = jnp.where(valid[:, None, :, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkwhs,bksd->bkwhd", p, v)
    return o.astype(jnp.bfloat16)


def packed_kv_attention_ref(q, k_packed, v_packed, k_scale, v_scale,
                            lengths, kv_bits: int = 4) -> jax.Array:
    """Layouts as the kernel: q (B,KV,Hg,D); kv (B,KV,S,D//2) uint8 for
    kv_bits=4 or (B,KV,S,D) int8 for kv_bits=8;
    scales (B,KV,S); lengths (B,). fp32 softmax, exact."""
    B, KV, Hg, D = q.shape
    S = k_packed.shape[2]
    lengths = jnp.minimum(lengths.astype(jnp.int32), S)
    if kv_bits == 4:
        k_int = _unpack_pairs_ref(k_packed)
        v_int = _unpack_pairs_ref(v_packed)
    else:
        k_int, v_int = k_packed, v_packed
    k = (k_int.astype(jnp.float32)
         * k_scale.astype(jnp.float32)[..., None])         # (B,KV,S,D)
    v = (v_int.astype(jnp.float32)
         * v_scale.astype(jnp.float32)[..., None])
    s = jnp.einsum("bkhd,bksd->bkhs", q.astype(jnp.float32), k) / (D ** 0.5)
    valid = jnp.arange(S)[None, :] < lengths[:, None]       # (B,S)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkhs,bksd->bkhd", p, v)
    return o.astype(jnp.bfloat16)
