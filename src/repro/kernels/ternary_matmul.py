"""Pallas TPU kernel: packed-ternary weight matmul (the 7T augmented cell's
compute path).

Weights live in augmented storage: 2-bit trits, 4 per uint8 byte, packed
along the contraction (K) axis — an 8x capacity augmentation vs bf16.  The
kernel streams PACKED bytes HBM->VMEM (the full-precision weight matrix
never exists in HBM), unpacks trits in VMEM registers (shift/mask — VPU
friendly; base-3 would serialize on divmods), feeds the MXU in bf16, and
applies the per-output-channel TWN scale in the epilogue ("inverter-based
sensing").

Roofline effect (decode, memory-bound): weight bytes / 8 -> the dominant
memory term drops ~8x for weight-dominated steps.

Block sizes: (bm, bk, bn) = (128, 512, 256) by default — MXU-aligned
(multiples of 128 in M/N; bk covers 128 packed rows = 512 trits), VMEM
footprint = bm*bk*2 (x) + bk/4*bn (w) + bm*bn*4 (acc) ~ 292 KiB, well
under the ~16 MiB/core VMEM budget, leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM = 128
DEFAULT_BK = 512   # trits of K per step = 128 packed bytes
DEFAULT_BN = 256


def _unpack_2bit(wp: jax.Array, bk: int, bn: int) -> jax.Array:
    """(bk//4, bn) uint8 -> (bk, bn) bf16 trits in {-1, 0, +1}.

    One broadcast shift over a unit axis extracts all four 2-bit digits
    at once (vs four serialized shift/mask rounds)."""
    shifts = (jnp.arange(4, dtype=jnp.uint8) * 2)[None, :, None]
    d = jnp.bitwise_and(jnp.right_shift(wp[:, None, :], shifts),
                        jnp.uint8(0x3))            # (bk//4, 4, bn)
    w = d.astype(jnp.int8) - 1
    return w.reshape(bk, bn).astype(jnp.bfloat16)


def _ternary_matmul_kernel(x_ref, wp_ref, scale_ref, o_ref, acc_ref, *,
                           bk: int, bn: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _unpack_2bit(wp_ref[...], bk, bn)
    acc_ref[...] += jnp.dot(x_ref[...], w,
                            preferred_element_type=jnp.float32)

    @pl.when(k_step == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = (acc_ref[...] * scale_ref[...]).astype(o_ref.dtype)


def ternary_matmul_pallas(x: jax.Array, w_packed: jax.Array,
                          scale: jax.Array, *,
                          bm: int = DEFAULT_BM, bk: int = DEFAULT_BK,
                          bn: int = DEFAULT_BN,
                          out_dtype=jnp.bfloat16,
                          interpret: bool = False) -> jax.Array:
    """x: (M, K) bf16; w_packed: (K//4, N) uint8; scale: (1, N) f32.

    Returns (M, N) out_dtype. M % bm == 0, K % bk == 0, N % bn == 0.
    """
    M, K = x.shape
    Kp, N = w_packed.shape
    assert Kp * 4 == K, (Kp, K)
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (M, K, N, bm, bk, bn)
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        functools.partial(_ternary_matmul_kernel, bk=bk, bn=bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // 4, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w_packed, scale)
