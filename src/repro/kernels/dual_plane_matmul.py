"""Pallas TPU kernel: dual-plane matmul (the 8T dual-bit augmented cell's
compute path).

ONE physical uint8 buffer holds TWO int4 weight matrices — high nibble =
static plane, low nibble = dynamic plane (e.g. the K-projection and
V-projection of an attention layer, written by the AugmentedStore under
its FILO ledger). The kernel reads each byte from HBM ONCE, splits the
planes in VMEM registers (arithmetic shift for the hi nibble's sign,
shift-left-then-right for lo), and issues two MXU dots per tile:

    y_hi = x @ dequant(hi(buf), hi_scale)
    y_lo = x @ dequant(lo(buf), lo_scale)

vs. two separate bf16 matmuls this moves 4x fewer weight bytes (and 2x
fewer than two separate int4 buffers' worth of scale/index traffic, since
the planes share one stream).

Blocks (bm, bk, bn) = (128, 256, 256): VMEM = bm*bk*2 + bk*bn*1 +
2*bm*bn*4 ~ 384 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 128
DEFAULT_BK = 256
DEFAULT_BN = 256


def _split_planes(buf: jax.Array):
    """uint8 -> (hi int4, lo int4) as bf16, sign-extended."""
    hi = jnp.right_shift(buf.astype(jnp.int8), 4)
    lo = jnp.right_shift(
        jnp.left_shift(buf.astype(jnp.uint8), 4).astype(jnp.int8), 4)
    return hi.astype(jnp.bfloat16), lo.astype(jnp.bfloat16)


def _dual_plane_kernel(x_ref, buf_ref, hs_ref, ls_ref, ohi_ref, olo_ref,
                       acc_hi, acc_lo):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_hi[...] = jnp.zeros_like(acc_hi)
        acc_lo[...] = jnp.zeros_like(acc_lo)

    hi, lo = _split_planes(buf_ref[...])
    x = x_ref[...]
    acc_hi[...] += jnp.dot(x, hi, preferred_element_type=jnp.float32)
    acc_lo[...] += jnp.dot(x, lo, preferred_element_type=jnp.float32)

    @pl.when(k_step == pl.num_programs(2) - 1)
    def _done():
        ohi_ref[...] = (acc_hi[...] * hs_ref[...]).astype(ohi_ref.dtype)
        olo_ref[...] = (acc_lo[...] * ls_ref[...]).astype(olo_ref.dtype)


def dual_plane_matmul_pallas(x: jax.Array, buf: jax.Array,
                             hi_scale: jax.Array, lo_scale: jax.Array, *,
                             bm: int = DEFAULT_BM, bk: int = DEFAULT_BK,
                             bn: int = DEFAULT_BN, out_dtype=jnp.bfloat16,
                             interpret: bool = False):
    """x: (M, K) bf16; buf: (K, N) uint8 (two int4 planes);
    scales: (1, N) f32 per plane. Returns (y_hi, y_lo), each (M, N)."""
    M, K = x.shape
    K2, N = buf.shape
    assert K2 == K
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        _dual_plane_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=[pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
                   pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((M, N), out_dtype),
                   jax.ShapeDtypeStruct((M, N), out_dtype)],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, buf, hi_scale, lo_scale)
