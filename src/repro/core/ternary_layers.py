"""Ternary (error-aware-trained) layers — the 7T cell's end application.

The paper motivates the 7T ternary cell with TNN accelerators and notes
(SS.IV) that error-aware training of the network lets the application
tolerate the augmented storage.  `ternary_dense` is that co-design: the
forward pass uses the ternarized weights (what the augmented memory will
actually hold at serving time), the backward pass flows straight-through to
the fp master, so the network learns to be accurate *under* the augmented
representation.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import ternary


class TernaryDenseParams(NamedTuple):
    w: jax.Array  # fp32/bf16 master weights (in_dim, out_dim)
    b: jax.Array | None


def init_ternary_dense(key, in_dim: int, out_dim: int, bias: bool = True,
                       dtype=jnp.float32) -> TernaryDenseParams:
    w = jax.random.normal(key, (in_dim, out_dim), dtype) / jnp.sqrt(in_dim)
    b = jnp.zeros((out_dim,), dtype) if bias else None
    return TernaryDenseParams(w, b)


def ternary_dense(params: TernaryDenseParams, x: jax.Array,
                  train: bool = True) -> jax.Array:
    """y = x @ ternarize(w) + b, with STE gradients to the master in train."""
    if train:
        wq = ternary.ternarize_ste(params.w)
    else:
        t, scale = ternary.ternarize(params.w)
        wq = ternary.ternary_dequant(t, scale, dtype=params.w.dtype)
    y = x @ wq.astype(x.dtype)
    if params.b is not None:
        y = y + params.b.astype(x.dtype)
    return y


class FrozenTernaryDense(NamedTuple):
    """Serving-time form: weights live packed in augmented memory."""
    packed: jax.Array    # uint8 (in_dim//5, out_dim) base-3 packed
    scale: jax.Array     # (1, out_dim)
    b: jax.Array | None
    in_dim: int


def freeze_ternary_dense(params: TernaryDenseParams,
                         fmt: str = "base3") -> FrozenTernaryDense:
    t, scale = ternary.ternarize(params.w)
    pack = (ternary.pack_ternary_base3 if fmt == "base3"
            else ternary.pack_ternary_2bit)
    return FrozenTernaryDense(pack(t), scale, params.b, params.w.shape[0])


def frozen_ternary_dense_ref(fr: FrozenTernaryDense, x: jax.Array,
                             fmt: str = "base3") -> jax.Array:
    """Pure-jnp serving path (the kernels/ternary_matmul oracle uses this)."""
    unpack = (ternary.unpack_ternary_base3 if fmt == "base3"
              else ternary.unpack_ternary_2bit)
    t = unpack(fr.packed, fr.in_dim)
    w = ternary.ternary_dequant(t, fr.scale, dtype=x.dtype)
    y = x @ w
    if fr.b is not None:
        y = y + fr.b.astype(x.dtype)
    return y
