"""Core AMC (Augmented Memory Computing) library.

The paper's contribution — mode-switchable memory that stores >1 logical
datum per physical word, with retention/refresh and FILO access discipline —
as composable JAX modules.
"""
from repro.core.amc import AugmentedStore, Mode, FILOViolation, RetentionExpired
from repro.core.retention import LeakageModel, RefreshPolicy

__all__ = [
    "AugmentedStore", "Mode", "FILOViolation", "RetentionExpired",
    "LeakageModel", "RefreshPolicy",
]
