"""Quantization primitives shared by the AMC storage planes.

Symmetric integer quantization with per-channel (or per-group) scales.
These are the "sensing"/"writing" circuits of the software-defined
augmented memory: `quantize` is the write driver, `dequantize` the sense
amplifier. Stochastic rounding plays the role of the paper's word-line
boosting — it lets weak writes (values below half an LSB) land on the
correct level in expectation.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

INT4_MAX = 7        # symmetric int4: [-7, 7] (-8 reserved, keeps negation closed)
INT8_MAX = 127


def absmax_scale(x: jax.Array, axis=None, qmax: int = INT4_MAX,
                 eps: float = 1e-8) -> jax.Array:
    """Per-axis symmetric scale so that max|x| maps to qmax."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    return jnp.maximum(amax, eps) / qmax


def quantize(x: jax.Array, scale: jax.Array, qmax: int,
             stochastic: bool = False,
             key: Optional[jax.Array] = None) -> jax.Array:
    """Symmetric quantize to signed ints in [-qmax, qmax] (int8 container)."""
    y = x / scale
    if stochastic:
        if key is None:
            raise ValueError("stochastic rounding requires a PRNG key")
        noise = jax.random.uniform(key, y.shape, dtype=y.dtype) - 0.5
        q = jnp.floor(y + 0.5 + noise)
    else:
        q = jnp.round(y)
    return jnp.clip(q, -qmax, qmax).astype(jnp.int8)


def dequantize(q: jax.Array, scale: jax.Array,
               dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def quantize_int4(x: jax.Array, axis=-1, stochastic: bool = False,
                  key: Optional[jax.Array] = None):
    """Returns (q:int8 in [-7,7], scale) with per-`axis` scales."""
    scale = absmax_scale(x, axis=axis, qmax=INT4_MAX)
    return quantize(x, scale, INT4_MAX, stochastic, key), scale


def quantize_int8(x: jax.Array, axis=-1, stochastic: bool = False,
                  key: Optional[jax.Array] = None):
    scale = absmax_scale(x, axis=axis, qmax=INT8_MAX)
    return quantize(x, scale, INT8_MAX, stochastic, key), scale


# ---------------------------------------------------------------------------
# int4 <-> uint8 nibble packing (two int4 values per byte).
# ---------------------------------------------------------------------------

def pack_int4_pair(hi: jax.Array, lo: jax.Array) -> jax.Array:
    """Pack two int4 tensors (int8 storage, values in [-8,7]) into one uint8.

    `hi` occupies the high nibble, `lo` the low nibble. Shapes must match.
    This is the 8T dual-bit cell: one physical byte, two logical values.
    """
    hi_u = jnp.bitwise_and(hi.astype(jnp.uint8), jnp.uint8(0x0F))
    lo_u = jnp.bitwise_and(lo.astype(jnp.uint8), jnp.uint8(0x0F))
    return jnp.bitwise_or(jnp.left_shift(hi_u, 4), lo_u)


def unpack_int4_hi(packed: jax.Array) -> jax.Array:
    """Extract the high nibble as sign-extended int8 (the static plane)."""
    # arithmetic shift on int8 sign-extends the high nibble
    return jnp.right_shift(packed.astype(jnp.int8), 4)


def unpack_int4_lo(packed: jax.Array) -> jax.Array:
    """Extract the low nibble as sign-extended int8 (the dynamic plane)."""
    shifted = jnp.left_shift(packed.astype(jnp.uint8), 4).astype(jnp.int8)
    return jnp.right_shift(shifted, 4)


def unpack_int4_pair(packed: jax.Array):
    return unpack_int4_hi(packed), unpack_int4_lo(packed)
