"""Retention-fault model for the augmented (dynamic) planes.

The paper's Augmented mode is DYNAMIC storage: charge leaks, and past the
retention window (Tables I-II: 8T 25us @85C / 250us @25C, 7T 4us @85C)
the sense circuit simply cannot recover the bit.  `core/retention.py`
models the *nominal* window; this module models its TAILS — the cells
that fall off the retention cliff early (process variation, hot spots),
the refresh cycles that miss their slot under bank contention, and the
rare whole-array loss (power/pd-gating event taking a macro down).

Everything is sampled DETERMINISTICALLY from `(seed, unit, step)` via a
counter-based hash, so a chaos run is exactly reproducible: the same
seed injects the same corruption at the same steps, which is what lets
the chaos harness prove token-identity against the fault-free run.

Fault probability follows the leakage physics:

  * scales with temperature through `LeakageModel.retention_us` (the
    85C/25C asymmetry of Tables I-II: a hot array faults ~10x more),
  * grows linearly with the unit's AGE within its retention window —
    freshly (re)written cells sit at full level, cells near expiry sit
    at the sense margin where variation bites,
  * becomes CERTAIN once age exceeds `retention_steps` (past the window
    the stored level is below V_SENSE_FRACTION by construction — this
    only happens after a missed refresh).

The static (Normal / 6T) plane never faults here: that is the paper's
static-survives / dynamic-decays asymmetry, and the reason the serving
stack pins repeat-offender units back to Normal mode.

`integrity_word` is the host-side checksum over packed payload + scale
bytes that the state stores stamp at quantize-on-write and verify on
gather/refresh; `kernels/quantize_pack_kv.py` computes the same
byte-weighted word fused with the pack (see `with_integrity`).
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np

from repro.core.retention import LeakageModel


def integrity_word(*arrays) -> int:
    """Byte-weighted checksum over any number of arrays (packed payload
    planes + scale planes of one page/slab): word = sum_i (i + 1) * b_i
    mod 2**32 over the concatenated little-endian bytes.  The weight
    makes the word order-sensitive (a swap of two bytes changes it), and
    any single-byte corruption changes it by construction."""
    word = np.uint64(0)
    offset = 1
    for a in arrays:
        b = np.frombuffer(np.ascontiguousarray(a).tobytes(), np.uint8)
        if b.size == 0:
            continue
        w = np.arange(offset, offset + b.size, dtype=np.uint64)
        word = word + np.uint64((b.astype(np.uint64) * w).sum())
        offset += b.size
    return int(word % np.uint64(2 ** 32))


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Seeded sampler of retention faults for dynamic-plane units.

    `rate` is the per-unit, per-decode-step fault probability at the
    calibration temperature (85C) for a unit at the END of its retention
    window; younger units scale down linearly, colder arrays scale down
    through the leakage model.  `array_loss_rate` is the per-step
    probability of a whole-array failure event (handled by the engine's
    Supervisor as drain-and-requeue, not per-unit corruption)."""
    rate: float = 0.0
    seed: int = 0
    cell: str = "8T"
    temp_c: float = 85.0
    array_loss_rate: float = 0.0
    pin_threshold: int = 3

    # -- deterministic uniform draws -----------------------------------------

    def _u(self, tag: str, unit, step: int) -> float:
        """Uniform in [0, 1) from a stable hash of (seed, tag, unit, step)."""
        h = zlib.crc32(f"{self.seed}|{tag}|{unit}|{step}".encode())
        return h / 2 ** 32

    # -- physics-scaled probabilities ----------------------------------------

    def temp_scale(self) -> float:
        """Fault-rate multiplier vs the 85C calibration point: retention
        shrinks as temperature rises, so the tail probability grows in
        proportion (Tables I-II: the 8T window is 10x shorter at 85C
        than at 25C)."""
        m = LeakageModel(cell=self.cell)
        return m.retention_us(85.0) / m.retention_us(self.temp_c)

    def p_fault(self, age: int, retention_steps: int) -> float:
        """Early-expiry probability for a unit `age` steps after its last
        write under a `retention_steps` window.  age == 0 (just written,
        full level) never faults; age > retention_steps (only reachable
        after a missed refresh) always does."""
        if age <= 0:
            return 0.0
        retention_steps = max(retention_steps, 1)
        if age > retention_steps:
            return 1.0
        return min(1.0, self.rate * self.temp_scale()
                   * (age / retention_steps))

    # -- event samplers ------------------------------------------------------

    def fault(self, unit, step: int, age: int, retention_steps: int) -> bool:
        """Does dynamic unit `unit` suffer an early retention expiry at
        this step?"""
        p = self.p_fault(age, retention_steps)
        return p > 0.0 and self._u("fault", unit, step) < p

    def refresh_miss(self, unit, step: int) -> bool:
        """Does this unit's due refresh cycle miss its slot (bank
        contention)?  The unit keeps aging; past the window the NEXT
        fault draw is certain — a miss is never silent for long."""
        p = min(1.0, self.rate * self.temp_scale())
        return p > 0.0 and self._u("miss", unit, step) < p

    def array_loss(self, step: int) -> bool:
        """Whole-array failure event at this step."""
        return (self.array_loss_rate > 0.0
                and self._u("array", "loss", step) < self.array_loss_rate)

    def corruption_mask(self, unit, step: int) -> int:
        """Nonzero byte the injector XORs over the unit's packed payload
        — deterministic per (seed, unit, step), so the same chaos run
        corrupts the same bits."""
        h = zlib.crc32(f"{self.seed}|mask|{unit}|{step}".encode())
        return 1 + h % 255
