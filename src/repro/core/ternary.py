"""Ternary storage — the 7T augmented cell, TPU-native.

The paper's 7T cell stores one trit {-1, 0, +1} per cell in Augmented mode
(vs. two 6T cells per trit conventionally). Here a trit costs 1.6 bits
(base-3 packing, 5 trits/byte) or 2 bits (shift packing, 4 trits/byte)
instead of 16 bits (bf16 Normal mode): a 10x / 8x capacity augmentation.

Ternarization follows TWN (Li & Liu 2016), which the paper's TNN references
build on: w_t = scale * sign(w) * 1{|w| > Delta}, Delta = 0.7 * E|w|,
per-output-channel scale. `ternarize_ste` provides the straight-through
estimator used for error-aware training (paper SS.IV: error-aware training
relaxes retention requirements).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_POW3 = (1, 3, 9, 27, 81)  # 3^0..3^4 ; 5 trits/byte since 3^5 = 243 <= 255
TRITS_PER_BYTE_B3 = 5
TRITS_PER_BYTE_2B = 4


# ---------------------------------------------------------------------------
# Ternarization (TWN)
# ---------------------------------------------------------------------------

def ternarize(w: jax.Array, axis=0):
    """TWN ternarization. Returns (t in {-1,0,1} int8, scale per channel).

    `axis` is the reduction axis (input dim for a (in, out) weight); the
    scale is per remaining (output) channel.
    """
    delta = 0.7 * jnp.mean(jnp.abs(w), axis=axis, keepdims=True)
    mask = (jnp.abs(w) > delta)
    t = jnp.sign(w) * mask
    # optimal scale: mean |w| over the kept entries
    denom = jnp.maximum(jnp.sum(mask, axis=axis, keepdims=True), 1)
    scale = jnp.sum(jnp.abs(w) * mask, axis=axis, keepdims=True) / denom
    return t.astype(jnp.int8), scale.astype(jnp.float32)


def ternary_dequant(t: jax.Array, scale: jax.Array,
                    dtype=jnp.bfloat16) -> jax.Array:
    return (t.astype(jnp.float32) * scale).astype(dtype)


@jax.custom_vjp
def ternarize_ste(w: jax.Array) -> jax.Array:
    """Forward: dequantized ternary weights. Backward: identity (STE)."""
    t, scale = ternarize(w)
    return ternary_dequant(t, scale, dtype=w.dtype)


def _ste_fwd(w):
    return ternarize_ste(w), None


def _ste_bwd(_, g):
    return (g,)


ternarize_ste.defvjp(_ste_fwd, _ste_bwd)


# ---------------------------------------------------------------------------
# Base-3 packing: 5 trits per byte (1.6 bits/trit) — densest form.
# ---------------------------------------------------------------------------

def pack_ternary_base3(t: jax.Array) -> jax.Array:
    """Pack trits in {-1,0,1} along the FIRST axis, 5 per byte.

    t: (K, ...) int8 with K % 5 == 0  ->  (K//5, ...) uint8.
    First-axis packing keeps the (in, out) weight layout contiguous in the
    output dimension, which is what the matmul kernel tiles over.
    """
    k = t.shape[0]
    if k % TRITS_PER_BYTE_B3:
        raise ValueError(f"leading dim {k} not a multiple of 5")
    u = (t + 1).astype(jnp.uint8)  # {-1,0,1} -> {0,1,2}
    u = u.reshape((k // TRITS_PER_BYTE_B3, TRITS_PER_BYTE_B3) + t.shape[1:])
    out = jnp.zeros(u.shape[:1] + u.shape[2:], dtype=jnp.uint8)
    for i, p in enumerate(_POW3):
        out = out + u[:, i] * jnp.uint8(p)
    return out


def unpack_ternary_base3(packed: jax.Array, k: int) -> jax.Array:
    """Inverse of pack_ternary_base3: (K//5, ...) uint8 -> (K, ...) int8."""
    rem = packed.astype(jnp.int32)
    digs = []
    for _ in range(TRITS_PER_BYTE_B3):
        digs.append((rem % 3).astype(jnp.int8) - 1)
        rem = rem // 3
    u = jnp.stack(digs, axis=1)  # (K//5, 5, ...)
    return u.reshape((k,) + packed.shape[1:])


# ---------------------------------------------------------------------------
# 2-bit packing: 4 trits per byte — cheaper unpack (shift/mask only),
# preferred inside MXU-adjacent kernels where the base-3 divmod chain
# would serialize the VPU.
# ---------------------------------------------------------------------------

def pack_ternary_2bit(t: jax.Array) -> jax.Array:
    """Pack trits along the FIRST axis, 4 per byte, 2 bits each ({0,1,2})."""
    k = t.shape[0]
    if k % TRITS_PER_BYTE_2B:
        raise ValueError(f"leading dim {k} not a multiple of 4")
    u = (t + 1).astype(jnp.uint8)
    u = u.reshape((k // TRITS_PER_BYTE_2B, TRITS_PER_BYTE_2B) + t.shape[1:])
    out = jnp.zeros(u.shape[:1] + u.shape[2:], dtype=jnp.uint8)
    for i in range(TRITS_PER_BYTE_2B):
        out = jnp.bitwise_or(out, jnp.left_shift(u[:, i], 2 * i))
    return out


def unpack_ternary_2bit(packed: jax.Array, k: int) -> jax.Array:
    digs = []
    for i in range(TRITS_PER_BYTE_2B):
        d = jnp.bitwise_and(jnp.right_shift(packed, 2 * i), jnp.uint8(0x3))
        digs.append(d.astype(jnp.int8) - 1)
    u = jnp.stack(digs, axis=1)
    return u.reshape((k,) + packed.shape[1:])


def bits_per_value(fmt: str) -> float:
    return {"base3": 1.6, "2bit": 2.0, "bf16": 16.0, "int8": 8.0,
            "int4": 4.0}[fmt]
