"""Retention / leakage model and refresh policy.

The paper's augmented planes are DYNAMIC: charge leaks, and after the
retention time the sense circuit can no longer recover the bit (Tables I-II:
8T cell 25us @85C / 250us @25C; 7T cell 4us @85C / >50us @25C — a strong
temperature dependence).

On TPU there is no charge to leak; what "leaks" is representational
fidelity: the dynamic plane is a lossy int4 snapshot of a moving master
(activations drift, KV statistics shift, quantized optimizer moments
accumulate rounding error).  We keep BOTH views:

  * an analog-calibrated model (`paper_retention_us`, `sense_margin`) that
    reproduces the paper's tables for the benchmark harness, and
  * a step-based error budget (`RefreshPolicy`) that the framework actually
    uses: a dynamic plane is valid for `retention_steps` steps, after which
    the refresh scheduler must re-materialize it from its master.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

# Calibration points straight from the paper (85C with the paper's bias knobs)
PAPER_RETENTION_US = {
    # cell: {temp_C: retention_us}
    "8T": {85: 25.0, 25: 250.0},
    "7T": {85: 4.0, 25: 50.0},
}
V_SENSE_FRACTION = 0.5  # sense succeeds while >50% of the written level remains


@dataclasses.dataclass(frozen=True)
class LeakageModel:
    """Exponential-decay leakage, calibrated to the paper's two table points.

    retention(T) = r25 * (r85/r25) ** ((T - 25) / 60)  — log-linear in T,
    matching the paper's observation that retention is a strong function of
    temperature and improves as temperature drops (cryo-friendly).
    """
    cell: str = "8T"

    def retention_us(self, temp_c: float) -> float:
        r = PAPER_RETENTION_US[self.cell]
        r25, r85 = r[25], r[85]
        return r25 * (r85 / r25) ** ((temp_c - 25.0) / 60.0)

    def tau_us(self, temp_c: float) -> float:
        """Decay constant such that level hits V_SENSE_FRACTION at retention."""
        return self.retention_us(temp_c) / math.log(1.0 / V_SENSE_FRACTION)

    def decay(self, level: jax.Array, dt_us, temp_c) -> jax.Array:
        """Continuous decay of a stored (normalized) level after dt_us."""
        return level * jnp.exp(-jnp.asarray(dt_us) / self.tau_us(temp_c))

    def readable(self, level0: jax.Array, dt_us, temp_c) -> jax.Array:
        """Can the sense circuit still recover the datum after dt_us?"""
        return self.decay(level0, dt_us, temp_c) > V_SENSE_FRACTION * level0


@dataclasses.dataclass
class RefreshPolicy:
    """Step-based validity window for a dynamic plane.

    `retention_steps` plays the role of retention time; `refresh()` is the
    DRAM-style refresh (re-quantize from master).  Error-aware training
    (STE) corresponds to raising the application's tolerance, i.e. a larger
    `retention_steps` for the same accuracy — the paper's SS.IV co-design.
    """
    retention_steps: int = 1
    _written_at: int = dataclasses.field(default=-1, init=False)

    @classmethod
    def from_leakage(cls, cell: str, temp_c: float,
                     step_time_us: float) -> "RefreshPolicy":
        """Derive the step budget from the analog model: how many decode
        steps of `step_time_us` fit inside the cell's retention window at
        `temp_c`.  This is the bridge between the paper's Tables I-II and
        the serving scheduler's refresh cadence — colder parts (longer
        retention) buy strictly more steps between refreshes.  Always at
        least 1 step, else an augmented page could never be read back.
        """
        ret_us = LeakageModel(cell=cell).retention_us(temp_c)
        return cls(retention_steps=max(1, int(ret_us // step_time_us)))

    def stamp(self, step: int) -> None:
        self._written_at = step

    def valid(self, step: int) -> bool:
        if self._written_at < 0:
            return False
        return (step - self._written_at) < self.retention_steps

    def expires_at(self) -> int:
        return self._written_at + self.retention_steps

    def age(self, step: int) -> int:
        """Steps since the last stamp (0 if never written)."""
        if self._written_at < 0:
            return 0
        return step - self._written_at

    def needs_refresh(self, step: int) -> bool:
        return self._written_at >= 0 and not self.valid(step)


def quant_error_halflife(bits: int) -> float:
    """Half-LSB error budget for a `bits`-wide symmetric plane (normalized)."""
    qmax = 2 ** (bits - 1) - 1
    return 0.5 / qmax
