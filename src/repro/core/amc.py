"""AugmentedStore — the paper's mode-switchable augmented memory, as a
framework-level buffer abstraction.

A store owns ONE physical allocation and operates in one of three modes
(switchable at runtime, per store — the software analogue of the paper's
per-sub-array mode configuration):

  NORMAL           dense bf16, one value per 16-bit word (the 6T mode)
  AUGMENTED_DUAL   uint8 dual-plane: static int4 + dynamic int4 (8T mode)
  AUGMENTED_TERNARY packed trits, 1.6 or 2 bits/value (7T mode)

The host-side LEDGER enforces the paper's access discipline:
  * a static-plane write/read runs through the dynamic node -> it DESTROYS
    the dynamic plane; FILO ordering (static first-in, last-out) is required
    while dynamic data is live, and violations raise `FILOViolation` unless
    `force=True` (in which case the dynamic plane is really zeroed — the
    physics, not just the bookkeeping).
  * every dynamic write is stamped; `RefreshPolicy` bounds its validity
    window and `refresh()` re-materializes it from the master.

Inside jit-compiled steps the raw functional ops (core.dual_plane,
core.ternary) are used directly; AugmentedStore is the engine/trainer-level
owner that tracks modes, validity and capacity accounting.
"""
from __future__ import annotations

import enum
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import dual_plane as dp
from repro.core import ternary
from repro.core.retention import RefreshPolicy


class Mode(enum.Enum):
    NORMAL = "normal"
    AUGMENTED_DUAL = "augmented_dual"
    AUGMENTED_TERNARY = "augmented_ternary"


class FILOViolation(RuntimeError):
    """Static-plane access while dynamic data is live (paper SS.II-B)."""


class RetentionExpired(RuntimeError):
    """Dynamic plane read past its retention window without refresh."""


BITS_PER_VALUE = {
    Mode.NORMAL: 16.0,
    Mode.AUGMENTED_DUAL: 4.0,     # two int4 values per byte
    Mode.AUGMENTED_TERNARY: 1.6,  # base-3, 5 trits/byte
}

# Config-string spellings of the modes (cfg.amc.weight_mode / kv_mode).
WEIGHT_MODES = {"normal": Mode.NORMAL, "dual": Mode.AUGMENTED_DUAL,
                "ternary": Mode.AUGMENTED_TERNARY}
KV_BITS_PER_VALUE = {"normal": 16.0, "int8": 8.0, "int4": 4.0}


def mode_bits_per_value(mode: Mode, ternary_fmt: str = "base3") -> float:
    """Physical bits per logical value for a storage mode (the paper's
    capacity headline; shared by AugmentedStore and the serving stats)."""
    if mode == Mode.AUGMENTED_TERNARY and ternary_fmt == "2bit":
        return 2.0
    return BITS_PER_VALUE[mode]


def mode_physical_bytes(n_values: int, mode: Mode,
                        ternary_fmt: str = "base3") -> int:
    if mode == Mode.NORMAL:
        return 2 * n_values
    if mode == Mode.AUGMENTED_DUAL:
        return n_values  # one byte holds static+dynamic for one index
    per = 5 if ternary_fmt == "base3" else 4
    return (n_values + per - 1) // per


def capacity_factor(mode: Mode, ternary_fmt: str = "base3") -> float:
    """Storage augmentation vs NORMAL mode (values per physical bit)."""
    return (BITS_PER_VALUE[Mode.NORMAL]
            / mode_bits_per_value(mode, ternary_fmt))


# Array access events per logical VALUE, by mode (the paper's Tables
# III/IV access structure; per-event energies live in `repro.imc.energy`).
# NORMAL reads 16 6T cells per bf16 value; AUGMENTED_DUAL touches 4 8T
# cells per int4 value (static plane sensed through the dynamic node,
# dynamic plane with the boosted WL); AUGMENTED_TERNARY reads one 7T cell
# per trit.
MODE_ACCESS_EVENTS = {
    (Mode.NORMAL, "read"): ("read_6t", 16),
    (Mode.NORMAL, "write"): ("write_6t", 16),
    (Mode.AUGMENTED_DUAL, "read"): ("read_8t_static", 4),
    (Mode.AUGMENTED_DUAL, "read_dynamic"): ("read_8t_dynamic", 4),
    (Mode.AUGMENTED_DUAL, "write"): ("write_8t_dual", 4),
    (Mode.AUGMENTED_DUAL, "write_dynamic"): ("write_8t_dynamic", 4),
    (Mode.AUGMENTED_TERNARY, "read"): ("read_7t", 1),
    (Mode.AUGMENTED_TERNARY, "write"): ("write_7t", 1),
}


def mode_access_events(mode: Mode, n_values: int, kind: str) -> dict:
    """{event_class: count} of one `kind` access to `n_values` values
    stored in `mode` — the bridge between this module's capacity ledger
    and the array-level energy model (`repro.imc.energy`)."""
    cls, cells = MODE_ACCESS_EVENTS[(mode, kind)]
    return {cls: cells * n_values}


def dynamic_plane_access_events(n_values: int, bits: int,
                                kind: str = "read") -> dict:
    """{event_class: count} for `bits`-wide packed DYNAMIC-plane data —
    one boosted-WL 8T cell per stored bit. This is the shared costing of
    every dynamic storage class in the serving stack: augmented KV pages
    (int4/int8 per `aug_bits`) and augmented recurrent-state slabs
    (`amc.state_bits`, serve/state_store.py) bill through the same
    event classes."""
    cls = "read_8t_dynamic" if kind == "read" else "write_8t_dynamic"
    return {cls: bits * n_values} if n_values else {}


class AugmentedStore:
    def __init__(self, shape, *, retention_steps: int = 4,
                 ternary_fmt: str = "base3"):
        self.shape = tuple(shape)
        self.mode = Mode.NORMAL
        self.ternary_fmt = ternary_fmt
        self._dense: Optional[jax.Array] = jnp.zeros(self.shape, jnp.bfloat16)
        self._dual: Optional[dp.DualPlane] = None
        self._tern_packed = None
        self._tern_scale = None
        self._dynamic_live = False
        self._static_written = False
        self._step = 0
        self.policy = RefreshPolicy(retention_steps=retention_steps)
        self.stats = {"refreshes": 0, "filo_faults": 0, "mode_switches": 0}
        # array access events by class (paper Tables III/IV; energies in
        # repro.imc.energy — see `energy_fj()`)
        self.events: dict = {}

    def _note_access(self, kind: str) -> None:
        import numpy as np
        n = int(np.prod(self.shape))
        for cls, c in mode_access_events(self.mode, n, kind).items():
            self.events[cls] = self.events.get(cls, 0) + c

    def energy_fj(self) -> float:
        """Modeled energy of every access so far (lazy import keeps
        core free of the imc package at module load)."""
        from repro.imc.energy import energy_fj
        return energy_fj(self.events)

    # -- mode switching (the WL/SL reconfiguration of the paper) ------------

    def set_mode(self, mode: Mode) -> None:
        if mode == self.mode:
            return
        if self._dynamic_live:
            raise FILOViolation(
                "mode switch while dynamic plane is live; drain first")
        self.stats["mode_switches"] += 1
        if mode == Mode.NORMAL:
            self._dense = self.read_static()
            self._dual = None
            self._tern_packed = None
        elif mode == Mode.AUGMENTED_DUAL:
            master = self._materialize_master()
            self._dual = dp.write_static(dp.alloc(self.shape), master)
            self._dense = None
            self._tern_packed = None
        elif mode == Mode.AUGMENTED_TERNARY:
            master = self._materialize_master()
            t, scale = ternary.ternarize(master)
            if self.ternary_fmt == "base3":
                self._tern_packed = ternary.pack_ternary_base3(t)
            else:
                self._tern_packed = ternary.pack_ternary_2bit(t)
            self._tern_scale = scale
            self._dense = None
            self._dual = None
        self.mode = mode
        self._static_written = True

    def _materialize_master(self) -> jax.Array:
        if self._dense is not None:
            return self._dense
        return self.read_static()

    # -- static plane --------------------------------------------------------

    def write_static(self, x: jax.Array, *, force: bool = False) -> None:
        self._guard_filo(force)
        if self.mode == Mode.NORMAL:
            self._dense = x.astype(jnp.bfloat16)
        elif self.mode == Mode.AUGMENTED_DUAL:
            base = self._dual if self._dual is not None else dp.alloc(self.shape)
            self._dual = dp.write_static(base, x)  # zeroes the dynamic nibble
        else:
            t, scale = ternary.ternarize(x)
            if self.ternary_fmt == "base3":
                self._tern_packed = ternary.pack_ternary_base3(t)
            else:
                self._tern_packed = ternary.pack_ternary_2bit(t)
            self._tern_scale = scale
        self._note_access("write")
        self._static_written = True
        self._dynamic_live = False

    def read_static(self, *, force: bool = False) -> jax.Array:
        if self.mode == Mode.AUGMENTED_DUAL:
            # the SRAM read path runs through the dynamic node (paper fig. 1)
            self._guard_filo(force)
        self._note_access("read")
        if self.mode == Mode.NORMAL:
            return self._dense
        if self.mode == Mode.AUGMENTED_DUAL:
            return dp.read_static(self._dual)
        k = self.shape[0]
        if self.ternary_fmt == "base3":
            t = ternary.unpack_ternary_base3(self._tern_packed, k)
        else:
            t = ternary.unpack_ternary_2bit(self._tern_packed, k)
        return ternary.ternary_dequant(t, self._tern_scale)

    def _guard_filo(self, force: bool) -> None:
        if self._dynamic_live:
            if not force:
                self.stats["filo_faults"] += 1
                raise FILOViolation(
                    "static access while dynamic plane live (FILO: drain the "
                    "dynamic plane first, or pass force=True to clobber it)")
            # the physics: the access destroys the dynamic bit
            if self._dual is not None:
                hi = jnp.bitwise_and(self._dual.buf, jnp.uint8(0xF0))
                self._dual = dp.DualPlane(hi, self._dual.static_scale,
                                          self._dual.dynamic_scale)
            self._dynamic_live = False

    # -- dynamic plane (AUGMENTED_DUAL only) ---------------------------------

    def push_dynamic(self, x: jax.Array, *, stochastic=False, key=None) -> None:
        if self.mode != Mode.AUGMENTED_DUAL:
            raise RuntimeError("dynamic plane exists only in AUGMENTED_DUAL")
        self._dual = dp.write_dynamic(self._dual, x, stochastic=stochastic,
                                      key=key)
        self._note_access("write_dynamic")
        self._dynamic_live = True
        self.policy.stamp(self._step)

    def pop_dynamic(self) -> jax.Array:
        """Read and drain the dynamic plane (the last-out of FILO)."""
        if not self._dynamic_live:
            raise RuntimeError("no live dynamic data")
        if self.policy.needs_refresh(self._step):
            raise RetentionExpired(
                f"dynamic plane expired at step {self.policy.expires_at()}, "
                f"now {self._step}; refresh() from master first")
        self._note_access("read_dynamic")
        out = dp.read_dynamic(self._dual)
        self._dynamic_live = False
        return out

    def peek_dynamic(self) -> jax.Array:
        if self.policy.needs_refresh(self._step):
            raise RetentionExpired("dynamic plane expired")
        self._note_access("read_dynamic")
        return dp.read_dynamic(self._dual)

    def refresh(self, master: jax.Array) -> None:
        """DRAM-style refresh: re-write the dynamic plane from its master."""
        if self.mode != Mode.AUGMENTED_DUAL or not self._dynamic_live:
            return
        self._dual = dp.write_dynamic(self._dual, master)
        self._note_access("write_dynamic")
        self.policy.stamp(self._step)
        self.stats["refreshes"] += 1

    # -- clock / accounting ---------------------------------------------------

    def tick(self, n: int = 1) -> None:
        self._step += n

    @property
    def dynamic_live(self) -> bool:
        return self._dynamic_live

    def bits_per_value(self) -> float:
        return mode_bits_per_value(self.mode, self.ternary_fmt)

    def capacity_factor(self) -> float:
        """Storage augmentation vs NORMAL mode (values per physical bit)."""
        return capacity_factor(self.mode, self.ternary_fmt)

    def physical_bytes(self) -> int:
        import numpy as np
        n = int(np.prod(self.shape))
        return mode_physical_bytes(n, self.mode, self.ternary_fmt)
