"""Dual-plane storage — the 8T dual-bit augmented cell, TPU-native.

One physical uint8 buffer stores two logical int4 tensors:
  * the STATIC plane (high nibble) — written rarely, long-lived.  In the
    paper this is the SRAM bit (nodes Vx/Vy); here it holds e.g. int4
    weights.
  * the DYNAMIC plane (low nibble) — streamed, short-lived, lossy.  In the
    paper this is the DRAM bit on node Vz; here it holds e.g. streamed
    activations or KV entries.

The paper's central hazard is preserved: the SRAM access path runs through
the dynamic node, so a static-plane (re)write CLOBBERS the dynamic plane.
`write_static` therefore zeroes the low nibble, and the `AugmentedStore`
ledger (core/amc.py) enforces the FILO discipline around it.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import quant


class DualPlane(NamedTuple):
    """The physical buffer plus per-plane scales ("reference voltages")."""
    buf: jax.Array           # uint8, shape S
    static_scale: jax.Array  # broadcastable to S
    dynamic_scale: jax.Array # broadcastable to S

    @property
    def shape(self):
        return self.buf.shape


def alloc(shape, static_scale=None, dynamic_scale=None) -> DualPlane:
    one = jnp.ones((), jnp.float32)
    return DualPlane(
        buf=jnp.zeros(shape, jnp.uint8),
        static_scale=one if static_scale is None else static_scale,
        dynamic_scale=one if dynamic_scale is None else dynamic_scale,
    )


def write_static(dp: DualPlane, x: jax.Array, axis=0) -> DualPlane:
    """Quantize `x` to int4 and write the static plane.

    DESTROYS the dynamic plane (low nibble zeroed) — the SRAM write drives
    BL/BLB through the dynamic node, exactly as in the paper.  Callers must
    go through AugmentedStore, which enforces the FILO ledger.
    """
    q, scale = quant.quantize_int4(x, axis=axis)
    buf = quant.pack_int4_pair(q, jnp.zeros_like(q))
    return DualPlane(buf=buf, static_scale=scale,
                     dynamic_scale=dp.dynamic_scale)


def write_dynamic(dp: DualPlane, x: jax.Array, axis=-1,
                  stochastic: bool = False, key=None) -> DualPlane:
    """Quantize `x` to int4 and write the dynamic plane, preserving static."""
    q, scale = quant.quantize_int4(x, axis=axis, stochastic=stochastic,
                                   key=key)
    hi = jnp.bitwise_and(dp.buf, jnp.uint8(0xF0))
    lo = jnp.bitwise_and(q.astype(jnp.uint8), jnp.uint8(0x0F))
    return DualPlane(buf=jnp.bitwise_or(hi, lo),
                     static_scale=dp.static_scale, dynamic_scale=scale)


def read_static(dp: DualPlane, dtype=jnp.bfloat16) -> jax.Array:
    return quant.dequantize(quant.unpack_int4_hi(dp.buf), dp.static_scale,
                            dtype)


def read_dynamic(dp: DualPlane, dtype=jnp.bfloat16) -> jax.Array:
    return quant.dequantize(quant.unpack_int4_lo(dp.buf), dp.dynamic_scale,
                            dtype)


def read_static_q(dp: DualPlane) -> jax.Array:
    """Raw int4 (as int8) static plane — for kernels that compute packed."""
    return quant.unpack_int4_hi(dp.buf)


def read_dynamic_q(dp: DualPlane) -> jax.Array:
    return quant.unpack_int4_lo(dp.buf)
