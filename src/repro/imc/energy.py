"""Array-level event/energy accounting for augmented storage + IMC.

Every storage access and every in-array dot product is decomposed into
EVENT CLASSES with a nominal per-event energy (fJ, 22nm-FDX-class figures
of merit). The absolute numbers are placeholders for the paper's measured
Tables III/IV values; what the model preserves — and what the tests pin —
is the paper's *relative structure*:

  * Normal-mode (6T) reads/writes are the cheapest per cell;
  * Augmented-mode accesses cost MORE per cell (the 8T dual read senses
    both the static and the dynamic bit through the extra access
    transistor; 7T ternary sensing needs the inverter reference), but
    each cell carries >1 logical bit — so per *value* the augmented modes
    win (Tables III/IV's headline);
  * IMC dot products replace per-value fetches with wordline pulses,
    bitline discharges and ADC conversions whose count scales with the
    bit-serial cycle count `mag_bits(abits)` (arXiv:2008.03378) — lower
    activation precision is linearly cheaper.

Counting conventions (per VALUE, by storage format):

  dense bf16      16 cells (6T, one bit each)
  ternary 2-bit   1 cell   (7T, one trit each)
  dual int4 pair  4 cells  (8T, static bit + dynamic bit each; a dual
                            read returns BOTH planes -> `read_8t_dual`)
  packed KV int4  4 cells  (8T dynamic bits)      int8: 8 cells

`ImcEventLedger` is the host-side accumulator `ServeEngine` folds into
`stats()["imc"]`; the analytic per-dispatch counts live here so the jitted
hot path stays pure (events are a deterministic function of shapes, modes
and the page tables — nothing is traced)."""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Optional

from repro.kernels.imc_dot import mag_bits

# Nominal per-event energies (fJ). Relative structure per Tables III/IV:
# augmented accesses > normal per CELL, < normal per VALUE.
EVENT_ENERGY_FJ = {
    # von-Neumann storage-array events (Table III reads / Table IV writes)
    "read_6t": 2.0,
    "write_6t": 2.2,
    "read_8t_static": 2.6,    # static bit sensed through the dynamic node
    "read_8t_dynamic": 3.4,   # boosted-WL dynamic bit read
    "read_8t_dual": 4.2,      # one access, both planes (the dual read)
    "write_8t_dual": 4.8,     # static + dynamic write pair
    "write_8t_dynamic": 2.9,  # dynamic-plane-only write (KV stream)
    "read_7t": 2.9,
    "write_7t": 3.5,
    # IMC array events (arXiv:1802.08601 / 2008.03378)
    "wordline": 1.2,          # one WL pulse (one activation bit, one row)
    "bitline": 0.45,          # one BL partial discharge (one column)
    "adc": 6.0,               # one sense/ADC conversion (one column)
    # maintenance
    "refresh_cell": 1.8,      # DRAM-style restore of one augmented cell
}

# Cells read per logical VALUE for a von-Neumann weight fetch, by storage.
_WEIGHT_FETCH = {
    "dense": ("read_6t", 16),
    "ternary": ("read_7t", 1),
    "dual": ("read_8t_dual", 4),   # one event per cell returns BOTH planes
    "int8": ("read_8t_dynamic", 8),
    "int4": ("read_8t_dynamic", 4),
}


def energy_fj(events: dict) -> float:
    return float(sum(EVENT_ENERGY_FJ[cls] * n for cls, n in events.items()))


def imc_dot_events(M: int, K: int, N: int, *, abits: int,
                   planes: int = 1) -> dict:
    """Events of one (M, K) x (K, N) bit-serial in-array dot product.

    Per bit-serial cycle: every K wordline pulses once per output row,
    every N bitline discharges and converts once per resident plane.
    `planes=2` is the dual-plane engine — ONE wordline stream, TWO
    bitline/ADC banks (the dual cell's throughput win)."""
    c = mag_bits(abits)
    return {"wordline": M * K * c,
            "bitline": M * N * c * planes,
            "adc": M * N * c * planes}


def weight_fetch_events(n_values: int, storage: str) -> dict:
    """Von-Neumann events for fetching `n_values` weights to the MXU."""
    cls, per = _WEIGHT_FETCH[storage]
    return {cls: n_values * per}


def matmul_events(M: int, K: int, N: int, *, storage: str, impl: str,
                  abits: int = 8) -> dict:
    """Events of one (M, K) x (K, N) matmul under a storage x impl cell.

    impl="imc" computes in-array when the storage is resident-packed
    (ternary/dual/int4/int8); dense storage has no array to compute in,
    so it falls back to the fetch model whatever the impl."""
    if M == 0:
        return {}
    if impl == "imc" and storage != "dense":
        return imc_dot_events(M, K, N, abits=abits,
                              planes=2 if storage == "dual" else 1)
    # von-Neumann: the weight matrix is fetched ONCE per batched dispatch
    # (not per token); dual fetches count value PAIRS (4 cells = 2 values)
    n = K * N
    if storage == "dual":
        n = n // 2
    return weight_fetch_events(n, storage)


def kv_read_events(n_values_normal: int, n_values_aug: int, *,
                   aug_bits: int) -> dict:
    """Decode-state reads (KV pages AND recurrent-state slabs): Normal
    storage is 6T static data (16 cells/value), Augmented storage is
    dynamic-plane data (`aug_bits` 8T cells/value) — the per-page /
    per-slab mode decides the event class (core.amc owns the mapping)."""
    from repro.core.amc import dynamic_plane_access_events
    ev: dict = {}
    if n_values_normal:
        ev["read_6t"] = 16 * n_values_normal
    ev.update(dynamic_plane_access_events(n_values_aug, aug_bits, "read"))
    return ev


def kv_write_events(n_values_normal: int, n_values_aug: int, *,
                    aug_bits: int) -> dict:
    from repro.core.amc import dynamic_plane_access_events
    ev: dict = {}
    if n_values_normal:
        ev["write_6t"] = 16 * n_values_normal
    ev.update(dynamic_plane_access_events(n_values_aug, aug_bits, "write"))
    return ev


def refresh_events(n_bytes: int) -> dict:
    """Refresh traffic (pool `refresh_bytes`) -> cell restore events:
    augmented bytes hold 2 bits/cell -> 4 cells per byte."""
    return {"refresh_cell": 4 * n_bytes}


# ---------------------------------------------------------------------------
# Per-model analytic step counts (what ServeEngine folds into stats())
# ---------------------------------------------------------------------------

def _layer_matmuls(cfg) -> list:
    """(K, N, storage) of every per-token matmul in one transformer
    decoder layer, given cfg.amc.weight_mode (mirrors `augment_params`'
    packing map)."""
    d, H, KV, hd, f = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                       cfg.d_ff)
    wm = cfg.amc.weight_mode
    tern = "ternary" if wm == "ternary" else "dense"
    mm = [(d, H * hd, tern)]                               # wq
    if wm == "dual":
        mm += [(d, KV * hd, "dual")]                       # wk+wv, one pass
    else:
        mm += [(d, KV * hd, tern), (d, KV * hd, tern)]
    mm += [(H * hd, d, tern)]                              # wo
    if cfg.moe is not None:
        mm += [(d, cfg.moe.n_experts, "dense")]            # router
        n_ffn = 3 if cfg.act == "swiglu" else 2
        # top-k active experts; banks are ternary-packed in ternary mode
        for _ in range(cfg.moe.top_k):
            mm += [(d, f, tern)] * (n_ffn - 1) + [(f, d, tern)]
    else:
        if wm == "dual" and cfg.act == "swiglu":
            mm += [(d, f, "dual"), (f, d, "dense")]        # gate+up fused
        else:
            n_ffn = 3 if cfg.act == "swiglu" else 2
            mm += [(d, f, tern)] * (n_ffn - 1) + [(f, d, tern)]
    return mm


def _mlp_matmuls(cfg) -> list:
    n_ffn = 3 if cfg.act == "swiglu" else 2
    return ([(cfg.d_model, cfg.d_ff, "dense")] * (n_ffn - 1)
            + [(cfg.d_ff, cfg.d_model, "dense")])


def _attn_matmuls(cfg) -> list:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return [(d, H * hd, "dense"), (d, KV * hd, "dense"),
            (d, KV * hd, "dense"), (H * hd, d, "dense")]


def model_decode_matmuls(cfg) -> list:
    """(K, N, storage, count) of every per-token weight matmul in one
    decode step, for ANY family — the unified serving engine accounts
    weight-side array events for ssm/hybrid/encdec/vlm rows too.
    Families `augment_params` doesn't pack keep "dense" (6T) storage."""
    fam = cfg.family
    if fam in ("dense", "moe"):
        return [(K, N, s, cfg.n_layers) for K, N, s in _layer_matmuls(cfg)]
    d = cfg.d_model
    if fam == "ssm":
        s = cfg.ssm
        din = s.expand * d
        H = din // s.head_dim
        GN = s.n_groups * s.state_dim
        per = [(d, din, "dense"), (d, din, "dense"),        # z, x
               (d, GN, "dense"), (d, GN, "dense"),          # b, c
               (d, H, "dense"), (din, d, "dense")]          # dt, out
        return [(K, N, st, cfg.n_layers) for K, N, st in per]
    if fam == "hybrid":
        h = cfg.hybrid
        n_att = cfg.n_layers // len(h.pattern)
        n_rec = cfg.n_layers - n_att
        rec = [(d, h.lru_width, "dense"), (d, h.lru_width, "dense"),
               (h.lru_width, d, "dense")] + _mlp_matmuls(cfg)
        att = _attn_matmuls(cfg) + _mlp_matmuls(cfg)
        return ([(K, N, st, n_rec) for K, N, st in rec]
                + [(K, N, st, n_att) for K, N, st in att])
    if fam == "audio":
        # decode-side: self attn + cross q/o (cross K/V precomputed at
        # prefill — the static plane) + mlp, per decoder layer
        H, hd = cfg.n_heads, cfg.hd
        per = (_attn_matmuls(cfg)
               + [(d, H * hd, "dense"), (H * hd, d, "dense")]
               + _mlp_matmuls(cfg))
        return [(K, N, st, cfg.n_layers) for K, N, st in per]
    if fam == "vlm":
        from repro.models.vision import N_SELF_PER_BLOCK, _n_blocks
        nb = _n_blocks(cfg)
        H, hd = cfg.n_heads, cfg.hd
        self_l = _attn_matmuls(cfg) + _mlp_matmuls(cfg)
        cross = ([(d, H * hd, "dense"), (H * hd, d, "dense")]
                 + _mlp_matmuls(cfg))
        return ([(K, N, st, nb * N_SELF_PER_BLOCK) for K, N, st in self_l]
                + [(K, N, st, nb) for K, N, st in cross])
    raise ValueError(f"no decode matmul model for family {fam!r}")


def decode_matmul_events(cfg, n_tokens: int) -> dict:
    """Weight-side events of one decode dispatch over `n_tokens` useful
    tokens (padding rows are not counted — this is the per-token model)."""
    a = cfg.amc
    ev: Counter = Counter()
    for K, N, storage, count in model_decode_matmuls(cfg):
        for cls, n in matmul_events(n_tokens, K, N, storage=storage,
                                    impl=a.matmul_impl,
                                    abits=a.imc_abits).items():
            ev[cls] += n * count
    return dict(ev)


# ---------------------------------------------------------------------------
# Ledger
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ImcEventLedger:
    """Host-side event accumulator, grouped by traffic source ("weights",
    "kv_read", "kv_write", "refresh"). Energies use EVENT_ENERGY_FJ."""
    counts: dict = dataclasses.field(default_factory=Counter)
    tokens: int = 0

    def add(self, events: dict, group: str) -> None:
        for cls, n in events.items():
            if n:
                self.counts[(group, cls)] += int(n)

    def note_tokens(self, n: int) -> None:
        self.tokens += int(n)

    def energy_fj(self, group: Optional[str] = None) -> float:
        return float(sum(EVENT_ENERGY_FJ[cls] * n
                         for (g, cls), n in self.counts.items()
                         if group is None or g == group))

    def describe(self) -> dict:
        groups: dict = {}
        for (g, cls), n in sorted(self.counts.items()):
            gd = groups.setdefault(g, {"events": {}, "energy_fj": 0.0})
            gd["events"][cls] = n
            gd["energy_fj"] += EVENT_ENERGY_FJ[cls] * n
        total = self.energy_fj()
        return {
            "event_energy_fj": dict(EVENT_ENERGY_FJ),
            "groups": groups,
            "energy_fj_total": total,
            "tokens": self.tokens,
            "energy_pj_per_token": (total / self.tokens / 1e3
                                    if self.tokens else 0.0),
        }
