"""In-memory compute (IMC) subsystem: bit-serial dot-product engine over
packed augmented storage + array-level event/energy accounting.

  engine.BitSerialArray   resident packed weights, wordline-serial dot()
  energy.ImcEventLedger   host-side event/energy accumulator
  energy.*_events         analytic per-dispatch event counts

The Pallas kernels themselves live in `repro.kernels.imc_dot`; the model
routing knob is `cfg.amc.matmul_impl` ("dense" | "packed" | "imc").
"""
from repro.imc.energy import (EVENT_ENERGY_FJ, ImcEventLedger,
                              decode_matmul_events, imc_dot_events,
                              kv_read_events, kv_write_events,
                              matmul_events, refresh_events,
                              weight_fetch_events)
from repro.imc.engine import BitSerialArray

__all__ = [
    "EVENT_ENERGY_FJ", "ImcEventLedger", "BitSerialArray",
    "decode_matmul_events", "imc_dot_events", "kv_read_events",
    "kv_write_events", "matmul_events", "refresh_events",
    "weight_fetch_events",
]
