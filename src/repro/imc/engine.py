"""Bit-serial IMC engine: array objects that own packed augmented weights
and evaluate dot products in place, logging array events per call.

`BitSerialArray` is the eager, host-driven view of one IMC sub-array —
what the benches and direct callers use. It pairs the `imc_dot` kernels
with the `energy.ImcEventLedger` so every `dot()` logs its wordline /
bitline / ADC events. Inside jit-compiled model steps the pure kernel ops
(`kernels.ops.imc_dot` / `imc_dual_dot`) are used directly and the
*engine-level* accounting is analytic (`energy.decode_matmul_events`,
called per real dispatch by `ServeEngine`) — a Python counter cannot be
bumped from inside a traced function.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import quant, ternary
from repro.imc import energy
from repro.kernels import ops as kops
from repro.kernels.imc_dot import _k_pack


class BitSerialArray:
    """One IMC sub-array: packed weights resident, activations streamed
    bit-serially at `abits` precision (reconfigurable per call)."""

    def __init__(self, wp: jax.Array, scale, *, fmt: str,
                 lo_scale=None, abits: int = 8,
                 ledger: Optional[energy.ImcEventLedger] = None):
        if fmt not in ("ternary", "dual", "int8", "int4"):
            raise ValueError(f"unknown IMC weight format {fmt!r}")
        self.fmt, self.abits = fmt, abits
        self.wp, self.scale, self.lo_scale = wp, scale, lo_scale
        self.ledger = ledger if ledger is not None else energy.ImcEventLedger()
        self.K = wp.shape[0] * _k_pack(fmt)
        self.N = wp.shape[1]

    # -- constructors (the write drivers) -----------------------------------

    @classmethod
    def from_dense(cls, w: jax.Array, *, fmt: str = "ternary",
                   abits: int = 8, ledger=None) -> "BitSerialArray":
        """Pack a dense (K, N) weight into the array's resident format."""
        w = w.astype(jnp.float32)
        if fmt == "ternary":
            t, scale = ternary.ternarize(w, axis=0)
            return cls(ternary.pack_ternary_2bit(t), scale, fmt=fmt,
                       abits=abits, ledger=ledger)
        if fmt == "int8":
            q, scale = quant.quantize_int8(w, axis=0)
            return cls(q, scale, fmt=fmt, abits=abits, ledger=ledger)
        if fmt == "int4":
            q, scale = quant.quantize_int4(w, axis=0)
            return cls(quant.pack_int4_pair(q[0::2], q[1::2]), scale,
                       fmt=fmt, abits=abits, ledger=ledger)
        raise ValueError("use from_dense_pair for the dual format")

    @classmethod
    def from_dense_pair(cls, w_hi: jax.Array, w_lo: jax.Array, *,
                        abits: int = 8, ledger=None) -> "BitSerialArray":
        """Two dense (K, N) weights into ONE dual-plane uint8 array."""
        qh, sh = quant.quantize_int4(w_hi.astype(jnp.float32), axis=0)
        ql, sl = quant.quantize_int4(w_lo.astype(jnp.float32), axis=0)
        return cls(quant.pack_int4_pair(qh, ql), sh, fmt="dual",
                   lo_scale=sl, abits=abits, ledger=ledger)

    # -- compute ------------------------------------------------------------

    def dot(self, x: jax.Array, *, abits: Optional[int] = None):
        """x (M, K) -> (M, N) (dual: ((M, N), (M, N))). Logs the call's
        wordline/bitline/ADC events to the ledger."""
        a = self.abits if abits is None else abits
        M = x.shape[0]
        self.ledger.add(
            energy.imc_dot_events(M, self.K, self.N, abits=a,
                                  planes=2 if self.fmt == "dual" else 1),
            group="imc_dot")
        if self.fmt == "dual":
            return kops.imc_dual_dot(x, self.wp, self.scale, self.lo_scale,
                                     abits=a)
        return kops.imc_dot(x, self.wp, self.scale, fmt=self.fmt, abits=a)

    def physical_bytes(self) -> int:
        scales = [s for s in (self.scale, self.lo_scale) if s is not None]
        return int(self.wp.nbytes) + sum(int(s.nbytes) for s in scales)
