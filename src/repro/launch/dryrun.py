"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct inputs (no allocation), then extract the roofline terms
from the compiled artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b \
      --shape train_4k [--multi-pod] [--out results/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
# The VERY FIRST lines, before any other import (jax locks device count on
# first init):
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCHS, SHAPES, cell_applicable, get_arch,
                           get_shape, input_specs)
from repro.distributed.sharding import Rules
from repro.launch import mesh as mesh_lib
from repro.models import model as M
from repro.models.params import to_shape_dtype
from repro.optim import adamw
from repro.train import step as step_lib

COLLECTIVE_RE = re.compile(
    r"=\s*(\S+?)\[([0-9,]*)\][^=]*?\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)",
)

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
               "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "s64": 8,
               "u64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-device result bytes of every collective op in the HLO."""
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    counts = dict.fromkeys(out, 0)
    for m in COLLECTIVE_RE.finditer(hlo_text):
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        out[kind] += n * DTYPE_BYTES[dt]
        counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def build_cell(arch: str, shape_name: str, mesh, settings=None):
    """Returns (jitted_fn, example_args_shapedtypes) for one cell."""
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    rules = Rules.make(mesh, cfg, shape)
    if settings is None:
        n = cfg.param_count()
        # accum=4 for the largest models: balances FSDP weight-gather
        # traffic (proportional to microbatch count) against activation
        # memory — see EXPERIMENTS.md SSPerf cell B
        accum = 4 if n > 1e11 else (2 if n > 8e9 else 1)
        settings = step_lib.TrainSettings(
            optimizer="amc_adamw" if n > 5e10 else "adamw",
            grad_accum=accum, q_chunk=1024)
    ap = M.abstract_params(cfg)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           step_lib.param_pspecs(ap, rules),
                           is_leaf=lambda x: isinstance(x, P))
    b_specs = input_specs(cfg, shape)
    b_shard = {k: NamedSharding(mesh, v)
               for k, v in step_lib.batch_pspecs(cfg, shape, rules).items()}
    p_abs = to_shape_dtype(ap)

    if shape.kind == "train":
        oa = step_lib.opt_abstract(ap, settings.optimizer)
        o_shard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                               step_lib.param_pspecs(oa, rules),
                               is_leaf=lambda x: isinstance(x, P))
        o_abs = to_shape_dtype(oa)
        train_step = step_lib.make_train_step(cfg, settings, rules)
        state_shard = step_lib.TrainState(
            p_shard, o_shard, NamedSharding(mesh, P()))
        state_abs = step_lib.TrainState(
            p_abs, o_abs, jax.ShapeDtypeStruct((), jnp.int32))
        fn = jax.jit(train_step,
                     in_shardings=(state_shard, b_shard),
                     out_shardings=(state_shard, NamedSharding(mesh, P())),
                     donate_argnums=(0,))
        return fn, (state_abs, b_specs)

    if shape.kind == "prefill":
        prefill = step_lib.make_prefill_step(cfg, settings, rules)
        c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                               step_lib.cache_pspecs(cfg, shape, rules),
                               is_leaf=lambda x: isinstance(x, P))
        logits_shard = NamedSharding(
            mesh, P(rules.resolve("batch"), None, rules.resolve("vocab")))
        fn = jax.jit(prefill,
                     in_shardings=(p_shard, b_shard),
                     out_shardings=(logits_shard, None))
        return fn, (p_abs, b_specs)

    # decode
    decode = step_lib.make_decode_step(cfg, rules)
    ca = M.abstract_cache(cfg, shape)
    c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           step_lib.param_pspecs(ca, rules),
                           is_leaf=lambda x: isinstance(x, P))
    c_abs = to_shape_dtype(ca)
    logits_shard = NamedSharding(
        mesh, P(rules.resolve("batch"), None, rules.resolve("vocab")))
    fn = jax.jit(decode,
                 in_shardings=(p_shard, c_shard, b_shard),
                 out_shardings=(logits_shard, c_shard),
                 donate_argnums=(1,))
    return fn, (p_abs, c_abs, b_specs)


def analyze(compiled, lowered, cfg, shape, mesh) -> dict:
    from repro.launch.hlo_analysis import analyze_hlo
    n_dev = mesh.devices.size
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    # trip-count-aware analysis (XLA's cost_analysis counts loop bodies once)
    h = analyze_hlo(hlo)
    coll = {"bytes": h["collective_bytes"],
            "counts": h["collective_counts"],
            "total_bytes": h["collective_total_bytes"]}
    flops_dev = float(h["flops"])
    bytes_dev = float(h["bytes_accessed"])
    bytes_fused_dev = float(h["bytes_fused"])
    compute_s = flops_dev / mesh_lib.PEAK_BF16_FLOPS
    memory_s = bytes_dev / mesh_lib.HBM_BW
    memory_fused_s = bytes_fused_dev / mesh_lib.HBM_BW
    coll_s = coll["total_bytes"] / mesh_lib.ICI_LINK_BW
    # MODEL_FLOPS: 6*N*D train / 2*N*D fwd on active non-embedding params
    model_flops_dev = cfg.model_flops(shape) / n_dev
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    mem_gib = (ma.argument_size_in_bytes + ma.output_size_in_bytes
               + ma.temp_size_in_bytes) / 2**30
    return {
        "arch": cfg.name, "shape": shape.name,
        "mesh": dict(mesh.shape), "n_devices": int(n_dev),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "bytes_fused_per_device": bytes_fused_dev,
        "collectives": coll,
        "compute_s": compute_s, "memory_s": memory_s,
        "memory_fused_s": memory_fused_s,
        "collective_s": coll_s, "dominant": dominant,
        "bytes_by_op": h.get("bytes_by_op", {}),
        "model_flops_per_device": model_flops_dev,
        "useful_flops_ratio": (model_flops_dev / flops_dev
                               if flops_dev else 0.0),
        "roofline_fraction": (compute_s / max(terms.values())
                              if max(terms.values()) > 0 else 0.0),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "total_gib_per_device": mem_gib,
            "fits_16gib": bool(mem_gib < 16.0),
        },
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             settings=None) -> dict:
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    cfg, shape = get_arch(arch), get_shape(shape_name)
    ok, reason = cell_applicable(cfg, shape)
    tag = f"{arch}_{shape_name}_{'multipod' if multi_pod else 'pod'}"
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "skipped": True,
               "reason": reason}
    else:
        t0 = time.time()
        fn, args = build_cell(arch, shape_name, mesh, settings)
        lowered = fn.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        rec = analyze(compiled, lowered, cfg, shape, mesh)
        rec.update({"skipped": False, "lower_s": t1 - t0,
                    "compile_s": t2 - t1})
        print(compiled.memory_analysis())
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        cells.append((args.arch, args.shape))

    failures = []
    for a, s in cells:
        try:
            rec = run_cell(a, s, args.multi_pod, args.out)
            status = ("SKIP " + rec.get("reason", "")[:40] if rec.get("skipped")
                      else f"ok dom={rec['dominant']} "
                           f"comp={rec['compute_s']:.3e}s "
                           f"mem={rec['memory_s']:.3e}s "
                           f"coll={rec['collective_s']:.3e}s "
                           f"hbm={rec['memory']['total_gib_per_device']:.2f}GiB")
        except Exception as e:
            traceback.print_exc()
            failures.append((a, s, str(e)[:200]))
            status = "FAIL " + str(e)[:120]
        print(f"[dryrun] {a:24s} {s:12s} {status}", flush=True)
    if failures:
        print(f"{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("all cells passed")


if __name__ == "__main__":
    main()
