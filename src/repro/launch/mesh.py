"""Production mesh construction.

Single pod: (16, 16) = ("data", "model") — 256 chips.
Multi-pod:  (2, 16, 16) = ("pod", "data", "model") — 512 chips; the "pod"
axis carries pure data parallelism (and, optionally, the microbatch
pipeline of distributed/pipeline.py), with gradient all-reduce across the
slow inter-pod links — which is where gradient compression applies.

A FUNCTION, not a module constant: importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh() -> jax.sharding.Mesh:
    """Whatever devices exist (CPU smoke tests: 1 device)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def mesh_context(mesh: jax.sharding.Mesh):
    """`jax.set_mesh(mesh)` where available, else the Mesh's own context
    manager (pre-0.5 jax has no `set_mesh`; entering the Mesh sets the
    global mesh for sharding resolution the same way)."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


# TPU v5e-class hardware constants for the roofline (per chip)
PEAK_BF16_FLOPS = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_LINK_BW = 50e9              # bytes/s per link
HBM_BYTES = 16 * 1024**3        # capacity
