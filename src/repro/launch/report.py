"""Generate the EXPERIMENTS.md dry-run + roofline tables from the per-cell
JSON records written by launch/dryrun.py.

  PYTHONPATH=src python -m repro.launch.report --out results/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load_records(out_dir: str) -> list[dict]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(fn) as f:
            r = json.load(f)
        r["_file"] = os.path.basename(fn)
        r["_multipod"] = fn.endswith("_multipod.json")
        recs.append(r)
    return recs


def _fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def dryrun_table(recs: list[dict], multipod: bool) -> str:
    rows = ["| arch | shape | status | HBM/dev | AG | AR | RS | A2A | CP | coll bytes/dev |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["_multipod"] != multipod:
            continue
        if r.get("skipped"):
            rows.append(f"| {r['arch']} | {r['shape']} | SKIP: "
                        f"{r['reason'][:46]} | – | – | – | – | – | – | – |")
            continue
        c = r["collectives"]["counts"]
        g = lambda k: int(c.get(k, 0))
        m = r["memory"]
        fits = "" if m["fits_16gib"] else " ⚠"
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok | "
            f"{m['total_gib_per_device']:.2f}GiB{fits} | "
            f"{g('all-gather')} | {g('all-reduce')} | {g('reduce-scatter')} | "
            f"{g('all-to-all')} | {g('collective-permute')} | "
            f"{r['collectives']['total_bytes']/2**30:.2f}GiB |")
    return "\n".join(rows)


def roofline_table(recs: list[dict]) -> str:
    rows = ["| arch | shape | compute | memory | memory(fused) | collective "
            "| dominant | MODEL/HLO flops | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["_multipod"] or r.get("skipped"):
            continue
        terms = {"compute": r["compute_s"], "memory": r["memory_s"],
                 "collective": r["collective_s"]}
        dom = max(terms, key=terms.get)
        frac = r["compute_s"] / max(max(terms.values()), 1e-12)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(r['compute_s'])} | "
            f"{_fmt_s(r['memory_s'])} | {_fmt_s(r.get('memory_fused_s', 0))} | "
            f"{_fmt_s(r['collective_s'])} | {dom} | "
            f"{r['useful_flops_ratio']:.2f} | {frac:.2f} |")
    return "\n".join(rows)


def summarize(out_dir: str) -> str:
    recs = load_records(out_dir)
    n_ok = sum(1 for r in recs if not r.get("skipped"))
    n_skip = sum(1 for r in recs if r.get("skipped"))
    parts = [
        f"Records: {len(recs)} ({n_ok} compiled, {n_skip} recorded skips)",
        "",
        "### Single-pod (16x16 = 256 chips) dry-run",
        "",
        dryrun_table(recs, multipod=False),
        "",
        "### Multi-pod (2x16x16 = 512 chips) dry-run",
        "",
        dryrun_table(recs, multipod=True),
        "",
        "### Roofline terms (single-pod, per device, per step)",
        "",
        roofline_table(recs),
    ]
    return "\n".join(parts)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()
    print(summarize(args.out))
