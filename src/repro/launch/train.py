"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --steps 50 --batch 8 --seq 128 [--reduced] [--optimizer amc_adamw]

On this CPU container use --reduced (small same-family config). On a real
pod, omit it and pass --mesh pod|multipod.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.launch import mesh as mesh_lib
from repro.train import TrainSettings
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "amc_adamw"])
    ap.add_argument("--schedule", default="cosine", choices=["cosine", "wsd"])
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--mesh", default="local",
                    choices=["local", "pod", "multipod"])
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = (mesh_lib.make_local_mesh() if args.mesh == "local" else
            mesh_lib.make_production_mesh(multi_pod=args.mesh == "multipod"))
    shape = ShapeConfig("train", args.seq, args.batch, "train")
    settings = TrainSettings(optimizer=args.optimizer, lr=args.lr,
                             grad_accum=args.grad_accum,
                             q_chunk=min(1024, args.seq))
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                         schedule=args.schedule,
                         warmup=max(2, args.steps // 10),
                         ckpt_every=max(5, args.steps // 5))
    tr = Trainer(cfg, shape, mesh, settings, tcfg)
    losses = tr.train()
    print(f"[train] {cfg.name}: step {tr.current_step()} "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    tr.close()


if __name__ == "__main__":
    main()
