"""Trip-count-aware HLO analysis.

XLA's `compiled.cost_analysis()` counts each while-loop body ONCE, so for a
scan-over-layers program (every model here) its FLOPs/bytes are off by the
trip count (verified empirically: scan of length 10 reports exactly 1/10th
of the analytic FLOPs). This module re-derives the roofline terms from
`compiled.as_text()` with loop multipliers:

  * flops: dot/convolution ops, 2 * prod(output_dims) * prod(contracting),
    multiplied along the enclosing while/call/fusion chain.
  * collective bytes: result bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute ops, same multipliers.
  * bytes accessed: per-op output + operand bytes (gather/scatter and
    dynamic-slice/update special-cased to bytes actually touched), fusion
    bodies counted as one kernel (the fusion op's own operands/outputs).

Trip counts come from the loop-condition computation (the `constant(K)`
compared against the induction variable — how scan lowers).
"""
from __future__ import annotations

import re
from collections import defaultdict

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
               "s4": 1, "u4": 1}

_COMP_START = re.compile(r"^(ENTRY\s+)?%?([\w\.\-_]+)\s*\(.*\)\s*->.*\{")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-_]+)\s*=\s*([a-z0-9]+)\[([0-9,]*)\]\S*\s+(\w[\w\-]*)\(")
_TUPLE_OP = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-_]+)\s*=\s*\(")
_OPERANDS = re.compile(r"%([\w\.\-_]+)")
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-_]+)")
_COND = re.compile(r"condition=%?([\w\.\-_]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                  "bitcast", "while", "conditional", "after-all", "domain",
                  "opt-barrier", "partition-id", "replica-id", "iota",
                  "copy-start", "copy-done"}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[str]] = {}
        self.entry = None
        cur, name = None, None
        for line in text.splitlines():
            m = _COMP_START.match(line.strip()) if "{" in line else None
            if m and "=" not in line.split("(")[0]:
                name = m.group(2)
                cur = []
                self.computations[name] = cur
                if m.group(1):
                    self.entry = name
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is not None:
                cur.append(line)
        # shapes of every named op (module-global; names are unique)
        self.shapes: dict[str, tuple[str, str]] = {}
        for ops in self.computations.values():
            for line in ops:
                m = _OP_LINE.match(line)
                if m:
                    self.shapes[m.group(1)] = (m.group(2), m.group(3))

    def trip_count(self, cond_name: str) -> int:
        """Largest int constant in the loop condition (scan lowers to
        `lt(i, K)`); 1 if none found (conservative)."""
        best = 1
        for line in self.computations.get(cond_name, ()):
            for c in _CONST_INT.findall(line):
                best = max(best, int(c))
        return best

    def analyze(self, top_n: int = 0) -> dict:
        flops = 0.0
        bytes_accessed = 0.0
        bytes_fused = 0.0      # idealized fusion: dots/collectives/slices only
        coll_bytes = defaultdict(float)
        coll_counts = defaultdict(float)
        by_op_bytes = defaultdict(float)
        by_op_flops = defaultdict(float)
        top = []
        visited_stack = set()

        def visit(comp: str, mult: float, bytes_on: bool):
            nonlocal flops, bytes_accessed, bytes_fused
            if comp not in self.computations or comp in visited_stack:
                return
            visited_stack.add(comp)
            for line in self.computations[comp]:
                m = _OP_LINE.match(line)
                if not m:
                    # tuple-typed ops: may still be while loops
                    if " while(" in line:
                        self._visit_while(line, mult, visit, bytes_on)
                    continue
                name, dtype, dims, op = m.groups()
                if op == "while":
                    self._visit_while(line, mult, visit, bytes_on)
                    continue
                dus_update_bytes = None
                if op in ("fusion", "call", "conditional", "map"):
                    for callee in _CALLS.findall(line):
                        # fusion internals: flops yes, bytes no (one kernel)
                        visit(callee, mult, bytes_on and op in ("call",))
                        if op == "fusion":
                            dus_update_bytes = self._fusion_dus_bytes(callee)
                if op in ("dot", "convolution"):
                    out_elems = _shape_elems(dims)
                    contract = 1
                    cm = _CONTRACT.search(line)
                    ops_named = _OPERANDS.findall(
                        line.split("(", 1)[1].split(")", 1)[0])
                    if cm and ops_named:
                        lhs = self.shapes.get(ops_named[0])
                        if lhs:
                            ldims = [int(x) for x in lhs[1].split(",") if x]
                            for ci in cm.group(1).split(","):
                                if ci and int(ci) < len(ldims):
                                    contract *= ldims[int(ci)]
                    elif op == "convolution" and ops_named:
                        rhs = self.shapes.get(ops_named[1])
                        if rhs:
                            contract = max(
                                1, _shape_elems(rhs[1]) // max(out_elems, 1))
                    flops += mult * 2.0 * out_elems * contract
                    if bytes_on:
                        opb = _shape_bytes(dtype, dims)
                        for o in _OPERANDS.findall(
                                line.split("(", 1)[1].split(")", 1)[0])[:3]:
                            sh = self.shapes.get(o)
                            if sh:
                                opb += _shape_bytes(*sh)
                        bytes_fused += mult * opb
                for kind in COLLECTIVES:
                    if op == kind or op.startswith(kind + "-"):
                        b = _shape_bytes(dtype, dims)
                        coll_bytes[kind] += mult * b
                        coll_counts[kind] += mult
                        if bytes_on:
                            bytes_fused += mult * b
                        break
                if bytes_on and op not in SKIP_BYTES_OPS:
                    out_b = _shape_bytes(dtype, dims)
                    if dus_update_bytes is not None:
                        # in-place fused dynamic-update-slice: only the
                        # updated slice is touched (read+write), the rest
                        # of the buffer is aliased
                        bytes_accessed += mult * 2 * dus_update_bytes
                        bytes_fused += mult * 2 * dus_update_bytes
                        by_op_bytes[op] += mult * 2 * dus_update_bytes
                        if top_n:
                            top.append((mult * 2 * dus_update_bytes, op,
                                        name, dtype, dims, mult))
                        continue
                    if op in ("dynamic-slice", "gather"):
                        bytes_accessed += mult * 2 * out_b
                        bytes_fused += mult * 2 * out_b
                    elif op in ("dynamic-update-slice", "scatter"):
                        # bytes touched ~ the update operand, twice
                        ops_named = _OPERANDS.findall(
                            line.split("(", 1)[1].split(")", 1)[0])
                        upd = (self.shapes.get(ops_named[1])
                               if len(ops_named) > 1 else None)
                        ub = _shape_bytes(*upd) if upd else out_b
                        bytes_accessed += mult * 2 * min(ub, out_b)
                        bytes_fused += mult * 2 * min(ub, out_b)
                    else:
                        opb = 0
                        arg_str = line.split("(", 1)[1]
                        for o in _OPERANDS.findall(arg_str)[:8]:
                            sh = self.shapes.get(o)
                            if sh:
                                opb += _shape_bytes(*sh)
                        bytes_accessed += mult * (out_b + opb)
                    by_op_bytes[op] += mult * out_b
                    if top_n:
                        top.append((mult * out_b, op, name, dtype, dims,
                                    mult))
            visited_stack.discard(comp)

        def _noop(*a):
            pass

        visit(self.entry, 1.0, True)
        out = {
            "flops": flops,
            "bytes_accessed": bytes_accessed,
            "bytes_fused": bytes_fused,
            "collective_bytes": dict(coll_bytes),
            "collective_counts": dict(coll_counts),
            "collective_total_bytes": float(sum(coll_bytes.values())),
            "bytes_by_op": dict(sorted(by_op_bytes.items(),
                                       key=lambda kv: -kv[1])[:20]),
        }
        if top_n:
            out["top_tensors"] = sorted(top, key=lambda t: -t[0])[:top_n]
        return out

    def _fusion_dus_bytes(self, comp: str):
        """If the fusion computation's ROOT is a dynamic-update-slice (an
        in-place cache write), return the update operand's byte count."""
        for line in self.computations.get(comp, ()):
            if "dynamic-update-slice(" in line:
                ops_named = _OPERANDS.findall(
                    line.split("(", 1)[1].split(")", 1)[0])
                if len(ops_named) > 1:
                    sh = self.shapes.get(ops_named[1])
                    if sh:
                        return _shape_bytes(*sh)
        return None

    def _visit_while(self, line, mult, visit, bytes_on):
        cond = _COND.search(line)
        body = re.search(r"body=%?([\w\.\-_]+)", line)
        k = self.trip_count(cond.group(1)) if cond else 1
        if body:
            visit(body.group(1), mult * k, bytes_on)
        if cond:
            visit(cond.group(1), mult * k, False)


def analyze_hlo(text: str) -> dict:
    return HloModule(text).analyze()
