"""Serving launcher: batched greedy decoding with AMC-packed KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --reduced \
      --requests 6 --max-new 12
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_arch
from repro.launch import mesh as mesh_lib
from repro.serve import ArrayFleet, Request, make_serving


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=5,
                    help="synthetic prompt length (longer prompts build "
                         "more cold storage — pressure + fault surface)")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--retention-steps", type=int, default=None,
                    help="augmented retention window override (small "
                         "windows force refresh traffic)")
    ap.add_argument("--pool-mode", default=None,
                    choices=["normal-only", "augment-on-pressure",
                             "always-augmented"],
                    help="paged-pool policy override (default: auto from "
                         "kv_mode)")
    ap.add_argument("--pool-budget-bytes", type=int, default=None,
                    help="paged-pool byte budget (the modeled SRAM array "
                         "size; small budgets exercise augmentation "
                         "pressure and preemption)")
    ap.add_argument("--matmul-impl", default=None,
                    choices=["dense", "packed", "imc"],
                    help="consumer for packed weight matmuls (imc = "
                         "bit-serial in-array dot product)")
    ap.add_argument("--imc-abits", type=int, default=None,
                    choices=[1, 4, 8],
                    help="IMC activation precision (bit-serial cycles)")
    ap.add_argument("--state-bits", type=int, default=None,
                    choices=[4, 8],
                    help="augmented recurrent-state slab width "
                         "(ssm/hybrid/vlm-prefix stores)")
    ap.add_argument("--spec-k", type=int, default=None,
                    help="speculative window: draft spec_k-1 tokens per "
                         "round out of the cheap plane, verify them in "
                         "one packed dispatch (1 = stepwise decode)")
    ap.add_argument("--spec-draft-impl", default=None,
                    choices=["dequant", "dense", "packed", "imc1", "imc4",
                             "imc8", "same"],
                    help="representation the draft pass reads (default "
                         "dequant: XLA over dequantized KV)")
    ap.add_argument("--fault-rate", type=float, default=None,
                    help="per-unit retention-fault probability at end of "
                         "window, 85C (0 disables injection)")
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="seed of the deterministic fault sampler")
    ap.add_argument("--array-loss-rate", type=float, default=None,
                    help="per-step whole-array failure probability "
                         "(drain-and-requeue recovery)")
    ap.add_argument("--max-retries", type=int, default=None,
                    help="fault-recovery retries per request before it is "
                         "failed (never silently served)")
    ap.add_argument("--no-integrity-check", action="store_true",
                    help="disable integrity-word verification (ablation: "
                         "forfeits the zero-silent-corruption property)")
    ap.add_argument("--trace-out", default=None, metavar="TRACE.json",
                    help="record per-request spans and write a "
                         "perfetto-loadable Chrome trace here (implies "
                         "tracing on)")
    ap.add_argument("--metrics-out", default=None, metavar="METRICS.prom",
                    help="record latency histograms / time series and "
                         "write a Prometheus text dump here (implies "
                         "metrics on)")
    ap.add_argument("--obs-sample-every", type=int, default=None,
                    help="time-series sampling stride in engine steps "
                         "(default 1: every step)")
    ap.add_argument("--num-arrays", type=int, default=None,
                    help="logical SRAM arrays to serve across (>1 runs "
                         "an ArrayFleet: per-array budgets, refresh "
                         "clocks, fault domains and trace lanes)")
    ap.add_argument("--placement", default=None,
                    choices=["least-loaded", "budget-headroom", "affinity"],
                    help="fleet admission policy (default: "
                         "cfg.amc.placement = least-loaded)")
    ap.add_argument("--prefix-cache", type=int, default=None,
                    help="shared-prefix page-reuse entries per array "
                         "(paged stores; >0 maps repeated prompt "
                         "prefixes to the same physical pages and "
                         "prefills only the tail; 0 disables)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend this many common system-prompt tokens "
                         "to every synthetic request (the prefix-cache "
                         "hit workload)")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = mesh_lib.make_local_mesh()
    eng = make_serving(cfg, mesh, num_arrays=args.num_arrays,
                       placement=args.placement,
                       max_batch=args.max_batch,
                       max_seq=args.max_seq, pool_mode=args.pool_mode,
                       pool_budget_bytes=args.pool_budget_bytes,
                       retention_steps=args.retention_steps,
                       matmul_impl=args.matmul_impl,
                       imc_abits=args.imc_abits,
                       state_bits=args.state_bits,
                       spec_k=args.spec_k,
                       spec_draft_impl=args.spec_draft_impl,
                       fault_rate=args.fault_rate,
                       fault_seed=args.fault_seed,
                       array_loss_rate=args.array_loss_rate,
                       max_retries=args.max_retries,
                       integrity_check=(False if args.no_integrity_check
                                        else None),
                       trace=(True if args.trace_out else None),
                       metrics=(True if args.metrics_out else None),
                       obs_sample_every=args.obs_sample_every,
                       prefix_cache=args.prefix_cache)
    rng = np.random.default_rng(0)
    system = rng.integers(0, cfg.vocab,
                          size=(args.shared_prefix,)).astype(np.int32)
    reqs = [Request(prompt=np.concatenate(
                        [system, rng.integers(0, cfg.vocab,
                                              size=(args.prompt_len,))
                         .astype(np.int32)]),
                    max_new_tokens=args.max_new, id=i)
            for i in range(args.requests)]
    outs = eng.generate(reqs)
    for rid in sorted(outs):
        print(f"[serve] req {rid}: {outs[rid]}")
    if isinstance(eng, ArrayFleet):
        st = eng.stats()
        fl = st["fleet"]
        print(f"[serve] fleet arrays={fl['num_arrays']} "
              f"placement={fl['placement']} "
              f"peak_concurrency={fl['peak_concurrency']} "
              f"migrations={fl['migrations']} "
              f"array_losses={fl['array_losses']} "
              f"placements_per_array={fl['placements_per_array']}")
        for a in fl["per_array"]:
            print(f"[serve]   array {a['array']}: alive={a['alive']} "
                  f"peak_conc={a['peak_concurrency']} "
                  f"occupancy={a['occupancy']:.2f} "
                  f"mode(norm/aug)={a['mode_normal']}/"
                  f"{a['mode_augmented']} "
                  f"refresh_debt={a['refresh_debt']} "
                  f"tp={a['tensor_parallel']}")
        if args.trace_out:
            trace = eng.export_trace(args.trace_out)
            print(f"[serve] trace: {len(trace['traceEvents'])} events "
                  f"({fl['num_arrays']} array lanes) -> {args.trace_out}")
        if args.metrics_out:
            eng.export_metrics(args.metrics_out)
            print(f"[serve] metrics (fleet-wide) -> {args.metrics_out}")
        return
    print(f"[serve] kv_mode={eng.cfg.amc.kv_mode} "
          f"(augmented KV capacity factor "
          f"{ {'normal':1,'int8':2,'int4':4}[eng.cfg.amc.kv_mode] }x)")
    imc = eng.stats()["imc"]
    print(f"[serve] matmul_impl={imc['matmul_impl']} "
          f"abits={imc['imc_abits']} "
          f"modeled_energy_pj_per_token={imc['energy_pj_per_token']:.1f}")
    st = eng.stats()
    sp = st["spec"]
    if sp["enabled"]:
        print(f"[serve] spec_k={sp['spec_k']} draft={sp['spec_draft_impl']} "
              f"accepted/dispatch={sp['accepted_tokens_per_dispatch']:.2f} "
              f"accepted/round={sp['accepted_tokens_per_round']:.2f} "
              f"rounds={sp['spec_rounds']}")
    live = st["pool"]
    if eng.store.kind == "paged":
        occupancy = (f"pages(norm/aug)={live['pages_live_normal']}/"
                     f"{live['pages_live_augmented']}")
    elif eng.store.kind == "slab":
        occupancy = (f"slabs(norm/aug)={live['slabs_live_normal']}/"
                     f"{live['slabs_live_augmented']}")
    else:
        occupancy = f"parts={sorted(live['parts'])}"
    print(f"[serve] store={eng.store.kind} {occupancy} "
          f"augments={st['augment_events']} refreshes={st['refreshes']} "
          f"preemptions={st['preemptions']} "
          f"queue_peak={st['scheduler']['peak_queue_depth']}")
    pf = st["prefix"]
    if pf["enabled"]:
        print(f"[serve] prefix_cache entries={pf['capacity']} "
              f"hits={pf['hits']} misses={pf['misses']} "
              f"hit_rate={pf['hit_rate']:.2f} "
              f"dispatches_saved={pf['dispatches_saved']} "
              f"cow={pf['cow_events']} demotions={pf['demotions']} "
              f"evictions={pf['evictions']}")
    fl = st["faults"]
    if fl["enabled"]:
        print(f"[serve] faults injected={fl['faults_injected']} "
              f"detected={fl['faults_detected']} "
              f"masked={fl['faults_masked']} recovered={fl['recovered']} "
              f"(scrub={fl['recovered_scrub']} "
              f"recompute={fl['recovered_recompute']}) "
              f"uncorrectable={fl['uncorrectable']} "
              f"array_losses={fl['array_losses']} "
              f"zero_silent_corruption={fl['zero_silent_corruption']}")
    if args.trace_out:
        trace = eng.export_trace(args.trace_out)
        print(f"[serve] trace: {len(trace['traceEvents'])} events -> "
              f"{args.trace_out}")
    if args.metrics_out:
        eng.export_metrics(args.metrics_out)
        ob = st["obs"]
        h = ob["histograms"]
        if "ttft_s" in h:
            print(f"[serve] obs: ttft_p50={h['ttft_s']['p50'] * 1e3:.2f}ms "
                  f"p99={h['ttft_s']['p99'] * 1e3:.2f}ms "
                  f"step_p50={h['step_wall_s']['p50'] * 1e3:.2f}ms "
                  f"-> {args.metrics_out}")


if __name__ == "__main__":
    main()
