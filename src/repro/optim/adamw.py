"""AdamW, plus AMC-Adam: Adam whose moment buffers live in augmented
(int8-quantized, dynamic-plane) storage.

AMC-Adam is the optimizer-state instance of the paper's capacity
augmentation: m and v are DYNAMIC data (rewritten every step, tolerant of
quantization noise), so they take the augmented plane — 1 byte/param each
instead of 4, with per-row scales as the "reference voltage" and the
every-step rewrite acting as the DRAM-style refresh. Cuts optimizer HBM
from 8 to ~2 bytes/param, which is what lets grok-1-314b train on a single
256-chip pod (DESIGN.md SS4). Moments keep the parameter's shape (int8),
so they inherit the parameter's sharding unchanged.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def adamw_init(params) -> AdamState:
    z = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamState(step=jnp.zeros((), jnp.int32),
                     m=jax.tree.map(z, params),
                     v=jax.tree.map(z, params))


def _split3(out):
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), pick(1), pick(2)


def adamw_update(grads, state: AdamState, params, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1):
    step = state.step + 1
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        new_p = (p.astype(jnp.float32)
                 - lr * ((m / bc1) / (jnp.sqrt(v / bc2) + eps)
                         + weight_decay * p.astype(jnp.float32)))
        return new_p.astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_p, new_m, new_v = _split3(out)
    return new_p, AdamState(step=step, m=new_m, v=new_v)


# ---------------------------------------------------------------------------
# AMC-Adam: int8 row-quantized moments (augmented dynamic plane)
# ---------------------------------------------------------------------------

class AMCAdamState(NamedTuple):
    step: jax.Array
    m_q: dict      # int8, param-shaped
    m_scale: dict  # f32, shape[:-1] + (1,)
    v_q: dict      # int8, sqrt-space for dynamic range
    v_scale: dict


def _q_write(x: jax.Array):
    """Per-row symmetric int8 write to the augmented plane."""
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0,
                        1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _q_read(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Sense amplifier: dequantize the plane."""
    return q.astype(jnp.float32) * scale


def amc_adamw_init(params) -> AMCAdamState:
    zq = lambda p: jnp.zeros(p.shape, jnp.int8)
    zs = lambda p: jnp.zeros(p.shape[:-1] + (1,), jnp.float32)
    return AMCAdamState(step=jnp.zeros((), jnp.int32),
                        m_q=jax.tree.map(zq, params),
                        m_scale=jax.tree.map(zs, params),
                        v_q=jax.tree.map(zq, params),
                        v_scale=jax.tree.map(zs, params))


def amc_adamw_update(grads, state: AMCAdamState, params, *, lr, b1=0.9,
                     b2=0.95, eps=1e-8, weight_decay=0.1):
    step = state.step + 1
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, mq, ms, vq, vs, p):
        g = g.astype(jnp.float32)
        m = _q_read(mq, ms)                    # sense the dynamic plane
        v = _q_read(vq, vs) ** 2               # v stored in sqrt-space
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        new_p = (p.astype(jnp.float32)
                 - lr * ((m / bc1) / (jnp.sqrt(v / bc2) + eps)
                         + weight_decay * p.astype(jnp.float32)))
        mq2, ms2 = _q_write(m)                 # refresh (re-write) the plane
        vq2, vs2 = _q_write(jnp.sqrt(v))
        return new_p.astype(p.dtype), mq2, ms2, vq2, vs2

    out = jax.tree.map(upd, grads, state.m_q, state.m_scale, state.v_q,
                       state.v_scale, params)
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), AMCAdamState(step=step, m_q=pick(1), m_scale=pick(2),
                                 v_q=pick(3), v_scale=pick(4))


def make_optimizer(kind: str):
    if kind == "adamw":
        return adamw_init, adamw_update
    if kind == "amc_adamw":
        return amc_adamw_init, amc_adamw_update
    raise KeyError(kind)
