from repro.optim.adamw import (AdamState, AMCAdamState, adamw_init,
                               adamw_update, amc_adamw_init,
                               amc_adamw_update, make_optimizer)
from repro.optim.schedule import SCHEDULES, cosine, wsd

__all__ = ["AdamState", "AMCAdamState", "adamw_init", "adamw_update",
           "amc_adamw_init", "amc_adamw_update", "make_optimizer",
           "SCHEDULES", "cosine", "wsd"]
