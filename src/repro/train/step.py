"""Sharded train/prefill/decode steps and their sharding-spec builders.

These are the functions the launcher jits with explicit in/out shardings;
the dry-run lowers exactly these (so the roofline reads from the real
production program, not a proxy).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import Rules
from repro.models import model as M
from repro.models.params import PSpec, is_pspec, to_shape_dtype
from repro.optim import adamw


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    optimizer: str = "adamw"        # adamw | amc_adamw
    lr: float = 3e-4
    weight_decay: float = 0.1
    remat_policy: str = "nothing"   # none | dots | nothing (full remat)
    q_chunk: int = 1024
    grad_accum: int = 1


# ---------------------------------------------------------------------------
# Spec builders
# ---------------------------------------------------------------------------

def param_pspecs(abstract, rules: Rules):
    return jax.tree.map(lambda l: rules.pspec(*l.axes), abstract,
                        is_leaf=is_pspec)


def param_shardings(abstract, rules: Rules):
    return jax.tree.map(lambda s: NamedSharding(rules.mesh, s),
                        param_pspecs(abstract, rules),
                        is_leaf=lambda x: isinstance(x, P))


def opt_abstract(abstract_params, kind: str):
    """PSpec tree for the optimizer state, mirroring param sharding."""
    if kind == "adamw":
        f32 = lambda l: PSpec(l.shape, l.axes, dtype="f32", init="zeros")
        return adamw.AdamState(
            step=PSpec((), (), dtype="i32", init="zeros"),
            m=jax.tree.map(f32, abstract_params, is_leaf=is_pspec),
            v=jax.tree.map(f32, abstract_params, is_leaf=is_pspec))
    q = lambda l: PSpec(l.shape, l.axes, dtype="i8", init="zeros")
    s = lambda l: PSpec(l.shape[:-1] + (1,), l.axes[:-1] + (None,),
                        dtype="f32", init="zeros")
    return adamw.AMCAdamState(
        step=PSpec((), (), dtype="i32", init="zeros"),
        m_q=jax.tree.map(q, abstract_params, is_leaf=is_pspec),
        m_scale=jax.tree.map(s, abstract_params, is_leaf=is_pspec),
        v_q=jax.tree.map(q, abstract_params, is_leaf=is_pspec),
        v_scale=jax.tree.map(s, abstract_params, is_leaf=is_pspec))


def batch_pspecs(cfg: ModelConfig, shape: ShapeConfig, rules: Rules):
    specs = {}
    b = rules.resolve("batch")
    if shape.kind == "train":
        specs["tokens"] = P(b, None)
        specs["targets"] = P(b, None)
    elif shape.kind == "prefill":
        specs["tokens"] = P(b, None)
    else:
        specs["tokens"] = P(b, None)
        specs["positions"] = P(b)
    if cfg.encdec is not None:
        specs["frames"] = P(b, None, None)
    if cfg.vision is not None:
        specs["patches"] = P(b, None, None)
    return specs


def cache_pspecs(cfg: ModelConfig, shape: ShapeConfig, rules: Rules):
    return param_pspecs(M.abstract_cache(cfg, shape), rules)


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

class TrainState(NamedTuple):
    params: dict
    opt: object
    step: jax.Array


def make_train_step(cfg: ModelConfig, settings: TrainSettings, rules: Rules,
                    lr_fn=None):
    _, opt_update = adamw.make_optimizer(settings.optimizer)

    def loss(p, b):
        return M.loss_fn(cfg, p, b, rules=rules,
                         remat_policy=settings.remat_policy,
                         q_chunk=settings.q_chunk)

    def train_step(state: TrainState, batch: dict):
        n = settings.grad_accum
        if n <= 1:
            lval, grads = jax.value_and_grad(loss)(state.params, batch)
        else:
            # Gradient microbatching: bounds live activation memory to one
            # microbatch; grads accumulate in fp32 (scan carry, aliased).
            micro = jax.tree.map(
                lambda t: t.reshape((n, t.shape[0] // n) + t.shape[1:]),
                batch)

            def mb(carry, mbatch):
                gacc, lacc = carry
                l, g = jax.value_and_grad(loss)(state.params, mbatch)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g)
                return (gacc, lacc + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (gsum, lsum), _ = jax.lax.scan(mb, (zeros, jnp.zeros((), jnp.float32)),
                                           micro)
            grads = jax.tree.map(lambda g: g / n, gsum)
            lval = lsum / n
        lr = settings.lr if lr_fn is None else lr_fn(state.step)
        new_p, new_opt = opt_update(grads, state.opt, state.params, lr=lr,
                                    weight_decay=settings.weight_decay)
        return TrainState(new_p, new_opt, state.step + 1), lval

    return train_step


def make_prefill_step(cfg: ModelConfig, settings: TrainSettings, rules: Rules):
    def prefill_step(params, batch):
        return M.forward(cfg, params, batch, rules=rules, return_cache=True,
                         remat_policy="none", q_chunk=settings.q_chunk)
    return prefill_step


def make_decode_step(cfg: ModelConfig, rules: Rules):
    def decode_step(params, cache, batch):
        return M.decode_step(cfg, params, cache, batch, rules=rules)
    return decode_step
