"""Trainer: jitted sharded steps + checkpointing + fault tolerance.

Composes: train step (train/step.py), synthetic data pipeline (prefetch +
checkpointable position), async atomic checkpoints, auto-resume, simulated
failure injection (Supervisor) and straggler monitoring — the host-side
half of the multi-pod deployment story.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import checkpoint as ckpt_lib
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data import PrefetchIterator, SyntheticLM
from repro.distributed.fault import StragglerMonitor, Supervisor
from repro.distributed.sharding import Rules
from repro.launch.mesh import mesh_context
from repro.models import model as M
from repro.models.params import init_params, to_shape_dtype
from repro.optim import adamw, SCHEDULES
from repro.train import step as step_lib


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    schedule: str = "cosine"       # cosine | wsd
    warmup: int = 10
    seed: int = 0
    keep_ckpts: int = 3


class Trainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, mesh,
                 settings: step_lib.TrainSettings,
                 tcfg: TrainerConfig,
                 failure_injector: Optional[Callable[[int], None]] = None):
        self.cfg, self.shape, self.mesh = cfg, shape, mesh
        self.settings, self.tcfg = settings, tcfg
        self.rules = Rules.make(mesh, cfg, shape)
        lr_fn = lambda step: SCHEDULES[tcfg.schedule](
            step, peak_lr=settings.lr, warmup=tcfg.warmup,
            total=tcfg.total_steps)
        self._step_fn = step_lib.make_train_step(cfg, settings, self.rules,
                                                 lr_fn=lr_fn)
        ap = M.abstract_params(cfg)
        self.param_shardings = step_lib.param_shardings(ap, self.rules)
        oa = step_lib.opt_abstract(ap, settings.optimizer)
        self.opt_shardings = step_lib.param_shardings(oa, self.rules)
        self.state_shardings = step_lib.TrainState(
            self.param_shardings, self.opt_shardings,
            NamedSharding(mesh, P()))
        b_pspecs = step_lib.batch_pspecs(cfg, shape, self.rules)
        self.batch_shardings = {k: NamedSharding(mesh, v)
                                for k, v in b_pspecs.items()}
        self.jit_step = jax.jit(
            self._step_fn,
            in_shardings=(self.state_shardings, self.batch_shardings),
            out_shardings=(self.state_shardings, NamedSharding(mesh, P())),
            donate_argnums=(0,))

        opt_init, _ = adamw.make_optimizer(settings.optimizer)
        with mesh_context(mesh):
            params = init_params(ap, jax.random.PRNGKey(tcfg.seed))
            params = jax.tree.map(jax.device_put, params,
                                  self.param_shardings)
            self.state = step_lib.TrainState(
                params, opt_init(params), jnp.zeros((), jnp.int32))
        self.data = PrefetchIterator(
            SyntheticLM(cfg.vocab, shape.seq_len, shape.global_batch,
                        seed=tcfg.seed),
            put_fn=lambda b: {k: jax.device_put(jnp.asarray(v),
                                                self.batch_shardings[k])
                              for k, v in b.items()})
        self.ckpt = ckpt_lib.AsyncCheckpointer(tcfg.ckpt_dir,
                                               keep=tcfg.keep_ckpts)
        self.straggler = StragglerMonitor()
        self.supervisor = Supervisor(self._restore_latest)
        self.failure_injector = failure_injector
        self.losses: list = []
        self._maybe_resume()

    # -- checkpoint/restore --------------------------------------------------

    def _save(self, step: int) -> None:
        self.ckpt.save(step, self.state,
                       extra={"data": self.data.state_dict(),
                              "losses": [float(l) for l in self.losses]})

    def _maybe_resume(self) -> None:
        latest = ckpt_lib.latest_step(self.tcfg.ckpt_dir)
        if latest is not None:
            self._restore(latest)

    def _restore_latest(self) -> int:
        self.ckpt.wait()
        latest = ckpt_lib.latest_step(self.tcfg.ckpt_dir)
        if latest is None:
            raise RuntimeError("failure before any checkpoint")
        self._restore(latest)
        return latest

    def _restore(self, step: int) -> None:
        self.state, extra = ckpt_lib.restore(
            self.tcfg.ckpt_dir, step, self.state,
            shardings=self.state_shardings)
        self.data.load_state_dict(extra["data"])
        self.losses = list(extra.get("losses", []))

    # -- loop -----------------------------------------------------------------

    def current_step(self) -> int:
        return int(self.state.step)

    def train(self, n_steps: Optional[int] = None) -> list:
        target = (self.tcfg.total_steps if n_steps is None
                  else self.current_step() + n_steps)
        with mesh_context(self.mesh):
            while self.current_step() < target:
                step = self.current_step()

                def one():
                    if self.failure_injector is not None:
                        self.failure_injector(step)
                    batch = next(self.data)
                    t0 = time.time()
                    self.state, loss = self.jit_step(self.state, batch)
                    loss = float(loss)
                    self.straggler.record(step, time.time() - t0)
                    self.losses.append(loss)
                    if (step + 1) % self.tcfg.ckpt_every == 0:
                        self._save(step + 1)

                self.supervisor.run_step(one)
        self.ckpt.wait()
        return self.losses

    def close(self):
        self.data.close()
        self.ckpt.wait()
