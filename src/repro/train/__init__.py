from repro.train.step import (TrainSettings, TrainState, make_decode_step,
                              make_prefill_step, make_train_step)

__all__ = ["TrainSettings", "TrainState", "make_decode_step",
           "make_prefill_step", "make_train_step"]
