from repro.data.pipeline import PrefetchIterator, SyntheticLM

__all__ = ["PrefetchIterator", "SyntheticLM"]
