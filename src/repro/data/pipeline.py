"""Deterministic synthetic token pipeline.

Production-shaped: sharded per-host batches, background prefetch thread,
and a checkpointable iterator state (the stream is a pure function of
(seed, step), so restoring `step` resumes bit-exactly — no sample skipped
or repeated after a crash, which the fault-tolerance test asserts).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


class SyntheticLM:
    """Zipf-ish token stream with enough structure to overfit a tiny LM
    (next-token = f(current) mixtures), deterministic per (seed, step)."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed

    def batch_at(self, step: int) -> dict:
        # the structural map (a, b) is FIXED per seed so there is signal to
        # learn; initial tokens and noise vary per step
        srng = np.random.default_rng(np.random.SeedSequence([self.seed]))
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        B, S, V = self.global_batch, self.seq_len, self.vocab
        # markov-ish: tok[t+1] = (a * tok[t] + b + noise) % V with a GLOBAL
        # (a, b) so next-token is a learnable function of the current token
        a = srng.integers(2, 8, size=(1, 1))
        b = srng.integers(0, V, size=(1, 1))
        t0 = rng.integers(0, V, size=(B, 1))
        toks = [t0]
        for _ in range(S):
            nxt = (a * toks[-1] + b) % V
            flip = rng.random((B, 1)) < 0.1
            rand = rng.integers(0, V, size=(B, 1))
            toks.append(np.where(flip, rand, nxt))
        seq = np.concatenate(toks, axis=1)           # (B, S+1)
        return {"tokens": seq[:, :-1].astype(np.int32),
                "targets": seq[:, 1:].astype(np.int32)}


class PrefetchIterator:
    """Background-thread prefetch with checkpointable position."""

    def __init__(self, source: SyntheticLM, start_step: int = 0,
                 prefetch: int = 2, put_fn=None):
        self.source = source
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._put = put_fn or (lambda b: jax.tree.map(jnp.asarray, b))
        self._next_to_produce = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while not self._stop.is_set():
            b = self.source.batch_at(self._next_to_produce)
            try:
                self._q.put((self._next_to_produce, b), timeout=0.5)
                self._next_to_produce += 1
            except queue.Full:
                continue

    def __next__(self) -> dict:
        while True:
            step, b = self._q.get()
            if step == self.step:  # discard stale prefetches after restore
                self.step += 1
                return self._put(b)
            if step > self.step:
                # thread is ahead of a restored position; restart it
                self._restart()

    def _restart(self):
        self._stop.set()
        self._thread.join(timeout=2)
        while not self._q.empty():
            self._q.get_nowait()
        self._stop = threading.Event()
        self._next_to_produce = self.step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    # -- checkpoint interface --
    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.source.seed}

    def load_state_dict(self, state: dict) -> None:
        assert state["seed"] == self.source.seed, "data seed mismatch"
        self.step = int(state["step"])
        self._restart()

    def close(self):
        self._stop.set()
