"""Declarative parameter specs: single source of truth for shapes, dtypes,
logical sharding axes and initializers.

`abstract_params(cfg)` (per model family) returns a pytree of `PSpec`
leaves; from it we derive (a) ShapeDtypeStructs for the dry-run, (b) real
initialized arrays for smoke tests / training, (c) PartitionSpecs via the
sharding rules. One tree, three views — structure mismatches are impossible.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis per dim (None = replicated)
    dtype: str = "bf16"
    init: str = "normal"              # normal | zeros | ones
    fan_in_dims: Tuple[int, ...] = () # dims to normalize variance over

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    @property
    def jdtype(self):
        return {"bf16": jnp.bfloat16, "f32": jnp.float32,
                "u8": jnp.uint8, "i8": jnp.int8, "i32": jnp.int32}[self.dtype]


def is_pspec(x) -> bool:
    return isinstance(x, PSpec)


def to_shape_dtype(tree):
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.jdtype),
        tree, is_leaf=is_pspec)


def init_params(tree, key, dtype_override=None):
    """Materialize real arrays; each leaf gets a path-derived subkey."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_pspec)
    out = []
    for i, l in enumerate(leaves):
        k = jax.random.fold_in(key, i)
        dt = dtype_override or l.jdtype
        if l.init == "zeros" or l.dtype in ("u8", "i8", "i32"):
            out.append(jnp.zeros(l.shape, l.jdtype))
        elif l.init == "ones":
            out.append(jnp.ones(l.shape, dt))
        else:
            fan = 1
            dims = l.fan_in_dims or (tuple(range(len(l.shape) - 1))
                                     if len(l.shape) > 1 else (0,))
            for d in dims:
                fan *= l.shape[d]
            w = jax.random.normal(k, l.shape, jnp.float32) / np.sqrt(max(fan, 1))
            out.append(w.astype(dt))
    return jax.tree.unflatten(treedef, out)


def count_params(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_pspec)
    return sum(int(np.prod(l.shape)) for l in leaves)
