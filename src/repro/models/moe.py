"""Mixture-of-Experts FFN with GShard-style capacity dispatch.

Two sharding modes (DESIGN.md SS4):
  * "ep": experts sharded over the model axis (qwen3-moe: 128/16 = 8 per
    device). Tokens are grouped, dispatch/combine einsums move them between
    group-sharded and expert-sharded layouts — XLA SPMD inserts the
    all-to-alls (this is the EP dispatch of real systems).
  * "tp": expert count doesn't divide the axis (grok-1: 8 experts on a
    16-way axis), so the expert hidden dim is sharded instead and the
    expert axis stays replicated.

Top-k routing with per-(group, expert) capacity C = ceil(Sg*k*cf/E); tokens
over capacity are dropped (standard GShard semantics). Router logits in
fp32; top-k probabilities renormalized.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import augment
from repro.models import layers as L
from repro.models.params import PSpec


def moe_pspecs(cfg: ModelConfig, n: int) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    ea = "experts"
    # EP: experts ride the model axis -> the hidden dim must not also claim
    # it. TP: experts replicated (via Rules sizes), hidden dim rides it.
    fa = "mlp" if cfg.moe.sharding == "tp" else None
    p = {"norm": PSpec((n, d), (None, None), init="zeros"),
         "router": PSpec((n, d, E), (None, "embed", None)),
         "w_up": PSpec((n, E, d, f), (None, ea, "embed", fa)),
         "w_down": PSpec((n, E, f, d), (None, ea, fa, "embed"))}
    if cfg.act == "swiglu":
        p["w_gate"] = PSpec((n, E, d, f), (None, ea, "embed", fa))
    return p


def _best_axes(n: int, mesh, preferred):
    """Largest prefix of `preferred` mesh axes whose product divides n."""
    if mesh is None:
        return None
    axes = [a for a in preferred if a in mesh.shape]
    while axes:
        t = 1
        for a in axes:
            t *= mesh.shape[a]
        if n % t == 0:
            return tuple(axes)
        axes.pop()
    return None


def moe_ffn(cfg: ModelConfig, p: dict, x: jax.Array, rules=None,
            group_size: int = 512) -> jax.Array:
    """x: (B, S, d) pre-normed -> (B, S, d)."""
    moe = cfg.moe
    B, S, d = x.shape
    E, k, cf = moe.n_experts, moe.top_k, moe.capacity_factor
    T = B * S
    sg = min(group_size, T)
    G = T // sg
    assert T % sg == 0, (T, sg)
    C = max(1, math.ceil(sg * k * cf / E))

    xs = x.reshape(G, sg, d)
    mesh = rules.mesh if rules is not None else None
    # Groups ride ("pod","data") ONLY: with experts on the model axis, the
    # dispatch einsum then needs no model-axis resharding of the (G,Sg,E,C)
    # dispatch tensor (it becomes a local slice) and the combine reduces
    # over local experts with a single all-reduce — the canonical GShard
    # pattern. Including "model" here all-gathers disp/comb per layer
    # (measured 2.6 TiB/device/step on qwen3 train_4k; SSPerf cell A).
    g_axes = _best_axes(G, mesh, ("pod", "data"))
    if mesh is not None:
        xs = jax.lax.with_sharding_constraint(
            xs, jax.sharding.NamedSharding(mesh, P(g_axes, None, None)))

    # --- routing (fp32) ---
    # NOTE (SSPerf cell A, iteration 3 — REFUTED): pinning the routing
    # tensors (logits/mask/disp/comb) to group sharding doubled collective
    # bytes (6.1s -> 11.8s); the partitioner's own intermediate layouts win.
    logits = xs.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                 # (G,Sg,E)
    top_p, top_i = jax.lax.top_k(probs, k)                  # (G,Sg,k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    sel = jax.nn.one_hot(top_i, E, dtype=jnp.float32)       # (G,Sg,k,E)
    mask = sel.sum(axis=2)                                  # (G,Sg,E) 0/1
    gates = (sel * top_p[..., None]).sum(axis=2)            # (G,Sg,E)

    # position-in-expert within each group; drop tokens over capacity
    pos = jnp.cumsum(mask, axis=1) - 1.0                    # (G,Sg,E)
    keep = (pos < C) * mask
    disp = jax.nn.one_hot(pos.astype(jnp.int32), C,
                          dtype=jnp.bfloat16) * keep[..., None]  # (G,Sg,E,C)
    comb = disp * gates[..., None].astype(jnp.bfloat16)

    # --- dispatch: group-sharded tokens -> expert-sharded slots (a2a) ---
    xe = jnp.einsum("gsec,gsd->egcd", disp, xs)             # (E,G,C,d)
    e_ax = rules.resolve("experts") if rules is not None else None
    g2 = _best_axes(G, mesh, ("pod", "data")) if mesh is not None else None

    def cst(t, spec):
        if mesh is None:
            return t
        return jax.lax.with_sharding_constraint(
            t, jax.sharding.NamedSharding(mesh, spec))

    # In EP mode experts ride the model axis; in TP mode (experts
    # indivisible) the expert hidden dim rides it instead.
    f_ax = None if e_ax is not None else (rules.resolve("mlp")
                                          if rules is not None else None)
    xe = cst(xe, P(e_ax, g2, None, None))

    # --- expert FFN (batched over E; banks may be ternary-packed —
    # augment.expert_proj consumes them as stored, per expert) ---
    if cfg.act == "swiglu":
        h = jax.nn.silu(augment.expert_proj(p, "w_gate", xe, cfg.amc))
        h = h * augment.expert_proj(p, "w_up", xe, cfg.amc)
    else:
        h = jax.nn.gelu(augment.expert_proj(p, "w_up", xe, cfg.amc),
                        approximate=True)
    h = cst(h, P(e_ax, g2, None, f_ax))
    ye = augment.expert_proj(p, "w_down", h, cfg.amc)       # (E,G,C,d)
    ye = cst(ye, P(e_ax, g2, None, None))

    # --- combine: expert-sharded slots -> group-sharded tokens (a2a) ---
    out = jnp.einsum("egcd,gsec->gsd", ye, comb)
    out = cst(out, P(g_axes, None, None))
    return out.reshape(B, S, d).astype(x.dtype)
