"""Mamba-2 (SSD — state-space duality) blocks, attention-free.

Chunked dual-form SSD following Dao & Gu 2024: quadratic attention-like
compute within chunks, linear state recurrence across chunks (lax.scan).
Projections are kept separate (z/x/B/C/dt) rather than fused so each output
dim can carry its own sharding axis (the fused dim 2*din+2GN+H doesn't
divide a 16-way axis). Gates on dt are per-head; conv is causal depthwise
width-4 implemented as shifted adds.

AMC note (DESIGN.md SS5/SS9): weights take ternary/dual-plane augmented
storage; there is NO KV cache (the paper's packed-KV plane is
inapplicable). The recurrent state (`abstract_cache`: ssd_state f32 +
conv_state) accumulates, so it defaults to high precision — but in
serving it is a fixed-size slab the unified store
(`serve/state_store.AugmentedStatePool`) can hold as Augmented dynamic
data (packed int8/int4, quantize-on-write / dequantize-on-read every
decode step, RefreshPolicy-restamped) when the pool-mode policy opts
into the capacity.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.params import PSpec


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    din = s.expand * cfg.d_model
    H = din // s.head_dim
    return din, H, s.head_dim, s.n_groups, s.state_dim, s.conv_dim


def abstract_params(cfg: ModelConfig) -> dict:
    n, d, V = cfg.n_layers, cfg.d_model, cfg.vocab_padded
    din, H, P_, G, N, K = _dims(cfg)
    layer = {
        "norm": PSpec((n, d), (None, None), init="zeros"),
        "z_proj": PSpec((n, d, din), (None, "embed", "lru")),
        "x_proj": PSpec((n, d, din), (None, "embed", "lru")),
        "b_proj": PSpec((n, d, G * N), (None, "embed", None)),
        "c_proj": PSpec((n, d, G * N), (None, "embed", None)),
        "dt_proj": PSpec((n, d, H), (None, "embed", None)),
        "conv_x": PSpec((n, K, din), (None, None, "lru")),
        "conv_b": PSpec((n, K, G * N), (None, None, None)),
        "conv_c": PSpec((n, K, G * N), (None, None, None)),
        "a_log": PSpec((n, H), (None, None), init="zeros"),
        "d_skip": PSpec((n, H), (None, None), init="ones"),
        "dt_bias": PSpec((n, H), (None, None), init="zeros"),
        "gate_norm": PSpec((n, din), (None, "lru"), init="zeros"),
        "out_proj": PSpec((n, din, d), (None, "lru", "embed")),
    }
    params = {
        "embed": PSpec((V, d), ("vocab", "embed")),
        "final_norm": PSpec((d,), (None,), init="zeros"),
        "layers": layer,
    }
    if not cfg.tie_embeddings:
        params["head"] = PSpec((d, V), ("embed", "vocab"))
    return params


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (B,S,C), w: (K,C) depthwise causal conv via shifted adds."""
    K = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(K):
        shift = K - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]]
        out = out + xi * w[i]
    return out


def _segsum_decay(a_cum: jax.Array) -> jax.Array:
    """a_cum (..., L) -> lower-tri decay matrix exp(a_cum[t]-a_cum[s]) t>=s."""
    Lm = a_cum[..., :, None] - a_cum[..., None, :]
    Ln = a_cum.shape[-1]
    tri = jnp.tril(jnp.ones((Ln, Ln), bool))
    return jnp.where(tri, jnp.exp(Lm), 0.0)


def ssd_chunked(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
                chunk: int, h0=None):
    """SSD scan. x:(B,S,H,P) (dt-weighted), a:(B,S,H) log-decay,
    b,c:(B,S,H,N) (already head-expanded). Returns y:(B,S,H,P), h_final.
    Recurrence: h_t = e^{a_t} h_{t-1} + b_t (x) x_t ; y_t = c_t . h_t."""
    B, S, H, P_ = x.shape
    N = b.shape[-1]
    nc = S // chunk
    assert S % chunk == 0, (S, chunk)
    r = lambda t: t.reshape((B, nc, chunk) + t.shape[2:]).swapaxes(0, 1)
    xr, ar, br, cr = r(x), r(a), r(b), r(c)          # (nc, B, L, ...)
    a_cum = jnp.cumsum(ar.astype(jnp.float32), axis=2)  # (nc,B,L,H)

    if h0 is None:
        h0 = jnp.zeros((B, H, N, P_), jnp.float32)

    def chunk_step(h, inp):
        xc, ac_cum, bc, cc = inp                     # (B,L,...), fp32 decays
        # intra-chunk (dual quadratic form)
        decay = _segsum_decay(ac_cum.swapaxes(1, 2))  # (B,H,L,L)
        scores = jnp.einsum("blhn,bshn->bhls", cc, bc,
                            preferred_element_type=jnp.float32) * decay
        y = jnp.einsum("bhls,bshp->blhp", scores.astype(xc.dtype), xc)
        # inter-chunk contribution from the carried state
        in_decay = jnp.exp(ac_cum)                   # (B,L,H)
        y = y + jnp.einsum("blhn,bhnp,blh->blhp", cc.astype(jnp.float32), h,
                           in_decay).astype(y.dtype)
        # state update
        out_decay = jnp.exp(ac_cum[:, -1:, :] - ac_cum)  # (B,L,H)
        states = jnp.einsum("blhn,blh,blhp->bhnp", bc.astype(jnp.float32),
                            out_decay, xc.astype(jnp.float32))
        h = jnp.exp(ac_cum[:, -1])[:, :, None, None] * h + states
        return h, y

    h, ys = jax.lax.scan(chunk_step, h0, (xr, a_cum, br, cr))
    y = ys.swapaxes(0, 1).reshape(B, S, H, P_)
    return y, h


def _head_expand(t: jax.Array, H: int) -> jax.Array:
    """(B,S,G,N) -> (B,S,H,N) by repeating groups."""
    G = t.shape[2]
    return jnp.repeat(t, H // G, axis=2)


def block(cfg: ModelConfig, p: dict, x: jax.Array, h0=None, conv0=None,
          return_state=False):
    """One mamba2 block over a full sequence. x: (B,S,d).

    With return_state=True also returns (ssd_state, conv_state) so a
    prefill can hand off to O(1) decode.
    """
    din, H, P_, G, N, K = _dims(cfg)
    B, S, d = x.shape
    hN = L.rms_norm(x, p["norm"], cfg.norm_eps)
    z = hN @ p["z_proj"]
    xi = hN @ p["x_proj"]
    bi = hN @ p["b_proj"]
    ci = hN @ p["c_proj"]
    conv_tail = jnp.concatenate([xi, bi, ci], -1)[:, S - (K - 1):]
    dt = jax.nn.softplus((hN @ p["dt_proj"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,S,H)
    xi = jax.nn.silu(_causal_conv(xi, p["conv_x"]))
    bi = jax.nn.silu(_causal_conv(bi, p["conv_b"]))
    ci = jax.nn.silu(_causal_conv(ci, p["conv_c"]))
    xh = xi.reshape(B, S, H, P_)
    bh = _head_expand(bi.reshape(B, S, G, N), H)
    ch = _head_expand(ci.reshape(B, S, G, N), H)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))              # (H,)
    a = dt * A                                                 # (B,S,H)
    xw = (xh * dt[..., None]).astype(xh.dtype)
    y, h_fin = ssd_chunked(xw, a, bh, ch, cfg.ssm.chunk, h0)
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(B, S, din)
    y = L.rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = (y @ p["out_proj"]).astype(x.dtype)
    if return_state:
        return out, h_fin, conv_tail
    return out


def forward(cfg: ModelConfig, params: dict, tokens: jax.Array, *,
            rules=None, return_cache=False, remat_policy="dots",
            q_chunk=None):
    from repro.distributed.sharding import constrain
    B, S = tokens.shape
    x = L.embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)
    x = constrain(x, rules, "batch", "seq_sp", None)

    def body(x, lp):
        x = constrain(x, rules, "batch", "seq_sp", None)
        if return_cache:
            out, h_fin, conv_tail = block(cfg, lp, x, return_state=True)
            return constrain(x + out, rules, "batch", "seq_sp", None), (h_fin, conv_tail)
        return constrain(x + block(cfg, lp, x), rules, "batch", "seq_sp",
                         None), None

    from repro.models.transformer import _remat
    x, states = jax.lax.scan(_remat(body, remat_policy), x, params["layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    logits = L.lm_head(x, head, cfg.vocab)
    if return_cache:
        h_fin, conv_tail = states
        return logits, {"ssd_state": h_fin, "conv_state": conv_tail}
    return logits


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                tokens: jax.Array, positions: jax.Array, *, rules=None):
    """O(1) decode: state update, no KV cache (attention-free)."""
    din, H, P_, G, N, K = _dims(cfg)
    B = tokens.shape[0]
    x = L.embed_lookup(params["embed"], tokens[:, 0]).astype(jnp.bfloat16)

    def body(x, scanned):
        lp, h, conv_s = scanned                     # h:(B,H,N,P) conv:(B,K-1,C)
        hN = L.rms_norm(x, lp["norm"], cfg.norm_eps)
        z = hN @ lp["z_proj"]
        xi = hN @ lp["x_proj"]
        bi = hN @ lp["b_proj"]
        ci = hN @ lp["c_proj"]
        dt = jax.nn.softplus((hN @ lp["dt_proj"]).astype(jnp.float32)
                             + lp["dt_bias"].astype(jnp.float32))   # (B,H)
        # conv over ring state
        full = jnp.concatenate([conv_s,
                                jnp.concatenate([xi, bi, ci], -1)[:, None]], 1)
        w = jnp.concatenate([lp["conv_x"], lp["conv_b"], lp["conv_c"]], -1)
        conv_out = jnp.einsum("bkc,kc->bc", full, w)
        new_conv = full[:, 1:]
        xi = jax.nn.silu(conv_out[:, :din])
        bi = jax.nn.silu(conv_out[:, din:din + G * N])
        ci = jax.nn.silu(conv_out[:, din + G * N:])
        xh = xi.reshape(B, H, P_)
        bh = jnp.repeat(bi.reshape(B, G, N), H // G, axis=1)
        ch = jnp.repeat(ci.reshape(B, G, N), H // G, axis=1)
        A = -jnp.exp(lp["a_log"].astype(jnp.float32))
        a = jnp.exp(dt * A)                                          # (B,H)
        upd = jnp.einsum("bhn,bhp->bhnp", bh.astype(jnp.float32),
                         (xh * dt[..., None]).astype(jnp.float32))
        h = a[:, :, None, None] * h + upd
        y = jnp.einsum("bhn,bhnp->bhp", ch.astype(jnp.float32), h)
        y = y + lp["d_skip"].astype(jnp.float32)[None, :, None] * xh
        y = y.reshape(B, din)
        y = L.rms_norm(y * jax.nn.silu(z), lp["gate_norm"], cfg.norm_eps)
        out = (y @ lp["out_proj"]).astype(x.dtype)
        return x + out, (h, new_conv)

    x, (hs, convs) = jax.lax.scan(
        body, x, (params["layers"], cache["ssd_state"], cache["conv_state"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    logits = L.lm_head(x[:, None], head, cfg.vocab)
    return logits, {"ssd_state": hs, "conv_state": convs}


def abstract_cache(cfg: ModelConfig, batch: int, seq: int) -> dict:
    din, H, P_, G, N, K = _dims(cfg)
    n = cfg.n_layers
    return {
        "ssd_state": PSpec((n, batch, H, N, P_),
                           (None, "cache_batch", None, None, None),
                           dtype="f32", init="zeros"),
        "conv_state": PSpec((n, batch, K - 1, din + 2 * G * N),
                            (None, "cache_batch", None, "lru"), init="zeros"),
    }
