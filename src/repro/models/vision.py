"""Llama-3.2-Vision backbone: 8 macro-blocks of (4 self-attn + 1 gated
cross-attn) = 40 layers. The vision tower is a STUB per the assignment:
`input_specs` provides projected patch embeddings (B, n_patches, vision_dim).

AMC note: patch-embedding cross KV is computed once per image at prefill
(static plane); decoder self KV streams (dynamic plane) — FILO holds.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.params import PSpec


N_SELF_PER_BLOCK = 4


def _n_blocks(cfg: ModelConfig) -> int:
    return cfg.n_layers // (N_SELF_PER_BLOCK + 1)


def abstract_params(cfg: ModelConfig) -> dict:
    v = cfg.vision
    nb = _n_blocks(cfg)
    d, V = cfg.d_model, cfg.vocab_padded
    # self layers: (nb, 4, ...) — scan over nb, inner scan over 4
    self_p = {k: PSpec((nb,) + s.shape, (None,) + s.axes, s.dtype, s.init)
              for k, s in {**T.attn_pspecs(cfg, N_SELF_PER_BLOCK)}.items()}
    self_m = {k: PSpec((nb,) + s.shape, (None,) + s.axes, s.dtype, s.init)
              for k, s in T.mlp_pspecs(cfg, N_SELF_PER_BLOCK).items()}
    cross = T.attn_pspecs(cfg, nb)
    cross["gate_attn"] = PSpec((nb,), (None,), init="zeros")
    cross["gate_ffn"] = PSpec((nb,), (None,), init="zeros")
    cross_m = T.mlp_pspecs(cfg, nb)
    return {
        "embed": PSpec((V, d), ("vocab", "embed")),
        "patch_proj": PSpec((v.vision_dim, d), (None, "embed")),
        "final_norm": PSpec((d,), (None,), init="zeros"),
        "blocks": {"self_attn": self_p, "self_mlp": self_m,
                   "cross": cross, "cross_mlp": cross_m},
        "head": PSpec((d, V), ("embed", "vocab")),
    }


def _patch_kv(cfg: ModelConfig, p: dict, patches: jax.Array):
    B, Np, _ = patches.shape
    KV, hd = cfg.n_kv_heads, cfg.hd
    h = patches
    return ((h @ p["wk"]).reshape(B, Np, KV, hd),
            (h @ p["wv"]).reshape(B, Np, KV, hd))


def _cross_attn(cfg, p, x, pk, pv):
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.hd
    h = L.rms_norm(x, p["norm"], cfg.norm_eps)
    q = (h @ p["wq"]).reshape(B, S, H, hd)
    o = L.attention(q, pk, pv, causal=False, q_chunk=1024 if S % 1024 == 0 else S)
    a = (o.reshape(B, S, -1) @ p["wo"]).astype(x.dtype)
    return jnp.tanh(p["gate_attn"]).astype(x.dtype) * a


def forward(cfg: ModelConfig, params: dict, tokens: jax.Array,
            patches: jax.Array, *, rules=None, return_cache=False,
            remat_policy="dots", q_chunk=1024):
    from repro.distributed.sharding import constrain
    B, S = tokens.shape
    x = L.embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)
    x = constrain(x, rules, "batch", "seq_sp", None)
    px = (patches @ params["patch_proj"]).astype(jnp.bfloat16)
    px = constrain(px, rules, "batch", None, None)
    positions = jnp.arange(S)

    def self_body(x, lp):
        x = constrain(x, rules, "batch", "seq_sp", None)
        a, kv = T.attn_block(cfg, lp["attn"], x, positions, q_chunk=q_chunk)
        x = constrain(x + a, rules, "batch", "seq_sp", None)
        x = x + T.mlp_block(cfg, lp["mlp"], x)
        return x, (kv if return_cache else None)

    def block_body(x, bp):
        x, kvs = jax.lax.scan(
            T._remat(self_body, remat_policy), x,
            {"attn": bp["self_attn"], "mlp": bp["self_mlp"]})
        pk, pv = _patch_kv(cfg, bp["cross"], px)
        x = constrain(x, rules, "batch", "seq_sp", None)
        x = x + _cross_attn(cfg, bp["cross"], x, pk, pv)
        g = jnp.tanh(bp["cross"]["gate_ffn"]).astype(x.dtype)
        x = x + g * T.mlp_block(cfg, bp["cross_mlp"], x)
        return constrain(x, rules, "batch", "seq_sp", None), (kvs, (pk, pv) if return_cache else None)

    # remat at the MACRO-block level too: without it the 8-block scan saves
    # the cross-attention probabilities (B,KV,Hg,S,1601) for backward —
    # measured 12.5 GiB f32 per device at train_4k
    x, caches = jax.lax.scan(T._remat(block_body, remat_policy), x,
                             params["blocks"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_head(x, params["head"], cfg.vocab)
    if return_cache:
        kvs, crosskv = caches
        k, v = kvs  # (nb, 4, B, S, KV, hd) -> (nb*4, ...)
        k = k.reshape((-1,) + k.shape[2:])
        v = v.reshape((-1,) + v.shape[2:])
        cache = T._pack_prefill_cache(cfg, (k, v))
        cache["patch_k"], cache["patch_v"] = crosskv
        return logits, cache
    return logits


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                tokens: jax.Array, positions: jax.Array, *, rules=None):
    nb = _n_blocks(cfg)
    x = L.embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)
    cache = dict(cache)
    pk, pv = cache.pop("patch_k"), cache.pop("patch_v")
    selfc = {k: v.reshape((nb, N_SELF_PER_BLOCK) + v.shape[1:])
             for k, v in cache.items()}

    def self_body(x, scanned):
        lp, cl = scanned
        a, nc = T.attn_block_decode(cfg, lp["attn"], x, cl, positions)
        x = x + a
        x = x + T.mlp_block(cfg, lp["mlp"], x)
        return x, nc

    def block_body(x, scanned):
        bp, bc, bpk, bpv = scanned
        x, ncs = jax.lax.scan(self_body, x,
                              ({"attn": bp["self_attn"],
                                "mlp": bp["self_mlp"]}, bc))
        x = x + _cross_attn(cfg, bp["cross"], x, bpk, bpv)
        g = jnp.tanh(bp["cross"]["gate_ffn"]).astype(x.dtype)
        x = x + g * T.mlp_block(cfg, bp["cross_mlp"], x)
        return x, ncs

    x, new_selfc = jax.lax.scan(block_body, x,
                                (params["blocks"], selfc, pk, pv))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_head(x, params["head"], cfg.vocab)
    new_cache = {k: v.reshape((-1,) + v.shape[2:]) for k, v in new_selfc.items()}
    new_cache["patch_k"], new_cache["patch_v"] = pk, pv
    return logits, new_cache


def abstract_cache(cfg: ModelConfig, batch: int, seq: int) -> dict:
    v = cfg.vision
    nb = _n_blocks(cfg)
    KV, hd = cfg.n_kv_heads, cfg.hd
    import dataclasses as dc
    flat = dc.replace(cfg, n_layers=nb * N_SELF_PER_BLOCK)
    c = T.abstract_cache(flat, batch, seq)
    ax = (None, "cache_batch", "frames", "kv_heads", None)
    c["patch_k"] = PSpec((nb, batch, v.n_patches, KV, hd), ax)
    c["patch_v"] = PSpec((nb, batch, v.n_patches, KV, hd), ax)
    return c


def prefix_state_specs(cfg: ModelConfig, batch: int) -> dict:
    """The STATIC per-row decode state (patch-embedding cross KV, computed
    once per image at prefill) — the slab the serving engine stores in an
    `AugmentedStatePool` against the same byte budget as the KV pages."""
    v = cfg.vision
    nb = _n_blocks(cfg)
    KV, hd = cfg.n_kv_heads, cfg.hd
    ax = (None, "cache_batch", "frames", "kv_heads", None)
    return {"patch_k": PSpec((nb, batch, v.n_patches, KV, hd), ax),
            "patch_v": PSpec((nb, batch, v.n_patches, KV, hd), ax)}


def paged_decode_step(cfg: ModelConfig, params: dict, arenas: dict,
                      tokens: jax.Array, positions: jax.Array, meta: dict,
                      *, rules=None):
    """One decode step against the paged pool: the nb*4 self-attention
    layers walk the decode band (arena leaves carry the flat layer dim,
    reshaped to (nb, 4, ...) for the macro-block scan); the gated
    cross-attention reads the dense patch KV the engine reconstitutes
    from its static prefix slab (``meta["patch_k"/"patch_v"]``)."""
    nb = _n_blocks(cfg)
    x = L.embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)
    pk, pv = meta["patch_k"], meta["patch_v"]
    ar = {k: v.reshape((nb, N_SELF_PER_BLOCK) + v.shape[1:])
          for k, v in arenas.items()}

    def self_body(x, scanned):
        lp, arena_layer = scanned
        a, new_arenas = T.attn_block_decode_paged(cfg, lp["attn"], x,
                                                  arena_layer, positions,
                                                  meta)
        x = x + a
        x = x + T.mlp_block(cfg, lp["mlp"], x)
        return x, new_arenas

    def block_body(x, scanned):
        bp, bar, bpk, bpv = scanned
        x, nar = jax.lax.scan(self_body, x,
                              ({"attn": bp["self_attn"],
                                "mlp": bp["self_mlp"]}, bar))
        x = x + _cross_attn(cfg, bp["cross"], x, bpk, bpv)
        g = jnp.tanh(bp["cross"]["gate_ffn"]).astype(x.dtype)
        x = x + g * T.mlp_block(cfg, bp["cross_mlp"], x)
        return x, nar

    x, new_ar = jax.lax.scan(block_body, x,
                             (params["blocks"], ar, pk, pv))
    new_arenas = {k: v.reshape((-1,) + v.shape[2:])
                  for k, v in new_ar.items()}
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_head(x, params["head"], cfg.vocab)
    return logits, new_arenas
