"""Augmented weight storage: the paper's 7T/8T cells applied to the model
parameters (the STATIC plane; the KV cache is the dynamic plane).

`augment_params` transforms a dense parameter tree so the hot path's matmul
weights live packed in HBM and are consumed packed by the Pallas kernels:

  weight_mode="ternary"  every attention/MLP matmul weight becomes 2-bit
                         packed trits (4 / byte) + a per-output-channel TWN
                         scale — the 7T cell's 8x capacity augmentation;
                         matmuls run through `K.ternary_matmul`.
  weight_mode="dual"     naturally-paired weights share ONE uint8 buffer,
                         two int4 planes (the 8T dual-bit cell): wk (static
                         nibble) + wv (dynamic nibble), and for swiglu MLPs
                         w_gate + w_up.  `K.dual_plane_matmul` reads each
                         byte once and issues two MXU dots.  Unpaired
                         weights (wq, wo, w_down) stay dense bf16.

`augment_pspecs` is the same transform on the declarative PSpec tree
(dry-run shapes + sharding); `dequant_params` inverts the packing into a
dense bf16 tree — the golden reference the kernel-backed forward is tested
against.  Packed contraction dims carry the replicated "packed" logical
axis (a 2-bit-packed dim cannot take the FSDP embed sharding); output dims
keep their original TP axes.

Applies to the transformer family (dense/MoE attention + dense MLP); MoE
expert banks and the other families keep dense weights for now.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import quant, ternary
from repro.kernels import ops as kops
from repro.models.params import PSpec

TERNARY_KEYS = ("wq", "wk", "wv", "wo", "w_up", "w_down", "w_gate")
DUAL_PAIRS = ((("wk", "wv"), "wkv_buf"), (("w_gate", "w_up"), "w_gate_up_buf"))


# ---------------------------------------------------------------------------
# Kernel application (2-D tiling over arbitrary leading dims)
# ---------------------------------------------------------------------------

def _as_rows(x: jax.Array, bm: int = 128):
    """(..., K) -> padded (M', K) bf16 rows + (lead, M, bm) restore info."""
    lead, K = x.shape[:-1], x.shape[-1]
    x2 = x.reshape(-1, K).astype(jnp.bfloat16)
    M = x2.shape[0]
    bm = min(bm, M)
    pad = (-M) % bm
    if pad:
        x2 = jnp.concatenate([x2, jnp.zeros((pad, K), x2.dtype)], axis=0)
    return x2, lead, M, bm


def ternary_apply(x: jax.Array, packed: jax.Array, scale: jax.Array):
    """x (..., K) @ unpack(packed (K//4, N)) * scale (1, N) -> (..., N).

    The weight stays 2 bits/value in HBM; `K.ternary_matmul` unpacks in
    VMEM registers on the way into the MXU."""
    x2, lead, M, bm = _as_rows(x)
    K, N = packed.shape[0] * 4, packed.shape[1]
    y = kops.ternary_matmul(x2, packed, scale, bm=bm,
                            bk=math.gcd(K, 512), bn=math.gcd(N, 256))
    return y[:M].reshape(*lead, N)


def dual_apply(x: jax.Array, buf: jax.Array, hi_scale: jax.Array,
               lo_scale: jax.Array):
    """x (..., K) @ BOTH int4 planes of buf (K, N): one byte stream read
    from HBM, two results — ((..., N), (..., N))."""
    x2, lead, M, bm = _as_rows(x)
    K, N = buf.shape
    y_hi, y_lo = kops.dual_plane_matmul(x2, buf, hi_scale, lo_scale, bm=bm,
                                        bk=math.gcd(K, 256),
                                        bn=math.gcd(N, 256))
    return y_hi[:M].reshape(*lead, N), y_lo[:M].reshape(*lead, N)


def proj(p: dict, name: str, x: jax.Array) -> jax.Array:
    """x @ p[name], dispatching to the ternary kernel when the weight is
    stored packed (`{name}_packed` / `{name}_scale`)."""
    if f"{name}_packed" in p:
        return ternary_apply(x, p[f"{name}_packed"], p[f"{name}_scale"])
    return x @ p[name]


def ternary_mlp(cfg: ModelConfig, p: dict, h: jax.Array) -> jax.Array:
    """MLP with all weights 2-bit packed (h is already normed)."""
    if cfg.act == "swiglu":
        mid = jax.nn.silu(proj(p, "w_gate", h)) * proj(p, "w_up", h)
    else:
        mid = jax.nn.gelu(proj(p, "w_up", h), approximate=True)
    return proj(p, "w_down", mid)


def dual_mlp(cfg: ModelConfig, p: dict, h: jax.Array) -> jax.Array:
    """swiglu MLP with w_gate + w_up sharing one dual-plane buffer."""
    gate, up = dual_apply(h, p["w_gate_up_buf"], p["w_gate_scale"],
                          p["w_up_scale"])
    return (jax.nn.silu(gate) * up) @ p["w_down"]


# ---------------------------------------------------------------------------
# Params transform (dense -> packed) and its PSpec / inverse views
# ---------------------------------------------------------------------------

def _ternary_pack(w: jax.Array):
    """(n, K, N) dense -> (packed (n, K//4, N) u8, scale (n, 1, N) f32)."""
    t, scale = ternary.ternarize(w.astype(jnp.float32), axis=1)
    return jax.vmap(ternary.pack_ternary_2bit)(t), scale


def _dual_pack(w_hi: jax.Array, w_lo: jax.Array):
    """Two (n, K, N) dense weights -> one (n, K, N) u8 buffer + scales."""
    qh, sh = quant.quantize_int4(w_hi.astype(jnp.float32), axis=1)
    ql, sl = quant.quantize_int4(w_lo.astype(jnp.float32), axis=1)
    return quant.pack_int4_pair(qh, ql), sh, sl


def is_augmented(params: dict) -> bool:
    attn = params.get("layers", {}).get("attn", {})
    return "wkv_buf" in attn or any(k.endswith("_packed") for k in attn)


def _transform(cfg: ModelConfig, params: dict, pack_tern, pack_dual) -> dict:
    mode = cfg.amc.weight_mode
    layers = dict(params["layers"])
    attn = dict(layers["attn"])
    mlp = dict(layers["mlp"]) if "mlp" in layers else None
    groups = [g for g in (attn, mlp) if g is not None]
    if mode == "ternary":
        for g in groups:
            for key in TERNARY_KEYS:
                if key in g:
                    g[f"{key}_packed"], g[f"{key}_scale"] = pack_tern(
                        g.pop(key))
    elif mode == "dual":
        for g in groups:
            for (hi, lo), buf_key in DUAL_PAIRS:
                if hi in g and lo in g:
                    (g[buf_key], g[f"{hi}_scale"],
                     g[f"{lo}_scale"]) = pack_dual(g.pop(hi), g.pop(lo))
    else:
        raise ValueError(f"unknown weight_mode {mode!r}")
    layers["attn"] = attn
    if mlp is not None:
        layers["mlp"] = mlp
    out = dict(params)
    out["layers"] = layers
    return out


def augment_params(cfg: ModelConfig, params: dict) -> dict:
    """Dense parameter tree -> augmented storage per cfg.amc.weight_mode.

    Idempotent (already-packed trees pass through); families other than
    the transformer keep dense weights."""
    if cfg.amc.weight_mode == "normal" or cfg.family not in ("dense", "moe"):
        return params
    if is_augmented(params):
        return params
    return _transform(cfg, params, _ternary_pack, _dual_pack)


def augment_pspecs(cfg: ModelConfig, pspecs: dict) -> dict:
    """The same transform on the PSpec tree (shapes/dtypes/sharding)."""
    if cfg.amc.weight_mode == "normal" or cfg.family not in ("dense", "moe"):
        return pspecs

    def pack_tern(spec: PSpec):
        n, K, N = spec.shape
        out_ax = spec.axes[2]
        return (PSpec((n, K // 4, N), (None, "packed", out_ax), dtype="u8"),
                PSpec((n, 1, N), (None, None, out_ax), dtype="f32",
                      init="ones"))

    def pack_dual(hi: PSpec, lo: PSpec):
        n, K, N = hi.shape
        assert hi.shape == lo.shape, (hi.shape, lo.shape)
        scale = PSpec((n, 1, N), (None, None, hi.axes[2]), dtype="f32",
                      init="ones")
        return (PSpec((n, K, N), hi.axes, dtype="u8"), scale, scale)

    return _transform(cfg, pspecs, pack_tern, pack_dual)


def dequant_params(cfg: ModelConfig, params: dict) -> dict:
    """Augmented tree -> dense bf16 tree (the golden test reference: what
    the packed weights represent, materialized)."""
    if not is_augmented(params):
        return params
    layers = dict(params["layers"])
    for group_key in ("attn", "mlp"):
        if group_key not in layers:
            continue
        g = dict(layers[group_key])
        for key in list(g):
            if key.endswith("_packed"):
                name = key[:-len("_packed")]
                packed, scale = g.pop(key), g.pop(f"{name}_scale")
                K = packed.shape[1] * 4
                t = jax.vmap(lambda p_: ternary.unpack_ternary_2bit(p_, K)
                             )(packed)
                g[name] = ternary.ternary_dequant(t, scale)
        for (hi, lo), buf_key in DUAL_PAIRS:
            if buf_key in g:
                buf = g.pop(buf_key)
                g[hi] = quant.dequantize(quant.unpack_int4_hi(buf),
                                         g.pop(f"{hi}_scale"))
                g[lo] = quant.dequantize(quant.unpack_int4_lo(buf),
                                         g.pop(f"{lo}_scale"))
        layers[group_key] = g
    out = dict(params)
    out["layers"] = layers
    return out
