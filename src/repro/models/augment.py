"""Augmented weight storage: the paper's 7T/8T cells applied to the model
parameters (the STATIC plane; the KV cache is the dynamic plane).

`augment_params` transforms a dense parameter tree so the hot path's matmul
weights live packed in HBM and are consumed packed by the Pallas kernels:

  weight_mode="ternary"  every attention/MLP matmul weight becomes 2-bit
                         packed trits (4 / byte) + a per-output-channel TWN
                         scale — the 7T cell's 8x capacity augmentation;
                         matmuls run through `K.ternary_matmul`.
  weight_mode="dual"     naturally-paired weights share ONE uint8 buffer,
                         two int4 planes (the 8T dual-bit cell): wk (static
                         nibble) + wv (dynamic nibble), and for swiglu MLPs
                         w_gate + w_up.  `K.dual_plane_matmul` reads each
                         byte once and issues two MXU dots.  Unpaired
                         weights (wq, wo, w_down) stay dense bf16.

`augment_pspecs` is the same transform on the declarative PSpec tree
(dry-run shapes + sharding); `dequant_params` inverts the packing into a
dense bf16 tree — the golden reference the kernel-backed forward is tested
against.  Packed contraction dims carry the replicated "packed" logical
axis (a 2-bit-packed dim cannot take the FSDP embed sharding); output dims
keep their original TP axes.

Applies to the transformer family: attention + dense-MLP matmuls, and in
ternary mode also the 4-D MoE expert banks (the dominant bytes of a MoE
checkpoint; consumed per expert via `expert_proj`). Dual mode pairs 3-D
weights only; the other families keep dense weights.

Consumption is routed by `cfg.amc.matmul_impl`: "packed" streams through
the Pallas matmul kernels, "imc" evaluates bit-serially in the array
(`kernels/imc_dot.py`, activation precision `cfg.amc.imc_abits`), and
"dense" takes the dequantize-then-XLA reference path.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import quant, ternary
from repro.kernels import ops as kops
from repro.models.params import PSpec

TERNARY_KEYS = ("wq", "wk", "wv", "wo", "w_up", "w_down", "w_gate")
DUAL_PAIRS = ((("wk", "wv"), "wkv_buf"), (("w_gate", "w_up"), "w_gate_up_buf"))


# ---------------------------------------------------------------------------
# Kernel application (2-D tiling over arbitrary leading dims)
# ---------------------------------------------------------------------------

def _as_rows(x: jax.Array, bm: int = 128):
    """(..., K) -> padded (M', K) bf16 rows + (lead, M, bm) restore info."""
    lead, K = x.shape[:-1], x.shape[-1]
    x2 = x.reshape(-1, K).astype(jnp.bfloat16)
    M = x2.shape[0]
    bm = min(bm, M)
    pad = (-M) % bm
    if pad:
        x2 = jnp.concatenate([x2, jnp.zeros((pad, K), x2.dtype)], axis=0)
    return x2, lead, M, bm


def _impl_of(amc) -> str:
    impl = "packed" if amc is None else amc.matmul_impl
    if impl not in ("dense", "packed", "imc"):
        raise ValueError(f"unknown matmul_impl {impl!r}")
    return impl


def ternary_apply(x: jax.Array, packed: jax.Array, scale: jax.Array,
                  amc=None):
    """x (..., K) @ unpack(packed (K//4, N)) * scale (1, N) -> (..., N).

    The weight stays 2 bits/value in HBM. `amc.matmul_impl` picks the
    consumer: "packed" unpacks in VMEM registers on the way into the MXU
    (`K.ternary_matmul`); "imc" evaluates in-array, wordline-serial at
    `amc.imc_abits` activation bits (`K.imc_dot`); "dense" is the
    dequantize-then-XLA reference."""
    impl = _impl_of(amc)
    x2, lead, M, bm = _as_rows(x)
    K, N = packed.shape[0] * 4, packed.shape[1]
    if impl == "imc":
        y = kops.imc_dot(x2, packed, scale, fmt="ternary",
                         abits=amc.imc_abits, bm=bm,
                         bk=math.gcd(K, 512), bn=math.gcd(N, 256))
    else:
        y = kops.ternary_matmul(x2, packed, scale, bm=bm,
                                bk=math.gcd(K, 512), bn=math.gcd(N, 256),
                                use_ref=impl == "dense")
    return y[:M].reshape(*lead, N)


def dual_apply(x: jax.Array, buf: jax.Array, hi_scale: jax.Array,
               lo_scale: jax.Array, amc=None):
    """x (..., K) @ BOTH int4 planes of buf (K, N): one byte stream read
    from HBM, two results — ((..., N), (..., N)). Under "imc" one
    wordline-serial activation stream drives both planes' bitlines."""
    impl = _impl_of(amc)
    x2, lead, M, bm = _as_rows(x)
    K, N = buf.shape
    if impl == "imc":
        y_hi, y_lo = kops.imc_dual_dot(x2, buf, hi_scale, lo_scale,
                                       abits=amc.imc_abits, bm=bm,
                                       bk=math.gcd(K, 256),
                                       bn=math.gcd(N, 256))
    else:
        y_hi, y_lo = kops.dual_plane_matmul(x2, buf, hi_scale, lo_scale,
                                            bm=bm, bk=math.gcd(K, 256),
                                            bn=math.gcd(N, 256),
                                            use_ref=impl == "dense")
    return y_hi[:M].reshape(*lead, N), y_lo[:M].reshape(*lead, N)


def proj(p: dict, name: str, x: jax.Array, amc=None) -> jax.Array:
    """x @ p[name], dispatching to the packed/IMC consumer when the weight
    is stored packed (`{name}_packed` / `{name}_scale`)."""
    if f"{name}_packed" in p:
        return ternary_apply(x, p[f"{name}_packed"], p[f"{name}_scale"],
                             amc=amc)
    return x @ p[name]


def expert_proj(p: dict, name: str, xe: jax.Array, amc=None) -> jax.Array:
    """Batched expert matmul xe (E, ..., K) @ p[name] (E, K, N), consuming
    ternary-packed expert banks per expert when present (the MoE form of
    `proj`; each expert's packed bank is one kernel call via lax.map)."""
    if f"{name}_packed" not in p:
        return jnp.einsum("e...k,ekn->e...n", xe, p[name])
    E, lead, K = xe.shape[0], xe.shape[1:-1], xe.shape[-1]
    N = p[f"{name}_packed"].shape[-1]
    x2 = xe.reshape(E, -1, K)

    def one(args):
        packed, scale, x = args
        return ternary_apply(x, packed, scale, amc=amc)

    y = jax.lax.map(one, (p[f"{name}_packed"], p[f"{name}_scale"], x2))
    return y.reshape(E, *lead, N)


def ternary_mlp(cfg: ModelConfig, p: dict, h: jax.Array) -> jax.Array:
    """MLP with all weights 2-bit packed (h is already normed)."""
    amc = cfg.amc
    if cfg.act == "swiglu":
        mid = jax.nn.silu(proj(p, "w_gate", h, amc)) * proj(p, "w_up", h, amc)
    else:
        mid = jax.nn.gelu(proj(p, "w_up", h, amc), approximate=True)
    return proj(p, "w_down", mid, amc)


def dual_mlp(cfg: ModelConfig, p: dict, h: jax.Array) -> jax.Array:
    """swiglu MLP with w_gate + w_up sharing one dual-plane buffer."""
    gate, up = dual_apply(h, p["w_gate_up_buf"], p["w_gate_scale"],
                          p["w_up_scale"], amc=cfg.amc)
    return (jax.nn.silu(gate) * up) @ p["w_down"]


# ---------------------------------------------------------------------------
# Params transform (dense -> packed) and its PSpec / inverse views
# ---------------------------------------------------------------------------

def _ternary_pack(w: jax.Array):
    """(..., K, N) dense -> (packed (..., K//4, N) u8, scale (..., 1, N)
    f32). Leading dims (layer stack, expert banks) are vmapped over."""
    t, scale = ternary.ternarize(w.astype(jnp.float32), axis=-2)
    pack = ternary.pack_ternary_2bit
    for _ in range(w.ndim - 2):
        pack = jax.vmap(pack)
    return pack(t), scale


def _ternary_unpack(packed: jax.Array) -> jax.Array:
    """Inverse of `_ternary_pack` (without the scale): (..., K//4, N) u8
    -> (..., K, N) int8 trits."""
    K = packed.shape[-2] * 4
    unpack = lambda p_: ternary.unpack_ternary_2bit(p_, K)  # noqa: E731
    for _ in range(packed.ndim - 2):
        unpack = jax.vmap(unpack)
    return unpack(packed)


def _dual_pack(w_hi: jax.Array, w_lo: jax.Array):
    """Two (n, K, N) dense weights -> one (n, K, N) u8 buffer + scales."""
    qh, sh = quant.quantize_int4(w_hi.astype(jnp.float32), axis=-2)
    ql, sl = quant.quantize_int4(w_lo.astype(jnp.float32), axis=-2)
    return quant.pack_int4_pair(qh, ql), sh, sl


def is_augmented(params: dict) -> bool:
    attn = params.get("layers", {}).get("attn", {})
    return "wkv_buf" in attn or any(k.endswith("_packed") for k in attn)


def _transform(cfg: ModelConfig, params: dict, pack_tern, pack_dual) -> dict:
    mode = cfg.amc.weight_mode
    layers = dict(params["layers"])
    attn = dict(layers["attn"])
    mlp = dict(layers["mlp"]) if "mlp" in layers else None
    moe = dict(layers["moe"]) if "moe" in layers else None
    if mode == "ternary":
        # ternary packs every matmul weight, including the 4-D MoE expert
        # banks (the dominant bytes of a MoE checkpoint — each expert's
        # (d, f) slab becomes 2-bit trits, consumed via expert_proj)
        for g in (g for g in (attn, mlp, moe) if g is not None):
            for key in TERNARY_KEYS:
                if key in g:
                    g[f"{key}_packed"], g[f"{key}_scale"] = pack_tern(
                        g.pop(key))
    elif mode == "dual":
        # dual pairs naturally-coupled 3-D weights; expert banks stay
        # dense (no per-expert pairing is defined for them)
        for g in (g for g in (attn, mlp) if g is not None):
            for (hi, lo), buf_key in DUAL_PAIRS:
                if hi in g and lo in g:
                    (g[buf_key], g[f"{hi}_scale"],
                     g[f"{lo}_scale"]) = pack_dual(g.pop(hi), g.pop(lo))
    else:
        raise ValueError(f"unknown weight_mode {mode!r}")
    layers["attn"] = attn
    if mlp is not None:
        layers["mlp"] = mlp
    if moe is not None:
        layers["moe"] = moe
    out = dict(params)
    out["layers"] = layers
    return out


def augment_params(cfg: ModelConfig, params: dict) -> dict:
    """Dense parameter tree -> augmented storage per cfg.amc.weight_mode.

    Idempotent (already-packed trees pass through); families other than
    the transformer keep dense weights."""
    if cfg.amc.weight_mode == "normal" or cfg.family not in ("dense", "moe"):
        return params
    if is_augmented(params):
        return params
    return _transform(cfg, params, _ternary_pack, _dual_pack)


def augment_pspecs(cfg: ModelConfig, pspecs: dict) -> dict:
    """The same transform on the PSpec tree (shapes/dtypes/sharding)."""
    if cfg.amc.weight_mode == "normal" or cfg.family not in ("dense", "moe"):
        return pspecs

    def pack_tern(spec: PSpec):
        *lead, K, N = spec.shape
        lead_ax, out_ax = spec.axes[:-2], spec.axes[-1]
        return (PSpec((*lead, K // 4, N), (*lead_ax, "packed", out_ax),
                      dtype="u8"),
                PSpec((*lead, 1, N), (*lead_ax, None, out_ax), dtype="f32",
                      init="ones"))

    def pack_dual(hi: PSpec, lo: PSpec):
        n, K, N = hi.shape
        assert hi.shape == lo.shape, (hi.shape, lo.shape)
        scale = PSpec((n, 1, N), (None, None, hi.axes[2]), dtype="f32",
                      init="ones")
        return (PSpec((n, K, N), hi.axes, dtype="u8"), scale, scale)

    return _transform(cfg, pspecs, pack_tern, pack_dual)


def dequant_params(cfg: ModelConfig, params: dict) -> dict:
    """Augmented tree -> dense bf16 tree (the golden test reference: what
    the packed weights represent, materialized)."""
    if not is_augmented(params):
        return params
    layers = dict(params["layers"])
    for group_key in ("attn", "mlp", "moe"):
        if group_key not in layers:
            continue
        g = dict(layers[group_key])
        for key in list(g):
            if key.endswith("_packed"):
                name = key[:-len("_packed")]
                packed, scale = g.pop(key), g.pop(f"{name}_scale")
                g[name] = ternary.ternary_dequant(_ternary_unpack(packed),
                                                  scale)
        for (hi, lo), buf_key in DUAL_PAIRS:
            if buf_key in g:
                buf = g.pop(buf_key)
                g[hi] = quant.dequantize(quant.unpack_int4_hi(buf),
                                         g.pop(f"{hi}_scale"))
                g[lo] = quant.dequantize(quant.unpack_int4_lo(buf),
                                         g.pop(f"{lo}_scale"))
        layers[group_key] = g
    out = dict(params)
    out["layers"] = layers
    return out
