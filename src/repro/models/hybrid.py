"""RecurrentGemma-style hybrid: RG-LRU recurrent blocks + local sliding-
window attention, pattern (rec, rec, attn) — 38 layers = 12 macro-blocks
of 3 + 2 trailing recurrent layers (DESIGN.md SS8).

RG-LRU (diagonal-gated variant, gates per channel from the branch input):
    r_t = sigmoid(w_r * x_t + b_r)            recurrence gate
    i_t = sigmoid(w_i * x_t + b_i)            input gate
    log a_t = -8 * softplus(lam) * r_t        per-channel decay
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
computed over the sequence with an associative scan (first-order linear
recurrence), O(S log S) depth — the sub-quadratic path that makes
long_500k decode feasible (O(1) per token, bounded state).

AMC note (DESIGN.md SS9): the decode state (`abstract_cache`) is a
FIXED-SIZE slab per row — LRU h (f32), conv tails, and the window ring
KV (packed per `kv_mode` by this module; those integer leaves pass
through the serving store unchanged). The unified store can hold a whole
slab as Augmented dynamic storage (int8/int4 via `amc.state_bits`) under
pressure, giving hybrid rows the same admit-more-by-augmenting behavior
as paged KV.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.params import PSpec


def _layout(cfg: ModelConfig):
    npat = len(cfg.hybrid.pattern)          # 3
    nb = cfg.n_layers // npat               # 12 macro-blocks
    tail = cfg.n_layers - nb * npat         # 2 trailing rec layers
    return nb, tail


def rec_pspecs(cfg: ModelConfig, n: int) -> dict:
    d, w = cfg.d_model, cfg.hybrid.lru_width
    return {
        "norm": PSpec((n, d), (None, None), init="zeros"),
        "proj_x": PSpec((n, d, w), (None, "embed", "lru")),
        "proj_gate": PSpec((n, d, w), (None, "embed", "lru")),
        "conv": PSpec((n, 4, w), (None, None, "lru")),
        "w_r": PSpec((n, w), (None, "lru"), init="zeros"),
        "b_r": PSpec((n, w), (None, "lru"), init="zeros"),
        "w_i": PSpec((n, w), (None, "lru"), init="zeros"),
        "b_i": PSpec((n, w), (None, "lru"), init="zeros"),
        "lam": PSpec((n, w), (None, "lru"), init="ones"),
        "out": PSpec((n, w, d), (None, "lru", "embed")),
    }


def abstract_params(cfg: ModelConfig) -> dict:
    nb, tail = _layout(cfg)
    d, V = cfg.d_model, cfg.vocab_padded
    blocks = {
        "rec_a": rec_pspecs(cfg, nb), "rec_a_mlp": T.mlp_pspecs(cfg, nb),
        "rec_b": rec_pspecs(cfg, nb), "rec_b_mlp": T.mlp_pspecs(cfg, nb),
        "attn": T.attn_pspecs(cfg, nb), "attn_mlp": T.mlp_pspecs(cfg, nb),
    }
    params = {
        "embed": PSpec((V, d), ("vocab", "embed")),
        "final_norm": PSpec((d,), (None,), init="zeros"),
        "blocks": blocks,
        "tail": {"rec": rec_pspecs(cfg, tail),
                 "mlp": T.mlp_pspecs(cfg, tail)},
    }
    if not cfg.tie_embeddings:
        params["head"] = PSpec((d, V), ("embed", "vocab"))
    return params


def _lru_gates(p, x):
    r = jax.nn.sigmoid(x.astype(jnp.float32) * p["w_r"] + p["b_r"])
    i = jax.nn.sigmoid(x.astype(jnp.float32) * p["w_i"] + p["b_i"])
    log_a = -8.0 * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * (i * x.astype(jnp.float32))
    return a, gated_in


def rec_block(cfg: ModelConfig, p: dict, x: jax.Array, h0=None,
              conv0=None, return_state: bool = False):
    """Full-sequence RG-LRU block. x: (B,S,d).

    With return_state=True also returns (h_final, conv_tail) for prefill ->
    decode handoff.
    """
    B, S, d = x.shape
    hN = L.rms_norm(x, p["norm"], cfg.norm_eps)
    xb = hN @ p["proj_x"]                         # (B,S,w)
    gate = jax.nn.gelu((hN @ p["proj_gate"]), approximate=True)
    # causal depthwise conv width 4 (shifted adds)
    conv = jnp.zeros_like(xb)
    for i in range(4):
        shift = 3 - i
        xi = jnp.pad(xb, ((0, 0), (shift, 0), (0, 0)))[:, :S]
        conv = conv + xi * p["conv"][i]
    a, gin = _lru_gates(p, conv)                  # (B,S,w) fp32
    # first-order linear recurrence via associative scan over S
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2
    if h0 is not None:
        gin = gin.at[:, 0].add(a[:, 0] * h0)
    aa, hh = jax.lax.associative_scan(combine, (a, gin), axis=1)
    y = (hh.astype(x.dtype) * gate) @ p["out"]
    if return_state:
        return y.astype(x.dtype), hh[:, -1], xb[:, S - 3:]
    return y.astype(x.dtype)


def _ring_from_full(k: jax.Array, W: int) -> jax.Array:
    """Full-seq keys (B,S,KV,hd) -> ring cache (B,W,KV,hd), slot = pos % W."""
    S = k.shape[1]
    if S <= W:
        pad = [(0, 0), (0, W - S)] + [(0, 0)] * (k.ndim - 2)
        return jnp.pad(k, pad)
    last = k[:, S - W:]
    slots = (jnp.arange(S - W, S) % W)
    return jnp.zeros((k.shape[0], W) + k.shape[2:], k.dtype).at[:, slots].set(last)


def rec_step(cfg: ModelConfig, p: dict, x: jax.Array, h: jax.Array,
             conv_s: jax.Array):
    """O(1) decode step. x: (B,d); h: (B,w) fp32; conv_s: (B,3,w)."""
    hN = L.rms_norm(x, p["norm"], cfg.norm_eps)
    xb = hN @ p["proj_x"]
    gate = jax.nn.gelu(hN @ p["proj_gate"], approximate=True)
    full = jnp.concatenate([conv_s, xb[:, None]], axis=1)  # (B,4,w)
    conv = jnp.einsum("bkw,kw->bw", full, p["conv"])
    new_conv = full[:, 1:]
    a, gin = _lru_gates(p, conv)
    h = a * h + gin
    y = (h.astype(x.dtype) * gate) @ p["out"]
    return y.astype(x.dtype), h, new_conv


def forward(cfg: ModelConfig, params: dict, tokens: jax.Array, *,
            rules=None, return_cache=False, remat_policy="dots",
            q_chunk=1024):
    from repro.distributed.sharding import constrain
    B, S = tokens.shape
    W = cfg.hybrid.window
    x = L.embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)
    x = constrain(x, rules, "batch", "seq_sp", None)
    positions = jnp.arange(S)

    def block_body(x, bp):
        x = constrain(x, rules, "batch", "seq_sp", None)
        st = {}
        if return_cache:
            y, ha, ca = rec_block(cfg, bp["rec_a"], x, return_state=True)
            st["h_a"], st["conv_a"] = ha, ca
        else:
            y = rec_block(cfg, bp["rec_a"], x)
        x = x + y
        x = x + T.mlp_block(cfg, bp["rec_a_mlp"], x)
        if return_cache:
            y, hb, cb = rec_block(cfg, bp["rec_b"], x, return_state=True)
            st["h_b"], st["conv_b"] = hb, cb
        else:
            y = rec_block(cfg, bp["rec_b"], x)
        x = x + y
        x = x + T.mlp_block(cfg, bp["rec_b_mlp"], x)
        x = constrain(x, rules, "batch", "seq_sp", None)
        a, kv = T.attn_block(cfg, bp["attn"], x, positions, window=W,
                             q_chunk=q_chunk)
        x = constrain(x + a, rules, "batch", "seq_sp", None)
        x = x + T.mlp_block(cfg, bp["attn_mlp"], x)
        if return_cache:
            k, v = kv
            Wc = min(W, k.shape[1])
            kvs = {"k": _ring_from_full(k, W), "v": _ring_from_full(v, W)}
            mode = cfg.amc.kv_mode
            if mode != "normal":
                # packed ring caches are head-major (B, KV, W, ·) — the
                # layout the packed decode-attention kernel streams
                pack = L.pack_kv_int4 if mode == "int4" else L.pack_kv_int8
                kvs["k"], kvs["k_scale"] = pack(L.to_kvmajor(kvs["k"]))
                kvs["v"], kvs["v_scale"] = pack(L.to_kvmajor(kvs["v"]))
            st.update(kvs)
        return x, (st if return_cache else None)

    def tail_body(x, tp):
        x = constrain(x, rules, "batch", "seq_sp", None)
        if return_cache:
            y, h, c = rec_block(cfg, tp["rec"], x, return_state=True)
            x = x + y
            x = x + T.mlp_block(cfg, tp["mlp"], x)
            return x, {"h": h, "conv": c}
        x = x + rec_block(cfg, tp["rec"], x)
        x = x + T.mlp_block(cfg, tp["mlp"], x)
        return x, None

    x, block_st = jax.lax.scan(T._remat(block_body, remat_policy), x,
                               params["blocks"])
    x, tail_st = jax.lax.scan(T._remat(tail_body, remat_policy), x,
                              params["tail"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    logits = L.lm_head(x, head, cfg.vocab)
    if return_cache:
        return logits, {"blocks": block_st, "tail": tail_st}
    return logits


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                tokens: jax.Array, positions: jax.Array, *, rules=None):
    nb, tail = _layout(cfg)
    W = cfg.hybrid.window
    x = L.embed_lookup(params["embed"], tokens[:, 0]).astype(jnp.bfloat16)

    def block_body(x, scanned):
        bp, st = scanned
        y, ha, ca = rec_step(cfg, bp["rec_a"], x, st["h_a"], st["conv_a"])
        x = x + y
        x = x + T.mlp_block(cfg, bp["rec_a_mlp"], x[:, None])[:, 0]
        y, hb, cb = rec_step(cfg, bp["rec_b"], x, st["h_b"], st["conv_b"])
        x = x + y
        x = x + T.mlp_block(cfg, bp["rec_b_mlp"], x[:, None])[:, 0]
        a, new_kv = T.attn_block_decode(
            cfg, bp["attn"], x[:, None],
            {k: st[k] for k in st if k.startswith(("k", "v"))},
            positions, window=W)
        x = x + a[:, 0]
        x = x + T.mlp_block(cfg, bp["attn_mlp"], x[:, None])[:, 0]
        new_st = dict(new_kv)
        new_st.update({"h_a": ha, "conv_a": ca, "h_b": hb, "conv_b": cb})
        return x, new_st

    def tail_body(x, scanned):
        tp, st = scanned
        y, h, c = rec_step(cfg, tp["rec"], x, st["h"], st["conv"])
        x = x + y
        x = x + T.mlp_block(cfg, tp["mlp"], x[:, None])[:, 0]
        return x, {"h": h, "conv": c}

    x, new_block_st = jax.lax.scan(block_body, x,
                                   (params["blocks"], cache["blocks"]))
    x, new_tail_st = jax.lax.scan(tail_body, x,
                                  (params["tail"], cache["tail"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    logits = L.lm_head(x[:, None], head, cfg.vocab)
    return logits, {"blocks": new_block_st, "tail": new_tail_st}


def abstract_cache(cfg: ModelConfig, batch: int, seq: int) -> dict:
    nb, tail = _layout(cfg)
    w = cfg.hybrid.lru_width
    W = cfg.hybrid.window
    KV, hd = cfg.n_kv_heads, cfg.hd
    mode = cfg.amc.kv_mode
    bax = "cache_batch"
    kv_ax = (None, bax, "cache_seq", "kv_heads", None)
    blocks = {
        "h_a": PSpec((nb, batch, w), (None, bax, "lru"), dtype="f32",
                     init="zeros"),
        "conv_a": PSpec((nb, batch, 3, w), (None, bax, None, "lru"),
                        init="zeros"),
        "h_b": PSpec((nb, batch, w), (None, bax, "lru"), dtype="f32",
                     init="zeros"),
        "conv_b": PSpec((nb, batch, 3, w), (None, bax, None, "lru"),
                        init="zeros"),
    }
    if mode == "normal":
        blocks["k"] = PSpec((nb, batch, W, KV, hd), kv_ax)
        blocks["v"] = PSpec((nb, batch, W, KV, hd), kv_ax)
    else:
        dt = "u8" if mode == "int4" else "i8"
        ds = hd // 2 if mode == "int4" else hd
        kvm_ax = (None, bax, "kv_heads", "cache_seq", None)
        blocks["k"] = PSpec((nb, batch, KV, W, ds), kvm_ax, dtype=dt)
        blocks["v"] = PSpec((nb, batch, KV, W, ds), kvm_ax, dtype=dt)
        blocks["k_scale"] = PSpec((nb, batch, KV, W, 1), kvm_ax)
        blocks["v_scale"] = PSpec((nb, batch, KV, W, 1), kvm_ax)
    tail_c = {
        "h": PSpec((tail, batch, w), (None, bax, "lru"), dtype="f32",
                   init="zeros"),
        "conv": PSpec((tail, batch, 3, w), (None, bax, None, "lru"),
                      init="zeros"),
    }
    return {"blocks": blocks, "tail": tail_c}
