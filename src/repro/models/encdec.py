"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: `input_specs` provides
precomputed frame embeddings (B, n_frames, frame_dim). Positions are
sinusoidal (deviation from whisper's learned decoder positions, recorded in
DESIGN.md) so parameters stay independent of sequence length.

AMC note: the cross-attention KV (computed once per utterance at prefill)
is the STATIC plane; the decoder self-attention KV is the DYNAMIC plane —
the cleanest FILO instance in the model zoo (paper SS.II-B).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.params import PSpec


def abstract_params(cfg: ModelConfig) -> dict:
    e = cfg.encdec
    n, ne, d, V = cfg.n_layers, e.n_encoder_layers, cfg.d_model, cfg.vocab_padded
    return {
        "embed": PSpec((V, d), ("vocab", "embed")),
        "final_norm": PSpec((d,), (None,), init="zeros"),
        "enc_final_norm": PSpec((d,), (None,), init="zeros"),
        "frame_proj": PSpec((e.frame_dim, d), (None, "embed")),
        "encoder": {"attn": T.attn_pspecs(cfg, ne),
                    "mlp": T.mlp_pspecs(cfg, ne)},
        "layers": {"attn": T.attn_pspecs(cfg, n),
                   "cross": T.attn_pspecs(cfg, n),
                   "mlp": T.mlp_pspecs(cfg, n)},
        "head": PSpec((d, V), ("embed", "vocab")),
    }


def encode(cfg: ModelConfig, params: dict, frames: jax.Array,
           rules=None) -> jax.Array:
    """frames (B, F, frame_dim) -> encoder states (B, F, d)."""
    from repro.distributed.sharding import constrain
    B, F, _ = frames.shape
    x = (frames @ params["frame_proj"]).astype(jnp.bfloat16)
    x = constrain(x, rules, "batch", None, None)
    x = x + L.sinusoidal_positions(jnp.arange(F), cfg.d_model)[None].astype(x.dtype)
    positions = jnp.arange(F)

    def body(x, lp):
        x = constrain(x, rules, "batch", None, None)
        a, _ = T.attn_block(cfg, lp["attn"], x, positions, causal=False,
                            q_chunk=min(F, 1024) if F % 1024 == 0 or F < 1024 else F)
        x = x + a
        x = x + T.mlp_block(cfg, lp["mlp"], x)
        return constrain(x, rules, "batch", None, None), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["encoder"])
    return L.rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


def cross_block(cfg: ModelConfig, p: dict, x: jax.Array,
                enc_k: jax.Array, enc_v: jax.Array) -> jax.Array:
    """Cross-attention with precomputed encoder K/V (the static plane)."""
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.hd
    h = L.rms_norm(x, p["norm"], cfg.norm_eps)
    q = (h @ p["wq"]).reshape(B, S, H, hd)
    o = L.attention(q, enc_k, enc_v, causal=False,
                    q_chunk=1024 if S % 1024 == 0 else S)
    return (o.reshape(B, S, -1) @ p["wo"]).astype(x.dtype)


def _enc_kv(cfg: ModelConfig, p: dict, enc: jax.Array):
    B, F, _ = enc.shape
    KV, hd = cfg.n_kv_heads, cfg.hd
    return ((enc @ p["wk"]).reshape(B, F, KV, hd),
            (enc @ p["wv"]).reshape(B, F, KV, hd))


def forward(cfg: ModelConfig, params: dict, tokens: jax.Array,
            frames: jax.Array, *, rules=None, return_cache=False,
            remat_policy="dots", q_chunk=1024):
    """Teacher-forced decoder over encoder states. Returns logits [,cache]."""
    from repro.distributed.sharding import constrain
    enc = encode(cfg, params, frames, rules)
    B, S = tokens.shape
    x = L.embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)
    x = x + L.sinusoidal_positions(jnp.arange(S), cfg.d_model)[None].astype(x.dtype)
    x = constrain(x, rules, "batch", "seq_sp", None)
    positions = jnp.arange(S)

    def body(x, lp):
        x = constrain(x, rules, "batch", "seq_sp", None)
        a, kv = T.attn_block(cfg, lp["attn"], x, positions, q_chunk=q_chunk)
        x = constrain(x + a, rules, "batch", "seq_sp", None)
        ek, ev = _enc_kv(cfg, lp["cross"], enc)
        x = x + cross_block(cfg, lp["cross"], x, ek, ev)
        x = x + T.mlp_block(cfg, lp["mlp"], x)
        return constrain(x, rules, "batch", "seq_sp", None), ((kv, (ek, ev)) if return_cache else None)

    x, kvs = jax.lax.scan(T._remat(body, remat_policy), x, params["layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_head(x, params["head"], cfg.vocab)
    if return_cache:
        (selfkv, crosskv) = kvs
        cache = T._pack_prefill_cache(cfg, selfkv)
        cache["cross_k"], cache["cross_v"] = crosskv  # static plane: bf16
        return logits, cache
    return logits


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                tokens: jax.Array, positions: jax.Array, *, rules=None):
    x = L.embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)
    x = x + L.sinusoidal_positions(positions.astype(jnp.float32),
                                   cfg.d_model)[:, None].astype(x.dtype)
    cache = dict(cache)
    cross_k, cross_v = cache.pop("cross_k"), cache.pop("cross_v")

    def body(x, scanned):
        lp, cl, ck, cv = scanned
        a, new_cache = T.attn_block_decode(cfg, lp["attn"], x, cl, positions)
        x = x + a
        x = x + cross_block(cfg, lp["cross"], x, ck, cv)
        x = x + T.mlp_block(cfg, lp["mlp"], x)
        return x, new_cache

    x, new_cache = jax.lax.scan(
        body, x, (params["layers"], cache, cross_k, cross_v))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_head(x, params["head"], cfg.vocab)
    new_cache["cross_k"], new_cache["cross_v"] = cross_k, cross_v
    return logits, new_cache


def abstract_cache(cfg: ModelConfig, batch: int, seq: int) -> dict:
    e = cfg.encdec
    n, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    c = T.abstract_cache(cfg, batch, seq)
    ax = (None, "cache_batch", "frames", "kv_heads", None)
    c["cross_k"] = PSpec((n, batch, e.n_frames, KV, hd), ax)
    c["cross_v"] = PSpec((n, batch, e.n_frames, KV, hd), ax)
    return c


# ---------------------------------------------------------------------------
# paged serving path: self-KV decode pages + STATIC-LENGTH cross pages
# ---------------------------------------------------------------------------

def cross_block_decode_paged(cfg: ModelConfig, p: dict, x: jax.Array,
                             arena_layer: dict, meta: dict) -> jax.Array:
    """Single-token cross-attention against the pool's static prefix band.

    The cross KV lives in the SAME paged arenas as the decoder self-KV —
    rows [B, 2B) of the page table, allocated once at admission and read
    with the fixed ``cross_lengths`` every step (the paper's static plane;
    under pressure these cold pages are the first to be augmented). The
    kernel is `paged_kv_attention`'s static-length variant: no rope on q,
    lengths pinned to the prefix length instead of positions + 1."""
    from repro.kernels import ops as K
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = L.rms_norm(x, p["norm"], cfg.norm_eps)
    q = (h @ p["wq"]).reshape(B, H, hd)
    if cfg.amc.kv_impl == "kernel":
        qk = q.reshape(B, KV, H // KV, hd)
        o = K.paged_prefix_attention(
            qk, arena_layer["kn"], arena_layer["vn"], arena_layer["kp"],
            arena_layer["vp"], arena_layer["ks"], arena_layer["vs"],
            meta["cross_lengths"], meta["cross_modes"],
            meta["cross_normal_idx"], meta["cross_packed_idx"],
            page=cfg.amc.page_size, kv_bits=cfg.amc.aug_bits)
        o = o.reshape(B, 1, H, hd)
    else:   # reference: gather the prefix band densely, mask by length
        from repro.kernels.ref import paged_gather_kv_ref
        kd, vd = paged_gather_kv_ref(
            arena_layer["kn"], arena_layer["vn"], arena_layer["kp"],
            arena_layer["vp"], arena_layer["ks"], arena_layer["vs"],
            meta["cross_table"], meta["cross_modes"],
            kv_bits=cfg.amc.aug_bits)
        o = L.decode_attention_kvmajor(
            q[:, None], kd.astype(jnp.bfloat16), vd.astype(jnp.bfloat16),
            meta["cross_lengths"] - 1)
    return (o.reshape(B, 1, -1) @ p["wo"]).astype(x.dtype)


def paged_decode_step(cfg: ModelConfig, params: dict, arenas: dict,
                      tokens: jax.Array, positions: jax.Array, meta: dict,
                      *, rules=None):
    """One decode step against the paged pool: self-attention walks the
    decode band, cross-attention the static prefix band. Same math as
    `decode_step` (the cross output over a zeroed prefix is exactly 0)."""
    x = L.embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)
    x = x + L.sinusoidal_positions(positions.astype(jnp.float32),
                                   cfg.d_model)[:, None].astype(x.dtype)

    def body(x, scanned):
        lp, arena_layer = scanned
        a, new_arenas = T.attn_block_decode_paged(cfg, lp["attn"], x,
                                                  arena_layer, positions,
                                                  meta)
        x = x + a
        x = x + cross_block_decode_paged(cfg, lp["cross"], x, new_arenas,
                                         meta)
        x = x + T.mlp_block(cfg, lp["mlp"], x)
        return x, new_arenas

    x, new_arenas = jax.lax.scan(body, x, (params["layers"], arenas))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_head(x, params["head"], cfg.vocab)
    return logits, new_arenas
