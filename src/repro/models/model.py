"""Family dispatcher: one uniform interface over the 10-arch zoo.

  abstract_params(cfg)                -> PSpec tree
  forward(cfg, params, batch, ...)    -> logits  (train / prefill)
  abstract_cache(cfg, shape)          -> PSpec tree for decode state
  decode_step(cfg, params, cache, batch) -> (logits, new_cache)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, hybrid, ssm, transformer, vision
from repro.models.params import PSpec


def _family_mod(cfg: ModelConfig):
    return {"dense": transformer, "moe": transformer, "audio": encdec,
            "vlm": vision, "ssm": ssm, "hybrid": hybrid}[cfg.family]


def abstract_params(cfg: ModelConfig):
    return _family_mod(cfg).abstract_params(cfg)


def forward(cfg: ModelConfig, params, batch: dict, *, rules=None,
            return_cache=False, remat_policy="dots", q_chunk=1024):
    """batch: {"tokens": (B,S)} plus frames/patches for audio/vlm."""
    mod = _family_mod(cfg)
    kw = dict(rules=rules, return_cache=return_cache,
              remat_policy=remat_policy, q_chunk=q_chunk)
    if cfg.family == "audio":
        return mod.forward(cfg, params, batch["tokens"], batch["frames"], **kw)
    if cfg.family == "vlm":
        return mod.forward(cfg, params, batch["tokens"], batch["patches"], **kw)
    return mod.forward(cfg, params, batch["tokens"], **kw)


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig):
    return _family_mod(cfg).abstract_cache(cfg, shape.global_batch,
                                           shape.seq_len)


def decode_step(cfg: ModelConfig, params, cache, batch: dict, *, rules=None):
    return _family_mod(cfg).decode_step(cfg, params, cache, batch["tokens"],
                                        batch["positions"], rules=rules)


def supports_prefill(cfg: ModelConfig) -> bool:
    """Whether the family has a chunked-prefill step (transformer-style
    caches); others fall back to the per-token decode loop in serving."""
    return hasattr(_family_mod(cfg), "prefill_chunk_step")


def prefill_step(cfg: ModelConfig, params, cache, batch: dict, *,
                 rules=None):
    """Chunked prefill: batch = {"tokens" (B, C), "positions" (B,) start
    of the chunk per row, "write_mask" (B,) rows being prefilled}.
    Returns (logits (B, C, V), new_cache) in ONE device dispatch."""
    mod = _family_mod(cfg)
    fn = getattr(mod, "prefill_chunk_step", None)
    if fn is None:
        raise NotImplementedError(
            f"family {cfg.family!r} has no chunked prefill")
    return fn(cfg, params, cache, batch["tokens"], batch["positions"],
              batch.get("write_mask"), rules=rules)


def paged_decode_step(cfg: ModelConfig, params, arenas, batch: dict, *,
                      rules=None):
    """One decode step against the paged pool. batch adds the store's
    device tables (page_table/page_modes/normal_idx/packed_idx, plus the
    cross_* prefix tables for encdec and the dense patch KV for vlm) and
    write_mask to the decode operands; everything that is not a token or
    a position is forwarded as kernel/table meta."""
    meta = {k: v for k, v in batch.items()
            if k not in ("tokens", "positions")}
    return _family_mod(cfg).paged_decode_step(
        cfg, params, arenas, batch["tokens"], batch["positions"], meta,
        rules=rules)


def paged_verify_step(cfg: ModelConfig, params, arenas, batch: dict, *,
                      rules=None):
    """Speculative verify over the paged pool: batch carries the draft
    window tokens (B, W), the window's start positions (B,), a 2-D
    write_mask (B, W) capping each row's window, and the store's device
    tables. Returns (logits (B, W, V), new_arenas) with only the
    accepted prefix of each window committed (greedy in-graph accept)."""
    meta = {k: v for k, v in batch.items()
            if k not in ("tokens", "positions")}
    return _family_mod(cfg).paged_verify_window_step(
        cfg, params, arenas, batch["tokens"], batch["positions"], meta,
        rules=rules)


def paged_prefill_step(cfg: ModelConfig, params, arenas, batch: dict, *,
                       rules=None):
    """Chunked prefill into the paged pool (one dispatch per chunk)."""
    return _family_mod(cfg).paged_prefill_chunk_step(
        cfg, params, arenas, batch["tokens"], batch["positions"],
        batch.get("write_mask"),
        {k: batch[k] for k in ("page_table", "page_modes", "normal_idx",
                               "packed_idx")}, rules=rules)


def loss_fn(cfg: ModelConfig, params, batch: dict, *, rules=None,
            remat_policy="dots", q_chunk=1024):
    """Next-token cross-entropy, vocab-sharding-friendly.

    Computed as lse(logits) - <logits, one_hot(target)>: both terms reduce
    over the (model-sharded) vocab dim locally and all-reduce only (B, S)
    stats — never gathers the full logits (which would be ~40 GiB/device at
    train_4k scale).
    """
    from repro.distributed.sharding import constrain
    logits = forward(cfg, params, batch, rules=rules,
                     remat_policy=remat_policy, q_chunk=q_chunk)
    targets = batch["targets"]
    logits = logits.astype(jnp.float32)
    if rules is not None:
        logits = constrain(logits, rules, "batch", None, "vocab")
    lse = jax.nn.logsumexp(logits, axis=-1)                     # (B,S)
    oh = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    if rules is not None:
        oh = constrain(oh, rules, "batch", None, "vocab")
    tgt = jnp.einsum("bsv,bsv->bs", logits, oh)
    return (lse - tgt).mean()
