"""Shared neural net layers: norms, RoPE, GQA attention (chunked flash
pattern for long sequences, single-token decode against dense or
AMC-packed KV), MLPs, embeddings.

All attention math accumulates in fp32 and is exact (online chunking only
bounds live memory, it never approximates the softmax).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import quant


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def sinusoidal_positions(positions: jax.Array, dim: int,
                         base: float = 10000.0) -> jax.Array:
    """positions (...,) -> (..., dim) sinusoidal embedding (whisper-style)."""
    half = dim // 2
    freqs = jnp.exp(-jnp.log(base) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) or (S,). Rotate-half RoPE."""
    if theta <= 0:
        return x
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions.astype(jnp.float32)[:, :, None, None] * freqs  # (B,S,1,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B,Sq,KV,Hg,D), k: (B,Sk,KV,D) -> (B,KV,Hg,Sq,Sk) fp32."""
    return jnp.einsum("bqkhd,bskd->bkhqs", q, k,
                      preferred_element_type=jnp.float32)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True,
              window: Optional[int] = None,
              q_chunk: int = 1024,
              q_offset: int = 0) -> jax.Array:
    """Exact attention, chunked over the query axis to bound live memory.

    q: (B, Sq, H, D); k, v: (B, Sk, KV, D) with H % KV == 0 (GQA).
    Returns (B, Sq, H, D). Scores/softmax in fp32.
    """
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    Hg = H // KV
    scale = 1.0 / (D ** 0.5)
    qg = q.reshape(B, Sq, KV, Hg, D)

    def one_chunk(qc: jax.Array, start) -> jax.Array:
        s = _gqa_scores(qc, k) * scale               # (B,KV,Hg,Cq,Sk)
        if causal or window is not None:
            cq = qc.shape[1]
            qpos = start + jnp.arange(cq) + q_offset
            kpos = jnp.arange(Sk)
            m = jnp.ones((cq, Sk), bool)
            if causal:
                m &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                m &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(m[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkhqs,bskd->bqkhd", p.astype(v.dtype), v)
        return o.reshape(B, qc.shape[1], H, D)

    if Sq <= q_chunk:
        return one_chunk(qg, 0)
    n = Sq // q_chunk
    assert Sq % q_chunk == 0, (Sq, q_chunk)
    qs = qg.reshape(B, n, q_chunk, KV, Hg, D)

    def body(_, i):
        qc = jax.lax.dynamic_index_in_dim(qs, i, axis=1, keepdims=False)
        return None, one_chunk(qc, i * q_chunk)

    _, out = jax.lax.scan(body, None, jnp.arange(n))
    # (n, B, Cq, H, D) -> (B, Sq, H, D)
    return jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, D)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     positions: jax.Array, *,
                     window: Optional[int] = None) -> jax.Array:
    """Single-token attention against a (possibly ring) cache.

    q: (B, 1, H, D); caches: (B, S, KV, D); positions: (B,) index of the
    token being generated (== number of valid cache slots - 1).
    Ring caches rely on softmax permutation-invariance: slot order is
    irrelevant, only the validity mask matters.
    """
    B, _, H, D = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    Hg = H // KV
    scale = 1.0 / (D ** 0.5)
    qg = q.reshape(B, 1, KV, Hg, D)
    s = _gqa_scores(qg, k_cache) * scale             # (B,KV,Hg,1,S)
    slot = jnp.arange(S)
    if window is None:
        valid = slot[None, :] <= positions[:, None]
    else:
        # ring buffer of size S == window: slot i valid once written
        valid = slot[None, :] <= jnp.minimum(positions[:, None], S - 1)
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkhqs,bskd->bqkhd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, 1, H, D)


def decode_attention_kvmajor(q: jax.Array, k_cache: jax.Array,
                             v_cache: jax.Array, positions: jax.Array, *,
                             window: Optional[int] = None) -> jax.Array:
    """`decode_attention` over head-major caches (B, KV, S, D) — the
    dequant reference path for the packed layouts (the hot path streams
    the packed cache through `kernels.ops.packed_kv_attention` instead)."""
    return decode_attention(q, jnp.swapaxes(k_cache, 1, 2),
                            jnp.swapaxes(v_cache, 1, 2), positions,
                            window=window)


def prefill_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                      starts: jax.Array, *,
                      window: Optional[int] = None) -> jax.Array:
    """Chunk-vs-cache attention for single-dispatch chunked prefill.

    q: (B, C, H, D) — a chunk whose row-b token i sits at absolute
    position starts[b] + i; caches: (B, S, KV, D), already containing the
    chunk's own KV (written before this call). Token i attends to cache
    slots [0, starts[b] + i] — prior chunks plus the causal prefix of its
    own chunk — which is exact: during prefill, slot index == position.
    """
    return prefill_attention_kvmajor(q, jnp.swapaxes(k_cache, 1, 2),
                                     jnp.swapaxes(v_cache, 1, 2), starts,
                                     window=window)


def prefill_attention_kvmajor(q: jax.Array, k_cache: jax.Array,
                              v_cache: jax.Array, starts: jax.Array, *,
                              window: Optional[int] = None) -> jax.Array:
    """`prefill_attention` over head-major caches (B, KV, S, D) — the
    native layout of the packed decode cache, so the dequantized chunk
    attention needs no cache transpose."""
    B, C, H, D = q.shape
    KV, S = k_cache.shape[1], k_cache.shape[2]
    Hg = H // KV
    scale = 1.0 / (D ** 0.5)
    qg = q.reshape(B, C, KV, Hg, D)
    s = jnp.einsum("bqkhd,bksd->bkhqs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    qpos = starts[:, None] + jnp.arange(C)[None, :]  # (B, C)
    kpos = jnp.arange(S)
    m = kpos[None, None, :] <= qpos[:, :, None]      # (B, C, S)
    if window is not None:
        m &= kpos[None, None, :] > qpos[:, :, None] - window
    s = jnp.where(m[:, None, None, :, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkhqs,bksd->bqkhd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, C, H, D)


def mlp(x, w_gate, w_up, w_down, act: str):
    if act == "swiglu":
        h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    else:
        h = jax.nn.gelu(x @ w_up, approximate=True)
    return h @ w_down


def embed_lookup(table: jax.Array, tokens: jax.Array) -> jax.Array:
    """Vocab-sharded embedding gather (XLA SPMD lowers to mask+all-reduce)."""
    return jnp.take(table, tokens, axis=0)


def lm_head(x: jax.Array, head_w: jax.Array, vocab_real: int) -> jax.Array:
    """x: (B,S,d) @ (d,V) -> masked logits (padded vocab slots -> -inf)."""
    logits = (x @ head_w).astype(jnp.float32)
    V = head_w.shape[-1]
    if vocab_real < V:
        col = jax.lax.broadcasted_iota(jnp.int32, (1, 1, V), 2)
        logits = jnp.where(col < vocab_real, logits, -1e30)
    return logits


# ---------------------------------------------------------------------------
# KV cache update + AMC packing (the dynamic plane of the serving engine)
# ---------------------------------------------------------------------------

def to_kvmajor(x: jax.Array) -> jax.Array:
    """Seq-major (..., S, KV, d) -> head-major (..., KV, S, d): the packed
    decode-cache layout `kernels.ops.packed_kv_attention` streams. The ONE
    place the layout convention is encoded — model code goes through here."""
    return jnp.swapaxes(x, -3, -2)

def update_cache_chunk(cache: jax.Array, new: jax.Array,
                       starts: jax.Array,
                       write_mask: Optional[jax.Array] = None, *,
                       axis: int = 0) -> jax.Array:
    """Scatter a per-row chunk into the cache.

    cache: (B, S, ...); new: (B, C, ...); starts: (B,) first slot per row.
    `axis` is the sequence axis AFTER the batch dim is stripped (0 for
    seq-major (B, S, ...) caches, 1 for head-major (B, KV, S, ...)).
    `write_mask` (B,) bool keeps masked-off rows bit-identical — prefill
    of one slot must not spill garbage into its batch neighbours' caches.
    """
    def upd(c, n, p):
        return jax.lax.dynamic_update_slice_in_dim(c, n, p, axis=axis)
    updated = jax.vmap(upd)(cache, new, starts)
    if write_mask is None:
        return updated
    mask = write_mask.reshape((-1,) + (1,) * (cache.ndim - 1))
    return jnp.where(mask, updated, cache)


def update_cache_line(cache: jax.Array, new: jax.Array,
                      positions: jax.Array, *, axis: int = 0) -> jax.Array:
    """cache: (B, S, ...); new: (B, 1, ...); positions: (B,)."""
    return update_cache_chunk(cache, new, positions, axis=axis)


def pack_kv_int4(kv: jax.Array):
    """kv: (..., D) bf16 -> (uint8 (..., D//2), scale (..., 1))."""
    q, scale = quant.quantize_int4(kv, axis=-1)
    hi, lo = q[..., 0::2], q[..., 1::2]
    return quant.pack_int4_pair(hi, lo), scale.astype(jnp.bfloat16)


def unpack_kv_int4(packed: jax.Array, scale: jax.Array,
                   dtype=jnp.bfloat16) -> jax.Array:
    hi = quant.unpack_int4_hi(packed)
    lo = quant.unpack_int4_lo(packed)
    q = jnp.stack([hi, lo], axis=-1).reshape(*packed.shape[:-1],
                                             packed.shape[-1] * 2)
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def pack_kv_int8(kv: jax.Array):
    q, scale = quant.quantize_int8(kv, axis=-1)
    return q, scale.astype(jnp.bfloat16)


def unpack_kv_int8(q: jax.Array, scale: jax.Array,
                   dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)
