"""Decoder-only transformer (dense and MoE): llama/qwen/granite/grok family.

Layers are stacked along a leading L dim and `lax.scan`ned (MaxText-style)
so HLO size and compile time stay bounded at 512 devices. Each layer body
is `jax.checkpoint`ed (remat) for training.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as K
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models.params import PSpec


# ---------------------------------------------------------------------------
# Parameter declaration
# ---------------------------------------------------------------------------

def attn_pspecs(cfg: ModelConfig, n: int, qk_norm: bool = False) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    p = {
        "norm": PSpec((n, d), (None, None), init="zeros"),
        "wq": PSpec((n, d, H * hd), (None, "embed", "heads")),
        "wk": PSpec((n, d, KV * hd), (None, "embed", "kv_heads")),
        "wv": PSpec((n, d, KV * hd), (None, "embed", "kv_heads")),
        "wo": PSpec((n, H * hd, d), (None, "heads", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = PSpec((n, H * hd), (None, "heads"), init="zeros")
        p["bk"] = PSpec((n, KV * hd), (None, "kv_heads"), init="zeros")
        p["bv"] = PSpec((n, KV * hd), (None, "kv_heads"), init="zeros")
    if qk_norm:
        p["q_norm"] = PSpec((n, hd), (None, None), init="zeros")
        p["k_norm"] = PSpec((n, hd), (None, None), init="zeros")
    return p


def mlp_pspecs(cfg: ModelConfig, n: int) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    p = {"norm": PSpec((n, d), (None, None), init="zeros"),
         "w_up": PSpec((n, d, f), (None, "embed", "mlp")),
         "w_down": PSpec((n, f, d), (None, "mlp", "embed"))}
    if cfg.act == "swiglu":
        p["w_gate"] = PSpec((n, d, f), (None, "embed", "mlp"))
    return p


def abstract_params(cfg: ModelConfig) -> dict:
    n, d, V = cfg.n_layers, cfg.d_model, cfg.vocab_padded
    qk_norm = cfg.family == "moe" and cfg.moe.n_experts >= 64  # qwen3-style
    layer = {"attn": attn_pspecs(cfg, n, qk_norm)}
    if cfg.moe is not None:
        layer["moe"] = moe_mod.moe_pspecs(cfg, n)
    else:
        layer["mlp"] = mlp_pspecs(cfg, n)
    params = {
        "embed": PSpec((V, d), ("vocab", "embed")),
        "final_norm": PSpec((d,), (None,), init="zeros"),
        "layers": layer,
    }
    if not cfg.tie_embeddings:
        params["head"] = PSpec((d, V), ("embed", "vocab"))
    return params


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _project_qkv(cfg: ModelConfig, p: dict, x: jax.Array, positions):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = L.rms_norm(x, p["norm"], cfg.norm_eps)
    q = h @ p["wq"]
    k = h @ p["wk"]
    v = h @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if "q_norm" in p:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_block(cfg: ModelConfig, p: dict, x: jax.Array, positions,
               causal=True, window=None, q_chunk=1024):
    """Full-sequence attention block. Returns (out, (k, v)) for cache fill."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, positions)
    o = L.attention(q, k, v, causal=causal, window=window, q_chunk=q_chunk)
    return (o.reshape(B, S, -1) @ p["wo"]).astype(x.dtype), (k, v)


def attn_block_decode(cfg: ModelConfig, p: dict, x: jax.Array,
                      cache_layer: dict, positions: jax.Array,
                      window=None):
    """Single-token attention against (possibly packed) KV cache."""
    B = x.shape[0]
    q, k_new, v_new = _project_qkv(cfg, p, x, positions[:, None])
    kv_mode = cfg.amc.kv_mode
    slot = positions % window if window is not None else positions
    if kv_mode == "normal":
        k_cache = L.update_cache_line(cache_layer["k"], k_new, slot)
        v_cache = L.update_cache_line(cache_layer["v"], v_new, slot)
        new_cache = {"k": k_cache, "v": v_cache}
        kd, vd = k_cache, v_cache
    elif kv_mode == "int4":
        kp, ks = L.pack_kv_int4(k_new)
        vp, vs = L.pack_kv_int4(v_new)
        k_cache = L.update_cache_line(cache_layer["k"], kp, slot)
        v_cache = L.update_cache_line(cache_layer["v"], vp, slot)
        k_scale = L.update_cache_line(cache_layer["k_scale"], ks, slot)
        v_scale = L.update_cache_line(cache_layer["v_scale"], vs, slot)
        new_cache = {"k": k_cache, "v": v_cache,
                     "k_scale": k_scale, "v_scale": v_scale}
        kd = L.unpack_kv_int4(k_cache, k_scale)
        vd = L.unpack_kv_int4(v_cache, v_scale)
    else:  # int8
        kp, ks = L.pack_kv_int8(k_new)
        vp, vs = L.pack_kv_int8(v_new)
        k_cache = L.update_cache_line(cache_layer["k"], kp, slot)
        v_cache = L.update_cache_line(cache_layer["v"], vp, slot)
        k_scale = L.update_cache_line(cache_layer["k_scale"], ks, slot)
        v_scale = L.update_cache_line(cache_layer["v_scale"], vs, slot)
        new_cache = {"k": k_cache, "v": v_cache,
                     "k_scale": k_scale, "v_scale": v_scale}
        kd = L.unpack_kv_int8(k_cache, k_scale)
        vd = L.unpack_kv_int8(v_cache, v_scale)
    o = L.decode_attention(q, kd, vd, positions, window=window)
    return (o.reshape(B, 1, -1) @ p["wo"]).astype(x.dtype), new_cache


def attn_block_prefill(cfg: ModelConfig, p: dict, x: jax.Array,
                       cache_layer: dict, starts: jax.Array,
                       write_mask: Optional[jax.Array] = None):
    """Chunked-prefill attention: a C-token chunk per row, written into the
    (possibly packed) decode cache in ONE pass and attended exactly.

    x: (B, C, d); starts: (B,) absolute position of each row's first chunk
    token; write_mask: (B,) bool — rows not being prefilled keep their
    cache bit-identical. int4 packing runs through the fused
    `quantize_pack_kv` kernel (bf16 chunk -> packed rows + scales, no
    dequantized intermediate), which is bit-exact with `pack_kv_int4`.
    """
    B, C, _ = x.shape
    positions = starts[:, None] + jnp.arange(C)[None, :]
    q, k_new, v_new = _project_qkv(cfg, p, x, positions)
    kv_mode = cfg.amc.kv_mode

    def put(cache, new):
        return L.update_cache_chunk(cache, new, starts, write_mask)

    if kv_mode == "normal":
        k_cache = put(cache_layer["k"], k_new)
        v_cache = put(cache_layer["v"], v_new)
        new_cache = {"k": k_cache, "v": v_cache}
        kd, vd = k_cache, v_cache
    else:
        if kv_mode == "int4":
            kp, ks = K.quantize_pack_kv(k_new)
            vp, vs = K.quantize_pack_kv(v_new)
            unpack = L.unpack_kv_int4
        else:  # int8
            kp, ks = L.pack_kv_int8(k_new)
            vp, vs = L.pack_kv_int8(v_new)
            unpack = L.unpack_kv_int8
        k_cache = put(cache_layer["k"], kp)
        v_cache = put(cache_layer["v"], vp)
        k_scale = put(cache_layer["k_scale"], ks)
        v_scale = put(cache_layer["v_scale"], vs)
        new_cache = {"k": k_cache, "v": v_cache,
                     "k_scale": k_scale, "v_scale": v_scale}
        kd = unpack(k_cache, k_scale)
        vd = unpack(v_cache, v_scale)
    o = L.prefill_attention(q, kd, vd, starts)
    return (o.reshape(B, C, -1) @ p["wo"]).astype(x.dtype), new_cache


def mlp_block(cfg: ModelConfig, p: dict, x: jax.Array):
    h = L.rms_norm(x, p["norm"], cfg.norm_eps)
    out = L.mlp(h, p.get("w_gate"), p["w_up"], p["w_down"], cfg.act)
    return out.astype(x.dtype)


def ffn_dispatch(cfg: ModelConfig, layer_p: dict, x: jax.Array, rules=None):
    if cfg.moe is not None:
        h = L.rms_norm(x, layer_p["moe"]["norm"], cfg.norm_eps)
        return moe_mod.moe_ffn(cfg, layer_p["moe"], h, rules)
    return mlp_block(cfg, layer_p["mlp"], x)


# ---------------------------------------------------------------------------
# Full forward (train / prefill) and decode
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: dict, tokens: jax.Array, *,
            rules=None, return_cache: bool = False,
            remat_policy: str = "dots", q_chunk: int = 1024):
    """tokens (B, S) -> logits (B, S, V) [+ prefill cache]."""
    from repro.distributed.sharding import constrain
    B, S = tokens.shape
    x = L.embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)
    # Sequence parallelism: the residual stream (and thus the scan carry
    # saved per layer for backward) is sharded along seq over the model
    # axis; attention/MLP entry gathers it, exit re-scatters (Megatron-SP).
    x = constrain(x, rules, "batch", "seq_sp", None)
    positions = jnp.arange(S)

    def body(x, lp):
        x = constrain(x, rules, "batch", "seq_sp", None)
        a, kv = attn_block(cfg, lp["attn"], x, positions, q_chunk=q_chunk)
        x = constrain(x + a, rules, "batch", "seq_sp", None)
        x = x + ffn_dispatch(cfg, lp, x, rules)
        x = constrain(x, rules, "batch", "seq_sp", None)
        return x, (kv if return_cache else None)

    body_fn = _remat(body, remat_policy)
    x, kvs = jax.lax.scan(body_fn, x, params["layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    logits = L.lm_head(x, head, cfg.vocab)
    if return_cache:
        return logits, _pack_prefill_cache(cfg, kvs)
    return logits


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    pol = {"dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
           "nothing": jax.checkpoint_policies.nothing_saveable,
           "everything": jax.checkpoint_policies.everything_saveable,
           }[policy]
    return jax.checkpoint(fn, policy=pol)


def _pack_prefill_cache(cfg: ModelConfig, kvs):
    """Stacked per-layer (k, v) from prefill -> decode cache layout.

    k/v arrive as (L, B, S, KV, hd). AMC kv modes pack them (the dynamic
    plane of the serving engine: 4x / 2x capacity augmentation).
    """
    k, v = kvs
    mode = cfg.amc.kv_mode
    if mode == "normal":
        return {"k": k, "v": v}
    pack = L.pack_kv_int4 if mode == "int4" else L.pack_kv_int8
    kp, ks = pack(k)
    vp, vs = pack(v)
    return {"k": kp, "v": vp, "k_scale": ks, "v_scale": vs}


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                tokens: jax.Array, positions: jax.Array, *, rules=None):
    """One decode step. tokens (B,1), positions (B,). Returns logits, cache."""
    x = L.embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)

    from repro.distributed.sharding import constrain

    def body(x, scanned):
        lp, cache_layer = scanned
        x = constrain(x, rules, "batch", None, None)
        a, new_cache = attn_block_decode(cfg, lp["attn"], x, cache_layer,
                                         positions)
        x = constrain(x + a, rules, "batch", None, None)
        x = x + ffn_dispatch(cfg, lp, x, rules)
        return x, new_cache

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    logits = L.lm_head(x, head, cfg.vocab)
    return logits, new_cache


def prefill_chunk_step(cfg: ModelConfig, params: dict, cache: dict,
                       tokens: jax.Array, starts: jax.Array,
                       write_mask: Optional[jax.Array] = None, *,
                       rules=None):
    """One chunked-prefill dispatch: tokens (B, C) at absolute positions
    starts (B,). Writes the chunk's (packed) KV into the decode cache and
    returns (logits (B, C, V), new_cache). A P-token prompt costs
    ceil(P / C) of these instead of P decode steps."""
    x = L.embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)

    from repro.distributed.sharding import constrain

    def body(x, scanned):
        lp, cache_layer = scanned
        x = constrain(x, rules, "batch", None, None)
        a, new_cache = attn_block_prefill(cfg, lp["attn"], x, cache_layer,
                                          starts, write_mask)
        x = constrain(x + a, rules, "batch", None, None)
        x = x + ffn_dispatch(cfg, lp, x, rules)
        return x, new_cache

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    logits = L.lm_head(x, head, cfg.vocab)
    return logits, new_cache


def abstract_cache(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """PSpec tree for the decode KV cache (dense/MoE transformer)."""
    n, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    mode = cfg.amc.kv_mode
    ax = (None, "cache_batch", "cache_seq", "kv_heads", None)
    if mode == "normal":
        return {"k": PSpec((n, batch, seq, KV, hd), ax),
                "v": PSpec((n, batch, seq, KV, hd), ax)}
    dt = "u8" if mode == "int4" else "i8"
    d_store = hd // 2 if mode == "int4" else hd
    return {"k": PSpec((n, batch, seq, KV, d_store), ax, dtype=dt),
            "v": PSpec((n, batch, seq, KV, d_store), ax, dtype=dt),
            "k_scale": PSpec((n, batch, seq, KV, 1), ax),
            "v_scale": PSpec((n, batch, seq, KV, 1), ax)}
