"""Decoder-only transformer (dense and MoE): llama/qwen/granite/grok family.

Layers are stacked along a leading L dim and `lax.scan`ned (MaxText-style)
so HLO size and compile time stay bounded at 512 devices. Each layer body
is `jax.checkpoint`ed (remat) for training.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as K
from repro.models import augment
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models.params import PSpec


# ---------------------------------------------------------------------------
# Parameter declaration
# ---------------------------------------------------------------------------

def attn_pspecs(cfg: ModelConfig, n: int, qk_norm: bool = False) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    p = {
        "norm": PSpec((n, d), (None, None), init="zeros"),
        "wq": PSpec((n, d, H * hd), (None, "embed", "heads")),
        "wk": PSpec((n, d, KV * hd), (None, "embed", "kv_heads")),
        "wv": PSpec((n, d, KV * hd), (None, "embed", "kv_heads")),
        "wo": PSpec((n, H * hd, d), (None, "heads", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = PSpec((n, H * hd), (None, "heads"), init="zeros")
        p["bk"] = PSpec((n, KV * hd), (None, "kv_heads"), init="zeros")
        p["bv"] = PSpec((n, KV * hd), (None, "kv_heads"), init="zeros")
    if qk_norm:
        p["q_norm"] = PSpec((n, hd), (None, None), init="zeros")
        p["k_norm"] = PSpec((n, hd), (None, None), init="zeros")
    return p


def mlp_pspecs(cfg: ModelConfig, n: int) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    p = {"norm": PSpec((n, d), (None, None), init="zeros"),
         "w_up": PSpec((n, d, f), (None, "embed", "mlp")),
         "w_down": PSpec((n, f, d), (None, "mlp", "embed"))}
    if cfg.act == "swiglu":
        p["w_gate"] = PSpec((n, d, f), (None, "embed", "mlp"))
    return p


def abstract_params(cfg: ModelConfig) -> dict:
    n, d, V = cfg.n_layers, cfg.d_model, cfg.vocab_padded
    qk_norm = cfg.family == "moe" and cfg.moe.n_experts >= 64  # qwen3-style
    layer = {"attn": attn_pspecs(cfg, n, qk_norm)}
    if cfg.moe is not None:
        layer["moe"] = moe_mod.moe_pspecs(cfg, n)
    else:
        layer["mlp"] = mlp_pspecs(cfg, n)
    params = {
        "embed": PSpec((V, d), ("vocab", "embed")),
        "final_norm": PSpec((d,), (None,), init="zeros"),
        "layers": layer,
    }
    if not cfg.tie_embeddings:
        params["head"] = PSpec((d, V), ("embed", "vocab"))
    # NOTE: this is the DENSE master tree (training operates on it; ternary
    # training goes through the STE path). Serving packs it into augmented
    # storage via `augment.augment_params` / `augment.augment_pspecs`.
    return params


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _project_qkv(cfg: ModelConfig, p: dict, x: jax.Array, positions):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = L.rms_norm(x, p["norm"], cfg.norm_eps)
    if "wkv_buf" in p:
        # dual-plane: wk (static nibble) + wv (dynamic nibble) share ONE
        # uint8 stream — one HBM read, two MXU dots
        q = augment.proj(p, "wq", h, cfg.amc)
        k, v = augment.dual_apply(h, p["wkv_buf"], p["wk_scale"],
                                  p["wv_scale"], amc=cfg.amc)
    else:
        q = augment.proj(p, "wq", h, cfg.amc)
        k = augment.proj(p, "wk", h, cfg.amc)
        v = augment.proj(p, "wv", h, cfg.amc)
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if "q_norm" in p:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_block(cfg: ModelConfig, p: dict, x: jax.Array, positions,
               causal=True, window=None, q_chunk=1024):
    """Full-sequence attention block. Returns (out, (k, v)) for cache fill."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, positions)
    o = L.attention(q, k, v, causal=causal, window=window, q_chunk=q_chunk)
    o = augment.proj(p, "wo", o.reshape(B, S, -1), cfg.amc)
    return o.astype(x.dtype), (k, v)


def _seq_block(S: int, bs: int = 512) -> int:
    """Largest divisor of S that is <= `bs` (kernel grids require
    S % bs == 0; the VMEM budget caps the block). Runs at trace time.
    E.g. S=100 -> 100, S=768 -> 384, S=8192 -> 512."""
    for b in range(min(bs, S), 0, -1):
        if S % b == 0:
            return b
    return 1


def attn_block_decode(cfg: ModelConfig, p: dict, x: jax.Array,
                      cache_layer: dict, positions: jax.Array,
                      window=None):
    """Single-token attention against (possibly packed) KV cache.

    Packed kv modes (int4/int8) keep the cache head-major (B, KV, S, ·)
    and stream it straight through `K.packed_kv_attention` — the bf16
    cache is NEVER materialized in HBM; dequant scales are applied to
    score columns inside the kernel. `cfg.amc.kv_impl == "dequant"`
    selects the reference unpack-then-dense path (tests/debug only).
    """
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q, k_new, v_new = _project_qkv(cfg, p, x, positions[:, None])
    kv_mode = cfg.amc.kv_mode
    slot = positions % window if window is not None else positions
    if kv_mode == "normal":
        k_cache = L.update_cache_line(cache_layer["k"], k_new, slot)
        v_cache = L.update_cache_line(cache_layer["v"], v_new, slot)
        new_cache = {"k": k_cache, "v": v_cache}
        o = L.decode_attention(q, k_cache, v_cache, positions, window=window)
    else:
        if kv_mode == "int4":
            pack, unpack, kv_bits = L.pack_kv_int4, L.unpack_kv_int4, 4
        else:  # int8
            pack, unpack, kv_bits = L.pack_kv_int8, L.unpack_kv_int8, 8
        kp, ks = pack(k_new)                      # (B, 1, KV, ·)
        vp, vs = pack(v_new)
        write = functools.partial(L.update_cache_line, positions=slot, axis=1)
        k_cache = write(cache_layer["k"], new=L.to_kvmajor(kp))
        v_cache = write(cache_layer["v"], new=L.to_kvmajor(vp))
        k_scale = write(cache_layer["k_scale"], new=L.to_kvmajor(ks))
        v_scale = write(cache_layer["v_scale"], new=L.to_kvmajor(vs))
        new_cache = {"k": k_cache, "v": v_cache,
                     "k_scale": k_scale, "v_scale": v_scale}
        # valid slots = positions + 1 (the just-written token included);
        # ring caches run past capacity — the kernel clamps lengths to S
        lengths = positions + 1
        if cfg.amc.kv_impl not in ("kernel", "dequant"):
            raise ValueError(f"unknown kv_impl {cfg.amc.kv_impl!r}")
        if cfg.amc.kv_impl == "kernel":
            S = k_cache.shape[2]
            qk = q[:, 0].reshape(B, KV, H // KV, hd)
            o = K.packed_kv_attention(qk, k_cache, v_cache,
                                      k_scale[..., 0], v_scale[..., 0],
                                      lengths, bs=_seq_block(S),
                                      kv_bits=kv_bits)
            o = o.reshape(B, 1, H, hd)
        else:  # reference: dequantize the full cache, dense attention
            kd = unpack(k_cache, k_scale)
            vd = unpack(v_cache, v_scale)
            o = L.decode_attention_kvmajor(q, kd, vd, positions,
                                           window=window)
    o = augment.proj(p, "wo", o.reshape(B, 1, -1), cfg.amc)
    return o.astype(x.dtype), new_cache


def _paged_pack(cfg: ModelConfig, kv: jax.Array, valid=None):
    """Quantize a bf16 KV tensor for the pool's Augmented plane. int4 runs
    through the fused `quantize_pack_kv` Pallas write driver; int8 through
    the jnp pack (no nibble interleave to fuse). `valid` (broadcastable to
    kv.shape[:-1]) is the speculative store-back mask — rejected rows
    commit as zero bytes + unit scale."""
    if cfg.amc.aug_bits == 4:
        return K.quantize_pack_kv(kv, valid)
    kq, ks = L.pack_kv_int8(kv)
    if valid is not None:
        keep = jnp.broadcast_to(valid, kv.shape[:-1])[..., None]
        kq = jnp.where(keep, kq, jnp.int8(0))
        ks = jnp.where(keep, ks, jnp.asarray(1.0, ks.dtype))
    return kq, ks


def _paged_scatter(cfg: ModelConfig, arenas: dict, k_new: jax.Array,
                   v_new: jax.Array, pos: jax.Array, meta: dict,
                   write: jax.Array, commit=None) -> dict:
    """Scatter per-token KV rows into the two-plane paged arena.

    k/v_new: (B, T, KV, hd); pos: (B, T) absolute positions; write:
    (B, T) bool. Each token lands in its logical page's physical page
    (page_table) in the plane its mode bit selects; masked-off rows are
    redirected to physical page 0, the write-dump page, so neighbours
    stay bit-identical (the paged form of the write-masked scatter).

    `commit` (B, T) bool, optional: the speculative accept mask. Unlike
    `write` (which redirects to the dump page), tokens with commit ==
    False are WRITTEN at their slot as zeros (zero bf16 rows in the
    Normal plane, zero bytes + unit scale in the Augmented plane) — the
    rejected tail of a draft window is scrubbed, only accepted tokens'
    values land."""
    page = cfg.amc.page_size
    lp = pos // page
    slot = pos % page
    phys = jnp.take_along_axis(meta["page_table"], lp, axis=1)    # (B, T)
    mode = jnp.take_along_axis(meta["page_modes"], lp, axis=1)
    if commit is not None:
        keep = commit[:, :, None, None]
        k_new = jnp.where(keep, k_new, 0)
        v_new = jnp.where(keep, v_new, 0)
    out = dict(arenas)
    # pool_mode is trace-time static: pinned-mode pools skip the plane
    # they can never write (half the scatter work of the mixed path)
    policy = cfg.amc.resolved_pool_mode
    if policy != "always-augmented":
        pn = jnp.where(write & (mode == 0), phys, 0)
        out["kn"] = arenas["kn"].at[pn, :, slot].set(
            k_new.astype(jnp.bfloat16))
        out["vn"] = arenas["vn"].at[pn, :, slot].set(
            v_new.astype(jnp.bfloat16))
    if policy != "normal-only":
        pp = jnp.where(write & (mode == 1), phys, 0)
        pack_valid = None if commit is None else commit[:, :, None]
        kq, ks = _paged_pack(cfg, k_new, pack_valid)
        vq, vs = _paged_pack(cfg, v_new, pack_valid)
        out["kp"] = arenas["kp"].at[pp, :, slot].set(kq)
        out["vp"] = arenas["vp"].at[pp, :, slot].set(vq)
        out["ks"] = arenas["ks"].at[pp, :, slot].set(
            ks[..., 0].astype(jnp.bfloat16))
        out["vs"] = arenas["vs"].at[pp, :, slot].set(
            vs[..., 0].astype(jnp.bfloat16))
    return out


def _paged_gather(cfg: ModelConfig, arenas: dict, meta: dict):
    """Reference gather: materialize the pool's logical contiguous caches
    (B, KV, maxP*page, hd) bf16 — the dequant/debug path and the chunked-
    prefill attention operand (prefill is compute-bound; the decode hot
    path streams pages through `K.paged_kv_attention` instead)."""
    from repro.kernels.ref import paged_gather_kv_ref
    kd, vd = paged_gather_kv_ref(
        arenas["kn"], arenas["vn"], arenas["kp"], arenas["vp"],
        arenas["ks"], arenas["vs"], meta["page_table"], meta["page_modes"],
        kv_bits=cfg.amc.aug_bits)
    return kd.astype(jnp.bfloat16), vd.astype(jnp.bfloat16)


def attn_block_decode_paged(cfg: ModelConfig, p: dict, x: jax.Array,
                            arenas: dict, positions: jax.Array,
                            meta: dict) -> tuple:
    """Single-token attention against the paged mode-switchable pool.

    `meta` carries the scheduler's device tables: page_table/page_modes
    (true per-(row, logical-page) physical index + mode bit) plus
    normal_idx/packed_idx (hold-previous gather indices for the kernel)
    and write_mask (rows actively decoding). The new token's KV is
    scattered into whichever plane its tail page is in; attention walks
    the page table via the scalar-prefetched Pallas kernel."""
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q, k_new, v_new = _project_qkv(cfg, p, x, positions[:, None])
    new_arenas = _paged_scatter(cfg, arenas, k_new, v_new,
                                positions[:, None], meta,
                                meta["write_mask"][:, None])
    lengths = positions + 1
    if cfg.amc.kv_impl == "kernel":
        qk = q[:, 0].reshape(B, KV, H // KV, hd)
        o = K.paged_kv_attention(
            qk, new_arenas["kn"], new_arenas["vn"], new_arenas["kp"],
            new_arenas["vp"], new_arenas["ks"], new_arenas["vs"], lengths,
            meta["page_modes"], meta["normal_idx"], meta["packed_idx"],
            page=cfg.amc.page_size, kv_bits=cfg.amc.aug_bits)
        o = o.reshape(B, 1, H, hd)
    else:  # reference: gather + dense attention
        kd, vd = _paged_gather(cfg, new_arenas, meta)
        o = L.decode_attention_kvmajor(q, kd, vd, positions)
    o = augment.proj(p, "wo", o.reshape(B, 1, -1), cfg.amc)
    return o.astype(x.dtype), new_arenas


def attn_block_verify_paged(cfg: ModelConfig, p: dict, x: jax.Array,
                            arenas: dict, starts: jax.Array,
                            meta: dict) -> tuple:
    """Speculative-verify attention: a W-token draft window per row
    through the FULL packed path (the static-plane read of the 8T
    duality).

    x: (B, W, d) — the window [last committed token, W-1 drafts] at
    absolute positions starts + [0..W). The window's full-quality KV is
    scattered over whatever the draft pass wrote, then each window slot
    attends causally (slot w sees tokens < starts + w + 1) via the
    W-query page-walk kernel — per slot bit-identical to the
    single-token decode read. Also returns the window's (k, v) so the
    epilogue can re-commit only accepted tokens."""
    B, W, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    positions = starts[:, None] + jnp.arange(W)[None, :]
    q, k_new, v_new = _project_qkv(cfg, p, x, positions)
    # near the cache end a row's window is host-capped (write_mask False
    # past the cap); clamp table lookups for those dump-bound slots
    max_s = meta["page_table"].shape[1] * cfg.amc.page_size
    pos_w = jnp.minimum(positions, max_s - 1)
    new_arenas = _paged_scatter(cfg, arenas, k_new, v_new, pos_w, meta,
                                meta["write_mask"])
    if cfg.amc.kv_impl == "kernel":
        qk = q.reshape(B, W, KV, H // KV, hd).transpose(0, 2, 1, 3, 4)
        o = K.paged_kv_attention_window(
            qk, new_arenas["kn"], new_arenas["vn"], new_arenas["kp"],
            new_arenas["vp"], new_arenas["ks"], new_arenas["vs"], starts,
            meta["page_modes"], meta["normal_idx"], meta["packed_idx"],
            page=cfg.amc.page_size, kv_bits=cfg.amc.aug_bits)
        o = o.transpose(0, 2, 1, 3, 4).reshape(B, W, H, hd)
    else:  # reference: gather + dense causal attention from `starts`
        kd, vd = _paged_gather(cfg, new_arenas, meta)
        o = L.prefill_attention_kvmajor(q, kd, vd, starts)
    o = augment.proj(p, "wo", o.reshape(B, W, -1), cfg.amc)
    return o.astype(x.dtype), new_arenas, (k_new, v_new)


def attn_block_prefill_paged(cfg: ModelConfig, p: dict, x: jax.Array,
                             arenas: dict, starts: jax.Array,
                             write_mask: Optional[jax.Array],
                             meta: dict) -> tuple:
    """Chunked-prefill attention over the paged pool: the chunk's KV is
    scattered across whatever pages (and modes) the page table assigns,
    then attended exactly against the gathered logical cache."""
    B, C, _ = x.shape
    positions = starts[:, None] + jnp.arange(C)[None, :]
    q, k_new, v_new = _project_qkv(cfg, p, x, positions)
    write = (jnp.ones((B, 1), bool) if write_mask is None
             else write_mask[:, None]) & jnp.ones((B, C), bool)
    new_arenas = _paged_scatter(cfg, arenas, k_new, v_new, positions,
                                meta, write)
    kd, vd = _paged_gather(cfg, new_arenas, meta)
    o = L.prefill_attention_kvmajor(q, kd, vd, starts)
    o = augment.proj(p, "wo", o.reshape(B, C, -1), cfg.amc)
    return o.astype(x.dtype), new_arenas


def attn_block_prefill(cfg: ModelConfig, p: dict, x: jax.Array,
                       cache_layer: dict, starts: jax.Array,
                       write_mask: Optional[jax.Array] = None):
    """Chunked-prefill attention: a C-token chunk per row, written into the
    (possibly packed) decode cache in ONE pass and attended exactly.

    x: (B, C, d); starts: (B,) absolute position of each row's first chunk
    token; write_mask: (B,) bool — rows not being prefilled keep their
    cache bit-identical. int4 packing runs through the fused
    `quantize_pack_kv` kernel (bf16 chunk -> packed rows + scales, no
    dequantized intermediate), which is bit-exact with `pack_kv_int4`.
    """
    B, C, _ = x.shape
    positions = starts[:, None] + jnp.arange(C)[None, :]
    q, k_new, v_new = _project_qkv(cfg, p, x, positions)
    kv_mode = cfg.amc.kv_mode

    if kv_mode == "normal":
        k_cache = L.update_cache_chunk(cache_layer["k"], k_new, starts,
                                       write_mask)
        v_cache = L.update_cache_chunk(cache_layer["v"], v_new, starts,
                                       write_mask)
        new_cache = {"k": k_cache, "v": v_cache}
        o = L.prefill_attention(q, k_cache, v_cache, starts)
    else:
        if kv_mode == "int4":
            kp, ks = K.quantize_pack_kv(k_new)
            vp, vs = K.quantize_pack_kv(v_new)
            unpack = L.unpack_kv_int4
        else:  # int8
            kp, ks = L.pack_kv_int8(k_new)
            vp, vs = L.pack_kv_int8(v_new)
            unpack = L.unpack_kv_int8

        def put(cache, new):
            # packed caches are head-major (B, KV, S, ·): seq axis is 1
            # after the batch dim is stripped
            return L.update_cache_chunk(cache, L.to_kvmajor(new), starts,
                                        write_mask, axis=1)

        k_cache = put(cache_layer["k"], kp)
        v_cache = put(cache_layer["v"], vp)
        k_scale = put(cache_layer["k_scale"], ks)
        v_scale = put(cache_layer["v_scale"], vs)
        new_cache = {"k": k_cache, "v": v_cache,
                     "k_scale": k_scale, "v_scale": v_scale}
        kd = unpack(k_cache, k_scale)
        vd = unpack(v_cache, v_scale)
        o = L.prefill_attention_kvmajor(q, kd, vd, starts)
    o = augment.proj(p, "wo", o.reshape(B, C, -1), cfg.amc)
    return o.astype(x.dtype), new_cache


def mlp_block(cfg: ModelConfig, p: dict, x: jax.Array):
    h = L.rms_norm(x, p["norm"], cfg.norm_eps)
    if "w_up_packed" in p:            # ternary: 2-bit weights stay packed
        out = augment.ternary_mlp(cfg, p, h)
    elif "w_gate_up_buf" in p:        # dual: w_gate + w_up share one stream
        out = augment.dual_mlp(cfg, p, h)
    else:
        out = L.mlp(h, p.get("w_gate"), p["w_up"], p["w_down"], cfg.act)
    return out.astype(x.dtype)


def ffn_dispatch(cfg: ModelConfig, layer_p: dict, x: jax.Array, rules=None,
                 group_size: int = 512):
    if cfg.moe is not None:
        h = L.rms_norm(x, layer_p["moe"]["norm"], cfg.norm_eps)
        return moe_mod.moe_ffn(cfg, layer_p["moe"], h, rules,
                               group_size=group_size)
    return mlp_block(cfg, layer_p["mlp"], x)


def _ffn_window(cfg: ModelConfig, layer_p: dict, x: jax.Array, rules=None):
    """FFN over a speculative-verify window (B, W, d).

    Decode-time MoE routing is per-token (group_size=1, see decode_step),
    so the whole window can be fed at once: every token routes in its own
    capacity group and the result is identical to W single-token decode
    dispatches regardless of batch composition."""
    return ffn_dispatch(cfg, layer_p, x, rules,
                        group_size=1 if cfg.moe is not None else 512)


# ---------------------------------------------------------------------------
# Full forward (train / prefill) and decode
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: dict, tokens: jax.Array, *,
            rules=None, return_cache: bool = False,
            remat_policy: str = "dots", q_chunk: int = 1024):
    """tokens (B, S) -> logits (B, S, V) [+ prefill cache]."""
    from repro.distributed.sharding import constrain
    B, S = tokens.shape
    x = L.embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)
    # Sequence parallelism: the residual stream (and thus the scan carry
    # saved per layer for backward) is sharded along seq over the model
    # axis; attention/MLP entry gathers it, exit re-scatters (Megatron-SP).
    x = constrain(x, rules, "batch", "seq_sp", None)
    positions = jnp.arange(S)

    def body(x, lp):
        x = constrain(x, rules, "batch", "seq_sp", None)
        a, kv = attn_block(cfg, lp["attn"], x, positions, q_chunk=q_chunk)
        x = constrain(x + a, rules, "batch", "seq_sp", None)
        x = x + ffn_dispatch(cfg, lp, x, rules)
        x = constrain(x, rules, "batch", "seq_sp", None)
        return x, (kv if return_cache else None)

    body_fn = _remat(body, remat_policy)
    x, kvs = jax.lax.scan(body_fn, x, params["layers"])
    logits = _logits_head(cfg, params, x)
    if return_cache:
        return logits, _pack_prefill_cache(cfg, kvs)
    return logits


def _logits_head(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    """Final norm + (possibly tied) LM head — the shared epilogue of
    every forward / decode / prefill variant."""
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    return L.lm_head(x, head, cfg.vocab)


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    pol = {"dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
           "nothing": jax.checkpoint_policies.nothing_saveable,
           "everything": jax.checkpoint_policies.everything_saveable,
           }[policy]
    return jax.checkpoint(fn, policy=pol)


def _pack_prefill_cache(cfg: ModelConfig, kvs):
    """Stacked per-layer (k, v) from prefill -> decode cache layout.

    k/v arrive as (L, B, S, KV, hd). AMC kv modes pack them head-major
    (L, B, KV, S, ·) — the layout `K.packed_kv_attention` streams — the
    dynamic plane of the serving engine: 4x / 2x capacity augmentation.
    """
    k, v = kvs
    mode = cfg.amc.kv_mode
    if mode == "normal":
        return {"k": k, "v": v}
    pack = L.pack_kv_int4 if mode == "int4" else L.pack_kv_int8
    kp, ks = pack(L.to_kvmajor(k))
    vp, vs = pack(L.to_kvmajor(v))
    return {"k": kp, "v": vp, "k_scale": ks, "v_scale": vs}


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                tokens: jax.Array, positions: jax.Array, *, rules=None):
    """One decode step. tokens (B,1), positions (B,). Returns logits, cache."""
    x = L.embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)

    from repro.distributed.sharding import constrain

    def body(x, scanned):
        lp, cache_layer = scanned
        x = constrain(x, rules, "batch", None, None)
        a, new_cache = attn_block_decode(cfg, lp["attn"], x, cache_layer,
                                         positions)
        x = constrain(x + a, rules, "batch", None, None)
        # per-token MoE routing groups: decode output must not depend on
        # which rows happen to be co-scheduled (capacity drops couple
        # tokens within a group) — this is what makes speculative
        # accept/rollback token-identical to stepwise decode
        x = x + ffn_dispatch(cfg, lp, x, rules, group_size=1)
        return x, new_cache

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    logits = _logits_head(cfg, params, x)
    return logits, new_cache


def prefill_chunk_step(cfg: ModelConfig, params: dict, cache: dict,
                       tokens: jax.Array, starts: jax.Array,
                       write_mask: Optional[jax.Array] = None, *,
                       rules=None):
    """One chunked-prefill dispatch: tokens (B, C) at absolute positions
    starts (B,). Writes the chunk's (packed) KV into the decode cache and
    returns (logits (B, C, V), new_cache). A P-token prompt costs
    ceil(P / C) of these instead of P decode steps."""
    x = L.embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)

    from repro.distributed.sharding import constrain

    def body(x, scanned):
        lp, cache_layer = scanned
        x = constrain(x, rules, "batch", None, None)
        a, new_cache = attn_block_prefill(cfg, lp["attn"], x, cache_layer,
                                          starts, write_mask)
        x = constrain(x + a, rules, "batch", None, None)
        x = x + ffn_dispatch(cfg, lp, x, rules)
        return x, new_cache

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    logits = _logits_head(cfg, params, x)
    return logits, new_cache


def paged_decode_step(cfg: ModelConfig, params: dict, arenas: dict,
                      tokens: jax.Array, positions: jax.Array, meta: dict,
                      *, rules=None):
    """One decode step against the paged augmented KV pool.

    tokens (B, 1); positions (B,); `meta` holds the pool's device tables
    (see `attn_block_decode_paged`) — scalar operands, shared by every
    layer of the scan. Returns (logits, new_arenas)."""
    x = L.embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)

    from repro.distributed.sharding import constrain

    def body(x, scanned):
        lp, arena_layer = scanned
        x = constrain(x, rules, "batch", None, None)
        a, new_arenas = attn_block_decode_paged(cfg, lp["attn"], x,
                                                arena_layer, positions, meta)
        x = constrain(x + a, rules, "batch", None, None)
        # per-token MoE routing: batch-composition invariance (see
        # decode_step) — the speculative token-identity contract
        x = x + ffn_dispatch(cfg, lp, x, rules, group_size=1)
        return x, new_arenas

    x, new_arenas = jax.lax.scan(body, x, (params["layers"], arenas))
    logits = _logits_head(cfg, params, x)
    return logits, new_arenas


def paged_verify_window_step(cfg: ModelConfig, params: dict, arenas: dict,
                             tokens: jax.Array, starts: jax.Array,
                             meta: dict, *, rules=None):
    """Speculative verify dispatch: tokens (B, W) = [last committed
    token, W-1 drafted tokens] at absolute positions starts + [0..W).

    One dispatch recomputes the whole window through the full packed
    path, greedily accepts the longest draft prefix matching its own
    argmax IN-GRAPH, and commits exactly the accepted tokens' KV — the
    rejected tail is scrubbed to zeros through the masked
    quantize-pack store-back. Returns (logits (B, W, V), new_arenas);
    the host replays the same argmax acceptance on the returned logits
    for its bookkeeping, so device and host agree by construction."""
    B, W = tokens.shape
    x = L.embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)

    from repro.distributed.sharding import constrain
    wmask = meta["write_mask"]                              # (B, W)

    def body(x, scanned):
        lp, arena_layer = scanned
        x = constrain(x, rules, "batch", None, None)
        a, new_arenas, kv = attn_block_verify_paged(cfg, lp["attn"], x,
                                                    arena_layer, starts,
                                                    meta)
        x = constrain(x + a, rules, "batch", None, None)
        x = x + _ffn_window(cfg, lp, x, rules)
        return x, (new_arenas, kv)

    x, (new_arenas, kvs) = jax.lax.scan(body, x, (params["layers"], arenas))
    logits = _logits_head(cfg, params, x)                   # (B, W, V)

    # greedy acceptance: slot 0 is the already-committed last token, so
    # at least one verify output is always emitted; n_acc - 1 drafts
    # matched the full path's own argmax
    v = jnp.argmax(logits, axis=-1).astype(tokens.dtype)
    mism = jnp.concatenate([tokens[:, 1:] != v[:, :-1],
                            jnp.ones((B, 1), bool)], axis=1)
    n_acc = jnp.argmax(mism, axis=1) + 1                    # (B,) in [1, W]
    accept = (jnp.arange(W)[None, :] < n_acc[:, None]) & wmask

    positions = starts[:, None] + jnp.arange(W)[None, :]
    max_s = meta["page_table"].shape[1] * cfg.amc.page_size
    pos_w = jnp.minimum(positions, max_s - 1)
    k_news, v_news = kvs

    def commit_body(c, scanned):
        arena_layer, k_l, v_l = scanned
        return c, _paged_scatter(cfg, arena_layer, k_l, v_l, pos_w, meta,
                                 wmask, commit=accept)

    _, final_arenas = jax.lax.scan(commit_body, 0,
                                   (new_arenas, k_news, v_news))
    return logits, final_arenas


def paged_prefill_chunk_step(cfg: ModelConfig, params: dict, arenas: dict,
                             tokens: jax.Array, starts: jax.Array,
                             write_mask: Optional[jax.Array], meta: dict,
                             *, rules=None):
    """Chunked prefill into the paged pool: tokens (B, C) at absolute
    positions starts (B,), scattered across the rows' page tables in one
    dispatch. Returns (logits (B, C, V), new_arenas)."""
    x = L.embed_lookup(params["embed"], tokens).astype(jnp.bfloat16)

    from repro.distributed.sharding import constrain

    def body(x, scanned):
        lp, arena_layer = scanned
        x = constrain(x, rules, "batch", None, None)
        a, new_arenas = attn_block_prefill_paged(cfg, lp["attn"], x,
                                                 arena_layer, starts,
                                                 write_mask, meta)
        x = constrain(x + a, rules, "batch", None, None)
        x = x + ffn_dispatch(cfg, lp, x, rules)
        return x, new_arenas

    x, new_arenas = jax.lax.scan(body, x, (params["layers"], arenas))
    logits = _logits_head(cfg, params, x)
    return logits, new_arenas


def abstract_cache(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """PSpec tree for the decode KV cache (dense/MoE transformer).

    Packed modes are head-major (L, B, KV, S, ·): the exact layout
    `K.packed_kv_attention` streams HBM->VMEM, so the decode hot path
    reads the packed bytes with no transpose and no dequantized copy."""
    n, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    mode = cfg.amc.kv_mode
    if mode == "normal":
        ax = (None, "cache_batch", "cache_seq", "kv_heads", None)
        return {"k": PSpec((n, batch, seq, KV, hd), ax),
                "v": PSpec((n, batch, seq, KV, hd), ax)}
    dt = "u8" if mode == "int4" else "i8"
    d_store = hd // 2 if mode == "int4" else hd
    ax = (None, "cache_batch", "kv_heads", "cache_seq", None)
    return {"k": PSpec((n, batch, KV, seq, d_store), ax, dtype=dt),
            "v": PSpec((n, batch, KV, seq, d_store), ax, dtype=dt),
            "k_scale": PSpec((n, batch, KV, seq, 1), ax),
            "v_scale": PSpec((n, batch, KV, seq, 1), ax)}
