"""Observability plane: tracing, metrics, exporters (DESIGN.md SS12)."""
from repro.obs.export import (validate_chrome_trace,
                              validate_chrome_trace_file,
                              write_chrome_trace, write_prometheus)
from repro.obs.hooks import NULL_OBS, EngineObs, NullEngineObs, \
    make_engine_obs
from repro.obs.metrics import LogHistogram, MetricsRegistry, TimeSeries
from repro.obs.trace import (ENGINE_TRACK, FAULT_TRACK, REFRESH_TRACK,
                             REQ_TRACK_BASE, SCHED_TRACK, NullTracer,
                             Tracer)

__all__ = [
    "EngineObs", "NullEngineObs", "NULL_OBS", "make_engine_obs",
    "Tracer", "NullTracer", "MetricsRegistry", "LogHistogram",
    "TimeSeries", "write_chrome_trace", "write_prometheus",
    "validate_chrome_trace", "validate_chrome_trace_file",
    "ENGINE_TRACK", "SCHED_TRACK", "REFRESH_TRACK", "FAULT_TRACK",
    "REQ_TRACK_BASE",
]
