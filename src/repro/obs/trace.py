"""Structured span/event tracer for the serving stack.

One `Tracer` per engine records the full request lifecycle (enqueue ->
admit -> prefill chunks -> decode / spec-verify rounds -> preempt /
recompute -> refresh -> fault inject / detect / heal -> complete or
failed) as Chrome-trace-event-compatible records:

  * spans        complete "X" events with a start timestamp and duration
                 (begin()/end() across function boundaries, or the
                 `span()` context manager for lexically scoped phases)
  * instants     "i" events (token emission, fault detection, refresh)
  * counters     "C" events (mode-mix / occupancy timelines perfetto
                 renders as graph tracks)

Tracks are integer `tid`s inside one `pid`: fixed tracks for the engine
step loop, the scheduler, the refresh clock and the fault/heal machinery,
plus one track per request (`REQ_TRACK_BASE + id`) so a request's whole
life — including preempt/requeue hops between rows — reads as one
horizontal lane in perfetto. `NullTracer` is the zero-overhead disabled
mode: every method is a constant-return no-op and the engine shares one
`nullcontext` for its span sites.

Timestamps are host-side `perf_counter` microseconds from the tracer's
construction. Dispatches are asynchronous, so a dispatch span measures
host-side dispatch+bookkeeping time; device compute is only observed
where the engine genuinely blocks (argmax readback) — documented, not
hidden.
"""
from __future__ import annotations

import contextlib
import time
from typing import Optional

# fixed tracks (tid); request tracks live at REQ_TRACK_BASE + request id
ENGINE_TRACK = 0
SCHED_TRACK = 1
REFRESH_TRACK = 2
FAULT_TRACK = 3
REQ_TRACK_BASE = 10

TRACK_NAMES = {
    ENGINE_TRACK: "engine/steps",
    SCHED_TRACK: "scheduler",
    REFRESH_TRACK: "refresh",
    FAULT_TRACK: "faults/heal",
}

_NULL_CTX = contextlib.nullcontext()


class _LexSpan:
    """Lexically-scoped span: a slotted context manager that records one
    complete "X" event on exit. Cheaper than a generator-based
    contextmanager on the per-step hot path, and it cannot leak an open
    span — only begin()/end() pairs participate in open_spans()."""

    __slots__ = ("_tr", "_tid", "_name", "_args", "_ts")

    def __init__(self, tr, tid, name, args):
        self._tr, self._tid = tr, tid
        self._name, self._args = name, args

    def __enter__(self):
        self._ts = self._tr.now_us()
        return self

    def __exit__(self, *exc):
        tr = self._tr
        tr.events.append({"name": self._name, "ph": "X", "ts": self._ts,
                          "dur": max(tr.now_us() - self._ts, 0.0),
                          "pid": tr.pid, "tid": self._tid,
                          "args": self._args})
        return False


class Tracer:
    """Recording tracer (enabled mode)."""

    enabled = True

    def __init__(self, *, clock=None, pid: int = 0,
                 epoch: Optional[float] = None,
                 process: str = "amc-serve"):
        self._clock = clock if clock is not None else time.perf_counter
        # `epoch` (clock units) lets several tracers share one time base:
        # an ArrayFleet passes the same epoch to every array's tracer so
        # the merged multi-pid trace has comparable timestamps
        self._t0 = self._clock() if epoch is None else epoch
        self.pid = pid
        self.process = process
        self.events: list[dict] = []
        self._open: dict[int, tuple] = {}   # span id -> (tid, name, ts, args)
        self._next_id = 0
        self._track_names: dict[int, str] = dict(TRACK_NAMES)

    # -- clock ---------------------------------------------------------------

    def now_us(self) -> float:
        return (self._clock() - self._t0) * 1e6

    # -- tracks --------------------------------------------------------------

    def name_track(self, tid: int, name: str) -> None:
        self._track_names[tid] = name

    def request_track(self, rid: int) -> int:
        tid = REQ_TRACK_BASE + rid
        if tid not in self._track_names:
            self._track_names[tid] = f"req {rid}"
        return tid

    # -- spans ---------------------------------------------------------------

    def begin(self, tid: int, name: str, **args) -> int:
        """Open a span; returns the id `end()` closes it with."""
        self._next_id += 1
        self._open[self._next_id] = (tid, name, self.now_us(), args)
        return self._next_id

    def end(self, span_id: int, **args) -> None:
        tid, name, ts, a0 = self._open.pop(span_id)
        if args:
            a0 = {**a0, **args}
        self.events.append({"name": name, "ph": "X", "ts": ts,
                            "dur": max(self.now_us() - ts, 0.0),
                            "pid": self.pid, "tid": tid, "args": a0})

    def span(self, tid: int, name: str, **args) -> _LexSpan:
        return _LexSpan(self, tid, name, args)

    def open_spans(self) -> int:
        return len(self._open)

    # -- instants / counters ---------------------------------------------------

    def instant(self, tid: int, name: str, **args) -> None:
        self.events.append({"name": name, "ph": "i", "ts": self.now_us(),
                            "s": "t", "pid": self.pid, "tid": tid,
                            "args": args})

    def counter(self, name: str, **values) -> None:
        """Perfetto counter track sample (mode mix / occupancy timeline)."""
        self.events.append({"name": name, "ph": "C", "ts": self.now_us(),
                            "pid": self.pid, "tid": ENGINE_TRACK,
                            "args": values})

    # -- export ----------------------------------------------------------------

    def chrome_trace(self) -> dict:
        """Perfetto-loadable Chrome trace JSON object. Spans still open at
        export are closed AT the export timestamp and flagged
        (`open_at_export`) so the artifact stays schema-valid mid-run;
        a clean end-of-run export has none (tests pin open_spans()==0)."""
        now = self.now_us()
        events = list(self.events)
        for tid, name, ts, args in self._open.values():
            events.append({"name": name, "ph": "X", "ts": ts,
                           "dur": max(now - ts, 0.0), "pid": self.pid,
                           "tid": tid,
                           "args": {**args, "open_at_export": True}})
        events.sort(key=lambda e: e["ts"])
        meta = [{"name": "process_name", "ph": "M", "pid": self.pid,
                 "tid": 0, "args": {"name": self.process}}]
        meta += [{"name": "thread_name", "ph": "M", "pid": self.pid,
                  "tid": tid, "args": {"name": name}}
                 for tid, name in sorted(self._track_names.items())]
        # thread_sort_index keeps the fixed tracks above the request lanes
        meta += [{"name": "thread_sort_index", "ph": "M", "pid": self.pid,
                  "tid": tid, "args": {"sort_index": tid}}
                 for tid in sorted(self._track_names)]
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}


class NullTracer:
    """Disabled mode: every method is a no-op (shared nullcontext for
    span sites), so tracing costs one attribute lookup + call when off."""

    enabled = False
    events = ()        # len()-able like the recording tracer's list

    def now_us(self) -> float:
        return 0.0

    def name_track(self, tid: int, name: str) -> None:
        pass

    def request_track(self, rid: int) -> int:
        return 0

    def begin(self, tid: int, name: str, **args) -> int:
        return 0

    def end(self, span_id: int, **args) -> None:
        pass

    def span(self, tid: int, name: str, **args):
        return _NULL_CTX

    def open_spans(self) -> int:
        return 0

    def instant(self, tid: int, name: str, **args) -> None:
        pass

    def counter(self, name: str, **values) -> None:
        pass

    def chrome_trace(self) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}
