"""CLI trace-schema validator (the CI trace-artifact gate).

  PYTHONPATH=src python -m repro.obs.validate trace.json [more.json ...]

Exits non-zero and prints every schema problem if any file fails."""
from __future__ import annotations

import sys

from repro.obs.export import validate_chrome_trace_file


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: python -m repro.obs.validate TRACE.json [...]",
              file=sys.stderr)
        return 2
    bad = 0
    for path in argv:
        problems = validate_chrome_trace_file(path)
        if problems:
            bad += 1
            print(f"INVALID {path}:")
            for p in problems:
                print(f"  - {p}")
        else:
            print(f"ok {path}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
