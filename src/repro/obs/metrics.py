"""Counters, gauges, log-bucketed histograms and bounded time series.

`MetricsRegistry` is the host-side metrics plane `ServeEngine` folds into
`stats()["obs"]` and dumps as Prometheus text exposition:

  * `Counter` / gauge values: plain monotonic / last-value numbers.
  * `LogHistogram`: geometric (log-spaced) buckets — the natural shape
    for latencies spanning microseconds to seconds. Percentiles are
    reported as the upper edge of the containing bucket, so two
    estimates of the same distribution agree "within one bucket" by
    construction (the acceptance check the trace/metrics cross-
    validation tests use).
  * `TimeSeries`: (step, value) samples under a hard memory bound —
    when full, every other sample is dropped and the keep-stride
    doubles, so a series keeps uniform coverage of the whole run at
    bounded cost (mode-mix timelines, pool occupancy, refresh debt,
    energy-ledger group rates).

Everything here is plain Python/host-side: nothing is traced, nothing
touches the jitted hot path.
"""
from __future__ import annotations

import math
from typing import Optional

# default latency bucketing: 1us .. ~87s at 5 buckets per decade
_LAT_LO = 1e-6
_LAT_GROWTH = 10.0 ** 0.2
_LAT_N = 40


class LogHistogram:
    """Geometric-bucket histogram: bucket i covers
    [lo * growth**(i-1), lo * growth**i); values below `lo` land in
    bucket 0, values past the top land in the overflow bucket."""

    def __init__(self, lo: float = _LAT_LO, growth: float = _LAT_GROWTH,
                 n_buckets: int = _LAT_N):
        assert lo > 0 and growth > 1 and n_buckets >= 1
        self.lo, self.growth, self.n_buckets = lo, growth, n_buckets
        self._log_g = math.log(growth)
        self.counts = [0] * (n_buckets + 1)     # +1 overflow
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def bucket_index(self, value: float) -> int:
        if value < self.lo:
            return 0
        i = int(math.log(value / self.lo) / self._log_g) + 1
        return min(i, self.n_buckets)

    def bucket_edge(self, i: int) -> float:
        """Upper edge of bucket i (inf for the overflow bucket)."""
        if i >= self.n_buckets:
            return math.inf
        return self.lo * self.growth ** i

    def observe(self, value: float) -> None:
        self.counts[self.bucket_index(value)] += 1
        self.count += 1
        self.sum += value
        self.min = value if self.min is None else min(self.min, value)
        self.max = value if self.max is None else max(self.max, value)

    def observe_n(self, value: float, n: int) -> None:
        """`n` observations of the same value in one bucket update (e.g.
        the per-token gap of an accepted speculative window)."""
        if n <= 0:
            return
        self.counts[self.bucket_index(value)] += n
        self.count += n
        self.sum += value * n
        self.min = value if self.min is None else min(self.min, value)
        self.max = value if self.max is None else max(self.max, value)

    def percentile(self, p: float) -> float:
        """Upper edge of the bucket holding the p-th percentile (0 with
        no observations) — a one-bucket-granular estimate."""
        if self.count == 0:
            return 0.0
        target = p / 100.0 * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= target and c:
                edge = self.bucket_edge(i)
                return self.max if math.isinf(edge) else edge
        return self.max

    def within_one_bucket(self, a: float, b: float) -> bool:
        """Whether two values land in the same or adjacent buckets —
        the agreement criterion for trace-derived vs metrics-derived
        latency estimates."""
        return abs(self.bucket_index(a) - self.bucket_index(b)) <= 1

    def summary(self) -> dict:
        return {
            "count": self.count,
            "sum": self.sum,
            "mean": self.sum / self.count if self.count else 0.0,
            "min": self.min if self.min is not None else 0.0,
            "max": self.max if self.max is not None else 0.0,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
        }


class TimeSeries:
    """Bounded (t, value) sampler: at `max_samples` the series drops
    every other retained sample and doubles its keep-stride, preserving
    uniform coverage of an arbitrarily long run in fixed memory."""

    def __init__(self, max_samples: int = 512):
        assert max_samples >= 4
        self.max_samples = max_samples
        self.samples: list[tuple] = []
        self._stride = 1
        self._seen = 0

    def sample(self, t, value) -> None:
        if self._seen % self._stride == 0:
            if len(self.samples) >= self.max_samples:
                self.samples = self.samples[::2]
                self._stride *= 2
                if self._seen % self._stride != 0:
                    self._seen += 1
                    return
            self.samples.append((t, value))
        self._seen += 1

    def last(self):
        return self.samples[-1][1] if self.samples else None

    def describe(self) -> dict:
        return {"n_samples": len(self.samples), "stride": self._stride,
                "last": self.last()}


class MetricsRegistry:
    """Name -> counter/gauge/histogram/series maps with auto-creation.
    `describe()` is a pure snapshot (no mutation — `stats()` must be
    idempotent); `prometheus_text()` is the text exposition dump."""

    def __init__(self, *, series_max_samples: int = 512):
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        self.histograms: dict[str, LogHistogram] = {}
        self.series: dict[str, TimeSeries] = {}
        self._series_max = series_max_samples

    def inc(self, name: str, n: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + n

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = value

    def histogram(self, name: str) -> LogHistogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = LogHistogram()
        return h

    def observe(self, name: str, value: float) -> None:
        self.histogram(name).observe(value)

    def sample(self, name: str, t, value) -> None:
        s = self.series.get(name)
        if s is None:
            s = self.series[name] = TimeSeries(self._series_max)
        s.sample(t, value)

    def describe(self) -> dict:
        return {
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "histograms": {k: h.summary()
                           for k, h in sorted(self.histograms.items())},
            "timeseries": {k: s.describe()
                           for k, s in sorted(self.series.items())},
        }

    def dump_timeseries(self) -> dict:
        """Full sampled timelines (BENCH_obs / offline analysis)."""
        return {k: list(s.samples) for k, s in sorted(self.series.items())}

    # -- Prometheus text exposition -------------------------------------------

    def prometheus_text(self) -> str:
        out: list[str] = []
        for name, v in sorted(self.counters.items()):
            m = _prom_name(name)
            out += [f"# TYPE {m} counter", f"{m} {_prom_num(v)}"]
        for name, v in sorted(self.gauges.items()):
            m = _prom_name(name)
            out += [f"# TYPE {m} gauge", f"{m} {_prom_num(v)}"]
        for name, h in sorted(self.histograms.items()):
            m = _prom_name(name)
            out.append(f"# TYPE {m} histogram")
            cum = 0
            for i, c in enumerate(h.counts[:-1]):
                cum += c
                if not c:
                    continue            # sparse dump: skip empty buckets
                edge = _prom_num(h.bucket_edge(i))
                out.append(f'{m}_bucket{{le="{edge}"}} {cum}')
            out.append(f'{m}_bucket{{le="+Inf"}} {h.count}')
            out.append(f"{m}_sum {_prom_num(h.sum)}")
            out.append(f"{m}_count {h.count}")
        return "\n".join(out) + "\n"


def _prom_name(name: str) -> str:
    return "amc_" + "".join(c if c.isalnum() or c == "_" else "_"
                            for c in name)


def _prom_num(v: float) -> str:
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))
