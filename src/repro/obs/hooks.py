"""EngineObs — the one observability facade the serving stack talks to.

`ServeEngine`, `Scheduler` and the state stores never touch the tracer or
the metrics registry directly: they call the lifecycle hooks below, and
the facade fans each hook out to spans/instants (Chrome trace) and
counters/histograms/time series (metrics). `NullEngineObs` implements the
same surface as constant no-ops — the engine holds exactly one `self.obs`
and never branches on "is tracing on?" at a call site.

Span taxonomy (one request = one perfetto lane, fixed lanes for the
engine/scheduler/refresh/fault machinery — DESIGN.md SS12):

  request lane   enqueue(i) -> [queue] -> [active [prefill [chunk]*]]
                 -> first_token(i)/token instants -> complete(i)
                 with preempt/heal hops re-opening [queue] on the SAME
                 lane (request-id continuity across requeues)
  engine lane    [step [admit] [spec_draft] [spec_verify]] per decode
                 round, plus counter tracks (mode mix, occupancy, queue)
  refresh lane   refresh_pass / augment / promote / restamp instants
  fault lane     [fault_pass] spans, inject/detect/heal/fail instants

Latency metrics (log-bucketed histograms, seconds): ttft_s (enqueue ->
first emitted token), queue_wait_s (enqueue/requeue -> admit),
inter_token_s (per-token gap between emissions), step_wall_s (host wall
per engine step), prefill_chunk_s, request_latency_s.
"""
from __future__ import annotations

import contextlib
import time
from typing import Callable, Optional

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import (ENGINE_TRACK, FAULT_TRACK, REFRESH_TRACK,
                             SCHED_TRACK, NullTracer, Tracer, _NULL_CTX)


class _Req:
    """Host-side lifecycle record of one request (both planes read it)."""
    __slots__ = ("tid", "enqueue_s", "queue_since_s", "queue_span",
                 "active_span", "first_s", "last_s", "tokens", "done")

    def __init__(self, tid: int, now: float):
        self.tid = tid
        self.enqueue_s = now
        self.queue_since_s = now
        self.queue_span = 0
        self.active_span = 0
        self.first_s: Optional[float] = None
        self.last_s: Optional[float] = None
        self.tokens = 0
        self.done = False


class EngineObs:
    enabled = True

    def __init__(self, *, trace: bool = True, metrics: bool = True,
                 sample_every: int = 1, clock: Optional[Callable] = None,
                 pid: int = 0, process: str = "amc-serve",
                 epoch: Optional[float] = None, registry=None):
        self._clock = clock if clock is not None else time.perf_counter
        self.trace_on = trace
        self.metrics_on = metrics
        self.sample_every = max(int(sample_every), 1)
        # pid/process/epoch: an ArrayFleet gives each array its own trace
        # pid ("array N" process lane) on ONE shared time base; `registry`
        # shares a single metrics plane across arrays (fleet-wide
        # histograms) while traces stay per-array
        self.tracer = (Tracer(clock=clock, pid=pid, process=process,
                              epoch=epoch) if trace else NullTracer())
        self.metrics = registry if registry is not None else MetricsRegistry()
        self._reqs: dict[int, _Req] = {}
        # pre-bound hot-path histograms: the decode loop observes these
        # every step/token, so skip the registry name lookup there
        self._h_ttft = self.metrics.histogram("ttft_s")
        self._h_itl = self.metrics.histogram("inter_token_s")
        self._h_step = self.metrics.histogram("step_wall_s")
        # last-emitted counter-track / time-series values: both are step
        # functions (a reader holds the previous value until the next
        # sample), so re-recording an unchanged value adds bytes and
        # allocations but no information — emit deltas only
        self._last_counters: dict[str, tuple] = {}
        self._last_series: dict = {}

    def _now(self) -> float:
        return self._clock()

    # -- request lifecycle ------------------------------------------------------

    def on_enqueue(self, rid: int, prompt_len: int, max_new: int,
                   step: int) -> None:
        tid = self.tracer.request_track(rid)
        rec = _Req(tid, self._now())
        self._reqs[rid] = rec
        self.tracer.instant(tid, "enqueue", step=step,
                            prompt_len=prompt_len, max_new=max_new)
        rec.queue_span = self.tracer.begin(tid, "queue", step=step)
        self.metrics.inc("requests_enqueued")

    def _reopen_queue(self, rec: _Req, step: int, reason: str) -> None:
        rec.queue_since_s = self._now()
        rec.queue_span = self.tracer.begin(rec.tid, "queue", step=step,
                                           reason=reason)

    def on_admit(self, rid: int, row: int, step: int) -> None:
        rec = self._reqs.get(rid)
        if rec is None:
            return
        if rec.queue_span:
            self.tracer.end(rec.queue_span, row=row, step=step)
            rec.queue_span = 0
        self.metrics.observe("queue_wait_s", self._now() - rec.queue_since_s)
        rec.active_span = self.tracer.begin(rec.tid, "active", row=row,
                                            step=step)
        self.metrics.inc("admissions")

    def prefill_span(self, rid: Optional[int], n_tokens: int):
        tid = (self._reqs[rid].tid if rid in self._reqs else ENGINE_TRACK)
        return self.tracer.span(tid, "prefill", tokens=n_tokens)

    @contextlib.contextmanager
    def chunk_span(self, rid: Optional[int], n_tokens: int):
        """One chunked-prefill dispatch (async: host dispatch time) —
        traced as a span AND observed into the prefill_chunk_s histogram."""
        tid = (self._reqs[rid].tid if rid in self._reqs else ENGINE_TRACK)
        t0 = self._now()
        with self.tracer.span(tid, "prefill_chunk", tokens=n_tokens):
            yield
        self.metrics.observe("prefill_chunk_s", self._now() - t0)

    def on_tokens(self, rid: int, n: int, step: int) -> None:
        """`n` tokens of request `rid` were emitted at this instant
        (n > 1 for an accepted speculative window)."""
        rec = self._reqs.get(rid)
        if rec is None or n <= 0:
            return
        now = self._clock()
        if rec.first_s is None:
            rec.first_s = now
            self._h_ttft.observe(now - rec.enqueue_s)
            self.tracer.instant(rec.tid, "first_token", step=step)
        elif rec.last_s is not None:
            # n tokens arrived in one dispatch (accepted spec window):
            # each is credited the mean gap
            self._h_itl.observe_n((now - rec.last_s) / n, n)
        rec.last_s = now
        rec.tokens += n
        c = self.metrics.counters
        c["tokens_emitted"] = c.get("tokens_emitted", 0) + n

    def on_preempt(self, rid: int, step: int, reason: str) -> None:
        """Preemption/heal requeue: the active span ends, a NEW queue
        span opens on the same lane (request-id continuity)."""
        rec = self._reqs.get(rid)
        if rec is None:
            return
        if rec.active_span:
            self.tracer.end(rec.active_span, outcome="preempted",
                            reason=reason)
            rec.active_span = 0
        self.tracer.instant(rec.tid, "preempt", step=step, reason=reason)
        self.tracer.instant(SCHED_TRACK, "preempt", req=rid, step=step,
                            reason=reason)
        self.metrics.inc(f"preempt_{reason}")
        self._reopen_queue(rec, step, reason)

    def _finish(self, rid: int, step: int, outcome: str) -> None:
        rec = self._reqs.get(rid)
        if rec is None or rec.done:
            return
        if rec.queue_span:                  # failed while queued
            self.tracer.end(rec.queue_span, outcome=outcome)
            rec.queue_span = 0
        if rec.active_span:
            self.tracer.end(rec.active_span, outcome=outcome, step=step)
            rec.active_span = 0
        self.tracer.instant(rec.tid, outcome, step=step, tokens=rec.tokens)
        self.metrics.inc(f"requests_{outcome}")
        self.metrics.observe("request_latency_s",
                             self._now() - rec.enqueue_s)
        rec.done = True

    def on_complete(self, rid: int, step: int) -> None:
        self._finish(rid, step, "completed")

    def on_failed(self, rid: int, step: int) -> None:
        self._finish(rid, step, "failed")

    def on_handoff(self, rid: int, step: int, kind: str) -> None:
        """Request leaves THIS array (fleet migration / array-loss
        drain): close its open spans on this pid — the lifecycle
        continues on the destination array's lane. No latency is
        observed here (the request is not finished, just elsewhere)."""
        rec = self._reqs.get(rid)
        if rec is None or rec.done:
            return
        if rec.queue_span:
            self.tracer.end(rec.queue_span, outcome=kind)
            rec.queue_span = 0
        if rec.active_span:
            self.tracer.end(rec.active_span, outcome=kind, step=step)
            rec.active_span = 0
        self.tracer.instant(rec.tid, kind, step=step, tokens=rec.tokens)
        self.metrics.inc(f"requests_{kind}")
        rec.done = True

    # -- engine phases ----------------------------------------------------------

    def step_span(self, step: int, kind: str):
        return self.tracer.span(ENGINE_TRACK, "step", step=step, kind=kind)

    def phase_span(self, name: str, **args):
        return self.tracer.span(ENGINE_TRACK, name, **args)

    def on_step_done(self, step: int, dt_s: float) -> None:
        self._h_step.observe(dt_s)
        self.metrics.inc("steps")

    def on_spec_round(self, accepted: int, rows: int, step: int) -> None:
        self.metrics.observe("accepted_per_round", accepted)
        self.metrics.inc("spec_rounds")

    def on_queue_depth(self, depth: int) -> None:
        self.metrics.gauge("queue_depth", depth)

    def on_placement(self, rid: int, array_id: int, policy: str, kind: str,
                     step: int) -> None:
        """Fleet placement decision landing a request on THIS array's
        scheduler lane: kind = admit | migrate | drain."""
        self.tracer.instant(SCHED_TRACK, "placement", req=rid,
                            array=array_id, policy=policy, kind=kind,
                            step=step)
        self.metrics.inc(f"placement_{kind}")

    # -- refresh / store maintenance -------------------------------------------

    def on_refresh_pass(self, n_units: int, step: int) -> None:
        if n_units:
            self.tracer.instant(REFRESH_TRACK, "refresh_pass", step=step,
                                units=n_units)
            self.metrics.inc("refresh_units", n_units)

    def store_event(self, kind: str, unit: str, step: int) -> None:
        """Mode transitions / refresh outcomes from the state stores:
        augment | promote | restamp | decommission | demote | cow
        (demote = shared-prefix page pressed Normal -> Augmented instead
        of evicted; cow = copy-on-write divergence page copy)."""
        self.tracer.instant(REFRESH_TRACK, kind, unit=unit, step=step)
        self.metrics.inc(f"store_{kind}")

    def on_prefix(self, kind: str, rid: int, tokens: int, step: int) -> None:
        """Prefix-cache outcome for a request admission: kind =
        hit | miss, with the matched token count on hits."""
        self.tracer.instant(SCHED_TRACK, f"prefix_{kind}", req=rid,
                            tokens=tokens, step=step)
        self.metrics.inc(f"prefix_{kind}")
        if tokens:
            self.metrics.inc("prefix_tokens_shared", tokens)

    # -- faults / healing --------------------------------------------------------

    def fault_span(self, step: int):
        return self.tracer.span(FAULT_TRACK, "fault_pass", step=step)

    def on_fault(self, kind: str, detail: str, step: int) -> None:
        """inject | detect | heal_scrub | heal_recompute | uncorrectable
        | array_loss instants on the fault lane."""
        self.tracer.instant(FAULT_TRACK, kind, unit=detail, step=step)
        self.metrics.inc(f"fault_{kind}")

    # -- sampling ---------------------------------------------------------------

    def wants_sample(self, step: int) -> bool:
        return self.metrics_on and step % self.sample_every == 0

    def sample(self, step: int, payload: dict) -> None:
        """Time-series tick: pool occupancy, Normal-vs-Augmented mode
        mix, queue depth, refresh debt, energy-ledger group totals —
        sampled into bounded series AND perfetto counter tracks (both
        delta-compressed: unchanged values re-record nothing)."""
        prev = self._last_series
        metrics_sample = self.metrics.sample
        for k, v in payload.items():
            if prev.get(k) != v:
                prev[k] = v
                metrics_sample(k, step, v)
        last = self._last_counters
        mix = (payload.get("mode_normal", 0),
               payload.get("mode_augmented", 0))
        if last.get("mode_mix") != mix:
            last["mode_mix"] = mix
            self.tracer.counter("mode_mix", normal=mix[0],
                                augmented=mix[1])
        occ = round(payload.get("pool_occupancy", 0.0), 4)
        if last.get("pool_occupancy") != occ:
            last["pool_occupancy"] = occ
            self.tracer.counter("pool_occupancy", frac=occ)
        depth = payload.get("queue_depth", 0)
        if last.get("queue_depth") != depth:
            last["queue_depth"] = depth
            self.tracer.counter("queue_depth", depth=depth)

    # -- export / summary --------------------------------------------------------

    def describe(self) -> dict:
        """Pure snapshot for stats()["obs"] — calling it never mutates
        the planes (stats() idempotence)."""
        m = self.metrics.describe()
        return {
            "enabled": True,
            "trace": self.trace_on,
            "metrics": self.metrics_on,
            "sample_every": self.sample_every,
            "trace_events": len(self.tracer.events),
            "open_spans": self.tracer.open_spans(),
            "requests_tracked": len(self._reqs),
            **m,
        }

    def export_trace(self, path: str) -> dict:
        from repro.obs.export import write_chrome_trace
        return write_chrome_trace(self.tracer, path)

    def export_metrics(self, path: str) -> str:
        from repro.obs.export import write_prometheus
        return write_prometheus(self.metrics, path)


class NullEngineObs:
    """Disabled observability: every hook is a constant no-op (shared
    nullcontext for the span sites), so the instrumented engine pays one
    attribute lookup + empty call per hook — unmeasurable against a
    device dispatch. `make_engine_obs` returns this unless a plane is
    switched on."""

    enabled = False
    trace_on = False
    metrics_on = False

    def on_enqueue(self, rid, prompt_len, max_new, step):
        pass

    def on_admit(self, rid, row, step):
        pass

    def prefill_span(self, rid, n_tokens):
        return _NULL_CTX

    def chunk_span(self, rid, n_tokens):
        return _NULL_CTX

    def on_tokens(self, rid, n, step):
        pass

    def on_preempt(self, rid, step, reason):
        pass

    def on_complete(self, rid, step):
        pass

    def on_failed(self, rid, step):
        pass

    def on_handoff(self, rid, step, kind):
        pass

    def step_span(self, step, kind):
        return _NULL_CTX

    def phase_span(self, name, **args):
        return _NULL_CTX

    def on_step_done(self, step, dt_s):
        pass

    def on_spec_round(self, accepted, rows, step):
        pass

    def on_queue_depth(self, depth):
        pass

    def on_placement(self, rid, array_id, policy, kind, step):
        pass

    def on_refresh_pass(self, n_units, step):
        pass

    def store_event(self, kind, unit, step):
        pass

    def on_prefix(self, kind, rid, tokens, step):
        pass

    def fault_span(self, step):
        return _NULL_CTX

    def on_fault(self, kind, detail, step):
        pass

    def wants_sample(self, step):
        return False

    def sample(self, step, payload):
        pass

    def describe(self):
        return {"enabled": False, "trace": False, "metrics": False}

    def export_trace(self, path):
        raise ValueError(
            "tracing is disabled on this engine — construct it with "
            "trace=True (or cfg.amc.trace=True / --trace-out) first")

    def export_metrics(self, path):
        raise ValueError(
            "metrics are disabled on this engine — construct it with "
            "metrics=True (or cfg.amc.metrics=True / --metrics-out) first")


NULL_OBS = NullEngineObs()


def make_engine_obs(amc_cfg, *, clock=None, pid=0, process="amc-serve",
                    epoch=None, registry=None):
    """AMCConfig -> the engine's obs facade (Null unless a plane is on).
    `pid`/`process`/`epoch`/`registry` are the fleet hooks: per-array
    trace lanes on one time base, one shared metrics registry."""
    if not (amc_cfg.trace or amc_cfg.metrics):
        return NULL_OBS
    return EngineObs(trace=amc_cfg.trace, metrics=amc_cfg.metrics,
                     sample_every=amc_cfg.obs_sample_every, clock=clock,
                     pid=pid, process=process, epoch=epoch,
                     registry=registry)
