"""Trace / metrics file exporters + Chrome-trace schema validation.

`write_chrome_trace` dumps a `Tracer` as perfetto-loadable Chrome trace
JSON; `write_prometheus` dumps a `MetricsRegistry` as text exposition.
`validate_chrome_trace` is the schema check the tests and the CI trace
artifact step run: it returns a list of problems (empty == valid) instead
of raising, so callers can report everything wrong at once.
"""
from __future__ import annotations

import json

_PHASES = {"X", "i", "C", "M"}
_REQUIRED = ("name", "ph", "pid", "tid")


def write_chrome_trace(tracer, path: str) -> dict:
    obj = tracer.chrome_trace()
    with open(path, "w") as f:
        json.dump(obj, f)
    return obj


def merge_chrome_traces(objs: list) -> dict:
    """Merge several Chrome trace objects (one per array pid) into one:
    metadata events first, timed events re-sorted into one global
    monotonic timeline. Only meaningful when the tracers shared an epoch
    (ArrayFleet passes one), so their timestamps are comparable."""
    meta, timed = [], []
    for obj in objs:
        for e in obj.get("traceEvents", ()):
            (meta if e.get("ph") == "M" else timed).append(e)
    timed.sort(key=lambda e: e.get("ts", 0.0))
    return {"traceEvents": meta + timed, "displayTimeUnit": "ms"}


def write_prometheus(registry, path: str) -> str:
    text = registry.prometheus_text()
    with open(path, "w") as f:
        f.write(text)
    return text


def validate_chrome_trace(obj) -> list[str]:
    """Schema-validate a Chrome trace JSON object (or a parsed file).

    Checks: top-level shape, per-event required keys, known phase types,
    timestamp presence + global monotonic order of timed events, span
    durations >= 0, no span left open at export, and that every
    non-metadata track is named by a thread_name metadata event."""
    problems: list[str] = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["top level must be an object with a traceEvents array"]
    events = obj["traceEvents"]
    if not isinstance(events, list):
        return ["traceEvents must be an array"]
    named_tracks = set()
    used_tracks = set()
    last_ts = None
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            problems.append(f"event {i}: not an object")
            continue
        for k in _REQUIRED:
            if k not in e:
                problems.append(f"event {i}: missing key {k!r}")
        ph = e.get("ph")
        if ph not in _PHASES:
            problems.append(f"event {i}: unknown phase {ph!r}")
            continue
        if ph == "M":
            if e.get("name") == "thread_name":
                named_tracks.add((e.get("pid"), e.get("tid")))
            continue
        used_tracks.add((e.get("pid"), e.get("tid")))
        ts = e.get("ts")
        if not isinstance(ts, (int, float)):
            problems.append(f"event {i}: ts missing or non-numeric")
            continue
        if last_ts is not None and ts < last_ts:
            problems.append(
                f"event {i}: timestamps not monotonic ({ts} < {last_ts})")
        last_ts = ts
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i}: span with bad dur {dur!r}")
            if e.get("args", {}).get("open_at_export"):
                problems.append(
                    f"event {i}: span {e.get('name')!r} left open at export")
        if ph == "i" and e.get("s") not in ("t", "p", "g"):
            problems.append(f"event {i}: instant with bad scope "
                            f"{e.get('s')!r}")
    for track in sorted(used_tracks - named_tracks):
        problems.append(f"track pid/tid {track} has events but no "
                        f"thread_name metadata")
    return problems


def validate_chrome_trace_file(path: str) -> list[str]:
    try:
        with open(path) as f:
            obj = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable trace file: {e}"]
    return validate_chrome_trace(obj)
