"""Granite-3.0-2B: 40L d=2048 32H (GQA kv=8) d_ff=8192 vocab=49155.
[hf:ibm-granite/granite-3.0-2b-base; hf-verified]"""
from repro.configs.base import AMCConfig, ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    family="dense",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab=49155,                   # padded to 49408
    tie_embeddings=True,
    act="swiglu",
    amc=AMCConfig(weight_mode="dual", kv_mode="int4"),
    source="hf:ibm-granite/granite-3.0-2b-base",
)
