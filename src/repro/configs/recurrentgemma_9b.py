"""RecurrentGemma-9B: 38L d=4096 16H (MQA kv=1) d_ff=12288 vocab=256000;
RG-LRU + local attention, pattern (rec, rec, attn). 38 layers = 12 macro-
blocks of 3 + 2 trailing recurrent layers. [arXiv:2402.19427; unverified]"""
from repro.configs.base import AMCConfig, HybridConfig, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,                  # MQA
    d_ff=12288,
    vocab=256000,
    head_dim=256,                  # gemma-style wide heads
    tie_embeddings=True,
    act="gelu",
    hybrid=HybridConfig(lru_width=4096, window=2048,
                        pattern=("rec", "rec", "attn")),
    amc=AMCConfig(weight_mode="dual", kv_mode="int4"),
    source="arXiv:2402.19427",
)
