"""Minitron-8B (pruned Nemotron): 32L d=4096 32H (GQA kv=8) d_ff=16384
vocab=256000. [arXiv:2407.14679; hf-verified]"""
from repro.configs.base import AMCConfig, ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab=256000,
    head_dim=128,
    act="swiglu",                  # squared-relu in paper; swiglu param-equiv
    amc=AMCConfig(weight_mode="dual", kv_mode="int4"),
    source="arXiv:2407.14679",
)
