"""Whisper-tiny: 4L enc + 4L dec, d=384 6H d_ff=1536 vocab=51865; enc-dec
with conv frontend STUB (input_specs provides precomputed frame embeddings).
[arXiv:2212.04356; unverified]"""
from repro.configs.base import AMCConfig, EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,                    # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,                   # padded to 51968 for 16-way vocab sharding
    act="gelu",
    rope_theta=0.0,                # whisper: learned positions, no RoPE
    encdec=EncDecConfig(n_encoder_layers=4, n_frames=1500, frame_dim=384),
    amc=AMCConfig(weight_mode="ternary", kv_mode="int8"),
    source="arXiv:2212.04356",
)
