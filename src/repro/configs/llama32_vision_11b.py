"""Llama-3.2-11B-Vision backbone: 40L (32 self + 8 cross-attn) d=4096 32H
(GQA kv=8) d_ff=14336 vocab=128256; vision frontend STUB (input_specs
provides projected patch embeddings). [hf:meta-llama/Llama-3.2-11B-Vision;
unverified]"""
from repro.configs.base import AMCConfig, ModelConfig, VisionConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,                   # total: 8 macro-blocks of (4 self + 1 cross)
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    head_dim=128,
    rope_theta=5e5,
    act="swiglu",
    vision=VisionConfig(cross_attn_every=5, n_cross_layers=8,
                        n_patches=1601, vision_dim=4096),
    amc=AMCConfig(weight_mode="dual", kv_mode="int4"),
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
