"""Model/shape configuration system.

Every assigned architecture is an exact `ModelConfig`; every assigned input
shape is a `ShapeConfig`.  `input_specs()` produces ShapeDtypeStruct
stand-ins (no allocation) for the dry-run; `reduced()` produces the small
same-family config used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def pad_to(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # "ep": experts sharded over the model axis (requires divisibility);
    # "tp": expert FFN hidden dim sharded over the model axis.
    sharding: str = "ep"
    # dense FFN interleave (qwen3-moe uses pure MoE; grok uses MoE every layer)
    shared_expert: bool = False


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128     # N (ssm_state)
    head_dim: int = 64       # P
    expand: int = 2          # d_inner = expand * d_model
    n_groups: int = 1
    conv_dim: int = 4
    chunk: int = 256         # SSD chunk length


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    lru_width: int = 4096
    window: int = 2048            # local attention window
    pattern: Tuple[str, ...] = ("rec", "rec", "attn")


@dataclasses.dataclass(frozen=True)
class VisionConfig:
    cross_attn_every: int = 5     # one cross-attn layer per this many layers
    n_cross_layers: int = 8
    n_patches: int = 1601         # 1 CLS + 40x40 patches (llama-3.2 vision)
    vision_dim: int = 4096        # projected patch embedding dim (stub)


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_encoder_layers: int = 4
    n_frames: int = 1500          # whisper 30s @ 50Hz after conv stub
    frame_dim: int = 384


@dataclasses.dataclass(frozen=True)
class AMCConfig:
    """Augmented-memory settings for this model instance."""
    weight_mode: str = "normal"     # normal | ternary | dual
    ternary_fmt: str = "2bit"       # base3 | 2bit (kernels prefer 2bit)
    kv_mode: str = "normal"         # normal | int4 | int8
    # Decode-attention implementation for packed kv_modes: "kernel" streams
    # the packed cache through the Pallas flash-decode kernel (the cache is
    # never dequantized in HBM); "dequant" is the reference unpack-then-dense
    # path kept for golden-equivalence tests and debugging.
    kv_impl: str = "kernel"         # kernel | dequant
    # Matmul implementation for augmented weight storage: "packed" streams
    # the packed bytes through the Pallas matmul kernels; "dense" is the
    # dequantize-then-XLA reference path; "imc" evaluates the dot product
    # IN the array — wordline-serial activation bits x bitline-parallel
    # accumulation (kernels/imc_dot.py), with array-level event/energy
    # accounting in imc/energy.py. Dense (unpacked) weights have no
    # resident array and fall back to the fetch model under "imc".
    matmul_impl: str = "packed"     # dense | packed | imc
    # Activation precision of the bit-serial IMC path: 1/4/8 bits
    # (arXiv:2008.03378's reconfigurable bit-precision).
    imc_abits: int = 8
    retention_steps: int = 8
    # -- paged augmented KV pool (serve/cache_pool.py) ----------------------
    # Tokens per page: the mode-switch granularity of the pool (the paper's
    # per-sub-array WL/SL reconfiguration unit).
    page_size: int = 16
    # Pool mode policy: "auto" derives the legacy-equivalent behavior from
    # kv_mode (normal -> normal-only, int4/int8 -> always-augmented);
    # "augment-on-pressure" starts pages in Normal mode and augments cold
    # pages in place when the byte budget runs out (the paper's on-demand
    # capacity); "normal-only" / "always-augmented" pin the mode.
    pool_mode: str = "auto"
    # Refresh policy: promote expired augmented pages back to Normal when
    # the budget has room (augment-on-pressure only); otherwise they are
    # re-written in place (restamped) and the traffic is accounted.
    refresh_promote: bool = True
    # -- shared-prefix page reuse (serve/prefix.py) -------------------------
    # Capacity of the engine's PrefixIndex in cached prefix entries. When
    # > 0 (paged stores only) prompt prefixes are hashed page-granularly
    # into a share band of the pool: later requests with the same prefix
    # map the SAME physical pages into their page tables (refcounted) and
    # prefill only the tail; divergence copies-on-write the boundary page.
    # Cold shared prefixes DEMOTE Normal -> Augmented under byte pressure
    # instead of being evicted (the dual-context ROM-augmented 8T RAM,
    # arXiv:2304.02908) and are only freed at refcount 0. 0 disables the
    # index entirely (no share band, zero hot-path cost).
    prefix_cache: int = 0
    # -- augmented recurrent-state store (serve/state_store.py) -------------
    # Packed width of an Augmented recurrent-state slab (SSM/LRU/conv state
    # of ssm/hybrid rows, static prefix KV of vlm rows): int8 stores one
    # value per byte, int4 nibble-packs pairs — the slab-granularity
    # analogue of the pool's per-page aug_bits.
    state_bits: int = 8
    # -- retention-fault injection & self-healing (core/faults.py) ----------
    # Per-unit (page/slab), per-decode-step probability of an early
    # retention expiry for a dynamic unit at the END of its retention
    # window at 85C; younger units scale down linearly with age and
    # colder arrays through LeakageModel (Tables I-II tails). 0 disables
    # the whole fault machinery (zero hot-path overhead).
    fault_rate: float = 0.0
    # Seed of the deterministic fault schedule (chaos runs reproduce).
    fault_seed: int = 0
    # Per-step probability of a whole-array failure event; the engine's
    # Supervisor drains and requeues every active row (tokens preserved).
    array_loss_rate: float = 0.0
    # Modeled array temperature the fault tails are sampled at (85C is
    # the paper's hot calibration point; 25C cuts the 8T rate 10x).
    fault_temp_c: float = 85.0
    # Verify integrity words (checksum over packed payload + scales) on
    # gather/refresh so corrupted reads are detected, never served.
    # Only consulted when fault injection is active; disabling it with a
    # nonzero fault_rate is the silent-corruption ablation.
    integrity_check: bool = True
    # Request-level bound on fault-recovery retries (recompute-via-
    # preemption with exponential backoff); past it the request is
    # surfaced as an accounted failure, never silently served.
    max_retries: int = 3
    # Detections of the SAME physical unit before it is pinned back to
    # Normal mode / decommissioned (repeat-offender = weak cell).
    fault_pin_threshold: int = 3
    # -- self-speculative decoding (serve/engine.py) ------------------------
    # Window size: spec_k - 1 tokens are drafted per round from the cheap
    # (dynamic-plane) representation and the whole spec_k-token window is
    # verified in ONE full-path dispatch; greedy accept/rollback keeps the
    # emitted stream token-identical to step-by-step decode. 1 disables.
    spec_k: int = 1
    # Cheap representation the draft pass decodes with: "dequant" reads the
    # pool through the dequantize-then-dense path (no Pallas dispatch),
    # "dense"/"packed" force that matmul_impl, "imc8"/"imc4"/"imc1" run the
    # bit-serial IMC matmuls at that activation precision (the dynamic-
    # plane read of the 8T duality), "same" drafts with the full config.
    spec_draft_impl: str = "dequant"
    # -- array fleet (serve/fleet.py) ---------------------------------------
    # Number of logical SRAM arrays the serving stack instantiates. 1 is
    # the classic single-array `ServeEngine`; above 1 an `ArrayFleet`
    # runs one engine per array — each with its OWN byte budget, state
    # store, refresh clock, fault domain and energy ledger — over a
    # partition of the jax device mesh (arrays share devices when there
    # are fewer devices than arrays).
    num_arrays: int = 1
    # Fleet admission policy (serve/placement.py): "least-loaded" (fewest
    # running+queued requests), "budget-headroom" (most free bytes), or
    # "affinity" (prompt-prefix hash -> preferred array for shared-prefix
    # locality, falling back to least-loaded under pressure).
    placement: str = "least-loaded"
    # -- observability (obs/) ------------------------------------------------
    # Chrome-trace span/instant recording of the full request lifecycle
    # (one perfetto lane per request + engine/scheduler/refresh/fault
    # lanes). Off by default: the engine then holds a null facade whose
    # hooks are constant no-ops on the decode hot path.
    trace: bool = False
    # Host-side metrics plane: latency histograms (TTFT, queue wait,
    # inter-token, step wall) plus sampled time series (pool occupancy,
    # Normal/Augmented mode mix, refresh debt, energy-group totals),
    # folded into stats()["obs"] and exportable as Prometheus text.
    metrics: bool = False
    # Sample the time-series payload every N engine steps (1 = each step;
    # raise on long runs to bound sampling work — the series themselves
    # are already memory-bounded).
    obs_sample_every: int = 1

    @property
    def aug_bits(self) -> int:
        """Augmented-plane width for the paged pool: follows kv_mode,
        int8 when the model itself serves a Normal cache (conservative
        default for pressure-augmented pages of a bf16 pool)."""
        return 4 if self.kv_mode == "int4" else 8

    @property
    def resolved_pool_mode(self) -> str:
        """``auto`` maps kv_mode onto the legacy-equivalent pool policy:
        a normal cache serves from Normal pages, a packed cache from
        Augmented pages; augment-on-pressure must be asked for."""
        if self.pool_mode == "auto":
            return "normal-only" if self.kv_mode == "normal" \
                else "always-augmented"
        return self.pool_mode


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | audio | vlm | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "swiglu"            # swiglu | gelu
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    vision: Optional[VisionConfig] = None
    encdec: Optional[EncDecConfig] = None
    amc: AMCConfig = dataclasses.field(default_factory=AMCConfig)
    source: str = ""

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def vocab_padded(self) -> int:
        return pad_to(self.vocab, 256)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Supports 500k-token decode (bounded state / windowed attention)."""
        return self.family in ("ssm", "hybrid")

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encdec is not None

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS and capacity tables)."""
        d, v = self.d_model, self.vocab_padded
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d  # lm head
        per_layer = 0
        hd, H, KV = self.hd, self.n_heads, self.n_kv_heads
        attn = d * H * hd + 2 * d * KV * hd + H * hd * d
        ffn_mults = 3 if self.act == "swiglu" else 2
        if self.family == "ssm":
            s = self.ssm
            din = s.expand * d
            per_layer = d * (2 * din) + din * d + din * 2 * s.state_dim
        elif self.family == "hybrid":
            h = self.hybrid
            rec = 2 * d * h.lru_width + h.lru_width * d + 2 * h.lru_width
            att = attn
            npat = len(h.pattern)
            n_att = self.n_layers // npat
            n_rec = self.n_layers - n_att
            per_layer = 0
            n += n_rec * (rec + ffn_mults * d * self.d_ff + 2 * d)
            n += n_att * (att + ffn_mults * d * self.d_ff + 2 * d)
            return n
        elif self.moe is not None:
            per_layer = attn + self.moe.n_experts * ffn_mults * d * self.d_ff
            per_layer += d * self.moe.n_experts  # router
        else:
            per_layer = attn + ffn_mults * d * self.d_ff
        if self.vision is not None:
            cross = d * H * hd + 2 * d * self.n_kv_heads * hd + H * hd * d
            n += self.vision.n_cross_layers * (cross + ffn_mults * d * self.d_ff)
        per_layer += 2 * d  # norms
        n += self.n_layers * per_layer
        if self.encdec is not None:
            enc_layer = attn + ffn_mults * d * self.d_ff + 2 * d
            dec_cross = attn
            n += self.encdec.n_encoder_layers * enc_layer
            n += self.n_layers * dec_cross  # decoder cross-attn blocks
        return int(n)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only top-k experts count)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        ffn_mults = 3 if self.act == "swiglu" else 2
        full = self.param_count()
        inactive = (self.moe.n_experts - self.moe.top_k) * ffn_mults * d * self.d_ff
        return int(full - self.n_layers * inactive)

    def nonembed_param_count(self) -> int:
        v, d = self.vocab_padded, self.d_model
        emb = v * d * (1 if self.tie_embeddings else 2)
        return self.param_count() - emb

    def nonembed_active_param_count(self) -> int:
        v, d = self.vocab_padded, self.d_model
        emb = v * d * (1 if self.tie_embeddings else 2)
        return self.active_param_count() - emb

    def model_flops(self, shape: "ShapeConfig") -> float:
        """Analytic useful FLOPs per step (global): 6ND train / 2ND fwd for
        non-embedding active params, plus the LM-head matmul explicitly."""
        tokens = shape.global_batch * (shape.seq_len
                                       if shape.kind != "decode" else 1)
        mult = 6 if shape.kind == "train" else 2
        body = mult * self.nonembed_active_param_count() * tokens
        head = mult * tokens * self.d_model * self.vocab_padded
        return float(body + head)

    def reduced(self) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-reduced",
            family=self.family,
            n_layers=min(self.n_layers, 2 if self.hybrid is None else 3),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads < self.n_heads else 4,
            d_ff=256,
            vocab=512,
            head_dim=32,
            qkv_bias=self.qkv_bias,
            act=self.act,
            tie_embeddings=self.tie_embeddings,
            amc=self.amc,
            source=self.source,
        )
        if self.moe:
            kw["moe"] = dataclasses.replace(self.moe, n_experts=8, top_k=2)
            kw["d_ff"] = 64
        if self.ssm:
            kw["ssm"] = SSMConfig(state_dim=16, head_dim=16, expand=2,
                                  conv_dim=4, chunk=32)
            kw["n_heads"] = 0
            kw["n_kv_heads"] = 0
            kw["head_dim"] = None
        if self.hybrid:
            kw["hybrid"] = HybridConfig(lru_width=128, window=16,
                                        pattern=self.hybrid.pattern)
            kw["n_layers"] = 4   # 1 macro-block (rec,rec,attn) + 1 tail rec
            kw["n_kv_heads"] = 1
        if self.vision:
            kw["vision"] = VisionConfig(cross_attn_every=5, n_cross_layers=1,
                                        n_patches=16, vision_dim=128)
            kw["n_layers"] = 5   # 1 macro-block: 4 self + 1 cross
        if self.encdec:
            kw["encdec"] = EncDecConfig(n_encoder_layers=2, n_frames=16,
                                        frame_dim=128)
            kw["n_layers"] = 2
        return ModelConfig(**kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell runs, with a skip reason otherwise."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full quadratic attention at 524k context: "
                       "skipped per assignment (sub-quadratic archs only)")
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    specs = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        specs["targets"] = jax.ShapeDtypeStruct((B, S), i32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    else:  # decode: one new token against a cache of S
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
        specs["positions"] = jax.ShapeDtypeStruct((B,), i32)
    if cfg.encdec is not None:
        e = cfg.encdec
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, e.n_frames, e.frame_dim), jnp.bfloat16)
    if cfg.vision is not None:
        v = cfg.vision
        specs["patches"] = jax.ShapeDtypeStruct(
            (B, v.n_patches, v.vision_dim), jnp.bfloat16)
    return specs
