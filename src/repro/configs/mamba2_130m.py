"""Mamba2-130M: 24L d=768, attention-free SSD blocks, ssm_state=128,
vocab=50280. [arXiv:2405.21060; unverified]"""
from repro.configs.base import AMCConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,                     # attention-free
    n_kv_heads=0,
    d_ff=0,                        # no separate MLP; SSD block contains it
    vocab=50280,                   # padded to 50432
    tie_embeddings=True,
    act="swiglu",
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, n_groups=1,
                  conv_dim=4, chunk=256),
    amc=AMCConfig(weight_mode="ternary", kv_mode="normal"),  # no KV cache
    source="arXiv:2405.21060",
)
