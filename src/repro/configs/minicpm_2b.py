"""MiniCPM-2B: 40L d=2304 36H (kv=36, MHA) d_ff=5760 vocab=122753;
llama-like arch trained with the WSD schedule (optim/schedule.py).
[arXiv:2404.06395; hf-verified]"""
from repro.configs.base import AMCConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,                    # 36 % 16 != 0 -> attention TP disabled
    n_kv_heads=36,
    d_ff=5760,
    vocab=122753,                  # padded to 122880
    tie_embeddings=True,
    act="swiglu",
    amc=AMCConfig(weight_mode="dual", kv_mode="int4"),
    source="arXiv:2404.06395",
)
