"""Qwen3-30B-A3B: 48L d=2048 32H (GQA kv=4) moe_d_ff=768, 128 experts top-8.
[hf:Qwen/Qwen3-30B-A3B; hf-verified]"""
from repro.configs.base import AMCConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,                      # per-expert MoE intermediate size
    vocab=151936,
    head_dim=128,                  # qwen3 uses explicit head_dim 128
    rope_theta=1e6,
    act="swiglu",
    moe=MoEConfig(n_experts=128, top_k=8, capacity_factor=1.25,
                  sharding="ep"),  # 128 experts / 16-way model axis = 8/dev
    amc=AMCConfig(weight_mode="dual", kv_mode="int4"),
    source="hf:Qwen/Qwen3-30B-A3B",
)
