"""Registry of all assigned architectures and shapes."""
from __future__ import annotations

from repro.configs import (grok_1_314b, granite_3_2b, llama32_vision_11b,
                           mamba2_130m, minicpm_2b, minitron_8b,
                           qwen15_0_5b, qwen3_moe_30b_a3b,
                           recurrentgemma_9b, whisper_tiny)
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, cell_applicable

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in [
        qwen3_moe_30b_a3b.CONFIG,
        grok_1_314b.CONFIG,
        whisper_tiny.CONFIG,
        minitron_8b.CONFIG,
        granite_3_2b.CONFIG,
        qwen15_0_5b.CONFIG,
        minicpm_2b.CONFIG,
        llama32_vision_11b.CONFIG,
        mamba2_130m.CONFIG,
        recurrentgemma_9b.CONFIG,
    ]
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def all_cells():
    """Yield every (arch, shape, applicable, skip_reason) cell — 40 total."""
    for aname, cfg in ARCHS.items():
        for sname, shape in SHAPES.items():
            ok, reason = cell_applicable(cfg, shape)
            yield cfg, shape, ok, reason
