"""Grok-1 314B: 64L d=6144 48H (GQA kv=8) d_ff=32768, 8 experts top-2.
[hf:xai-org/grok-1; unverified]"""
from repro.configs.base import AMCConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab=131072,
    head_dim=128,
    act="swiglu",                  # grok's MoE MLP is gated (3-matrix geglu
                                   # form) -> ~314B total params
    # 8 experts do not divide the 16-way model axis -> TP mode: the expert
    # hidden dim (32768/16=2048) is sharded instead (see DESIGN.md SS4).
    moe=MoEConfig(n_experts=8, top_k=2, capacity_factor=1.25, sharding="tp"),
    amc=AMCConfig(weight_mode="dual", kv_mode="int8"),
    source="hf:xai-org/grok-1",
)
