from repro.configs.base import (AMCConfig, ModelConfig, MoEConfig,
                                ShapeConfig, SHAPES, cell_applicable,
                                input_specs)
from repro.configs.registry import ARCHS, all_cells, get_arch, get_shape

__all__ = ["AMCConfig", "ModelConfig", "MoEConfig", "ShapeConfig", "SHAPES",
           "ARCHS", "all_cells", "get_arch", "get_shape", "cell_applicable",
           "input_specs"]
