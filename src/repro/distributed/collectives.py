"""Distributed-optimization collectives.

`compressed_psum_mean` — int8 error-feedback gradient all-reduce for the
slow cross-pod hop: each shard quantizes its local gradient to int8 with a
per-row scale (the augmented-memory write, same machinery as AMC-Adam),
all-reduces the int8 payload + scales in f32 (4x fewer bytes than bf16
gradients), and keeps the quantization residual locally, feeding it back
into the next step's gradient (error feedback — unbiased in the long run,
standard in 1-bit/8-bit Adam literature).

Implemented with shard_map + jax.lax collectives so the compressed wire
format is explicit in the HLO (visible to the dry-run's collective-bytes
accounting).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
try:
    from jax import shard_map
except ImportError:  # pre-0.5 jax: shard_map lives in experimental
    from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def _q8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0,
                        1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_allreduce_mean(g: jax.Array, axis_name: str,
                              residual: Optional[jax.Array] = None):
    """Inside shard_map: int8+scale all-reduce-mean of `g` over axis_name.

    Returns (g_mean, new_residual). Payload: 1 byte/elem + 4/row instead of
    2-4 bytes/elem.
    """
    gf = g.astype(jnp.float32)
    if residual is not None:
        gf = gf + residual
    if gf.ndim == 0:
        gf2 = gf[None, None]
    elif gf.ndim == 1:
        gf2 = gf[None, :]
    else:
        gf2 = gf
    q, scale = _q8(gf2)
    deq = q.astype(jnp.float32) * scale
    new_residual = (gf2 - deq).reshape(gf.shape)
    # the wire: int8 payload + f32 per-row scales
    summed = jax.lax.psum(q.astype(jnp.float32) * scale, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    out = (summed / n).reshape(gf.shape)
    return out.astype(g.dtype), new_residual


def make_compressed_grad_allreduce(mesh, axis: str = "pod"):
    """Tree-level compressed mean over `axis` (identity if axis absent).

    Used by the trainer when cross-pod links are the bottleneck: in-pod
    reduction stays in native precision (XLA's psum via pjit), only the
    cross-pod hop is compressed.
    """
    if axis not in mesh.shape or mesh.shape[axis] == 1:
        return None

    def one(g, res):
        spec = P(*([None] * g.ndim))

        def f(gl, rl):
            out, new_res = compressed_allreduce_mean(gl, axis, rl)
            return out, new_res

        return shard_map(f, mesh=mesh, in_specs=(spec, spec),
                         out_specs=(spec, spec))(g, res)

    def tree_allreduce(grads, residuals):
        if residuals is None:
            residuals = jax.tree.map(
                lambda g: jnp.zeros(g.shape, jnp.float32), grads)
        flat_g, treedef = jax.tree.flatten(grads)
        flat_r = jax.tree.leaves(residuals)
        outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
        new_g = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_r = jax.tree.unflatten(treedef, [o[1] for o in outs])
        return new_g, new_r

    return tree_allreduce
