"""Microbatch pipeline parallelism over the "pod" axis (GPipe-style).

For workloads where cross-pod DP gradient traffic dominates, the pod axis
can instead carry PIPELINE stages: each pod owns a contiguous block of
layers; microbatches stream stage-to-stage via collective_permute
(point-to-point over the inter-pod links), overlapping the transfer of
microbatch i+1 with the compute of microbatch i.

Implemented with shard_map over the "pod" axis: each stage holds its layer
block (params stacked (n_stages, L/n_stages, ...) and sharded on dim 0);
the schedule runs n_micro + n_stages - 1 ticks (fill + steady state +
drain). This is the forward pipeline used for serving/inference scale-out;
for training, the trainer composes it with DP/TP inside each pod.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
try:
    from jax import shard_map
except ImportError:  # pre-0.5 jax: shard_map lives in experimental
    from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def pipeline_forward(mesh, stage_fn: Callable, n_micro: int,
                     axis: str = "pod"):
    """Build a pipelined forward over `axis`.

    stage_fn(stage_params, x) -> x, applied by every stage to whatever
    microbatch currently occupies it.

    Returns fn(stage_params_stacked, x_microbatched):
      stage_params_stacked: (n_stages, ...) sharded on dim 0 over `axis`
      x_microbatched: (n_micro, mb, ...) replicated
      -> (n_micro, mb, ...) outputs (each microbatch processed by ALL
         stages in order).
    """
    n_stages = mesh.shape[axis]

    def pipelined(params, xs):
        def local(params_l, xs_l):
            # params_l: (1, ...) this stage's block; xs_l: full (n_micro,...)
            stage = jax.lax.axis_index(axis)
            p = jax.tree.map(lambda t: t[0], params_l)
            n_ticks = n_micro + n_stages - 1
            mb_shape = xs_l.shape[1:]

            def tick(carry, t):
                buf, outs = carry           # buf: current occupant (mb,...)
                # stage 0 ingests microbatch t (if any)
                src = jnp.where(t < n_micro, t, n_micro - 1)
                fresh = jax.lax.dynamic_index_in_dim(xs_l, src, 0,
                                                     keepdims=False)
                x_in = jnp.where(stage == 0, fresh, buf)
                active = (t >= stage) & (t - stage < n_micro)
                y = stage_fn(p, x_in)
                y = jnp.where(active, y, buf)
                # last stage emits microbatch (t - n_stages + 1)
                emit_idx = t - (n_stages - 1)
                do_emit = (stage == n_stages - 1) & (emit_idx >= 0)
                emit = jnp.maximum(emit_idx, 0)
                cur = jax.lax.dynamic_index_in_dim(outs, emit, 0,
                                                   keepdims=False)
                newval = jnp.where(do_emit, y.astype(outs.dtype), cur)
                outs = jax.lax.dynamic_update_index_in_dim(outs, newval,
                                                           emit, 0)
                # shift: stage s sends to s+1 (ring permute; wraparound
                # harmless — stage 0 overwrites from fresh input)
                perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
                nxt = jax.lax.ppermute(y, axis, perm)
                return (nxt, outs), None

            # pcast marks carries as axis-varying for the new vartype
            # checker; absent (pre-0.5 jax) everything in shard_map is
            # already local/varying, so it degrades to identity.
            pcast = getattr(jax.lax, "pcast",
                            lambda t, _axes, to: t)
            buf0 = pcast(jnp.zeros(mb_shape, xs_l.dtype), (axis,),
                         to="varying")
            outs0 = pcast(jnp.zeros_like(xs_l), (axis,),
                          to="varying")
            (_, outs), _ = jax.lax.scan(tick, (buf0, outs0),
                                        jnp.arange(n_ticks))
            # only the last stage holds valid outputs; replicate them
            outs = jnp.where(stage == n_stages - 1, outs, 0)
            return jax.lax.psum(outs, axis)

        in_specs = (jax.tree.map(lambda _: P(axis), params),
                    P(*([None] * xs.ndim)))
        return shard_map(local, mesh=mesh,
                         in_specs=in_specs,
                         out_specs=P(*([None] * xs.ndim)))(params, xs)

    return pipelined
