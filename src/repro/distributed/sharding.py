"""Logical-axis sharding rules (MaxText-style), resolved per (config, mesh).

Every parameter/activation dimension carries a LOGICAL axis name; `Rules`
maps logical names to physical mesh axes, degrading gracefully (replicate)
when a dimension does not divide the mesh axis — e.g. minicpm's 36 heads and
whisper's 6 heads cannot be tensor-parallel 16 ways, so `heads` resolves to
None for those archs and the FFN still gets TP via `mlp`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# Logical axis vocabulary. Values are the *preferred* physical axes;
# Rules.resolve() drops entries that don't divide.
DEFAULT_RULES: dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),     # pure DP
    "seq": (),                    # unsharded by default
    "seq_sp": ("model",),         # sequence parallelism (MoE dispatch, cache)
    "embed": ("data",),           # FSDP: weight d_model dim over data axis
    "heads": ("model",),          # Megatron TP
    "kv_heads": ("model",),
    "mlp": ("model",),            # d_ff TP
    "experts": ("model",),        # EP
    "vocab": ("model",),
    "lru": ("model",),            # RG-LRU width / SSM inner dim
    "cache_seq": ("model",),      # decode KV cache sequence sharding (SP)
    "cache_batch": ("pod", "data"),
    "packed": (),                 # 2-bit/nibble-packed contraction dims:
                                  # sub-byte strides cannot take FSDP slicing
                                  # -> replicated; the output dim keeps TP
    "frames": (),                 # encoder frames / vision patches
    "replicated": (),
}


@dataclasses.dataclass(frozen=True)
class Rules:
    mesh: Mesh
    sizes: dict          # logical axis -> dim size it must divide (0=any)
    table: dict

    @staticmethod
    def make(mesh: Mesh, cfg=None, shape=None,
             overrides: Optional[dict] = None) -> "Rules":
        sizes = {}
        if cfg is not None:
            sizes = {
                "heads": cfg.n_heads,
                "kv_heads": cfg.n_kv_heads,
                "mlp": cfg.d_ff,
                "embed": cfg.d_model,
                "vocab": cfg.vocab_padded,
                "experts": cfg.moe.n_experts if cfg.moe else 0,
                "lru": (cfg.hybrid.lru_width if cfg.hybrid
                        else (cfg.ssm.expand * cfg.d_model if cfg.ssm else 0)),
            }
            if cfg.moe and cfg.moe.sharding == "tp":
                # experts don't divide the model axis -> TP the expert FFN
                sizes["experts"] = 1  # force replication of the expert axis
        if shape is not None:
            sizes["batch"] = shape.global_batch
            sizes["cache_batch"] = shape.global_batch
            sizes["seq_sp"] = shape.seq_len
            sizes["cache_seq"] = shape.seq_len
        if cfg is not None:
            tp = mesh.shape.get("model", 1)
            if cfg.n_kv_heads and tp > 1 and cfg.n_kv_heads % tp == 0:
                # KV heads take the model axis -> the cache seq dim must
                # not double-claim it (SP on the cache is the fallback for
                # kv_heads < tp only)
                sizes["cache_seq"] = 1
        table = dict(DEFAULT_RULES)
        if shape is not None and shape.kind != "train" and cfg is not None:
            # Inference profile: no optimizer state -> FSDP weight sharding
            # buys nothing and costs an all-gather per layer per step; keep
            # weights TP-sharded only (beyond-paper optimization, see
            # EXPERIMENTS.md SSPerf cell C iteration 2) — unless the
            # TP-sharded weights alone would blow the 16 GiB HBM budget
            # (grok-1: 316B*2B/16 = 39.5 GiB -> keep FSDP for serving).
            tp = mesh.shape.get("model", 1)
            if cfg.param_count() * 2 / tp < 8 * 1024**3:
                table["embed"] = ()
        if overrides:
            table.update(overrides)
        return Rules(mesh, sizes, table)

    def resolve(self, logical: Optional[str]) -> Optional[Tuple[str, ...]]:
        """Logical axis -> tuple of mesh axes (or None = replicated)."""
        if logical is None:
            return None
        axes = [a for a in self.table.get(logical, ()) if a in self.mesh.shape]
        if not axes:
            return None
        total = 1
        for a in axes:
            total *= self.mesh.shape[a]
        need = self.sizes.get(logical, 0)
        if need and need % total != 0:
            # try progressively smaller prefixes before replicating
            for cut in range(len(axes) - 1, 0, -1):
                t = 1
                for a in axes[:cut]:
                    t *= self.mesh.shape[a]
                if need % t == 0:
                    return tuple(axes[:cut])
            return None
        return tuple(axes) if axes else None

    def pspec(self, *logical_axes) -> P:
        return P(*[self.resolve(a) for a in logical_axes])

    def sharding(self, *logical_axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.pspec(*logical_axes))


def constrain(x: jax.Array, rules: Optional[Rules], *logical_axes) -> jax.Array:
    """with_sharding_constraint by logical axes (no-op without rules)."""
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, rules.sharding(*logical_axes))


def tree_pspecs(abstract_tree, rules: Rules):
    """Map a tree of PSpec leaves (configs side) to PartitionSpecs."""
    from repro.models.params import PSpec  # local import to avoid cycle
    return jax.tree.map(
        lambda l: rules.pspec(*l.axes) if isinstance(l, PSpec) else P(),
        abstract_tree, is_leaf=lambda l: isinstance(l, PSpec))
