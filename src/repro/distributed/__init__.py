from repro.distributed.sharding import Rules, constrain
from repro.distributed.fault import (SimulatedFailure, StragglerMonitor,
                                     Supervisor)

__all__ = ["Rules", "constrain", "SimulatedFailure", "StragglerMonitor",
           "Supervisor"]
