"""Fault tolerance & straggler mitigation (host-side supervisor).

On a real cluster these hooks bind to the TPU runtime's health API and the
coordination service; here they are driven by injectable simulators so the
behaviour is testable:

  * `Supervisor.run_step` catches worker failure (SimulatedFailure or any
    exception matching `retryable`), restores the latest checkpoint
    (including the data-iterator position) and resumes — the fault path the
    multi-pod deployment relies on.
  * `StragglerMonitor` tracks a per-step wall-time EWMA; a step slower than
    `threshold` x EWMA flags the step, and after `patience` consecutive
    flags requests mitigation (on a real pod: demote the slow host /
    re-shard its data; here: recorded + callback).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional


class SimulatedFailure(RuntimeError):
    """Injected node failure for tests/examples."""


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 2.0
    patience: int = 3
    decay: float = 0.9
    on_straggler: Optional[Callable[[int, float, float], None]] = None
    _ewma: float = dataclasses.field(default=0.0, init=False)
    _flags: int = dataclasses.field(default=0, init=False)
    events: list = dataclasses.field(default_factory=list, init=False)
    # aggregate wall-time accumulators over every recorded step
    n_steps: int = dataclasses.field(default=0, init=False)
    total_s: float = dataclasses.field(default=0.0, init=False)
    min_s: float = dataclasses.field(default=0.0, init=False)
    max_s: float = dataclasses.field(default=0.0, init=False)

    def record(self, step: int, dt: float) -> bool:
        """Returns True if mitigation was requested at this step."""
        self.min_s = dt if self.n_steps == 0 else min(self.min_s, dt)
        self.max_s = dt if self.n_steps == 0 else max(self.max_s, dt)
        self.n_steps += 1
        self.total_s += dt
        if self._ewma == 0.0:
            self._ewma = dt
            return False
        slow = dt > self.threshold * self._ewma
        self._flags = self._flags + 1 if slow else 0
        # slow steps poison the EWMA less
        w = self.decay if not slow else 0.98
        self._ewma = w * self._ewma + (1 - w) * dt
        if self._flags >= self.patience:
            self.events.append((step, dt, self._ewma))
            if self.on_straggler:
                self.on_straggler(step, dt, self._ewma)
            self._flags = 0
            return True
        return False

    def describe(self) -> dict:
        """Pure wall-time summary of every recorded step (engine
        stats()["step_times"])."""
        return {
            "n_steps": self.n_steps,
            "min_s": self.min_s,
            "mean_s": self.total_s / self.n_steps if self.n_steps else 0.0,
            "max_s": self.max_s,
            "ewma_s": self._ewma,
            "mitigations": len(self.events),
        }


class Supervisor:
    """Wraps the train loop with catch -> restore -> resume."""

    def __init__(self, restore_fn: Callable[[], int], max_restarts: int = 5,
                 retryable=(SimulatedFailure,)):
        self.restore_fn = restore_fn
        self.max_restarts = max_restarts
        self.retryable = retryable
        self.restarts = 0

    def run_step(self, step_fn: Callable[[], None]) -> bool:
        """Returns True if the step ran, False if it was recovered."""
        try:
            step_fn()
            return True
        except self.retryable:
            self.restarts += 1
            if self.restarts > self.max_restarts:
                raise
            self.restore_fn()
            return False
