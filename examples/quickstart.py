"""Quickstart: the AMC library in 60 lines.

  PYTHONPATH=src python examples/quickstart.py

Shows the paper's two augmented cells as framework objects:
  1. an AugmentedStore switching Normal -> Augmented-dual (8T) with the
     FILO discipline and a refresh,
  2. ternary (7T) packed weights driving the Pallas ternary matmul,
  3. the capacity augmentation numbers.
"""
import jax
import jax.numpy as jnp

from repro.core import AugmentedStore, FILOViolation, Mode
from repro.core import ternary
from repro.kernels import ops

key = jax.random.PRNGKey(0)

# --- 1. the 8T dual-bit cell as a buffer ------------------------------------
store = AugmentedStore((256, 256), retention_steps=4)
weights = jax.random.normal(key, (256, 256))
store.write_static(weights)                    # Normal mode: plain bf16
print(f"normal mode: {store.physical_bytes()} bytes, "
      f"{store.bits_per_value()} bits/value")

store.set_mode(Mode.AUGMENTED_DUAL)            # augment on demand
print(f"augmented:   {store.physical_bytes()} bytes, "
      f"{store.bits_per_value()} bits/value "
      f"({store.capacity_factor():.0f}x capacity)")

acts = jax.random.normal(jax.random.fold_in(key, 1), (256, 256))
store.push_dynamic(acts)                       # stream activations in
try:
    store.read_static()                        # FILO violation!
except FILOViolation as e:
    print("FILO enforced:", str(e)[:60], "...")
_ = store.pop_dynamic()                        # drain dynamic first
_ = store.read_static()                        # now fine
store.tick(10)                                 # past retention window
store.push_dynamic(acts)
store.tick(10)
store.refresh(acts)                            # DRAM-style refresh
print("refreshes:", store.stats["refreshes"])

# --- 2. the 7T ternary cell as a matmul -------------------------------------
w = jax.random.normal(jax.random.fold_in(key, 2), (1024, 512))
t, scale = ternary.ternarize(w)                # TWN: {-1,0,+1} * scale
packed = ternary.pack_ternary_2bit(t)          # 4 trits / byte
x = jax.random.normal(jax.random.fold_in(key, 3), (128, 1024), jnp.bfloat16)
y = ops.ternary_matmul(x, packed, scale)       # Pallas kernel (interpret on CPU)
dense = (x.astype(jnp.float32)
         @ (t.astype(jnp.float32) * scale.astype(jnp.float32)))
err = (jnp.max(jnp.abs(y.astype(jnp.float32) - dense))
       / jnp.max(jnp.abs(dense)))
print(f"ternary matmul: out {y.shape}, packed weights "
      f"{packed.nbytes} bytes vs bf16 {w.size*2} "
      f"({w.size*2/packed.nbytes:.0f}x), kernel rel-err {err:.5f}")
