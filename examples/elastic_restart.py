"""Fault tolerance + elasticity demo.

  PYTHONPATH=src python examples/elastic_restart.py

1. trains with a checkpoint every 4 steps,
2. injects a simulated node failure mid-run — the Supervisor restores the
   latest checkpoint (params, optimizer, data-iterator position) and
   resumes; final losses are identical to a failure-free run,
3. then restores the same checkpoint onto a DIFFERENT mesh layout
   (elastic restart: e.g. a job rescheduled on fewer chips).
"""
import shutil

import jax
import numpy as np

from repro import checkpoint as ckpt_lib
from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.distributed.fault import SimulatedFailure
from repro.launch.mesh import make_local_mesh
from repro.train import TrainSettings
from repro.train.trainer import Trainer, TrainerConfig

CKPT = "/tmp/amc_elastic_demo"
shutil.rmtree(CKPT, ignore_errors=True)
shutil.rmtree(CKPT + "_clean", ignore_errors=True)

cfg = get_arch("qwen1.5-0.5b").reduced()
shape = ShapeConfig("t", 64, 4, "train")
settings = TrainSettings(lr=5e-3, q_chunk=16)

fired = {"done": False}


def injector(step):
    if step == 6 and not fired["done"]:
        fired["done"] = True
        raise SimulatedFailure("pod 1 lost heartbeat")


tr = Trainer(cfg, shape, make_local_mesh(), settings,
             TrainerConfig(total_steps=12, ckpt_every=4, ckpt_dir=CKPT,
                           warmup=2),
             failure_injector=injector)
losses = tr.train()
tr.close()
print(f"run with failure @6: restarts={tr.supervisor.restarts}, "
      f"{len(losses)} losses, final={losses[-1]:.4f}")

tr2 = Trainer(cfg, shape, make_local_mesh(), settings,
              TrainerConfig(total_steps=12, ckpt_every=4,
                            ckpt_dir=CKPT + "_clean", warmup=2))
losses_clean = tr2.train()
tr2.close()
assert np.allclose(losses, losses_clean, rtol=1e-5), "recovery diverged!"
print("failure-free run matches exactly: recovery lost/repeated no steps")

# elastic restore: same checkpoint, different mesh (here 1 device x (1,1) —
# on a pod this is e.g. 512 -> 256 chips; arrays are saved as full logical
# values and re-laid-out by device_put)
step = ckpt_lib.latest_step(CKPT)
mesh2 = jax.make_mesh((1, 1), ("data", "model"))
tr3 = Trainer(cfg, shape, mesh2, settings,
              TrainerConfig(total_steps=12, ckpt_dir=CKPT, warmup=2))
print(f"elastic restore at step {tr3.current_step()} onto mesh "
      f"{dict(mesh2.shape)}: OK")
tr3.close()
