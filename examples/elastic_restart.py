"""Fault tolerance + elasticity demo.

  PYTHONPATH=src python examples/elastic_restart.py

1. trains with a checkpoint every 4 steps,
2. injects a simulated node failure mid-run — the Supervisor restores the
   latest checkpoint (params, optimizer, data-iterator position) and
   resumes; final losses are identical to a failure-free run,
3. then restores the same checkpoint onto a DIFFERENT mesh layout
   (elastic restart: e.g. a job rescheduled on fewer chips),
4. finally, the SERVING side: a whole augmented array is lost mid-decode
   — the engine's Supervisor drains the in-flight rows, requeues them
   from their prompts + already-emitted tokens, and the finished streams
   are token-identical to a loss-free run.
"""
import dataclasses
import shutil

import jax
import numpy as np

from repro import checkpoint as ckpt_lib
from repro.configs import get_arch
from repro.configs.base import AMCConfig, ShapeConfig
from repro.distributed.fault import SimulatedFailure
from repro.launch.mesh import make_local_mesh
from repro.serve import Request, ServeEngine
from repro.train import TrainSettings
from repro.train.trainer import Trainer, TrainerConfig

CKPT = "/tmp/amc_elastic_demo"
shutil.rmtree(CKPT, ignore_errors=True)
shutil.rmtree(CKPT + "_clean", ignore_errors=True)

cfg = get_arch("qwen1.5-0.5b").reduced()
shape = ShapeConfig("t", 64, 4, "train")
settings = TrainSettings(lr=5e-3, q_chunk=16)

fired = {"done": False}


def injector(step):
    if step == 6 and not fired["done"]:
        fired["done"] = True
        raise SimulatedFailure("pod 1 lost heartbeat")


tr = Trainer(cfg, shape, make_local_mesh(), settings,
             TrainerConfig(total_steps=12, ckpt_every=4, ckpt_dir=CKPT,
                           warmup=2),
             failure_injector=injector)
losses = tr.train()
tr.close()
print(f"run with failure @6: restarts={tr.supervisor.restarts}, "
      f"{len(losses)} losses, final={losses[-1]:.4f}")

tr2 = Trainer(cfg, shape, make_local_mesh(), settings,
              TrainerConfig(total_steps=12, ckpt_every=4,
                            ckpt_dir=CKPT + "_clean", warmup=2))
losses_clean = tr2.train()
tr2.close()
assert np.allclose(losses, losses_clean, rtol=1e-5), "recovery diverged!"
print("failure-free run matches exactly: recovery lost/repeated no steps")

# elastic restore: same checkpoint, different mesh (here 1 device x (1,1) —
# on a pod this is e.g. 512 -> 256 chips; arrays are saved as full logical
# values and re-laid-out by device_put)
step = ckpt_lib.latest_step(CKPT)
mesh2 = jax.make_mesh((1, 1), ("data", "model"))
tr3 = Trainer(cfg, shape, mesh2, settings,
              TrainerConfig(total_steps=12, ckpt_dir=CKPT, warmup=2))
print(f"elastic restore at step {tr3.current_step()} onto mesh "
      f"{dict(mesh2.shape)}: OK")
tr3.close()

# --- serving array-loss recovery -------------------------------------------
# lose a whole augmented SRAM array mid-decode; the engine's Supervisor
# preempts every in-flight row (the dynamic plane is gone) and requeues
# each request from prompt + tokens already emitted — greedy decode makes
# the recovered streams bit-identical to a loss-free run.
scfg = dataclasses.replace(
    get_arch("qwen1.5-0.5b").reduced(),
    amc=AMCConfig(pool_mode="always-augmented", kv_mode="int4"))
smesh = make_local_mesh()
rng = np.random.default_rng(0)
prompts = [rng.integers(0, scfg.vocab, size=(20,)).astype(np.int32)
           for _ in range(3)]


def serve_reqs():
    return [Request(prompt=p, max_new_tokens=6, id=i)
            for i, p in enumerate(prompts)]


golden = ServeEngine(scfg, smesh, max_batch=2, max_seq=64,
                     prefill_chunk=16).generate(serve_reqs())

eng = ServeEngine(scfg, smesh, max_batch=2, max_seq=64, prefill_chunk=16)
for r in serve_reqs():
    eng.add_request(r)
eng.step_all()
eng.step_all()
eng.inject_array_loss()          # the whole dynamic plane, gone
while eng.active.any() or eng._queue:
    eng.step_all()
fl = eng.stats()["faults"]
assert all(np.array_equal(golden[i], eng.outputs[i]) for i in golden), \
    "array-loss recovery diverged!"
print(f"serving array loss @step2: requeued={fl['array_loss_requeues']} "
      f"restarts={fl['supervisor_restarts']}, recovered streams "
      f"token-identical to loss-free run")
