"""Batched serving with augmented (int4-packed) KV storage.

  PYTHONPATH=src python examples/serve_augmented.py

Serves a reduced granite-3-2b with continuous batching twice — Normal-mode
bf16 KV vs Augmented-mode int4 KV — and compares cache bytes, effective
KV-tokens-per-GiB and output agreement. The int4 cache is the paper's
dynamic plane: written once per token (streamed), lossy, drained by
attention reads (FILO), never rematerialized densely in HBM (the Pallas
packed_kv_attention kernel computes on packed bytes on TPU).
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_arch
from repro.configs.base import AMCConfig
from repro.launch.mesh import make_local_mesh
from repro.serve import Request, ServeEngine

cfg0 = get_arch("granite-3-2b").reduced()
mesh = make_local_mesh()
rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg0.vocab, size=(6,)).astype(np.int32)
           for _ in range(6)]

results = {}
for mode in ("normal", "int4"):
    cfg = dataclasses.replace(cfg0, amc=AMCConfig(kv_mode=mode))
    eng = ServeEngine(cfg, mesh, max_batch=3, max_seq=48, seed=11)
    reqs = [Request(prompt=p, max_new_tokens=8, id=i)
            for i, p in enumerate(prompts)]
    outs = eng.generate(reqs)
    cache_bytes = sum(x.nbytes for x in jax.tree.leaves(eng.cache))
    results[mode] = (outs, cache_bytes)
    print(f"[{mode:6s}] cache={cache_bytes:8d} B  "
          f"first outputs: {outs[0]}")

outs_n, bytes_n = results["normal"]
outs_q, bytes_q = results["int4"]
agree = np.mean([outs_n[i] == outs_q[i] for i in outs_n])
print(f"\ncache bytes: {bytes_n} -> {bytes_q} "
      f"({bytes_n/bytes_q:.2f}x augmentation)")
print(f"greedy output agreement int4 vs bf16: {agree:.0%} "
      f"(lossy dynamic plane, error-aware serving tolerates it)")

# -- array fleet: the same requests across 2 logical SRAM arrays ------------
# Each array is a full engine (own byte budget, store, refresh clock,
# fault domain); placement spreads admissions, and outputs stay
# token-identical to the single-array int4 run above.
from repro.serve import make_serving  # noqa: E402

cfg = dataclasses.replace(cfg0, amc=AMCConfig(kv_mode="int4"))
fleet = make_serving(cfg, num_arrays=2, placement="least-loaded",
                     max_batch=3, max_seq=48, seed=11)
outs_f = fleet.generate([Request(prompt=p, max_new_tokens=8, id=i)
                         for i, p in enumerate(prompts)])
fl = fleet.stats()["fleet"]
print(f"\n[fleet ] arrays={fl['num_arrays']} "
      f"peak_concurrency={fl['peak_concurrency']} "
      f"placements_per_array={fl['placements_per_array']} "
      f"aggregate_budget={fl['aggregate_budget_bytes']} B")
assert outs_f == outs_q, "fleet decode must be token-identical"
print("fleet vs single-array int4 outputs: identical")
