"""Error-aware training of a ternary LM (paper SS.IV co-design), end to end.

  PYTHONPATH=src python examples/train_ternary_lm.py [--steps 300]

Trains a ~small qwen-family LM twice on the same synthetic stream:
  (a) baseline fp training,
  (b) ternary-STE training (forward through the 7T augmented representation,
      gradient straight-through to the fp master),
then FREEZES (b) into base-3 packed storage (1.6 bits/weight) and verifies
the frozen ternary model's loss ~ the STE training loss — i.e. the network
has learned to be accurate *under* augmented storage, which is what lets
serving run from 10x-augmented memory.

This is the paper's "error-aware training extends retention/robustness"
claim in working code.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ternary
from repro.data import SyntheticLM
from repro.models import layers as L


def make_params(key, vocab, d, f, n_layers):
    ks = jax.random.split(key, 16)
    p = {"embed": jax.random.normal(ks[0], (vocab, d)) * 0.02,
         "layers": []}
    params = {"embed": p["embed"]}
    for i in range(n_layers):
        params[f"w1_{i}"] = jax.random.normal(ks[2 + i], (d, f)) / np.sqrt(d)
        params[f"w2_{i}"] = jax.random.normal(ks[8 + i], (f, d)) / np.sqrt(f)
    params["head"] = jax.random.normal(ks[1], (d, vocab)) / np.sqrt(d)
    return params


def forward(params, tokens, n_layers, ternary_mode):
    x = params["embed"][tokens]
    # causal mixing: shifted cumulative mean (cheap token mixer so the
    # example focuses on the MLP weights that live in augmented storage)
    cum = jnp.cumsum(x, axis=1) / (1 + jnp.arange(x.shape[1]))[None, :, None]
    x = x + jnp.pad(cum, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    for i in range(n_layers):
        w1, w2 = params[f"w1_{i}"], params[f"w2_{i}"]
        if ternary_mode == "ste":
            w1, w2 = ternary.ternarize_ste(w1), ternary.ternarize_ste(w2)
        h = jax.nn.gelu(x @ w1)
        x = x + h @ w2
    return x @ params["head"]


def loss_fn(params, batch, n_layers, mode):
    logits = forward(params, batch["tokens"], n_layers, mode)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, batch["targets"][..., None],
                                axis=-1).mean()


def train(mode, steps, data, params0, n_layers, lr=1e-2):
    from repro.optim import adamw_init, adamw_update
    params, opt = params0, adamw_init(params0)

    @jax.jit
    def step(p, o, batch):
        l, g = jax.value_and_grad(loss_fn)(p, batch, n_layers, mode)
        p, o = adamw_update(g, o, p, lr=lr, weight_decay=0.0)
        return p, o, l

    losses = []
    for s in range(steps):
        b = jax.tree.map(jnp.asarray, data.batch_at(s))
        params, opt, l = step(params, opt, b)
        losses.append(float(l))
    return params, losses


def freeze_and_eval(params, data, n_layers, steps=20):
    """Pack MLP weights base-3 (1.6 b/w), eval the frozen model."""
    frozen = dict(params)
    total_bf16 = total_packed = 0
    for i in range(n_layers):
        for name in (f"w1_{i}", f"w2_{i}"):
            w = params[name]
            t, scale = ternary.ternarize(w)
            packed = ternary.pack_ternary_base3(t)
            total_bf16 += w.size * 2
            total_packed += packed.nbytes
            # serving path: unpack from augmented storage
            frozen[name] = ternary.ternary_dequant(
                ternary.unpack_ternary_base3(packed, w.shape[0]), scale,
                dtype=jnp.float32)
    ls = []
    for s in range(1000, 1000 + steps):
        b = jax.tree.map(jnp.asarray, data.batch_at(s))
        ls.append(float(loss_fn(frozen, b, n_layers, "none")))
    return float(np.mean(ls)), total_bf16, total_packed


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--dim", type=int, default=160)
    ap.add_argument("--ff", type=int, default=320)
    ap.add_argument("--layers", type=int, default=2)
    args = ap.parse_args()

    data = SyntheticLM(args.vocab, 64, 8, seed=0)
    key = jax.random.PRNGKey(0)
    params0 = make_params(key, args.vocab, args.dim, args.ff, args.layers)

    fp_params, fp_losses = train("none", args.steps, data, params0,
                                 args.layers)
    ste_params, ste_losses = train("ste", args.steps, data, params0,
                                   args.layers)
    frozen_loss, b16, bpk = freeze_and_eval(ste_params, data, args.layers)
    # a non-error-aware baseline: ternarize the FP model post-hoc
    post_loss, _, _ = freeze_and_eval(fp_params, data, args.layers)

    print(f"fp      loss: {fp_losses[0]:.3f} -> {fp_losses[-1]:.3f}")
    print(f"ste     loss: {ste_losses[0]:.3f} -> {ste_losses[-1]:.3f}")
    print(f"frozen ternary (error-aware) eval loss: {frozen_loss:.3f}")
    print(f"frozen ternary (post-hoc)    eval loss: {post_loss:.3f}")
    print(f"weight storage: {b16} -> {bpk} bytes "
          f"({b16/bpk:.1f}x augmentation)")
    assert frozen_loss < post_loss + 0.05, "error-aware training should win"


if __name__ == "__main__":
    main()
