"""Observability overhead + trace/metrics cross-validation — the
BENCH_obs.json payload.

ONE engine serves the same decode-heavy load (single-token prompts, so
the timed region is the decode hot path) twice per repeat: once with the
Null facade swapped in (planes off) and once with tracing AND metrics
on. Swapping the facade on a single engine instance — instead of
comparing two separately-constructed engines — removes per-instance
variance (jit cache, allocation layout), which a two-engine control
measured at the same magnitude as the effect (~1.3%). The headline is
`overhead_frac` — the ratio of the two modes' 10th-percentile process
CPU times over many paired repeats. The decode loop on the CPU backend
is compute-bound, so the hooks' cost is CPU work and
`time.process_time` measures exactly that while being immune to the
involuntary OS-scheduler preemptions that put ±5-10% of noise on wall
time on a shared box — an order of magnitude above the ~1% effect (the
acceptance bound is < 2%). CPU noise is additive (interrupts, cache
eviction by co-tenants only ever ADD cycles), so a low percentile over
many repeats approaches each mode's true floor; p10 rather than the
raw min keeps one lucky sample from deciding the figure. GC is held
off during each timed region (timeit's protocol) — a gen-2 pause
inside one run is itself a >1% distortion. Wall time is still what
throughput (tok/s) is reported from, and the median of per-repeat
paired on/off CPU ratios rides along as a drift-robust secondary
estimate.

The instrumented run then cross-validates its own two planes: per-request
TTFT derived from the trace's enqueue/first_token instants must agree
with the metrics histogram's percentile estimates within one log-bucket
(the construction guarantee `LogHistogram.within_one_bucket` encodes),
and the exported Chrome trace must pass the schema validator.
"""
from __future__ import annotations

import gc
import time

import numpy as np

from benchmarks.paper_tables import row
from repro.configs import get_arch
from repro.launch.mesh import make_local_mesh
from repro.obs import LogHistogram, validate_chrome_trace
from repro.serve import Request, ServeEngine

ARCH = "qwen1.5-0.5b"


def _engine(cfg, mesh, *, obs: bool):
    return ServeEngine(cfg, mesh, max_batch=4, max_seq=64,
                       prefill_chunk=16, trace=obs, metrics=obs)


def _set_obs(eng, obs) -> None:
    """Swap the obs facade on a live engine (engine + scheduler + store
    all hold the same reference)."""
    eng.obs = obs
    eng.scheduler.obs = obs
    eng.store.attach_obs(obs)


def _reqs(rng, cfg, n, max_new, id0):
    # single-token prompts: no prefill dispatches, the timed region is
    # pure decode rounds
    return [Request(prompt=rng.integers(0, cfg.vocab, size=(1,))
                    .astype(np.int32), max_new_tokens=max_new, id=id0 + i)
            for i in range(n)]


def _decode_times(eng, rng, cfg, *, n_req, max_new, repeats):
    """Paired off/on decode timings on ONE engine, the obs facade
    swapped between runs. Each repeat times an off generate and an on
    generate back to back (order alternating), recording both wall time
    (throughput) and process CPU time (overhead). Returns
    ((wall_off, wall_on), (tokens_off, tokens_on), overhead_frac,
    median_paired_ratio, paired_ratio_iqr) where the walls are
    min-of-repeats and the overhead comes from the ratio of p10 CPU
    times; the IQR of the paired ratios is the run's own noise floor."""
    from repro.obs import NULL_OBS
    real_obs = eng.obs
    modes = (NULL_OBS, real_obs)
    eng.generate(_reqs(rng, cfg, n_req, max_new, 10_000))  # warmup/jit
    best_wall = [float("inf")] * len(modes)
    cpus = [[], []]
    tokens = [0] * len(modes)
    ratios = []
    for r in range(repeats):
        cpu = [0.0, 0.0]
        # alternate within-pair order (off,on / on,off) so any cost the
        # first run of a pair defers onto the second (GC, page faults)
        # cancels across repeats
        order = (0, 1) if r % 2 == 0 else (1, 0)
        for k, i in enumerate(order):
            _set_obs(eng, modes[i])
            reqs = _reqs(rng, cfg, n_req, max_new,
                         20_000 + (r * len(modes) + k) * 1000)
            # timeit-style GC control: collect to a fresh heap, then keep
            # the collector out of the timed region — a gen-2 pause
            # landing inside one 0.3s run is a >1% distortion, larger
            # than the effect being measured
            gc.collect()
            gc.disable()
            try:
                w0 = time.perf_counter()
                c0 = time.process_time()
                outs = eng.generate(reqs)
                cpu[i] = time.process_time() - c0
                wall = time.perf_counter() - w0
            finally:
                gc.enable()
            # count THIS batch only: the shared engine's outputs dict
            # accumulates every request it has ever served
            tokens[i] = sum(len(outs[q.id]) for q in reqs)
            best_wall[i] = min(best_wall[i], wall)
            cpus[i].append(cpu[i])
        ratios.append(cpu[1] / cpu[0] - 1.0)
    _set_obs(eng, real_obs)
    # CPU noise is strictly additive (an interrupt only ever adds
    # cycles), so a low percentile over many repeats approaches each
    # mode's true floor — p10 rather than the raw min so no single
    # lucky sample decides the figure; the paired-ratio median is
    # reported alongside as a drift-robust secondary estimate
    p10 = [float(np.percentile(c, 10)) for c in cpus]
    overhead = (p10[1] - p10[0]) / p10[0]
    # inter-quartile range of the paired ratios: the measurement's own
    # noise floor (a quiet box shows ~1-2%, a loud co-tenant phase can
    # triple it — read the headline against this)
    iqr = float(np.percentile(ratios, 75) - np.percentile(ratios, 25))
    return best_wall, tokens, overhead, float(np.median(ratios)), iqr


def _trace_ttfts(trace_obj) -> list[float]:
    """Per-request TTFT (seconds) recomputed from the trace artifact's
    enqueue / first_token instants, keyed by request track."""
    enq, first = {}, {}
    for e in trace_obj["traceEvents"]:
        if e.get("ph") != "i":
            continue
        if e["name"] == "enqueue":
            enq[e["tid"]] = e["ts"]
        elif e["name"] == "first_token":
            first.setdefault(e["tid"], e["ts"])
    return [(first[tid] - enq[tid]) * 1e-6 for tid in enq if tid in first]


def run_all(*, seed: int = 0, tiny: bool = False) -> dict:
    cfg = get_arch(ARCH).reduced()
    mesh = make_local_mesh()
    n_req = 4 if tiny else 8
    max_new = 16 if tiny else 32
    # noisy shared-CPU environments need many pairs: per-pair noise is
    # ±5% while the effect is ~1%, and both estimators' error shrinks
    # ~1/sqrt(repeats). Shorter runs buy more pairs for the same budget
    # AND cancel contention better (the two runs of a pair sit closer
    # in time); odd count = the median is a real paired ratio
    repeats = 5 if tiny else 75

    rng = np.random.default_rng(seed)
    eng_on = _engine(cfg, mesh, obs=True)
    (t_off, t_on), (tok_off, tok_on), overhead, med_paired, iqr = \
        _decode_times(
        eng_on, rng, cfg, n_req=n_req, max_new=max_new, repeats=repeats)
    row("obs/decode_tok_per_s_off", t_off / tok_off * 1e6,
        f"{tok_off / t_off:.1f} tok/s")
    row("obs/decode_tok_per_s_on", t_on / tok_on * 1e6,
        f"{tok_on / t_on:.1f} tok/s overhead={overhead * 100:.2f}%")

    # -- cross-validate the instrumented run's two planes ---------------------
    trace_obj = eng_on.obs.tracer.chrome_trace()
    problems = validate_chrome_trace(trace_obj)
    ttfts = _trace_ttfts(trace_obj)
    hist = eng_on.stats()["obs"]["histograms"]["ttft_s"]
    ref = LogHistogram()
    for t in ttfts:
        ref.observe(t)
    agree_p50 = ref.within_one_bucket(ref.percentile(50), hist["p50"])
    agree_p99 = ref.within_one_bucket(ref.percentile(99), hist["p99"])
    row("obs/ttft_p50_ms", hist["p50"] * 1e3,
        f"trace_p50={ref.percentile(50) * 1e3:.3f}ms "
        f"agree={agree_p50 and agree_p99}")

    return {
        "arch": ARCH,
        "seed": seed,
        "tiny": tiny,
        "decode": {
            "n_requests": n_req,
            "max_new_tokens": max_new,
            "repeats": repeats,
            "wall_s_obs_off": t_off,
            "wall_s_obs_on": t_on,
            "tokens_per_s_obs_off": tok_off / t_off,
            "tokens_per_s_obs_on": tok_on / t_on,
            "overhead_frac": overhead,
            "overhead_frac_median_paired": med_paired,
            "paired_ratio_iqr": iqr,
            "overhead_estimator": "p10_cpu_ratio",
            "overhead_timer": "process_time",
        },
        "cross_check": {
            "n_ttfts_from_trace": len(ttfts),
            "ttft_p50_metrics_s": hist["p50"],
            "ttft_p99_metrics_s": hist["p99"],
            "ttft_p50_trace_s": ref.percentile(50),
            "ttft_p99_trace_s": ref.percentile(99),
            "agree_within_one_bucket_p50": bool(agree_p50),
            "agree_within_one_bucket_p99": bool(agree_p99),
        },
        "trace": {
            "events": len(trace_obj["traceEvents"]),
            "schema_problems": problems,
            "valid": not problems,
        },
    }
