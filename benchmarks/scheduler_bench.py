"""Continuous-batching scheduler bench: sustained req/s and latency
percentiles vs offered load, for the three pool modes, with refresh
overhead — the BENCH_scheduler.json payload.

The acceptance sweep offers up to 4x `max_batch` concurrent requests and
verifies (a) every request completes — zero drops — and (b) at EQUAL byte
budget the augment-on-pressure pool reaches strictly higher peak
concurrency than normal-only (the paper's on-demand capacity, measured at
the allocator). The ``--arch`` family sweep (dense / moe / ssm / hybrid /
encdec) proves the same claim for every decode-state type of the unified
store — augmenting cold recurrent-state SLABS admits more concurrent
sequences exactly like augmenting cold KV pages. CPU wall-clock on the
reduced configs: relative numbers only; the step-count latencies are
machine-independent.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.paper_tables import row
from repro.configs import get_arch
from repro.configs.base import AMCConfig
from repro.launch.mesh import make_local_mesh
from repro.serve import ArrayFleet, Request, ServeEngine

# pool-mode -> kv_mode pairing: normal-only serves bf16 pages; the
# pressure pool starts bf16 and augments to int8; always-augmented is the
# legacy packed-cache equivalent
MODES = {
    "normal-only": "normal",
    "augment-on-pressure": "normal",
    "always-augmented": "int8",
}
LOADS = (1, 2, 4)                       # x max_batch, offered all at once


def _drive(eng: ServeEngine, reqs: list[Request]) -> dict:
    """Submit everything at t0, step to drain, record per-request
    completion latency (steps and seconds) + live-byte integral for the
    refresh-overhead model."""
    t0 = time.perf_counter()
    for r in reqs:
        eng.add_request(r)
    want = {r.id: r.max_new_tokens for r in reqs}
    done_at_s, done_at_step = {}, {}
    live_byte_steps = 0
    steps = 0
    while eng.active.any() or eng._queue:
        eng.step_all()
        steps += 1
        live_byte_steps += eng.pool.live_bytes
        now = time.perf_counter() - t0
        for rid, n in want.items():
            if rid not in done_at_s and len(eng.outputs.get(rid, ())) >= n:
                done_at_s[rid] = now
                done_at_step[rid] = steps
    total_s = time.perf_counter() - t0
    lat_s = np.array([done_at_s[r.id] for r in reqs])
    lat_steps = np.array([done_at_step[r.id] for r in reqs])
    st = eng.stats()
    completed = sum(len(eng.outputs.get(r.id, ())) >= want[r.id]
                    for r in reqs)
    # refresh overhead: refresh traffic vs the decode stream's modeled
    # cache reads (every step touches the live working set once)
    refresh_b = st["refresh_bytes"]
    decode_b = max(live_byte_steps, 1)
    return {
        "requests": len(reqs),
        "completed": completed,
        "drops": len(reqs) - completed,
        "total_s": total_s,
        "decode_steps": steps,
        "req_per_s": len(reqs) / total_s,
        "latency_steps_p50": float(np.percentile(lat_steps, 50)),
        "latency_steps_p99": float(np.percentile(lat_steps, 99)),
        "latency_s_p50": float(np.percentile(lat_s, 50)),
        "latency_s_p99": float(np.percentile(lat_s, 99)),
        "peak_concurrency": eng.scheduler.stats["peak_concurrency"],
        "peak_queue_depth": eng.scheduler.stats["peak_queue_depth"],
        "preemptions": eng.scheduler.stats["preemptions"],
        "augment_events": st["augment_events"],
        "promote_events": st["promote_events"],
        "refreshes": st["refreshes"],
        "refresh_bytes": refresh_b,
        "refresh_overhead_pct": 100.0 * refresh_b / (refresh_b + decode_b),
        "budget_bytes": eng.pool.budget_bytes,
        "live_bytes_peak": st["pool"]["peak_live_bytes"],
    }


def bench_refresh(seed: int = 0) -> dict:
    """Refresh-overhead probe: prompts spanning two pages leave page 0
    cold while decode stamps only the tail page, so the cold page expires
    every `retention_steps` steps and the refresh scheduler must
    re-materialize it — the steady-state refresh tax of augmented
    serving, as a % of modeled decode cache traffic."""
    base = get_arch("qwen1.5-0.5b").reduced()
    cfg = dataclasses.replace(
        base, amc=AMCConfig(kv_mode="int8", pool_mode="always-augmented",
                            retention_steps=2))
    eng = ServeEngine(cfg, make_local_mesh(), max_batch=2, max_seq=32,
                      prefill_chunk=16, seed=2)
    rng = np.random.default_rng(seed + 3)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=(20,))
                    .astype(np.int32), max_new_tokens=8, id=i)
            for i in range(2)]
    res = _drive(eng, reqs)
    row("sched_refresh_probe", res["total_s"] * 1e6,
        f"refreshes={res['refreshes']} "
        f"refresh_bytes={res['refresh_bytes']} "
        f"refresh_ovh={res['refresh_overhead_pct']:.1f}% "
        f"retention_steps=2")
    return {k: res[k] for k in ("refreshes", "refresh_bytes",
                                "refresh_overhead_pct", "decode_steps")}


# arch sweep: one member per model family — the unified state store gives
# recurrent-state (ssm/hybrid) and encdec rows the same admission control
# and augment-on-pressure capacity as dense/MoE KV pages
SWEEP_ARCHS = {
    "dense": "qwen1.5-0.5b",
    "moe": "qwen3-moe-30b-a3b",
    "ssm": "mamba2-130m",
    "hybrid": "recurrentgemma-9b",
    "encdec": "whisper-tiny",
}


def _equal_budget(cfg, max_batch, max_seq) -> int:
    """A budget that pressures the allocator at 4x load: the smallest
    Normal-mode budget a single full-grown row needs (short rows use
    less, so normal-only admits ~2 and augmentation must buy the rest),
    whatever the store kind."""
    from repro.serve.state_store import make_store
    store = make_store(cfg, max_batch=max_batch, max_seq=max_seq)
    if store.kind == "slab":
        return 2 * store.slab_bytes_normal
    if store.kind == "composite":
        return 2 * (store.budget_bytes // max_batch)
    return ((store.max_pages + store.prefix_pages)
            * store.geom.page_bytes_normal)


def bench_arch_sweep(seed: int = 0) -> dict:
    """Augment-on-pressure vs normal-only at EQUAL byte budget, across
    the family zoo: the unified store must admit strictly more
    concurrent sequences under pressure for every decode-state type —
    recurrent-state slabs included, not just KV pages."""
    out: dict = {}
    rng = np.random.default_rng(seed + 2)
    max_batch, max_seq = 4, 32
    for family, arch in SWEEP_ARCHS.items():
        base = get_arch(arch).reduced()
        budget = _equal_budget(base, max_batch, max_seq)
        peaks, loads = {}, {}
        for mode in ("normal-only", "augment-on-pressure"):
            cfg = dataclasses.replace(
                base, amc=dataclasses.replace(base.amc, kv_mode="normal",
                                              pool_mode=mode,
                                              retention_steps=4))
            eng = ServeEngine(cfg, make_local_mesh(), max_batch=max_batch,
                              max_seq=max_seq, prefill_chunk=16,
                              pool_budget_bytes=budget, seed=1)
            reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=(8,))
                            .astype(np.int32), max_new_tokens=4, id=i)
                    for i in range(4 * max_batch)]
            res = _drive(eng, reqs)
            peaks[mode] = res["peak_concurrency"]
            loads[mode] = res
            row(f"sched_{family}_{mode}_4x", res["total_s"] * 1e6,
                f"arch={arch} peak_conc={res['peak_concurrency']} "
                f"drops={res['drops']} augments={res['augment_events']}")
        out[family] = {
            "arch": arch,
            "budget_bytes": budget,
            "modes": loads,
            "normal_only_peak_concurrency": peaks["normal-only"],
            "augment_on_pressure_peak_concurrency":
                peaks["augment-on-pressure"],
            "augment_admits_strictly_more":
                peaks["augment-on-pressure"] > peaks["normal-only"],
            "zero_drops": all(m["drops"] == 0 for m in loads.values()),
        }
    return out


# fleet sweep: array counts at FIXED per-array bytes (the paper's
# array-level scaling — each array is one more SRAM array's worth of
# serving capacity, so aggregate admitted concurrency should scale
# near-linearly with array count)
FLEET_ARRAYS = (1, 2, 4)


def _drive_fleet(fleet: ArrayFleet, reqs: list[Request]) -> dict:
    """Fleet analogue of `_drive`: submit everything at t0, step fleet
    rounds to drain, record aggregate peak concurrency + drops."""
    t0 = time.perf_counter()
    for r in reqs:
        fleet.add_request(r)
    steps = 0
    while fleet.has_work:
        fleet.step_all()
        steps += 1
    total_s = time.perf_counter() - t0
    outs = fleet.outputs
    completed = sum(len(outs.get(r.id, ())) >= r.max_new_tokens
                    for r in reqs)
    fl = fleet.stats()["fleet"]
    return {
        "requests": len(reqs),
        "completed": completed,
        "drops": len(reqs) - completed,
        "total_s": total_s,
        "decode_rounds": steps,
        "req_per_s": len(reqs) / total_s,
        "peak_concurrency": fl["peak_concurrency"],
        "migrations": fl["migrations"],
        "placements_per_array": fl["placements_per_array"],
        "per_array_peak_concurrency": [a["peak_concurrency"]
                                       for a in fl["per_array"]],
        "budget_bytes_per_array": fl["aggregate_budget_bytes"]
                                  // fl["num_arrays"],
        "outputs": {r.id: outs.get(r.id, []) for r in reqs},
    }


def bench_fleet_sweep(seed: int = 0, tiny: bool = False,
                      num_arrays=FLEET_ARRAYS) -> dict:
    """Aggregate admitted concurrency vs array count at FIXED per-array
    byte budget, same offered request set for every fleet size (so the
    sweep also proves token identity across fleet sizes — per-request
    decode is batch-composition and placement invariant). Acceptance:
    >=1.8x concurrency from 1->2 arrays and >=3.2x from 1->4, zero
    drops everywhere."""
    base = get_arch("qwen1.5-0.5b").reduced()
    max_batch, max_seq, plen = 4, 32, 8
    max_new = 4
    load_mult = 2 if tiny else 4
    cfg = dataclasses.replace(
        base, amc=AMCConfig(kv_mode="normal",
                            pool_mode="augment-on-pressure",
                            retention_steps=4))
    # fixed PER-ARRAY budget: two Normal pages' worth — the same
    # pressured-allocator regime as the pool-mode sweep, per array
    from repro.serve.state_store import make_store
    probe = make_store(cfg, max_batch=max_batch, max_seq=max_seq)
    budget = 2 * probe.geom.page_bytes_normal
    del probe
    offered = load_mult * max_batch * max(num_arrays)
    sizes: dict = {}
    golden = None
    for n in num_arrays:
        rng = np.random.default_rng(seed + 7)   # same requests per size
        reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=(plen,))
                        .astype(np.int32), max_new_tokens=max_new, id=i)
                for i in range(offered)]
        fleet = ArrayFleet(cfg, num_arrays=n, placement="least-loaded",
                           max_batch=max_batch, max_seq=max_seq,
                           prefill_chunk=16, pool_budget_bytes=budget,
                           seed=1)
        res = _drive_fleet(fleet, reqs)
        outs = res.pop("outputs")
        if golden is None:
            golden = outs
        res["token_identical_to_single_array"] = outs == golden
        sizes[str(n)] = res
        row(f"sched_fleet_{n}arrays", res["total_s"] * 1e6,
            f"peak_conc={res['peak_concurrency']} "
            f"drops={res['drops']} migrations={res['migrations']} "
            f"budget/array={budget}")
    peak1 = max(sizes[str(num_arrays[0])]["peak_concurrency"], 1)
    scaling = {str(n): sizes[str(n)]["peak_concurrency"] / peak1
               for n in num_arrays}
    acceptance = {
        "offered_requests": offered,
        "budget_bytes_per_array": budget,
        "zero_drops": all(s["drops"] == 0 for s in sizes.values()),
        "token_identity_across_sizes": all(
            s["token_identical_to_single_array"] for s in sizes.values()),
        "concurrency_scaling": scaling,
        "scales_1_to_2_at_least_1p8x": scaling.get("2", 0.0) >= 1.8,
        "scales_1_to_4_at_least_3p2x": scaling.get("4", 0.0) >= 3.2,
    }
    return {"config": {"arch": "qwen1.5-0.5b(reduced)",
                       "pool_mode": "augment-on-pressure",
                       "max_batch": max_batch, "max_seq": max_seq,
                       "prompt_len": plen, "max_new_tokens": max_new,
                       "placement": "least-loaded",
                       "num_arrays": list(num_arrays)},
            "sizes": sizes, "acceptance": acceptance}


def run_all(*, seed: int = 0, tiny: bool = False,
            num_arrays=FLEET_ARRAYS) -> dict:
    base = get_arch("qwen1.5-0.5b").reduced()
    max_batch, max_seq, plen, max_new = 4, 32, 8, 4
    rng = np.random.default_rng(seed)
    # equal HBM byte budget across ALL modes: two Normal pages' worth —
    # small enough that 4x load actually pressures the allocator
    probe = ServeEngine(
        dataclasses.replace(base, amc=AMCConfig(kv_mode="normal")),
        make_local_mesh(), max_batch=max_batch, max_seq=max_seq)
    budget = 2 * probe.pool.geom.page_bytes_normal
    del probe

    config = {"arch": "qwen1.5-0.5b(reduced)", "max_batch": max_batch,
              "max_seq": max_seq, "page_size": base.amc.page_size,
              "prompt_len": plen, "max_new_tokens": max_new,
              "retention_steps": 4}
    if tiny:
        # one pressure-pool cell at 1x load: exercises the whole
        # admit/refresh/augment path without the full mode x load sweep
        cfg = dataclasses.replace(
            base, amc=AMCConfig(kv_mode="normal",
                                pool_mode="augment-on-pressure",
                                retention_steps=4))
        eng = ServeEngine(cfg, make_local_mesh(), max_batch=max_batch,
                          max_seq=max_seq, prefill_chunk=16,
                          pool_budget_bytes=budget, seed=1)
        reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=(plen,))
                        .astype(np.int32), max_new_tokens=max_new, id=i)
                for i in range(max_batch)]
        res = _drive(eng, reqs)
        row("sched_tiny_augment-on-pressure_1x", res["total_s"] * 1e6,
            f"req_per_s={res['req_per_s']:.2f} drops={res['drops']}")
        return {"config": config, "tiny": True,
                "modes": {"augment-on-pressure": {
                    "kv_mode": "normal", "budget_bytes": budget,
                    "loads": {"1x": res}}},
                "fleet": bench_fleet_sweep(seed, tiny=True,
                                           num_arrays=num_arrays)}

    modes: dict = {}
    for pool_mode, kv_mode in MODES.items():
        cfg = dataclasses.replace(
            base, amc=AMCConfig(kv_mode=kv_mode, pool_mode=pool_mode,
                                retention_steps=4))
        loads = {}
        for mult in LOADS:
            eng = ServeEngine(cfg, make_local_mesh(), max_batch=max_batch,
                              max_seq=max_seq, prefill_chunk=16,
                              pool_budget_bytes=budget, seed=1)
            n = mult * max_batch
            reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=(plen,))
                            .astype(np.int32), max_new_tokens=max_new, id=i)
                    for i in range(n)]
            res = _drive(eng, reqs)
            loads[f"{mult}x"] = res
            row(f"sched_{pool_mode}_{mult}x", res["total_s"] * 1e6,
                f"req_per_s={res['req_per_s']:.2f} "
                f"p50={res['latency_steps_p50']:.0f}steps "
                f"p99={res['latency_steps_p99']:.0f}steps "
                f"peak_conc={res['peak_concurrency']} "
                f"drops={res['drops']} "
                f"refresh_ovh={res['refresh_overhead_pct']:.1f}%")
        modes[pool_mode] = {"kv_mode": kv_mode, "budget_bytes": budget,
                            "loads": loads}

    peak_no = modes["normal-only"]["loads"]["4x"]["peak_concurrency"]
    peak_ap = modes["augment-on-pressure"]["loads"]["4x"]["peak_concurrency"]
    acceptance = {
        "offered_load_4x_requests": 4 * max_batch,
        "zero_drops_at_4x": all(m["loads"]["4x"]["drops"] == 0
                                for m in modes.values()),
        "equal_budget_bytes": budget,
        "normal_only_peak_concurrency_at_4x": peak_no,
        "augment_on_pressure_peak_concurrency_at_4x": peak_ap,
        "augment_admits_strictly_more": peak_ap > peak_no,
    }
    sweep = bench_arch_sweep(seed)
    acceptance["arch_sweep_augment_admits_more"] = {
        fam: d["augment_admits_strictly_more"] for fam, d in sweep.items()}
    fleet = bench_fleet_sweep(seed, num_arrays=num_arrays)
    acceptance["fleet_concurrency_scaling"] = \
        fleet["acceptance"]["concurrency_scaling"]
    acceptance["fleet_zero_drops"] = fleet["acceptance"]["zero_drops"]
    return {
        "config": config,
        "modes": modes,
        "refresh": bench_refresh(seed),
        "arch_sweep": sweep,
        "fleet": fleet,
        "acceptance": acceptance,
    }


def main() -> None:
    """Standalone entry: ``python benchmarks/scheduler_bench.py [--arch
    dense moe ...]`` runs just the family sweep (or everything with no
    flag) and prints the acceptance verdicts."""
    global SWEEP_ARCHS
    import argparse
    import json
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", choices=sorted(SWEEP_ARCHS),
                    default=None,
                    help="family subset for the sweep (default: the full "
                         "BENCH_scheduler.json payload)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.arch is not None:
        SWEEP_ARCHS = {k: v for k, v in SWEEP_ARCHS.items()
                       if k in args.arch}
        payload = {"arch_sweep": bench_arch_sweep()}
    else:
        payload = run_all()
    print(json.dumps(payload.get("arch_sweep", {}), indent=2,
                     default=str))


if __name__ == "__main__":
    main()
