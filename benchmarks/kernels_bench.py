"""Kernel micro-benchmarks.

On this CPU container Pallas kernels run in interpret mode (Python-speed),
so wall-clock there is meaningless; what we report per kernel is
  * the HBM bytes moved by the kernel vs its bf16 XLA equivalent (the
    quantity the TPU roofline actually charges),
  * wall time of the jnp reference path as a CPU sanity number, and
  * a Pallas-interpret PARITY check against the jnp oracle (max rel err on
    a reduced shape) so a kernel regression shows up in the bench artifact
    (`BENCH_kernels.json`), not just in CI.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.paper_tables import row, _time_us
from repro.core import quant, ternary
from repro.kernels import ops, ref
from repro.launch.mesh import HBM_BW, PEAK_BF16_FLOPS

ROWS: list[dict] = []    # BENCH_kernels.json payload (one dict per kernel)


_rel_err = ref.rel_err


def _record(name: str, us: float, *, bytes_kernel: int, bytes_baseline: int,
            baseline: str, parity_rel_err: float, flops: int = 0,
            extra: str = ""):
    ratio = bytes_baseline / bytes_kernel
    ROWS.append({
        "kernel": name,
        "ref_cpu_us": us,
        "hbm_bytes_modeled": bytes_kernel,
        "hbm_bytes_baseline": bytes_baseline,
        "baseline": baseline,
        "traffic_ratio": ratio,
        # roofline = max(memory term, compute term) — matches the printed
        # CSV for compute-bound kernels, not memory-only
        "tpu_roofline_us": max(bytes_kernel / HBM_BW,
                               flops / PEAK_BF16_FLOPS) * 1e6,
        "pallas_interpret_rel_err": parity_rel_err,
        "parity_ok": parity_rel_err < 0.03,
    })
    row(f"{name}_ref_cpu", us,
        f"hbm_bytes={bytes_kernel} vs_{baseline}={bytes_baseline} "
        f"traffic_ratio={ratio:.2f}x "
        f"pallas_parity_rel_err={parity_rel_err:.4f} {extra}".strip())


def bench_ternary_matmul(seed: int = 0):
    M, K, N = 256, 4096, 4096
    w = jax.random.normal(jax.random.PRNGKey(seed), (K, N))
    t, scale = ternary.ternarize(w)
    wp = ternary.pack_ternary_2bit(t)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (M, K), jnp.bfloat16)
    us = _time_us(jax.jit(ref.ternary_matmul_ref), x, wp, scale, n=5)
    # parity on a reduced shape (interpret mode is Python-speed)
    Mp, Kp, Np = 128, 512, 256
    xp = x[:Mp, :Kp]
    err = _rel_err(ops.ternary_matmul(xp, wp[:Kp // 4, :Np],
                                      scale[:, :Np]),
                   ref.ternary_matmul_ref(xp, wp[:Kp // 4, :Np],
                                          scale[:, :Np]))
    bytes_packed = wp.size + M * K * 2 + M * N * 2
    bytes_bf16 = K * N * 2 + M * K * 2 + M * N * 2
    flops = 2 * M * K * N
    roof_packed = max(bytes_packed / HBM_BW, flops / PEAK_BF16_FLOPS) * 1e6
    roof_bf16 = max(bytes_bf16 / HBM_BW, flops / PEAK_BF16_FLOPS) * 1e6
    _record("ternary_matmul", us, bytes_kernel=bytes_packed,
            bytes_baseline=bytes_bf16, baseline="bf16",
            parity_rel_err=err, flops=flops,
            extra=f"M{M}xK{K}xN{N} tpu_roofline_us={roof_packed:.2f} "
                  f"vs_bf16_us={roof_bf16:.2f}")


def bench_dual_plane_matmul(seed: int = 0):
    M, K, N = 256, 2048, 2048
    k = jax.random.PRNGKey(seed)
    qh, sh = quant.quantize_int4(jax.random.normal(k, (K, N)), axis=0)
    ql, sl = quant.quantize_int4(
        jax.random.normal(jax.random.fold_in(k, 1), (K, N)), axis=0)
    buf = quant.pack_int4_pair(qh, ql)
    x = jax.random.normal(jax.random.fold_in(k, 2), (M, K), jnp.bfloat16)
    us = _time_us(jax.jit(ref.dual_plane_matmul_ref), x, buf, sh, sl, n=5)
    Mp, Kp, Np = 128, 256, 256
    yh, yl = ops.dual_plane_matmul(x[:Mp, :Kp], buf[:Kp, :Np],
                                   sh[:, :Np], sl[:, :Np])
    rh, rl = ref.dual_plane_matmul_ref(x[:Mp, :Kp], buf[:Kp, :Np],
                                       sh[:, :Np], sl[:, :Np])
    err = max(_rel_err(yh, rh), _rel_err(yl, rl))
    bytes_dual = buf.size + M * K * 2 + 2 * M * N * 2
    bytes_two_bf16 = 2 * K * N * 2 + M * K * 2 + 2 * M * N * 2
    _record("dual_plane_matmul", us, bytes_kernel=bytes_dual,
            bytes_baseline=bytes_two_bf16, baseline="two_bf16_matmuls",
            parity_rel_err=err, flops=2 * 2 * M * K * N,
            extra="two_matmuls_one_buffer")


def bench_packed_kv_attention(seed: int = 0):
    B, KV, Hg, D, S = 8, 8, 4, 128, 8192
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (B, KV, Hg, D), jnp.bfloat16)
    kf = jax.random.normal(jax.random.fold_in(key, 1), (B, KV, S, D))
    vf = jax.random.normal(jax.random.fold_in(key, 2), (B, KV, S, D))
    kq, ks = quant.quantize_int4(kf, axis=-1)
    vq, vs = quant.quantize_int4(vf, axis=-1)
    kp = quant.pack_int4_pair(kq[..., 0::2], kq[..., 1::2])
    vp = quant.pack_int4_pair(vq[..., 0::2], vq[..., 1::2])
    ks2 = ks[..., 0].astype(jnp.bfloat16)
    vs2 = vs[..., 0].astype(jnp.bfloat16)
    lengths = jnp.full((B,), S, jnp.int32)
    us = _time_us(jax.jit(ref.packed_kv_attention_ref), q, kp, vp, ks2, vs2,
                  lengths, n=3)
    sl = (slice(0, 2), slice(0, 2), slice(0, 256))
    err = _rel_err(
        ops.packed_kv_attention(q[:2, :2], kp[sl], vp[sl], ks2[sl], vs2[sl],
                                jnp.array([100, 256], jnp.int32), bs=128),
        ref.packed_kv_attention_ref(q[:2, :2], kp[sl], vp[sl], ks2[sl],
                                    vs2[sl],
                                    jnp.array([100, 256], jnp.int32)))
    cache_packed = 2 * B * KV * S * (D // 2 + 2)
    cache_bf16 = 2 * B * KV * S * D * 2
    _record("packed_kv_attention", us, bytes_kernel=cache_packed,
            bytes_baseline=cache_bf16, baseline="bf16", parity_rel_err=err,
            extra=f"B{B}xKV{KV}xS{S}xD{D}")


def bench_packed_kv_attention_int8(seed: int = 0):
    B, KV, Hg, D, S = 2, 2, 4, 64, 512
    key = jax.random.PRNGKey(seed + 9)
    q = jax.random.normal(key, (B, KV, Hg, D), jnp.bfloat16)
    kf = jax.random.normal(jax.random.fold_in(key, 1), (B, KV, S, D))
    vf = jax.random.normal(jax.random.fold_in(key, 2), (B, KV, S, D))
    kq, ks = quant.quantize_int8(kf, axis=-1)
    vq, vs = quant.quantize_int8(vf, axis=-1)
    ks2 = ks[..., 0].astype(jnp.bfloat16)
    vs2 = vs[..., 0].astype(jnp.bfloat16)
    lengths = jnp.array([300, 512], jnp.int32)
    fn = jax.jit(lambda *a: ref.packed_kv_attention_ref(*a, kv_bits=8))
    us = _time_us(fn, q, kq, vq, ks2, vs2, lengths, n=3)
    err = _rel_err(
        ops.packed_kv_attention(q, kq, vq, ks2, vs2, lengths, bs=128,
                                kv_bits=8),
        ref.packed_kv_attention_ref(q, kq, vq, ks2, vs2, lengths, kv_bits=8))
    cache_int8 = 2 * B * KV * S * (D + 2)
    cache_bf16 = 2 * B * KV * S * D * 2
    _record("packed_kv_attention_int8", us, bytes_kernel=cache_int8,
            bytes_baseline=cache_bf16, baseline="bf16", parity_rel_err=err,
            extra=f"B{B}xKV{KV}xS{S}xD{D}")


def bench_quantize_pack_kv(seed: int = 0):
    """Fused bf16 -> packed int4 + scales (one pass) vs the unfused
    quantize-then-pack pipeline whose int8 intermediate round-trips HBM."""
    B, S, KV, D = 8, 4096, 8, 128
    kv = jax.random.normal(jax.random.PRNGKey(seed), (B, S, KV, D),
                           jnp.bfloat16)
    us = _time_us(jax.jit(ref.quantize_pack_kv_ref), kv, n=5)
    small = kv[:1, :16]
    p, s = ops.quantize_pack_kv(small)
    pr, sr = ref.quantize_pack_kv_ref(small)
    err = 0.0 if (np.array_equal(np.asarray(p), np.asarray(pr))
                  and np.array_equal(np.asarray(s, np.float32),
                                     np.asarray(sr.astype(jnp.bfloat16),
                                                np.float32))) else 1.0
    N = B * S * KV
    bytes_fused = N * D * 2 + N * (D // 2) + N * 4          # in+packed+scale
    bytes_unfused = bytes_fused + 2 * N * D                  # + int8 roundtrip
    _record("quantize_pack_kv", us, bytes_kernel=bytes_fused,
            bytes_baseline=bytes_unfused, baseline="unfused",
            parity_rel_err=err,
            extra=f"B{B}xS{S}xKV{KV}xD{D} (parity = bit-exactness)")


def bench_length_skipping(seed: int = 0):
    """Grid work ∝ length: the attention kernel's block-visit counter on a
    ragged batch, vs the blocks a length-blind kernel would touch."""
    B, KV, Hg, D, S, bs = 4, 2, 4, 64, 1024, 128
    key = jax.random.PRNGKey(seed + 3)
    q = jax.random.normal(key, (B, KV, Hg, D), jnp.bfloat16)
    kf = jax.random.normal(jax.random.fold_in(key, 1), (B, KV, S, D),
                           jnp.bfloat16)
    vf = jax.random.normal(jax.random.fold_in(key, 2), (B, KV, S, D),
                           jnp.bfloat16)
    kp, ks = ops.quantize_pack_kv(kf)
    vp, vs = ops.quantize_pack_kv(vf)
    lengths = jnp.array([12, 100, 512, 1024], jnp.int32)

    def run():
        return ops.packed_kv_attention(q, kp, vp, ks[..., 0], vs[..., 0],
                                       lengths, bs=bs, debug_visits=True)

    _, visits = run()                      # warmup: trace + compile
    us = _time_us(run, n=3)
    visited = int(jax.block_until_ready(visits).sum())
    total = B * KV * (S // bs)
    row("packed_kv_attention_length_skip", us,
        f"lengths={list(map(int, lengths))} bs={bs} "
        f"blocks_visited={visited} blocks_total={total} "
        f"grid_work_saved={1 - visited/total:.2%}")
    ROWS.append({"kernel": "packed_kv_attention_length_skip",
                 "blocks_visited": visited, "blocks_total": total,
                 "grid_work_saved": 1 - visited / total})


# ---------------------------------------------------------------------------
# Modeled per-decode-step HBM traffic (the TPU roofline's memory term)
# ---------------------------------------------------------------------------

# Full-scale stand-in dims (llama-8b-class) used when no cfg is given.
_MODEL_DIMS = dict(L=32, KV=8, hd=128, d=4096, f=14336, H=32)


def serve_hbm_model(cfg=None, *, batch=8, seq=8192, kv_mode="int4",
                    weight_mode="normal"):
    """Modeled per-decode-step HBM traffic: KV cache bytes (every decode
    step streams the whole valid cache) + weight bytes (every step reads
    every matmul weight once), per storage mode. This is the quantity the
    TPU roofline charges the decode loop."""
    dims = (_MODEL_DIMS if cfg is None else
            dict(L=cfg.n_layers, KV=cfg.n_kv_heads, hd=cfg.hd,
                 d=cfg.d_model, f=cfg.d_ff, H=cfg.n_heads))
    L_, KV, hd, d, f, H = (dims[k] for k in ("L", "KV", "hd", "d", "f", "H"))
    rows_ = batch * seq * KV * L_
    kv_bytes = {
        "normal": rows_ * hd * 2 * 2,            # K and V, bf16
        "int8": rows_ * (hd + 2) * 2,            # int8 + bf16 scale
        "int4": rows_ * (hd // 2 + 2) * 2,       # packed nibbles + scale
    }[kv_mode]
    attn_p = d * H * hd + 2 * d * KV * hd + H * hd * d
    mlp_p = 3 * d * f
    paired = 2 * d * KV * hd + 2 * d * f         # wk+wv, w_gate+w_up
    unpaired = attn_p + mlp_p - paired
    weight_bytes = {
        "normal": (attn_p + mlp_p) * 2.0,
        "ternary": (attn_p + mlp_p) * 0.25,      # 2-bit trits
        "dual": paired * 0.5 + unpaired * 2.0,   # int4 pairs share a byte
    }[weight_mode] * L_
    total = kv_bytes + weight_bytes
    baseline = rows_ * hd * 2 * 2 + (attn_p + mlp_p) * 2.0 * L_
    return {
        "kv_mode": kv_mode, "weight_mode": weight_mode,
        "kv_bytes": int(kv_bytes), "weight_bytes": int(weight_bytes),
        "total_bytes": int(total),
        "bf16_baseline_bytes": int(baseline),
        "traffic_ratio_vs_bf16": baseline / total,
        "decode_roofline_us": total / HBM_BW * 1e6,
    }


def run_all(*, seed: int = 0, tiny: bool = False) -> list[dict]:
    """Runs every kernel bench; returns the BENCH_kernels.json payload.
    ``tiny`` keeps one matmul and one cache kernel (the quantize-pack
    parity is bit-exactness, the cheapest meaningful smoke)."""
    ROWS.clear()
    bench_ternary_matmul(seed)
    if not tiny:
        bench_dual_plane_matmul(seed)
        bench_packed_kv_attention(seed)
        bench_packed_kv_attention_int8(seed)
    bench_quantize_pack_kv(seed)
    if not tiny:
        bench_length_skipping(seed)
    return ROWS
