"""Kernel micro-benchmarks.

On this CPU container Pallas kernels run in interpret mode (Python-speed),
so wall-clock there is meaningless; what we report per kernel is
  * the HBM bytes moved by the kernel vs its bf16 XLA equivalent (the
    quantity the TPU roofline actually charges), and
  * wall time of the jnp reference path as a CPU sanity number.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.paper_tables import row, _time_us
from repro.core import quant, ternary
from repro.kernels import ref
from repro.launch.mesh import HBM_BW, PEAK_BF16_FLOPS


def bench_ternary_matmul():
    M, K, N = 256, 4096, 4096
    w = jax.random.normal(jax.random.PRNGKey(0), (K, N))
    t, scale = ternary.ternarize(w)
    wp = ternary.pack_ternary_2bit(t)
    x = jax.random.normal(jax.random.PRNGKey(1), (M, K), jnp.bfloat16)
    us = _time_us(jax.jit(ref.ternary_matmul_ref), x, wp, scale, n=5)
    bytes_packed = wp.size + M * K * 2 + M * N * 2
    bytes_bf16 = K * N * 2 + M * K * 2 + M * N * 2
    flops = 2 * M * K * N
    roof_packed = max(bytes_packed / HBM_BW, flops / PEAK_BF16_FLOPS) * 1e6
    roof_bf16 = max(bytes_bf16 / HBM_BW, flops / PEAK_BF16_FLOPS) * 1e6
    row("ternary_matmul_ref_cpu", us,
        f"M{M}xK{K}xN{N} hbm_bytes={bytes_packed} vs_bf16={bytes_bf16} "
        f"traffic_ratio={bytes_bf16/bytes_packed:.2f}x "
        f"tpu_roofline_us={roof_packed:.2f} vs_bf16_us={roof_bf16:.2f}")


def bench_dual_plane_matmul():
    M, K, N = 256, 2048, 2048
    k = jax.random.PRNGKey(0)
    qh, sh = quant.quantize_int4(jax.random.normal(k, (K, N)), axis=0)
    ql, sl = quant.quantize_int4(
        jax.random.normal(jax.random.fold_in(k, 1), (K, N)), axis=0)
    buf = quant.pack_int4_pair(qh, ql)
    x = jax.random.normal(jax.random.fold_in(k, 2), (M, K), jnp.bfloat16)
    us = _time_us(jax.jit(ref.dual_plane_matmul_ref), x, buf, sh, sl, n=5)
    bytes_dual = buf.size + M * K * 2 + 2 * M * N * 2
    bytes_two_bf16 = 2 * K * N * 2 + M * K * 2 + 2 * M * N * 2
    row("dual_plane_matmul_ref_cpu", us,
        f"two_matmuls_one_buffer traffic_ratio="
        f"{bytes_two_bf16/bytes_dual:.2f}x")


def bench_packed_kv_attention():
    B, KV, Hg, D, S = 8, 8, 4, 128, 8192
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, KV, Hg, D), jnp.bfloat16)
    kf = jax.random.normal(jax.random.fold_in(key, 1), (B, KV, S, D))
    vf = jax.random.normal(jax.random.fold_in(key, 2), (B, KV, S, D))
    kq, ks = quant.quantize_int4(kf, axis=-1)
    vq, vs = quant.quantize_int4(vf, axis=-1)
    kp = quant.pack_int4_pair(kq[..., 0::2], kq[..., 1::2])
    vp = quant.pack_int4_pair(vq[..., 0::2], vq[..., 1::2])
    ks2 = ks[..., 0].astype(jnp.bfloat16)
    vs2 = vs[..., 0].astype(jnp.bfloat16)
    lengths = jnp.full((B,), S, jnp.int32)
    us = _time_us(jax.jit(ref.packed_kv_attention_ref), q, kp, vp, ks2, vs2,
                  lengths, n=3)
    cache_packed = 2 * B * KV * S * (D // 2 + 2)
    cache_bf16 = 2 * B * KV * S * D * 2
    row("packed_kv_attention_ref_cpu", us,
        f"B{B}xKV{KV}xS{S}xD{D} cache_bytes={cache_packed} "
        f"vs_bf16={cache_bf16} traffic_ratio={cache_bf16/cache_packed:.2f}x "
        f"decode_roofline_us={cache_packed/HBM_BW*1e6:.1f} "
        f"vs_bf16_us={cache_bf16/HBM_BW*1e6:.1f}")


def bench_quantize_pack_kv():
    """Fused bf16 -> packed int4 + scales (one pass) vs the unfused
    quantize-then-pack pipeline whose int8 intermediate round-trips HBM."""
    B, S, KV, D = 8, 4096, 8, 128
    kv = jax.random.normal(jax.random.PRNGKey(0), (B, S, KV, D),
                           jnp.bfloat16)
    us = _time_us(jax.jit(ref.quantize_pack_kv_ref), kv, n=5)
    N = B * S * KV
    bytes_fused = N * D * 2 + N * (D // 2) + N * 4          # in + packed + scale
    bytes_unfused = bytes_fused + 2 * N * D                  # + int8 roundtrip
    row("quantize_pack_kv_ref_cpu", us,
        f"B{B}xS{S}xKV{KV}xD{D} hbm_bytes={bytes_fused} "
        f"vs_unfused={bytes_unfused} "
        f"traffic_ratio={bytes_unfused/bytes_fused:.2f}x "
        f"tpu_roofline_us={bytes_fused/HBM_BW*1e6:.1f}")


def bench_length_skipping():
    """Grid work ∝ length: the attention kernel's block-visit counter on a
    ragged batch, vs the blocks a length-blind kernel would touch."""
    from repro.kernels import ops
    B, KV, Hg, D, S, bs = 4, 2, 4, 64, 1024, 128
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (B, KV, Hg, D), jnp.bfloat16)
    kf = jax.random.normal(jax.random.fold_in(key, 1), (B, KV, S, D),
                           jnp.bfloat16)
    vf = jax.random.normal(jax.random.fold_in(key, 2), (B, KV, S, D),
                           jnp.bfloat16)
    kp, ks = ops.quantize_pack_kv(kf)
    vp, vs = ops.quantize_pack_kv(vf)
    lengths = jnp.array([12, 100, 512, 1024], jnp.int32)

    def run():
        return ops.packed_kv_attention(q, kp, vp, ks[..., 0], vs[..., 0],
                                       lengths, bs=bs, debug_visits=True)

    _, visits = run()                      # warmup: trace + compile
    us = _time_us(run, n=3)
    visited = int(jax.block_until_ready(visits).sum())
    total = B * KV * (S // bs)
    row("packed_kv_attention_length_skip", us,
        f"lengths={list(map(int, lengths))} bs={bs} "
        f"blocks_visited={visited} blocks_total={total} "
        f"grid_work_saved={1 - visited/total:.2%}")


def serve_hbm_model(cfg=None, *, batch=8, seq=8192):
    """Modeled per-decode-step KV HBM traffic: packed int4 vs bf16 cache.
    This is the quantity the TPU roofline charges the decode loop."""
    L_, KV, hd = ((cfg.n_layers, cfg.n_kv_heads, cfg.hd) if cfg is not None
                  else (32, 8, 128))
    rows = batch * seq * KV * L_
    int4 = rows * (hd // 2 + 2) * 2          # K and V: packed + bf16 scale
    bf16 = rows * hd * 2 * 2
    return {"kv_int4_bytes": int4, "kv_bf16_bytes": bf16,
            "traffic_ratio": bf16 / int4,
            "decode_roofline_us_int4": int4 / HBM_BW * 1e6,
            "decode_roofline_us_bf16": bf16 / HBM_BW * 1e6}


def run_all():
    bench_ternary_matmul()
    bench_dual_plane_matmul()
    bench_packed_kv_attention()
    bench_quantize_pack_kv()
    bench_length_skipping()
